//! Fleet planner: size a production fleet for a workload + SLO across
//! every topology × GPU generation, find the FleetOpt optimum (B_short,
//! γ*), and verify the analytical prediction against the discrete-event
//! simulator — the full inference-fleet-sim workflow of paper §4.
//!
//! ```bash
//! cargo run --release --example fleet_planner [azure|lmsys|agent]
//! ```

use std::sync::Arc;

use wattlaw::fleet::analysis::fleet_tpw_analysis;
use wattlaw::fleet::optimizer::optimize_fleetopt;
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use wattlaw::fleet::topology::{Topology, LONG_CTX};
use wattlaw::power::Gpu;
use wattlaw::router::context::ContextRouter;
use wattlaw::router::HomogeneousRouter;
use wattlaw::sim::{simulate_topology, GroupSimConfig};
use wattlaw::workload::cdf::{agent_heavy, azure_conversations, lmsys_chat};
use wattlaw::workload::synth::{generate, GenConfig};

fn main() -> anyhow::Result<()> {
    let trace = match std::env::args().nth(1).as_deref() {
        Some("lmsys") => lmsys_chat(),
        Some("agent") => agent_heavy(),
        _ => azure_conversations(),
    };
    let (lambda, rho, slo) = (1000.0, 0.85, 0.5);
    println!(
        "== planning for {} at λ={lambda} req/s, ρ={rho}, P99 TTFT ≤ {slo}s ==",
        trace.name
    );

    // 1. Topology × generation grid.
    let b = trace.paper_b_short;
    let topos = [
        Topology::Homogeneous { ctx: LONG_CTX },
        Topology::PoolRouting { b_short: b, short_ctx: b.max(2048) },
        Topology::FleetOpt { b_short: b, short_ctx: b.max(2048), gamma: 2.0 },
    ];
    println!(
        "\n{:<28} {:<11} {:>7} {:>9} {:>8}",
        "topology", "gpu", "groups", "kW", "tok/W"
    );
    let mut baseline = None;
    for gpu in [Gpu::H100, Gpu::B200] {
        let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
        for topo in &topos {
            let pools = topo.pools(
                &trace, lambda, profile.clone(), None,
                LBarPolicy::Window, rho, slo);
            let r = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
            let vs = match baseline {
                None => {
                    baseline = Some(r.tok_per_watt.0);
                    String::from("(baseline)")
                }
                Some(b0) => format!("({:+.0}%)", (r.tok_per_watt.0 / b0 - 1.0) * 100.0),
            };
            println!(
                "{:<28} {:<11} {:>7} {:>9.1} {:>8.2} {vs}",
                topo.label(),
                gpu.spec().name,
                r.total_groups,
                r.total_power.kw(),
                r.tok_per_watt.0
            );
        }
    }

    // 2. FleetOpt optimum.
    let h100: Arc<dyn GpuProfile> = Arc::new(ManualProfile::h100_70b());
    let best = optimize_fleetopt(
        &trace, lambda, h100.clone(), LBarPolicy::Window, rho, slo,
        PowerAccounting::PerGpu);
    println!(
        "\nFleetOpt optimum on H100: B_short = {}, γ* = {} → {:.2} tok/W",
        best.b_short, best.gamma, best.report.tok_per_watt.0
    );

    // 3. Validate the topology ordering dynamically (scaled-down DES).
    let sim_reqs = generate(
        &trace,
        &GenConfig {
            lambda_rps: 40.0,
            duration_s: 5.0,
            max_prompt_tokens: 60_000,
            max_output_tokens: 1024,
            seed: 11,
        },
    );
    let p = ManualProfile::h100_70b();
    let mk = |window: u32| GroupSimConfig {
        window_tokens: window,
        n_max: p.n_max(window),
        roofline: p.roofline(),
        power: p.gpu.power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    let homo = simulate_topology(&sim_reqs, &HomogeneousRouter, &[4], &[mk(LONG_CTX)]);
    let routed = simulate_topology(
        &sim_reqs,
        &ContextRouter::two_pool(b),
        &[2, 2],
        &[mk(b.max(2048) + 1024), mk(LONG_CTX)],
    );
    println!(
        "\nDES check (4 groups, λ=40): homo {:.2} tok/W vs routed {:.2} tok/W \
         → simulated gain {:.2}x",
        homo.tok_per_watt,
        routed.tok_per_watt,
        routed.tok_per_watt / homo.tok_per_watt
    );
    anyhow::ensure!(routed.tok_per_watt > homo.tok_per_watt);
    println!("fleet_planner OK");
    Ok(())
}
