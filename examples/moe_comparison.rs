//! The MoE architecture lever (paper §3.2): dense vs mixture-of-experts
//! token economy across context windows, with the dispatch-overhead
//! sensitivity sweep that bounds the paper's "upper bound" caveat, plus
//! the §5.2 quantization sweep.
//!
//! ```bash
//! cargo run --release --example moe_comparison
//! ```

use wattlaw::fleet::profile::{ComputedProfile, PowerAccounting};
use wattlaw::model::spec::{
    DEEPSEEK_V3, LLAMA31_70B, QWEN3_235B_A22B,
};
use wattlaw::model::KvPlacement;
use wattlaw::power::profiles::{B200, H100};
use wattlaw::roofline::moe::{breakeven_dispatch_ms, dispatch_erosion};
use wattlaw::roofline::quant::quant_sweep;
use wattlaw::tokeconomy::operating_point;

fn main() -> anyhow::Result<()> {
    // 1. Dense vs MoE across context windows on H100.
    println!("single-GPU tok/W at n_max (ComputedProfile, H100 vs B200):");
    println!(
        "{:<18} {:>7} {:>11} {:>11} {:>11}",
        "model", "ctx", "H100 tok/W", "B200 tok/W", "gen gain"
    );
    for model in [&LLAMA31_70B, &QWEN3_235B_A22B, &DEEPSEEK_V3] {
        for ctx in [4096u32, 8192, 32_768] {
            let h = ComputedProfile::new(&H100, model, 8, KvPlacement::Replicated);
            let b = ComputedProfile::new(&B200, model, 8, KvPlacement::Replicated);
            let oh = operating_point(&h, ctx, 1.0, PowerAccounting::PerGpu);
            let ob = operating_point(&b, ctx, 1.0, PowerAccounting::PerGpu);
            println!(
                "{:<18} {:>7} {:>11.2} {:>11.2} {:>10.2}x",
                model.name,
                ctx,
                oh.tok_per_watt.0,
                ob.tok_per_watt.0,
                ob.tok_per_watt.0 / oh.tok_per_watt.0
            );
        }
    }

    // 2. Dispatch-overhead erosion (the Table 2 "upper bound" caveat).
    println!("\nMoE dispatch-overhead sensitivity (Qwen3 vs dense 70B, H100, n=2):");
    let grid = [0.0, 1.0, 2.0, 5.0, 10.0, 20.0];
    for row in dispatch_erosion(
        &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 2.0, 8192.0, &grid)
    {
        println!(
            "  dispatch {:>5.1} ms: MoE {:>7.0} tok/s vs dense {:>6.0} tok/s \
             → advantage {:.2}x",
            row.dispatch_ms, row.moe_tok_s, row.dense_tok_s, row.ratio
        );
    }
    let be = breakeven_dispatch_ms(&H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 2.0, 8192.0);
    println!("  break-even dispatch: {be:.1} ms (advantage gone beyond this)");

    // 3. §5.2 quantization sweep for the dense baseline.
    println!("\nquantization sweep (dense 70B on H100, n=16, L̄=8K):");
    for row in quant_sweep(&H100, &LLAMA31_70B, 8, KvPlacement::Sharded, 16.0, 8192.0) {
        println!(
            "  {:<5} W = {:>5.2} ms → {:>6.0} tok/s ({:.2}x vs fp16)",
            row.precision.label(),
            row.w_ms,
            row.throughput_tok_s,
            row.speedup_vs_fp16
        );
    }

    println!("\nmoe_comparison OK");
    Ok(())
}
