//! Quickstart: load the AOT artifact, validate numerics against the JAX
//! golden trace, prefill a prompt batch, decode a few tokens, and compute
//! single-GPU tok/W from the paper-calibrated models.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use wattlaw::fleet::profile::{ManualProfile, PowerAccounting};
use wattlaw::runtime::TinyModel;
use wattlaw::tokeconomy::operating_point;

fn main() -> anyhow::Result<()> {
    // ---- 1. The analytical core: the 1/W law in four lines. -----------
    let h100 = ManualProfile::h100_70b();
    println!("The 1/W law on the calibrated H100/70B profile:");
    for ctx in [4096u32, 8192, 16384, 65536] {
        let op = operating_point(&h100, ctx, 1.0, PowerAccounting::PerGpu);
        println!(
            "  context {:>6}: n_max {:>4}, {:>6.0} tok/s at {:>3.0} W -> {:.2} tok/W",
            ctx, op.n_max, op.throughput_tok_s, op.power.0, op.tok_per_watt.0
        );
    }

    // ---- 2. The real model: load, validate, prefill, decode. -----------
    let artifacts = wattlaw::runtime::default_artifacts_dir();
    println!("\nloading AOT artifacts from {} ...", artifacts.display());
    let model = TinyModel::load(&artifacts)?;
    let err = model.validate_golden()?;
    println!("golden check vs JAX: max |err| = {err:.2e}");
    anyhow::ensure!(err < 1e-3, "numerics drift");

    let b = model.cfg.batch as usize;
    let t = model.cfg.prefill_len as usize;
    // A batch of toy prompts (token ids are synthetic; the energy study is
    // length-shaped).
    let tokens: Vec<i32> = (0..b * t).map(|i| (i % 37) as i32).collect();
    let lens: Vec<i32> = (0..b).map(|i| 4 + (i as i32 * 3) % 28).collect();
    let (last_logits, mut kv_k, mut kv_v) = model.prefill(&tokens, &lens)?;
    let mut next = model.argmax(&last_logits);
    println!("prefilled {b} prompts (lens {lens:?}); first sampled tokens: {next:?}");

    let mut pos: Vec<i32> = lens.clone();
    let t0 = std::time::Instant::now();
    let steps = 16;
    for _ in 0..steps {
        let (logits, k, v) = model.decode_step(&next, &kv_k, &kv_v, &pos)?;
        kv_k = k;
        kv_v = v;
        next = model.argmax(&logits);
        for p in &mut pos {
            *p += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{steps} decode steps x batch {b}: {:.1} ms/step, {:.0} tok/s on CPU PJRT",
        dt / steps as f64 * 1e3,
        (steps * b) as f64 / dt
    );
    println!("\nquickstart OK");
    Ok(())
}
