//! End-to-end serving demo — the repository's E2E validation run
//! (recorded in EXPERIMENTS.md): an LMSYS-like trace is routed by prompt
//! length across a two-pool topology, each pool running the real
//! AOT-compiled model under continuous batching with paged-KV admission;
//! per-pool energy is metered on the paper-calibrated H100 logistic with
//! the pool's emulated window (short = 4K, long = 64K).
//!
//! The expected result is the 1/W law, live: the short pool sustains
//! ~4x the concurrency of the long pool from the same KV budget and
//! lands several times higher tok/W, and the routed fleet beats the
//! homogeneous baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace
//! ```

use wattlaw::router::context::ContextRouter;
use wattlaw::router::HomogeneousRouter;
use wattlaw::serve::{render_report, serve_trace, EngineConfig, PoolSpec};

fn main() -> anyhow::Result<()> {
    let artifacts = wattlaw::runtime::default_artifacts_dir();
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    // Deterministic demo mix: 75 % short prompts (16–96 tokens), 25 %
    // long (224–376) — the short-dominant archetype at tiny-model scale.
    let mut reqs: Vec<wattlaw::workload::Request> = Vec::new();
    let mut rng = wattlaw::xrand::Rng::new(7);
    for id in 0..n_requests as u64 {
        let prompt_tokens = if id % 4 == 3 {
            rng.range_u64(224, 376) as u32
        } else {
            rng.range_u64(16, 96) as u32
        };
        reqs.push(wattlaw::workload::Request {
            id,
            arrival_s: 0.0,
            prompt_tokens,
            output_tokens: rng.range_u64(8, 32) as u32,
        });
    }
    let short = reqs.iter().filter(|r| r.prompt_tokens <= 128).count();
    println!(
        "serving {} requests ({} short / {} long) through the real model",
        reqs.len(),
        short,
        reqs.len() - short
    );

    // Two-pool context routing, both pools drawing on the same virtual KV
    // budget (16 x 64-token blocks): short holds 8 sequences, long ~2.
    let routed_pools = vec![
        PoolSpec {
            name: "short".into(),
            config: EngineConfig::for_window(128, 16)
                .with_ingest_slots(8)
                .emulating_h100(4096),
        },
        PoolSpec {
            name: "long".into(),
            config: EngineConfig::for_window(480, 16)
                .with_ingest_slots(8)
                .emulating_h100(65_536),
        },
    ];
    let routed = serve_trace(
        &artifacts,
        &ContextRouter::two_pool(128),
        &routed_pools,
        &reqs,
    )?;
    println!("{}", render_report(&routed));

    // Homogeneous baseline: every request through the long-window pool.
    let homo_pools = vec![PoolSpec {
        name: "homo".into(),
        config: EngineConfig::for_window(480, 16)
                .with_ingest_slots(8)
                .emulating_h100(65_536),
    }];
    let homo = serve_trace(&artifacts, &HomogeneousRouter, &homo_pools, &reqs)?;
    println!("{}", render_report(&homo));

    let gain = routed.tok_per_watt / homo.tok_per_watt;
    println!("topology gain, real model end-to-end: {gain:.2}x");
    anyhow::ensure!(
        gain > 1.2,
        "routing must beat homogeneous on a short-dominant trace"
    );
    anyhow::ensure!(routed.golden_max_err < 1e-3);
    println!("serve_trace OK");
    Ok(())
}
