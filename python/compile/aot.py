"""AOT compile path: lower the L2 model (with its L1 Pallas kernel) to HLO
text artifacts the Rust runtime loads via PJRT.

Run once at build time (``make artifacts``); Python never runs at serve
time.  Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Outputs (in --out-dir, default ../artifacts):
  decode_step.hlo.txt   one continuous-batching decode iteration
  prefill.hlo.txt       prompt ingestion filling the KV cache
  weights.bin           deterministic tiny-Llama weights (WLW1 container)
  golden.bin            input/output pairs for Rust-side numeric validation
  manifest.json         shapes, parameter order, artifact signatures
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import struct
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as m

MAGIC = b"WLW1"
DTYPE_CODES = {"float32": 0, "int32": 1}


def write_container(path: Path, tensors: "dict[str, np.ndarray]") -> None:
    """WLW1 container: magic, u32 count, then per tensor
    (u32 name_len, name, u8 dtype, u8 ndim, u64*dims, raw LE data)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = DTYPE_CODES[str(arr.dtype)]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_golden(params, cfg: m.ModelConfig):
    """Deterministic end-to-end trace: prefill a prompt batch, then two
    decode steps.  The Rust runtime must reproduce every output tensor."""
    key = jax.random.PRNGKey(7)
    B, T = cfg.batch, cfg.prefill_len
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab, dtype=jnp.int32)
    # Varied prompt lengths exercise the masking path.
    lens = jnp.array(
        [1 + (3 * i + 5) % T for i in range(B)], dtype=jnp.int32
    )

    last_logits, kv_k, kv_v = m.prefill(params, tokens, lens, cfg)
    next_tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

    pos0 = lens  # first decode position is the slot after the prompt
    logits1, kv_k1, kv_v1 = m.decode_step(
        params, next_tok, kv_k, kv_v, pos0, cfg
    )
    tok1 = jnp.argmax(logits1, axis=-1).astype(jnp.int32)
    logits2, kv_k2, kv_v2 = m.decode_step(
        params, tok1, kv_k1, kv_v1, pos0 + 1, cfg
    )

    g = {
        "prefill.in.tokens": tokens,
        "prefill.in.lens": lens,
        "prefill.out.last_logits": last_logits,
        "decode1.in.tokens": next_tok,
        "decode1.in.pos": pos0,
        "decode1.out.logits": logits1,
        "decode2.in.tokens": tok1,
        "decode2.in.pos": pos0 + 1,
        "decode2.out.logits": logits2,
    }
    return {k: np.asarray(v) for k, v in g.items()}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--kernel", choices=["single", "paged"], default="single",
        help="L1 decode-attention kernel variant to lower into the "
             "artifact (single is fastest under the CPU Pallas "
             "interpreter; paged is the TPU-shaped schedule)",
    )
    args = ap.parse_args()
    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    cfg = m.ModelConfig(attention_kernel=args.kernel)
    params = m.init_params(jax.random.PRNGKey(args.seed), cfg)

    # --- weights -----------------------------------------------------------
    write_container(
        out / "weights.bin",
        {name: np.asarray(params[name]) for name in m.PARAM_ORDER},
    )

    # --- lower both entry points ------------------------------------------
    B, T, V = cfg.batch, cfg.prefill_len, cfg.vocab
    kv_spec = jax.ShapeDtypeStruct(cfg.kv_shape(), jnp.float32)
    i32 = jnp.int32
    param_specs = [
        jax.ShapeDtypeStruct(np.asarray(params[n]).shape, jnp.float32)
        for n in m.PARAM_ORDER
    ]

    decode_fn = jax.jit(
        lambda *a: m.decode_step_flat(*a, cfg=cfg, interpret=True)
    )
    decode_lowered = decode_fn.lower(
        *param_specs,
        jax.ShapeDtypeStruct((B,), i32),        # tokens
        kv_spec, kv_spec,                        # kv_k, kv_v
        jax.ShapeDtypeStruct((B,), i32),        # pos
    )
    (out / "decode_step.hlo.txt").write_text(to_hlo_text(decode_lowered))

    prefill_fn = jax.jit(lambda *a: m.prefill_flat(*a, cfg=cfg))
    prefill_lowered = prefill_fn.lower(
        *param_specs,
        jax.ShapeDtypeStruct((B, T), i32),      # tokens
        jax.ShapeDtypeStruct((B,), i32),        # lens
    )
    (out / "prefill.hlo.txt").write_text(to_hlo_text(prefill_lowered))

    # --- golden trace -------------------------------------------------------
    write_container(out / "golden.bin", build_golden(params, cfg))

    # --- manifest ------------------------------------------------------------
    manifest = {
        "config": dataclasses.asdict(cfg),
        "param_order": list(m.PARAM_ORDER),
        "param_shapes": {
            n: list(np.asarray(params[n]).shape) for n in m.PARAM_ORDER
        },
        "artifacts": {
            "decode_step": {
                "file": "decode_step.hlo.txt",
                "inputs": list(m.PARAM_ORDER)
                + ["tokens[B]i32", "kv_k[L,B,S,Hkv,D]f32",
                   "kv_v[L,B,S,Hkv,D]f32", "pos[B]i32"],
                "outputs": ["logits[B,V]f32", "kv_k'", "kv_v'"],
            },
            "prefill": {
                "file": "prefill.hlo.txt",
                "inputs": list(m.PARAM_ORDER) + ["tokens[B,T]i32", "lens[B]i32"],
                "outputs": ["last_logits[B,V]f32", "kv_k", "kv_v"],
            },
        },
        "kv_bytes_per_token": cfg.kv_bytes_per_token(),
        "attention_kernel": cfg.attention_kernel,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))

    print(f"wrote artifacts to {out.resolve()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
