"""L1 Pallas kernels: batched GQA decode attention over a (paged) KV cache.

This is the compute hot-spot behind the paper's roofline term
``H(L_bar) * n``: every decode iteration streams the whole KV cache of every
in-flight sequence past the compute units once.  The kernels are written the
way a TPU implementation would be structured (BlockSpec tiling of the
HBM->VMEM stream over KV pages, online-softmax accumulation so a page never
needs to be revisited), but are lowered with ``interpret=True`` because the
CPU PJRT plugin cannot execute Mosaic custom-calls.  See DESIGN.md
"Hardware adaptation" and section 9 for the VMEM/MXU estimates.

Two variants:

* :func:`decode_attention` - single-block kernel, one grid step per batch
  element; the whole KV cache of that sequence is one block.  Simplest
  correct form; used as a cross-check.
* :func:`decode_attention_paged` - the TPU-shaped kernel.  Grid is
  ``(batch, num_pages)``; the KV cache is streamed page by page with a
  running (max, sum, acc) online softmax, which is exactly the
  flash-decoding schedule the paper's ``H`` term models.  This is the
  variant the L2 model lowers into the AOT artifact.

Both are validated against the pure-jnp oracle in :mod:`ref` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes, dtypes, and
sequence lengths).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Page size of the paged variant.  64 tokens * (Hkv*D) * 2 bytes is a few
# KiB per page per head -- far below VMEM limits; the grid streams pages
# sequentially so only two pages (double-buffered) are resident at a time.
PAGE_TOKENS = 64

_NEG_INF = -1e30


def _gqa_scores(q, k, scale):
    """Grouped-query attention scores.

    q: [Hkv, G, D] (query heads folded into Hkv groups of G)
    k: [S, Hkv, D]
    returns [Hkv, G, S]
    """
    return jnp.einsum("hgd,shd->hgs", q, k) * scale


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, n_kv_heads):
    """One batch element, whole KV cache in one block."""
    q = q_ref[0]  # [Hq, D]
    k = k_ref[0]  # [S, Hkv, D]
    v = v_ref[0]  # [S, Hkv, D]
    seq_len = len_ref[0]  # scalar int32: number of valid KV positions

    n_q_heads, head_dim = q.shape
    s = k.shape[0]
    group = n_q_heads // n_kv_heads
    scale = 1.0 / math.sqrt(head_dim)

    qg = q.reshape(n_kv_heads, group, head_dim)
    scores = _gqa_scores(qg.astype(jnp.float32), k.astype(jnp.float32), scale)

    # Mask KV slots at or beyond the sequence's current length.
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, s), 2)
    scores = jnp.where(pos < seq_len, scores, _NEG_INF)

    attn = jax.nn.softmax(scores, axis=-1)  # [Hkv, G, S]
    out = jnp.einsum("hgs,shd->hgd", attn, v.astype(jnp.float32))
    o_ref[0] = out.reshape(n_q_heads, head_dim).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, seq_lens, *, interpret=True):
    """Single-block GQA decode attention.

    Args:
      q:        [B, Hq, D] current-step queries.
      k_cache:  [B, S, Hkv, D] keys for all past positions (padded to S).
      v_cache:  [B, S, Hkv, D] values.
      seq_lens: [B] int32, valid KV length per sequence (including the
                current token, whose K/V must already be written).
      interpret: run under the Pallas interpreter (required on CPU PJRT).

    Returns:
      [B, Hq, D] attention outputs, dtype of ``q``.
    """
    batch, n_q_heads, head_dim = q.shape
    _, s, n_kv_heads, _ = k_cache.shape
    if n_q_heads % n_kv_heads:
        raise ValueError(f"Hq={n_q_heads} not divisible by Hkv={n_kv_heads}")

    kernel = functools.partial(_decode_attn_kernel, n_kv_heads=n_kv_heads)
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n_q_heads, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s, n_kv_heads, head_dim), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, s, n_kv_heads, head_dim), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((1, n_q_heads, head_dim), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_q_heads, head_dim), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, seq_lens)


def _paged_kernel(q_ref, k_ref, v_ref, len_ref, acc_ref, m_ref, l_ref, *,
                  n_kv_heads, num_pages):
    """Online-softmax page-streaming kernel body.

    Grid: (batch, page).  The page axis is sequential ("arbitrary"
    dimension semantics on TPU), so (acc, m, l) accumulate across pages in
    the output refs; the caller finalizes with ``acc / l``.

    Block shapes (per grid step):
      q: [1, Hq, D]          -- revisited every page (stays in VMEM on TPU)
      k/v: [1, PAGE, Hkv, D] -- the HBM->VMEM stream the 1/W law meters
      acc: [1, Hq, D], m/l: [1, Hq] -- running accumulator state
    """
    page = pl.program_id(1)
    q = q_ref[0]  # [Hq, D]
    k = k_ref[0]  # [P, Hkv, D]
    v = v_ref[0]
    seq_len = len_ref[0]

    n_q_heads, head_dim = q.shape
    p = k.shape[0]
    group = n_q_heads // n_kv_heads
    scale = 1.0 / math.sqrt(head_dim)

    @pl.when(page == 0)
    def _init():
        acc_ref[0] = jnp.zeros_like(acc_ref[0])
        m_ref[0] = jnp.full_like(m_ref[0], _NEG_INF)
        l_ref[0] = jnp.zeros_like(l_ref[0])

    qg = q.reshape(n_kv_heads, group, head_dim).astype(jnp.float32)
    scores = _gqa_scores(qg, k.astype(jnp.float32), scale)  # [Hkv, G, P]

    # Global KV position of each slot in this page.
    pos = page * p + jax.lax.broadcasted_iota(jnp.int32, (1, 1, p), 2)
    scores = jnp.where(pos < seq_len, scores, _NEG_INF)

    m_prev = m_ref[0].reshape(n_kv_heads, group)  # [Hkv, G]
    l_prev = l_ref[0].reshape(n_kv_heads, group)
    acc_prev = acc_ref[0].reshape(n_kv_heads, group, head_dim)

    m_page = jnp.max(scores, axis=-1)  # [Hkv, G]
    m_new = jnp.maximum(m_prev, m_page)
    # Rescale factor for previously accumulated state.
    alpha = jnp.exp(m_prev - m_new)  # [Hkv, G]
    probs = jnp.exp(scores - m_new[..., None])  # [Hkv, G, P]

    l_new = l_prev * alpha + jnp.sum(probs, axis=-1)
    acc_new = acc_prev * alpha[..., None] + jnp.einsum(
        "hgp,phd->hgd", probs, v.astype(jnp.float32)
    )

    m_ref[0] = m_new.reshape(n_q_heads)
    l_ref[0] = l_new.reshape(n_q_heads)
    acc_ref[0] = acc_new.reshape(n_q_heads, head_dim)


def decode_attention_paged(q, k_cache, v_cache, seq_lens, *,
                           page_tokens=PAGE_TOKENS, interpret=True):
    """Page-streamed GQA decode attention with online softmax.

    Same contract as :func:`decode_attention`; ``S`` must be a multiple of
    ``page_tokens``.  This is the kernel variant lowered into the AOT
    artifact (see python/compile/model.py).
    """
    batch, n_q_heads, head_dim = q.shape
    _, s, n_kv_heads, _ = k_cache.shape
    if n_q_heads % n_kv_heads:
        raise ValueError(f"Hq={n_q_heads} not divisible by Hkv={n_kv_heads}")
    if s % page_tokens:
        raise ValueError(f"S={s} not a multiple of page_tokens={page_tokens}")
    num_pages = s // page_tokens

    kernel = functools.partial(
        _paged_kernel, n_kv_heads=n_kv_heads, num_pages=num_pages
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(batch, num_pages),
        in_specs=[
            pl.BlockSpec((1, n_q_heads, head_dim), lambda b, s_: (b, 0, 0)),
            pl.BlockSpec(
                (1, page_tokens, n_kv_heads, head_dim),
                lambda b, s_: (b, s_, 0, 0),
            ),
            pl.BlockSpec(
                (1, page_tokens, n_kv_heads, head_dim),
                lambda b, s_: (b, s_, 0, 0),
            ),
            pl.BlockSpec((1,), lambda b, s_: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_q_heads, head_dim), lambda b, s_: (b, 0, 0)),
            pl.BlockSpec((1, n_q_heads), lambda b, s_: (b, 0)),
            pl.BlockSpec((1, n_q_heads), lambda b, s_: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, n_q_heads, head_dim), jnp.float32),
            jax.ShapeDtypeStruct((batch, n_q_heads), jnp.float32),
            jax.ShapeDtypeStruct((batch, n_q_heads), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, seq_lens)

    out = acc / l[..., None]
    return out.astype(q.dtype)
