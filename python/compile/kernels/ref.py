"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel variant must match these
to tight tolerances across the hypothesis shape/dtype sweep in
``python/tests/test_kernel.py``.  No pallas, no tricks -- just the textbook
definition of masked grouped-query attention.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, seq_lens):
    """Reference GQA decode attention.

    Args:
      q:        [B, Hq, D]
      k_cache:  [B, S, Hkv, D]
      v_cache:  [B, S, Hkv, D]
      seq_lens: [B] int32 valid KV lengths

    Returns:
      [B, Hq, D] in ``q``'s dtype (accumulation in f32).
    """
    batch, n_q_heads, head_dim = q.shape
    _, s, n_kv_heads, _ = k_cache.shape
    group = n_q_heads // n_kv_heads
    scale = 1.0 / math.sqrt(head_dim)

    qg = q.reshape(batch, n_kv_heads, group, head_dim).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)

    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale  # [B,Hkv,G,S]
    pos = jnp.arange(s)[None, None, None, :]
    mask = pos < seq_lens[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)

    # Stable softmax; fully-masked rows cannot occur (seq_lens >= 1).
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)

    out = jnp.einsum("bhgs,bshd->bhgd", probs, v)
    return out.reshape(batch, n_q_heads, head_dim).astype(q.dtype)


def mha_prefill_ref(q, k, v, seq_lens):
    """Reference causal GQA prefill attention.

    Args:
      q: [B, T, Hq, D]; k/v: [B, T, Hkv, D]; seq_lens: [B] valid prompt lens.

    Returns: [B, T, Hq, D].  Positions >= seq_len attend only inside the
    causal window and are ignored by callers.
    """
    batch, t, n_q_heads, head_dim = q.shape
    n_kv_heads = k.shape[2]
    group = n_q_heads // n_kv_heads
    scale = 1.0 / math.sqrt(head_dim)

    qg = q.reshape(batch, t, n_kv_heads, group, head_dim).astype(jnp.float32)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k.astype(jnp.float32)) * scale

    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    causal = j <= i  # [T, S]
    valid = jnp.arange(t)[None, :] < seq_lens[:, None]  # [B, S]
    mask = causal[None, None, None] & valid[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)

    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v.astype(jnp.float32))
    return out.reshape(batch, t, n_q_heads, head_dim).astype(q.dtype)
