"""L2: the serving-demo model -- a tiny Llama-style decoder in JAX.

This is the compute graph the Rust coordinator serves.  It is deliberately
small (CPU PJRT executes it on the request path) but architecturally real:
token embedding, RoPE, grouped-query attention (decode attention is the L1
Pallas kernel), RMSNorm, SwiGLU MLP, tied output head, and an explicit
externally-owned KV cache -- the same memory object whose capacity limit
gives rise to the paper's 1/W law.

Entry points (both lowered AOT to HLO text by ``aot.py``):

* :func:`prefill`      -- fill the KV cache from a (padded) prompt batch.
* :func:`decode_step`  -- one continuous-batching decode iteration.

Weights are *runtime inputs*, not baked constants: the artifact stays small
and the weight tensors stream HBM->compute each step exactly like the
``W_ms`` term in the paper's roofline.  Python never runs at serve time;
Rust feeds weights (from ``artifacts/weights.bin``), tokens, KV literals and
positions into the compiled executable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.decode_attention import (
    decode_attention,
    decode_attention_paged,
)
from compile.kernels.ref import mha_prefill_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration for one AOT artifact."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_q_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 688
    max_seq: int = 512       # S: KV-cache slots per sequence
    batch: int = 8           # B: decode batch (the paper's n_act knob)
    prefill_len: int = 64    # T: padded prompt length per prefill call
    rope_theta: float = 10000.0
    # Which L1 Pallas kernel the decode step lowers: "single" (one grid
    # step per batch element; fastest under the CPU interpreter) or
    # "paged" (page-streamed online-softmax; the TPU-shaped schedule).
    # Both are validated against the same oracle.
    attention_kernel: str = "single"

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def kv_shape(self) -> Tuple[int, int, int, int, int]:
        """KV cache shape: [L, B, S, Hkv, D]."""
        return (self.n_layers, self.batch, self.max_seq,
                self.n_kv_heads, self.head_dim)

    def kv_bytes_per_token(self) -> int:
        """kappa for this model in f32 -- mirrored by the Rust model catalog."""
        return 2 * 4 * self.n_layers * self.n_kv_heads * self.head_dim


# Deterministic parameter order for weights.bin / the HLO signature.
PARAM_ORDER = (
    "embed",        # [V, d]
    "attn_norm",    # [L, d]
    "wq",           # [L, d, Hq*D]
    "wk",           # [L, d, Hkv*D]
    "wv",           # [L, d, Hkv*D]
    "wo",           # [L, Hq*D, d]
    "mlp_norm",     # [L, d]
    "w_gate",       # [L, d, f]
    "w_up",         # [L, d, f]
    "w_down",       # [L, f, d]
    "final_norm",   # [d]
)


def init_params(key, cfg: ModelConfig) -> Dict[str, jax.Array]:
    """Normal(0, scale) init; output head is tied to the embedding."""
    keys = jax.random.split(key, len(PARAM_ORDER))
    kmap = dict(zip(PARAM_ORDER, keys))
    s = 0.05
    L, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff

    def norm(shape):
        return jnp.ones(shape, jnp.float32)

    def rand(k, shape, scale=s):
        return (jax.random.normal(k, shape, jnp.float32) * scale)

    return {
        "embed": rand(kmap["embed"], (cfg.vocab, d), 0.6),
        "attn_norm": norm((L, d)),
        "wq": rand(kmap["wq"], (L, d, cfg.q_dim)),
        "wk": rand(kmap["wk"], (L, d, cfg.kv_dim)),
        "wv": rand(kmap["wv"], (L, d, cfg.kv_dim)),
        "wo": rand(kmap["wo"], (L, cfg.q_dim, d)),
        "mlp_norm": norm((L, d)),
        "w_gate": rand(kmap["w_gate"], (L, d, f)),
        "w_up": rand(kmap["w_up"], (L, d, f)),
        "w_down": rand(kmap["w_down"], (L, f, d)),
        "final_norm": norm((d,)),
    }


def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta: float):
    """Rotary embedding.  x: [..., H, D]; positions broadcastable to x[...,0,0]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _stacked(params):
    """Per-layer parameter pytree for lax.scan (leading L axis)."""
    return {
        k: params[k]
        for k in ("attn_norm", "wq", "wk", "wv", "wo",
                  "mlp_norm", "w_gate", "w_up", "w_down")
    }


def decode_step(params, tokens, kv_k, kv_v, pos, cfg: ModelConfig,
                *, interpret=True):
    """One decode iteration for a continuous batch.

    Args:
      params: dict per PARAM_ORDER.
      tokens: [B] int32 current token per slot.
      kv_k, kv_v: [L, B, S, Hkv, D] caches (slots >= pos are stale).
      pos: [B] int32 position the current token occupies (0-based).
      cfg: static shapes.

    Returns:
      (logits [B, V], kv_k', kv_v') -- caches with the current token's K/V
      written at ``pos``; attention sees lengths ``pos + 1``.
    """
    B = cfg.batch
    x = params["embed"][tokens]  # [B, d]
    seq_lens = pos + 1

    def layer(x, xs):
        lp, kvk_l, kvv_l = xs
        h = rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(B, cfg.n_q_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, cfg.n_kv_heads, cfg.head_dim)

        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

        # Write this step's K/V into each sequence's slot `pos[b]`.
        def put(cache, kv, p):
            return jax.lax.dynamic_update_slice(cache, kv[None], (p, 0, 0))

        kvk_l = jax.vmap(put)(kvk_l, k, pos)
        kvv_l = jax.vmap(put)(kvv_l, v, pos)

        # L1 Pallas kernel (variant per cfg.attention_kernel).
        if cfg.attention_kernel == "paged":
            attn = decode_attention_paged(
                q, kvk_l, kvv_l, seq_lens, interpret=interpret
            )
        else:
            attn = decode_attention(
                q, kvk_l, kvv_l, seq_lens, interpret=interpret
            )
        x = x + attn.reshape(B, cfg.q_dim) @ lp["wo"]

        h2 = rms_norm(x, lp["mlp_norm"])
        x = x + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
        return x, (kvk_l, kvv_l)

    x, (kv_k_new, kv_v_new) = jax.lax.scan(
        layer, x, (_stacked(params), kv_k, kv_v)
    )

    logits = rms_norm(x, params["final_norm"]) @ params["embed"].T
    return logits, kv_k_new, kv_v_new


def prefill(params, tokens, lens, cfg: ModelConfig):
    """Fill the KV cache from a padded prompt batch.

    Args:
      tokens: [B, T] int32, padded with anything past ``lens``.
      lens:   [B] int32 true prompt lengths (>= 1).

    Returns:
      (last_logits [B, V], kv_k, kv_v) where last_logits is the logits at
      each sequence's final valid position (the token that seeds decode) and
      the caches hold K/V for positions [0, T) (entries past ``lens`` are
      garbage and masked by construction downstream).
    """
    B, T = tokens.shape
    x = params["embed"][tokens]  # [B, T, d]
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]  # [1, T]

    def layer(x, lp):
        h = rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(B, T, cfg.n_q_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

        attn = mha_prefill_ref(q, k, v, lens)  # [B, T, Hq, D]
        x = x + attn.reshape(B, T, cfg.q_dim) @ lp["wo"]
        h2 = rms_norm(x, lp["mlp_norm"])
        x = x + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])

        # Pad the T prefix out to the S-slot cache.
        pad = [(0, 0), (0, cfg.max_seq - T), (0, 0), (0, 0)]
        return x, (jnp.pad(k, pad), jnp.pad(v, pad))

    x, (kv_k, kv_v) = jax.lax.scan(layer, x, _stacked(params))

    logits = rms_norm(x, params["final_norm"]) @ params["embed"].T  # [B,T,V]
    last = jnp.take_along_axis(
        logits, (lens - 1)[:, None, None], axis=1
    )[:, 0, :]
    return last, kv_k, kv_v


def decode_step_flat(*args, cfg: ModelConfig, interpret=True):
    """Flat-signature wrapper for AOT lowering.

    Signature: (*params_in_PARAM_ORDER, tokens, kv_k, kv_v, pos).
    """
    n = len(PARAM_ORDER)
    params = dict(zip(PARAM_ORDER, args[:n]))
    tokens, kv_k, kv_v, pos = args[n:]
    return decode_step(params, tokens, kv_k, kv_v, pos, cfg,
                       interpret=interpret)


def prefill_flat(*args, cfg: ModelConfig):
    """Flat-signature wrapper: (*params, tokens[B,T], lens[B])."""
    n = len(PARAM_ORDER)
    params = dict(zip(PARAM_ORDER, args[:n]))
    tokens, lens = args[n:]
    return prefill(params, tokens, lens, cfg)
