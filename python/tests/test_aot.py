"""AOT pipeline tests: the WLW1 container format, HLO-text lowering, and
golden-trace determinism — the contract the Rust runtime depends on."""

import io
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as m

jax.config.update("jax_platform_name", "cpu")

SMALL = m.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_q_heads=4, n_kv_heads=2,
    head_dim=8, d_ff=48, max_seq=128, batch=2, prefill_len=16,
)


def read_container(path):
    """Reference reader for the WLW1 format (mirrors rust/runtime/container)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"WLW1"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            n = int(np.prod(dims)) if dims else 1
            dt = np.float32 if code == 0 else np.int32
            data = np.frombuffer(f.read(n * 4), dtype=dt).reshape(dims)
            out[name] = data
        assert f.read() == b"", "trailing bytes"
    return out


def test_container_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, -2, 3], dtype=np.int32),
    }
    p = tmp_path / "t.bin"
    aot.write_container(p, tensors)
    back = read_container(p)
    np.testing.assert_array_equal(back["a"], tensors["a"])
    np.testing.assert_array_equal(back["b"], tensors["b"])
    assert back["a"].dtype == np.float32
    assert back["b"].dtype == np.int32


def test_hlo_text_lowering_has_parameters_and_tuple_root():
    params = m.init_params(jax.random.PRNGKey(0), SMALL)
    specs = [
        jax.ShapeDtypeStruct(np.asarray(params[n]).shape, jnp.float32)
        for n in m.PARAM_ORDER
    ]
    kv = jax.ShapeDtypeStruct(SMALL.kv_shape(), jnp.float32)
    fn = jax.jit(lambda *a: m.decode_step_flat(*a, cfg=SMALL, interpret=True))
    lowered = fn.lower(
        *specs,
        jax.ShapeDtypeStruct((SMALL.batch,), jnp.int32),
        kv, kv,
        jax.ShapeDtypeStruct((SMALL.batch,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    # HLO text (not proto); the entry computation has one parameter per
    # flat input (inner computations — scan bodies, reductions — have
    # their own, so count *distinct indices*), and a tuple root.
    assert "HloModule" in text
    import re

    distinct = {int(x) for x in re.findall(r"parameter\((\d+)\)", text)}
    assert distinct == set(range(len(m.PARAM_ORDER) + 4)), sorted(distinct)
    assert "tuple(" in text or "ROOT" in text


def test_golden_trace_is_deterministic():
    params = m.init_params(jax.random.PRNGKey(42), SMALL)
    g1 = aot.build_golden(params, SMALL)
    g2 = aot.build_golden(params, SMALL)
    assert set(g1) == set(g2)
    for k in g1:
        np.testing.assert_array_equal(g1[k], g2[k])
    # The trace must exercise both decode steps at advanced positions.
    assert (g1["decode2.in.pos"] == g1["decode1.in.pos"] + 1).all()


def test_golden_logits_depend_on_weights():
    g_a = aot.build_golden(m.init_params(jax.random.PRNGKey(1), SMALL), SMALL)
    g_b = aot.build_golden(m.init_params(jax.random.PRNGKey(2), SMALL), SMALL)
    assert not np.allclose(
        g_a["prefill.out.last_logits"], g_b["prefill.out.last_logits"]
    )


def test_kernel_choice_changes_artifact_not_numerics():
    """single vs paged kernels must produce the same decode numerics."""
    key = jax.random.PRNGKey(3)
    cfg_s = SMALL
    cfg_p = m.ModelConfig(**{**SMALL.__dict__, "attention_kernel": "paged"})
    params = m.init_params(key, cfg_s)
    tokens = jnp.array([1, 2], jnp.int32)
    kv = jnp.zeros(cfg_s.kv_shape())
    pos = jnp.array([3, 5], jnp.int32)
    l_s, _, _ = m.decode_step(params, tokens, kv, kv, pos, cfg_s)
    l_p, _, _ = m.decode_step(params, tokens, kv, kv, pos, cfg_p)
    np.testing.assert_allclose(np.asarray(l_s), np.asarray(l_p),
                               rtol=2e-5, atol=2e-5)


def test_dataclass_rejects_mutation():
    with pytest.raises(Exception):
        SMALL.vocab = 128  # frozen dataclass
