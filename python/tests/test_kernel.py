"""L1 correctness: Pallas decode-attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, GQA group factors, dtypes and sequence lengths;
both the single-block and the paged (online-softmax) variants must agree
with ``ref.decode_attention_ref`` to tight tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import (
    decode_attention,
    decode_attention_paged,
)
from compile.kernels.ref import decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(seed, batch, s, hq, hkv, d, dtype):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((batch, hq, d)).astype(dtype)
    k = rng.standard_normal((batch, s, hkv, d)).astype(dtype)
    v = rng.standard_normal((batch, s, hkv, d)).astype(dtype)
    lens = rng.integers(1, s + 1, size=(batch,)).astype(np.int32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 64, 128]),
    heads=st.sampled_from([(4, 1), (4, 2), (8, 2), (8, 8)]),
    d=st.sampled_from([16, 32, 64]),
)
def test_single_block_matches_ref(seed, batch, s, heads, d):
    hq, hkv = heads
    q, k, v, lens = make_inputs(seed, batch, s, hq, hkv, d, np.float32)
    got = decode_attention(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([64, 128, 256]),
    heads=st.sampled_from([(4, 1), (4, 2), (8, 2), (8, 8)]),
    d=st.sampled_from([16, 32]),
    page=st.sampled_from([16, 32, 64]),
)
def test_paged_matches_ref(seed, batch, s, heads, d, page):
    hq, hkv = heads
    q, k, v, lens = make_inputs(seed, batch, s, hq, hkv, d, np.float32)
    got = decode_attention_paged(q, k, v, lens, page_tokens=page)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_paged_bf16_close_to_f32_ref(seed):
    """bf16 inputs: accumulate in f32, stay within bf16-grade tolerance."""
    q, k, v, lens = make_inputs(seed, 2, 128, 8, 2, 32, np.float32)
    qb = q.astype(jnp.bfloat16)
    kb = k.astype(jnp.bfloat16)
    vb = v.astype(jnp.bfloat16)
    got = decode_attention_paged(qb, kb, vb, lens).astype(jnp.float32)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


def test_paged_equals_single_block_exact_shapes():
    """The two kernel variants agree with each other on the AOT shapes."""
    q, k, v, lens = make_inputs(0, 8, 512, 8, 2, 32, np.float32)
    a = decode_attention(q, k, v, lens)
    b = decode_attention_paged(q, k, v, lens)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_mask_is_respected():
    """Changing K/V beyond seq_len must not change the output."""
    q, k, v, lens = make_inputs(3, 2, 64, 8, 2, 32, np.float32)
    lens = jnp.array([5, 17], dtype=jnp.int32)
    out1 = decode_attention_paged(q, k, v, lens)
    k2 = k.at[0, 5:].set(99.0).at[1, 17:].set(-99.0)
    v2 = v.at[0, 5:].set(42.0).at[1, 17:].set(-42.0)
    out2 = decode_attention_paged(q, k2, v2, lens)
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)


def test_len_one_attends_only_first_slot():
    """seq_len == 1 reduces attention to v[:, 0] exactly."""
    q, k, v, _ = make_inputs(4, 2, 64, 8, 2, 32, np.float32)
    lens = jnp.ones((2,), jnp.int32)
    out = decode_attention_paged(q, k, v, lens)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
    # group g of kv-head h reads v[0, h]
    v0 = np.asarray(v)[:, 0]  # [B, Hkv, D]
    want_direct = np.repeat(v0, 4, axis=1)  # G = Hq // Hkv = 4
    np.testing.assert_allclose(out, want_direct, rtol=1e-5, atol=1e-5)


def test_softmax_rows_convex_combination():
    """Outputs lie within [min, max] of the valid V slots (convexity)."""
    q, k, v, lens = make_inputs(5, 4, 128, 8, 2, 32, np.float32)
    out = np.asarray(decode_attention_paged(q, k, v, lens))
    v_np, lens_np = np.asarray(v), np.asarray(lens)
    for b in range(4):
        valid = v_np[b, : lens_np[b]]  # [len, Hkv, D]
        lo = valid.min(axis=0).repeat(4, axis=0)  # [Hq, D]
        hi = valid.max(axis=0).repeat(4, axis=0)
        assert (out[b] >= lo - 1e-4).all()
        assert (out[b] <= hi + 1e-4).all()
