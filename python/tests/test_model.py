"""L2 correctness: the tiny-Llama decode/prefill graph invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

jax.config.update("jax_platform_name", "cpu")

CFG = m.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_q_heads=4, n_kv_heads=2,
    head_dim=8, d_ff=48, max_seq=128, batch=2, prefill_len=16,
)


@pytest.fixture(scope="module")
def params():
    return m.init_params(jax.random.PRNGKey(0), CFG)


def _prompt(key, b, t, vocab):
    return jax.random.randint(key, (b, t), 0, vocab, dtype=jnp.int32)


def test_shapes(params):
    tokens = _prompt(jax.random.PRNGKey(1), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.array([5, 16], jnp.int32)
    last, kv_k, kv_v = m.prefill(params, tokens, lens, CFG)
    assert last.shape == (CFG.batch, CFG.vocab)
    assert kv_k.shape == CFG.kv_shape()
    assert kv_v.shape == CFG.kv_shape()

    logits, kv_k2, kv_v2 = m.decode_step(
        params, jnp.argmax(last, -1).astype(jnp.int32), kv_k, kv_v, lens, CFG
    )
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert kv_k2.shape == CFG.kv_shape()


def test_decode_consistent_with_prefill(params):
    """prefill(t+1).last_logits == decode_step after prefill(t).

    This is the strongest end-to-end invariant: the incremental KV path
    (Pallas kernel, RoPE at a single position, dynamic cache update) must
    reproduce the full-prompt attention bit-for-bit up to float tolerance.
    """
    t = 7
    tokens = _prompt(jax.random.PRNGKey(2), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.full((CFG.batch,), t, jnp.int32)

    # Path A: prefill over t tokens, then decode token t.
    _, kv_k, kv_v = m.prefill(params, tokens, lens, CFG)
    next_tok = tokens[:, t]
    logits_inc, _, _ = m.decode_step(
        params, next_tok, kv_k, kv_v, lens, CFG
    )

    # Path B: prefill over t+1 tokens directly.
    lens_b = jnp.full((CFG.batch,), t + 1, jnp.int32)
    logits_full, _, _ = m.prefill(params, tokens, lens_b, CFG)

    np.testing.assert_allclose(
        np.asarray(logits_inc), np.asarray(logits_full), rtol=2e-4, atol=2e-4
    )


def test_multi_step_decode_consistency(params):
    """Three chained decode steps match the equivalent longer prefill."""
    t = 4
    steps = 3
    tokens = _prompt(jax.random.PRNGKey(3), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.full((CFG.batch,), t, jnp.int32)

    _, kv_k, kv_v = m.prefill(params, tokens, lens, CFG)
    pos = lens
    logits = None
    for i in range(steps):
        tok = tokens[:, t + i]
        logits, kv_k, kv_v = m.decode_step(params, tok, kv_k, kv_v, pos, CFG)
        pos = pos + 1

    lens_b = jnp.full((CFG.batch,), t + steps, jnp.int32)
    logits_full, _, _ = m.prefill(params, tokens, lens_b, CFG)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_full), rtol=5e-4, atol=5e-4
    )


def test_batch_slots_independent(params):
    """Slot 0's logits must not depend on slot 1's content (batch isolation)."""
    tokens = _prompt(jax.random.PRNGKey(4), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.array([6, 9], jnp.int32)
    last_a, kv_k, kv_v = m.prefill(params, tokens, lens, CFG)

    tokens_b = tokens.at[1].set((tokens[1] + 13) % CFG.vocab)
    last_b, _, _ = m.prefill(params, tokens_b, lens, CFG)
    np.testing.assert_allclose(
        np.asarray(last_a[0]), np.asarray(last_b[0]), rtol=1e-6, atol=1e-6
    )
    assert not np.allclose(np.asarray(last_a[1]), np.asarray(last_b[1]))


def test_padding_tokens_do_not_leak(params):
    """Tokens past `lens` must not influence the last valid logits."""
    tokens = _prompt(jax.random.PRNGKey(5), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.array([5, 8], jnp.int32)
    a, _, _ = m.prefill(params, tokens, lens, CFG)
    noisy = tokens.at[:, 10:].set(0)
    b, _, _ = m.prefill(params, noisy, lens, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_logits_finite(params):
    tokens = _prompt(jax.random.PRNGKey(6), CFG.batch, CFG.prefill_len, CFG.vocab)
    lens = jnp.array([1, CFG.prefill_len], jnp.int32)
    last, kv_k, kv_v = m.prefill(params, tokens, lens, CFG)
    assert np.isfinite(np.asarray(last)).all()
    logits, _, _ = m.decode_step(
        params, jnp.zeros((CFG.batch,), jnp.int32), kv_k, kv_v, lens, CFG
    )
    assert np.isfinite(np.asarray(logits)).all()


def test_kv_bytes_per_token_matches_formula():
    assert CFG.kv_bytes_per_token() == 2 * 4 * 2 * 2 * 8


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 4, 16))
    pos = jnp.array([0.0, 5.0, 11.0])
    y = m.rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # position 0 is the identity
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0]), rtol=1e-6)
