//! Ablation benches for the design choices DESIGN.md calls out:
//! L̄ policy (window vs traffic-mean), power accounting (per-GPU vs
//! per-group), FleetOpt γ sweep, K-tier topologies, and the §10.3
//! extensions (disaggregation, carbon mapping, speculative decoding).
use std::sync::Arc;
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::fleet::analysis::fleet_tpw_analysis;
use wattlaw::fleet::carbon::{carbon_report, GridContext};
use wattlaw::fleet::disagg::disaggregate;
use wattlaw::fleet::optimizer::multi_pool;
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use wattlaw::fleet::topology::{Topology, LONG_CTX};
use wattlaw::power::LogisticPower;
use wattlaw::roofline::speculative::{spec_point, SpecConfig};
use wattlaw::roofline::Roofline;
use wattlaw::tables::render::{f2, Table};
use wattlaw::workload::cdf::azure_conversations;

fn main() {
    let trace = azure_conversations();
    let h100: Arc<dyn GpuProfile> = Arc::new(ManualProfile::h100_70b());
    let fleet = |topo: &Topology, lbar, acct| {
        let pools = topo.pools(&trace, 1000.0, h100.clone(), None, lbar, 0.85, 0.5);
        fleet_tpw_analysis(&pools, acct)
    };
    let opt = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 };
    let homo = Topology::Homogeneous { ctx: LONG_CTX };

    // --- Ablation A: L̄ policy × accounting ---------------------------------
    let mut t = Table::new(
        "Ablation — L̄ policy × power accounting (Azure, FleetOpt vs Homo)",
        &["L̄", "accounting", "Homo tok/W", "FleetOpt tok/W", "Δ_topo"],
    );
    for lbar in [LBarPolicy::Window, LBarPolicy::TrafficMean] {
        for acct in [PowerAccounting::PerGpu, PowerAccounting::PerGroup] {
            let h = fleet(&homo, lbar, acct).tok_per_watt.0;
            let o = fleet(&opt, lbar, acct).tok_per_watt.0;
            t.row(vec![
                format!("{lbar:?}"),
                format!("{acct:?}"),
                f2(h),
                f2(o),
                format!("{:.2}x", o / h),
            ]);
        }
    }
    println!("{}", t.render());

    // --- Ablation B: γ sweep -------------------------------------------------
    let mut t = Table::new("Ablation — FleetOpt γ", &["γ", "tok/W", "groups"]);
    for gamma in [1.0, 1.5, 2.0, 3.0, 4.0] {
        let r = fleet(
            &Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma },
            LBarPolicy::Window,
            PowerAccounting::PerGpu,
        );
        t.row(vec![format!("{gamma}"), f2(r.tok_per_watt.0),
                   r.total_groups.to_string()]);
    }
    println!("{}", t.render());

    // --- Ablation C: K-tier topologies --------------------------------------
    let mut t = Table::new("Ablation — K context tiers (§10.3)", &["tiers", "tok/W"]);
    for tiers in [
        vec![LONG_CTX],
        vec![4096, LONG_CTX],
        vec![4096, 16_384, LONG_CTX],
        vec![2048, 8192, 32_768, LONG_CTX],
    ] {
        let r = multi_pool(&trace, 1000.0, h100.clone(), &tiers,
                           LBarPolicy::Window, 0.85, 0.5, PowerAccounting::PerGpu);
        t.row(vec![format!("{}", tiers.len()), f2(r.tok_per_watt.0)]);
    }
    println!("{}", t.render());

    // --- Ablation D: §10.3 extensions ----------------------------------------
    let d = disaggregate(&trace, 1000.0, h100.clone(), &opt,
                         LBarPolicy::Window, 0.85, 0.5, PowerAccounting::PerGpu);
    println!(
        "disaggregation: decode-only {:.2} tok/W vs total {:.2} tok/W \
         ({} prefill groups)\n",
        d.tok_per_watt_decode_only, d.tok_per_watt_total, d.prefill_groups
    );
    let c = carbon_report(&fleet(&opt, LBarPolicy::Window, PowerAccounting::PerGpu),
                          &GridContext::typical());
    println!(
        "carbon (typical grid): {:.2e} gCO2/token, ${:.3}/Mtok\n",
        c.g_co2_per_token, c.usd_per_mtok
    );
    let r = Roofline::manual(6.72, 0.1387);
    let p = LogisticPower::h100();
    for alpha in [0.5, 0.7, 0.9] {
        let s = spec_point(&r, &p, &SpecConfig {
            k: 4, alpha, draft_w_ms: 6.72 / 70.0, draft_power_scale: 0.8,
        }, 16.0, 8192.0);
        println!("speculative α={alpha}: {:.2} tok/W @64-seq-equivalent batch",
                 s.tok_per_watt);
    }

    // Timings.
    let mut g = BenchGroup::new("ablation timings");
    g.bench("fleet_analysis_4tier", || {
        black_box(multi_pool(&trace, 1000.0, h100.clone(),
                             &[2048, 8192, 32_768, LONG_CTX],
                             LBarPolicy::Window, 0.85, 0.5,
                             PowerAccounting::PerGpu))
    });
    g.bench("disaggregate", || {
        black_box(disaggregate(&trace, 1000.0, h100.clone(), &opt,
                               LBarPolicy::Window, 0.85, 0.5,
                               PowerAccounting::PerGpu))
    });
    g.finish();
}
