//! Runtime hot path: the compiled decode step / prefill on CPU PJRT
//! (needs `make artifacts`; prints a notice and exits cleanly otherwise).
use wattlaw::benchkit::{black_box, BenchConfig, BenchGroup};
use wattlaw::runtime::TinyModel;

fn main() {
    let dir = wattlaw::runtime::default_artifacts_dir();
    if !dir.join("decode_step.hlo.txt").exists() {
        println!("artifacts missing — run `make artifacts`; skipping runtime bench");
        return;
    }
    let model = TinyModel::load(&dir).expect("load artifacts");
    let b = model.cfg.batch as usize;
    let t = model.cfg.prefill_len as usize;

    let mut g = BenchGroup::new("runtime decode/prefill (CPU PJRT)")
        .with_config(BenchConfig { warmup_iters: 3, samples: 15, batch: 1 });

    let (kv_k, kv_v) = model.fresh_kv().unwrap();
    let tok = vec![1i32; b];
    let pos = vec![64i32; b];
    g.bench("decode_step_b8_s512", || {
        black_box(model.decode_step(&tok, &kv_k, &kv_v, &pos).unwrap().0[0])
    });

    let tokens: Vec<i32> = (0..b * t).map(|i| (i % 31) as i32).collect();
    let lens = vec![t as i32; b];
    g.bench("prefill_b8_t64", || {
        black_box(model.prefill(&tokens, &lens).unwrap().0[0])
    });

    let logits = vec![0.5f32; b * model.cfg.vocab as usize];
    g.bench("argmax_b8_v512", || black_box(model.argmax(&logits)));

    let r = g.finish();
    let step_ms = r[0].mean_ns / 1e6;
    println!(
        "\ndecode tokens/s at batch {b}: {:.0}",
        b as f64 / (step_ms / 1e3)
    );
}
