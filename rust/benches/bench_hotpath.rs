//! L3 hot-path microbenchmarks: batcher step assembly, KV block
//! allocation, energy integration, Erlang-C sizing, workload sampling,
//! and the discrete-event simulator's event rate.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::power::LogisticPower;
use wattlaw::queueing::erlang;
use wattlaw::router::HomogeneousRouter;
use wattlaw::serve::batcher::{Batcher, SlotWork};
use wattlaw::serve::energy::EnergyMeter;
use wattlaw::serve::kvblocks::BlockAllocator;
use wattlaw::serve::request::ServeRequest;
use wattlaw::sim::{simulate_topology, GroupSimConfig};
use wattlaw::workload::cdf::azure_conversations;
use wattlaw::workload::synth::{generate, GenConfig};
use wattlaw::xrand::Rng;

fn main() {
    let mut g = BenchGroup::new("L3 hot paths");

    // Batcher at n = 256 slots, fully loaded.
    let mut b = Batcher::new(256, BlockAllocator::new(64, 1 << 16), 1024, 65_536);
    for i in 0..512u64 {
        b.submit(ServeRequest {
            id: i, prompt_tokens: 2048, output_tokens: 256, arrival_s: 0.0,
        });
    }
    b.admit(0.0);
    g.bench("batcher_plan_256_slots", || black_box(b.plan()));
    g.bench("batcher_full_step_256_slots", || {
        let plan = b.plan();
        let mut done = 0;
        for (i, w) in plan.into_iter().enumerate() {
            if !matches!(w, SlotWork::Idle) && b.on_step(i, w, 1.0).is_some() {
                done += 1;
            }
        }
        b.admit(1.0);
        black_box(done)
    });

    // KV block allocator churn.
    let mut alloc = BlockAllocator::new(64, 1 << 16);
    let mut id = 0u64;
    g.bench("kvblocks_admit_grow_release", || {
        id += 1;
        alloc.admit(id, 4096);
        alloc.grow(id, 8192);
        alloc.release(id);
        black_box(alloc.used())
    });

    // Energy integration.
    let mut meter = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
    let mut t = 0.0;
    g.bench("energy_observe", || {
        t += 0.01;
        meter.observe(t, 100.0);
        black_box(meter.joules())
    });

    // Queueing: sizing a 1000-slot pool.
    g.bench("erlang_min_servers", || {
        black_box(erlang::min_servers_for_p99(1000.0, 0.5, 0.4))
    });

    // Workload sampling.
    let trace = azure_conversations();
    let mut rng = Rng::new(1);
    g.bench("cdf_sample", || black_box(trace.prompt_cdf.sample(&mut rng)));
    g.bench("trace_gen_1s_at_1krps", || {
        black_box(
            generate(&trace, &GenConfig {
                lambda_rps: 1000.0, duration_s: 1.0, seed: 2,
                ..Default::default()
            })
            .len(),
        )
    });

    // DES simulator throughput (events ≈ steps × slots).
    let reqs = generate(&trace, &GenConfig {
        lambda_rps: 50.0, duration_s: 2.0, max_prompt_tokens: 30_000,
        max_output_tokens: 256, seed: 3,
    });
    let p = wattlaw::fleet::profile::ManualProfile::h100_70b();
    use wattlaw::fleet::profile::GpuProfile;
    let cfg = GroupSimConfig {
        window_tokens: 65_536,
        n_max: p.n_max(65_536),
        roofline: p.roofline(),
        power: p.gpu.power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    g.bench("simulate_100req_trace_2groups", || {
        black_box(simulate_topology(&reqs, &HomogeneousRouter, &[2], &[cfg.clone()]))
    });

    g.finish();
}
