//! Bench the 1/W-law figure: sweep + slope fit across all generations.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::fleet::profile::ManualProfile;
use wattlaw::tables::law_fig;
use wattlaw::tokeconomy::law::{fit_law, LAW_CONTEXTS};

fn main() {
    println!("{}", law_fig::generate());
    let mut g = BenchGroup::new("1/W law figure");
    let p = ManualProfile::h100_70b();
    g.bench("fit_law_h100", || black_box(fit_law(&p, &LAW_CONTEXTS)));
    g.bench("all_generations", || black_box(law_fig::fits()));
    g.finish();
}
