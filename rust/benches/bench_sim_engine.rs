//! Bench: events/sec of the event-driven simulation core on a 16-group,
//! 10k-request Azure trace — sequential shared-heap vs the parallel
//! per-group fast path, plus the stateful-dispatch overhead (JSQ snapshots
//! the fleet at every arrival).
//!
//! An "event" here is one engine iteration (step-complete) of one group;
//! arrivals and wakes add a few percent on top. Record the headline
//! events/sec numbers in CHANGES.md when they move.
use wattlaw::benchkit::{black_box, BenchConfig, BenchGroup};
use wattlaw::fleet::profile::{GpuProfile, ManualProfile};
use wattlaw::router::context::ContextRouter;
use wattlaw::sim::dispatch::{JoinShortestQueue, RoundRobin};
use wattlaw::sim::{simulate_topology_with, GroupSimConfig};
use wattlaw::workload::synth::{generate, GenConfig};

fn main() {
    // ~10k requests: λ=2000 × 5 s.
    let trace = generate(
        &wattlaw::workload::cdf::azure_conversations(),
        &GenConfig {
            lambda_rps: 2000.0,
            duration_s: 5.0,
            max_prompt_tokens: 30_000,
            max_output_tokens: 256,
            seed: 3,
        },
    );
    println!("trace: {} requests", trace.len());

    let p = ManualProfile::h100_70b();
    let mk = |window: u32| GroupSimConfig {
        window_tokens: window,
        n_max: p.n_max(window),
        roofline: p.roofline(),
        power: p.gpu().power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    let router = ContextRouter::two_pool(4096);
    let pool_groups = [8u32, 8u32];
    let cfgs = [mk(4096 + 1024), mk(65_536)];

    // The simulation itself is the workload: a handful of samples is
    // plenty (each run is hundreds of ms), and --quick still shrinks it.
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("WATTLAW_BENCH_QUICK").is_ok();
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, samples: 3, batch: 1 }
    } else {
        BenchConfig { warmup_iters: 1, samples: 5, batch: 1 }
    };
    let mut g = BenchGroup::new(
        "sim engine — 16 groups, 10k-request trace (two-pool 4K/64K)",
    )
    .with_config(cfg);

    let mut steps_seq = 0u64;
    g.bench("event_core_sequential_rr", || {
        let mut rr = RoundRobin::new();
        let r = simulate_topology_with(
            &trace, &router, &pool_groups, &cfgs, &mut rr, false,
        );
        steps_seq = r.steps;
        black_box(r.output_tokens)
    });
    let mut steps_par = 0u64;
    g.bench("event_core_parallel_rr", || {
        let mut rr = RoundRobin::new();
        let r = simulate_topology_with(
            &trace, &router, &pool_groups, &cfgs, &mut rr, true,
        );
        steps_par = r.steps;
        black_box(r.output_tokens)
    });
    let mut steps_jsq = 0u64;
    g.bench("event_core_sequential_jsq", || {
        let mut jsq = JoinShortestQueue;
        let r = simulate_topology_with(
            &trace, &router, &pool_groups, &cfgs, &mut jsq, true,
        );
        steps_jsq = r.steps;
        black_box(r.output_tokens)
    });

    let stats = g.finish();
    assert_eq!(steps_seq, steps_par, "parallel fast path must replay exactly");
    println!();
    for (name, steps, s) in [
        ("sequential rr", steps_seq, &stats[0]),
        ("parallel rr", steps_par, &stats[1]),
        ("sequential jsq", steps_jsq, &stats[2]),
    ] {
        let ev_per_s = steps as f64 / (s.mean_ns / 1e9);
        println!(
            "{name:<16} {steps} step events, {:.0} events/sec (mean)",
            ev_per_s
        );
    }
    println!(
        "parallel speedup over sequential (rr): {:.2}x",
        stats[0].mean_ns / stats[1].mean_ns
    );
}
