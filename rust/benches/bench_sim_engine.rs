//! Bench: events/sec of the event-driven simulation core on a 16-group,
//! 10k-request Azure trace — sequential shared-heap vs the parallel
//! per-group fast path, plus the incremental-state refactor's
//! before/after: JSQ dispatch with the legacy rebuild-a-snapshot-per-
//! arrival mode (`StateMode::RebuildPerArrival`, O(total groups)
//! allocations per arrival) against the in-place live state
//! (`StateMode::Incremental`, zero allocations per decision).
//!
//! An "event" here is one engine iteration (step-complete) of one group;
//! arrivals and wakes add a few percent on top.
//!
//! Also measures the two-stage optimizer's stage costs: the analytical
//! screen of the full legacy (B_short × γ) grid (stage A, closed form)
//! against one simulate-refine cell (stage B, the event engine on the
//! same 10k-request trace) — the ratio is why the search screens wide
//! and refines narrow.
//!
//! Two raw-speed sections cover the PR 6 refactors head-to-head:
//!
//! * `event_queue` — the calendar/bucket event queue vs the legacy
//!   binary heap (`QueueMode::BinaryHeap`, the replay oracle) at
//!   λ ∈ {1000, 4000} on the sequential shared-queue path; both modes
//!   must replay bit-for-bit, so the delta is pure queue cost.
//! * `bnb_screen` — the branch-and-bound heterogeneous screen vs the
//!   brute-force assignment cross-product at K ∈ {3, 4, 5} over a
//!   3-generation set: Eq. 4 evaluations visited and wall time.
//!
//! The PR 7 `streaming_arrivals` section pits the materialized
//! `Vec<Request>` engine against the fused generate-as-you-go
//! `SynthSource` stream at λ ∈ {1000, 4000}: events/sec (the streamed
//! run pays arrival generation inside the loop — that is the point, no
//! trace is ever held) plus the trace-memory footprint each path holds,
//! with both paths replay-asserted to the same bits.
//!
//! The `macro_step` section measures what macro-step event fusion buys:
//! `StepMode::Fused` (the production default — quiescent decode spans
//! run in one in-line loop, one fused event at the horizon) against the
//! `StepMode::PerStep` one-event-per-step oracle at λ ∈ {1000, 4000},
//! replay-asserted to the same bits, with the events-popped ratio
//! (per-step must pop ≥ 10× more at λ=4000 — asserted) and the fused
//! events-per-arrival figure. The earlier sections deliberately pin
//! `StepMode::PerStep` so their events/sec keep meaning "one engine
//! iteration per event" and stay comparable with the numbers recorded
//! before fusion existed.
//!
//! The PR 10 `parallel_stream` section pits the sequential streamed
//! engine against the sharded per-group demux (round-robin dispatch —
//! the arrival-static, parallel-eligible path) at λ ∈ {1000, 4000} on
//! the same generated streams, replay-asserted to the same bits and
//! the same per-step event count; full (non-`--quick`) runs assert the
//! sharded λ=4000 cell is strictly faster. The `screen_memo` section
//! measures the memoized stage-A screen against the disabled-memo
//! oracle on the mixed H100×B200 grid — same ranking, bit for bit,
//! with the Eq. 4 cache hit rate reported.
//!
//! Run `cargo bench --bench bench_sim_engine -- --record` to write the
//! headline numbers to `BENCH_sim_engine.json` at the repo root
//! (`--quick` shrinks the sample count for smoke runs; `--gate` fails
//! the run if calendar-queue events/sec regresses more than 20% against
//! the committed baseline, once that baseline is non-null).
use wattlaw::benchkit::{black_box, BenchConfig, BenchGroup, BenchStats};
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use wattlaw::fleet::topology::Topology;
use wattlaw::power::Gpu;
use wattlaw::router::context::ContextRouter;
use wattlaw::scenario::optimize::{
    self, MixedScreen, MixedScreenStats, OptimizeConfig, ScreenMemoStats,
    ScreenedCell,
};
use wattlaw::scenario::ScenarioSpec;
use wattlaw::sim::dispatch::{JoinShortestQueue, RoundRobin};
use wattlaw::sim::{
    simulate_topology_opts, simulate_topology_source, EngineOptions,
    GroupSimConfig, QueueMode, StateMode, StepMode,
};
use wattlaw::workload::synth::{generate, GenConfig};
use wattlaw::workload::{Request, SynthSource};

const JSON_PATH: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_engine.json");

fn main() {
    // ~10k requests: λ=2000 × 5 s.
    let gen = GenConfig {
        lambda_rps: 2000.0,
        duration_s: 5.0,
        max_prompt_tokens: 30_000,
        max_output_tokens: 256,
        seed: 3,
    };
    let trace = generate(&wattlaw::workload::cdf::azure_conversations(), &gen);
    println!("trace: {} requests", trace.len());

    let p = ManualProfile::h100_70b();
    let mk = |window: u32| GroupSimConfig {
        window_tokens: window,
        n_max: p.n_max(window),
        roofline: p.roofline(),
        power: p.gpu().power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    let router = ContextRouter::two_pool(4096);
    let pool_groups = [8u32, 8u32];
    let cfgs = [mk(4096 + 1024), mk(65_536)];

    // The simulation itself is the workload: a handful of samples is
    // plenty (each run is hundreds of ms), and --quick still shrinks it.
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("WATTLAW_BENCH_QUICK").is_ok();
    let record = std::env::args().any(|a| a == "--record");
    let gate = std::env::args().any(|a| a == "--gate");
    // Read the committed baseline *before* --record overwrites it.
    let baseline = if gate {
        std::fs::read_to_string(JSON_PATH).ok()
    } else {
        None
    };
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, samples: 3, batch: 1 }
    } else {
        BenchConfig { warmup_iters: 1, samples: 5, batch: 1 }
    };
    let mut g = BenchGroup::new(
        "sim engine — 16 groups, 10k-request trace (two-pool 4K/64K)",
    )
    .with_config(cfg);

    // Per-step keeps "events/sec" meaning one engine iteration per
    // event (and the numbers comparable with pre-fusion records); the
    // fused default is measured head-to-head in `macro_step` below.
    let opts = |allow_parallel: bool, mode: StateMode| EngineOptions {
        allow_parallel,
        state_mode: mode,
        queue_mode: QueueMode::Calendar,
        step_mode: StepMode::PerStep,
        validate_state: false,
    };
    let mut steps_seq = 0u64;
    g.bench("event_core_sequential_rr", || {
        let mut rr = RoundRobin::new();
        let r = simulate_topology_opts(
            &trace,
            &router,
            &pool_groups,
            &cfgs,
            &mut rr,
            opts(false, StateMode::Incremental),
        );
        steps_seq = r.steps;
        black_box(r.output_tokens)
    });
    let mut steps_par = 0u64;
    g.bench("event_core_parallel_rr", || {
        let mut rr = RoundRobin::new();
        let r = simulate_topology_opts(
            &trace,
            &router,
            &pool_groups,
            &cfgs,
            &mut rr,
            opts(true, StateMode::Incremental),
        );
        steps_par = r.steps;
        black_box(r.output_tokens)
    });
    // Before: the pre-refactor engine rebuilt a full FleetState per
    // arrival for stateful dispatch.
    let mut steps_jsq_rebuild = 0u64;
    g.bench("event_core_jsq_rebuild_per_arrival(before)", || {
        let mut jsq = JoinShortestQueue;
        let r = simulate_topology_opts(
            &trace,
            &router,
            &pool_groups,
            &cfgs,
            &mut jsq,
            opts(true, StateMode::RebuildPerArrival),
        );
        steps_jsq_rebuild = r.steps;
        black_box(r.output_tokens)
    });
    // After: one live state, refreshed in place per event.
    let mut steps_jsq_incr = 0u64;
    g.bench("event_core_jsq_incremental(after)", || {
        let mut jsq = JoinShortestQueue;
        let r = simulate_topology_opts(
            &trace,
            &router,
            &pool_groups,
            &cfgs,
            &mut jsq,
            opts(true, StateMode::Incremental),
        );
        steps_jsq_incr = r.steps;
        black_box(r.output_tokens)
    });

    // Optimizer stage costs on the same workload: stage A screens the
    // full legacy grid analytically; stage B replays one refined cell
    // through the event engine.
    let workload = wattlaw::workload::cdf::azure_conversations();
    let opt_cfg = OptimizeConfig {
        gpus: vec![Gpu::H100],
        gen: gen.clone(),
        groups: 16,
        ..Default::default()
    };
    let mut screened_cells = 0usize;
    g.bench("optimize_stage_a_screen(legacy grid)", || {
        let cells = optimize::screen(&workload, &opt_cfg);
        screened_cells = cells.len();
        black_box(cells.len())
    });
    let refine_spec = ScenarioSpec::new(
        Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
        Gpu::H100,
        workload.clone(),
        gen.clone(),
    )
    .with_groups(16);
    g.bench("optimize_stage_b_refine(one cell)", || {
        black_box(refine_spec.simulate_trace(&trace, true).output_tokens)
    });

    // K-pool screen: the partition-native stage A over the full
    // generated K ∈ {2,3,4} cutoff grids × the legacy γ grid — the cost
    // of opening the K axis analytically.
    let kpool_parts: Vec<Vec<u32>> =
        (2u32..=4).flat_map(optimize::kpool_partitions).collect();
    let mut kpool_cells = 0usize;
    g.bench("optimize_stage_a_kpool_screen(K=2..4)", || {
        let cfg = OptimizeConfig {
            gpus: vec![Gpu::H100],
            partitions: kpool_parts.clone(),
            gen: gen.clone(),
            groups: 16,
            ..Default::default()
        };
        let cells = optimize::screen(&workload, &cfg);
        kpool_cells = cells.len();
        black_box(cells.len())
    });

    // Heterogeneous screen: the mixed H100/B200 cross-product over the
    // K ∈ {2, 3} cutoff grids — the analytical cost of opening the
    // GPU-assignment-per-pool axis.
    let hetero_parts: Vec<Vec<u32>> =
        (2u32..=3).flat_map(optimize::kpool_partitions).collect();
    let mut hetero_cells = 0usize;
    g.bench("optimize_stage_a_hetero_screen(K=2..3, H100xB200)", || {
        let cfg = OptimizeConfig {
            gpus: vec![Gpu::H100, Gpu::B200],
            partitions: hetero_parts.clone(),
            gpu_axis: optimize::GpuAxis::Mixed,
            gen: gen.clone(),
            groups: 16,
            ..Default::default()
        };
        let cells = optimize::screen(&workload, &cfg);
        hetero_cells = cells.len();
        black_box(cells.len())
    });

    // Event-queue head-to-head: the calendar/bucket queue vs the legacy
    // binary heap on the sequential shared-queue path (one queue carries
    // every group's events — the path the queue swap targets). JSQ keeps
    // the live-state maintenance in the loop, like production runs.
    let eq_gen = |lambda_rps: f64, duration_s: f64| GenConfig {
        lambda_rps,
        duration_s,
        max_prompt_tokens: 30_000,
        max_output_tokens: 256,
        seed: 5,
    };
    let eq_trace_l1k =
        generate(&wattlaw::workload::cdf::azure_conversations(), &eq_gen(1000.0, 5.0));
    let eq_trace_l4k =
        generate(&wattlaw::workload::cdf::azure_conversations(), &eq_gen(4000.0, 2.5));
    let eq_opts = |qm: QueueMode| EngineOptions {
        allow_parallel: false,
        state_mode: StateMode::Incremental,
        queue_mode: qm,
        // Per-step: the queue swap is only visible under full event
        // pressure (fusion would collapse the very event counts this
        // section exists to stress).
        step_mode: StepMode::PerStep,
        validate_state: false,
    };
    // (steps, output tokens) per (queue, λ) cell, stats[8..12].
    let mut eq_steps = [0u64; 4];
    let mut eq_toks = [0u64; 4];
    {
        let cells: [(&str, &Vec<wattlaw::workload::Request>, QueueMode); 4] = [
            ("event_queue_calendar_l1000", &eq_trace_l1k, QueueMode::Calendar),
            ("event_queue_heap_l1000", &eq_trace_l1k, QueueMode::BinaryHeap),
            ("event_queue_calendar_l4000", &eq_trace_l4k, QueueMode::Calendar),
            ("event_queue_heap_l4000", &eq_trace_l4k, QueueMode::BinaryHeap),
        ];
        for (i, (name, tr, qm)) in cells.into_iter().enumerate() {
            g.bench(name, || {
                let mut jsq = JoinShortestQueue;
                let r = simulate_topology_opts(
                    tr,
                    &router,
                    &pool_groups,
                    &cfgs,
                    &mut jsq,
                    eq_opts(qm),
                );
                eq_steps[i] = r.steps;
                eq_toks[i] = r.output_tokens;
                black_box(r.output_tokens)
            });
        }
    }

    // Branch-and-bound heterogeneous screen vs the brute-force
    // cross-product at K ∈ {3, 4, 5} over a 3-generation set:
    // Eq. 4 evaluations visited and wall time, stats[12..18].
    let bnb_gpus = [Gpu::H100, Gpu::H200, Gpu::B200];
    let bnb_gammas = [1.0, 2.0];
    let bnb_keep = OptimizeConfig::default().mixed_keep;
    // (K, brute stats, bnb stats) in bench order.
    let mut bnb_work: Vec<(u32, MixedScreenStats, MixedScreenStats)> =
        Vec::new();
    for k in [3u32, 4, 5] {
        let parts = optimize::kpool_partitions(k);
        let mut run = |mode: MixedScreen| {
            let mut stats = MixedScreenStats::default();
            g.bench(
                format!(
                    "bnb_screen_k{k}_{}",
                    if mode == MixedScreen::BruteForce { "brute" } else { "bnb" }
                ),
                || {
                    let (cells, s) = optimize::screen_mixed(
                        &workload,
                        gen.lambda_rps,
                        &parts,
                        &bnb_gpus,
                        &bnb_gammas,
                        LBarPolicy::Window,
                        0.85,
                        0.5,
                        PowerAccounting::PerGpu,
                        mode,
                        bnb_keep,
                        ModelAxis::Dense,
                    );
                    stats = s;
                    black_box(cells.len())
                },
            );
            stats
        };
        let brute = run(MixedScreen::BruteForce);
        let bnb = run(MixedScreen::BranchAndBound);
        bnb_work.push((k, brute, bnb));
    }

    // Streaming arrivals head-to-head: the materialized Vec<Request>
    // engine vs the fused generate-as-you-go SynthSource on the same
    // seeded workload. The streamed run re-derives every arrival inside
    // the loop (no trace is ever held, so its events/sec includes
    // generation); the replay asserts below pin both paths to the same
    // bits. JSQ keeps live-state maintenance in the loop. stats[18..22].
    let stream_gens = [eq_gen(1000.0, 5.0), eq_gen(4000.0, 2.5)];
    let stream_traces = [&eq_trace_l1k, &eq_trace_l4k];
    let mut sa_steps = [0u64; 4];
    let mut sa_toks = [0u64; 4];
    let mut sa_joules = [0f64; 4];
    for (li, label) in ["l1000", "l4000"].into_iter().enumerate() {
        let tr = stream_traces[li];
        g.bench(format!("streaming_materialized_{label}"), || {
            let mut jsq = JoinShortestQueue;
            let r = simulate_topology_opts(
                tr,
                &router,
                &pool_groups,
                &cfgs,
                &mut jsq,
                eq_opts(QueueMode::Calendar),
            );
            sa_steps[2 * li] = r.steps;
            sa_toks[2 * li] = r.output_tokens;
            sa_joules[2 * li] = r.joules;
            black_box(r.output_tokens)
        });
        g.bench(format!("streaming_streamed_{label}"), || {
            let mut jsq = JoinShortestQueue;
            let mut src = SynthSource::new(&workload, &stream_gens[li]);
            let r = simulate_topology_source(
                &mut src,
                &router,
                &pool_groups,
                &cfgs,
                &mut jsq,
                eq_opts(QueueMode::Calendar),
            );
            sa_steps[2 * li + 1] = r.steps;
            sa_toks[2 * li + 1] = r.output_tokens;
            sa_joules[2 * li + 1] = r.joules;
            black_box(r.output_tokens)
        });
    }

    // Macro-step event fusion head-to-head: the fused production
    // default vs the per-step oracle on the λ ∈ {1000, 4000} traces
    // (JSQ, calendar queue). Same floats either way — the replay
    // asserts below pin that — so the delta is pure event-schedule
    // cost. stats[22..26].
    let ms_opts = |step_mode: StepMode| EngineOptions {
        allow_parallel: false,
        state_mode: StateMode::Incremental,
        queue_mode: QueueMode::Calendar,
        step_mode,
        validate_state: false,
    };
    let ms_names = [
        "macro_step_per_step_l1000",
        "macro_step_fused_l1000",
        "macro_step_per_step_l4000",
        "macro_step_fused_l4000",
    ];
    let ms_traces = [&eq_trace_l1k, &eq_trace_l1k, &eq_trace_l4k, &eq_trace_l4k];
    let ms_modes = [
        StepMode::PerStep,
        StepMode::Fused,
        StepMode::PerStep,
        StepMode::Fused,
    ];
    let mut ms_events = [0u64; 4];
    let mut ms_steps = [0u64; 4];
    let mut ms_toks = [0u64; 4];
    let mut ms_joules = [0f64; 4];
    for i in 0..4 {
        let tr = ms_traces[i];
        let mode = ms_modes[i];
        g.bench(ms_names[i], || {
            let mut jsq = JoinShortestQueue;
            let r = simulate_topology_opts(
                tr,
                &router,
                &pool_groups,
                &cfgs,
                &mut jsq,
                ms_opts(mode),
            );
            ms_events[i] = r.events_popped;
            ms_steps[i] = r.steps;
            ms_toks[i] = r.output_tokens;
            ms_joules[i] = r.joules;
            black_box(r.output_tokens)
        });
    }

    // The model-architecture axis through the event engine: the same
    // λ=1000 trace and two-pool fleet, re-profiled per ModelAxis the
    // way `sim_pools_with_model` does. The axis is pure roofline/power
    // re-parameterization — dense must replay the calendar baseline
    // bit-for-bit (asserted below), so any per-event cost of the axis
    // would show up as a dense slowdown. stats[26..29].
    let ma_models = [
        ("dense", ModelAxis::Dense),
        ("qwen3_moe", ModelAxis::MoeStreaming { dispatch_ms: 0.0 }),
        (
            "dense_spec",
            ModelAxis::Speculative {
                k: ModelAxis::SPEC_K,
                alpha: ModelAxis::SPEC_ALPHA,
            },
        ),
    ];
    let mut ma_steps = [0u64; 3];
    let mut ma_toks = [0u64; 3];
    let mut ma_joules = [0f64; 3];
    for (i, (label, model)) in ma_models.iter().enumerate() {
        let mp = model.profile_for(Gpu::H100);
        let ma_mk = |window: u32| GroupSimConfig {
            window_tokens: window,
            n_max: mp.n_max(window),
            roofline: mp.roofline(),
            power: mp.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        };
        let ma_cfgs = [ma_mk(4096 + 1024), ma_mk(65_536)];
        g.bench(format!("model_axis_{label}_l1000"), || {
            let mut jsq = JoinShortestQueue;
            let r = simulate_topology_opts(
                &eq_trace_l1k,
                &router,
                &pool_groups,
                &ma_cfgs,
                &mut jsq,
                eq_opts(QueueMode::Calendar),
            );
            ma_steps[i] = r.steps;
            ma_toks[i] = r.output_tokens;
            ma_joules[i] = r.joules;
            black_box(r.output_tokens)
        });
    }

    // Sharded streaming head-to-head: the sequential streamed engine vs
    // the per-group demux on the same generated λ ∈ {1000, 4000}
    // streams. Round-robin dispatch is arrival-static, so the parallel
    // path engages the demux: the main thread routes each arrival into
    // a bounded per-group channel and one worker per group drains its
    // own calendar. Per-step keeps events/sec meaning one engine
    // iteration per event, and under per-step the sharded run must pop
    // exactly the sequential event count (asserted below along with the
    // float replay). stats[29..33].
    let ps_opts = |allow_parallel: bool| EngineOptions {
        allow_parallel,
        state_mode: StateMode::Incremental,
        queue_mode: QueueMode::Calendar,
        step_mode: StepMode::PerStep,
        validate_state: false,
    };
    let ps_names = [
        "parallel_stream_sequential_l1000",
        "parallel_stream_sharded_l1000",
        "parallel_stream_sequential_l4000",
        "parallel_stream_sharded_l4000",
    ];
    let mut ps_steps = [0u64; 4];
    let mut ps_toks = [0u64; 4];
    let mut ps_joules = [0f64; 4];
    let mut ps_events = [0u64; 4];
    for (i, name) in ps_names.iter().enumerate() {
        let li = i / 2;
        let sharded = i % 2 == 1;
        g.bench(*name, || {
            let mut rr = RoundRobin::new();
            let mut src = SynthSource::new(&workload, &stream_gens[li]);
            let r = simulate_topology_source(
                &mut src,
                &router,
                &pool_groups,
                &cfgs,
                &mut rr,
                ps_opts(sharded),
            );
            ps_steps[i] = r.steps;
            ps_toks[i] = r.output_tokens;
            ps_joules[i] = r.joules;
            ps_events[i] = r.events_popped;
            black_box(r.output_tokens)
        });
    }

    // Memoized stage-A screen vs the disabled-memo oracle on the mixed
    // H100×B200 grid: every homogeneous Eq. 4 table row the
    // branch-and-bound axis re-derives is a cache replay under the
    // shared memo. Both screens must rank identically, bit for bit
    // (asserted below). stats[33..35].
    let sm_cfg = OptimizeConfig {
        gpus: vec![Gpu::H100, Gpu::B200],
        partitions: hetero_parts.clone(),
        gpu_axis: optimize::GpuAxis::Mixed,
        gen: gen.clone(),
        groups: 16,
        ..Default::default()
    };
    let mut sm_uncached_cells: Vec<ScreenedCell> = Vec::new();
    g.bench("screen_memo_uncached", || {
        sm_uncached_cells = optimize::screen_uncached(&workload, &sm_cfg);
        black_box(sm_uncached_cells.len())
    });
    let mut sm_cached_cells: Vec<ScreenedCell> = Vec::new();
    let mut sm_stats = ScreenMemoStats::default();
    g.bench("screen_memo_cached", || {
        let (cells, st) = optimize::screen_with_stats(&workload, &sm_cfg);
        sm_cached_cells = cells;
        sm_stats = st;
        black_box(sm_cached_cells.len())
    });

    let stats = g.finish();
    assert_eq!(steps_seq, steps_par, "parallel fast path must replay exactly");
    assert_eq!(
        steps_jsq_rebuild, steps_jsq_incr,
        "incremental state must replay the rebuild oracle exactly"
    );
    let ev_per_s = |steps: u64, s: &BenchStats| steps as f64 / (s.mean_ns / 1e9);
    println!();
    let rows = [
        ("sequential rr", steps_seq, &stats[0]),
        ("parallel rr", steps_par, &stats[1]),
        ("jsq rebuild (before)", steps_jsq_rebuild, &stats[2]),
        ("jsq incremental (after)", steps_jsq_incr, &stats[3]),
    ];
    for (name, steps, s) in rows {
        println!(
            "{name:<24} {steps} step events, {:.0} events/sec (mean)",
            ev_per_s(steps, s)
        );
    }
    println!(
        "parallel speedup over sequential (rr): {:.2}x",
        stats[0].mean_ns / stats[1].mean_ns
    );
    let incr_speedup = stats[2].mean_ns / stats[3].mean_ns;
    println!(
        "incremental-state speedup over per-arrival snapshots (jsq): {:.2}x",
        incr_speedup
    );
    let screen_us_per_cell =
        stats[4].mean_ns / 1e3 / screened_cells.max(1) as f64;
    let refine_vs_screen_cell =
        stats[5].mean_ns / (stats[4].mean_ns / screened_cells.max(1) as f64);
    println!(
        "optimizer: stage A {:.1} µs/analytical cell ({screened_cells} cells), \
         stage B {:.1} ms/refined cell — refine/screen cell ratio {:.0}x",
        screen_us_per_cell,
        stats[5].mean_ns / 1e6,
        refine_vs_screen_cell,
    );
    let kpool_us_per_cell = stats[6].mean_ns / 1e3 / kpool_cells.max(1) as f64;
    println!(
        "kpool screen: {} partition x gamma cells (K=2..4) in {:.1} ms \
         ({kpool_us_per_cell:.1} µs/cell)",
        kpool_cells,
        stats[6].mean_ns / 1e6,
    );
    let hetero_us_per_cell =
        stats[7].mean_ns / 1e3 / hetero_cells.max(1) as f64;
    println!(
        "hetero screen: {} assignment x partition x gamma cells (K=2..3, \
         H100 x B200, branch-and-bound) in {:.1} ms \
         ({hetero_us_per_cell:.1} µs/cell)",
        hetero_cells,
        stats[7].mean_ns / 1e6,
    );

    // Queue-swap correctness + headline: both queues must replay the
    // same trace bit-for-bit, so the events/sec delta is pure queue cost.
    for pair in [(0usize, 1usize), (2, 3)] {
        assert_eq!(
            eq_steps[pair.0], eq_steps[pair.1],
            "calendar queue must replay the binary-heap oracle exactly"
        );
        assert_eq!(eq_toks[pair.0], eq_toks[pair.1]);
    }
    let eq_names = [
        "event_queue_calendar_l1000",
        "event_queue_heap_l1000",
        "event_queue_calendar_l4000",
        "event_queue_heap_l4000",
    ];
    for (i, name) in eq_names.iter().enumerate() {
        println!(
            "{name:<28} {} step events, {:.0} events/sec (mean)",
            eq_steps[i],
            ev_per_s(eq_steps[i], &stats[8 + i])
        );
    }
    println!(
        "calendar speedup over heap: {:.2}x (λ=1000), {:.2}x (λ=4000)",
        stats[9].mean_ns / stats[8].mean_ns,
        stats[11].mean_ns / stats[10].mean_ns,
    );
    for (i, (k, brute, bnb)) in bnb_work.iter().enumerate() {
        let (bs, ns) = (&stats[12 + 2 * i], &stats[13 + 2 * i]);
        let visited = bnb.nodes_visited + bnb.table_evals + bnb.full_evals;
        println!(
            "bnb screen K={k}: brute {} cells in {:.1} ms vs B&B {} \
             visited ({} pruned subtrees, {} exact re-evals) in {:.1} ms \
             — {:.2}x",
            brute.brute_cells,
            bs.mean_ns / 1e6,
            visited,
            bnb.pruned,
            bnb.full_evals,
            ns.mean_ns / 1e6,
            bs.mean_ns / ns.mean_ns,
        );
    }

    // Streamed runs must replay the materialized engine exactly —
    // otherwise the events/sec comparison is comparing different
    // simulations.
    for li in 0..2 {
        assert_eq!(
            sa_steps[2 * li],
            sa_steps[2 * li + 1],
            "streamed engine must replay the materialized oracle exactly"
        );
        assert_eq!(sa_toks[2 * li], sa_toks[2 * li + 1]);
        assert_eq!(
            sa_joules[2 * li].to_bits(),
            sa_joules[2 * li + 1].to_bits(),
            "streamed joules must match bit-for-bit"
        );
    }
    let sa_names = [
        "streaming_materialized_l1000",
        "streaming_streamed_l1000",
        "streaming_materialized_l4000",
        "streaming_streamed_l4000",
    ];
    for (i, name) in sa_names.iter().enumerate() {
        println!(
            "{name:<30} {} step events, {:.0} events/sec (mean)",
            sa_steps[i],
            ev_per_s(sa_steps[i], &stats[18 + i])
        );
    }
    // Peak trace-memory proxy: what each path must hold of the arrival
    // stream. The materialized engine owns the whole sorted Vec; the
    // streamed engine owns exactly one pending Request at any moment.
    let req_bytes = std::mem::size_of::<Request>();
    let sa_trace_bytes =
        [stream_traces[0].len() * req_bytes, stream_traces[1].len() * req_bytes];
    println!(
        "streamed/materialized time ratio: {:.2}x (λ=1000), {:.2}x (λ=4000); \
         trace memory held: {:.1} KB / {:.1} KB materialized vs \
         {req_bytes} B streamed",
        stats[19].mean_ns / stats[18].mean_ns,
        stats[21].mean_ns / stats[20].mean_ns,
        sa_trace_bytes[0] as f64 / 1e3,
        sa_trace_bytes[1] as f64 / 1e3,
    );

    // Fused runs must replay the per-step oracle exactly — the whole
    // point of macro-stepping is fewer events, not different floats —
    // and at λ=4000 per-step must pop at least 10× more events (the
    // PR's acceptance bar).
    for li in 0..2 {
        let (ps, fu) = (2 * li, 2 * li + 1);
        assert_eq!(
            ms_steps[ps], ms_steps[fu],
            "fused engine must execute exactly the per-step schedule"
        );
        assert_eq!(ms_toks[ps], ms_toks[fu]);
        assert_eq!(
            ms_joules[ps].to_bits(),
            ms_joules[fu].to_bits(),
            "fused joules must replay the per-step oracle bit-for-bit"
        );
        assert!(
            ms_events[fu] < ms_events[ps],
            "fusion must reduce events popped: {} vs {}",
            ms_events[fu],
            ms_events[ps]
        );
    }
    assert!(
        ms_events[2] >= 10 * ms_events[3],
        "λ=4000: per-step must pop ≥10× the fused events — got {} vs {}",
        ms_events[2],
        ms_events[3]
    );
    let ms_arrivals = [eq_trace_l1k.len() as u64, eq_trace_l4k.len() as u64];
    for (i, name) in ms_names.iter().enumerate() {
        println!(
            "{name:<28} {} events popped ({} sim steps), \
             {:.0} sim steps/sec (mean)",
            ms_events[i],
            ms_steps[i],
            ev_per_s(ms_steps[i], &stats[22 + i])
        );
    }
    let ms_ratio = |li: usize| ms_events[2 * li] as f64 / ms_events[2 * li + 1] as f64;
    let ms_fused_per_arrival =
        |li: usize| ms_events[2 * li + 1] as f64 / ms_arrivals[li] as f64;
    println!(
        "macro-step fusion: {:.1}x fewer events, {:.2}x faster, \
         {:.2} fused events/arrival (λ=1000); {:.1}x fewer events, \
         {:.2}x faster, {:.2} fused events/arrival (λ=4000)",
        ms_ratio(0),
        stats[22].mean_ns / stats[23].mean_ns,
        ms_fused_per_arrival(0),
        ms_ratio(1),
        stats[24].mean_ns / stats[25].mean_ns,
        ms_fused_per_arrival(1),
    );

    // The dense model-axis cell is the calendar λ=1000 cell under a new
    // name: the axis must cost nothing when it is not exercised.
    assert_eq!(
        ma_steps[0], eq_steps[0],
        "dense ModelAxis must replay the calendar baseline exactly"
    );
    assert_eq!(ma_toks[0], eq_toks[0]);
    let ma_tok_per_j = |i: usize| ma_toks[i] as f64 / ma_joules[i];
    assert!(
        ma_tok_per_j(1) > ma_tok_per_j(0),
        "weight streaming must beat dense through the simulator: \
         {} vs {} tok/J",
        ma_tok_per_j(1),
        ma_tok_per_j(0)
    );
    for (i, (label, _)) in ma_models.iter().enumerate() {
        println!(
            "model_axis_{label:<12} {} step events, {:.0} events/sec \
             (mean), {:.2} tok/J",
            ma_steps[i],
            ev_per_s(ma_steps[i], &stats[26 + i]),
            ma_tok_per_j(i)
        );
    }

    // The sharded demux must replay the sequential stream exactly —
    // same floats and, under per-step, the same event count — otherwise
    // the events/sec comparison is comparing different simulations.
    for li in 0..2 {
        let (sq, sh) = (2 * li, 2 * li + 1);
        assert_eq!(
            ps_steps[sq], ps_steps[sh],
            "sharded stream must replay the sequential stream exactly"
        );
        assert_eq!(ps_toks[sq], ps_toks[sh]);
        assert_eq!(
            ps_joules[sq].to_bits(),
            ps_joules[sh].to_bits(),
            "sharded joules must match bit-for-bit"
        );
        assert_eq!(
            ps_events[sq], ps_events[sh],
            "per-step sharded run must pop exactly the sequential events"
        );
    }
    for (i, name) in ps_names.iter().enumerate() {
        println!(
            "{name:<34} {} step events, {:.0} events/sec (mean)",
            ps_steps[i],
            ev_per_s(ps_steps[i], &stats[29 + i])
        );
    }
    println!(
        "sharded speedup over sequential stream: {:.2}x (λ=1000), \
         {:.2}x (λ=4000)",
        stats[29].mean_ns / stats[30].mean_ns,
        stats[31].mean_ns / stats[32].mean_ns,
    );
    // 16 groups of decode work at λ=4000 dwarf the channel overhead —
    // the demux must actually win there. --quick smoke runs (3 samples,
    // cramped CI cores) are too noisy to hold a wall-clock bar, so the
    // bar applies to full runs only.
    if !quick {
        assert!(
            stats[32].mean_ns < stats[31].mean_ns,
            "sharded stream must beat the sequential stream at λ=4000: \
             {:.1} ms vs {:.1} ms",
            stats[32].mean_ns / 1e6,
            stats[31].mean_ns / 1e6
        );
    }

    // The memo must not change the ranking: same cells, same bits.
    assert_eq!(
        sm_uncached_cells.len(),
        sm_cached_cells.len(),
        "memoized screen must produce the uncached cell count"
    );
    for (a, b) in sm_uncached_cells.iter().zip(&sm_cached_cells) {
        assert_eq!(a.gpus, b.gpus, "memoized screen must rank identically");
        assert_eq!(
            a.analytic.tok_per_watt.0.to_bits(),
            b.analytic.tok_per_watt.0.to_bits(),
            "memoized screen must replay the uncached floats bit-for-bit"
        );
    }
    assert!(sm_stats.hits > 0, "the mixed screen must hit the memo");
    let sm_cells = sm_cached_cells.len().max(1) as f64;
    println!(
        "screen memo: {} cells — uncached {:.1} ms, cached {:.1} ms \
         ({:.2}x), {} of {} Eq. 4 evals from cache ({:.0}% hit rate)",
        sm_cached_cells.len(),
        stats[33].mean_ns / 1e6,
        stats[34].mean_ns / 1e6,
        stats[33].mean_ns / stats[34].mean_ns,
        sm_stats.hits,
        sm_stats.evals,
        100.0 * sm_stats.hit_rate(),
    );

    // --gate: fail (after optionally recording) if calendar events/sec
    // regressed more than 20% against the committed non-null baseline.
    let mut gate_failures: Vec<String> = Vec::new();
    if let Some(text) = &baseline {
        if let Ok(doc) = wattlaw::runtime::json::parse(text) {
            let entries = doc
                .get("event_queue")
                .and_then(|q| q.get("entries"))
                .and_then(|e| e.as_arr())
                .unwrap_or(&[]);
            for entry in entries {
                let Some(name) = entry.get("name").and_then(|n| n.as_str())
                else {
                    continue;
                };
                let Some(base) =
                    entry.get("events_per_sec").and_then(|v| v.as_f64())
                else {
                    continue; // still null: nothing to gate against
                };
                let Some(i) = eq_names.iter().position(|n| *n == name) else {
                    continue;
                };
                let now = ev_per_s(eq_steps[i], &stats[8 + i]);
                if now < 0.8 * base {
                    gate_failures.push(format!(
                        "{name}: {now:.0} events/sec is {:.1}% below the \
                         committed baseline {base:.0}",
                        (1.0 - now / base) * 100.0
                    ));
                }
            }
            // The fused cells are what production actually runs — gate
            // their sim-step throughput the same way.
            let ms_entries = doc
                .get("macro_step")
                .and_then(|q| q.get("entries"))
                .and_then(|e| e.as_arr())
                .unwrap_or(&[]);
            for entry in ms_entries {
                let Some(name) = entry.get("name").and_then(|n| n.as_str())
                else {
                    continue;
                };
                let Some(base) =
                    entry.get("sim_steps_per_sec").and_then(|v| v.as_f64())
                else {
                    continue; // still null: nothing to gate against
                };
                let Some(i) = ms_names.iter().position(|n| *n == name) else {
                    continue;
                };
                let now = ev_per_s(ms_steps[i], &stats[22 + i]);
                if now < 0.8 * base {
                    gate_failures.push(format!(
                        "{name}: {now:.0} sim steps/sec is {:.1}% below \
                         the committed baseline {base:.0}",
                        (1.0 - now / base) * 100.0
                    ));
                }
            }
            // Sharded-streaming cells gate the same way: a demux
            // regression shows up as events/sec lost against the
            // recorded baseline.
            let ps_entries = doc
                .get("parallel_stream")
                .and_then(|q| q.get("entries"))
                .and_then(|e| e.as_arr())
                .unwrap_or(&[]);
            for entry in ps_entries {
                let Some(name) = entry.get("name").and_then(|n| n.as_str())
                else {
                    continue;
                };
                let Some(base) =
                    entry.get("events_per_sec").and_then(|v| v.as_f64())
                else {
                    continue; // still null: nothing to gate against
                };
                let Some(i) = ps_names.iter().position(|n| *n == name) else {
                    continue;
                };
                let now = ev_per_s(ps_steps[i], &stats[29 + i]);
                if now < 0.8 * base {
                    gate_failures.push(format!(
                        "{name}: {now:.0} events/sec is {:.1}% below the \
                         committed baseline {base:.0}",
                        (1.0 - now / base) * 100.0
                    ));
                }
            }
            // The cached screen is what `optimize` now runs — gate its
            // cell throughput too.
            if let Some(base) = doc
                .get("screen_memo")
                .and_then(|q| q.get("cached_cells_per_ms"))
                .and_then(|v| v.as_f64())
            {
                let now = sm_cells / (stats[34].mean_ns / 1e6);
                if now < 0.8 * base {
                    gate_failures.push(format!(
                        "screen_memo_cached: {now:.1} cells/ms is {:.1}% \
                         below the committed baseline {base:.1}",
                        (1.0 - now / base) * 100.0
                    ));
                }
            }
        }
    }

    if record {
        let mut j = String::new();
        j.push_str("{\n");
        j.push_str("  \"bench\": \"bench_sim_engine\",\n");
        j.push_str(&format!(
            "  \"unit\": \"step events per second (mean over {} samples)\",\n",
            cfg.samples
        ));
        j.push_str(&format!(
            "  \"trace\": {{ \"requests\": {}, \"lambda_rps\": {}, \
             \"duration_s\": {} }},\n",
            trace.len(),
            gen.lambda_rps,
            gen.duration_s
        ));
        j.push_str(
            "  \"fleet\": { \"groups\": 16, \"topology\": \
             \"two-pool 4K/64K\", \"gpu\": \"H100\" },\n",
        );
        j.push_str("  \"results\": [\n");
        for (i, (name, steps, s)) in rows.iter().enumerate() {
            j.push_str(&format!(
                "    {{ \"name\": \"{name}\", \"steps\": {steps}, \
                 \"events_per_sec\": {:.0}, \"mean_ms\": {:.2} }}{}\n",
                ev_per_s(*steps, s),
                s.mean_ns / 1e6,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        j.push_str("  ],\n");
        j.push_str(&format!(
            "  \"incremental_state\": {{\n    \
             \"before_events_per_sec\": {:.0},\n    \
             \"after_events_per_sec\": {:.0},\n    \"speedup\": {:.3},\n    \
             \"note\": \"before = StateMode::RebuildPerArrival (full \
             FleetState snapshot per arrival, the pre-refactor engine); \
             after = StateMode::Incremental (in-place live state)\"\n  }},\n",
            ev_per_s(steps_jsq_rebuild, &stats[2]),
            ev_per_s(steps_jsq_incr, &stats[3]),
            incr_speedup
        ));
        j.push_str(&format!(
            "  \"optimizer\": {{\n    \
             \"stage_a_screen_ms\": {:.3},\n    \
             \"stage_a_cells\": {screened_cells},\n    \
             \"stage_a_us_per_cell\": {screen_us_per_cell:.2},\n    \
             \"stage_b_refine_cell_ms\": {:.2},\n    \
             \"refine_to_screen_cell_ratio\": {refine_vs_screen_cell:.0},\n    \
             \"note\": \"stage A = closed-form screen of the legacy \
             B_short x gamma grid (scenario::optimize::screen, H100); \
             stage B = one ScenarioSpec::simulate cell on the 10k-request \
             trace, 16 groups — the cost asymmetry that justifies \
             screen-wide-refine-narrow\"\n  }},\n",
            stats[4].mean_ns / 1e6,
            stats[5].mean_ns / 1e6,
        ));
        j.push_str(&format!(
            "  \"kpool_screen\": {{\n    \
             \"cells\": {kpool_cells},\n    \
             \"screen_ms\": {:.3},\n    \
             \"us_per_cell\": {kpool_us_per_cell:.2},\n    \
             \"note\": \"partition-native stage A over the generated \
             K in 2..=4 cutoff grids (41 partition vectors x the legacy \
             gamma grid, H100) — the analytical cost of the K-pool \
             topology axis\"\n  }},\n",
            stats[6].mean_ns / 1e6,
        ));
        j.push_str(&format!(
            "  \"hetero_screen\": {{\n    \
             \"cells\": {hetero_cells},\n    \
             \"screen_ms\": {:.3},\n    \
             \"us_per_cell\": {hetero_us_per_cell:.2},\n    \
             \"note\": \"GpuAxis::Mixed stage A: homogeneous H100/B200 \
             cells plus the branch-and-bound mixed H100xB200 assignment \
             screen over the K in 2..=3 cutoff grids x the \
             legacy gamma grid — the analytical cost of the \
             generation-per-pool axis\"\n  }},\n",
            stats[7].mean_ns / 1e6,
        ));
        j.push_str("  \"event_queue\": {\n    \"entries\": [\n");
        for (i, name) in eq_names.iter().enumerate() {
            j.push_str(&format!(
                "      {{ \"name\": \"{name}\", \"steps\": {}, \
                 \"events_per_sec\": {:.0}, \"mean_ms\": {:.2} }}{}\n",
                eq_steps[i],
                ev_per_s(eq_steps[i], &stats[8 + i]),
                stats[8 + i].mean_ns / 1e6,
                if i + 1 < eq_names.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "    ],\n    \
             \"calendar_speedup_l1000\": {:.3},\n    \
             \"calendar_speedup_l4000\": {:.3},\n    \
             \"note\": \"calendar/bucket event queue vs the legacy \
             binary heap (QueueMode::BinaryHeap, the bit-for-bit replay \
             oracle) on the sequential shared-queue JSQ path — the \
             events/sec gate (--gate) trips when a calendar cell drops \
             more than 20% below this baseline\"\n  }},\n",
            stats[9].mean_ns / stats[8].mean_ns,
            stats[11].mean_ns / stats[10].mean_ns,
        ));
        j.push_str("  \"bnb_screen\": {\n    \"k\": [\n");
        for (i, (k, brute, bnb)) in bnb_work.iter().enumerate() {
            let visited =
                bnb.nodes_visited + bnb.table_evals + bnb.full_evals;
            j.push_str(&format!(
                "      {{ \"k\": {k}, \"brute_cells\": {}, \
                 \"brute_ms\": {:.3}, \"bnb_visited\": {visited}, \
                 \"bnb_pruned_subtrees\": {}, \"bnb_full_evals\": {}, \
                 \"bnb_ms\": {:.3}, \"speedup\": {:.3} }}{}\n",
                brute.brute_cells,
                stats[12 + 2 * i].mean_ns / 1e6,
                bnb.pruned,
                bnb.full_evals,
                stats[13 + 2 * i].mean_ns / 1e6,
                stats[12 + 2 * i].mean_ns / stats[13 + 2 * i].mean_ns,
                if i + 1 < bnb_work.len() { "," } else { "" }
            ));
        }
        j.push_str(
            "    ],\n    \
             \"note\": \"branch-and-bound heterogeneous stage-A screen \
             vs the brute-force assignment cross-product over the \
             generated K-pool cutoff grids, H100/H200/B200, gamma in \
             {1,2}, keep=64 — bnb_visited counts DFS nodes + table \
             builds + exact survivor re-evals\"\n  },\n",
        );
        j.push_str("  \"streaming_arrivals\": {\n    \"entries\": [\n");
        for (i, name) in sa_names.iter().enumerate() {
            j.push_str(&format!(
                "      {{ \"name\": \"{name}\", \"steps\": {}, \
                 \"events_per_sec\": {:.0}, \"mean_ms\": {:.2} }}{}\n",
                sa_steps[i],
                ev_per_s(sa_steps[i], &stats[18 + i]),
                stats[18 + i].mean_ns / 1e6,
                if i + 1 < sa_names.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "    ],\n    \
             \"streamed_over_materialized_time_l1000\": {:.3},\n    \
             \"streamed_over_materialized_time_l4000\": {:.3},\n    \
             \"materialized_trace_bytes_l1000\": {},\n    \
             \"materialized_trace_bytes_l4000\": {},\n    \
             \"streamed_pending_bytes\": {req_bytes},\n    \
             \"note\": \"materialized Vec<Request> engine vs the fused \
             generate-as-you-go SynthSource stream (JSQ, calendar \
             queue); the streamed run pays arrival generation inside \
             the loop and holds exactly one pending Request instead of \
             the whole trace — both paths replay-asserted to the same \
             bits before recording\"\n  }},\n",
            stats[19].mean_ns / stats[18].mean_ns,
            stats[21].mean_ns / stats[20].mean_ns,
            sa_trace_bytes[0],
            sa_trace_bytes[1],
        ));
        j.push_str("  \"macro_step\": {\n    \"entries\": [\n");
        for (i, name) in ms_names.iter().enumerate() {
            j.push_str(&format!(
                "      {{ \"name\": \"{name}\", \"steps\": {}, \
                 \"events_popped\": {}, \"sim_steps_per_sec\": {:.0}, \
                 \"mean_ms\": {:.2} }}{}\n",
                ms_steps[i],
                ms_events[i],
                ev_per_s(ms_steps[i], &stats[22 + i]),
                stats[22 + i].mean_ns / 1e6,
                if i + 1 < ms_names.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "    ],\n    \
             \"event_reduction_l1000\": {:.2},\n    \
             \"event_reduction_l4000\": {:.2},\n    \
             \"fused_speedup_l1000\": {:.3},\n    \
             \"fused_speedup_l4000\": {:.3},\n    \
             \"fused_events_per_arrival_l1000\": {:.3},\n    \
             \"fused_events_per_arrival_l4000\": {:.3},\n    \
             \"note\": \"StepMode::Fused (production default: quiescent \
             decode spans run in one in-line loop, one fused event at \
             the next-arrival horizon) vs the StepMode::PerStep \
             one-event-per-step oracle (JSQ, calendar queue, \
             incremental state) — replay-asserted bit-for-bit before \
             recording, and per-step must pop >= 10x the fused events \
             at lambda=4000; the --gate check trips when a fused cell's \
             sim-step throughput drops more than 20% below this \
             baseline\"\n  }},\n",
            ms_ratio(0),
            ms_ratio(1),
            stats[22].mean_ns / stats[23].mean_ns,
            stats[24].mean_ns / stats[25].mean_ns,
            ms_fused_per_arrival(0),
            ms_fused_per_arrival(1),
        ));
        j.push_str("  \"model_axis\": {\n    \"entries\": [\n");
        for (i, (label, _)) in ma_models.iter().enumerate() {
            j.push_str(&format!(
                "      {{ \"name\": \"model_axis_{label}_l1000\", \
                 \"steps\": {}, \"events_per_sec\": {:.0}, \
                 \"tok_per_joule\": {:.3}, \"mean_ms\": {:.2} }}{}\n",
                ma_steps[i],
                ev_per_s(ma_steps[i], &stats[26 + i]),
                ma_tok_per_j(i),
                stats[26 + i].mean_ns / 1e6,
                if i + 1 < ma_models.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "    ],\n    \
             \"moe_over_dense_tok_per_joule\": {:.3},\n    \
             \"note\": \"the model-architecture axis through the event \
             engine (JSQ, calendar, per-step, lambda=1000): each cell \
             re-profiles the same two-pool H100 fleet via \
             ModelAxis::profile_for, exactly as sim_pools_with_model \
             does — the dense cell is replay-asserted against the \
             calendar baseline, so the axis itself adds no per-event \
             cost\"\n  }},\n",
            ma_tok_per_j(1) / ma_tok_per_j(0),
        ));
        j.push_str("  \"parallel_stream\": {\n    \"entries\": [\n");
        for (i, name) in ps_names.iter().enumerate() {
            j.push_str(&format!(
                "      {{ \"name\": \"{name}\", \"steps\": {}, \
                 \"events_per_sec\": {:.0}, \"mean_ms\": {:.2} }}{}\n",
                ps_steps[i],
                ev_per_s(ps_steps[i], &stats[29 + i]),
                stats[29 + i].mean_ns / 1e6,
                if i + 1 < ps_names.len() { "," } else { "" }
            ));
        }
        j.push_str(&format!(
            "    ],\n    \
             \"sharded_speedup_l1000\": {:.3},\n    \
             \"sharded_speedup_l4000\": {:.3},\n    \
             \"note\": \"sequential streamed engine vs the sharded \
             per-group demux (round-robin, per-step, calendar queue, 16 \
             groups): the main thread routes each generated arrival \
             into a bounded per-group channel and one worker per group \
             drains its own calendar — replay-asserted to the same bits \
             and the same per-step event count before recording; the \
             --gate check trips when a cell drops more than 20% below \
             this baseline\"\n  }},\n",
            stats[29].mean_ns / stats[30].mean_ns,
            stats[31].mean_ns / stats[32].mean_ns,
        ));
        j.push_str(&format!(
            "  \"screen_memo\": {{\n    \
             \"cells\": {},\n    \
             \"uncached_ms\": {:.3},\n    \
             \"cached_ms\": {:.3},\n    \
             \"cached_cells_per_ms\": {:.2},\n    \
             \"speedup\": {:.3},\n    \
             \"memo_evals\": {},\n    \
             \"memo_hits\": {},\n    \
             \"hit_rate\": {:.3},\n    \
             \"note\": \"GpuAxis::Mixed stage A (H100xB200, K in 2..=3) \
             with the shared ScreenMemo vs the disabled-memo oracle — \
             every homogeneous Eq. 4 table row the branch-and-bound \
             axis re-derives is a cache replay; both screens are \
             asserted to rank identically, bit for bit, before \
             recording; the --gate check trips when cached cells/ms \
             drops more than 20% below this baseline\"\n  }},\n",
            sm_cached_cells.len(),
            stats[33].mean_ns / 1e6,
            stats[34].mean_ns / 1e6,
            sm_cells / (stats[34].mean_ns / 1e6),
            stats[33].mean_ns / stats[34].mean_ns,
            sm_stats.evals,
            sm_stats.hits,
            sm_stats.hit_rate(),
        ));
        j.push_str(
            "  \"recorded_by\": \"cargo bench --bench bench_sim_engine -- \
             --record\"\n}\n",
        );
        std::fs::write(JSON_PATH, &j).expect("write BENCH_sim_engine.json");
        println!("recorded to {JSON_PATH}");
    } else {
        println!("(pass --record to update BENCH_sim_engine.json)");
    }

    if gate {
        if gate_failures.is_empty() {
            println!("--gate: events/sec within 20% of the committed baseline");
        } else {
            for f in &gate_failures {
                eprintln!("--gate FAIL: {f}");
            }
            std::process::exit(1);
        }
    }
}
