//! Bench T1: regenerate Table 1 (context sweep) and time the sweep.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::fleet::profile::{ManualProfile, PowerAccounting};
use wattlaw::tables::t1;
use wattlaw::tokeconomy::context_sweep;

fn main() {
    // Regenerate the artifact first (the bench IS the reproduction).
    println!("{}", t1::generate());

    let mut g = BenchGroup::new("T1 — context sweep");
    let h100 = ManualProfile::h100_70b();
    g.bench("t1_full_table", || black_box(t1::rows()));
    g.bench("context_sweep_7pts_h100", || {
        black_box(context_sweep(&h100, &t1::CONTEXTS, PowerAccounting::PerGpu))
    });
    g.bench("t1_render", || black_box(t1::generate().len()));
    g.finish();
}
