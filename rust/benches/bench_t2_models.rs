//! Bench T2: regenerate Table 2 (model comparison) and time it.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::tables::t2;

fn main() {
    println!("{}", t2::generate());
    let mut g = BenchGroup::new("T2 — model comparison");
    g.bench("t2_rows_all_models", || black_box(t2::rows()));
    g.bench("t2_render", || black_box(t2::generate().len()));
    g.finish();
}
