//! Bench T3: regenerate Table 3 (fleet topology × generation) and time
//! the full fleet analysis (sizing + Eq. 4) per configuration.
use std::sync::Arc;
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::fleet::analysis::fleet_tpw_analysis;
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use wattlaw::fleet::topology::{Topology, LONG_CTX};
use wattlaw::tables::t3;
use wattlaw::workload::cdf::azure_conversations;

fn main() {
    println!("{}", t3::generate(LBarPolicy::Window));
    let mut g = BenchGroup::new("T3 — fleet analysis");
    let trace = azure_conversations();
    let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::h100_70b());
    let topo = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 };
    g.bench("fleet_tpw_analysis_fleetopt", || {
        let pools = topo.pools(&trace, 1000.0, profile.clone(), None,
                               LBarPolicy::Window, 0.85, 0.5);
        black_box(fleet_tpw_analysis(&pools, PowerAccounting::PerGpu))
    });
    g.bench("t3_full_table_12_rows", || black_box(t3::rows(LBarPolicy::Window)));
    let homo = Topology::Homogeneous { ctx: LONG_CTX };
    g.bench("fleet_tpw_analysis_homo", || {
        let pools = homo.pools(&trace, 1000.0, profile.clone(), None,
                               LBarPolicy::Window, 0.85, 0.5);
        black_box(fleet_tpw_analysis(&pools, PowerAccounting::PerGpu))
    });
    g.finish();
}
