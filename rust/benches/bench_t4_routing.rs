//! Bench T4: regenerate Table 4 (context vs semantic routing) and time
//! the router hot path (the per-request O(1) decision), including the
//! load-aware live path (adaptive router reading the engine's live
//! fleet state) and the dispatch pick_group scan over a wide pool.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::router::adaptive::AdaptiveRouter;
use wattlaw::router::context::ContextRouter;
use wattlaw::router::fleetopt::FleetOptRouter;
use wattlaw::router::semantic::SemanticRouter;
use wattlaw::router::Router;
use wattlaw::serve::request::ServeRequest;
use wattlaw::sim::dispatch::DispatchPolicy;
use wattlaw::sim::{
    FleetState, GroupLoad, JoinShortestQueue, PoolLoad, PowerAware,
};
use wattlaw::tables::t4;
use wattlaw::workload::Request;

fn main() {
    println!("{}", t4::generate());
    let mut g = BenchGroup::new("T4 — routing");
    g.bench("t4_rows", || black_box(t4::rows()));

    let reqs: Vec<Request> = (0..1024)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 1 + ((i as u32 * 2654435761) % 131072),
            output_tokens: 128,
        })
        .collect();
    let ctx = ContextRouter::two_pool(4096);
    let ctx8 = ContextRouter::tiered(vec![1024, 2048, 4096, 8192, 16384, 32768, 65536]);
    let fo = FleetOptRouter::new(4096, 2.0);
    let sem = SemanticRouter::new(0.35);
    g.bench("route_1k_reqs_two_pool", || {
        black_box(reqs.iter().map(|r| ctx.route(r).pool).sum::<usize>())
    });
    g.bench("route_1k_reqs_8tier", || {
        black_box(reqs.iter().map(|r| ctx8.route(r).pool).sum::<usize>())
    });
    g.bench("route_1k_reqs_fleetopt", || {
        black_box(reqs.iter().map(|r| fo.route(r).pool).sum::<usize>())
    });
    g.bench("route_1k_reqs_semantic", || {
        black_box(reqs.iter().map(|r| sem.route(r).pool).sum::<usize>())
    });

    // Load-aware live routing: the adaptive router reads a fleet
    // snapshot per decision (the event engine's arrival path).
    let adaptive = AdaptiveRouter::new(4096);
    let pool = |backlog: usize, window: u32, n_max: u32, groups: usize| PoolLoad {
        window_tokens: window,
        n_max,
        groups: vec![
            GroupLoad {
                queued: backlog,
                active: n_max as usize / 2,
                free_blocks: 1024,
                used_blocks: 1024,
            };
            groups
        ],
    };
    let state =
        FleetState::from_pools(vec![pool(12, 5120, 128, 8), pool(1, 65_536, 16, 8)]);
    g.bench("route_live_1k_reqs_adaptive", || {
        black_box(
            reqs.iter()
                .map(|r| adaptive.route_live(r, &state).pool)
                .sum::<usize>(),
        )
    });

    // Dispatch hot path: one pick_group is an O(groups) scan of the live
    // state (the engine pays it once per arrival; since the
    // incremental-state refactor it pays *only* this — no snapshot).
    let wide = FleetState::from_pools(vec![PoolLoad {
        window_tokens: 5120,
        n_max: 128,
        groups: (0..64)
            .map(|g| GroupLoad {
                queued: (g * 7) % 13,
                active: (g * 11) % 97,
                free_blocks: 4096 - (g as u32 * 53) % 4096,
                used_blocks: (g as u32 * 53) % 4096,
            })
            .collect(),
    }]);
    let sreq =
        ServeRequest { id: 0, prompt_tokens: 512, output_tokens: 64, arrival_s: 0.0 };
    g.bench("dispatch_jsq_pick_1k_over_64_groups", || {
        let mut jsq = JoinShortestQueue;
        black_box(
            (0..1024).map(|_| jsq.pick_group(0, 64, &sreq, &wide)).sum::<usize>(),
        )
    });
    g.bench("dispatch_power_pick_1k_over_64_groups", || {
        let mut pa = PowerAware::new();
        black_box(
            (0..1024).map(|_| pa.pick_group(0, 64, &sreq, &wide)).sum::<usize>(),
        )
    });
    g.finish();
}
