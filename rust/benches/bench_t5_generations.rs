//! Bench T5: regenerate Table 5 (GPU generation comparison).
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::tables::t5;

fn main() {
    println!("{}", t5::generate());
    let mut g = BenchGroup::new("T5 — GPU generations");
    g.bench("t5_rows", || black_box(t5::rows()));
    g.finish();
}
