//! Bench T6: regenerate Table 6 (archetype recommendations — a full
//! topology × GPU argmax sweep per trace).
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::tables::t6;

fn main() {
    println!("{}", t6::generate());
    let mut g = BenchGroup::new("T6 — archetype recommendation sweep");
    g.bench("t6_rows_full_argmax", || black_box(t6::rows()));
    g.finish();
}
