//! Bench T7: regenerate Table 7 and time the logistic calibration fit.
use wattlaw::benchkit::{black_box, BenchGroup};
use wattlaw::power::fit::fit_logistic;
use wattlaw::power::mlenergy;
use wattlaw::tables::t7;

fn main() {
    println!("{}", t7::generate());
    let mut g = BenchGroup::new("T7 — power model calibration");
    let samples = mlenergy::h100_measurements(0, 0.03);
    g.bench("fit_logistic_9pts", || black_box(fit_logistic(&samples)));
    g.bench("regen_measurements", || black_box(mlenergy::h100_measurements(1, 0.03)));
    g.finish();
}
