//! `benchkit` — a small benchmark harness (criterion is not fetchable in
//! this offline image). Used by every `[[bench]]` target (`harness =
//! false`), producing warmed-up, repeatable timing statistics and
//! markdown-friendly output.
//!
//! Method: warm up for `warmup_iters`, then run `samples` batches of
//! `batch` iterations each, recording per-iteration time per batch;
//! report mean / p50 / p99 / min plus throughput. A `black_box` is
//! provided to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::time::Instant;

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's collected statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    fn from_samples(name: String, mut ns: Vec<f64>) -> Self {
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let q = |p: f64| ns[((n as f64 - 1.0) * p).round() as usize];
        BenchStats {
            name,
            mean_ns: mean,
            p50_ns: q(0.50),
            p99_ns: q(0.99),
            min_ns: ns[0],
            samples: ns,
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }

    /// One human-readable row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            format!("{:.0}/s", self.per_sec()),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: u64,
    pub samples: usize,
    pub batch: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Honor `--quick` on the bench command line and WATTLAW_BENCH_QUICK.
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("WATTLAW_BENCH_QUICK").is_ok();
        if quick {
            BenchConfig { warmup_iters: 3, samples: 10, batch: 1 }
        } else {
            BenchConfig { warmup_iters: 20, samples: 40, batch: 5 }
        }
    }
}

/// A group of related benchmarks printed as one table.
pub struct BenchGroup {
    title: String,
    cfg: BenchConfig,
    results: Vec<BenchStats>,
}

impl BenchGroup {
    pub fn new(title: impl Into<String>) -> Self {
        BenchGroup {
            title: title.into(),
            cfg: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Benchmark `f`, which must return a value (fed to `black_box`).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: impl Into<String>, mut f: F) {
        let name = name.into();
        for _ in 0..self.cfg.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t0 = Instant::now();
            for _ in 0..self.cfg.batch {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / self.cfg.batch as f64;
            samples.push(dt);
        }
        self.results.push(BenchStats::from_samples(name, samples));
    }

    /// Print the group's table and return the stats for programmatic use.
    pub fn finish(self) -> Vec<BenchStats> {
        println!("\n== {} ==", self.title);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}",
            "benchmark", "mean", "p50", "p99", "throughput"
        );
        for r in &self.results {
            println!("{}", r.row());
        }
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut g = BenchGroup::new("test").with_config(BenchConfig {
            warmup_iters: 2,
            samples: 8,
            batch: 4,
        });
        g.bench("sum", || (0..1000u64).sum::<u64>());
        let r = g.finish();
        assert_eq!(r.len(), 1);
        let s = &r[0];
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns);
        assert!(s.mean_ns > 0.0);
        assert!(s.per_sec() > 0.0);
    }

    #[test]
    fn format_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
