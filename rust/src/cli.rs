//! Command-line interface (hand-rolled: `clap` is not fetchable offline).
//!
//! ```text
//! wattlaw tables [--all|--t1..--t10|--law|--power-fig|--dispatch-fig|--independence]
//!                [--lbar window|traffic]
//! wattlaw fleet --trace azure|lmsys|agent --gpu h100|h200|b200|gb200
//!               --topo homo|pool|fleetopt [--b-short N] [--gamma G]
//!               [--lambda R] [--lbar window|traffic] [--acct pergpu|pergroup]
//! wattlaw sweep --trace azure --gpu h100 [--pools K | --cutoffs a,b,c]
//!               [--model llama70b|qwen3-moe|llama70b+spec] [--dispatch-ms D]
//!                  FleetOpt (B_short, γ*) sweep; K-pool partition sweep
//! wattlaw optimize [--trace azure] [--gpu h100 | --gpu h100,h100,b200]
//!                  [--lambda R] [--duration S] [--workload ARCHETYPE]
//!                  [--groups N] [--b-short N] [--gamma G] [--dispatch NAME]
//!                  [--pools K] [--cutoffs a,b,c] [--hetero]
//!                  [--model llama70b,qwen3-moe,...] [--dispatch-ms D]
//!                  [--upgrade-budget N --upgrade-to b200]
//!                  [--top-k K] [--slo-ttft S] [--workers N]
//!                  [--step-mode fused|per-step]
//!                  two-stage search: analytical screen, simulated refine
//! wattlaw power [--gpu b200]                        P(b) curve
//! wattlaw simulate [--trace azure|file.csv] [--lambda R] [--duration S]
//!                  [--groups N] [--workload ARCHETYPE]
//!                  [--dispatch rr|jsq|least-kv|power|power-slo]
//!                  [--router context|adaptive|fleetopt] [--spill F]
//!                  [--pools K] [--cutoffs a,b,c]   K-pool routed fleet
//!                  [--model NAME] [--dispatch-ms D] model-architecture lever
//!                  [--step-mode fused|per-step]    macro-step escape hatch
//!                  [--workers N]   sharded streaming when N > 1
//! wattlaw simulate sweep [--lambda 1000] [--duration S] [--groups N]
//!                  [--workload ARCHETYPE] [--trace file.csv]
//!                  [--dispatch NAME] [--b-short N] [--spill F]
//!                  [--pools K] [--cutoffs a,b,c] [--step-mode MODE]
//!                  [--model a,b,c] [--dispatch-ms D] model grid axis
//!                  [--slo-ttft S] [--workers N]   scenario grid, threaded
//! wattlaw serve [--requests N] [--b-short N] [--artifacts DIR]
//! wattlaw validate [--artifacts DIR]                golden numerics check
//! wattlaw report                                    paper-vs-measured summary
//! ```
//!
//! `tables`, `sweep`, `optimize`, `simulate sweep` and `report` accept
//! `--format table|csv|json` (default `table`): every result surface
//! emits through the typed results layer ([`crate::results`]).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::fleet::analysis::fleet_tpw_analysis;
use crate::fleet::optimizer;
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::results::{self, OutputFormat};
use crate::workload::arrival::{ArrivalSpec, CsvSource};
use crate::workload::cdf::{
    agent_heavy, azure_conversations, lmsys_chat, WorkloadTrace,
};

/// Parsed command line: positional command (plus optional positional
/// subcommand, e.g. `simulate sweep`) and `--key value` / `--flag`
/// options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    /// First bare (non `--`) token after the command.
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: HashMap<String, String>,
}

/// Keys that are value-taking options; everything else with `--` is a flag.
const VALUE_KEYS: [&str; 27] = [
    "lbar", "trace", "gpu", "topo", "b-short", "gamma", "lambda", "acct",
    "requests", "artifacts", "duration", "groups", "dispatch", "router",
    "spill", "slo-ttft", "workers", "format", "top-k", "pools", "cutoffs",
    "upgrade-budget", "upgrade-to", "workload", "step-mode", "model",
    "dispatch-ms",
];

pub fn parse_args<I: Iterator<Item = String>>(mut argv: I) -> Args {
    let mut a = Args::default();
    a.command = argv.next().unwrap_or_default();
    let mut argv = argv.peekable();
    while let Some(arg) = argv.next() {
        if let Some(key) = arg.strip_prefix("--") {
            if VALUE_KEYS.contains(&key) {
                if let Some(v) = argv.next() {
                    a.options.insert(key.to_string(), v);
                }
            } else {
                a.flags.push(key.to_string());
            }
        } else if a.subcommand.is_none() {
            a.subcommand = Some(arg);
        }
    }
    a
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u32(&self, name: &str, default: u32) -> u32 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Worker-thread count shared by every parallel surface
    /// (`simulate`, `simulate sweep`, `optimize`): explicit `--workers`
    /// wins, then the `WATTLAW_WORKERS` environment variable, then the
    /// machine's available parallelism
    /// ([`resolve_workers`](crate::sim::par::resolve_workers)).
    pub fn workers(&self) -> usize {
        crate::sim::par::resolve_workers(
            self.opt("workers").and_then(|v| v.parse().ok()),
        )
    }

    pub fn lbar(&self) -> LBarPolicy {
        match self.opt("lbar") {
            Some("traffic") => LBarPolicy::TrafficMean,
            _ => LBarPolicy::Window,
        }
    }

    pub fn acct(&self) -> PowerAccounting {
        match self.opt("acct") {
            Some("pergroup") => PowerAccounting::PerGroup,
            _ => PowerAccounting::PerGpu,
        }
    }

    pub fn trace(&self) -> WorkloadTrace {
        match self.opt("trace") {
            Some("lmsys") => lmsys_chat(),
            Some("agent") => agent_heavy(),
            _ => azure_conversations(),
        }
    }

    /// The arrival process for the simulated surfaces: `--workload
    /// <archetype>` picks a generated process
    /// (stationary|diurnal|flash-crowd|multi-tenant|heavy-tail);
    /// `--trace <file.csv>` (recognized as a path — contains `/` or
    /// ends in `.csv`; bare names keep the legacy built-in-trace
    /// meaning) replays a recorded CSV trace. Replay files are fully
    /// validated here so a malformed file is a line-numbered CLI error
    /// up front, not a panic on a sweep worker thread.
    pub fn arrivals(&self) -> crate::Result<ArrivalSpec> {
        let replay = self
            .opt("trace")
            .filter(|v| v.ends_with(".csv") || v.contains('/'));
        if let Some(path) = replay {
            anyhow::ensure!(
                self.opt("workload").is_none(),
                "--workload and a --trace replay file are both arrival \
                 processes — pick one"
            );
            CsvSource::open(std::path::Path::new(path))?;
            return Ok(ArrivalSpec::Replay { path: path.to_string() });
        }
        match self.opt("workload") {
            None => Ok(ArrivalSpec::Stationary),
            Some(name) => ArrivalSpec::parse(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --workload '{name}' ({})",
                    ArrivalSpec::NAMES.join("|")
                )
            }),
        }
    }

    pub fn gpu(&self) -> Gpu {
        self.opt("gpu").and_then(Gpu::parse).unwrap_or(Gpu::H100)
    }

    /// `--step-mode fused|per-step` (default fused): the engine's
    /// macro-stepping escape hatch — `per-step` replays the
    /// one-event-per-decode-step oracle schedule, bit-identical and
    /// slower. Errors on unknown names.
    pub fn step_mode(&self) -> crate::Result<crate::sim::StepMode> {
        match self.opt("step-mode") {
            None | Some("fused") => Ok(crate::sim::StepMode::Fused),
            Some("per-step") => Ok(crate::sim::StepMode::PerStep),
            Some(s) => anyhow::bail!(
                "unknown --step-mode '{s}' (fused|per-step)"
            ),
        }
    }

    /// `--gpu` as a comma-separated generation list (`h100,h100,b200`):
    /// a single value keeps the legacy fleet-wide meaning, several
    /// values are a per-pool assignment (one generation per partition
    /// pool). Unlike [`Self::gpu`], unknown names are an error, not a
    /// silent H100 default. `None` when the flag is absent.
    pub fn gpus(&self) -> crate::Result<Option<Vec<Gpu>>> {
        match self.opt("gpu") {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|part| {
                    let part = part.trim();
                    Gpu::parse(part).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown GPU '{part}' (h100|h200|b200|gb200)"
                        )
                    })
                })
                .collect::<crate::Result<Vec<Gpu>>>()
                .map(Some),
        }
    }

    /// Single fleet-wide `--gpu` for commands without a per-pool axis
    /// (`fleet`, `power`): unknown names and comma lists are errors —
    /// unlike [`Self::gpu`]'s silent H100 default, a user who types the
    /// list syntax the partition commands teach must not get H100
    /// numbers labeled as their requested fleet.
    pub fn gpu_single(&self) -> crate::Result<Gpu> {
        match self.gpus()? {
            None => Ok(Gpu::H100),
            Some(v) if v.len() == 1 => Ok(v[0]),
            Some(_) => anyhow::bail!(
                "this command takes one fleet-wide --gpu (per-pool \
                 lists live on simulate/sweep/optimize)"
            ),
        }
    }

    /// `--model` as a comma-separated architecture list
    /// (`llama70b,qwen3-moe`): the model axis for the grid surfaces.
    /// `--dispatch-ms D` sets the MoE all-to-all overhead on every
    /// weight-streaming entry and is an error without one — the knob
    /// means nothing on a dense or speculative fleet. Defaults to the
    /// dense baseline.
    pub fn models(&self) -> crate::Result<Vec<ModelAxis>> {
        let dispatch_ms = match self.opt("dispatch-ms") {
            None => None,
            Some(s) => {
                let v: f64 = s.parse().map_err(|_| {
                    anyhow::anyhow!("bad --dispatch-ms '{s}'")
                })?;
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "--dispatch-ms must be finite and >= 0 (got {v})"
                );
                Some(v)
            }
        };
        let mut models = match self.opt("model") {
            None => vec![ModelAxis::Dense],
            Some(s) => s
                .split(',')
                .map(|part| {
                    ModelAxis::parse(part.trim())
                        .map_err(|e| anyhow::anyhow!(e))
                })
                .collect::<crate::Result<Vec<ModelAxis>>>()?,
        };
        if let Some(d) = dispatch_ms {
            anyhow::ensure!(
                models
                    .iter()
                    .any(|m| matches!(m, ModelAxis::MoeStreaming { .. })),
                "--dispatch-ms is the MoE all-to-all overhead — it needs \
                 --model qwen3-moe"
            );
            for m in &mut models {
                *m = m.with_dispatch_ms(d);
            }
        }
        Ok(models)
    }

    /// Single `--model` for surfaces without a model grid (`simulate`,
    /// `sweep`): a comma list is an error, not a silent first-entry.
    pub fn model_single(&self) -> crate::Result<ModelAxis> {
        let v = self.models()?;
        anyhow::ensure!(
            v.len() == 1,
            "this command takes one --model (the model grid lives on \
             optimize / simulate sweep)"
        );
        Ok(v[0])
    }

    pub fn artifacts(&self) -> PathBuf {
        self.opt("artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(crate::runtime::default_artifacts_dir)
    }

    /// The `--format` option (default `table`); errors on unknown names.
    pub fn format(&self) -> crate::Result<OutputFormat> {
        match self.opt("format") {
            None => Ok(OutputFormat::Table),
            Some(s) => OutputFormat::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown --format '{s}' (table|csv|json)")
            }),
        }
    }

    /// Strictly-validated `--gamma` (errors on junk or γ < 1, unlike
    /// the legacy `opt_f64` silent-default convention) — the K-pool
    /// surfaces share this one parse.
    pub fn gamma_strict(&self) -> crate::Result<Option<f64>> {
        match self.opt("gamma") {
            None => Ok(None),
            Some(g) => {
                let v: f64 = g
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --gamma '{g}'"))?;
                anyhow::ensure!(v >= 1.0, "--gamma must be >= 1 (got {v})");
                Ok(Some(v))
            }
        }
    }

    /// `--pools K` — the K-pool partition axis (K ∈ 2..=6; the wide end
    /// is served by the branch-and-bound heterogeneous screen).
    pub fn pools_k(&self) -> crate::Result<Option<u32>> {
        match self.opt("pools") {
            None => Ok(None),
            Some(s) => {
                let k: u32 = s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --pools '{s}'"))?;
                anyhow::ensure!(
                    (2..=6).contains(&k),
                    "--pools must be in 2..=6 (got {k})"
                );
                Ok(Some(k))
            }
        }
    }

    /// `--cutoffs a,b,c` — explicit interior partition cutoffs, tokens.
    /// The long pool at `LONG_CTX` is appended automatically.
    ///
    /// Strictly validated: unsorted or duplicate values are rejected
    /// with a clear error instead of silently re-sorted — a typo like
    /// `16384,2048` almost certainly meant something else, and silent
    /// normalization would also misalign a per-pool `--gpu a,b,c`
    /// assignment. Interior cutoffs must stay below the 64K long
    /// window (a value of `LONG_CTX` is only legal as the final entry,
    /// which strict ordering enforces by construction).
    pub fn cutoffs(&self) -> crate::Result<Option<Vec<u32>>> {
        match self.opt("cutoffs") {
            None => Ok(None),
            Some(s) => {
                let mut cuts: Vec<u32> = Vec::new();
                for part in s.split(',') {
                    let c: u32 = part.trim().parse().map_err(|_| {
                        anyhow::anyhow!("bad --cutoffs entry '{part}'")
                    })?;
                    anyhow::ensure!(
                        (1..=LONG_CTX).contains(&c),
                        "cutoff {c} outside 1..={LONG_CTX}"
                    );
                    if let Some(&prev) = cuts.last() {
                        anyhow::ensure!(
                            c != prev,
                            "duplicate cutoff {c} in --cutoffs '{s}'"
                        );
                        anyhow::ensure!(
                            c > prev,
                            "--cutoffs must be strictly increasing (got {prev} \
                             then {c} in '{s}'); unsorted cutoffs would \
                             silently invert traffic slices"
                        );
                    }
                    cuts.push(c);
                }
                anyhow::ensure!(!cuts.is_empty(), "--cutoffs needs values");
                if cuts.last() != Some(&LONG_CTX) {
                    cuts.push(LONG_CTX);
                }
                anyhow::ensure!(
                    cuts.len() >= 2,
                    "--cutoffs needs at least one interior cutoff below \
                     {LONG_CTX} (a bare {LONG_CTX} is the homogeneous \
                     baseline, not a partition)"
                );
                Ok(Some(cuts))
            }
        }
    }
}

/// Entry point for `main` — returns the process exit code.
pub fn run<I: Iterator<Item = String>>(argv: I) -> crate::Result<i32> {
    let args = parse_args(argv);
    match args.command.as_str() {
        "tables" => cmd_tables(&args),
        "fleet" => cmd_fleet(&args),
        "sweep" => cmd_sweep(&args),
        "optimize" => cmd_optimize(&args),
        "power" => cmd_power(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "validate" => cmd_validate(&args),
        "report" => {
            println!("{}", crate::report::rowset().emit(args.format()?));
            Ok(0)
        }
        "" | "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            Ok(2)
        }
    }
}

const HELP: &str = "\
wattlaw — The 1/W Law, reproduced (context-length routing & GPU generation \
gains for LLM inference energy efficiency)

commands:
  tables     regenerate paper tables/figures (--all, --t1..--t10, --law,
             --power-fig, --dispatch-fig, --independence; --lbar window|traffic)
  fleet      analyze one fleet configuration (--trace --gpu --topo ...)
  sweep      FleetOpt (B_short, γ*) closed-form sweep (legacy, stage A only);
             with --pools K or --cutoffs a,b,c: K-pool partition x γ sweep
             (--gpu a,b,c pins a per-pool GPU assignment; --model picks
              the architecture: llama70b|qwen3-moe|llama70b+spec, with
              --dispatch-ms D the MoE all-to-all overhead)
  optimize   two-stage FleetOpt search over scenario space: stage A screens
             the partition x gamma x GPU-assignment grid with the closed-form
             planner, stage B replays the top-k cells (x dispatch policies)
             through the event-driven simulator and re-ranks by measured
             tok/W with the SLO verdict as a hard filter
             (--gpu restricts the generation axis, --top-k, --slo-ttft;
              --workload / --trace file.csv picks stage B's arrival
              process (stage A screens on the mean rate);
              --pools K (2..=6) screens the generated K-pool cutoff grids,
              --cutoffs a,b,c one explicit partition vector;
              --gpu h100,h100,b200 screens that per-pool assignment,
              --hetero the mixed per-pool assignments over the --gpu set
              (2+ generations, e.g. --gpu h100,h200,b200), searched by
              Eq. 4 branch-and-bound so K up to 6 stays tractable,
              --upgrade-budget N --upgrade-to b200 the greedy budgeted
              placement of at most N upgraded groups;
              --model llama70b,qwen3-moe,llama70b+spec adds the model
              architecture as a fourth stage-A axis — topology x GPU x
              partition x model — with --dispatch-ms D on the MoE entries)
  power      print a GPU's P(b) curve (--gpu)
  simulate   event-driven fleet simulation vs analytics, arrivals
             streamed in O(1) trace memory
             (--dispatch rr|jsq|least-kv|power|power-slo,
              --router context|adaptive|fleetopt, --spill F;
              --pools K / --cutoffs a,b,c simulate a K-pool routed fleet,
              --gpu a,b,c one generation per pool; zero-traffic pools
              warn and bill idle power;
              --workload stationary|diurnal|flash-crowd|multi-tenant|
              heavy-tail picks the arrival process, --trace file.csv
              replays a recorded arrival trace;
              --model llama70b|qwen3-moe|llama70b+spec swaps the model
              architecture (both fleets), --dispatch-ms D the MoE
              all-to-all overhead; the analytical 8K tok/W headline is
              printed for cross-model comparison;
              --workers N > 1 (default: WATTLAW_WORKERS env, then all
              cores) shards arrival-static runs across per-group worker
              threads — bitwise the sequential result, --workers 1
              forces sequential)
  simulate sweep
             dispatch x topology x context-window scenario grid at fleet
             scale (default λ=1000), cells pulled off a shared work
             queue by --workers N threads (same default ladder), each
             cell streaming its own arrivals; every cell reports tok/W +
             p99 TTFT + SLO verdict with its workload column; --pools K
             adds one K'-pool partition cell per K' in 2..=K, --gpu
             a,b,c a heterogeneous cell per matching partition;
             --model a,b,c replicates the grid per architecture (Model
             column); --workload / --trace file.csv as in simulate
  serve      serve a trace through the real AOT model (2-pool demo)
  validate   check runtime numerics against the JAX golden trace
  report     paper-vs-measured summary (EXPERIMENTS.md §input)

output:
  tables / sweep / optimize / simulate sweep / report take
  --format table|csv|json (typed results layer; CSV is pure data for
  plotting, JSON carries the full schema with units)
";

fn cmd_tables(args: &Args) -> crate::Result<i32> {
    use crate::tables;
    let format = args.format()?;
    let lbar = args.lbar();
    let all = args.flag("all") || args.flags.is_empty();

    if format == OutputFormat::Table {
        // Human path: tables plus the figures' ASCII plots.
        let mut out = String::new();
        if all || args.flag("t1") {
            out.push_str(&tables::t1::generate());
        }
        if all || args.flag("t2") {
            out.push_str(&tables::t2::generate());
        }
        if all || args.flag("t3") {
            out.push_str(&tables::t3::generate(lbar));
        }
        if all || args.flag("t4") {
            out.push_str(&tables::t4::generate());
        }
        if all || args.flag("t5") {
            out.push_str(&tables::t5::generate());
        }
        if all || args.flag("t6") {
            out.push_str(&tables::t6::generate());
        }
        if all || args.flag("t7") {
            out.push_str(&tables::t7::generate());
        }
        if all || args.flag("t8") {
            out.push_str(&tables::t8::generate());
        }
        if all || args.flag("t9") {
            out.push_str(&tables::t9::generate());
        }
        if all || args.flag("t10") {
            out.push_str(&tables::t10::generate());
        }
        if all || args.flag("law") {
            out.push_str(&tables::law_fig::generate());
        }
        if all || args.flag("power-fig") {
            out.push_str(&tables::power_fig::generate());
        }
        if all || args.flag("dispatch-fig") {
            out.push_str(&tables::dispatch_fig::generate());
        }
        if all || args.flag("independence") {
            out.push_str(&tables::independence::generate(lbar));
        }
        println!("{out}");
    } else {
        // Machine path: the same artifacts through the typed rowsets.
        let mut sets = Vec::new();
        for flag in tables::ALL_FLAGS {
            if all || args.flag(flag) {
                sets.extend(
                    tables::rowsets_for(flag, lbar)
                        .expect("every ALL_FLAGS entry resolves"),
                );
            }
        }
        println!("{}", results::emit_all(&sets, format));
    }
    Ok(0)
}

fn cmd_fleet(args: &Args) -> crate::Result<i32> {
    let trace = args.trace();
    let gpu = args.gpu_single()?;
    let lambda = args.opt_f64("lambda", 1000.0);
    let b_short = args.opt_u32("b-short", trace.paper_b_short);
    let gamma = args.opt_f64("gamma", 2.0);
    let topo = match args.opt("topo") {
        Some("homo") | None => Topology::Homogeneous { ctx: LONG_CTX },
        Some("pool") => Topology::PoolRouting { b_short, short_ctx: b_short.max(2048) },
        Some("fleetopt") => Topology::FleetOpt {
            b_short,
            short_ctx: b_short.max(2048),
            gamma,
        },
        Some(other) => anyhow::bail!("unknown topology '{other}'"),
    };
    let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
    let pools = topo.pools(&trace, lambda, profile, None, args.lbar(), 0.85, 0.5);
    let report = fleet_tpw_analysis(&pools, args.acct());

    println!(
        "\n== fleet: {} | {} | {} | λ={lambda} req/s ==",
        trace.name,
        topo.label(),
        gpu.spec().name
    );
    println!(
        "{:<16} {:>8} {:>8} {:>9} {:>10} {:>10} {:>9} {:>10}",
        "pool", "ctx", "groups", "n_act", "tok/s", "kW", "tok/W", "p99 TTFT"
    );
    for p in &report.pools {
        println!(
            "{:<16} {:>8} {:>8} {:>9.1} {:>10.0} {:>10.2} {:>9.2} {:>9.3}s",
            p.name,
            p.context_tokens,
            p.sizing.groups,
            p.sizing.n_active,
            p.sizing.pool_tok_s,
            p.power.kw(),
            p.tok_per_watt.0,
            p.sizing.p99_ttft_s,
        );
    }
    println!(
        "total: {} groups / {} GPUs, {:.1} kW, fleet tok/W = {:.2} ({:?})",
        report.total_groups,
        report.total_gpus,
        report.total_power.kw(),
        report.tok_per_watt.0,
        report.accounting,
    );
    Ok(0)
}

fn cmd_sweep(args: &Args) -> crate::Result<i32> {
    use crate::results::{Cell, Column, RowSet};
    use crate::scenario::optimize as scenario_optimize;
    // Validate the output format before doing any work.
    let format = args.format()?;
    let trace = args.trace();
    let gpus = args.gpus()?.unwrap_or_else(|| vec![Gpu::H100]);
    let model = args.model_single()?;
    let profile: Arc<dyn GpuProfile> = Arc::new(model.profile_for(gpus[0]));

    // K-pool mode: rank partition vectors × γ with the same closed-form
    // screen (`--pools K` for the generated grids, `--cutoffs` for one
    // explicit vector). `--gpu a,b,c` pins a per-pool GPU assignment,
    // ranked against the matching partitions only.
    let partitions = match (args.cutoffs()?, args.pools_k()?) {
        (Some(cuts), _) => Some(vec![cuts]),
        (None, Some(k)) => {
            Some((2..=k).flat_map(scenario_optimize::kpool_partitions).collect())
        }
        (None, None) => None,
    };
    if let Some(partitions) = partitions {
        let partitions: Vec<Vec<u32>> = partitions;
        let gammas: Vec<f64> = match args.gamma_strict()? {
            Some(gamma) => vec![gamma],
            None => optimizer::GAMMA_GRID.to_vec(),
        };
        let lambda = args.opt_f64("lambda", 1000.0);
        let ranked = if gpus.len() > 1 {
            let cells: Vec<(Vec<u32>, Vec<Gpu>)> = partitions
                .iter()
                .filter(|c| c.len() == gpus.len())
                .map(|c| (c.clone(), gpus.clone()))
                .collect();
            anyhow::ensure!(
                !cells.is_empty(),
                "--gpu lists {} generations but no screened partition has \
                 {} pools (match --cutoffs/--pools to the assignment)",
                gpus.len(),
                gpus.len()
            );
            scenario_optimize::screen_assignments(
                &trace, lambda, &cells, &gammas, args.lbar(), 0.85, 0.5,
                args.acct(), model,
            )
        } else {
            scenario_optimize::screen_partitions(
                &trace, lambda, profile, &partitions, &gammas, args.lbar(),
                0.85, 0.5, args.acct(), model,
            )
        };
        let fleet_label = scenario_optimize::assignment_label(&gpus);
        let mut rs = RowSet::new(
            format!(
                "K-pool partition closed-form sweep — {} on {} ({})",
                trace.name,
                fleet_label,
                model.label()
            ),
            vec![
                Column::int("pools"),
                Column::str("cutoffs").with_unit("tok"),
                Column::str("GPUs"),
                Column::float("gamma"),
                Column::float("tok/W").with_unit("tok/J"),
                Column::int("groups"),
            ],
        );
        for r in &ranked {
            let row_gpus = if r.gpus.is_empty() {
                fleet_label.clone()
            } else {
                scenario_optimize::assignment_label(&r.gpus)
            };
            rs.push(vec![
                Cell::int(r.cutoffs.len() as i64),
                Cell::str(scenario_optimize::cutoffs_label(&r.cutoffs)),
                Cell::str(row_gpus),
                Cell::float(r.gamma),
                Cell::float(r.report.tok_per_watt.0)
                    .shown(format!("{:.2}", r.report.tok_per_watt.0)),
                Cell::int(r.report.total_groups as i64),
            ]);
        }
        let best = &ranked[0];
        rs.note(format!(
            "best partition: K={} at cutoffs {:?}, γ={}",
            best.cutoffs.len(),
            best.cutoffs,
            best.gamma
        ));
        rs.note(
            "closed-form only (stage A); `wattlaw optimize --pools K` \
             additionally validates survivors against the event-driven \
             simulator and the SLO",
        );
        println!("{}", rs.emit(format));
        return Ok(0);
    }
    anyhow::ensure!(
        gpus.len() == 1,
        "per-pool --gpu a,b,c needs --pools or --cutoffs (the legacy \
         FleetOpt sweep takes one fleet-wide GPU)"
    );

    let ranked = optimizer::sweep_fleetopt(
        &trace,
        args.opt_f64("lambda", 1000.0),
        profile,
        args.lbar(),
        0.85,
        0.5,
        args.acct(),
    );
    let mut rs = RowSet::new(
        format!(
            "FleetOpt (B_short, γ*) closed-form sweep — {} on {} ({})",
            trace.name,
            gpus[0].spec().name,
            model.label()
        ),
        vec![
            Column::int("B_short").with_unit("tok"),
            Column::float("gamma"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::int("groups"),
        ],
    );
    for r in &ranked {
        rs.push(vec![
            Cell::int(r.b_short as i64),
            Cell::float(r.gamma),
            Cell::float(r.report.tok_per_watt.0)
                .shown(format!("{:.2}", r.report.tok_per_watt.0)),
            Cell::int(r.report.total_groups as i64),
        ]);
    }
    let best = &ranked[0];
    rs.note(format!("γ* = {} at B_short = {}", best.gamma, best.b_short));
    rs.note(
        "closed-form only (legacy stage A); `wattlaw optimize` additionally \
         validates the winner against the event-driven simulator and the SLO",
    );
    println!("{}", rs.emit(format));
    Ok(0)
}

/// `optimize` — the scenario-native two-stage FleetOpt search: stage A
/// screens the B_short × γ × GPU-generation grid with the closed-form
/// planner, stage B replays the analytical top-k (expanded across the
/// dispatch axis) through the event-driven simulator on worker threads
/// and re-ranks by measured tok/W under the SLO hard filter.
fn cmd_optimize(args: &Args) -> crate::Result<i32> {
    use crate::scenario::optimize::{
        self, GpuAxis, OptimizeConfig, UpgradeBudget,
    };
    use crate::scenario::SloTargets;
    use crate::sim::dispatch;
    use crate::workload::synth::GenConfig;

    // Validate the output format before the (expensive) search runs.
    let format = args.format()?;
    let trace = args.trace();
    let defaults = OptimizeConfig::default();

    // The GPU axis: a single `--gpu` restricts the homogeneous
    // generation sweep (legacy); a per-pool list (`--gpu h100,h100,b200`)
    // screens that explicit assignment next to each listed generation's
    // homogeneous cells; `--hetero` searches the mixed assignments over
    // the `--gpu` set (default h100,b200) by Eq. 4 branch-and-bound;
    // `--upgrade-budget N --upgrade-to b200` runs the greedy budgeted
    // placement instead.
    let gpu_list = args.gpus()?;
    let upgrade_budget = match args.opt("upgrade-budget") {
        None => None,
        Some(s) => {
            let n: u32 = s.parse().map_err(|_| {
                anyhow::anyhow!("bad --upgrade-budget '{s}'")
            })?;
            anyhow::ensure!(n > 0, "--upgrade-budget must be > 0 groups");
            Some(n)
        }
    };
    let upgrade_to = match args.opt("upgrade-to") {
        None => Gpu::B200,
        Some(g) => Gpu::parse(g).ok_or_else(|| {
            anyhow::anyhow!("unknown --upgrade-to '{g}' (h100|h200|b200|gb200)")
        })?,
    };
    anyhow::ensure!(
        args.opt("upgrade-to").is_none() || upgrade_budget.is_some(),
        "--upgrade-to needs --upgrade-budget N (the group budget)"
    );
    let distinct = |v: &[Gpu]| {
        let mut d: Vec<Gpu> = Vec::new();
        for g in v {
            if !d.contains(g) {
                d.push(*g);
            }
        }
        d
    };
    anyhow::ensure!(
        !(args.flag("hetero") && upgrade_budget.is_some()),
        "--hetero and --upgrade-budget are different searches over the \
         same axis (full cross-product vs greedy placement) — pick one"
    );
    let (gpus, gpu_axis) = if let Some(max_groups) = upgrade_budget {
        let base = match &gpu_list {
            None => Gpu::H100,
            Some(v) if v.len() == 1 => v[0],
            Some(_) => anyhow::bail!(
                "--upgrade-budget takes one base --gpu (the fleet floor), \
                 not a per-pool list — the search decides the placement"
            ),
        };
        anyhow::ensure!(
            base != upgrade_to,
            "--upgrade-to {} equals the base fleet GPU — nothing to upgrade",
            upgrade_to.short_name()
        );
        (
            vec![base],
            GpuAxis::Budget(UpgradeBudget { to: upgrade_to, max_groups }),
        )
    } else if args.flag("hetero") {
        let set = distinct(
            &gpu_list.clone().unwrap_or_else(|| vec![Gpu::H100, Gpu::B200]),
        );
        anyhow::ensure!(
            set.len() >= 2,
            "--hetero needs at least two distinct generations in --gpu"
        );
        // The assignment space is |gpus|^K per partition; stage A
        // searches it by branch-and-bound with the admissible Eq. 4
        // bound, so K up to the --pools ceiling (6) and 3+ generation
        // sets all screen without enumerating the cross-product.
        (set, GpuAxis::Mixed)
    } else {
        match gpu_list {
            None => (defaults.gpus.clone(), GpuAxis::Homogeneous),
            Some(v) if distinct(&v).len() == 1 => {
                // A single generation (or an all-same list): the legacy
                // homogeneous restriction.
                (vec![v[0]], GpuAxis::Homogeneous)
            }
            Some(v) => {
                let set = distinct(&v);
                (set, GpuAxis::Explicit(vec![v]))
            }
        }
    };
    let b_shorts = match args.opt("b-short") {
        Some(b) => {
            let v = b
                .parse::<u32>()
                .map_err(|_| anyhow::anyhow!("bad --b-short '{b}'"))?;
            // The boundary becomes the [b, LONG_CTX] partition vector;
            // b = LONG_CTX would collapse it to a single pool.
            anyhow::ensure!(
                (1..LONG_CTX).contains(&v),
                "--b-short must be in 1..{LONG_CTX} (got {v})"
            );
            vec![v]
        }
        None => defaults.b_shorts.clone(),
    };
    let gammas = match args.gamma_strict()? {
        Some(gamma) => vec![gamma],
        None => defaults.gammas.clone(),
    };
    let dispatches = match args.opt("dispatch") {
        Some(d) => {
            anyhow::ensure!(
                dispatch::parse(d).is_some(),
                "unknown dispatch policy '{d}' (rr|jsq|least-kv|power|power-slo)"
            );
            vec![d.to_string()]
        }
        None => defaults.dispatches.clone(),
    };
    // The K-pool partition axis: an explicit --cutoffs vector, or the
    // full generated grids for every K' in 2..=K with --pools K; left
    // empty (the legacy [B_short, 64K] axis) otherwise.
    let partitions = match (args.cutoffs()?, args.pools_k()?) {
        (Some(cuts), _) => vec![cuts],
        (None, Some(k)) => {
            (2..=k).flat_map(optimize::kpool_partitions).collect()
        }
        (None, None) => Vec::new(),
    };

    // An explicit per-pool assignment must fit at least one screened
    // partition, or stage A would silently screen homogeneous cells
    // only.
    if let GpuAxis::Explicit(vectors) = &gpu_axis {
        let lens: Vec<usize> = if partitions.is_empty() {
            vec![2] // legacy [B_short, LONG_CTX] two-pool axis
        } else {
            partitions.iter().map(Vec::len).collect()
        };
        for v in vectors {
            anyhow::ensure!(
                lens.contains(&v.len()),
                "--gpu lists {} generations but no screened partition has \
                 {} pools (use --pools/--cutoffs to match the assignment)",
                v.len(),
                v.len()
            );
        }
    }

    // Stage B needs at least one simulated group per pool of the widest
    // partition (sim_pools asserts it; erroring here beats a panic on a
    // worker thread after stage A ran).
    let max_k = partitions.iter().map(Vec::len).max().unwrap_or(2) as u32;
    let cfg = OptimizeConfig {
        gpus,
        models: args.models()?,
        b_shorts,
        partitions,
        gpu_axis,
        gammas,
        dispatches,
        gen: GenConfig {
            lambda_rps: args.opt_f64("lambda", 1000.0),
            duration_s: args.opt_f64("duration", 1.0),
            seed: 42,
            ..defaults.gen.clone()
        },
        arrivals: args.arrivals()?,
        groups: args.opt_u32("groups", 8).max(2).max(max_k),
        slo: SloTargets { ttft_p99_s: args.opt_f64("slo-ttft", 0.5) },
        lbar: args.lbar(),
        acct: args.acct(),
        top_k: args.opt_u32("top-k", 4).max(1) as usize,
        step_mode: args.step_mode()?,
        ..defaults
    };

    let workers = args.workers();
    let n_partitions = cfg.effective_partitions().len();
    // The homogeneous axis is an exact count; the heterogeneous modes
    // add assignment cells on top (the budget path's length depends on
    // the marginal gains it finds, so it cannot be pre-counted).
    let hetero_note = match &cfg.gpu_axis {
        optimize::GpuAxis::Homogeneous => String::new(),
        optimize::GpuAxis::Mixed => {
            " + the branch-and-bound mixed GPU-assignment screen".into()
        }
        optimize::GpuAxis::Explicit(v) => format!(
            " + {} explicit GPU assignment{}",
            v.len(),
            if v.len() == 1 { "" } else { "s" }
        ),
        optimize::GpuAxis::Budget(_) => {
            " + the budgeted-upgrade path".into()
        }
    };
    eprintln!(
        "optimize: screening {} analytical cells ({} GPUs x {} partition \
         vectors x {} gamma x {} model{}){hetero_note}, refining top {} x \
         {} dispatch on {} worker threads…",
        cfg.gpus.len()
            * n_partitions
            * cfg.gammas.len()
            * cfg.models.len(),
        cfg.gpus.len(),
        n_partitions,
        cfg.gammas.len(),
        cfg.models.len(),
        if cfg.models.len() == 1 { "" } else { "s" },
        cfg.top_k,
        cfg.dispatches.len(),
        workers,
    );
    let report = optimize::optimize(&trace, &cfg, workers);
    println!("{}", report.rowset().emit(format));
    Ok(0)
}

fn cmd_power(args: &Args) -> crate::Result<i32> {
    let spec = args.gpu_single()?.spec();
    println!("\n== {} P(b) | {} quality ==", spec.name, spec.quality.label());
    for e in 0..=10 {
        let b = (1u64 << e) as f64;
        println!("b={b:>6}  P={:>6.1} W", spec.power.power_w(b));
    }
    Ok(0)
}

fn cmd_simulate(args: &Args) -> crate::Result<i32> {
    use crate::router::adaptive::AdaptiveRouter;
    use crate::router::context::ContextRouter;
    use crate::router::fleetopt::FleetOptRouter;
    use crate::router::{HomogeneousRouter, Router};
    use crate::sim::{
        dispatch, simulate_topology_source, EngineOptions, RoundRobin,
    };
    use crate::workload::synth::GenConfig;

    match args.subcommand.as_deref() {
        Some("sweep") => return cmd_simulate_sweep(args),
        Some(other) => {
            anyhow::bail!("unknown simulate subcommand '{other}' (sweep)")
        }
        None => {}
    }

    let trace = args.trace();
    let lambda = args.opt_f64("lambda", 60.0);
    let duration = args.opt_f64("duration", 5.0);
    let b_short = args.opt_u32("b-short", trace.paper_b_short);
    let gamma = args.opt_f64("gamma", 2.0);

    // K-pool mode: `--cutoffs a,b,c` (explicit) or `--pools K` (default
    // powers-of-four ladder) swap the two-pool routed side for a K-pool
    // partition with its bucket router.
    let partition = match (args.cutoffs()?, args.pools_k()?) {
        (Some(cuts), _) => Some(cuts),
        (None, Some(k)) => Some(crate::fleet::topology::default_partition(k)),
        (None, None) => None,
    };
    // `--gpu a,b,c` (several values) assigns one generation per
    // partition pool; a single value keeps the fleet-wide meaning. The
    // homogeneous comparison baseline always runs the first generation.
    let gpus = args.gpus()?.unwrap_or_else(|| vec![Gpu::H100]);
    let model = args.model_single()?;
    let routed_topo = match &partition {
        // γ applies to the partition's last pool only when given
        // explicitly (plain bucket routing by default).
        Some(cuts) => {
            let gamma = args.gamma_strict()?.unwrap_or(1.0);
            if gpus.len() > 1 {
                anyhow::ensure!(
                    gpus.len() == cuts.len(),
                    "--gpu lists {} generations for {} pools (cutoffs \
                     {cuts:?}) — give one per pool",
                    gpus.len(),
                    cuts.len()
                );
                Topology::partition_with_gpus(cuts, &gpus, gamma)
            } else {
                Topology::partition_with_gamma(cuts, gamma)
            }
        }
        None => {
            anyhow::ensure!(
                gpus.len() == 1,
                "per-pool --gpu a,b,c needs --pools or --cutoffs (the \
                 two-pool default takes one fleet-wide GPU)"
            );
            Topology::PoolRouting { b_short, short_ctx: b_short.max(2048) }
        }
    };
    // The routed side of the comparison needs one group per pool.
    let groups =
        args.opt_u32("groups", 4).max(routed_topo.num_pools() as u32).max(2);

    let dispatch_name = args.opt("dispatch").unwrap_or("rr");
    let mut policy = dispatch::parse(dispatch_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown dispatch policy '{dispatch_name}' (rr|jsq|least-kv|power|power-slo)"
        )
    })?;
    let spill = args.opt_f64("spill", 2.0);
    anyhow::ensure!(spill > 0.0, "--spill must be positive (got {spill})");
    let router: Box<dyn Router> = match (&partition, args.opt("router")) {
        (Some(_), None) => routed_topo.router(),
        (Some(_), Some(_)) => anyhow::bail!(
            "--pools/--cutoffs route through the topology's K-pool bucket \
             router; drop --router"
        ),
        (None, None) | (None, Some("context")) => {
            Box::new(ContextRouter::two_pool(b_short))
        }
        (None, Some("adaptive")) => {
            Box::new(AdaptiveRouter::new(b_short).with_spill_factor(spill))
        }
        (None, Some("fleetopt")) => Box::new(FleetOptRouter::new(b_short, gamma)),
        (None, Some(other)) => {
            anyhow::bail!("unknown router '{other}' (context|adaptive|fleetopt)")
        }
    };

    let gen_cfg = GenConfig {
        lambda_rps: lambda,
        duration_s: duration,
        max_prompt_tokens: 60_000,
        max_output_tokens: 1024,
        seed: 42,
    };
    // Arrival process: stationary Poisson unless --workload picks an
    // archetype or --trace names a CSV replay file. One fresh source
    // per engine run — both fleets see the identical arrival stream
    // (same seed / same file), pulled one request at a time, so even a
    // million-arrival run holds no trace buffer.
    let arrivals = args.arrivals()?;
    let workload_label = match &arrivals {
        ArrivalSpec::Stationary => trace.name.to_string(),
        spec @ (ArrivalSpec::MultiTenant | ArrivalSpec::Replay { .. }) => {
            spec.label()
        }
        spec => format!("{}+{}", trace.name, spec.label()),
    };
    let traffic = match &arrivals {
        ArrivalSpec::Replay { path } => {
            let src = CsvSource::open(std::path::Path::new(path))?;
            format!(
                "{} recorded arrivals over {:.1}s (mean λ={:.1} req/s)",
                src.rows(),
                src.span_s(),
                src.mean_rate_rps()
            )
        }
        _ => format!("λ={lambda} req/s × {duration}s"),
    };

    let p = model.profile_for(gpus[0]);
    // `--workers 1` forces the sequential engine; more than one worker
    // lets arrival-static scenarios take the sharded streaming fast
    // path (one demux thread routing to per-group workers — bitwise the
    // sequential result, see `sim::events`). Load-aware routing or
    // dispatch stays sequential either way.
    let workers = args.workers();
    let opts = EngineOptions {
        allow_parallel: workers > 1,
        step_mode: args.step_mode()?,
        ..Default::default()
    };
    let (homo_groups, homo_cfgs) = Topology::Homogeneous { ctx: LONG_CTX }
        .sim_pools_with_model(&p, groups, 1024, model);
    let mut rr = RoundRobin::new();
    let homo = simulate_topology_source(
        arrivals.source(&trace, &gen_cfg)?.as_mut(),
        &HomogeneousRouter,
        &homo_groups,
        &homo_cfgs,
        &mut rr,
        opts,
    );

    let (routed_groups, routed_cfgs) =
        routed_topo.sim_pools_with_model(&p, groups, 1024, model);
    let routed = simulate_topology_source(
        arrivals.source(&trace, &gen_cfg)?.as_mut(),
        router.as_ref(),
        &routed_groups,
        &routed_cfgs,
        policy.as_mut(),
        opts,
    );

    println!(
        "\n== simulate: {workload_label} | {traffic} | {} groups of {} \
         | model {} | router {} | dispatch {} ==",
        groups,
        p.gpu.name,
        model.label(),
        router.name(),
        policy.name(),
    );
    let routed_label = format!("routed {}", routed_topo.label());
    for (name, r) in
        [("homogeneous 64K", &homo), (routed_label.as_str(), &routed)]
    {
        println!(
            "{name:<18} tok/W={:<7.3} tokens={:<8} J={:<10.0} pools={}",
            r.tok_per_watt_accounted(),
            r.output_tokens,
            r.accounted_joules(),
            r.pools.len()
        );
        for pl in &r.pools {
            let mut m = pl.metrics.clone();
            println!(
                "    {:<8} groups={} window={:<6} done={:<6} mean_b={:<6.2} \
                 tok/W={:<7.3} p99TTFT={:.3}s",
                pl.name,
                pl.groups,
                pl.window_tokens,
                pl.metrics.completed,
                pl.mean_batch,
                pl.tok_per_watt,
                m.ttft_s.p99()
            );
        }
        // A router whose cutoffs exclude a pool must say so out loud:
        // its idle groups are billed in the accounted tok/W above.
        for w in &r.warnings {
            println!("    warning: {w}");
        }
    }
    println!(
        "topology gain (simulated): {:.2}x",
        routed.tok_per_watt_accounted() / homo.tok_per_watt_accounted()
    );
    // The model lever's analytical headline, comparable across `--model`
    // runs at the paper's 8K anchor (Eq. 2 operating point, ρ=0.85).
    let op = crate::tokeconomy::operating_point(&p, 8192, 0.85, args.acct());
    println!(
        "analytical {} @ 8K: {:.2} tok/W ({})",
        model.label(),
        op.tok_per_watt.0,
        p.name,
    );
    Ok(0)
}

/// `simulate sweep` — a dispatch × topology × context-window scenario
/// grid at fleet scale (λ defaults to the paper's 1000 req/s), every
/// cell built from one [`ScenarioSpec`](crate::scenario::ScenarioSpec)
/// and run across worker threads.
fn cmd_simulate_sweep(args: &Args) -> crate::Result<i32> {
    use crate::scenario::sweep::{self, SweepConfig};
    use crate::scenario::SloTargets;
    use crate::sim::dispatch;
    use crate::workload::synth::GenConfig;

    // Validate the output format before the grid runs.
    let format = args.format()?;
    let trace = args.trace();
    let defaults = SweepConfig::default();

    let dispatches = match args.opt("dispatch") {
        Some(d) => {
            anyhow::ensure!(
                dispatch::parse(d).is_some(),
                "unknown dispatch policy '{d}' (rr|jsq|least-kv|power|power-slo)"
            );
            vec![d.to_string()]
        }
        None => defaults.dispatches,
    };
    let b_shorts = match args.opt("b-short") {
        Some(b) => vec![b
            .parse::<u32>()
            .map_err(|_| anyhow::anyhow!("bad --b-short '{b}'"))?],
        None => defaults.b_shorts,
    };
    let spill = args.opt_f64("spill", 2.0);
    anyhow::ensure!(spill > 0.0, "--spill must be positive (got {spill})");
    // K as a grid dimension: one default-ladder partition cell per K'
    // in 2..=K (`--pools K`), or a single explicit `--cutoffs` vector.
    let partitions = match (args.cutoffs()?, args.pools_k()?) {
        (Some(cuts), _) => vec![cuts],
        (None, Some(k)) => (2..=k)
            .map(crate::fleet::topology::default_partition)
            .collect(),
        (None, None) => Vec::new(),
    };
    let max_k = partitions.iter().map(Vec::len).max().unwrap_or(2) as u32;

    // `--gpu a,b,c` adds one heterogeneous cell per matching K-pool
    // partition (the single-value form keeps the legacy fleet-wide
    // meaning for every cell of the grid).
    let gpus = match args.gpus()? {
        Some(v) => v,
        None => vec![Gpu::H100],
    };
    let gpu_assignments = if gpus.len() > 1 {
        anyhow::ensure!(
            partitions.iter().any(|c| c.len() == gpus.len()),
            "--gpu lists {} generations but no grid partition has {} pools \
             (add --pools/--cutoffs to match the assignment)",
            gpus.len(),
            gpus.len()
        );
        vec![gpus.clone()]
    } else {
        Vec::new()
    };

    let cfg = SweepConfig {
        gpu: gpus[0],
        gen: GenConfig {
            lambda_rps: args.opt_f64("lambda", 1000.0),
            duration_s: args.opt_f64("duration", 1.0),
            seed: 42,
            ..defaults.gen
        },
        arrivals: args.arrivals()?,
        groups: args.opt_u32("groups", 8).max(2).max(max_k),
        dispatches,
        b_shorts,
        partitions,
        gpu_assignments,
        models: args.models()?,
        spill: Some(spill),
        slo: SloTargets { ttft_p99_s: args.opt_f64("slo-ttft", 0.5) },
        acct: args.acct(),
        step_mode: args.step_mode()?,
    };

    let specs = sweep::grid(&trace, &cfg);
    // Reject impossible cells (e.g. an adaptive router with no split
    // boundary) with a CLI error before any worker thread runs.
    for s in &specs {
        s.validate().map_err(|e| anyhow::anyhow!(e))?;
    }
    let workers = args.workers();
    eprintln!(
        "sweep: {} cells ({} topologies x {} dispatch) on {} worker threads…",
        specs.len(),
        specs.len() / cfg.dispatches.len().max(1),
        cfg.dispatches.len(),
        workers.min(specs.len().max(1)),
    );
    let outcomes = sweep::run(&specs, workers);
    let records = sweep::records(&specs, &outcomes, cfg.acct);
    println!("{}", sweep::rowset(&records, &cfg).emit(format));
    Ok(0)
}

fn cmd_serve(args: &Args) -> crate::Result<i32> {
    use crate::router::context::ContextRouter;
    use crate::serve::{render_report, serve_trace, EngineConfig, PoolSpec};

    let n_requests = args.opt_u32("requests", 24) as usize;
    let b_short = args.opt_u32("b-short", 128);
    let artifacts = args.artifacts();

    // Deterministic demo mix: 75 % short prompts (16-96 tokens), 25 %
    // long (224-376) — the short-dominant archetype at tiny-model scale.
    let mut reqs: Vec<crate::workload::Request> = Vec::new();
    let mut rng = crate::xrand::Rng::new(7);
    for id in 0..n_requests as u64 {
        let prompt_tokens = if id % 4 == 3 {
            rng.range_u64(224, 376) as u32
        } else {
            rng.range_u64(16, 96) as u32
        };
        reqs.push(crate::workload::Request {
            id,
            arrival_s: 0.0,
            prompt_tokens,
            output_tokens: rng.range_u64(8, 32) as u32,
        });
    }

    let router = ContextRouter::two_pool(b_short);
    // Each pool's energy clock emulates the paper's calibrated H100/70B
    // group at the pool's emulated window (short = 4K, long = 64K); the
    // CPU executes the real compiled model. Shared virtual KV budget of
    // 16 blocks (1024 tokens): the short pool fits 8 concurrent
    // sequences, the long pool ~2 — Eq. 3 live.
    let pools = vec![
        PoolSpec {
            name: "short".into(),
            config: EngineConfig::for_window(b_short, 16)
                .with_ingest_slots(8)
                .emulating_h100(4096),
        },
        PoolSpec {
            name: "long".into(),
            config: EngineConfig::for_window(480, 16)
                .with_ingest_slots(8)
                .emulating_h100(65_536),
        },
    ];
    let report = serve_trace(&artifacts, &router, &pools, &reqs)?;
    println!("{}", render_report(&report));
    Ok(0)
}

fn cmd_validate(args: &Args) -> crate::Result<i32> {
    use crate::runtime::TinyModel;
    let model = TinyModel::load(&args.artifacts())?;
    let err = model.validate_golden()?;
    println!(
        "golden validation: max |err| = {err:.3e} over prefill + 2 decode steps"
    );
    if err < 1e-3 {
        println!("runtime numerics OK");
        Ok(0)
    } else {
        eprintln!("numerics drift beyond 1e-3!");
        Ok(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        parse_args(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_flags_and_options() {
        let a = args("tables --t1 --lbar traffic --independence");
        assert_eq!(a.command, "tables");
        assert!(a.flag("t1") && a.flag("independence"));
        assert_eq!(a.opt("lbar"), Some("traffic"));
        assert_eq!(a.lbar(), LBarPolicy::TrafficMean);
    }

    #[test]
    fn defaults() {
        let a = args("fleet");
        assert_eq!(a.gpu(), Gpu::H100);
        assert_eq!(a.trace().name, "Azure");
        assert_eq!(a.opt_f64("lambda", 1000.0), 1000.0);
        assert_eq!(a.acct(), PowerAccounting::PerGpu);
    }

    #[test]
    fn gpu_and_trace_selection() {
        let a = args("fleet --gpu b200 --trace lmsys --lambda 250");
        assert_eq!(a.gpu(), Gpu::B200);
        assert_eq!(a.trace().name, "LMSYS");
        assert_eq!(a.opt_f64("lambda", 0.0), 250.0);
    }

    #[test]
    fn run_dispatches_analytic_commands() {
        assert_eq!(run(["power", "--gpu", "h100"].iter().map(|s| s.to_string())).unwrap(), 0);
        assert_eq!(run(["help"].iter().map(|s| s.to_string())).unwrap(), 0);
        assert_eq!(run(["bogus"].iter().map(|s| s.to_string())).unwrap(), 2);
    }

    #[test]
    fn simulate_accepts_dispatch_and_router_flags() {
        let a = args("simulate --dispatch jsq --router adaptive --lambda 30");
        assert_eq!(a.opt("dispatch"), Some("jsq"));
        assert_eq!(a.opt("router"), Some("adaptive"));
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 --groups 2 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        assert_eq!(quick("--dispatch jsq --router adaptive").unwrap(), 0);
        assert_eq!(quick("--dispatch power --router fleetopt").unwrap(), 0);
        assert_eq!(quick("--router adaptive --spill 3.5").unwrap(), 0);
        assert!(quick("--dispatch bogus").is_err());
        assert!(quick("--router bogus").is_err());
        assert!(quick("--router adaptive --spill -1").is_err());
    }

    #[test]
    fn model_axis_options_parse_and_validate() {
        // Default is the dense baseline — the pre-axis behavior.
        assert_eq!(args("simulate").models().unwrap(), vec![ModelAxis::Dense]);
        assert_eq!(args("simulate").model_single().unwrap(), ModelAxis::Dense);
        // Names and aliases.
        assert_eq!(
            args("simulate --model qwen3-moe").model_single().unwrap(),
            ModelAxis::MoeStreaming { dispatch_ms: 0.0 }
        );
        assert_eq!(
            args("simulate --model llama70b+spec").model_single().unwrap(),
            ModelAxis::Speculative {
                k: ModelAxis::SPEC_K,
                alpha: ModelAxis::SPEC_ALPHA,
            }
        );
        // Comma list is a grid axis; single-model surfaces reject it.
        assert_eq!(
            args("optimize --model llama70b,qwen3-moe").models().unwrap(),
            vec![
                ModelAxis::Dense,
                ModelAxis::MoeStreaming { dispatch_ms: 0.0 },
            ]
        );
        assert!(args("simulate --model llama70b,qwen3-moe")
            .model_single()
            .is_err());
        // --dispatch-ms binds to the MoE entries and needs one.
        assert_eq!(
            args("simulate --model qwen3-moe --dispatch-ms 10")
                .model_single()
                .unwrap(),
            ModelAxis::MoeStreaming { dispatch_ms: 10.0 }
        );
        let err = args("simulate --dispatch-ms 10")
            .models()
            .unwrap_err()
            .to_string();
        assert!(err.contains("--model qwen3-moe"), "{err}");
        assert!(args("simulate --model qwen3-moe --dispatch-ms -1")
            .models()
            .is_err());
        assert!(args("simulate --model qwen3-moe --dispatch-ms nan")
            .models()
            .is_err());
        let unknown =
            args("simulate --model bogus").models().unwrap_err().to_string();
        assert!(unknown.contains("qwen3-moe"), "{unknown}");
    }

    #[test]
    fn simulate_runs_the_model_axis_end_to_end() {
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 --groups 2 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        assert_eq!(quick("--model qwen3-moe").unwrap(), 0);
        assert_eq!(quick("--model llama70b+spec").unwrap(), 0);
        assert_eq!(quick("--model qwen3-moe --dispatch-ms 5").unwrap(), 0);
        assert!(quick("--model bogus").is_err());
    }

    #[test]
    fn subcommand_parsed_separately_from_options() {
        let a = args("simulate sweep --lambda 1000 --b-short 4096");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.opt("lambda"), Some("1000"));
        // Option values are not mistaken for subcommands.
        let b = args("simulate --dispatch jsq");
        assert_eq!(b.subcommand, None);
    }

    #[test]
    fn format_option_parses_and_rejects_unknown() {
        assert_eq!(args("report").format().unwrap(), OutputFormat::Table);
        assert_eq!(
            args("report --format csv").format().unwrap(),
            OutputFormat::Csv
        );
        assert_eq!(
            args("report --format json").format().unwrap(),
            OutputFormat::Json
        );
        assert!(args("report --format yaml").format().is_err());
        assert!(run(
            "report --format yaml".split_whitespace().map(String::from)
        )
        .is_err());
    }

    #[test]
    fn format_aware_commands_emit_machine_formats() {
        // Cheap surfaces only (tables t7 is closed-form; report is fast).
        for cmd in ["tables --t7 --format csv", "tables --t7 --format json",
                    "report --format json", "sweep --format csv"] {
            assert_eq!(
                run(cmd.split_whitespace().map(String::from)).unwrap(),
                0,
                "{cmd}"
            );
        }
    }

    #[test]
    fn optimize_runs_two_stage_search_end_to_end() {
        let code = run(
            "optimize --gpu h100 --lambda 60 --duration 0.5 --groups 2 \
             --b-short 4096 --dispatch rr --top-k 2 --workers 2 \
             --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        assert!(run(
            "optimize --gpu bogus".split_whitespace().map(String::from)
        )
        .is_err());
        assert!(run(
            "optimize --gamma 0.5 --gpu h100"
                .split_whitespace()
                .map(String::from)
        )
        .is_err());
    }

    #[test]
    fn pools_and_cutoffs_options_parse_and_validate() {
        assert_eq!(args("simulate").pools_k().unwrap(), None);
        assert_eq!(args("simulate --pools 3").pools_k().unwrap(), Some(3));
        assert_eq!(args("simulate --pools 6").pools_k().unwrap(), Some(6));
        assert!(args("simulate --pools 1").pools_k().is_err());
        assert!(args("simulate --pools 9").pools_k().is_err());
        assert!(args("simulate --pools x").pools_k().is_err());
        assert_eq!(args("simulate").cutoffs().unwrap(), None);
        // LONG_CTX long pool appended (and kept when given explicitly).
        assert_eq!(
            args("simulate --cutoffs 2048,16384").cutoffs().unwrap(),
            Some(vec![2048, 16384, LONG_CTX])
        );
        assert_eq!(
            args("simulate --cutoffs 4096,65536").cutoffs().unwrap(),
            Some(vec![4096, LONG_CTX])
        );
        // Unsorted or duplicate input is an error, not silently
        // normalized: a re-sort would also misalign a per-pool --gpu
        // assignment, and a typo deserves a message, not a guess.
        assert!(args("simulate --cutoffs 16384,2048").cutoffs().is_err());
        assert!(args("simulate --cutoffs 16384,2048,16384")
            .cutoffs()
            .is_err());
        assert!(args("simulate --cutoffs 2048,2048").cutoffs().is_err());
        assert!(args("simulate --cutoffs 4096,abc").cutoffs().is_err());
        assert!(args("simulate --cutoffs 0").cutoffs().is_err());
        // Values beyond the long window are rejected, so an interior
        // cutoff can never reach 64K: a 65536 entry is only legal last.
        assert!(args("simulate --cutoffs 70000,65536").cutoffs().is_err());
        assert!(args("simulate --cutoffs 65536,4096").cutoffs().is_err());
        // A bare 64K is the homogeneous baseline, not a partition.
        assert!(args("simulate --cutoffs 65536").cutoffs().is_err());
        assert!(args("simulate --cutoffs 65536,65536").cutoffs().is_err());
    }

    #[test]
    fn gpu_list_option_parses_and_validates() {
        assert_eq!(args("simulate").gpus().unwrap(), None);
        assert_eq!(
            args("simulate --gpu b200").gpus().unwrap(),
            Some(vec![Gpu::B200])
        );
        assert_eq!(
            args("simulate --gpu h100,h100,b200").gpus().unwrap(),
            Some(vec![Gpu::H100, Gpu::H100, Gpu::B200])
        );
        assert!(args("simulate --gpu h100,bogus").gpus().is_err());
        assert!(args("simulate --gpu h100,,b200").gpus().is_err());
        // Commands without a per-pool axis reject junk and lists
        // instead of silently defaulting to H100.
        assert!(run("power --gpu bogus".split_whitespace().map(String::from))
            .is_err());
        assert!(run(
            "fleet --gpu h100,b200 --topo fleetopt"
                .split_whitespace()
                .map(String::from)
        )
        .is_err());
        assert_eq!(
            run("power --gpu b200".split_whitespace().map(String::from))
                .unwrap(),
            0
        );
    }

    #[test]
    fn simulate_runs_a_heterogeneous_kpool_fleet() {
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        assert_eq!(
            quick("--cutoffs 2048,8192 --gpu h100,h100,b200 --groups 3")
                .unwrap(),
            0
        );
        // The assignment must match the pool count.
        assert!(quick("--cutoffs 2048,8192 --gpu h100,b200").is_err());
        // And needs a partition to assign across.
        assert!(quick("--gpu h100,b200").is_err());
    }

    #[test]
    fn optimize_accepts_the_heterogeneous_axes() {
        // Explicit per-pool assignment (the CI smoke cell's shape).
        let code = run(
            "optimize --trace agent --gpu h100,h100,b200 --pools 3 \
             --lambda 60 --duration 0.4 --groups 3 --gamma 1 \
             --dispatch rr --top-k 2 --workers 2 --slo-ttft 1000 \
             --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        // Budgeted upgrade search.
        let code = run(
            "optimize --gpu h100 --upgrade-budget 64 --upgrade-to b200 \
             --cutoffs 4096 --lambda 60 --duration 0.4 --groups 2 \
             --gamma 1 --dispatch rr --top-k 2 --workers 2 \
             --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        // The branch-and-bound mixed screen: a K ≤ 5 three-generation
        // search the old cross-product refused (|gpus|^K explosion).
        let code = run(
            "optimize --trace agent --hetero --pools 5 \
             --gpu h100,h200,b200 --lambda 60 --duration 0.4 --groups 5 \
             --gamma 1 --dispatch rr --top-k 1 --workers 2 \
             --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        // Axis validation errors.
        let fails = [
            // assignment length matches no screened partition
            "optimize --gpu h100,b200 --pools 3 --cutoffs 2048,8192",
            // --upgrade-to without a budget
            "optimize --upgrade-to b200",
            // upgrading to the base generation is a no-op
            "optimize --gpu b200 --upgrade-budget 8 --upgrade-to b200",
            // --hetero needs two distinct generations
            "optimize --hetero --gpu h100",
            // the two heterogeneous searches are mutually exclusive
            "optimize --hetero --upgrade-budget 8",
            // --pools stops at the ladder's K = 6 ceiling
            "optimize --hetero --pools 7",
        ];
        for cmd in fails {
            assert!(
                run(cmd.split_whitespace().map(String::from)).is_err(),
                "{cmd} should fail"
            );
        }
    }

    #[test]
    fn simulate_runs_a_kpool_fleet() {
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        assert_eq!(quick("--pools 3 --groups 3").unwrap(), 0);
        assert_eq!(quick("--cutoffs 2048,8192 --groups 4").unwrap(), 0);
        // The K-pool bucket router replaces --router.
        assert!(quick("--pools 3 --router adaptive").is_err());
        // γ on a partition is validated, not silently defaulted.
        assert!(quick("--pools 2 --gamma 0.5").is_err());
        assert!(quick("--pools 2 --gamma 2x").is_err());
    }

    #[test]
    fn sweep_ranks_partitions_with_pools_flag() {
        assert_eq!(
            run("sweep --cutoffs 4096,16384 --format csv"
                .split_whitespace()
                .map(String::from))
            .unwrap(),
            0
        );
    }

    #[test]
    fn optimize_screens_kpool_partitions() {
        let code = run(
            "optimize --gpu h100 --lambda 60 --duration 0.5 --groups 4 \
             --cutoffs 2048,8192 --gamma 1 --dispatch rr --top-k 1 \
             --workers 2 --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        // --groups below the partition's pool count is floored, not a
        // stage-B worker-thread panic.
        let code = run(
            "optimize --gpu h100 --lambda 60 --duration 0.5 --groups 2 \
             --cutoffs 2048,8192 --gamma 1 --dispatch rr --top-k 1 \
             --workers 2 --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        // A boundary at the full window has no two-pool reduction.
        assert!(run(
            "optimize --gpu h100 --b-short 65536"
                .split_whitespace()
                .map(String::from)
        )
        .is_err());
    }

    #[test]
    fn arrivals_option_parses_and_validates() {
        // Built-in trace names stay stationary; the legacy silent
        // default is untouched.
        assert_eq!(
            args("simulate --trace azure").arrivals().unwrap(),
            ArrivalSpec::Stationary
        );
        assert_eq!(
            args("simulate").arrivals().unwrap(),
            ArrivalSpec::Stationary
        );
        // Every archetype name parses; junk is a named error.
        for name in ArrivalSpec::NAMES {
            assert!(
                args(&format!("simulate --workload {name}"))
                    .arrivals()
                    .is_ok(),
                "{name}"
            );
        }
        assert!(args("simulate --workload bogus").arrivals().is_err());
        // A missing replay file fails at parse time, not on a worker.
        assert!(args("simulate --trace /no/such/file.csv")
            .arrivals()
            .is_err());
    }

    #[test]
    fn simulate_accepts_workload_archetypes() {
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 --groups 2 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        assert_eq!(quick("--workload diurnal").unwrap(), 0);
        assert_eq!(quick("--workload flash-crowd --dispatch jsq").unwrap(), 0);
        assert_eq!(quick("--workload multi-tenant").unwrap(), 0);
        assert!(quick("--workload bogus").is_err());
    }

    #[test]
    fn simulate_accepts_workers() {
        let quick = |extra: &str| {
            run(format!("simulate --lambda 10 --duration 1 --groups 2 {extra}")
                .split_whitespace()
                .map(String::from))
        };
        // --workers 1 forces the sequential engine; > 1 opts
        // arrival-static runs into the sharded streaming path (the
        // load-aware-dispatch run stays sequential either way).
        assert_eq!(quick("--workers 1").unwrap(), 0);
        assert_eq!(quick("--workers 2").unwrap(), 0);
        assert_eq!(quick("--workers 2 --dispatch jsq").unwrap(), 0);
    }

    #[test]
    fn simulate_replays_a_csv_trace_end_to_end() {
        // Record a generated trace, then replay it through simulate and
        // through a sweep cell — the full --trace file path.
        let gen = crate::workload::synth::GenConfig {
            lambda_rps: 20.0,
            duration_s: 1.0,
            max_prompt_tokens: 8000,
            max_output_tokens: 64,
            seed: 11,
        };
        let reqs =
            crate::workload::synth::generate(&azure_conversations(), &gen);
        let path = std::env::temp_dir().join("wattlaw_cli_replay.csv");
        crate::workload::trace::save_csv(&path, &reqs).unwrap();
        let p = path.display();

        assert_eq!(
            run(format!("simulate --trace {p} --groups 2")
                .split_whitespace()
                .map(String::from))
            .unwrap(),
            0
        );
        assert_eq!(
            run(format!(
                "simulate sweep --trace {p} --groups 2 --dispatch rr \
                 --b-short 4096 --workers 2 --format csv"
            )
            .split_whitespace()
            .map(String::from))
            .unwrap(),
            0
        );
        // Replay and an archetype are two answers to the same question.
        assert!(run(format!(
            "simulate --trace {p} --workload diurnal --groups 2"
        )
        .split_whitespace()
        .map(String::from))
        .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn simulate_sweep_accepts_a_workload_archetype() {
        let code = run(
            "simulate sweep --lambda 200 --duration 0.3 --groups 2 \
             --dispatch jsq --b-short 4096 --workload flash-crowd \
             --workers 2 --format csv"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn optimize_accepts_a_workload_archetype() {
        let code = run(
            "optimize --gpu h100 --lambda 60 --duration 0.5 --groups 2 \
             --b-short 4096 --dispatch rr --top-k 1 --workers 2 \
             --workload heavy-tail --slo-ttft 1000 --format json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn simulate_sweep_runs_a_grid_at_fleet_scale() {
        // λ=1000 end-to-end, shrunk along every other axis so the grid
        // (homo + pool + fleetopt + adaptive-pool, one dispatch) stays
        // test-sized.
        let code = run(
            "simulate sweep --lambda 1000 --duration 0.2 --groups 2 \
             --dispatch jsq --b-short 4096 --workers 2"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
        assert!(run(
            "simulate bogus-sub".split_whitespace().map(String::from)
        )
        .is_err());
        assert!(run(
            "simulate sweep --dispatch bogus"
                .split_whitespace()
                .map(String::from)
        )
        .is_err());
    }
}
