//! Adaptive topology control (paper §10.3 "Adaptive topology control"):
//! the split boundary B_short is normally fixed offline from a historical
//! CDF; this controller monitors the live request-length distribution in
//! a sliding window and re-estimates the boundary online, with hysteresis
//! so pools aren't reconfigured on noise.
//!
//! Policy: track the empirical q-quantile of prompt lengths (default
//! q = 0.85 — "most traffic short"), snap it to the power-of-two grid the
//! fleet planner uses, and switch only when the target is stable for
//! `hysteresis` consecutive re-evaluations.

use std::collections::VecDeque;

/// Online B_short controller.
#[derive(Debug, Clone)]
pub struct AdaptiveSplit {
    /// Sliding window of recent prompt lengths.
    window: VecDeque<u32>,
    capacity: usize,
    /// Quantile of traffic the short pool should capture.
    pub quantile: f64,
    /// Consecutive agreeing re-evaluations required to switch.
    pub hysteresis: u32,
    current: u32,
    pending: Option<(u32, u32)>, // (candidate, votes)
    /// Re-evaluate every `period` observations.
    pub period: u32,
    since_eval: u32,
    /// Total boundary switches performed (for reports).
    pub switches: u32,
}

/// Power-of-two boundary grid (matches the planner's sweep grid).
pub const BOUNDS: [u32; 8] = [512, 1024, 2048, 4096, 8192, 16_384, 32_768, 65_536];

fn snap(len: f64) -> u32 {
    for &b in &BOUNDS {
        if len <= b as f64 {
            return b;
        }
    }
    *BOUNDS.last().unwrap()
}

impl AdaptiveSplit {
    pub fn new(initial_b_short: u32, window: usize) -> Self {
        AdaptiveSplit {
            window: VecDeque::with_capacity(window),
            capacity: window.max(16),
            quantile: 0.85,
            hysteresis: 3,
            current: initial_b_short,
            pending: None,
            period: 256,
            since_eval: 0,
            switches: 0,
        }
    }

    /// Current split boundary.
    pub fn b_short(&self) -> u32 {
        self.current
    }

    /// Observe one request's prompt length; returns the (possibly
    /// updated) boundary.
    pub fn observe(&mut self, prompt_tokens: u32) -> u32 {
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(prompt_tokens);
        self.since_eval += 1;
        if self.since_eval >= self.period && self.window.len() >= 64 {
            self.since_eval = 0;
            self.reevaluate();
        }
        self.current
    }

    fn empirical_quantile(&self) -> f64 {
        let mut v: Vec<u32> = self.window.iter().copied().collect();
        v.sort_unstable();
        let idx = ((v.len() - 1) as f64 * self.quantile).round() as usize;
        v[idx] as f64
    }

    fn reevaluate(&mut self) {
        let candidate = snap(self.empirical_quantile());
        if candidate == self.current {
            self.pending = None;
            return;
        }
        let votes = match self.pending {
            Some((c, v)) if c == candidate => v + 1,
            _ => 1,
        };
        if votes >= self.hysteresis {
            self.current = candidate;
            self.pending = None;
            self.switches += 1;
        } else {
            self.pending = Some((candidate, votes));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::{agent_heavy, azure_conversations};
    use crate::xrand::Rng;

    fn feed(ctl: &mut AdaptiveSplit, trace: &crate::workload::WorkloadTrace,
            n: usize, rng: &mut Rng) {
        for _ in 0..n {
            let p = trace.prompt_cdf.sample(rng).round().max(1.0) as u32;
            ctl.observe(p);
        }
    }

    #[test]
    fn converges_to_the_trace_quantile() {
        let mut ctl = AdaptiveSplit::new(65_536, 4096);
        let mut rng = Rng::new(1);
        feed(&mut ctl, &azure_conversations(), 20_000, &mut rng);
        // Azure's 85th percentile sits near 3.3K → snapped to 4096,
        // matching the paper's chosen B_short.
        assert_eq!(ctl.b_short(), 4096, "converged to {}", ctl.b_short());
    }

    #[test]
    fn adapts_under_distribution_shift() {
        let mut ctl = AdaptiveSplit::new(4096, 2048);
        let mut rng = Rng::new(2);
        feed(&mut ctl, &azure_conversations(), 8_000, &mut rng);
        let before = ctl.b_short();
        // Workload shifts to agent-heavy: boundary must move up.
        feed(&mut ctl, &agent_heavy(), 8_000, &mut rng);
        let after = ctl.b_short();
        assert!(after > before, "shift: {before} -> {after}");
        assert!(ctl.switches >= 1);
    }

    #[test]
    fn hysteresis_suppresses_noise() {
        let mut ctl = AdaptiveSplit::new(4096, 1024);
        ctl.hysteresis = 1000; // effectively frozen
        let mut rng = Rng::new(3);
        feed(&mut ctl, &agent_heavy(), 10_000, &mut rng);
        assert_eq!(ctl.b_short(), 4096, "frozen controller must not move");
        assert_eq!(ctl.switches, 0);
    }

    #[test]
    fn snap_is_monotone_and_bounded() {
        let mut prev = 0;
        for len in [10.0, 600.0, 3000.0, 9000.0, 40_000.0, 1e9] {
            let b = snap(len);
            assert!(b >= prev);
            assert!(BOUNDS.contains(&b));
            prev = b;
        }
    }
}
