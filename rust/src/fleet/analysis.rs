//! Fleet-level token efficiency — the paper's Eq. (4) and the
//! `fleet_tpw_analysis` API of Appendix B:
//!
//! ```text
//! tok/W_fleet = Σ_i λ_i · L̄_out,i  /  Σ_i n_i · P(n_act,i)
//! ```
//!
//! where pools are sized to the arrival rate under the TTFT SLO
//! ([`crate::queueing::sizing`]), `n_act,i` is the achieved mean in-flight
//! batch, and the power denominator follows the selected
//! [`PowerAccounting`] convention.

use super::pool::PoolPlan;
use super::profile::PowerAccounting;
use crate::queueing::sizing::{size_pool, PoolSizing};
use crate::units::{TokensPerWatt, Watts};

/// Per-pool line in a fleet report.
#[derive(Debug, Clone)]
pub struct PoolReport {
    pub name: String,
    pub profile_label: String,
    pub context_tokens: u32,
    pub lambda_rps: f64,
    pub sizing: PoolSizing,
    /// Power denominator for this pool (groups × accounted power), watts.
    pub power: Watts,
    /// Output tokens/s this pool is credited with (λ_i · L̄_out,i).
    pub demand_tok_s: f64,
    pub tok_per_watt: TokensPerWatt,
}

/// Fleet-level aggregation.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub pools: Vec<PoolReport>,
    pub accounting: PowerAccounting,
    /// Σ groups over pools.
    pub total_groups: u64,
    /// Physical GPUs (groups × TP).
    pub total_gpus: u64,
    pub total_power: Watts,
    pub total_demand_tok_s: f64,
    pub tok_per_watt: TokensPerWatt,
}

/// Size and account a fleet of pools — Eq. (4).
pub fn fleet_tpw_analysis(
    pools: &[PoolPlan],
    accounting: PowerAccounting,
) -> FleetReport {
    let mut reports = Vec::with_capacity(pools.len());
    let (mut groups, mut gpus, mut power_w, mut demand) = (0u64, 0u64, 0.0, 0.0);

    for plan in pools {
        let sizing = size_pool(plan.profile.as_ref(), &plan.inputs);
        let per_group_w = plan
            .profile
            .group_power_w(sizing.n_active, accounting);
        let pool_power = per_group_w * sizing.groups as f64;
        let pool_demand = plan.inputs.lambda_rps * plan.inputs.mean_output_tokens;

        groups += sizing.groups;
        gpus += sizing.groups * plan.profile.tp() as u64;
        power_w += pool_power;
        demand += pool_demand;

        reports.push(PoolReport {
            name: plan.name.clone(),
            profile_label: plan.profile.label(),
            context_tokens: plan.inputs.context_tokens,
            lambda_rps: plan.inputs.lambda_rps,
            sizing,
            power: Watts(pool_power),
            demand_tok_s: pool_demand,
            tok_per_watt: TokensPerWatt(if pool_power > 0.0 {
                pool_demand / pool_power
            } else {
                0.0
            }),
        });
    }

    FleetReport {
        pools: reports,
        accounting,
        total_groups: groups,
        total_gpus: gpus,
        total_power: Watts(power_w),
        total_demand_tok_s: demand,
        tok_per_watt: TokensPerWatt(if power_w > 0.0 { demand / power_w } else { 0.0 }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::pool::LBarPolicy;
    use crate::fleet::profile::ManualProfile;
    use crate::fleet::topology::{Topology, LONG_CTX};
    use crate::workload::cdf::{azure_conversations, lmsys_chat};
    use std::sync::Arc;

    fn analyze(topo: Topology, b200: bool) -> FleetReport {
        let profile: Arc<dyn crate::fleet::GpuProfile> = if b200 {
            Arc::new(ManualProfile::b200_70b())
        } else {
            Arc::new(ManualProfile::h100_70b())
        };
        let pools = topo.pools(
            &azure_conversations(), 1000.0, profile, None,
            LBarPolicy::Window, 0.85, 0.5);
        fleet_tpw_analysis(&pools, PowerAccounting::PerGpu)
    }

    #[test]
    fn homogeneous_fleet_matches_long_pool_tok_w() {
        // A Homo-64K fleet can never beat the single-GPU 64K upper bound
        // (1.52 tok/W at ρ=0.85) — the internal-consistency check the
        // paper's own Table 3 fails; see DESIGN.md §4.
        let r = analyze(Topology::Homogeneous { ctx: LONG_CTX }, false);
        assert!(r.tok_per_watt.0 <= 1.60, "tok/W = {}", r.tok_per_watt.0);
        assert!(r.tok_per_watt.0 > 1.2, "tok/W = {}", r.tok_per_watt.0);
    }

    #[test]
    fn topology_ordering_homo_pool_fleetopt() {
        // Table 3's ordering: Homo < Pool routing < FleetOpt.
        let homo = analyze(Topology::Homogeneous { ctx: LONG_CTX }, false);
        let pool = analyze(
            Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }, false);
        let opt = analyze(
            Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
            false);
        assert!(pool.tok_per_watt.0 > homo.tok_per_watt.0 * 1.3,
                "pool {} vs homo {}", pool.tok_per_watt.0, homo.tok_per_watt.0);
        assert!(opt.tok_per_watt.0 > pool.tok_per_watt.0,
                "fleetopt {} vs pool {}", opt.tok_per_watt.0, pool.tok_per_watt.0);
        // Fewer GPUs as topology improves.
        assert!(opt.total_groups < pool.total_groups);
        assert!(pool.total_groups < homo.total_groups);
    }

    #[test]
    fn generation_gain_roughly_independent_of_topology() {
        // §4.2: Δ_gen barely changes between Homo and FleetOpt.
        let topos = [
            Topology::Homogeneous { ctx: LONG_CTX },
            Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
        ];
        let gains: Vec<f64> = topos
            .iter()
            .map(|t| {
                analyze(t.clone(), true).tok_per_watt.0
                    / analyze(t.clone(), false).tok_per_watt.0
            })
            .collect();
        let rel_spread = (gains[0] - gains[1]).abs() / gains[0];
        assert!(
            rel_spread < 0.15,
            "Δ_gen(Homo) = {:.2}, Δ_gen(FleetOpt) = {:.2}",
            gains[0],
            gains[1]
        );
    }

    #[test]
    fn gains_multiply() {
        // §4.2: combined ≈ Δ_topo × Δ_gen (independence ⇒ multiplicativity).
        let h_homo = analyze(Topology::Homogeneous { ctx: LONG_CTX }, false);
        let h_opt = analyze(
            Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
            false);
        let b_homo = analyze(Topology::Homogeneous { ctx: LONG_CTX }, true);
        let b_opt = analyze(
            Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
            true);
        let d_topo = h_opt.tok_per_watt.0 / h_homo.tok_per_watt.0;
        let d_gen = b_homo.tok_per_watt.0 / h_homo.tok_per_watt.0;
        let combined = b_opt.tok_per_watt.0 / h_homo.tok_per_watt.0;
        let product = d_topo * d_gen;
        assert!(
            ((combined - product) / product).abs() < 0.15,
            "combined {combined:.2} vs product {product:.2}"
        );
    }

    #[test]
    fn lmsys_also_benefits_from_routing() {
        let profile: Arc<dyn crate::fleet::GpuProfile> =
            Arc::new(ManualProfile::h100_70b());
        let t = lmsys_chat();
        let homo = fleet_tpw_analysis(
            &Topology::Homogeneous { ctx: LONG_CTX }.pools(
                &t, 1000.0, profile.clone(), None, LBarPolicy::Window, 0.85, 0.5),
            PowerAccounting::PerGpu,
        );
        let opt = fleet_tpw_analysis(
            &Topology::FleetOpt { b_short: 1536, short_ctx: 2048, gamma: 2.0 }
                .pools(&t, 1000.0, profile, None, LBarPolicy::Window, 0.85, 0.5),
            PowerAccounting::PerGpu,
        );
        assert!(opt.tok_per_watt.0 > homo.tok_per_watt.0 * 1.5);
    }

    #[test]
    fn per_group_accounting_is_tp_x_more_power() {
        let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
            &azure_conversations(), 1000.0,
            Arc::new(ManualProfile::h100_70b()), None,
            LBarPolicy::Window, 0.85, 0.5);
        let gpu = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
        let grp = fleet_tpw_analysis(&pools, PowerAccounting::PerGroup);
        assert!((grp.total_power.0 / gpu.total_power.0 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn demand_conserved_across_topologies() {
        let homo = analyze(Topology::Homogeneous { ctx: LONG_CTX }, false);
        let opt = analyze(
            Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 },
            false);
        assert!(
            (homo.total_demand_tok_s - opt.total_demand_tok_s).abs() < 1e-6,
            "routing must not create or destroy tokens"
        );
    }
}
