//! Carbon- and cost-aware joint optimization (paper §10.3 "Carbon-aware
//! joint optimization"): tok/W ignores PUE, grid carbon intensity and
//! time-of-day electricity pricing; this module extends the per-GPU power
//! model into $/Mtok and gCO₂/token objectives, exactly the "natural
//! starting point" the paper describes.

use super::analysis::FleetReport;

/// Datacenter + grid context.
#[derive(Debug, Clone, Copy)]
pub struct GridContext {
    /// Power usage effectiveness (total facility power / IT power).
    pub pue: f64,
    /// Grid carbon intensity, gCO₂ per kWh.
    pub carbon_g_per_kwh: f64,
    /// Electricity price, $ per kWh.
    pub price_per_kwh: f64,
}

impl GridContext {
    /// A hyperscale datacenter on a mixed grid (typical 2025 numbers).
    pub fn typical() -> Self {
        GridContext { pue: 1.2, carbon_g_per_kwh: 350.0, price_per_kwh: 0.08 }
    }

    /// A low-carbon grid (hydro/nuclear heavy) at off-peak pricing.
    pub fn low_carbon_offpeak() -> Self {
        GridContext { pue: 1.1, carbon_g_per_kwh: 40.0, price_per_kwh: 0.05 }
    }

    /// A coal-heavy grid at peak pricing.
    pub fn high_carbon_peak() -> Self {
        GridContext { pue: 1.4, carbon_g_per_kwh: 800.0, price_per_kwh: 0.18 }
    }
}

/// Carbon/cost metrics derived from a fleet report.
#[derive(Debug, Clone, Copy)]
pub struct CarbonReport {
    /// Facility-level watts (IT power × PUE).
    pub facility_kw: f64,
    /// Grams CO₂ per output token.
    pub g_co2_per_token: f64,
    /// Electricity dollars per million output tokens.
    pub usd_per_mtok: f64,
    /// Facility-level tokens per watt (tok/W ÷ PUE).
    pub facility_tok_per_watt: f64,
}

/// Evaluate a sized fleet under a grid context.
pub fn carbon_report(fleet: &FleetReport, grid: &GridContext) -> CarbonReport {
    let it_w = fleet.total_power.0;
    let facility_w = it_w * grid.pue;
    let tok_s = fleet.total_demand_tok_s;
    // kWh per second of operation = W / 3.6e6.
    let kwh_per_s = facility_w / 3.6e6;
    let g_per_s = kwh_per_s * grid.carbon_g_per_kwh;
    let usd_per_s = kwh_per_s * grid.price_per_kwh;
    CarbonReport {
        facility_kw: facility_w / 1e3,
        g_co2_per_token: if tok_s > 0.0 { g_per_s / tok_s } else { f64::NAN },
        usd_per_mtok: if tok_s > 0.0 {
            usd_per_s / tok_s * 1e6
        } else {
            f64::NAN
        },
        facility_tok_per_watt: if facility_w > 0.0 {
            tok_s / facility_w
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::analysis::fleet_tpw_analysis;
    use crate::fleet::pool::LBarPolicy;
    use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
    use crate::fleet::topology::{Topology, LONG_CTX};
    use crate::workload::cdf::azure_conversations;
    use std::sync::Arc;

    fn fleet(topo: Topology) -> crate::fleet::analysis::FleetReport {
        let p: Arc<dyn GpuProfile> = Arc::new(ManualProfile::h100_70b());
        let pools = topo.pools(&azure_conversations(), 1000.0, p, None,
                               LBarPolicy::Window, 0.85, 0.5);
        fleet_tpw_analysis(&pools, PowerAccounting::PerGpu)
    }

    #[test]
    fn topology_gain_carries_through_to_carbon() {
        // The 1/W multiplicative structure survives the carbon mapping:
        // gCO₂/token improves by the same factor tok/W does.
        let grid = GridContext::typical();
        let homo = carbon_report(&fleet(Topology::Homogeneous { ctx: LONG_CTX }), &grid);
        let opt = carbon_report(
            &fleet(Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }),
            &grid,
        );
        let tok_w_gain = opt.facility_tok_per_watt / homo.facility_tok_per_watt;
        let carbon_gain = homo.g_co2_per_token / opt.g_co2_per_token;
        assert!(
            (tok_w_gain - carbon_gain).abs() / tok_w_gain < 1e-9,
            "carbon gain {carbon_gain} != tok/W gain {tok_w_gain}"
        );
        assert!(carbon_gain > 1.5);
    }

    #[test]
    fn pue_scales_facility_power() {
        let r = fleet(Topology::Homogeneous { ctx: LONG_CTX });
        let a = carbon_report(&r, &GridContext { pue: 1.0, ..GridContext::typical() });
        let b = carbon_report(&r, &GridContext { pue: 1.5, ..GridContext::typical() });
        assert!((b.facility_kw / a.facility_kw - 1.5).abs() < 1e-9);
        assert!((a.facility_tok_per_watt / b.facility_tok_per_watt - 1.5).abs() < 1e-9);
    }

    #[test]
    fn grid_mix_dominates_carbon_not_cost_structure() {
        let r = fleet(Topology::Homogeneous { ctx: LONG_CTX });
        let clean = carbon_report(&r, &GridContext::low_carbon_offpeak());
        let dirty = carbon_report(&r, &GridContext::high_carbon_peak());
        assert!(dirty.g_co2_per_token > clean.g_co2_per_token * 10.0);
        assert!(dirty.usd_per_mtok > clean.usd_per_mtok);
    }

    #[test]
    fn plausible_magnitudes() {
        // Sanity: gCO₂/token for a 64K homo fleet should land in the
        // fraction-of-a-gram range, and $/Mtok in single-digit dollars.
        let r = carbon_report(
            &fleet(Topology::Homogeneous { ctx: LONG_CTX }),
            &GridContext::typical(),
        );
        // Order of magnitude: 1e-5–1e-2 gCO₂ per output token (public
        // LLM-inference estimates put whole *queries* at ~0.1–3 g).
        assert!(r.g_co2_per_token > 1e-5 && r.g_co2_per_token < 1e-2,
                "g/tok = {}", r.g_co2_per_token);
        assert!(r.usd_per_mtok > 0.01 && r.usd_per_mtok < 1_000.0,
                "$/Mtok = {}", r.usd_per_mtok);
    }
}
