//! Prefill/decode disaggregation (paper §10.3 "Prefill-decode
//! disaggregation", after Splitwise): assign prefill and decode to
//! different pools. Combined with context-length routing this removes
//! prefill work from the decode pools' iterations — decode pools run pure
//! roofline decode — at the cost of dedicated prefill GPUs and a KV
//! transfer between pools.
//!
//! This module sizes the prefill tier from the traces' prompt-token rate
//! (prefill is compute/bandwidth-bound: a group ingests
//! ~`bw_eff · BW / 2 bytes-per-weight-use` tokens/s at large chunks —
//! approximated by the roofline's chunked-prefill model) and reports both
//! accounting conventions the paper discusses: output-only tok/W with and
//! without the prefill tier's power in the denominator.

use std::sync::Arc;

use super::analysis::{fleet_tpw_analysis, FleetReport};
use super::pool::LBarPolicy;
use super::profile::{GpuProfile, PowerAccounting};
use super::topology::Topology;
use crate::workload::WorkloadTrace;

/// Disaggregated fleet analysis result.
#[derive(Debug, Clone)]
pub struct DisaggReport {
    /// The decode-side fleet (same topology, but sized for decode only —
    /// zero prefill interference).
    pub decode: FleetReport,
    /// Prefill-tier groups.
    pub prefill_groups: u64,
    /// Prefill-tier power, watts (accounted like the decode tier).
    pub prefill_power_w: f64,
    /// Output tok/W charging decode power only (the paper's output-only
    /// accounting — §10.1 caveat).
    pub tok_per_watt_decode_only: f64,
    /// Output tok/W charging decode + prefill tiers (honest total).
    pub tok_per_watt_total: f64,
}

/// Size and account a disaggregated fleet.
pub fn disaggregate(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    topo: &Topology,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> DisaggReport {
    // Decode-side fleet: identical topology/sizing (our sizing is already
    // decode-throughput + TTFT driven; with disaggregation the TTFT
    // constraint moves to the prefill tier, which can only shrink the
    // decode fleet — we keep it, making this a conservative bound).
    let pools = topo.pools(trace, lambda_rps, profile.clone(), None, lbar, rho, ttft_slo_s);
    let decode = fleet_tpw_analysis(&pools, acct);

    // Prefill tier: demand = λ · E[prompt] tokens/s. A prefill group
    // saturates near its chunked-prefill rate: chunk/(W + H(chunk/2)·1)
    // per iteration with chunk = 8K tokens.
    let mean_prompt = trace.prompt_cdf.mean();
    let demand_tok_s = lambda_rps * mean_prompt;
    let r = profile.roofline();
    let chunk = 8192.0;
    let iter_ms = r.tau_ms(1.0, chunk / 2.0) + r.w_ms * (chunk / 1024.0 - 1.0);
    let group_prefill_tok_s = chunk / iter_ms * 1e3;
    let groups_used = demand_tok_s / (rho * group_prefill_tok_s);
    let prefill_groups = groups_used.ceil() as u64;
    // Prefill runs hot (large effective batch) — but only while fed.
    // Fully-loaded groups bill near-saturation; the ceil-rounded last
    // group is busy only a `frac` duty fraction of the time and idles the
    // rest, exactly the idle-energy accounting the decode pools already
    // carry. Billing it at full hot watts overstated the prefill tier by
    // up to (P_hot − P_idle) per fleet.
    let hot_w = profile.group_power_w(128.0, acct);
    let idle_w = profile.group_power_w(0.0, acct);
    let full_groups = groups_used.floor();
    let frac = groups_used - full_groups;
    let prefill_power_w = full_groups * hot_w
        + if frac > 0.0 { frac * hot_w + (1.0 - frac) * idle_w } else { 0.0 };

    let out_tok_s = decode.total_demand_tok_s;
    let total_w = decode.total_power.0 + prefill_power_w;
    DisaggReport {
        tok_per_watt_decode_only: if decode.total_power.0 > 0.0 {
            out_tok_s / decode.total_power.0
        } else {
            0.0
        },
        tok_per_watt_total: if total_w > 0.0 { out_tok_s / total_w } else { 0.0 },
        prefill_groups,
        prefill_power_w,
        decode,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::fleet::topology::LONG_CTX;
    use crate::workload::cdf::{agent_heavy, azure_conversations};

    fn run(trace: &WorkloadTrace) -> DisaggReport {
        disaggregate(
            trace,
            1000.0,
            Arc::new(ManualProfile::h100_70b()),
            &Topology::FleetOpt { b_short: trace.paper_b_short,
                                  short_ctx: trace.paper_b_short.max(2048),
                                  gamma: 2.0 },
            LBarPolicy::Window,
            0.85,
            0.5,
            PowerAccounting::PerGpu,
        )
    }

    #[test]
    fn prefill_tier_sized_to_prompt_rate() {
        let azure = run(&azure_conversations());
        let agent = run(&agent_heavy());
        assert!(azure.prefill_groups >= 1);
        // Agent-heavy has far longer prompts → bigger prefill tier.
        assert!(
            agent.prefill_groups > azure.prefill_groups,
            "agent {} vs azure {}",
            agent.prefill_groups,
            azure.prefill_groups
        );
    }

    #[test]
    fn decode_only_accounting_is_an_upper_bound() {
        let r = run(&azure_conversations());
        assert!(r.tok_per_watt_decode_only > r.tok_per_watt_total);
        assert!(r.tok_per_watt_total > 0.0);
    }

    #[test]
    fn prompt_heavy_workloads_pay_more_for_prefill() {
        // §10.1: "for workloads with prompt-to-output ratios much greater
        // than one, the reported tok/W overestimates true efficiency" —
        // quantified: agent-heavy traffic needs several times the
        // absolute prefill power, and both workloads show a real
        // decode-only vs total accounting gap.
        let azure = run(&azure_conversations());
        let agent = run(&agent_heavy());
        assert!(
            agent.prefill_power_w > 2.0 * azure.prefill_power_w,
            "agent {} W vs azure {} W",
            agent.prefill_power_w,
            azure.prefill_power_w
        );
        let gap = |r: &DisaggReport| r.tok_per_watt_decode_only / r.tok_per_watt_total;
        assert!(gap(&azure) > 1.05 && gap(&agent) > 1.05);
    }

    #[test]
    fn fractional_prefill_group_bills_idle_residual() {
        // Demand sized to exactly 1.5 prefill groups: two groups are
        // provisioned, but the second is busy only half the time — its
        // idle half must bill idle watts, not near-saturation watts.
        let trace = azure_conversations();
        let profile = Arc::new(ManualProfile::h100_70b());
        let acct = PowerAccounting::PerGpu;
        let rho = 0.85;
        // Reproduce the sizing formula to pick λ for 1.5 groups exactly.
        let r = profile.roofline();
        let chunk = 8192.0;
        let iter_ms = r.tau_ms(1.0, chunk / 2.0) + r.w_ms * (chunk / 1024.0 - 1.0);
        let group_prefill_tok_s = chunk / iter_ms * 1e3;
        let lambda =
            1.5 * rho * group_prefill_tok_s / trace.prompt_cdf.mean();
        let rep = disaggregate(
            &trace,
            lambda,
            profile.clone(),
            &Topology::FleetOpt { b_short: trace.paper_b_short,
                                  short_ctx: trace.paper_b_short.max(2048),
                                  gamma: 2.0 },
            LBarPolicy::Window,
            rho,
            0.5,
            acct,
        );
        assert_eq!(rep.prefill_groups, 2, "1.5 groups of demand → ceil = 2");
        let hot = profile.group_power_w(128.0, acct);
        let idle = profile.group_power_w(0.0, acct);
        let expected = 1.5 * hot + 0.5 * idle;
        assert!(
            (rep.prefill_power_w - expected).abs() < 1e-6 * expected,
            "got {} W, want {expected} W",
            rep.prefill_power_w
        );
        // Strictly cheaper than the old both-groups-hot billing, dearer
        // than pretending the half-idle group doesn't exist.
        assert!(rep.prefill_power_w < 2.0 * hot);
        assert!(rep.prefill_power_w > 1.5 * hot);
    }
}
