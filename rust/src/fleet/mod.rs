//! The fleet planner — this crate's port of the paper's
//! `inference-fleet-sim` ([Chen et al., 2026b], Appendix B): pools,
//! routing topologies, SLO-constrained sizing, and the fleet-level tok/W
//! aggregation of Eq. (4).

pub mod adaptive;
pub mod analysis;
pub mod carbon;
pub mod disagg;
pub mod optimizer;
pub mod pool;
pub mod profile;
pub mod topology;

pub use analysis::{fleet_tpw_analysis, FleetReport, PoolReport};
pub use pool::{LBarPolicy, PoolPlan};
pub use profile::{ComputedProfile, GpuProfile, ManualProfile, PowerAccounting};
pub use topology::Topology;
