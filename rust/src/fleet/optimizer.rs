//! Topology optimization — the *legacy* closed-form API: sweep the
//! split boundary `B_short` and the FleetOpt overflow/compression
//! factor γ to maximize fleet tok/W (the γ* search of
//! [Chen et al. 2026a]), plus the §10.3 "multi-pool" extension (K ≥ 3
//! context-tiered pools).
//!
//! Since the scenario-native optimizer landed
//! ([`crate::scenario::optimize`], `wattlaw optimize`), this module is
//! a thin wrapper kept for source compatibility: [`sweep_fleetopt`]
//! delegates to the new search's stage-A screen
//! ([`screen_closed_form`](crate::scenario::optimize::screen_closed_form))
//! over the same grids, and [`multi_pool`] delegates to the K-pool
//! [`Topology::Partition`] pool plans — so every path ranks by
//! identical arithmetic. Neither legacy entry point validates its
//! winner dynamically. Prefer the two-stage search, which screens
//! partition vectors for any K, replays the analytical top-k through
//! the event-driven simulator, and refuses SLO-violating winners.

use std::sync::Arc;

use super::analysis::{fleet_tpw_analysis, FleetReport};
use super::pool::LBarPolicy;
use super::profile::{GpuProfile, PowerAccounting};
#[cfg(test)]
use super::topology::LONG_CTX;
use super::topology::Topology;
use crate::workload::WorkloadTrace;

/// Result of a (B_short, γ) sweep.
#[derive(Debug, Clone)]
pub struct OptResult {
    pub b_short: u32,
    pub gamma: f64,
    pub report: FleetReport,
}

/// Default sweep grids (powers of two around the paper's operating
/// points). Also the default axes of the scenario-native optimizer.
pub const B_SHORT_GRID: [u32; 6] = [1024, 1536, 2048, 4096, 8192, 16384];
pub const GAMMA_GRID: [f64; 5] = [1.0, 1.5, 2.0, 3.0, 4.0];

/// Exhaustive closed-form sweep; returns every evaluated point sorted
/// best-first. Thin wrapper over the scenario optimizer's stage-A
/// screen on the legacy grids.
pub fn sweep_fleetopt(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<OptResult> {
    crate::scenario::optimize::screen_closed_form(
        trace,
        lambda_rps,
        profile,
        &B_SHORT_GRID,
        &GAMMA_GRID,
        lbar,
        rho,
        ttft_slo_s,
        acct,
    )
}

/// The optimal (B_short, γ*) point.
pub fn optimize_fleetopt(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> OptResult {
    sweep_fleetopt(trace, lambda_rps, profile, lbar, rho, ttft_slo_s, acct)
        .into_iter()
        .next()
        .expect("non-empty sweep")
}

/// §10.3 extension: K context-tiered pools at power-of-two boundaries,
/// e.g. K=3 → windows {4K, 16K, 64K}. Returns the fleet report.
///
/// Since the K-pool [`Topology::Partition`] landed as a first-class
/// scenario axis, this is a thin wrapper over its pool plans (the
/// K-pool Eq. 4 path behind `ScenarioSpec::analyze` and the
/// partition-native optimizer screen) — `tests/optimize_oracle.rs`
/// pins the agreement. Windows are deduplicated and floored at 1024
/// tokens (the FleetOpt `short_ctx` convention); every grid this
/// function has ever been called with is unaffected.
pub fn multi_pool(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    windows: &[u32],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> FleetReport {
    assert!(!windows.is_empty());
    let pools = Topology::partition(windows).pools(
        trace, lambda_rps, profile, None, lbar, rho, ttft_slo_s,
    );
    fleet_tpw_analysis(&pools, acct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::workload::cdf::azure_conversations;

    fn h100() -> Arc<dyn GpuProfile> {
        Arc::new(ManualProfile::h100_70b())
    }

    #[test]
    fn optimum_beats_all_sweep_points() {
        let t = azure_conversations();
        let all = sweep_fleetopt(&t, 1000.0, h100(), LBarPolicy::Window,
                                 0.85, 0.5, PowerAccounting::PerGpu);
        let best = &all[0];
        for r in &all[1..] {
            assert!(best.report.tok_per_watt.0 >= r.report.tok_per_watt.0);
        }
    }

    #[test]
    fn optimal_gamma_is_above_one_for_azure() {
        // Compression always helps the long pool in this model (quality
        // constraints are outside the energy objective), so γ* should sit
        // at the top of the grid or at least above 1.
        let t = azure_conversations();
        let best = optimize_fleetopt(&t, 1000.0, h100(), LBarPolicy::Window,
                                     0.85, 0.5, PowerAccounting::PerGpu);
        assert!(best.gamma > 1.0, "γ* = {}", best.gamma);
    }

    #[test]
    fn three_tier_beats_two_tier_on_dispersed_traffic() {
        // §10.3: finer topologies compound on dispersed workloads.
        let t = crate::workload::cdf::agent_heavy();
        let two = multi_pool(&t, 1000.0, h100(), &[8192, LONG_CTX],
                             LBarPolicy::Window, 0.85, 0.5,
                             PowerAccounting::PerGpu);
        let three = multi_pool(&t, 1000.0, h100(), &[4096, 16384, LONG_CTX],
                               LBarPolicy::Window, 0.85, 0.5,
                               PowerAccounting::PerGpu);
        assert!(
            three.tok_per_watt.0 > two.tok_per_watt.0,
            "3-tier {} vs 2-tier {}",
            three.tok_per_watt.0,
            two.tok_per_watt.0
        );
    }

    #[test]
    fn multi_pool_conserves_traffic() {
        let t = azure_conversations();
        let r = multi_pool(&t, 1000.0, h100(), &[4096, 16384, LONG_CTX],
                           LBarPolicy::Window, 0.85, 0.5,
                           PowerAccounting::PerGpu);
        let sum: f64 = r.pools.iter().map(|p| p.lambda_rps).sum();
        assert!((sum - 1000.0).abs() < 1e-6);
    }
}
