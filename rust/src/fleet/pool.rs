//! Pool plans: one serving pool = a GPU/model binding (profile) plus a
//! context-window configuration and the slice of traffic routed to it.

use std::sync::Arc;

use super::profile::GpuProfile;
use crate::queueing::sizing::SizingInputs;
use crate::workload::WorkloadTrace;

/// How the mean KV length L̄ fed into the roofline is chosen.
///
/// * `Window` — L̄ equals the pool's serving context window. Conservative
///   full-occupancy bound; verifiably what the paper's Tables 1 and 4 use,
///   and the default for all headline tables.
/// * `TrafficMean` — L̄ is the conditional mean total length of the
///   traffic routed to the pool (prompt + half the output, the mean KV
///   footprint over a request's decode lifetime). More optimistic;
///   exposed as an ablation (`--lbar traffic`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LBarPolicy {
    #[default]
    Window,
    TrafficMean,
}

/// One pool, fully specified for sizing and Eq. (4) accounting.
#[derive(Clone)]
pub struct PoolPlan {
    pub name: String,
    pub profile: Arc<dyn GpuProfile>,
    pub inputs: SizingInputs,
}

impl std::fmt::Debug for PoolPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolPlan")
            .field("name", &self.name)
            .field("profile", &self.profile.label())
            .field("inputs", &self.inputs)
            .finish()
    }
}

impl PoolPlan {
    /// Build a pool serving the trace's requests with prompt length in
    /// `(lo, hi]` at total fleet arrival rate `lambda_rps`.
    ///
    /// `effective_ctx` is the window the pool is *configured* for (after
    /// any FleetOpt compression), `compression` the FleetOpt γ applied to
    /// this pool's KV (1.0 = none).
    #[allow(clippy::too_many_arguments)]
    pub fn for_slice(
        name: impl Into<String>,
        profile: Arc<dyn GpuProfile>,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        lo: f64,
        hi: f64,
        effective_ctx: u32,
        compression: f64,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
    ) -> Self {
        let frac = trace.prompt_cdf.frac_leq(hi) - trace.prompt_cdf.frac_leq(lo);
        let mean_prompt = if frac > 1e-9 {
            trace.prompt_cdf.conditional_mean(lo, hi)
        } else {
            0.0
        };
        let l_bar = match lbar {
            LBarPolicy::Window => effective_ctx as f64,
            LBarPolicy::TrafficMean => {
                // Mean KV footprint over decode: prompt + output/2, then
                // FleetOpt compression, clamped into the window.
                ((mean_prompt + trace.mean_output_tokens / 2.0) / compression)
                    .min(effective_ctx as f64)
                    .max(1.0)
            }
        };
        PoolPlan {
            name: name.into(),
            profile,
            inputs: SizingInputs {
                lambda_rps: lambda_rps * frac,
                mean_output_tokens: trace.mean_output_tokens,
                mean_prompt_tokens: (mean_prompt / compression).max(1.0),
                context_tokens: effective_ctx,
                l_bar,
                rho,
                ttft_slo_s,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::workload::cdf::azure_conversations;

    fn h100() -> Arc<dyn GpuProfile> {
        Arc::new(ManualProfile::h100_70b())
    }

    #[test]
    fn slice_traffic_fractions_sum_to_total() {
        let t = azure_conversations();
        let short = PoolPlan::for_slice(
            "short", h100(), &t, 1000.0, 0.0, 4096.0, 4096, 1.0,
            LBarPolicy::Window, 0.85, 0.5);
        let long = PoolPlan::for_slice(
            "long", h100(), &t, 1000.0, 4096.0, f64::INFINITY, 65_536, 1.0,
            LBarPolicy::Window, 0.85, 0.5);
        let sum = short.inputs.lambda_rps + long.inputs.lambda_rps;
        assert!((sum - 1000.0).abs() < 1e-6, "λ split sums to λ: {sum}");
        assert!((short.inputs.lambda_rps - 890.0).abs() < 5.0, "89% short");
    }

    #[test]
    fn window_policy_uses_window() {
        let t = azure_conversations();
        let p = PoolPlan::for_slice(
            "x", h100(), &t, 100.0, 0.0, 4096.0, 4096, 1.0,
            LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(p.inputs.l_bar, 4096.0);
    }

    #[test]
    fn traffic_mean_policy_is_below_window_for_short_slices() {
        let t = azure_conversations();
        let p = PoolPlan::for_slice(
            "x", h100(), &t, 100.0, 0.0, 4096.0, 4096, 1.0,
            LBarPolicy::TrafficMean, 0.85, 0.5);
        assert!(p.inputs.l_bar < 4096.0);
        assert!(p.inputs.l_bar > 100.0);
    }

    #[test]
    fn compression_shrinks_lbar_and_prompt() {
        let t = azure_conversations();
        let raw = PoolPlan::for_slice(
            "x", h100(), &t, 100.0, 4096.0, f64::INFINITY, 65_536, 1.0,
            LBarPolicy::TrafficMean, 0.85, 0.5);
        let comp = PoolPlan::for_slice(
            "x", h100(), &t, 100.0, 4096.0, f64::INFINITY, 32_768, 2.0,
            LBarPolicy::TrafficMean, 0.85, 0.5);
        assert!(comp.inputs.l_bar < raw.inputs.l_bar);
        assert!(comp.inputs.mean_prompt_tokens < raw.inputs.mean_prompt_tokens);
    }
}
