//! `GpuProfile` — the paper's Appendix-B protocol: "the API accepts any
//! object satisfying the GpuProfile protocol (ManualProfile or
//! ComputedProfile), which is what makes it straightforward to compare the
//! measured H100 profile against B200 or GB200 projections on equal
//! footing."
//!
//! * [`ManualProfile`] — empirically calibrated numbers (the paper's HIGH
//!   quality H100 fleet profile: κ=55 KB/tok TP-sharded incl. overhead,
//!   n_max=128 @8K, W=6.72 ms, H0=0.1387 ms), plus proportional scalings
//!   of it (the B200 fleet profile = H100 × 2.62 KV budget).
//! * [`ComputedProfile`] — first-principles from the GPU + model catalogs
//!   (the paper's Tables 2 and 5 convention: replicated KV).

use crate::model::spec::{ModelSpec, Precision};
use crate::model::{kappa_bytes_per_token, kv_budget_bytes, KvPlacement};
use crate::power::profiles::{B200, H100};
use crate::power::{GpuSpec, Quality};
use crate::roofline::Roofline;

/// Power-accounting convention for tok/W denominators.
///
/// The paper consistently divides TP-group throughput by a *single GPU's*
/// power (verified against Tables 1/3/4: e.g. 64K → 653 tok/s ÷ 435 W =
/// 1.50 tok/W; Table 3's 58.3 kW ÷ 141 "GPUs" = 413 W = P(14)). `PerGpu`
/// reproduces that convention; `PerGroup` charges all TP ranks and is the
/// physically complete bill (documented deviation — DESIGN.md §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PowerAccounting {
    #[default]
    PerGpu,
    PerGroup,
}

/// The protocol every profile satisfies (paper Appendix B).
pub trait GpuProfile: Send + Sync {
    /// Human-readable binding, e.g. `"Llama-3.1-70B @ H100-SXM5 TP8"`.
    fn label(&self) -> String;

    /// The GPU SKU (power curve, quality tag, cost).
    fn gpu(&self) -> &'static GpuSpec;

    /// Tensor-parallel group size.
    fn tp(&self) -> u32;

    /// Eq. (3) concurrency limit at a serving context window.
    fn n_max(&self, context_tokens: u32) -> u32;

    /// The decode roofline (W, H0).
    fn roofline(&self) -> Roofline;

    /// Logistic power at mean in-flight batch `n_active`, per GPU, watts.
    fn power_w(&self, n_active: f64) -> f64 {
        self.gpu().power.power_w(n_active)
    }

    /// Power denominator per TP group under `acct`, watts.
    fn group_power_w(&self, n_active: f64, acct: PowerAccounting) -> f64 {
        match acct {
            PowerAccounting::PerGpu => self.power_w(n_active),
            PowerAccounting::PerGroup => self.power_w(n_active) * self.tp() as f64,
        }
    }

    fn quality(&self) -> Quality {
        self.gpu().quality
    }
}

/// Empirically calibrated profile: explicit (W, H0, n_max@calib).
#[derive(Debug, Clone)]
pub struct ManualProfile {
    pub name: String,
    pub gpu: &'static GpuSpec,
    pub tp: u32,
    pub roofline: Roofline,
    /// Calibrated concurrency limit at `ctx_calib`.
    pub n_max_calib: f64,
    pub ctx_calib: u32,
}

impl ManualProfile {
    /// The paper's HIGH-quality H100 fleet profile for Llama-3.1-70B TP=8:
    /// κ≈55 KB/tok (TP-sharded, incl. allocator overhead), 60 GB KV budget
    /// → n_max = 128 @8K; W = 6.72 ms; H0 = 0.1387 ms. Closes Table 1.
    pub fn h100_70b() -> Self {
        ManualProfile {
            name: "Llama-3.1-70B @ H100-SXM5 TP8 (calibrated)".into(),
            gpu: &H100,
            tp: 8,
            roofline: Roofline::manual(6.72, 0.1387),
            n_max_calib: 128.0,
            ctx_calib: 8192,
        }
    }

    /// The paper's FAIR B200 fleet profile: H100 scaled by the 2.62× KV
    /// budget ratio; W = 2.95 ms; H0 from the Table 1 B200 column.
    pub fn b200_70b() -> Self {
        ManualProfile {
            name: "Llama-3.1-70B @ B200-SXM TP8 (projected)".into(),
            gpu: &B200,
            tp: 8,
            roofline: Roofline::manual(2.95, 0.0670),
            n_max_calib: 128.0 * 2.62,
            ctx_calib: 8192,
        }
    }

    /// H200 fleet profile, scaled like B200: KV budget ratio
    /// (141·0.969 − 17.5)/60.1 ≈ 1.98; W = 6.72·(3.35/4.8) ≈ 4.69 ms;
    /// H0 scales with the same bandwidth ratio.
    pub fn h200_70b() -> Self {
        use crate::power::profiles::H200;
        let bw_ratio = 3.35 / 4.8;
        ManualProfile {
            name: "Llama-3.1-70B @ H200-SXM TP8 (projected)".into(),
            gpu: &H200,
            tp: 8,
            roofline: Roofline::manual(6.72 * bw_ratio, 0.1387 * bw_ratio),
            n_max_calib: 128.0 * 1.98,
            ctx_calib: 8192,
        }
    }

    /// GB200 fleet profile: B200 silicon (same W/H0) with the larger
    /// 200 GB memory → KV ratio ≈ 2.94, but a 1200 W TDP power curve.
    pub fn gb200_70b() -> Self {
        use crate::power::profiles::GB200;
        ManualProfile {
            name: "Llama-3.1-70B @ GB200-NVL TP8 (projected)".into(),
            gpu: &GB200,
            tp: 8,
            roofline: Roofline::manual(2.95, 0.0670),
            n_max_calib: 128.0 * 2.94,
            ctx_calib: 8192,
        }
    }

    /// Fleet profile catalog by GPU generation.
    pub fn for_gpu(gpu: crate::power::Gpu) -> Self {
        use crate::power::Gpu;
        match gpu {
            Gpu::H100 => Self::h100_70b(),
            Gpu::H200 => Self::h200_70b(),
            Gpu::B200 => Self::b200_70b(),
            Gpu::GB200 => Self::gb200_70b(),
        }
    }
}

impl GpuProfile for ManualProfile {
    fn label(&self) -> String {
        self.name.clone()
    }
    fn gpu(&self) -> &'static GpuSpec {
        self.gpu
    }
    fn tp(&self) -> u32 {
        self.tp
    }
    fn n_max(&self, context_tokens: u32) -> u32 {
        // n_max ∝ 1/W with the calibrated anchor (Eq. 3 in ratio form).
        let n = self.n_max_calib * self.ctx_calib as f64 / context_tokens as f64;
        (n.floor() as u32).max(1)
    }
    fn roofline(&self) -> Roofline {
        self.roofline
    }
}

/// First-principles profile from the catalogs (paper's ComputedProfile).
#[derive(Debug, Clone)]
pub struct ComputedProfile {
    pub gpu: &'static GpuSpec,
    pub model: &'static ModelSpec,
    pub precision: Precision,
    pub tp: u32,
    pub placement: KvPlacement,
    /// Optional MoE dispatch overhead, ms (0 = the paper's upper bound).
    pub dispatch_ms: f64,
}

impl ComputedProfile {
    pub fn new(
        gpu: &'static GpuSpec,
        model: &'static ModelSpec,
        tp: u32,
        placement: KvPlacement,
    ) -> Self {
        ComputedProfile {
            gpu,
            model,
            precision: model.default_precision,
            tp,
            placement,
            dispatch_ms: 0.0,
        }
    }

    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn with_dispatch_ms(mut self, d: f64) -> Self {
        self.dispatch_ms = d;
        self
    }

    pub fn kappa(&self) -> f64 {
        kappa_bytes_per_token(self.model, self.placement, self.tp)
    }

    pub fn kv_budget(&self) -> f64 {
        kv_budget_bytes(self.gpu, self.model, self.precision, self.tp)
    }

    /// Whether the model's weights fit at all (405B/H100 fails).
    pub fn weights_fit(&self) -> bool {
        self.model.weight_bytes_per_gpu(self.precision, self.tp)
            <= self.gpu.vram_usable().0 as f64
    }
}

impl GpuProfile for ComputedProfile {
    fn label(&self) -> String {
        format!(
            "{} @ {} TP{} {}",
            self.model.name,
            self.gpu.name,
            self.tp,
            self.precision.label()
        )
    }
    fn gpu(&self) -> &'static GpuSpec {
        self.gpu
    }
    fn tp(&self) -> u32 {
        self.tp
    }
    fn n_max(&self, context_tokens: u32) -> u32 {
        crate::model::n_max(self.kv_budget(), self.kappa(), context_tokens)
    }
    fn roofline(&self) -> Roofline {
        Roofline::from_specs(
            self.gpu,
            self.model,
            self.precision,
            self.tp,
            self.placement,
        )
        .with_dispatch_ms(self.dispatch_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{LLAMA31_405B, LLAMA31_70B, LLAMA31_8B};

    #[test]
    fn manual_h100_reproduces_table1_nmax_column() {
        let p = ManualProfile::h100_70b();
        for (ctx, want) in [
            (2048u32, 512u32),
            (4096, 256),
            (8192, 128),
            (16384, 64),
            (32768, 32),
            (65536, 16),
            (131072, 8),
        ] {
            assert_eq!(p.n_max(ctx), want, "ctx = {ctx}");
        }
    }

    #[test]
    fn manual_b200_reproduces_table1_nmax_column() {
        let p = ManualProfile::b200_70b();
        for (ctx, want_lo, want_hi) in [
            (2048u32, 1337u32, 1343u32),
            (4096, 668, 671),
            (8192, 334, 336),
            (16384, 166, 168),
            (32768, 83, 84),
            (65536, 41, 42),
            (131072, 20, 21),
        ] {
            let n = p.n_max(ctx);
            assert!(
                (want_lo..=want_hi).contains(&n),
                "ctx {ctx}: n_max = {n}, want [{want_lo}, {want_hi}]"
            );
        }
    }

    #[test]
    fn computed_profile_labels_and_fit() {
        let p = ComputedProfile::new(&H100, &LLAMA31_70B, 8, KvPlacement::Replicated);
        assert!(p.label().contains("70B") && p.label().contains("H100"));
        assert!(p.weights_fit());
        let p405 =
            ComputedProfile::new(&H100, &LLAMA31_405B, 8, KvPlacement::Replicated);
        assert!(!p405.weights_fit(), "405B fp16 TP8 does not fit on H100");
        assert_eq!(p405.n_max(8192), 1);
    }

    #[test]
    fn per_group_power_is_tp_times_per_gpu() {
        let p = ManualProfile::h100_70b();
        let g = p.group_power_w(14.0, PowerAccounting::PerGroup);
        let s = p.group_power_w(14.0, PowerAccounting::PerGpu);
        assert!((g / s - 8.0).abs() < 1e-12);
    }

    #[test]
    fn computed_8b_tp1_matches_table2_nmax() {
        let p = ComputedProfile::new(&H100, &LLAMA31_8B, 1, KvPlacement::Replicated);
        let n = p.n_max(8192);
        assert!((57..=58).contains(&n), "n_max = {n}");
    }
}
