//! `GpuProfile` — the paper's Appendix-B protocol: "the API accepts any
//! object satisfying the GpuProfile protocol (ManualProfile or
//! ComputedProfile), which is what makes it straightforward to compare the
//! measured H100 profile against B200 or GB200 projections on equal
//! footing."
//!
//! * [`ManualProfile`] — empirically calibrated numbers (the paper's HIGH
//!   quality H100 fleet profile: κ=55 KB/tok TP-sharded incl. overhead,
//!   n_max=128 @8K, W=6.72 ms, H0=0.1387 ms), plus proportional scalings
//!   of it (the B200 fleet profile = H100 × 2.62 KV budget).
//! * [`ComputedProfile`] — first-principles from the GPU + model catalogs
//!   (the paper's Tables 2 and 5 convention: replicated KV).

use crate::model::spec::{ModelSpec, Precision};
use crate::model::{kappa_bytes_per_token, kv_budget_bytes, KvPlacement};
use crate::power::profiles::{B200, H100};
use crate::power::{Gpu, GpuSpec, Quality};
use crate::roofline::speculative::SpecConfig;
use crate::roofline::Roofline;

/// Power-accounting convention for tok/W denominators.
///
/// The paper consistently divides TP-group throughput by a *single GPU's*
/// power (verified against Tables 1/3/4: e.g. 64K → 653 tok/s ÷ 435 W =
/// 1.50 tok/W; Table 3's 58.3 kW ÷ 141 "GPUs" = 413 W = P(14)). `PerGpu`
/// reproduces that convention; `PerGroup` charges all TP ranks and is the
/// physically complete bill (documented deviation — DESIGN.md §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PowerAccounting {
    #[default]
    PerGpu,
    PerGroup,
}

/// The protocol every profile satisfies (paper Appendix B).
pub trait GpuProfile: Send + Sync {
    /// Human-readable binding, e.g. `"Llama-3.1-70B @ H100-SXM5 TP8"`.
    fn label(&self) -> String;

    /// The GPU SKU (power curve, quality tag, cost).
    fn gpu(&self) -> &'static GpuSpec;

    /// Tensor-parallel group size.
    fn tp(&self) -> u32;

    /// Eq. (3) concurrency limit at a serving context window.
    fn n_max(&self, context_tokens: u32) -> u32;

    /// The decode roofline (W, H0).
    fn roofline(&self) -> Roofline;

    /// Logistic power at mean in-flight batch `n_active`, per GPU, watts.
    fn power_w(&self, n_active: f64) -> f64 {
        self.gpu().power.power_w(n_active)
    }

    /// Power denominator per TP group under `acct`, watts.
    fn group_power_w(&self, n_active: f64, acct: PowerAccounting) -> f64 {
        match acct {
            PowerAccounting::PerGpu => self.power_w(n_active),
            PowerAccounting::PerGroup => self.power_w(n_active) * self.tp() as f64,
        }
    }

    fn quality(&self) -> Quality {
        self.gpu().quality
    }
}

/// Empirically calibrated profile: explicit (W, H0, n_max@calib).
#[derive(Debug, Clone)]
pub struct ManualProfile {
    pub name: String,
    pub gpu: &'static GpuSpec,
    pub tp: u32,
    pub roofline: Roofline,
    /// Calibrated concurrency limit at `ctx_calib`.
    pub n_max_calib: f64,
    pub ctx_calib: u32,
}

impl ManualProfile {
    /// The paper's HIGH-quality H100 fleet profile for Llama-3.1-70B TP=8:
    /// κ≈55 KB/tok (TP-sharded, incl. allocator overhead), 60 GB KV budget
    /// → n_max = 128 @8K; W = 6.72 ms; H0 = 0.1387 ms. Closes Table 1.
    pub fn h100_70b() -> Self {
        ManualProfile {
            name: "Llama-3.1-70B @ H100-SXM5 TP8 (calibrated)".into(),
            gpu: &H100,
            tp: 8,
            roofline: Roofline::manual(6.72, 0.1387),
            n_max_calib: 128.0,
            ctx_calib: 8192,
        }
    }

    /// The paper's FAIR B200 fleet profile: H100 scaled by the 2.62× KV
    /// budget ratio; W = 2.95 ms; H0 from the Table 1 B200 column.
    pub fn b200_70b() -> Self {
        ManualProfile {
            name: "Llama-3.1-70B @ B200-SXM TP8 (projected)".into(),
            gpu: &B200,
            tp: 8,
            roofline: Roofline::manual(2.95, 0.0670),
            n_max_calib: 128.0 * 2.62,
            ctx_calib: 8192,
        }
    }

    /// H200 fleet profile, scaled like B200: KV budget ratio
    /// (141·0.969 − 17.5)/60.1 ≈ 1.98; W = 6.72·(3.35/4.8) ≈ 4.69 ms;
    /// H0 scales with the same bandwidth ratio.
    pub fn h200_70b() -> Self {
        use crate::power::profiles::H200;
        let bw_ratio = 3.35 / 4.8;
        ManualProfile {
            name: "Llama-3.1-70B @ H200-SXM TP8 (projected)".into(),
            gpu: &H200,
            tp: 8,
            roofline: Roofline::manual(6.72 * bw_ratio, 0.1387 * bw_ratio),
            n_max_calib: 128.0 * 1.98,
            ctx_calib: 8192,
        }
    }

    /// GB200 fleet profile: B200 silicon (same W/H0) with the larger
    /// 200 GB memory → KV ratio ≈ 2.94, but a 1200 W TDP power curve.
    pub fn gb200_70b() -> Self {
        use crate::power::profiles::GB200;
        ManualProfile {
            name: "Llama-3.1-70B @ GB200-NVL TP8 (projected)".into(),
            gpu: &GB200,
            tp: 8,
            roofline: Roofline::manual(2.95, 0.0670),
            n_max_calib: 128.0 * 2.94,
            ctx_calib: 8192,
        }
    }

    /// Fleet profile catalog by GPU generation.
    pub fn for_gpu(gpu: crate::power::Gpu) -> Self {
        use crate::power::Gpu;
        match gpu {
            Gpu::H100 => Self::h100_70b(),
            Gpu::H200 => Self::h200_70b(),
            Gpu::B200 => Self::b200_70b(),
            Gpu::GB200 => Self::gb200_70b(),
        }
    }

    /// Qwen3-235B-A22B weight-streaming fleet profile (paper §3.2, Table
    /// 2 row 4): decode time scales with the 22B *active* experts, not
    /// the 235B total. Calibrated on H100 as W = 1.056 ms (fp8 active
    /// expert read, 2.75 GB, at the dense calibration's effective
    /// bandwidth), H0 = 0.0380 ms (GQA-4 over 94 layers with fp8 KV —
    /// the pure byte-ratio projection is 0.0408; measured ≈7% under it,
    /// the same measured-beats-derived convention as `h100_70b`) and
    /// n_max = 384 @8K (fp8 KV ≈ one third the dense κ on the
    /// post-weights HBM budget). Other generations scale by the same
    /// ratios off their dense calibrations, exactly as `b200_70b`
    /// scales off `h100_70b`. `dispatch_ms` is the §3.2 expert-dispatch
    /// overhead the paper's headline numbers exclude (its upper bound,
    /// 0 ms, is the default).
    pub fn qwen3_moe(gpu: Gpu, dispatch_ms: f64) -> Self {
        const W_RATIO: f64 = 1.056 / 6.72;
        const H0_RATIO: f64 = 0.0380 / 0.1387;
        const NMAX_RATIO: f64 = 3.0;
        let d = Self::for_gpu(gpu);
        ManualProfile {
            name: d.name.replace("Llama-3.1-70B", "Qwen3-235B-A22B"),
            roofline: Roofline::manual(
                d.roofline.w_ms * W_RATIO,
                d.roofline.h0_ms * H0_RATIO,
            )
            .with_dispatch_ms(dispatch_ms),
            n_max_calib: d.n_max_calib * NMAX_RATIO,
            ..d
        }
    }

    /// Dense Llama-70B with speculative decode folded into the
    /// roofline: the draft+verify iteration cost divided by the
    /// expected tokens accepted per iteration
    /// ([`SpecConfig::effective_roofline`]), so both engines consume
    /// the speedup through the same τ(n, L̄) path as every other
    /// profile. The draft weight read is W/70 (a ~1B-class drafter,
    /// the convention in `roofline::speculative`'s tests); KV capacity
    /// (n_max) is the target model's — draft KV is negligible at that
    /// scale. Power is billed on the target curve P(n), a documented
    /// approximation of `spec_point`'s time-weighted draft/verify
    /// split.
    pub fn speculative(gpu: Gpu, k: u32, alpha: f64) -> Self {
        let d = Self::for_gpu(gpu);
        let spec = SpecConfig {
            k,
            alpha,
            draft_w_ms: d.roofline.w_ms / 70.0,
            draft_power_scale: 0.8,
        };
        ManualProfile {
            name: format!("{} +spec(k={k}, a={alpha})", d.name),
            roofline: spec.effective_roofline(&d.roofline),
            ..d
        }
    }
}

/// The model-architecture axis of a scenario — the third lever next to
/// routing topology and GPU generation (ROADMAP item 3). Resolved to a
/// [`ManualProfile`] at the same single point as the per-pool GPU
/// override, so both engines (the Eq. 4 planner and the event
/// simulator) consume identical rooflines.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ModelAxis {
    /// Dense Llama-3.1-70B — the pre-axis behavior, bit-for-bit.
    #[default]
    Dense,
    /// Qwen3-235B-A22B MoE weight streaming; `dispatch_ms` is the §3.2
    /// expert-dispatch overhead (0 = the paper's excluded-overhead
    /// upper bound).
    MoeStreaming { dispatch_ms: f64 },
    /// Dense + speculative decode (k draft tokens, per-token acceptance
    /// rate α).
    Speculative { k: u32, alpha: f64 },
}

impl ModelAxis {
    /// Accepted `--model` names, for error messages.
    pub const NAMES: &'static str = "llama70b|qwen3-moe|llama70b+spec";

    /// Default speculative-decode configuration (`--model llama70b+spec`).
    pub const SPEC_K: u32 = 4;
    pub const SPEC_ALPHA: f64 = 0.8;

    /// Parse a CLI `--model` name. `llama70b` (alias `dense`) is the
    /// dense baseline; `qwen3-moe` (aliases `qwen3`, `moe`) streams
    /// expert weights with zero dispatch overhead until `--dispatch-ms`
    /// says otherwise; `llama70b+spec` (aliases `dense+spec`, `spec`)
    /// is dense + speculative decode at (k=4, α=0.8).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "llama70b" | "dense" => Ok(ModelAxis::Dense),
            "qwen3-moe" | "qwen3" | "moe" => {
                Ok(ModelAxis::MoeStreaming { dispatch_ms: 0.0 })
            }
            "llama70b+spec" | "dense+spec" | "spec" => {
                Ok(ModelAxis::Speculative {
                    k: Self::SPEC_K,
                    alpha: Self::SPEC_ALPHA,
                })
            }
            other => {
                Err(format!("unknown model '{other}' ({})", Self::NAMES))
            }
        }
    }

    /// Short label for rowset columns and scenario headers.
    pub fn label(&self) -> &'static str {
        match self {
            ModelAxis::Dense => "dense",
            ModelAxis::MoeStreaming { .. } => "qwen3-moe",
            ModelAxis::Speculative { .. } => "dense+spec",
        }
    }

    /// Override the MoE dispatch overhead; no-op on the other variants
    /// (the CLI rejects `--dispatch-ms` without `--model qwen3-moe`
    /// before this runs).
    pub fn with_dispatch_ms(self, d: f64) -> Self {
        match self {
            ModelAxis::MoeStreaming { .. } => {
                ModelAxis::MoeStreaming { dispatch_ms: d }
            }
            other => other,
        }
    }

    /// The MoE dispatch overhead, if this axis carries one.
    pub fn dispatch_ms(&self) -> Option<f64> {
        match self {
            ModelAxis::MoeStreaming { dispatch_ms } => Some(*dispatch_ms),
            _ => None,
        }
    }

    /// Resolve (model, generation) to the fleet profile both engines
    /// consume. `Dense` delegates to [`ManualProfile::for_gpu`]
    /// unchanged — the dense default is the pre-axis code path,
    /// bit-for-bit.
    pub fn profile_for(&self, gpu: Gpu) -> ManualProfile {
        match self {
            ModelAxis::Dense => ManualProfile::for_gpu(gpu),
            ModelAxis::MoeStreaming { dispatch_ms } => {
                ManualProfile::qwen3_moe(gpu, *dispatch_ms)
            }
            ModelAxis::Speculative { k, alpha } => {
                ManualProfile::speculative(gpu, *k, *alpha)
            }
        }
    }
}

impl GpuProfile for ManualProfile {
    fn label(&self) -> String {
        self.name.clone()
    }
    fn gpu(&self) -> &'static GpuSpec {
        self.gpu
    }
    fn tp(&self) -> u32 {
        self.tp
    }
    fn n_max(&self, context_tokens: u32) -> u32 {
        // n_max ∝ 1/W with the calibrated anchor (Eq. 3 in ratio form).
        let n = self.n_max_calib * self.ctx_calib as f64 / context_tokens as f64;
        (n.floor() as u32).max(1)
    }
    fn roofline(&self) -> Roofline {
        self.roofline
    }
}

/// First-principles profile from the catalogs (paper's ComputedProfile).
#[derive(Debug, Clone)]
pub struct ComputedProfile {
    pub gpu: &'static GpuSpec,
    pub model: &'static ModelSpec,
    pub precision: Precision,
    pub tp: u32,
    pub placement: KvPlacement,
    /// Optional MoE dispatch overhead, ms (0 = the paper's upper bound).
    pub dispatch_ms: f64,
}

impl ComputedProfile {
    pub fn new(
        gpu: &'static GpuSpec,
        model: &'static ModelSpec,
        tp: u32,
        placement: KvPlacement,
    ) -> Self {
        ComputedProfile {
            gpu,
            model,
            precision: model.default_precision,
            tp,
            placement,
            dispatch_ms: 0.0,
        }
    }

    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn with_dispatch_ms(mut self, d: f64) -> Self {
        self.dispatch_ms = d;
        self
    }

    pub fn kappa(&self) -> f64 {
        kappa_bytes_per_token(self.model, self.placement, self.tp)
    }

    pub fn kv_budget(&self) -> f64 {
        kv_budget_bytes(self.gpu, self.model, self.precision, self.tp)
    }

    /// Whether the model's weights fit at all (405B/H100 fails).
    pub fn weights_fit(&self) -> bool {
        self.model.weight_bytes_per_gpu(self.precision, self.tp)
            <= self.gpu.vram_usable().0 as f64
    }
}

impl GpuProfile for ComputedProfile {
    fn label(&self) -> String {
        format!(
            "{} @ {} TP{} {}",
            self.model.name,
            self.gpu.name,
            self.tp,
            self.precision.label()
        )
    }
    fn gpu(&self) -> &'static GpuSpec {
        self.gpu
    }
    fn tp(&self) -> u32 {
        self.tp
    }
    fn n_max(&self, context_tokens: u32) -> u32 {
        crate::model::n_max(self.kv_budget(), self.kappa(), context_tokens)
    }
    fn roofline(&self) -> Roofline {
        Roofline::from_specs(
            self.gpu,
            self.model,
            self.precision,
            self.tp,
            self.placement,
        )
        .with_dispatch_ms(self.dispatch_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{LLAMA31_405B, LLAMA31_70B, LLAMA31_8B};

    #[test]
    fn manual_h100_reproduces_table1_nmax_column() {
        let p = ManualProfile::h100_70b();
        for (ctx, want) in [
            (2048u32, 512u32),
            (4096, 256),
            (8192, 128),
            (16384, 64),
            (32768, 32),
            (65536, 16),
            (131072, 8),
        ] {
            assert_eq!(p.n_max(ctx), want, "ctx = {ctx}");
        }
    }

    #[test]
    fn manual_b200_reproduces_table1_nmax_column() {
        let p = ManualProfile::b200_70b();
        for (ctx, want_lo, want_hi) in [
            (2048u32, 1337u32, 1343u32),
            (4096, 668, 671),
            (8192, 334, 336),
            (16384, 166, 168),
            (32768, 83, 84),
            (65536, 41, 42),
            (131072, 20, 21),
        ] {
            let n = p.n_max(ctx);
            assert!(
                (want_lo..=want_hi).contains(&n),
                "ctx {ctx}: n_max = {n}, want [{want_lo}, {want_hi}]"
            );
        }
    }

    #[test]
    fn computed_profile_labels_and_fit() {
        let p = ComputedProfile::new(&H100, &LLAMA31_70B, 8, KvPlacement::Replicated);
        assert!(p.label().contains("70B") && p.label().contains("H100"));
        assert!(p.weights_fit());
        let p405 =
            ComputedProfile::new(&H100, &LLAMA31_405B, 8, KvPlacement::Replicated);
        assert!(!p405.weights_fit(), "405B fp16 TP8 does not fit on H100");
        assert_eq!(p405.n_max(8192), 1);
    }

    #[test]
    fn per_group_power_is_tp_times_per_gpu() {
        let p = ManualProfile::h100_70b();
        let g = p.group_power_w(14.0, PowerAccounting::PerGroup);
        let s = p.group_power_w(14.0, PowerAccounting::PerGpu);
        assert!((g / s - 8.0).abs() < 1e-12);
    }

    #[test]
    fn computed_8b_tp1_matches_table2_nmax() {
        let p = ComputedProfile::new(&H100, &LLAMA31_8B, 1, KvPlacement::Replicated);
        let n = p.n_max(8192);
        assert!((57..=58).contains(&n), "n_max = {n}");
    }

    #[test]
    fn dense_axis_resolves_to_for_gpu_bit_for_bit() {
        for gpu in Gpu::ALL {
            let dense = ModelAxis::Dense.profile_for(gpu);
            let legacy = ManualProfile::for_gpu(gpu);
            assert_eq!(dense.name, legacy.name);
            assert_eq!(
                dense.roofline.w_ms.to_bits(),
                legacy.roofline.w_ms.to_bits()
            );
            assert_eq!(
                dense.roofline.h0_ms.to_bits(),
                legacy.roofline.h0_ms.to_bits()
            );
            assert_eq!(
                dense.roofline.dispatch_ms.to_bits(),
                legacy.roofline.dispatch_ms.to_bits()
            );
            assert_eq!(
                dense.n_max_calib.to_bits(),
                legacy.n_max_calib.to_bits()
            );
            assert_eq!(dense.ctx_calib, legacy.ctx_calib);
        }
    }

    #[test]
    fn moe_h100_reproduces_the_paper_headline_at_8k() {
        // The acceptance row behind Table 10: Qwen3-235B-A22B on H100
        // at 8K context lands ≳35 tok/W and ≥4.5× the dense baseline
        // (paper: 37.8 tok/W, 5.1×; ours closes within ~10% — see the
        // t2 note on the paper's MoE rows not closing under its own
        // roofline either).
        let op = |m: ModelAxis| {
            crate::tokeconomy::operating_point(
                &m.profile_for(Gpu::H100),
                8192,
                1.0,
                PowerAccounting::PerGpu,
            )
        };
        let moe = op(ModelAxis::MoeStreaming { dispatch_ms: 0.0 });
        let dense = op(ModelAxis::Dense);
        assert!(
            moe.tok_per_watt.0 > 35.0,
            "MoE tok/W = {:.2}",
            moe.tok_per_watt.0
        );
        assert!(
            moe.tok_per_watt.0 / dense.tok_per_watt.0 >= 4.5,
            "MoE/dense ratio = {:.2}",
            moe.tok_per_watt.0 / dense.tok_per_watt.0
        );
        // The calibration anchors themselves.
        assert_eq!(ModelAxis::default().profile_for(Gpu::H100).n_max(8192), 128);
        let moe_p = ManualProfile::qwen3_moe(Gpu::H100, 0.0);
        assert_eq!(moe_p.n_max(8192), 384);
        assert!(moe_p.name.contains("Qwen3-235B-A22B"));
    }

    #[test]
    fn moe_dispatch_ms_erodes_throughput_monotonically() {
        let tok_s = |d: f64| {
            let p = ManualProfile::qwen3_moe(Gpu::H100, d);
            p.roofline().throughput_tok_s(p.n_max(8192) as f64, 8192.0)
        };
        assert!(tok_s(0.0) > tok_s(1.0));
        assert!(tok_s(1.0) > tok_s(10.0));
    }

    #[test]
    fn speculative_profile_beats_dense_and_keeps_capacity() {
        let dense = ManualProfile::h100_70b();
        let spec = ManualProfile::speculative(Gpu::H100, 4, 0.8);
        // Same KV capacity, strictly faster effective roofline.
        assert_eq!(spec.n_max(8192), dense.n_max(8192));
        assert!(
            spec.roofline().tau_ms(128.0, 8192.0)
                < dense.roofline().tau_ms(128.0, 8192.0)
        );
    }

    #[test]
    fn model_axis_parses_names_and_aliases() {
        assert_eq!(ModelAxis::parse("llama70b"), Ok(ModelAxis::Dense));
        assert_eq!(ModelAxis::parse("dense"), Ok(ModelAxis::Dense));
        assert_eq!(
            ModelAxis::parse("qwen3-moe"),
            Ok(ModelAxis::MoeStreaming { dispatch_ms: 0.0 })
        );
        assert_eq!(
            ModelAxis::parse("llama70b+spec"),
            Ok(ModelAxis::Speculative { k: 4, alpha: 0.8 })
        );
        assert!(ModelAxis::parse("bogus").is_err());
        assert_eq!(
            ModelAxis::MoeStreaming { dispatch_ms: 0.0 }
                .with_dispatch_ms(2.5)
                .dispatch_ms(),
            Some(2.5)
        );
        assert_eq!(ModelAxis::Dense.with_dispatch_ms(2.5), ModelAxis::Dense);
    }
}
