//! Routing topologies (paper §4/§5): homogeneous, two-pool context
//! routing, FleetOpt (two-pool + compress-and-route overflow factor γ),
//! and semantic routing (small model for short traffic).
//!
//! A topology turns (workload trace, total λ, GPU profile) into the pool
//! plans that [`fleet_tpw_analysis`](super::analysis::fleet_tpw_analysis)
//! sizes and accounts.

use std::sync::Arc;

use super::pool::{LBarPolicy, PoolPlan};
use super::profile::{GpuProfile, ManualProfile, ModelAxis};
use crate::power::Gpu;
use crate::sim::GroupSimConfig;
use crate::workload::WorkloadTrace;

/// Default long-pool serving window (the paper's homogeneous baseline).
pub const LONG_CTX: u32 = 65_536;

/// One pool of a K-pool context partition ([`Topology::Partition`]):
/// the inclusive upper prompt-length cutoff routed here, plus optional
/// per-pool overrides of the fleet GPU generation and the simulated
/// group count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionPool {
    /// Inclusive upper prompt cutoff, tokens. The last pool's cutoff is
    /// also its serving window; requests longer than the second-to-last
    /// cutoff all land in the last pool.
    pub cutoff: u32,
    /// GPU generation serving this pool (`None` = the fleet profile the
    /// caller passes in, i.e. the scenario's GPU).
    pub gpu: Option<Gpu>,
    /// Simulated TP groups for this pool (`None` = an even share of the
    /// scenario's total, remainder to the shorter pools).
    pub groups: Option<u32>,
}

impl PartitionPool {
    pub fn at(cutoff: u32) -> Self {
        PartitionPool { cutoff, gpu: None, groups: None }
    }

    /// This pool served by an explicit GPU generation (heterogeneous
    /// fleets: the scenario's `gpu` stays the default for pools without
    /// an override).
    pub fn with_gpu(mut self, gpu: Gpu) -> Self {
        self.gpu = Some(gpu);
        self
    }

    /// The override profile for this pool, if any — the single source
    /// of the (generation, model architecture)→profile mapping that
    /// [`Self::profile_or`] (closed-form planner via
    /// [`Topology::pools_with_model`]) and the simulator's
    /// [`Topology::sim_pools_with_model`] both consume, so an
    /// analyze-vs-simulate cross-check can never diverge on a mixed
    /// fleet. The scenario's model axis rides along: a MoE fleet with a
    /// B200 long-pool override serves the *MoE-on-B200* calibration
    /// there, not the dense one.
    pub fn override_profile(&self, model: ModelAxis) -> Option<ManualProfile> {
        self.gpu.map(|g| model.profile_for(g))
    }

    /// The profile serving this pool: the per-pool override when set,
    /// the caller's fleet default otherwise.
    pub fn profile_or(
        &self,
        default: &Arc<dyn GpuProfile>,
        model: ModelAxis,
    ) -> Arc<dyn GpuProfile> {
        match self.override_profile(model) {
            Some(p) => Arc::new(p),
            None => default.clone(),
        }
    }
}

/// The default K-pool cutoff vector: a powers-of-four ladder below the
/// 64K long window — K=3 is the paper's §10.3 example {4K, 16K, 64K}.
/// K runs to 6 (the `--pools` ceiling; at K=6 the shortest tier is a
/// 64-token micro-pool).
pub fn default_partition(k: u32) -> Vec<u32> {
    assert!((1..=6).contains(&k), "default partitions cover K in 1..=6");
    (1..=k).map(|i| LONG_CTX >> (2 * (k - i))).collect()
}

/// A fleet routing topology.
#[derive(Debug, Clone)]
pub enum Topology {
    /// Every GPU serves the full context window (paper's "Homo 64K").
    Homogeneous { ctx: u32 },
    /// Two pools split at `b_short`: short pool at a small window, long
    /// pool at `LONG_CTX` (paper's "Pool routing").
    PoolRouting { b_short: u32, short_ctx: u32 },
    /// FleetOpt [Chen et al. 2026a]: two-pool routing plus
    /// compress-and-route on the long pool — long-pool KV is compressed by
    /// γ, so the pool behaves as if its window were `LONG_CTX / γ`.
    FleetOpt { b_short: u32, short_ctx: u32, gamma: f64 },
    /// Semantic routing (§5.1): short/simple traffic to a *small model*
    /// pool at `short_ctx`; the rest to the large model at `LONG_CTX`.
    Semantic { b_short: u32, short_ctx: u32 },
    /// K context-tiered pools (§10.3 generalized): requests bucket-route
    /// by prompt length into the pool with the smallest sufficient
    /// cutoff, and the last (longest) pool optionally runs FleetOpt
    /// compress-and-route at γ. K=2 with γ reproduces [`Self::FleetOpt`]
    /// bit-for-bit; γ=1 reproduces the legacy
    /// [`multi_pool`](super::optimizer::multi_pool) closed form.
    Partition { pools: Vec<PartitionPool>, gamma: f64 },
}

impl Topology {
    /// A plain K-pool partition from its cutoff vector (sorted and
    /// deduplicated; the last entry is the long pool's window).
    pub fn partition(cutoffs: &[u32]) -> Self {
        Self::partition_with_gamma(cutoffs, 1.0)
    }

    /// A K-pool partition with FleetOpt γ-compression on the last pool.
    pub fn partition_with_gamma(cutoffs: &[u32], gamma: f64) -> Self {
        assert!(!cutoffs.is_empty(), "a partition needs at least one pool");
        assert!(gamma >= 1.0, "γ must be >= 1");
        let mut cs = cutoffs.to_vec();
        cs.sort_unstable();
        cs.dedup();
        assert!(cs[0] >= 1, "cutoffs must be positive");
        // A single-pool "partition" has no routing boundary, so the
        // router can never realize compress-and-route — reject γ > 1
        // rather than let analyze() model a fleet simulate() won't run.
        assert!(
            cs.len() >= 2 || gamma == 1.0,
            "γ-compression needs at least two pools (K=1 has no split \
             boundary to compress behind)"
        );
        Topology::Partition {
            pools: cs.into_iter().map(PartitionPool::at).collect(),
            gamma,
        }
    }

    /// A K-pool partition with an explicit per-pool GPU assignment
    /// vector (`gpus[i]` serves the pool at `cutoffs[i]`) — the
    /// heterogeneous-fleet constructor. Unlike [`Self::partition`],
    /// `cutoffs` must already be strictly increasing: silently sorting
    /// or deduplicating would misalign the assignment vector.
    pub fn partition_with_gpus(cutoffs: &[u32], gpus: &[Gpu], gamma: f64) -> Self {
        assert_eq!(
            cutoffs.len(),
            gpus.len(),
            "one GPU per pool: {} cutoffs vs {} GPUs",
            cutoffs.len(),
            gpus.len()
        );
        assert!(
            cutoffs.windows(2).all(|w| w[0] < w[1]),
            "partition_with_gpus needs strictly increasing cutoffs \
             (got {cutoffs:?}; sorting here would misalign the GPU \
             assignment vector)"
        );
        let Topology::Partition { pools, gamma } =
            Self::partition_with_gamma(cutoffs, gamma)
        else {
            unreachable!("partition_with_gamma builds a Partition")
        };
        Topology::Partition {
            pools: pools
                .into_iter()
                .zip(gpus)
                .map(|(p, &g)| p.with_gpu(g))
                .collect(),
            gamma,
        }
    }

    /// The per-pool GPU assignment this topology serves, with `default`
    /// filling every pool that carries no override — one generation per
    /// pool, the heterogeneity axis as data. Non-partition topologies
    /// are homogeneous in `default` by construction.
    pub fn pool_gpus(&self, default: Gpu) -> Vec<Gpu> {
        match self {
            Topology::Partition { pools, .. } => {
                pools.iter().map(|p| p.gpu.unwrap_or(default)).collect()
            }
            _ => vec![default; self.num_pools()],
        }
    }
}

/// Validate the [`Topology::Partition`] invariant the constructors
/// establish (strictly increasing cutoffs) — re-checked by every
/// consumer because the fields are public for per-pool overrides:
/// unsorted or duplicate cutoffs would silently invert traffic slices
/// and route long prompts into short windows.
fn assert_partition_sorted(pools: &[PartitionPool]) {
    assert!(!pools.is_empty(), "a partition needs at least one pool");
    assert!(
        pools.windows(2).all(|w| w[0].cutoff < w[1].cutoff),
        "partition cutoffs must be strictly increasing (got {:?}; build \
         via Topology::partition* or sort them)",
        pools.iter().map(|p| p.cutoff).collect::<Vec<_>>()
    );
}

/// Serving window of partition pool `i`: the cutoff floored at 1024
/// (the FleetOpt `short_ctx` convention, so the K=2 reduction is
/// bit-identical), with the last pool γ-compressed and floored at the
/// previous pool's window (FleetOpt's effective-window rule).
fn partition_window(pools: &[PartitionPool], i: usize, gamma: f64) -> u32 {
    if i + 1 == pools.len() && gamma > 1.0 {
        let eff = (pools[i].cutoff as f64 / gamma).round() as u32;
        let floor = if i == 0 {
            1024
        } else {
            partition_window(pools, i - 1, gamma)
        };
        eff.max(floor)
    } else {
        pools[i].cutoff.max(1024)
    }
}

impl Topology {
    /// The routing split boundary, when this topology has one (the
    /// homogeneous baseline routes nothing). Scenario specs use this to
    /// swap the canonical static router for the load-aware
    /// [`AdaptiveRouter`](crate::router::adaptive::AdaptiveRouter) at
    /// the same split.
    pub fn b_short(&self) -> Option<u32> {
        match *self {
            Topology::Homogeneous { .. } => None,
            Topology::PoolRouting { b_short, .. }
            | Topology::FleetOpt { b_short, .. }
            | Topology::Semantic { b_short, .. } => Some(b_short),
            // Only a two-pool partition has *the* split boundary the
            // adaptive router spills across.
            Topology::Partition { ref pools, .. } if pools.len() == 2 => {
                Some(pools[0].cutoff)
            }
            Topology::Partition { .. } => None,
        }
    }

    /// Number of pools this topology routes across.
    pub fn num_pools(&self) -> usize {
        match self {
            Topology::Homogeneous { .. } => 1,
            Topology::PoolRouting { .. }
            | Topology::FleetOpt { .. }
            | Topology::Semantic { .. } => 2,
            Topology::Partition { pools, .. } => pools.len(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Topology::Homogeneous { ctx } => format!("Homo {}K", ctx / 1024),
            Topology::PoolRouting { b_short, .. } => {
                format!("Pool routing ({}K split)", b_short / 1024)
            }
            Topology::FleetOpt { b_short, gamma, .. } => {
                format!("FleetOpt ({}K/γ={gamma})", b_short / 1024)
            }
            Topology::Semantic { b_short, .. } => {
                format!("Semantic ({}K split)", b_short / 1024)
            }
            Topology::Partition { pools, gamma } => {
                let tiers: Vec<String> = pools
                    .iter()
                    .map(|p| format!("{}K", p.cutoff / 1024))
                    .collect();
                // A mixed fleet names its per-pool generations — two
                // cells differing only in GPU placement must not render
                // identically. Uniform overrides stay suffix-free: the
                // scenario label already names the (single) generation,
                // and homogeneous-override cells must render like their
                // no-override twins (the reduction oracle's surface).
                let overrides: Vec<Option<Gpu>> =
                    pools.iter().map(|p| p.gpu).collect();
                let uniform = overrides.windows(2).all(|w| w[0] == w[1]);
                let gpus = if uniform {
                    String::new()
                } else {
                    let names: Vec<&str> = overrides
                        .iter()
                        .map(|g| g.map_or("-", |g| g.short_name()))
                        .collect();
                    format!(" [{}]", names.join("|"))
                };
                if *gamma > 1.0 {
                    format!(
                        "{}-pool {{{}}}/γ={gamma}{gpus}",
                        pools.len(),
                        tiers.join("|")
                    )
                } else {
                    format!("{}-pool {{{}}}{gpus}", pools.len(), tiers.join("|"))
                }
            }
        }
    }

    /// Build pool plans for the dense baseline model
    /// ([`ModelAxis::Dense`]) — the pre-model-axis behavior, bit-for-bit.
    /// Scenario-level callers that carry a model axis use
    /// [`Self::pools_with_model`]; everything else (tables, benches,
    /// disaggregation sizing) keeps this shorter signature.
    #[allow(clippy::too_many_arguments)]
    pub fn pools(
        &self,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        profile: Arc<dyn GpuProfile>,
        small_profile: Option<Arc<dyn GpuProfile>>,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
    ) -> Vec<PoolPlan> {
        self.pools_with_model(
            trace,
            lambda_rps,
            profile,
            small_profile,
            lbar,
            rho,
            ttft_slo_s,
            ModelAxis::Dense,
        )
    }

    /// Build pool plans. `profile` serves every pool except the semantic
    /// short pool, which uses `small_profile` (ignored otherwise).
    /// `model` re-resolves per-pool GPU *overrides* under the scenario's
    /// model architecture (the caller already folded it into `profile`
    /// for the default pools) — the analytical half of the same
    /// unification [`PartitionPool::override_profile`] gives the
    /// simulator.
    #[allow(clippy::too_many_arguments)]
    pub fn pools_with_model(
        &self,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        profile: Arc<dyn GpuProfile>,
        small_profile: Option<Arc<dyn GpuProfile>>,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
        model: ModelAxis,
    ) -> Vec<PoolPlan> {
        let max_len = trace.prompt_cdf.max_tokens();
        match *self {
            Topology::Homogeneous { ctx } => vec![PoolPlan::for_slice(
                format!("homo-{}k", ctx / 1024),
                profile,
                trace,
                lambda_rps,
                0.0,
                max_len,
                ctx,
                1.0,
                lbar,
                rho,
                ttft_slo_s,
            )],
            Topology::PoolRouting { b_short, short_ctx } => vec![
                PoolPlan::for_slice(
                    format!("short-{}k", short_ctx / 1024),
                    profile.clone(),
                    trace,
                    lambda_rps,
                    0.0,
                    b_short as f64,
                    short_ctx,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
                PoolPlan::for_slice(
                    "long-64k",
                    profile,
                    trace,
                    lambda_rps,
                    b_short as f64,
                    max_len,
                    LONG_CTX,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
            ],
            Topology::FleetOpt { b_short, short_ctx, gamma } => {
                assert!(gamma >= 1.0, "γ must be >= 1");
                let eff_ctx = ((LONG_CTX as f64 / gamma).round() as u32).max(short_ctx);
                vec![
                    PoolPlan::for_slice(
                        format!("short-{}k", short_ctx / 1024),
                        profile.clone(),
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        format!("long-64k/γ{gamma}"),
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        eff_ctx,
                        gamma,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
            Topology::Semantic { b_short, short_ctx } => {
                let small = small_profile
                    .expect("Semantic topology needs a small-model profile");
                vec![
                    PoolPlan::for_slice(
                        format!("semantic-small-{}k", short_ctx / 1024),
                        small,
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        "semantic-large-64k",
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        LONG_CTX,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
            Topology::Partition { ref pools, gamma } => {
                assert!(gamma >= 1.0, "γ must be >= 1");
                assert_partition_sorted(pools);
                let k = pools.len();
                let mut out = Vec::with_capacity(k);
                let mut lo = 0.0f64;
                for (i, part) in pools.iter().enumerate() {
                    let last = i + 1 == k;
                    let hi = if last { max_len } else { part.cutoff as f64 };
                    let window = partition_window(pools, i, gamma);
                    let compression = if last { gamma } else { 1.0 };
                    let pool_profile = part.profile_or(&profile, model);
                    let name = if last && gamma > 1.0 {
                        format!("tier-{}k/γ{gamma}", part.cutoff / 1024)
                    } else {
                        format!("tier-{}k", part.cutoff / 1024)
                    };
                    out.push(PoolPlan::for_slice(
                        name,
                        pool_profile,
                        trace,
                        lambda_rps,
                        lo,
                        hi,
                        window,
                        compression,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ));
                    lo = hi;
                }
                out
            }
        }
    }
}

impl Topology {
    /// Per-pool group counts and [`GroupSimConfig`]s for playing this
    /// topology through the event-driven simulator
    /// ([`crate::sim::simulate_topology_with`]): `total_groups` is split
    /// half/half between short and long pools (all of it for the
    /// homogeneous baseline), and the short pool's simulated window gets
    /// 1024 tokens of output headroom above the routing boundary so a
    /// prompt routed short always fits prompt + output.
    pub fn sim_pools(
        &self,
        profile: &dyn GpuProfile,
        total_groups: u32,
        ingest_chunk: u32,
    ) -> (Vec<u32>, Vec<GroupSimConfig>) {
        self.sim_pools_with_model(profile, total_groups, ingest_chunk, ModelAxis::Dense)
    }

    /// [`Self::sim_pools`] with the scenario's model axis: per-pool GPU
    /// overrides resolve to that model's calibration on the override
    /// generation (via [`PartitionPool::override_profile`]), mirroring
    /// [`Self::pools_with_model`] on the analytical side.
    pub fn sim_pools_with_model(
        &self,
        profile: &dyn GpuProfile,
        total_groups: u32,
        ingest_chunk: u32,
        model: ModelAxis,
    ) -> (Vec<u32>, Vec<GroupSimConfig>) {
        assert!(total_groups > 0);
        let mk_for = |p: &dyn GpuProfile, window: u32| GroupSimConfig {
            window_tokens: window,
            n_max: p.n_max(window),
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk,
        };
        let mk = |window: u32| mk_for(profile, window);
        let split = |short_ctx: u32, long_window: u32| {
            assert!(
                total_groups >= 2,
                "a two-pool topology needs at least 2 groups to split \
                 (got {total_groups})"
            );
            let short = total_groups.div_ceil(2);
            (
                vec![short, total_groups - short],
                vec![mk(short_ctx.max(2048) + 1024), mk(long_window)],
            )
        };
        match *self {
            Topology::Homogeneous { ctx } => (vec![total_groups], vec![mk(ctx)]),
            Topology::PoolRouting { short_ctx, .. }
            | Topology::Semantic { short_ctx, .. } => split(short_ctx, LONG_CTX),
            // FleetOpt's long pool keeps the full window in simulation:
            // compression happens in the router (γ-shrunk effective
            // prompts), which the live-L̄ roofline then rewards — the
            // dynamic counterpart of the analytical `W/γ` pool.
            Topology::FleetOpt { short_ctx, .. } => split(short_ctx, LONG_CTX),
            // K-pool partition: interior pools get the same
            // boundary + output-headroom window as the two-pool split (so
            // a prompt routed at its cutoff always fits prompt + output);
            // the last pool serves its cutoff as the full window, with γ
            // compression happening in the router exactly like FleetOpt.
            // Explicit per-pool group counts are honored; the remaining
            // groups split evenly with the surplus to the shorter pools
            // (reducing to ceil/floor halves at K=2).
            Topology::Partition { ref pools, .. } => {
                assert_partition_sorted(pools);
                let k = pools.len() as u32;
                assert!(
                    total_groups >= k,
                    "a {k}-pool partition needs at least {k} groups \
                     (got {total_groups})"
                );
                let explicit: u32 = pools.iter().filter_map(|p| p.groups).sum();
                let implicit =
                    pools.iter().filter(|p| p.groups.is_none()).count() as u32;
                assert!(
                    explicit + implicit <= total_groups,
                    "per-pool group counts ({explicit} explicit + {implicit} \
                     implicit pools) exceed the fleet's {total_groups} groups"
                );
                let rest = total_groups - explicit;
                assert!(
                    implicit > 0 || rest == 0,
                    "explicit per-pool group counts ({explicit}) must use all \
                     {total_groups} fleet groups when every pool is explicit"
                );
                let (mut counts, mut filled) = (Vec::with_capacity(pools.len()), 0);
                for part in pools {
                    counts.push(match part.groups {
                        Some(g) => {
                            assert!(g > 0, "explicit pool group count must be > 0");
                            g
                        }
                        None => {
                            let share = rest / implicit
                                + u32::from(filled < rest % implicit);
                            filled += 1;
                            share
                        }
                    });
                }
                let cfgs = pools
                    .iter()
                    .enumerate()
                    .map(|(i, part)| {
                        let window = if i + 1 == pools.len() {
                            part.cutoff
                        } else {
                            part.cutoff.max(2048) + 1024
                        };
                        match part.override_profile(model) {
                            Some(p) => mk_for(&p, window),
                            None => mk(window),
                        }
                    })
                    .collect();
                (counts, cfgs)
            }
        }
    }

    /// The request router realizing this topology at serving time.
    pub fn router(&self) -> Box<dyn crate::router::Router> {
        use crate::router::context::ContextRouter;
        use crate::router::fleetopt::FleetOptRouter;
        use crate::router::semantic::SemanticRouter;
        match *self {
            Topology::Homogeneous { .. } => {
                Box::new(crate::router::HomogeneousRouter)
            }
            Topology::PoolRouting { b_short, .. } => {
                Box::new(ContextRouter::two_pool(b_short))
            }
            Topology::FleetOpt { b_short, gamma, .. } => {
                Box::new(FleetOptRouter::new(b_short, gamma))
            }
            // Threshold = difficulty of a prompt exactly at b_short with
            // zero output (0.7·b/8192, the paper's 0.35 at b=4096). The
            // prompt term is the cheapest difficulty per token, so for
            // outputs up to 1024 (the difficulty proxy's saturation knee
            // and the simulate CLI's output cap) every short-routed
            // request has prompt + output < b_short and fits the short
            // pool's sim_pools window (b_short + 1024 headroom) — no
            // silent rejections.
            Topology::Semantic { b_short, .. } => Box::new(
                SemanticRouter::new(0.7 * b_short as f64 / 8192.0),
            ),
            // Bucket-route by request length across the K cutoffs; the
            // last pool compresses by γ (identical to the FleetOpt
            // router at K=2).
            Topology::Partition { ref pools, gamma } => {
                assert_partition_sorted(pools);
                let boundaries: Vec<u32> = pools[..pools.len() - 1]
                    .iter()
                    .map(|p| p.cutoff)
                    .collect();
                Box::new(crate::router::context::KPoolRouter::new(
                    boundaries, gamma,
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::workload::cdf::azure_conversations;

    fn h100() -> Arc<dyn GpuProfile> {
        Arc::new(ManualProfile::h100_70b())
    }

    #[test]
    fn homo_is_one_pool_with_all_traffic() {
        let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
            &azure_conversations(), 1000.0, h100(), None,
            LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 1);
        assert!((pools[0].inputs.lambda_rps - 1000.0).abs() < 1e-6);
        assert_eq!(pools[0].inputs.context_tokens, LONG_CTX);
    }

    #[test]
    fn two_pool_split_conserves_traffic() {
        let pools = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 2);
        let total: f64 = pools.iter().map(|p| p.inputs.lambda_rps).sum();
        assert!((total - 1000.0).abs() < 1e-6);
        assert!(pools[0].inputs.lambda_rps > pools[1].inputs.lambda_rps);
    }

    #[test]
    fn fleetopt_gamma_halves_effective_window() {
        let pools = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[1].inputs.context_tokens, LONG_CTX / 2);
    }

    #[test]
    #[should_panic(expected = "γ must be >= 1")]
    fn fleetopt_rejects_gamma_below_one() {
        Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 0.5 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
    }

    #[test]
    fn semantic_uses_small_profile_for_short_pool() {
        let small: Arc<dyn GpuProfile> = Arc::new(ManualProfile {
            name: "small".into(),
            ..ManualProfile::h100_70b()
        });
        let pools = Topology::Semantic { b_short: 8192, short_ctx: 8192 }
            .pools(&azure_conversations(), 1000.0, h100(), Some(small),
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[0].profile.label(), "small");
    }

    #[test]
    fn sim_pools_split_groups_and_add_short_headroom() {
        let p = ManualProfile::h100_70b();
        let topo = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 };
        let (groups, cfgs) = topo.sim_pools(&p, 4, 1024);
        assert_eq!(groups, vec![2, 2]);
        assert_eq!(cfgs[0].window_tokens, 4096 + 1024);
        assert_eq!(cfgs[1].window_tokens, LONG_CTX);
        assert!(cfgs[0].n_max > cfgs[1].n_max, "1/W: shorter window, more slots");

        let (hg, hc) = Topology::Homogeneous { ctx: LONG_CTX }.sim_pools(&p, 4, 1024);
        assert_eq!(hg, vec![4]);
        assert_eq!(hc[0].window_tokens, LONG_CTX);
    }

    #[test]
    fn router_matches_topology() {
        assert_eq!(
            Topology::Homogeneous { ctx: LONG_CTX }.router().num_pools(),
            1
        );
        let fo = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 };
        let r = fo.router();
        assert_eq!(r.num_pools(), 2);
        assert!(r.name().contains("fleetopt"));
    }

    #[test]
    fn b_short_accessor_matches_variant() {
        assert_eq!(Topology::Homogeneous { ctx: LONG_CTX }.b_short(), None);
        assert_eq!(
            Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }.b_short(),
            Some(4096)
        );
        assert_eq!(
            Topology::FleetOpt { b_short: 2048, short_ctx: 2048, gamma: 2.0 }
                .b_short(),
            Some(2048)
        );
    }

    #[test]
    fn labels_are_informative() {
        assert!(Topology::Homogeneous { ctx: LONG_CTX }.label().contains("64K"));
        assert!(Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .label()
            .contains("γ=2"));
        let p = Topology::partition(&[4096, 16384, LONG_CTX]);
        assert!(p.label().contains("3-pool"), "{}", p.label());
        assert!(p.label().contains("4K|16K|64K"), "{}", p.label());
        assert!(Topology::partition_with_gamma(&[4096, LONG_CTX], 2.0)
            .label()
            .contains("γ=2"));
    }

    #[test]
    fn partition_constructor_sorts_and_dedups() {
        let t = Topology::partition(&[16384, 4096, 16384, LONG_CTX]);
        match &t {
            Topology::Partition { pools, gamma } => {
                assert_eq!(
                    pools.iter().map(|p| p.cutoff).collect::<Vec<_>>(),
                    vec![4096, 16384, LONG_CTX]
                );
                assert_eq!(*gamma, 1.0);
            }
            _ => panic!("not a partition"),
        }
        assert_eq!(t.num_pools(), 3);
        assert_eq!(t.b_short(), None, "only K=2 exposes a split boundary");
        assert_eq!(
            Topology::partition(&[4096, LONG_CTX]).b_short(),
            Some(4096)
        );
    }

    #[test]
    fn partition_pools_tile_traffic_and_shrink_windows() {
        let t = azure_conversations();
        let pools = Topology::partition(&[4096, 16384, LONG_CTX]).pools(
            &t, 1000.0, h100(), None, LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 3);
        let total: f64 = pools.iter().map(|p| p.inputs.lambda_rps).sum();
        assert!((total - 1000.0).abs() < 1e-6, "λ conserved: {total}");
        assert_eq!(pools[0].inputs.context_tokens, 4096);
        assert_eq!(pools[1].inputs.context_tokens, 16384);
        assert_eq!(pools[2].inputs.context_tokens, LONG_CTX);
        // Azure is short-dominant: traffic decreases up the tiers.
        assert!(pools[0].inputs.lambda_rps > pools[1].inputs.lambda_rps);
        assert!(pools[1].inputs.lambda_rps > pools[2].inputs.lambda_rps);
    }

    #[test]
    fn k2_partition_pools_match_fleetopt_bitwise() {
        // The K=2 reduction the optimizer oracle rests on: a two-pool
        // partition with γ must produce the exact FleetOpt pool plans.
        let t = azure_conversations();
        for gamma in [1.0, 2.0, 3.0] {
            let part = Topology::partition_with_gamma(&[4096, LONG_CTX], gamma)
                .pools(&t, 1000.0, h100(), None, LBarPolicy::Window, 0.85, 0.5);
            let fleet =
                Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma }
                    .pools(&t, 1000.0, h100(), None, LBarPolicy::Window, 0.85, 0.5);
            assert_eq!(part.len(), fleet.len());
            for (a, b) in part.iter().zip(&fleet) {
                assert_eq!(
                    a.inputs.lambda_rps.to_bits(),
                    b.inputs.lambda_rps.to_bits(),
                    "γ={gamma}"
                );
                assert_eq!(a.inputs.context_tokens, b.inputs.context_tokens);
                assert_eq!(
                    a.inputs.l_bar.to_bits(),
                    b.inputs.l_bar.to_bits(),
                    "γ={gamma}"
                );
                assert_eq!(
                    a.inputs.mean_prompt_tokens.to_bits(),
                    b.inputs.mean_prompt_tokens.to_bits()
                );
            }
        }
    }

    #[test]
    fn partition_sim_pools_split_groups_with_remainder_to_short() {
        let p = ManualProfile::h100_70b();
        let topo = Topology::partition(&[2048, 8192, LONG_CTX]);
        let (groups, cfgs) = topo.sim_pools(&p, 8, 1024);
        assert_eq!(groups, vec![3, 3, 2]);
        assert_eq!(cfgs[0].window_tokens, 2048 + 1024);
        assert_eq!(cfgs[1].window_tokens, 8192 + 1024);
        assert_eq!(cfgs[2].window_tokens, LONG_CTX);
        assert!(cfgs[0].n_max > cfgs[2].n_max, "1/W: shorter window, more slots");
        // K=2 reduces to the two-pool ceil/floor split.
        let (g2, c2) =
            Topology::partition(&[4096, LONG_CTX]).sim_pools(&p, 5, 1024);
        assert_eq!(g2, vec![3, 2]);
        assert_eq!(c2[0].window_tokens, 4096 + 1024);
    }

    #[test]
    fn partition_honors_per_pool_group_and_gpu_overrides() {
        let p = ManualProfile::h100_70b();
        let topo = Topology::Partition {
            pools: vec![
                PartitionPool { cutoff: 4096, gpu: None, groups: Some(5) },
                PartitionPool {
                    cutoff: LONG_CTX,
                    gpu: Some(crate::power::Gpu::B200),
                    groups: None,
                },
            ],
            gamma: 1.0,
        };
        let (groups, cfgs) = topo.sim_pools(&p, 8, 1024);
        assert_eq!(groups, vec![5, 3]);
        // The B200 pool draws the B200 power curve, not the fleet H100's.
        let h100_b200_idle_differ = (cfgs[1].power.power_w(0.0)
            - cfgs[0].power.power_w(0.0))
        .abs()
            > 1.0;
        assert!(h100_b200_idle_differ, "per-pool GPU override ignored");
        // Analytical side picks the override profile too.
        let pools = topo.pools(
            &azure_conversations(), 1000.0, h100(), None,
            LBarPolicy::Window, 0.85, 0.5);
        assert!(pools[1].profile.label().contains("B200"), "{}", pools[1].profile.label());
    }

    #[test]
    #[should_panic(expected = "needs at least 3 groups")]
    fn partition_rejects_fewer_groups_than_pools() {
        Topology::partition(&[2048, 8192, LONG_CTX])
            .sim_pools(&ManualProfile::h100_70b(), 2, 1024);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn hand_built_unsorted_partition_is_rejected_by_consumers() {
        // The fields are public (per-pool overrides), so consumers
        // re-check the constructor's sorted invariant instead of
        // silently inverting traffic slices.
        Topology::Partition {
            pools: vec![PartitionPool::at(16384), PartitionPool::at(4096)],
            gamma: 1.0,
        }
        .router();
    }

    #[test]
    fn partition_router_buckets_and_compresses() {
        let r = Topology::partition_with_gamma(&[4096, 16384, LONG_CTX], 2.0)
            .router();
        assert_eq!(r.num_pools(), 3);
        use crate::workload::Request;
        let req = |p: u32| Request {
            id: 0, arrival_s: 0.0, prompt_tokens: p, output_tokens: 1,
        };
        assert_eq!(r.route(&req(100)).pool, 0);
        assert_eq!(r.route(&req(8000)).pool, 1);
        let long = r.route(&req(40_000));
        assert_eq!(long.pool, 2);
        assert_eq!(long.effective_prompt_tokens, 20_000);
    }

    #[test]
    fn partition_with_gpus_assigns_one_generation_per_pool() {
        use crate::power::Gpu;
        let t = Topology::partition_with_gpus(
            &[4096, 16384, LONG_CTX],
            &[Gpu::H100, Gpu::H100, Gpu::B200],
            1.0,
        );
        assert_eq!(
            t.pool_gpus(Gpu::H100),
            vec![Gpu::H100, Gpu::H100, Gpu::B200]
        );
        // Mixed assignments surface in the label; uniform overrides
        // render exactly like their no-override twins.
        assert!(t.label().contains("[H100|H100|B200]"), "{}", t.label());
        let uniform = Topology::partition_with_gpus(
            &[4096, LONG_CTX],
            &[Gpu::H100, Gpu::H100],
            1.0,
        );
        assert_eq!(
            uniform.label(),
            Topology::partition(&[4096, LONG_CTX]).label()
        );
        // No-override topologies resolve every pool to the default.
        assert_eq!(
            Topology::partition(&[4096, LONG_CTX]).pool_gpus(Gpu::B200),
            vec![Gpu::B200, Gpu::B200]
        );
        assert_eq!(
            Topology::Homogeneous { ctx: LONG_CTX }.pool_gpus(Gpu::H200),
            vec![Gpu::H200]
        );
    }

    #[test]
    #[should_panic(expected = "one GPU per pool")]
    fn partition_with_gpus_rejects_length_mismatch() {
        Topology::partition_with_gpus(
            &[4096, LONG_CTX],
            &[crate::power::Gpu::H100],
            1.0,
        );
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn partition_with_gpus_rejects_unsorted_cutoffs() {
        // Sorting here would silently misalign the assignment vector.
        use crate::power::Gpu;
        Topology::partition_with_gpus(
            &[16384, 4096],
            &[Gpu::H100, Gpu::B200],
            1.0,
        );
    }

    #[test]
    fn model_axis_reaches_per_pool_gpu_overrides_on_both_paths() {
        // A MoE fleet with a B200 long-pool override must serve the
        // MoE-on-B200 calibration there on BOTH engines — the model-axis
        // extension of the generation unification above.
        let moe = ModelAxis::MoeStreaming { dispatch_ms: 0.0 };
        let fleet_default = moe.profile_for(Gpu::H100);
        let topo = Topology::Partition {
            pools: vec![
                PartitionPool::at(4096),
                PartitionPool::at(LONG_CTX).with_gpu(Gpu::B200),
            ],
            gamma: 1.0,
        };
        let pools = topo.pools_with_model(
            &azure_conversations(), 1000.0, Arc::new(fleet_default.clone()),
            None, LBarPolicy::Window, 0.85, 0.5, moe);
        let label = pools[1].profile.label();
        assert!(
            label.contains("Qwen3-235B-A22B") && label.contains("B200"),
            "override pool must be MoE-on-B200, got {label}"
        );
        let (_, cfgs) = topo.sim_pools_with_model(&fleet_default, 4, 1024, moe);
        let want = moe.profile_for(Gpu::B200).roofline();
        assert_eq!(cfgs[1].roofline.w_ms.to_bits(), want.w_ms.to_bits());
        assert_eq!(cfgs[1].roofline.h0_ms.to_bits(), want.h0_ms.to_bits());
        // The dense wrappers stay the pre-axis behavior bit-for-bit.
        let p = ManualProfile::h100_70b();
        let (_, dense_cfgs) = topo.sim_pools(&p, 4, 1024);
        let dense_want = ManualProfile::for_gpu(Gpu::B200).roofline();
        assert_eq!(
            dense_cfgs[1].roofline.w_ms.to_bits(),
            dense_want.w_ms.to_bits()
        );
    }

    #[test]
    fn default_partition_is_a_powers_of_four_ladder() {
        assert_eq!(default_partition(1), vec![LONG_CTX]);
        assert_eq!(default_partition(2), vec![16384, LONG_CTX]);
        assert_eq!(default_partition(3), vec![4096, 16384, LONG_CTX]);
        assert_eq!(default_partition(4), vec![1024, 4096, 16384, LONG_CTX]);
        assert_eq!(
            default_partition(6),
            vec![64, 256, 1024, 4096, 16384, LONG_CTX]
        );
    }
}
