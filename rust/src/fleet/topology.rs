//! Routing topologies (paper §4/§5): homogeneous, two-pool context
//! routing, FleetOpt (two-pool + compress-and-route overflow factor γ),
//! and semantic routing (small model for short traffic).
//!
//! A topology turns (workload trace, total λ, GPU profile) into the pool
//! plans that [`fleet_tpw_analysis`](super::analysis::fleet_tpw_analysis)
//! sizes and accounts.

use std::sync::Arc;

use super::pool::{LBarPolicy, PoolPlan};
use super::profile::GpuProfile;
use crate::sim::GroupSimConfig;
use crate::workload::WorkloadTrace;

/// Default long-pool serving window (the paper's homogeneous baseline).
pub const LONG_CTX: u32 = 65_536;

/// A fleet routing topology.
#[derive(Debug, Clone)]
pub enum Topology {
    /// Every GPU serves the full context window (paper's "Homo 64K").
    Homogeneous { ctx: u32 },
    /// Two pools split at `b_short`: short pool at a small window, long
    /// pool at `LONG_CTX` (paper's "Pool routing").
    PoolRouting { b_short: u32, short_ctx: u32 },
    /// FleetOpt [Chen et al. 2026a]: two-pool routing plus
    /// compress-and-route on the long pool — long-pool KV is compressed by
    /// γ, so the pool behaves as if its window were `LONG_CTX / γ`.
    FleetOpt { b_short: u32, short_ctx: u32, gamma: f64 },
    /// Semantic routing (§5.1): short/simple traffic to a *small model*
    /// pool at `short_ctx`; the rest to the large model at `LONG_CTX`.
    Semantic { b_short: u32, short_ctx: u32 },
}

impl Topology {
    /// The routing split boundary, when this topology has one (the
    /// homogeneous baseline routes nothing). Scenario specs use this to
    /// swap the canonical static router for the load-aware
    /// [`AdaptiveRouter`](crate::router::adaptive::AdaptiveRouter) at
    /// the same split.
    pub fn b_short(&self) -> Option<u32> {
        match *self {
            Topology::Homogeneous { .. } => None,
            Topology::PoolRouting { b_short, .. }
            | Topology::FleetOpt { b_short, .. }
            | Topology::Semantic { b_short, .. } => Some(b_short),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Topology::Homogeneous { ctx } => format!("Homo {}K", ctx / 1024),
            Topology::PoolRouting { b_short, .. } => {
                format!("Pool routing ({}K split)", b_short / 1024)
            }
            Topology::FleetOpt { b_short, gamma, .. } => {
                format!("FleetOpt ({}K/γ={gamma})", b_short / 1024)
            }
            Topology::Semantic { b_short, .. } => {
                format!("Semantic ({}K split)", b_short / 1024)
            }
        }
    }

    /// Build pool plans. `profile` serves every pool except the semantic
    /// short pool, which uses `small_profile` (ignored otherwise).
    pub fn pools(
        &self,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        profile: Arc<dyn GpuProfile>,
        small_profile: Option<Arc<dyn GpuProfile>>,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
    ) -> Vec<PoolPlan> {
        let max_len = trace.prompt_cdf.max_tokens();
        match *self {
            Topology::Homogeneous { ctx } => vec![PoolPlan::for_slice(
                format!("homo-{}k", ctx / 1024),
                profile,
                trace,
                lambda_rps,
                0.0,
                max_len,
                ctx,
                1.0,
                lbar,
                rho,
                ttft_slo_s,
            )],
            Topology::PoolRouting { b_short, short_ctx } => vec![
                PoolPlan::for_slice(
                    format!("short-{}k", short_ctx / 1024),
                    profile.clone(),
                    trace,
                    lambda_rps,
                    0.0,
                    b_short as f64,
                    short_ctx,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
                PoolPlan::for_slice(
                    "long-64k",
                    profile,
                    trace,
                    lambda_rps,
                    b_short as f64,
                    max_len,
                    LONG_CTX,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
            ],
            Topology::FleetOpt { b_short, short_ctx, gamma } => {
                assert!(gamma >= 1.0, "γ must be >= 1");
                let eff_ctx = ((LONG_CTX as f64 / gamma).round() as u32).max(short_ctx);
                vec![
                    PoolPlan::for_slice(
                        format!("short-{}k", short_ctx / 1024),
                        profile.clone(),
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        format!("long-64k/γ{gamma}"),
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        eff_ctx,
                        gamma,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
            Topology::Semantic { b_short, short_ctx } => {
                let small = small_profile
                    .expect("Semantic topology needs a small-model profile");
                vec![
                    PoolPlan::for_slice(
                        format!("semantic-small-{}k", short_ctx / 1024),
                        small,
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        "semantic-large-64k",
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        LONG_CTX,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
        }
    }
}

impl Topology {
    /// Per-pool group counts and [`GroupSimConfig`]s for playing this
    /// topology through the event-driven simulator
    /// ([`crate::sim::simulate_topology_with`]): `total_groups` is split
    /// half/half between short and long pools (all of it for the
    /// homogeneous baseline), and the short pool's simulated window gets
    /// 1024 tokens of output headroom above the routing boundary so a
    /// prompt routed short always fits prompt + output.
    pub fn sim_pools(
        &self,
        profile: &dyn GpuProfile,
        total_groups: u32,
        ingest_chunk: u32,
    ) -> (Vec<u32>, Vec<GroupSimConfig>) {
        assert!(total_groups > 0);
        let mk = |window: u32| GroupSimConfig {
            window_tokens: window,
            n_max: profile.n_max(window),
            roofline: profile.roofline(),
            power: profile.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk,
        };
        let split = |short_ctx: u32, long_window: u32| {
            assert!(
                total_groups >= 2,
                "a two-pool topology needs at least 2 groups to split \
                 (got {total_groups})"
            );
            let short = total_groups.div_ceil(2);
            (
                vec![short, total_groups - short],
                vec![mk(short_ctx.max(2048) + 1024), mk(long_window)],
            )
        };
        match *self {
            Topology::Homogeneous { ctx } => (vec![total_groups], vec![mk(ctx)]),
            Topology::PoolRouting { short_ctx, .. }
            | Topology::Semantic { short_ctx, .. } => split(short_ctx, LONG_CTX),
            // FleetOpt's long pool keeps the full window in simulation:
            // compression happens in the router (γ-shrunk effective
            // prompts), which the live-L̄ roofline then rewards — the
            // dynamic counterpart of the analytical `W/γ` pool.
            Topology::FleetOpt { short_ctx, .. } => split(short_ctx, LONG_CTX),
        }
    }

    /// The request router realizing this topology at serving time.
    pub fn router(&self) -> Box<dyn crate::router::Router> {
        use crate::router::context::ContextRouter;
        use crate::router::fleetopt::FleetOptRouter;
        use crate::router::semantic::SemanticRouter;
        match *self {
            Topology::Homogeneous { .. } => {
                Box::new(crate::router::HomogeneousRouter)
            }
            Topology::PoolRouting { b_short, .. } => {
                Box::new(ContextRouter::two_pool(b_short))
            }
            Topology::FleetOpt { b_short, gamma, .. } => {
                Box::new(FleetOptRouter::new(b_short, gamma))
            }
            // Threshold = difficulty of a prompt exactly at b_short with
            // zero output (0.7·b/8192, the paper's 0.35 at b=4096). The
            // prompt term is the cheapest difficulty per token, so for
            // outputs up to 1024 (the difficulty proxy's saturation knee
            // and the simulate CLI's output cap) every short-routed
            // request has prompt + output < b_short and fits the short
            // pool's sim_pools window (b_short + 1024 headroom) — no
            // silent rejections.
            Topology::Semantic { b_short, .. } => Box::new(
                SemanticRouter::new(0.7 * b_short as f64 / 8192.0),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::workload::cdf::azure_conversations;

    fn h100() -> Arc<dyn GpuProfile> {
        Arc::new(ManualProfile::h100_70b())
    }

    #[test]
    fn homo_is_one_pool_with_all_traffic() {
        let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
            &azure_conversations(), 1000.0, h100(), None,
            LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 1);
        assert!((pools[0].inputs.lambda_rps - 1000.0).abs() < 1e-6);
        assert_eq!(pools[0].inputs.context_tokens, LONG_CTX);
    }

    #[test]
    fn two_pool_split_conserves_traffic() {
        let pools = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 2);
        let total: f64 = pools.iter().map(|p| p.inputs.lambda_rps).sum();
        assert!((total - 1000.0).abs() < 1e-6);
        assert!(pools[0].inputs.lambda_rps > pools[1].inputs.lambda_rps);
    }

    #[test]
    fn fleetopt_gamma_halves_effective_window() {
        let pools = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[1].inputs.context_tokens, LONG_CTX / 2);
    }

    #[test]
    #[should_panic(expected = "γ must be >= 1")]
    fn fleetopt_rejects_gamma_below_one() {
        Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 0.5 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
    }

    #[test]
    fn semantic_uses_small_profile_for_short_pool() {
        let small: Arc<dyn GpuProfile> = Arc::new(ManualProfile {
            name: "small".into(),
            ..ManualProfile::h100_70b()
        });
        let pools = Topology::Semantic { b_short: 8192, short_ctx: 8192 }
            .pools(&azure_conversations(), 1000.0, h100(), Some(small),
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[0].profile.label(), "small");
    }

    #[test]
    fn sim_pools_split_groups_and_add_short_headroom() {
        let p = ManualProfile::h100_70b();
        let topo = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 };
        let (groups, cfgs) = topo.sim_pools(&p, 4, 1024);
        assert_eq!(groups, vec![2, 2]);
        assert_eq!(cfgs[0].window_tokens, 4096 + 1024);
        assert_eq!(cfgs[1].window_tokens, LONG_CTX);
        assert!(cfgs[0].n_max > cfgs[1].n_max, "1/W: shorter window, more slots");

        let (hg, hc) = Topology::Homogeneous { ctx: LONG_CTX }.sim_pools(&p, 4, 1024);
        assert_eq!(hg, vec![4]);
        assert_eq!(hc[0].window_tokens, LONG_CTX);
    }

    #[test]
    fn router_matches_topology() {
        assert_eq!(
            Topology::Homogeneous { ctx: LONG_CTX }.router().num_pools(),
            1
        );
        let fo = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 };
        let r = fo.router();
        assert_eq!(r.num_pools(), 2);
        assert!(r.name().contains("fleetopt"));
    }

    #[test]
    fn b_short_accessor_matches_variant() {
        assert_eq!(Topology::Homogeneous { ctx: LONG_CTX }.b_short(), None);
        assert_eq!(
            Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }.b_short(),
            Some(4096)
        );
        assert_eq!(
            Topology::FleetOpt { b_short: 2048, short_ctx: 2048, gamma: 2.0 }
                .b_short(),
            Some(2048)
        );
    }

    #[test]
    fn labels_are_informative() {
        assert!(Topology::Homogeneous { ctx: LONG_CTX }.label().contains("64K"));
        assert!(Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .label()
            .contains("γ=2"));
    }
}
