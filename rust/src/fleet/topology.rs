//! Routing topologies (paper §4/§5): homogeneous, two-pool context
//! routing, FleetOpt (two-pool + compress-and-route overflow factor γ),
//! and semantic routing (small model for short traffic).
//!
//! A topology turns (workload trace, total λ, GPU profile) into the pool
//! plans that [`fleet_tpw_analysis`](super::analysis::fleet_tpw_analysis)
//! sizes and accounts.

use std::sync::Arc;

use super::pool::{LBarPolicy, PoolPlan};
use super::profile::GpuProfile;
use crate::workload::WorkloadTrace;

/// Default long-pool serving window (the paper's homogeneous baseline).
pub const LONG_CTX: u32 = 65_536;

/// A fleet routing topology.
#[derive(Debug, Clone)]
pub enum Topology {
    /// Every GPU serves the full context window (paper's "Homo 64K").
    Homogeneous { ctx: u32 },
    /// Two pools split at `b_short`: short pool at a small window, long
    /// pool at `LONG_CTX` (paper's "Pool routing").
    PoolRouting { b_short: u32, short_ctx: u32 },
    /// FleetOpt [Chen et al. 2026a]: two-pool routing plus
    /// compress-and-route on the long pool — long-pool KV is compressed by
    /// γ, so the pool behaves as if its window were `LONG_CTX / γ`.
    FleetOpt { b_short: u32, short_ctx: u32, gamma: f64 },
    /// Semantic routing (§5.1): short/simple traffic to a *small model*
    /// pool at `short_ctx`; the rest to the large model at `LONG_CTX`.
    Semantic { b_short: u32, short_ctx: u32 },
}

impl Topology {
    pub fn label(&self) -> String {
        match self {
            Topology::Homogeneous { ctx } => format!("Homo {}K", ctx / 1024),
            Topology::PoolRouting { b_short, .. } => {
                format!("Pool routing ({}K split)", b_short / 1024)
            }
            Topology::FleetOpt { b_short, gamma, .. } => {
                format!("FleetOpt ({}K/γ={gamma})", b_short / 1024)
            }
            Topology::Semantic { b_short, .. } => {
                format!("Semantic ({}K split)", b_short / 1024)
            }
        }
    }

    /// Build pool plans. `profile` serves every pool except the semantic
    /// short pool, which uses `small_profile` (ignored otherwise).
    pub fn pools(
        &self,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        profile: Arc<dyn GpuProfile>,
        small_profile: Option<Arc<dyn GpuProfile>>,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
    ) -> Vec<PoolPlan> {
        let max_len = trace.prompt_cdf.max_tokens();
        match *self {
            Topology::Homogeneous { ctx } => vec![PoolPlan::for_slice(
                format!("homo-{}k", ctx / 1024),
                profile,
                trace,
                lambda_rps,
                0.0,
                max_len,
                ctx,
                1.0,
                lbar,
                rho,
                ttft_slo_s,
            )],
            Topology::PoolRouting { b_short, short_ctx } => vec![
                PoolPlan::for_slice(
                    format!("short-{}k", short_ctx / 1024),
                    profile.clone(),
                    trace,
                    lambda_rps,
                    0.0,
                    b_short as f64,
                    short_ctx,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
                PoolPlan::for_slice(
                    "long-64k",
                    profile,
                    trace,
                    lambda_rps,
                    b_short as f64,
                    max_len,
                    LONG_CTX,
                    1.0,
                    lbar,
                    rho,
                    ttft_slo_s,
                ),
            ],
            Topology::FleetOpt { b_short, short_ctx, gamma } => {
                assert!(gamma >= 1.0, "γ must be >= 1");
                let eff_ctx = ((LONG_CTX as f64 / gamma).round() as u32).max(short_ctx);
                vec![
                    PoolPlan::for_slice(
                        format!("short-{}k", short_ctx / 1024),
                        profile.clone(),
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        format!("long-64k/γ{gamma}"),
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        eff_ctx,
                        gamma,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
            Topology::Semantic { b_short, short_ctx } => {
                let small = small_profile
                    .expect("Semantic topology needs a small-model profile");
                vec![
                    PoolPlan::for_slice(
                        format!("semantic-small-{}k", short_ctx / 1024),
                        small,
                        trace,
                        lambda_rps,
                        0.0,
                        b_short as f64,
                        short_ctx,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                    PoolPlan::for_slice(
                        "semantic-large-64k",
                        profile,
                        trace,
                        lambda_rps,
                        b_short as f64,
                        max_len,
                        LONG_CTX,
                        1.0,
                        lbar,
                        rho,
                        ttft_slo_s,
                    ),
                ]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;
    use crate::workload::cdf::azure_conversations;

    fn h100() -> Arc<dyn GpuProfile> {
        Arc::new(ManualProfile::h100_70b())
    }

    #[test]
    fn homo_is_one_pool_with_all_traffic() {
        let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
            &azure_conversations(), 1000.0, h100(), None,
            LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 1);
        assert!((pools[0].inputs.lambda_rps - 1000.0).abs() < 1e-6);
        assert_eq!(pools[0].inputs.context_tokens, LONG_CTX);
    }

    #[test]
    fn two_pool_split_conserves_traffic() {
        let pools = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools.len(), 2);
        let total: f64 = pools.iter().map(|p| p.inputs.lambda_rps).sum();
        assert!((total - 1000.0).abs() < 1e-6);
        assert!(pools[0].inputs.lambda_rps > pools[1].inputs.lambda_rps);
    }

    #[test]
    fn fleetopt_gamma_halves_effective_window() {
        let pools = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[1].inputs.context_tokens, LONG_CTX / 2);
    }

    #[test]
    #[should_panic(expected = "γ must be >= 1")]
    fn fleetopt_rejects_gamma_below_one() {
        Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 0.5 }
            .pools(&azure_conversations(), 1000.0, h100(), None,
                   LBarPolicy::Window, 0.85, 0.5);
    }

    #[test]
    fn semantic_uses_small_profile_for_short_pool() {
        let small: Arc<dyn GpuProfile> = Arc::new(ManualProfile {
            name: "small".into(),
            ..ManualProfile::h100_70b()
        });
        let pools = Topology::Semantic { b_short: 8192, short_ctx: 8192 }
            .pools(&azure_conversations(), 1000.0, h100(), Some(small),
                   LBarPolicy::Window, 0.85, 0.5);
        assert_eq!(pools[0].profile.label(), "small");
    }

    #[test]
    fn labels_are_informative() {
        assert!(Topology::Homogeneous { ctx: LONG_CTX }.label().contains("64K"));
        assert!(Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
            .label()
            .contains("γ=2"));
    }
}
