//! # wattlaw — The 1/W Law, as a deployable serving stack
//!
//! Reproduction of *"The 1/W Law: An Analytical Study of Context-Length
//! Routing Topology and GPU Generation Gains for LLM Inference Energy
//! Efficiency"* (CS.DC 2026) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordination contribution: context-length
//!   request routing, both static and load-aware over live fleet state
//!   ([`router`]), continuous batching and paged KV management
//!   ([`serve`]), the analytical fleet planner ([`fleet`], mirroring the
//!   paper's `inference-fleet-sim` API), an event-driven fleet simulator
//!   — one calendar/bucket event queue (amortized O(1) per event; the
//!   pre-refactor binary heap retained behind
//!   [`sim::QueueMode::BinaryHeap`] as a bit-for-bit replay oracle) and
//!   one virtual clock driving all groups of all pools concurrently,
//!   macro-stepped by default ([`sim::StepMode::Fused`]: quiescent
//!   decode spans between arrivals run in one in-line loop, so events
//!   scale with arrivals, not decode steps; the per-step schedule is
//!   the replay oracle),
//!   hot per-group state stored struct-of-arrays for cache-linear
//!   dispatch scans, with pluggable group-dispatch policies
//!   (round-robin / join-shortest-queue / least-KV-load /
//!   power-aware) and two parallel fast paths — the per-group split for
//!   materialized traces and, for arrival-static streams, a sharded
//!   demux that routes each arrival into a bounded per-group channel
//!   drained by one worker thread per group, bitwise the sequential
//!   result at O(groups) memory ([`sim`], worker counts resolved once
//!   by [`sim::par`]: `--workers` flag, then `WATTLAW_WORKERS`, then
//!   all cores) — a
//!   unified scenario layer feeding both the analytical planner and the
//!   simulator from one spec — four orthogonal fleet axes: routing
//!   topology (two-pool / FleetOpt-γ / K-pool context partitions), GPU
//!   generation *per pool* (heterogeneous fleets: an assignment vector
//!   like H100|H100|B200, resolved identically by both engines), model
//!   architecture ([`fleet::profile::ModelAxis`]: dense / MoE
//!   weight-streaming with an all-to-all `--dispatch-ms` knob /
//!   dense+speculative decode, resolved through one calibrated profile
//!   per model so both engines agree by construction), and
//!   workload — arrival processes as a first-class axis
//!   ([`workload::arrival`]): stationary Poisson, diurnal, flash-crowd,
//!   multi-tenant and heavy-tailed archetypes plus CSV trace replay
//!   (`--workload` / `--trace file.csv`), each a lazy
//!   [`workload::ArrivalSource`] the engine pulls one request at a time
//!   so trace memory stays O(1) at any λ × duration (the materialized
//!   path is retained as the bit-for-bit replay oracle) — with
//!   dispatch × topology × context-window sweeps whose cells are
//!   pulled off a shared work queue by worker threads (index-ordered
//!   merge, so any worker count emits identical bytes) and a two-stage
//!   (analytical screen → simulated refine) FleetOpt optimizer that
//!   searches assignment vectors by Eq. 4 branch-and-bound (admissible
//!   closed-form bound over partial assignments; brute-force
//!   cross-product retained as the oracle), greedy budgeted upgrades,
//!   or explicit lists, with one stage-A memo shared across the search
//!   axes so repeated Eq. 4 cells replay from cache — bitwise the
//!   uncached ranking, hit rate surfaced in the report ([`scenario`]) — a typed results subsystem every output surface
//!   emits through, with CSV/JSON alongside the text tables
//!   ([`results`]) — and per-GPU energy metering driven by the
//!   calibrated logistic power model ([`power`]).
//! * **L2/L1 (build-time Python)** — a tiny Llama-style decoder whose
//!   decode attention is a Pallas kernel, AOT-lowered to HLO text and
//!   executed from Rust through PJRT ([`runtime`]). Python never runs on
//!   the request path.
//!
//! The paper's headline claims, all regenerable via [`tables`] /
//! `wattlaw tables --all`:
//!
//! 1. **1/W law** — tokens-per-watt halves per context-window doubling
//!    ([`tokeconomy::law`]).
//! 2. **Topology × generation independence** — FleetOpt two-pool routing
//!    and an H100→B200 upgrade are orthogonal, multiplicative levers
//!    ([`tables::independence`]).
//! 3. **MoE architecture lever** — active-parameter weight streaming
//!    ([`roofline::moe`]), promoted to a scenario axis: `--model
//!    qwen3-moe` reproduces the ~38 tok/W headline and Table 10 shows
//!    the 1/W slope surviving weight streaming
//!    ([`tables::t10`]).

pub mod benchkit;
pub mod cli;
pub mod fleet;
pub mod model;
pub mod power;
pub mod queueing;
pub mod report;
pub mod results;
pub mod roofline;
pub mod router;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod tables;
pub mod tokeconomy;
pub mod units;
pub mod workload;
pub mod xcheck;
pub mod xrand;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
