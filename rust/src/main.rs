//! `wattlaw` — leader entrypoint.
//!
//! See `wattlaw help` (or [`wattlaw::cli`]) for commands. The analytic
//! commands run standalone; `serve`/`validate` need `make artifacts`
//! (build-time Python; never on the request path).

fn main() {
    let code = match wattlaw::cli::run(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}
