//! KV-cache geometry: κ (bytes per token per GPU) and the concurrency
//! limit `n_max` — paper Eq. (3):
//!
//! ```text
//! n_max(W) = floor( V_KV / (κ · W) )
//! ```
//!
//! This is the mechanism behind the 1/W law: doubling the serving context
//! window `W` halves `n_max` while the power draw at saturation barely
//! moves.
//!
//! The paper uses two κ conventions and we implement both:
//!
//! * [`KvPlacement::Sharded`] — tensor-parallel sharding of GQA KV heads:
//!   each GPU stores `max(n_kv / TP, 1)` heads. With Llama-3.1-70B's 8 KV
//!   heads at TP=8 that is one head per GPU. The paper's empirically
//!   calibrated H100 profile corresponds to κ ≈ 55 KB/token *including
//!   allocator overheads* — the pure-geometry value is 40 KB/token, so the
//!   calibrated fleet profile carries an explicit overhead factor.
//! * [`KvPlacement::Replicated`] — every GPU stores all KV heads (the
//!   paper's ComputedProfile used in Tables 2 and 5): κ counts the full
//!   `2 · bytes · layers · n_kv · head_dim`.

use super::spec::{ModelSpec, Precision};
use crate::power::GpuSpec;

/// How the KV cache is distributed across a TP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KvPlacement {
    /// TP-sharded GQA KV heads: `ceil(n_kv / tp)` heads per GPU (vLLM
    /// default for GQA models; the paper's fleet assumption).
    Sharded,
    /// Full KV replica per GPU (the paper's ComputedProfile convention).
    Replicated,
}

/// κ — KV-cache bytes per token *per GPU* for `model` under `placement`
/// at tensor parallelism `tp`.
pub fn kappa_bytes_per_token(
    model: &ModelSpec,
    placement: KvPlacement,
    tp: u32,
) -> f64 {
    if let Some(k) = model.kv_kappa_override {
        // MLA-style caches: the override is the full-replica value; TP
        // sharding divides it like any other per-token state.
        return match placement {
            KvPlacement::Replicated => k,
            KvPlacement::Sharded => k / tp as f64,
        };
    }
    let heads_per_gpu = match placement {
        KvPlacement::Replicated => model.n_kv_heads as f64,
        KvPlacement::Sharded => {
            // ceil(n_kv / tp), min 1: models with fewer KV heads than TP
            // ranks replicate the last head (paper §10.1).
            ((model.n_kv_heads as f64) / tp as f64).max(1.0).ceil()
        }
    };
    // K and V, each bytes × layers × heads × head_dim.
    2.0 * model.kv_precision.bytes()
        * model.n_layers as f64
        * heads_per_gpu
        * model.head_dim as f64
}

/// V_KV — per-GPU VRAM left for KV cache after model weights, in bytes.
/// Clamped at zero when weights alone exceed usable VRAM (the paper's
/// 405B-on-H100 "effectively unusable" regime).
pub fn kv_budget_bytes(
    gpu: &GpuSpec,
    model: &ModelSpec,
    prec: Precision,
    tp: u32,
) -> f64 {
    let usable = gpu.vram_usable().0 as f64;
    let weights = model.weight_bytes_per_gpu(prec, tp);
    (usable - weights).max(0.0)
}

/// Eq. (3): the KV-set concurrency limit for a serving context window of
/// `context_tokens`. Clamped below at 1 (a GPU can always hold one
/// sequence by evicting/recomputing — the paper's 405B row reports
/// n_max = 1 even where weights leave no KV headroom).
pub fn n_max(v_kv_bytes: f64, kappa: f64, context_tokens: u32) -> u32 {
    let n = v_kv_bytes / (kappa * context_tokens as f64);
    (n.floor() as u32).max(1)
}

/// Convenience: n_max straight from catalog entries.
pub fn n_max_for(
    gpu: &GpuSpec,
    model: &ModelSpec,
    prec: Precision,
    tp: u32,
    placement: KvPlacement,
    context_tokens: u32,
) -> u32 {
    let v = kv_budget_bytes(gpu, model, prec, tp);
    let k = kappa_bytes_per_token(model, placement, tp);
    n_max(v, k, context_tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::*;
    use crate::power::profiles::{B200, H100};

    #[test]
    fn kappa_70b_sharded_tp8_is_40kb_geometry() {
        // 2 × 2 B × 80 layers × 1 head × 128 = 40 960 B. The paper's 55 KB
        // includes allocator overhead (handled by ManualProfile).
        let k = kappa_bytes_per_token(&LLAMA31_70B, KvPlacement::Sharded, 8);
        assert_eq!(k, 40_960.0);
    }

    #[test]
    fn kappa_70b_replicated_is_320kb() {
        // Table 2 convention: 2 × 2 × 80 × 8 × 128 = 327 680 B = 320 KiB.
        let k = kappa_bytes_per_token(&LLAMA31_70B, KvPlacement::Replicated, 8);
        assert_eq!(k, 327_680.0);
    }

    #[test]
    fn kappa_8b_replicated_is_128kib() {
        let k = kappa_bytes_per_token(&LLAMA31_8B, KvPlacement::Replicated, 1);
        assert_eq!(k, 131_072.0);
    }

    #[test]
    fn sharded_clamps_at_one_head() {
        // Qwen3 has 4 KV heads; at TP=8 each GPU still stores >= 1 head.
        let k8 = kappa_bytes_per_token(&QWEN3_235B_A22B, KvPlacement::Sharded, 8);
        let k4 = kappa_bytes_per_token(&QWEN3_235B_A22B, KvPlacement::Sharded, 4);
        assert_eq!(k8, k4, "below one head per GPU the shard stops shrinking");
    }

    #[test]
    fn table2_n_max_dense_rows() {
        // Table 2 (ComputedProfile, replicated KV, 8K context):
        // 8B/H100 TP1 -> 58; 70B/H100 TP8 -> 22; 405B/B200 TP8 -> 17.
        let n_8b = n_max_for(&H100, &LLAMA31_8B, Precision::Fp16, 1,
                             KvPlacement::Replicated, 8192);
        assert!((57..=58).contains(&n_8b), "8B H100: {n_8b}");

        let n_70b = n_max_for(&H100, &LLAMA31_70B, Precision::Fp16, 8,
                              KvPlacement::Replicated, 8192);
        assert!((22..=23).contains(&n_70b), "70B H100: {n_70b}");

        let n_405b_h100 = n_max_for(&H100, &LLAMA31_405B, Precision::Fp16, 8,
                                    KvPlacement::Replicated, 8192);
        assert_eq!(n_405b_h100, 1, "405B does not fit on H100 at fp16");

        let n_405b_b200 = n_max_for(&B200, &LLAMA31_405B, Precision::Fp16, 8,
                                    KvPlacement::Replicated, 8192);
        assert!((16..=18).contains(&n_405b_b200), "405B B200: {n_405b_b200}");

        let n_70b_b200 = n_max_for(&B200, &LLAMA31_70B, Precision::Fp16, 8,
                                   KvPlacement::Replicated, 8192);
        assert!((58..=60).contains(&n_70b_b200), "70B B200: {n_70b_b200}");
    }

    #[test]
    fn n_max_halves_per_context_doubling() {
        // The 1/W mechanism at the Eq. (3) level. Sharded κ keeps n_max
        // large enough that the floor() rounding stays below 5 %.
        let v = kv_budget_bytes(&H100, &LLAMA31_70B, Precision::Fp16, 8);
        let k = kappa_bytes_per_token(&LLAMA31_70B, KvPlacement::Sharded, 8);
        let mut prev = n_max(v, k, 2048);
        for ctx in [4096u32, 8192, 16384, 32768] {
            let n = n_max(v, k, ctx);
            let ratio = prev as f64 / n as f64;
            assert!((ratio - 2.0).abs() < 0.1, "ctx {ctx}: ratio {ratio}");
            prev = n;
        }
    }

    #[test]
    fn n_max_never_zero() {
        assert_eq!(n_max(0.0, 40_960.0, 65_536), 1);
    }

    #[test]
    fn fp8_doubles_kv_budget_headroom() {
        let v16 = kv_budget_bytes(&H100, &LLAMA31_70B, Precision::Fp16, 8);
        let v8 = kv_budget_bytes(&H100, &LLAMA31_70B, Precision::Fp8, 8);
        assert!(v8 > v16, "fp8 weights leave more KV room");
        assert!((v8 - v16 - 8.75e9).abs() < 1e7); // half the 17.5 GB back
    }
}
