//! Model catalog and KV-cache geometry: the `κ` (KV bytes/token) and
//! `n_max` math of paper Eq. (3), with both KV placements the paper uses
//! (TP-sharded GQA heads for the calibrated fleet profile; replicated
//! heads for the ComputedProfile of Tables 2/5).

pub mod kv;
pub mod spec;

pub use kv::{KvPlacement, n_max, kappa_bytes_per_token, kv_budget_bytes};
pub use spec::{ModelSpec, Precision};
