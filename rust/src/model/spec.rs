//! The model catalog — every model the paper evaluates (Table 2), plus the
//! serving-demo tiny model whose geometry mirrors `python/compile/model.py`.

/// Weight/KV numeric precision (paper §5.2 quantization lever).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp16,
    Fp8,
    Int4,
}

impl Precision {
    /// Bytes per parameter/element.
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Fp16 => 2.0,
            Precision::Fp8 => 1.0,
            Precision::Int4 => 0.5,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::Fp8 => "fp8",
            Precision::Int4 => "int4",
        }
    }
}

/// Architectural description of one model, sufficient for the roofline
/// (weight bytes), the KV geometry (κ), and the MoE override (§3.2).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Total parameters, billions.
    pub total_params_b: f64,
    /// Parameters activated per token, billions (== total for dense).
    pub active_params_b: f64,
    pub n_layers: u32,
    pub n_q_heads: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// True for mixture-of-experts models (Table 2 † rows).
    pub is_moe: bool,
    /// Default weight precision in the paper's tables.
    pub default_precision: Precision,
    /// KV-cache element precision (DeepSeek-V3's MLA stores a compressed
    /// latent; modeled via `kv_kappa_override`).
    pub kv_precision: Precision,
    /// Explicit κ override in bytes/token *per full replica* (all layers,
    /// all KV heads). Used for MLA-style caches that the GQA formula
    /// cannot express. `None` → computed from the GQA geometry.
    pub kv_kappa_override: Option<f64>,
}

impl ModelSpec {
    /// Weight bytes for the whole model at `prec`.
    pub fn weight_bytes(&self, prec: Precision) -> f64 {
        self.total_params_b * 1e9 * prec.bytes()
    }

    /// Weight bytes streamed per decode iteration (MoE: active only).
    pub fn active_weight_bytes(&self, prec: Precision) -> f64 {
        self.active_params_b * 1e9 * prec.bytes()
    }

    /// Per-GPU weight bytes under TP sharding.
    pub fn weight_bytes_per_gpu(&self, prec: Precision, tp: u32) -> f64 {
        self.weight_bytes(prec) / tp as f64
    }

    /// Activation ratio (22/235 ≈ 9 % for Qwen3-235B-A22B).
    pub fn activation_ratio(&self) -> f64 {
        self.active_params_b / self.total_params_b
    }

    pub fn parse(name: &str) -> Option<&'static ModelSpec> {
        let n = name.to_ascii_lowercase();
        CATALOG
            .iter()
            .find(|m| m.name.to_ascii_lowercase().contains(&n))
            .copied()
    }
}

/// Llama-3.1-8B (dense).
pub static LLAMA31_8B: ModelSpec = ModelSpec {
    name: "Llama-3.1-8B",
    total_params_b: 8.0,
    active_params_b: 8.0,
    n_layers: 32,
    n_q_heads: 32,
    n_kv_heads: 8,
    head_dim: 128,
    is_moe: false,
    default_precision: Precision::Fp16,
    kv_precision: Precision::Fp16,
    kv_kappa_override: None,
};

/// Llama-3.1-70B (dense) — the paper's workhorse.
pub static LLAMA31_70B: ModelSpec = ModelSpec {
    name: "Llama-3.1-70B",
    total_params_b: 70.0,
    active_params_b: 70.0,
    n_layers: 80,
    n_q_heads: 64,
    n_kv_heads: 8,
    head_dim: 128,
    is_moe: false,
    default_precision: Precision::Fp16,
    kv_precision: Precision::Fp16,
    kv_kappa_override: None,
};

/// Llama-3.1-405B (dense).
pub static LLAMA31_405B: ModelSpec = ModelSpec {
    name: "Llama-3.1-405B",
    total_params_b: 405.0,
    active_params_b: 405.0,
    n_layers: 126,
    n_q_heads: 128,
    n_kv_heads: 8,
    head_dim: 128,
    is_moe: false,
    default_precision: Precision::Fp16,
    kv_precision: Precision::Fp16,
    kv_kappa_override: None,
};

/// Qwen3-235B-A22B (MoE; 22B active of 235B total).
pub static QWEN3_235B_A22B: ModelSpec = ModelSpec {
    name: "Qwen3-235B-A22B",
    total_params_b: 235.0,
    active_params_b: 22.0,
    n_layers: 94,
    n_q_heads: 64,
    n_kv_heads: 4,
    head_dim: 128,
    is_moe: true,
    default_precision: Precision::Fp16,
    kv_precision: Precision::Fp16,
    kv_kappa_override: None,
};

/// DeepSeek-V3 (MoE, fp8; ≈37B active of 671B; MLA compressed KV —
/// κ override: (512 latent + 64 rope) dims × 61 layers × 1 B ≈ 35 KB/tok).
pub static DEEPSEEK_V3: ModelSpec = ModelSpec {
    name: "DeepSeek-V3",
    total_params_b: 671.0,
    active_params_b: 37.0,
    n_layers: 61,
    n_q_heads: 128,
    n_kv_heads: 128, // MLA: not GQA — κ comes from the override
    head_dim: 128,
    is_moe: true,
    default_precision: Precision::Fp8,
    kv_precision: Precision::Fp8,
    kv_kappa_override: Some(35_136.0), // (512+64) * 61 * 1 B
};

/// The serving-demo tiny model (mirrors python/compile/model.py ModelConfig;
/// f32 on CPU PJRT).
pub static TINY_LLAMA: ModelSpec = ModelSpec {
    name: "TinyLlama-2.9M",
    total_params_b: 0.0029,
    active_params_b: 0.0029,
    n_layers: 4,
    n_q_heads: 8,
    n_kv_heads: 2,
    head_dim: 32,
    is_moe: false,
    default_precision: Precision::Fp16, // analytical default; runtime is f32
    kv_precision: Precision::Fp16,
    kv_kappa_override: None,
};

/// Every model the paper's Table 2 covers.
pub static CATALOG: [&ModelSpec; 5] = [
    &LLAMA31_8B,
    &LLAMA31_70B,
    &LLAMA31_405B,
    &QWEN3_235B_A22B,
    &DEEPSEEK_V3,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_models_activate_everything() {
        for m in [&LLAMA31_8B, &LLAMA31_70B, &LLAMA31_405B] {
            assert!(!m.is_moe);
            assert_eq!(m.activation_ratio(), 1.0);
        }
    }

    #[test]
    fn qwen_activation_ratio_is_nine_percent() {
        let r = QWEN3_235B_A22B.activation_ratio();
        assert!((r - 22.0 / 235.0).abs() < 1e-12);
        assert!((r - 0.094).abs() < 0.002, "paper: ≈9 %");
    }

    #[test]
    fn weight_bytes_per_gpu_70b_tp8_fp16_is_17_5_gb() {
        let b = LLAMA31_70B.weight_bytes_per_gpu(Precision::Fp16, 8);
        assert!((b / 1e9 - 17.5).abs() < 1e-9);
    }

    #[test]
    fn fp8_halves_int4_quarters_weight_bytes() {
        let w16 = LLAMA31_70B.weight_bytes(Precision::Fp16);
        assert!((LLAMA31_70B.weight_bytes(Precision::Fp8) / w16 - 0.5).abs() < 1e-12);
        assert!((LLAMA31_70B.weight_bytes(Precision::Int4) / w16 - 0.25).abs() < 1e-12);
    }

    #[test]
    fn deepseek_kappa_override_present() {
        assert!(DEEPSEEK_V3.kv_kappa_override.unwrap() > 30_000.0);
    }
}
