//! Least-squares logistic fit — the calibration step that turns
//! ML.ENERGY-style `(batch, watts)` samples into a [`LogisticPower`] model
//! (paper §2.1 / Appendix A).
//!
//! Strategy: coarse grid search over `(k, x0)` with closed-form linear
//! least squares for `(P_idle, P_range)` at each grid point (the model is
//! linear in those two once the logistic shape is fixed), followed by
//! Nelder–Mead-style local refinement. No external optimizer crates are
//! available offline, and the 2-D problem is tiny, so this is both robust
//! and fast (<1 ms per fit).

use super::logistic::LogisticPower;
use super::mlenergy::PowerSample;

/// Result of a calibration fit.
#[derive(Debug, Clone, Copy)]
pub struct FitResult {
    pub model: LogisticPower,
    /// Root-mean-square error, watts.
    pub rmse_w: f64,
    /// Maximum relative error across samples.
    pub max_rel_err: f64,
}

/// Logistic shape value s(b) = 1 / (1 + e^{-k (log2 b - x0)}).
#[inline]
fn shape(b: f64, k: f64, x0: f64) -> f64 {
    1.0 / (1.0 + (-(k * (b.log2() - x0))).exp())
}

/// Closed-form least squares for (p_idle, p_range) given fixed (k, x0):
/// watts ≈ p_idle + p_range * s(b) is linear in the two unknowns.
fn linear_solve(samples: &[PowerSample], k: f64, x0: f64) -> (f64, f64, f64) {
    let n = samples.len() as f64;
    let (mut ss, mut s1, mut sy, mut ssy) = (0.0, 0.0, 0.0, 0.0);
    for p in samples {
        let s = shape(p.batch, k, x0);
        ss += s * s;
        s1 += s;
        sy += p.watts;
        ssy += s * p.watts;
    }
    let det = n * ss - s1 * s1;
    if det.abs() < 1e-12 {
        return (f64::NAN, f64::NAN, f64::INFINITY);
    }
    let p_range = (n * ssy - s1 * sy) / det;
    let p_idle = (sy - p_range * s1) / n;
    let mut sse = 0.0;
    for p in samples {
        let e = p_idle + p_range * shape(p.batch, k, x0) - p.watts;
        sse += e * e;
    }
    (p_idle, p_range, sse)
}

/// Fit the logistic power model to measurement samples.
pub fn fit_logistic(samples: &[PowerSample]) -> FitResult {
    assert!(samples.len() >= 4, "need >= 4 samples to fit 4 parameters");

    // Coarse grid.
    let mut best = (f64::INFINITY, 1.0, 4.0, 0.0, 0.0); // (sse, k, x0, idle, range)
    let mut k = 0.2;
    while k <= 4.0 {
        let mut x0 = 0.0;
        while x0 <= 10.0 {
            let (pi, pr, sse) = linear_solve(samples, k, x0);
            if sse < best.0 && pr > 0.0 && pi > 0.0 {
                best = (sse, k, x0, pi, pr);
            }
            x0 += 0.1;
        }
        k += 0.05;
    }

    // Local refinement: coordinate descent with shrinking steps.
    let (mut sse, mut k, mut x0, mut pi, mut pr) = best;
    let mut step = 0.05;
    for _ in 0..60 {
        let mut improved = false;
        for (dk, dx) in [(step, 0.0), (-step, 0.0), (0.0, step), (0.0, -step)] {
            let (npi, npr, nsse) = linear_solve(samples, k + dk, x0 + dx);
            if nsse < sse && npr > 0.0 && npi > 0.0 {
                sse = nsse;
                k += dk;
                x0 += dx;
                pi = npi;
                pr = npr;
                improved = true;
            }
        }
        if !improved {
            step *= 0.5;
            if step < 1e-5 {
                break;
            }
        }
    }

    let model = LogisticPower::new(pi, pi + pr, k, x0);
    let rmse = (sse / samples.len() as f64).sqrt();
    let max_rel = super::mlenergy::max_rel_error(&model, samples);
    FitResult {
        model,
        rmse_w: rmse,
        max_rel_err: max_rel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::mlenergy;

    #[test]
    fn recovers_published_h100_parameters_from_clean_anchors() {
        let fit = fit_logistic(&mlenergy::h100_anchors());
        let m = fit.model;
        assert!((m.k - 1.0).abs() < 0.05, "k = {}", m.k);
        assert!((m.x0 - 4.2).abs() < 0.1, "x0 = {}", m.x0);
        assert!((m.p_idle_w - 300.0).abs() < 5.0, "p_idle = {}", m.p_idle_w);
        assert!((m.p_nom_w - 600.0).abs() < 8.0, "p_nom = {}", m.p_nom_w);
        assert!(fit.rmse_w < 0.5);
    }

    #[test]
    fn fit_error_stays_under_paper_band_with_noise() {
        // The paper reports <3 % fit error; with 3 % measurement noise the
        // refit must stay inside ~2x that band.
        for seed in 0..10 {
            let samples = mlenergy::h100_measurements(seed, 0.03);
            let fit = fit_logistic(&samples);
            assert!(
                fit.max_rel_err < 0.06,
                "seed {seed}: max rel err {}",
                fit.max_rel_err
            );
        }
    }

    #[test]
    fn fit_is_deterministic() {
        let s = mlenergy::h100_measurements(1, 0.02);
        let a = fit_logistic(&s);
        let b = fit_logistic(&s);
        assert_eq!(a.model, b.model);
    }

    #[test]
    fn recovers_b200_projection_from_its_own_curve() {
        let truth = LogisticPower::new(430.0, 860.0, 1.0, 6.8);
        let samples: Vec<_> = [1.0, 4.0, 16.0, 64.0, 128.0, 256.0, 512.0, 1024.0]
            .iter()
            .map(|&b| PowerSample {
                batch: b,
                watts: truth.power_w(b),
            })
            .collect();
        let fit = fit_logistic(&samples);
        assert!((fit.model.x0 - 6.8).abs() < 0.15, "x0 = {}", fit.model.x0);
        assert!((fit.model.p_idle_w - 430.0).abs() < 10.0);
    }

    #[test]
    #[should_panic(expected = "need >= 4 samples")]
    fn too_few_samples_panics() {
        fit_logistic(&[
            PowerSample { batch: 1.0, watts: 300.0 },
            PowerSample { batch: 2.0, watts: 320.0 },
        ]);
    }
}
