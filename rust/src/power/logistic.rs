//! The logistic power-vs-concurrency model — paper Eq. (1):
//!
//! ```text
//! P(b) = P_range / (1 + e^{-k (log2 b - x0)}) + P_idle
//! ```
//!
//! where `b` is the number of concurrently in-flight sequences
//! (`max_num_seqs` in vLLM terms), `P_idle` the idle floor, `P_range =
//! P_nom − P_idle` the dynamic range, `k` the slope and `x0` the
//! half-saturation point in log2 batch units.
//!
//! Liang et al. fitted H100-SXM5 under vLLM + Llama-3.1-class decode to
//! `k = 1.0`, `x0 = 4.2` against ML.ENERGY anchors `P(1) ≈ 300 W`,
//! `P(128) ≈ 600 W` (<3 % error). This module is the single source of
//! truth for power everywhere in the crate: analytical tables, the fleet
//! planner, the discrete-event simulator, and the live energy meter in the
//! serving engine all call [`LogisticPower::power_w`].

use crate::units::Watts;

/// Calibrated logistic power curve for one GPU under LLM decode load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogisticPower {
    /// Idle power floor in watts (`P(b→0⁺)` asymptote).
    pub p_idle_w: f64,
    /// Nominal saturated power in watts; `P_range = p_nom_w − p_idle_w`.
    pub p_nom_w: f64,
    /// Logistic slope in log2-batch units.
    pub k: f64,
    /// Half-saturation point: power reaches midrange at `b = 2^{x0}`.
    pub x0: f64,
}

impl LogisticPower {
    pub const fn new(p_idle_w: f64, p_nom_w: f64, k: f64, x0: f64) -> Self {
        Self {
            p_idle_w,
            p_nom_w,
            k,
            x0,
        }
    }

    /// The published H100-SXM5 calibration (HIGH quality).
    pub const fn h100() -> Self {
        Self::new(300.0, 600.0, 1.0, 4.2)
    }

    /// Dynamic range `P_nom − P_idle`.
    #[inline]
    pub fn p_range_w(&self) -> f64 {
        self.p_nom_w - self.p_idle_w
    }

    /// Eq. (1). `b` is clamped below at a vanishing batch (b → 0 gives the
    /// idle floor); fractional `b` (mean in-flight batch) is meaningful and
    /// used by the fleet model.
    #[inline]
    pub fn power_w(&self, b: f64) -> f64 {
        if b <= 0.0 {
            return self.p_idle_w;
        }
        let z = self.k * (b.log2() - self.x0);
        self.p_range_w() / (1.0 + (-z).exp()) + self.p_idle_w
    }

    /// Typed convenience wrapper.
    pub fn power(&self, b: f64) -> Watts {
        Watts(self.power_w(b))
    }

    /// Batch size at which power reaches `frac` of the dynamic range
    /// (inverse of Eq. 1); e.g. `saturation_batch(0.95)`.
    pub fn saturation_batch(&self, frac: f64) -> f64 {
        assert!(
            (0.0..1.0).contains(&frac) && frac > 0.0,
            "frac must be in (0,1)"
        );
        let z = (frac / (1.0 - frac)).ln();
        (self.x0 + z / self.k).exp2()
    }

    /// Energy (joules) spent holding batch `b` for `secs` seconds.
    pub fn energy_j(&self, b: f64, secs: f64) -> f64 {
        self.power_w(b) * secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    /// The paper's Table 1 P_sat column is reproduced by the published
    /// (k=1.0, x0=4.2, 300/600 W) parameters — verify every row.
    #[test]
    fn table1_h100_power_column() {
        let p = LogisticPower::h100();
        let rows: &[(f64, f64)] = &[
            (512.0, 598.0),
            (256.0, 593.0),
            (128.0, 583.0),
            (64.0, 557.0),
            (32.0, 507.0),
            (16.0, 435.0),
            (8.0, 369.0),
        ];
        for &(b, want) in rows {
            let got = p.power_w(b);
            assert!(close(got, want, 1.0), "P({b}) = {got}, want {want}");
        }
    }

    #[test]
    fn idle_floor_and_monotonicity() {
        let p = LogisticPower::h100();
        assert_eq!(p.power_w(0.0), 300.0);
        let mut prev = 0.0;
        for i in 0..20 {
            let b = (i as f64 / 2.0).exp2();
            let w = p.power_w(b);
            assert!(w >= prev, "power must be non-decreasing in b");
            assert!(w <= p.p_nom_w + 1e-9);
            prev = w;
        }
    }

    #[test]
    fn half_saturation_at_x0() {
        let p = LogisticPower::h100();
        let b_half = (4.2f64).exp2();
        let want = 300.0 + 150.0;
        assert!(close(p.power_w(b_half), want, 1e-9));
    }

    #[test]
    fn saturation_batch_inverts_power() {
        let p = LogisticPower::h100();
        for frac in [0.1, 0.5, 0.9, 0.99] {
            let b = p.saturation_batch(frac);
            let got = (p.power_w(b) - p.p_idle_w) / p.p_range_w();
            assert!(close(got, frac, 1e-9), "frac {frac} -> {got}");
        }
        // Paper: "power saturates around 2^4.2 ≈ 18 concurrent sequences"
        assert!(close(p.saturation_batch(0.5), 18.38, 0.01));
    }

    #[test]
    fn b200_projection_anchors() {
        // FAIR-quality projection: TDP fractions 0.43 / 0.86 on 1000 W.
        // x0 = 4.45 closes the paper's own Table 1 column (its published
        // x0 = 6.8 does not — see profiles.rs).
        let p = LogisticPower::new(430.0, 860.0, 1.0, 4.45);
        assert_eq!(p.power_w(0.0), 430.0);
        // Table 1 B200 P_sat column.
        for &(b, want) in &[
            (1343.0, 859.0),
            (671.0, 857.0),
            (335.0, 852.0),
            (167.0, 838.0),
            (83.0, 805.0),
            (41.0, 735.0),
            (20.0, 630.0),
        ] {
            let got = p.power_w(b);
            assert!(close(got, want, 1.5), "P({b}) = {got}, want {want}");
        }
    }

    #[test]
    fn energy_integrates_power() {
        let p = LogisticPower::h100();
        assert!(close(p.energy_j(16.0, 10.0), 4350.0, 15.0));
    }
}
