//! Synthetic ML.ENERGY-style measurement set.
//!
//! The paper calibrates its H100 logistic against ML.ENERGY Benchmark v3.0
//! (Chung et al.) Figure-2 data: H100-SXM5, vLLM, Llama-3.1-class, batch
//! sizes b ∈ {1, 2, 4, 8, 16, 32, 64, 128, 256}, fit error <3 %. That
//! dataset is not redistributable here, so — per the substitution rule in
//! DESIGN.md — we regenerate measurement points from the *published fit*
//! (anchors P(1)=300 W, P(128)=600 W, k=1.0, x0=4.2) plus deterministic
//! measurement noise inside the published <3 % error band.
//!
//! [`fit::fit_logistic`](super::fit) must then recover the parameters from
//! these points — closing the same loop the paper describes.

use super::logistic::LogisticPower;
use crate::xrand::Rng;

/// One power measurement: (in-flight batch size, mean watts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerSample {
    pub batch: f64,
    pub watts: f64,
}

/// The batch sizes ML.ENERGY v3.0 sweeps.
pub const MLENERGY_BATCHES: [f64; 9] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0];

/// Regenerate the H100-SXM5 measurement set from the published fit with
/// multiplicative noise bounded by `noise_frac` (default ≤3 %, the paper's
/// stated fit error). Deterministic in `seed`.
pub fn h100_measurements(seed: u64, noise_frac: f64) -> Vec<PowerSample> {
    let truth = LogisticPower::h100();
    let mut rng = Rng::new(seed);
    MLENERGY_BATCHES
        .iter()
        .map(|&b| {
            // Uniform in [-noise, +noise]; multiplicative, like meter error.
            let eps = (rng.f64() * 2.0 - 1.0) * noise_frac;
            PowerSample {
                batch: b,
                watts: truth.power_w(b) * (1.0 + eps),
            }
        })
        .collect()
}

/// Noise-free anchor points (exactly the published curve).
pub fn h100_anchors() -> Vec<PowerSample> {
    let truth = LogisticPower::h100();
    MLENERGY_BATCHES
        .iter()
        .map(|&b| PowerSample {
            batch: b,
            watts: truth.power_w(b),
        })
        .collect()
}

/// Maximum relative error of `model` against `samples`.
pub fn max_rel_error(model: &LogisticPower, samples: &[PowerSample]) -> f64 {
    samples
        .iter()
        .map(|s| ((model.power_w(s.batch) - s.watts) / s.watts).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_published_endpoints() {
        let a = h100_anchors();
        assert_eq!(a.len(), 9);
        // P(1) ~ 304 W (logistic at b=1), P(128) ~ 583 W; the paper's
        // "300 W at b=1, 600 W at b=128" anchors are within its own 3 %.
        let p1 = a[0].watts;
        let p128 = a[7].watts;
        assert!((p1 - 300.0).abs() / 300.0 < 0.03, "P(1)={p1}");
        assert!((p128 - 600.0).abs() / 600.0 < 0.03, "P(128)={p128}");
    }

    #[test]
    fn noisy_measurements_stay_in_band() {
        // Noise is multiplicative relative to truth, so the error relative
        // to the *sample* is |ε|/(1+ε) ≤ 0.031 at ε = −0.03.
        let truth = LogisticPower::h100();
        for seed in 0..20 {
            let ms = h100_measurements(seed, 0.03);
            assert!(max_rel_error(&truth, &ms) <= 0.031);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(h100_measurements(5, 0.03), h100_measurements(5, 0.03));
        assert_ne!(h100_measurements(5, 0.03), h100_measurements(6, 0.03));
    }
}
