//! GPU power modeling: the logistic `P(b)` curve (paper Eq. 1), the GPU
//! catalog with measurement-quality tags (paper Table 7), the logistic
//! fitter used to calibrate against ML.ENERGY-style measurements, and the
//! synthetic measurement set regenerated from the published H100 anchors.

pub mod fit;
pub mod logistic;
pub mod mlenergy;
pub mod profiles;

pub use logistic::LogisticPower;
pub use profiles::{Gpu, GpuSpec, Quality};
