//! The GPU catalog — paper Table 7 (Appendix A) plus the hardware
//! parameters consumed by the roofline (bandwidths, VRAM) and the cost
//! model (Table 5's $/hr column).
//!
//! H100-SXM5 is directly measured (HIGH quality). H200/B200/GB200 power is
//! projected from TDP fractions validated on H100 (`P_idle = 0.43·TDP`,
//! `P_nom = 0.86·TDP`) and carries the paper's stated ±15–20 % uncertainty;
//! every consumer of a FAIR profile inherits the tag so tables can label
//! projections honestly.

use super::logistic::LogisticPower;
use crate::units::Bytes;

/// Measurement quality of a power profile (paper's HIGH/FAIR labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Quality {
    /// Directly measured (ML.ENERGY v3.0 anchors; <3 % fit error).
    High,
    /// First-principles projection from TDP fractions; ±15–20 %.
    Fair,
}

impl Quality {
    pub fn label(self) -> &'static str {
        match self {
            Quality::High => "HIGH",
            Quality::Fair => "FAIR",
        }
    }
}

/// GPU generations covered by the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gpu {
    H100,
    H200,
    B200,
    GB200,
}

impl Gpu {
    pub const ALL: [Gpu; 4] = [Gpu::H100, Gpu::H200, Gpu::B200, Gpu::GB200];

    pub fn spec(self) -> &'static GpuSpec {
        match self {
            Gpu::H100 => &H100,
            Gpu::H200 => &H200,
            Gpu::B200 => &B200,
            Gpu::GB200 => &GB200,
        }
    }

    /// Compact generation name ("H100") for per-pool assignment labels
    /// like `H100|H100|B200`, where the full SKU name
    /// ([`GpuSpec::name`], "H100-SXM5") would drown the vector.
    pub fn short_name(self) -> &'static str {
        match self {
            Gpu::H100 => "H100",
            Gpu::H200 => "H200",
            Gpu::B200 => "B200",
            Gpu::GB200 => "GB200",
        }
    }

    pub fn parse(name: &str) -> Option<Gpu> {
        match name.to_ascii_lowercase().as_str() {
            "h100" | "h100-sxm5" => Some(Gpu::H100),
            "h200" | "h200-sxm" => Some(Gpu::H200),
            "b200" | "b200-sxm" => Some(Gpu::B200),
            "gb200" | "gb200-nvl" => Some(Gpu::GB200),
            _ => None,
        }
    }
}

/// Full hardware + power description of one GPU SKU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Thermal design power, watts.
    pub tdp_w: f64,
    /// Calibrated/projected logistic power curve.
    pub power: LogisticPower,
    /// Peak HBM bandwidth, bytes/second.
    pub mem_bw_bytes_s: f64,
    /// Achievable fraction of peak bandwidth for contiguous weight
    /// streaming (calibrated so H100/70B gives the paper's W = 6.72 ms).
    pub bw_eff_weights: f64,
    /// Achievable fraction of peak bandwidth for the KV-cache scan
    /// (calibrated so H100/70B gives the paper's H0 = 0.1387 ms @8K).
    pub bw_eff_kv: f64,
    /// Total HBM capacity.
    pub vram: Bytes,
    /// Fraction of VRAM usable for weights+KV after framework overheads
    /// (calibrated so H100 leaves the paper's 60 GB KV budget under 70B).
    pub vram_usable_frac: f64,
    /// Rental cost, $/hr for a TP=8 group (paper Table 5 convention).
    pub rental_per_hr_tp8: f64,
    /// Power-measurement quality tag.
    pub quality: Quality,
    /// Stated uncertainty on absolute tok/W for this profile, percent.
    pub uncertainty_pct: f64,
}

impl GpuSpec {
    /// Usable VRAM in bytes after framework overheads.
    pub fn vram_usable(&self) -> Bytes {
        Bytes((self.vram.0 as f64 * self.vram_usable_frac) as u64)
    }

    /// Effective weight-streaming bandwidth, bytes/s.
    pub fn bw_weights(&self) -> f64 {
        self.mem_bw_bytes_s * self.bw_eff_weights
    }

    /// Effective KV-scan bandwidth, bytes/s.
    pub fn bw_kv(&self) -> f64 {
        self.mem_bw_bytes_s * self.bw_eff_kv
    }
}

const TB: f64 = 1e12;

/// H100-SXM5 — HIGH quality (ML.ENERGY v3.0 anchors, G2G logistic fit).
pub static H100: GpuSpec = GpuSpec {
    name: "H100-SXM5",
    tdp_w: 700.0,
    power: LogisticPower::new(300.0, 600.0, 1.0, 4.2),
    mem_bw_bytes_s: 3.35 * TB,
    // 17.5 GB of 70B TP=8 weights in 6.72 ms -> 2.604 TB/s effective.
    bw_eff_weights: 0.7773,
    // 55 KB/tok * 8192 in 0.1387 ms -> 3.249 TB/s effective.
    bw_eff_kv: 0.9698,
    vram: Bytes(80 * Bytes::GB),
    vram_usable_frac: 0.969, // leaves 60.0 GB KV budget under 70B TP=8
    rental_per_hr_tp8: 32.2,
    quality: Quality::High,
    uncertainty_pct: 3.0,
};

/// H200-SXM — FAIR (same TDP class as H100; HBM3e).
///
/// `x0` note: no published H200 power-vs-concurrency measurements exist;
/// we inherit H100's *measured* saturation point (x0 = 4.2) rather than
/// the paper's Appendix-A 5.5, for the same reason as B200 below — the
/// published x0 values do not reproduce the paper's own power columns.
pub static H200: GpuSpec = GpuSpec {
    name: "H200-SXM",
    tdp_w: 700.0,
    power: LogisticPower::new(300.0, 600.0, 1.0, 4.2),
    mem_bw_bytes_s: 4.8 * TB,
    bw_eff_weights: 0.7773,
    bw_eff_kv: 0.9698,
    vram: Bytes(141 * Bytes::GB),
    vram_usable_frac: 0.969,
    rental_per_hr_tp8: 48.0,
    quality: Quality::Fair,
    uncertainty_pct: 15.0,
};

/// B200-SXM — FAIR (TDP-fraction projection: 0.43/0.86 of 1000 W).
///
/// `x0` note: the paper's Appendix-A table lists x0 = 6.8 for B200, but
/// its own Table 1 B200 power column is only reproduced by x0 ≈ 4.45
/// (every row then lands within 1.5 W). We adopt the value that closes
/// the calibration table and record the discrepancy in EXPERIMENTS.md.
pub static B200: GpuSpec = GpuSpec {
    name: "B200-SXM",
    tdp_w: 1000.0,
    power: LogisticPower::new(430.0, 860.0, 1.0, 4.45),
    mem_bw_bytes_s: 8.0 * TB,
    // 17.5 GB in 2.95 ms -> 5.93 TB/s effective.
    bw_eff_weights: 0.7415,
    // Table 1 implies H0 = 0.0670 ms -> 6.72 TB/s effective.
    bw_eff_kv: 0.8403,
    vram: Bytes(180 * Bytes::GB),
    vram_usable_frac: 0.964, // leaves ~156 GB KV budget under 70B TP=8
    rental_per_hr_tp8: 64.0,
    quality: Quality::Fair,
    uncertainty_pct: 20.0,
};

/// GB200-NVL — FAIR. Same silicon as B200 but higher per-GPU-equivalent
/// TDP (shared NVL infrastructure) and slightly more memory.
pub static GB200: GpuSpec = GpuSpec {
    name: "GB200-NVL",
    tdp_w: 1200.0,
    power: LogisticPower::new(516.0, 1032.0, 1.0, 4.45),
    mem_bw_bytes_s: 8.0 * TB,
    bw_eff_weights: 0.7415,
    bw_eff_kv: 0.8403,
    vram: Bytes(200 * Bytes::GB),
    vram_usable_frac: 0.964,
    rental_per_hr_tp8: 80.0,
    quality: Quality::Fair,
    uncertainty_pct: 15.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_is_high_quality_rest_fair() {
        assert_eq!(Gpu::H100.spec().quality, Quality::High);
        for g in [Gpu::H200, Gpu::B200, Gpu::GB200] {
            assert_eq!(g.spec().quality, Quality::Fair);
        }
    }

    #[test]
    fn tdp_fractions_match_paper_appendix() {
        // P_idle = 0.43 TDP, P_nom = 0.86 TDP for all projected SKUs.
        for g in [Gpu::B200, Gpu::GB200] {
            let s = g.spec();
            assert!((s.power.p_idle_w / s.tdp_w - 0.43).abs() < 1e-9);
            assert!((s.power.p_nom_w / s.tdp_w - 0.86).abs() < 1e-9);
        }
    }

    #[test]
    fn h100_weight_stream_time_is_paper_w() {
        // 70B fp16 TP=8 -> 17.5 GB per GPU -> 6.72 ms.
        let s = Gpu::H100.spec();
        let w_ms = 17.5e9 / s.bw_weights() * 1e3;
        assert!((w_ms - 6.72).abs() < 0.01, "W = {w_ms}");
    }

    #[test]
    fn b200_weight_stream_time_is_paper_w() {
        let s = Gpu::B200.spec();
        let w_ms = 17.5e9 / s.bw_weights() * 1e3;
        assert!((w_ms - 2.95).abs() < 0.01, "W = {w_ms}");
    }

    #[test]
    fn h100_kv_scan_matches_calibration() {
        // H0 = kappa * L_calib / bw_kv = 55 KB * 8192 / bw -> 0.1387 ms.
        let s = Gpu::H100.spec();
        let h0_ms = 55e3 * 8192.0 / s.bw_kv() * 1e3;
        assert!((h0_ms - 0.1387).abs() < 0.001, "H0 = {h0_ms}");
    }

    #[test]
    fn kv_budget_ratio_b200_over_h100_is_2_62() {
        // 70B TP=8 fp16: 17.5 GB weights per GPU.
        let w = 17.5e9;
        let h = Gpu::H100.spec().vram_usable().0 as f64 - w;
        let b = Gpu::B200.spec().vram_usable().0 as f64 - w;
        let ratio = b / h;
        assert!((ratio - 2.62).abs() < 0.03, "ratio = {ratio}");
        assert!((h / 1e9 - 60.0).abs() < 0.6, "H100 KV budget = {h}");
        assert!((b / 1e9 - 156.0).abs() < 2.0, "B200 KV budget = {b}");
    }

    #[test]
    fn parse_roundtrip() {
        for g in Gpu::ALL {
            assert_eq!(Gpu::parse(g.spec().name), Some(g));
        }
        assert_eq!(Gpu::parse("nope"), None);
    }
}
