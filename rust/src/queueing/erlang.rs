//! M/M/c queueing: Erlang-C delay probability and waiting-time quantiles.
//!
//! Each TP group is modeled as a server pool admitting requests whose
//! "service" is the time a slot is occupied. Under exponential assumptions
//! the probability an arrival waits is Erlang-C, and the waiting time of
//! delayed customers is exponential with rate `c·μ − λ`, giving closed-form
//! P99 waits — the TTFT tail constraint for fleet sizing.
//!
//! The Erlang-C formula is evaluated with the standard numerically-stable
//! recurrence (no factorials), so c in the tens of thousands is fine.

/// Erlang-C: probability that an arrival must queue, for `c` servers and
/// offered load `a = λ/μ` (in Erlangs). Returns 1.0 when the system is
/// unstable (a ≥ c).
pub fn erlang_c(c: u64, a: f64) -> f64 {
    assert!(a >= 0.0);
    if c == 0 {
        return 1.0;
    }
    let cf = c as f64;
    if a >= cf {
        return 1.0;
    }
    // Iteratively compute B = Erlang-B via B_{k} = a·B_{k-1} / (k + a·B_{k-1})
    let mut b = 1.0; // B_0
    for k in 1..=c {
        b = a * b / (k as f64 + a * b);
    }
    // Erlang-C from Erlang-B.
    let rho = a / cf;
    b / (1.0 - rho * (1.0 - b))
}

/// P(wait > t) for an M/M/c with per-server rate `mu` and arrival rate
/// `lambda`: `C(c, a) · exp(−(c·μ − λ)·t)`.
pub fn prob_wait_exceeds(c: u64, lambda: f64, mu: f64, t_s: f64) -> f64 {
    let a = lambda / mu;
    let pc = erlang_c(c, a);
    let slack = c as f64 * mu - lambda;
    if slack <= 0.0 {
        return 1.0;
    }
    pc * (-slack * t_s).exp()
}

/// The q-quantile of the waiting time (0 when enough arrivals don't wait).
pub fn wait_quantile_s(c: u64, lambda: f64, mu: f64, q: f64) -> f64 {
    assert!((0.0..1.0).contains(&q) && q > 0.0);
    let a = lambda / mu;
    let pc = erlang_c(c, a);
    let slack = c as f64 * mu - lambda;
    if slack <= 0.0 {
        return f64::INFINITY;
    }
    if pc <= 1.0 - q {
        return 0.0; // fewer than (1-q) of arrivals wait at all
    }
    (pc / (1.0 - q)).ln() / slack
}

/// P99 waiting time, seconds.
pub fn p99_wait_s(c: u64, lambda: f64, mu: f64) -> f64 {
    wait_quantile_s(c, lambda, mu, 0.99)
}

/// Smallest `c` with P99 wait ≤ `slo_s` (and a stable queue). Linear scan
/// from the stability bound — sizing values are small enough that scan
/// beats bisection bookkeeping.
pub fn min_servers_for_p99(lambda: f64, mu: f64, slo_s: f64) -> u64 {
    let mut c = (lambda / mu).ceil() as u64 + 1;
    loop {
        if p99_wait_s(c, lambda, mu) <= slo_s {
            return c;
        }
        c += 1 + c / 64; // gentle geometric acceleration for huge fleets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erlang_c_known_values() {
        // Classic telephony check: c=10, a=8 -> C ≈ 0.409.
        let c = erlang_c(10, 8.0);
        assert!((c - 0.409).abs() < 0.005, "C(10,8) = {c}");
        // c=1: C = a (for a<1).
        assert!((erlang_c(1, 0.3) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn unstable_system_always_waits() {
        assert_eq!(erlang_c(4, 4.0), 1.0);
        assert_eq!(erlang_c(4, 9.0), 1.0);
        assert_eq!(p99_wait_s(2, 10.0, 1.0), f64::INFINITY);
    }

    #[test]
    fn erlang_c_decreases_with_servers() {
        let a = 50.0;
        let mut prev = 1.0;
        for c in 51..80 {
            let v = erlang_c(c, a);
            assert!(v <= prev + 1e-12);
            prev = v;
        }
    }

    #[test]
    fn wait_quantiles_ordered() {
        let (c, l, m) = (20, 15.0, 1.0);
        let p50 = wait_quantile_s(c, l, m, 0.5);
        let p99 = wait_quantile_s(c, l, m, 0.99);
        assert!(p50 <= p99);
    }

    #[test]
    fn overprovisioned_pool_never_queues_at_p99() {
        // 100 servers for load 10: P(wait) tiny, so P99 wait = 0.
        assert_eq!(p99_wait_s(100, 10.0, 1.0), 0.0);
    }

    #[test]
    fn min_servers_meets_slo_and_is_minimal_nearby() {
        let (lambda, mu, slo) = (200.0, 2.0, 0.5);
        let c = min_servers_for_p99(lambda, mu, slo);
        assert!(p99_wait_s(c, lambda, mu) <= slo);
        // One fewer server (when stable) must violate the SLO or be the
        // stability floor — allow the geometric scan's small overshoot.
        assert!(c >= (lambda / mu).ceil() as u64 + 1);
    }

    #[test]
    fn stable_large_pool_is_fast() {
        // Numerical stability at scale: c = 50 000, a = 45 000.
        let v = erlang_c(50_000, 45_000.0);
        assert!((0.0..1.0).contains(&v));
    }
}
