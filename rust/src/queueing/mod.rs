//! Steady-state queueing: Erlang-C waiting times ([`erlang`]) and
//! SLO-constrained fleet sizing ([`sizing`]) — the "P99 TTFT ≤ 500 ms at
//! λ = 1000 req/s" machinery behind paper Table 3.

pub mod erlang;
pub mod sizing;

pub use erlang::{erlang_c, p99_wait_s, prob_wait_exceeds};
pub use sizing::{size_pool, PoolSizing, SizingInputs};
