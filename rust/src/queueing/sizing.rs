//! SLO-constrained pool sizing (paper §4.1): "an operator provisions
//! enough GPUs to sustain the request arrival rate" subject to
//! P99 TTFT ≤ 500 ms.
//!
//! Two constraints, take the max:
//!
//! 1. **Token throughput** — the pool must decode `λ · L̄_out` tokens/s at
//!    its operating point (ρ of n_max, mean context L̄).
//! 2. **Slot queueing (TTFT tail)** — model every KV slot in the pool as a
//!    server of an M/M/c; an arrival's TTFT is its queue wait plus the
//!    prefill time, and the P99 of that sum must meet the SLO.

use super::erlang;
use crate::fleet::profile::GpuProfile;

/// Inputs for sizing one pool.
#[derive(Debug, Clone)]
pub struct SizingInputs {
    /// Arrival rate into this pool, req/s.
    pub lambda_rps: f64,
    /// Mean output length, tokens.
    pub mean_output_tokens: f64,
    /// Mean prompt length of this pool's traffic, tokens.
    pub mean_prompt_tokens: f64,
    /// Serving context window the pool is configured for.
    pub context_tokens: u32,
    /// Mean KV length used for the decode roofline (the headline tables
    /// use the window itself; the TrafficMean ablation passes the CDF's
    /// conditional mean).
    pub l_bar: f64,
    /// Target steady-state utilization of n_max (paper uses ρ = 0.85).
    pub rho: f64,
    /// P99 TTFT SLO, seconds (paper: 0.5).
    pub ttft_slo_s: f64,
}

/// Result of sizing one pool.
#[derive(Debug, Clone)]
pub struct PoolSizing {
    /// TP groups provisioned.
    pub groups: u64,
    /// Mean in-flight sequences per group at the offered load.
    pub n_active: f64,
    /// Decode throughput the pool delivers at that batch, tokens/s.
    pub pool_tok_s: f64,
    /// Which constraint bound the size.
    pub binding: Binding,
    /// Achieved P99 TTFT, seconds.
    pub p99_ttft_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Binding {
    Throughput,
    TtftTail,
    /// No traffic: zero groups.
    Idle,
}

/// Size one pool for the offered load.
pub fn size_pool(profile: &dyn GpuProfile, inp: &SizingInputs) -> PoolSizing {
    if inp.lambda_rps <= 0.0 {
        return PoolSizing {
            groups: 0,
            n_active: 0.0,
            pool_tok_s: 0.0,
            binding: Binding::Idle,
            p99_ttft_s: 0.0,
        };
    }
    let n_max = profile.n_max(inp.context_tokens) as f64;
    let r = profile.roofline();
    let n_act = (inp.rho * n_max).max(1.0);
    let group_tok_s = r.throughput_tok_s(n_act, inp.l_bar);

    // (1) Token-throughput floor.
    let demand_tok_s = inp.lambda_rps * inp.mean_output_tokens;
    let groups_thpt = (demand_tok_s / group_tok_s).ceil() as u64;

    // (2) TTFT tail: each slot holds a request for prefill + decode.
    let prefill_s = r.prefill_ms(inp.mean_prompt_tokens) / 1e3;
    let tpot_s = r.tau_ms(n_act, inp.l_bar) / 1e3; // time per output token
    let holding_s = prefill_s + inp.mean_output_tokens * tpot_s;
    let mu = 1.0 / holding_s; // slot service rate
    let queue_budget_s = (inp.ttft_slo_s - prefill_s).max(1e-3);
    let slots_needed = erlang::min_servers_for_p99(inp.lambda_rps, mu, queue_budget_s);
    let groups_ttft = (slots_needed as f64 / n_max).ceil() as u64;

    let groups = groups_thpt.max(groups_ttft).max(1);
    let binding = if groups_thpt >= groups_ttft {
        Binding::Throughput
    } else {
        Binding::TtftTail
    };

    // Achieved operating point at the provisioned size.
    let in_flight = inp.lambda_rps * holding_s; // Little's law
    let n_active = (in_flight / groups as f64).min(n_max);
    let pool_tok_s = groups as f64 * r.throughput_tok_s(n_active, inp.l_bar);
    let p99_ttft_s = prefill_s
        + erlang::p99_wait_s((groups as f64 * n_max) as u64, inp.lambda_rps, mu);

    PoolSizing {
        groups,
        n_active,
        pool_tok_s,
        binding,
        p99_ttft_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;

    fn azure_homo_inputs() -> SizingInputs {
        SizingInputs {
            lambda_rps: 1000.0,
            mean_output_tokens: 325.0,
            mean_prompt_tokens: 2000.0,
            context_tokens: 65_536,
            l_bar: 65_536.0,
            rho: 0.85,
            ttft_slo_s: 0.5,
        }
    }

    #[test]
    fn homo_64k_pool_sizes_and_meets_slo() {
        let p = ManualProfile::h100_70b();
        let s = size_pool(&p, &azure_homo_inputs());
        assert!(s.groups > 0);
        assert!(s.p99_ttft_s <= 0.5 + 1e-9, "P99 TTFT = {}", s.p99_ttft_s);
        // Sanity: pool delivers at least the demanded tokens.
        assert!(s.pool_tok_s >= 1000.0 * 325.0 * 0.95, "tok/s = {}", s.pool_tok_s);
    }

    #[test]
    fn short_pool_needs_far_fewer_groups() {
        let p = ManualProfile::h100_70b();
        let long = size_pool(&p, &azure_homo_inputs());
        let short = size_pool(
            &p,
            &SizingInputs {
                context_tokens: 4096,
                l_bar: 4096.0,
                mean_prompt_tokens: 1200.0,
                ..azure_homo_inputs()
            },
        );
        assert!(
            short.groups * 4 < long.groups,
            "short {} vs long {}",
            short.groups,
            long.groups
        );
    }

    #[test]
    fn zero_traffic_needs_zero_groups() {
        let p = ManualProfile::h100_70b();
        let s = size_pool(
            &p,
            &SizingInputs { lambda_rps: 0.0, ..azure_homo_inputs() },
        );
        assert_eq!(s.groups, 0);
        assert_eq!(s.binding, Binding::Idle);
    }

    #[test]
    fn sizing_scales_with_lambda() {
        let p = ManualProfile::h100_70b();
        let s1 = size_pool(&p, &SizingInputs { lambda_rps: 250.0, ..azure_homo_inputs() });
        let s4 = size_pool(&p, &SizingInputs { lambda_rps: 1000.0, ..azure_homo_inputs() });
        let ratio = s4.groups as f64 / s1.groups as f64;
        assert!(
            (3.3..=4.7).contains(&ratio),
            "4x load ≈ 4x groups (got {ratio:.2})"
        );
    }

    #[test]
    fn tighter_slo_never_shrinks_fleet() {
        let p = ManualProfile::h100_70b();
        let loose = size_pool(&p, &SizingInputs { ttft_slo_s: 2.0, ..azure_homo_inputs() });
        let tight = size_pool(&p, &SizingInputs { ttft_slo_s: 0.3, ..azure_homo_inputs() });
        assert!(tight.groups >= loose.groups);
    }

    #[test]
    fn b200_needs_fewer_groups_than_h100() {
        let h = size_pool(&ManualProfile::h100_70b(), &azure_homo_inputs());
        let b = size_pool(&ManualProfile::b200_70b(), &azure_homo_inputs());
        assert!(b.groups < h.groups, "B200 {} vs H100 {}", b.groups, h.groups);
    }
}
