//! Paper-vs-measured reporting: the machine-generated half of
//! EXPERIMENTS.md. For every quantitative claim we reproduce, print the
//! paper's number, ours, and the relative delta.

use crate::fleet::pool::LBarPolicy;
use crate::results::{Cell, Column, RowSet};
use crate::tables::render::f2;
use crate::tables::{independence, t1, t2};
use crate::tokeconomy::law;

/// One claim check.
#[derive(Debug, Clone)]
pub struct Claim {
    pub id: &'static str,
    pub description: &'static str,
    pub paper: f64,
    pub ours: f64,
}

impl Claim {
    pub fn rel_err(&self) -> f64 {
        (self.ours - self.paper).abs() / self.paper.abs().max(1e-12)
    }
}

/// Evaluate the headline claims.
pub fn claims() -> Vec<Claim> {
    let mut out = Vec::new();

    // T1: tok/W anchors.
    let rows = t1::rows();
    out.push(Claim {
        id: "T1/H100@4K",
        description: "H100 tok/W at 4K context",
        paper: 17.6,
        ours: rows[1].h100.tok_per_watt.0,
    });
    out.push(Claim {
        id: "T1/H100@64K",
        description: "H100 tok/W at 64K context",
        paper: 1.50,
        ours: rows[5].h100.tok_per_watt.0,
    });
    out.push(Claim {
        id: "T1/B200@8K",
        description: "B200 tok/W at 8K context",
        paper: 15.5,
        ours: rows[2].b200.tok_per_watt.0,
    });

    // 1/W law statistics.
    let fit = law::fit_law(
        &crate::fleet::profile::ManualProfile::h100_70b(),
        &law::LAW_CONTEXTS,
    );
    out.push(Claim {
        id: "Law/spread",
        description: "2K→128K tok/W spread (paper: ≈40×)",
        paper: 39.8, // 35.0 / 0.88 from the paper's own Table 1
        ours: fit.spread,
    });
    out.push(Claim {
        id: "Law/slope",
        description: "log–log slope (paper's data: −0.886)",
        paper: -0.886,
        ours: fit.slope,
    });

    // §3.1 generation-ratio narrowing.
    let h = crate::fleet::profile::ManualProfile::h100_70b();
    let b = crate::fleet::profile::ManualProfile::b200_70b();
    let at = |ctx: u32| {
        use crate::fleet::profile::PowerAccounting;
        crate::tokeconomy::operating_point(&b, ctx, 1.0, PowerAccounting::PerGpu)
            .tok_per_watt
            .0
            / crate::tokeconomy::operating_point(&h, ctx, 1.0, PowerAccounting::PerGpu)
                .tok_per_watt
                .0
    };
    out.push(Claim {
        id: "Gen/4K",
        description: "B200/H100 ratio at 4K",
        paper: 1.75,
        ours: at(4096),
    });
    out.push(Claim {
        id: "Gen/64K",
        description: "B200/H100 ratio at 64K (narrows)",
        paper: 1.49,
        ours: at(65_536),
    });

    // §4.2 independence/multiplicativity.
    let ind = independence::analyze(
        &crate::workload::cdf::azure_conversations(),
        LBarPolicy::Window,
    );
    out.push(Claim {
        id: "Ind/topo-stability",
        description: "Δ_topo(B200)/Δ_topo(H100) (paper: 2.44/2.52 = 0.97)",
        paper: 0.97,
        ours: ind.d_topo_b200 / ind.d_topo_h100,
    });
    out.push(Claim {
        id: "Ind/gen-stability",
        description: "Δ_gen(FleetOpt)/Δ_gen(Homo) (paper: 1.68/1.75 = 0.96)",
        paper: 0.96,
        ours: ind.d_gen_opt / ind.d_gen_homo,
    });
    out.push(Claim {
        id: "Ind/multiplicative",
        description: "combined / (Δ_topo × Δ_gen) (paper: 4.25/4.4 ≈ 0.97)",
        paper: 0.97,
        ours: ind.combined / ind.product,
    });

    // T2 shape: 405B rescue ratio on B200.
    let t2r = t2::rows();
    out.push(Claim {
        id: "T2/405B-rescue",
        description: "405B B200/H100 tok/W ratio (paper: 24×; regime escape)",
        paper: 24.0,
        ours: t2r[2].b200.tok_per_watt.0 / t2r[2].h100.tok_per_watt.0,
    });

    out
}

/// The typed rowset behind the claim table: paper and measured values
/// as raw floats, the relative error in percent.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Paper vs measured — headline claims",
        vec![
            Column::str("claim"),
            Column::str("description"),
            Column::float("paper"),
            Column::float("ours"),
            Column::float("rel err").with_unit("%"),
        ],
    );
    for c in claims() {
        rs.push(vec![
            Cell::str(c.id),
            Cell::str(c.description),
            Cell::float(c.paper).shown(f2(c.paper)),
            Cell::float(c.ours).shown(f2(c.ours)),
            Cell::float(c.rel_err() * 100.0)
                .shown(format!("{:.1}%", c.rel_err() * 100.0)),
        ]);
    }
    rs.note("calibrated claims (T1, Gen, Law) must sit within a few percent; \
            structural claims (Ind/*) within ~15%; T2/405B is a regime-change \
            ratio where 'large' is the reproduction target");
    rs
}

/// Render the claim table (the `wattlaw report` command).
pub fn paper_vs_measured() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_claims_close() {
        for c in claims() {
            match c.id {
                "T1/H100@4K" | "T1/H100@64K" | "T1/B200@8K" => {
                    assert!(c.rel_err() < 0.03, "{}: {:?}", c.id, c);
                }
                "Gen/4K" | "Gen/64K" => {
                    assert!(c.rel_err() < 0.05, "{}: {:?}", c.id, c);
                }
                "Law/spread" | "Law/slope" => {
                    assert!(c.rel_err() < 0.05, "{}: {:?}", c.id, c);
                }
                "Ind/topo-stability" | "Ind/gen-stability"
                | "Ind/multiplicative" => {
                    assert!(c.rel_err() < 0.2, "{}: {:?}", c.id, c);
                }
                "T2/405B-rescue" => {
                    assert!(c.ours > 5.0, "{}: {:?}", c.id, c);
                }
                other => panic!("untested claim {other}"),
            }
        }
    }

    #[test]
    fn report_renders() {
        let s = paper_vs_measured();
        assert!(s.contains("T1/H100@4K"));
        assert!(s.contains("rel err"));
    }

    #[test]
    fn claim_rowset_is_machine_readable() {
        let rs = rowset();
        assert_eq!(rs.rows().len(), claims().len());
        let doc = crate::runtime::json::parse(&rs.to_json()).unwrap();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        // Raw values, not the 2-dp display strings.
        assert_eq!(rows[0].get("paper").unwrap().as_f64(), Some(17.6));
        assert!(rs.to_csv().starts_with("claim,description,paper,ours,rel err (%)\n"));
    }
}
