//! CSV emission (and a small RFC-4180-style parser for round-trip
//! verification) for [`RowSet`]s.
//!
//! Policy, golden-tested in `tests/results_format.rs`:
//!
//! * one header row, columns in schema order, units in parentheses
//!   (`tok/W (tok/J)`);
//! * fields are quoted only when they contain a comma, quote, CR or LF;
//!   embedded quotes double;
//! * floats emit Rust's shortest round-trippable `Display` form;
//! * NaN/±inf and [`Value::Missing`] emit an **empty field** — absent
//!   data stays absent instead of becoming a sentinel number;
//! * no title and no notes: the CSV is pure data for plotting (titles
//!   reappear as `# …` comment lines only when several tables share one
//!   document via [`super::emit_all`]).

use super::{Cell, RowSet, Value};

/// Emit the rowset as CSV (header + data rows, `\n` line endings).
pub fn to_csv(rs: &RowSet) -> String {
    let mut out = String::new();
    let hdr: Vec<String> =
        rs.columns().iter().map(|c| escape(&c.header())).collect();
    out.push_str(&hdr.join(","));
    out.push('\n');
    for row in rs.rows() {
        let fields: Vec<String> = row.iter().map(field).collect();
        out.push_str(&fields.join(","));
        out.push('\n');
    }
    out
}

fn field(c: &Cell) -> String {
    match &c.value {
        Value::Str(s) => escape(s),
        Value::Int(i) => i.to_string(),
        Value::Float(x) if x.is_finite() => format!("{x}"),
        Value::Float(_) => String::new(),
        Value::Bool(b) => b.to_string(),
        Value::Missing => String::new(),
    }
}

fn escape(s: &str) -> String {
    if s.contains(['"', ',', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Parse CSV text back into rows of string fields — the inverse of
/// [`to_csv`] (quoted fields unescape, empty fields come back as empty
/// strings). Exists so emitters can be property-tested against a real
/// parser rather than substring checks.
pub fn parse_csv(text: &str) -> crate::Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut quoted = false; // current field started with a quote
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    in_quotes = false;
                }
            } else {
                field.push(c);
            }
            continue;
        }
        match c {
            '"' if field.is_empty() && !quoted => {
                in_quotes = true;
                quoted = true;
            }
            ',' => {
                row.push(std::mem::take(&mut field));
                quoted = false;
            }
            '\n' => {
                row.push(std::mem::take(&mut field));
                rows.push(std::mem::take(&mut row));
                quoted = false;
            }
            '\r' => {}
            _ => field.push(c),
        }
    }
    if in_quotes {
        anyhow::bail!("unterminated quoted CSV field");
    }
    if !field.is_empty() || !row.is_empty() || quoted {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::super::{Column, RowSet};
    use super::*;

    #[test]
    fn golden_small_table() {
        let mut rs = RowSet::new(
            "ignored in csv",
            vec![
                Column::str("name"),
                Column::float("tok/W").with_unit("tok/J"),
                Column::int("groups"),
            ],
        );
        rs.push(vec![Cell::str("a,b"), Cell::float(17.6), Cell::int(42)]);
        rs.push(vec![
            Cell::str("say \"hi\""),
            Cell::float(f64::NAN),
            Cell::missing(),
        ]);
        rs.note("notes are not CSV data");
        assert_eq!(
            rs.to_csv(),
            "name,tok/W (tok/J),groups\n\
             \"a,b\",17.6,42\n\
             \"say \"\"hi\"\"\",,\n"
        );
    }

    #[test]
    fn display_override_never_leaks_into_csv() {
        let mut rs = RowSet::new("t", vec![Column::float("x")]);
        rs.push(vec![Cell::float(1.23456789).shown("1.2")]);
        assert_eq!(rs.to_csv(), "x\n1.23456789\n");
    }

    #[test]
    fn parser_handles_quotes_commas_newlines() {
        let rows = parse_csv("a,\"b,c\",\"d\"\"e\"\nf,\"g\nh\",\n").unwrap();
        assert_eq!(
            rows,
            vec![
                vec!["a".to_string(), "b,c".into(), "d\"e".into()],
                vec!["f".to_string(), "g\nh".into(), "".into()],
            ]
        );
    }

    #[test]
    fn parser_rejects_unterminated_quote() {
        assert!(parse_csv("a,\"bc\n").is_err());
    }

    #[test]
    fn crlf_tolerated() {
        let rows = parse_csv("a,b\r\nc,d\r\n").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["c".to_string(), "d".into()]);
    }

    #[test]
    fn empty_quoted_field_survives() {
        let rows = parse_csv("\"\",x\n").unwrap();
        assert_eq!(rows, vec![vec!["".to_string(), "x".into()]]);
    }
}
