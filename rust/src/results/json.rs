//! JSON emission for [`RowSet`]s: the full document — title, column
//! schema with units, typed rows, notes — as one object.
//!
//! ```json
//! {
//!   "title": "…",
//!   "columns": [ { "name": "tok/W", "unit": "tok/J" }, … ],
//!   "rows": [ { "tok/W": 17.6, … }, … ],
//!   "notes": [ "…" ]
//! }
//! ```
//!
//! Rows are keyed by column *name* (without the unit). Non-finite floats
//! and [`Value::Missing`] emit `null` (JSON has no NaN). All non-ASCII
//! and control characters are `\uXXXX`-escaped, so the output is plain
//! ASCII and parses with the crate's own minimal reader
//! ([`crate::runtime::json::parse`]) — the round-trip the golden tests
//! lean on.

use super::{Cell, RowSet, Value};

/// Emit the rowset as a pretty-printed JSON object.
pub fn to_json(rs: &RowSet) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"title\": {},\n", quote(&rs.title)));

    out.push_str("  \"columns\": [\n");
    let ncols = rs.columns().len();
    for (i, c) in rs.columns().iter().enumerate() {
        let unit = match &c.unit {
            Some(u) => quote(u),
            None => "null".into(),
        };
        out.push_str(&format!(
            "    {{ \"name\": {}, \"unit\": {} }}{}\n",
            quote(&c.name),
            unit,
            if i + 1 < ncols { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");

    out.push_str("  \"rows\": [\n");
    let nrows = rs.rows().len();
    for (ri, row) in rs.rows().iter().enumerate() {
        let fields: Vec<String> = row
            .iter()
            .zip(rs.columns())
            .map(|(cell, col)| format!("{}: {}", quote(&col.name), value(cell)))
            .collect();
        out.push_str(&format!(
            "    {{ {} }}{}\n",
            fields.join(", "),
            if ri + 1 < nrows { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");

    let notes: Vec<String> = rs.notes().iter().map(|n| quote(n)).collect();
    out.push_str(&format!("  \"notes\": [{}]\n", notes.join(", ")));
    out.push('}');
    out
}

fn value(c: &Cell) -> String {
    match &c.value {
        Value::Str(s) => quote(s),
        Value::Int(i) => i.to_string(),
        Value::Float(x) if x.is_finite() => format!("{x}"),
        Value::Float(_) | Value::Missing => "null".into(),
        Value::Bool(b) => b.to_string(),
    }
}

/// JSON string literal with ASCII-only output (control and non-ASCII
/// characters become `\uXXXX`, astral characters surrogate pairs).
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 || (c as u32) >= 0x7f => {
                let mut buf = [0u16; 2];
                for u in c.encode_utf16(&mut buf) {
                    out.push_str(&format!("\\u{u:04x}"));
                }
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::super::{Column, RowSet};
    use super::*;
    use crate::runtime::json::{parse, Json};

    fn demo() -> RowSet {
        let mut rs = RowSet::new(
            "Sweep — λ=1000, γ=2",
            vec![
                Column::str("topology"),
                Column::float("tok/W").with_unit("tok/J"),
                Column::int("groups"),
                Column::str("slo"),
            ],
        );
        rs.push(vec![
            Cell::str("FleetOpt (4K/γ=2)"),
            Cell::float(3.75).shown("3.8"),
            Cell::int(12),
            Cell::str("pass"),
        ]);
        rs.push(vec![
            Cell::str("Homo 64K"),
            Cell::float(f64::NAN),
            Cell::missing(),
            Cell::str("MISS"),
        ]);
        rs.note("note with \"quotes\" and γ");
        rs
    }

    #[test]
    fn output_is_ascii_and_self_parseable() {
        let j = demo().to_json();
        assert!(j.is_ascii(), "non-ASCII must be \\u-escaped");
        let doc = parse(&j).unwrap();
        assert_eq!(
            doc.get("title").unwrap().as_str(),
            Some("Sweep — λ=1000, γ=2")
        );
        let cols = doc.get("columns").unwrap().as_arr().unwrap();
        assert_eq!(cols.len(), 4);
        assert_eq!(cols[1].get("unit").unwrap().as_str(), Some("tok/J"));
        assert_eq!(cols[0].get("unit"), Some(&Json::Null));
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        // Raw value, not the display override.
        assert_eq!(rows[0].get("tok/W").unwrap().as_f64(), Some(3.75));
        assert_eq!(rows[0].get("groups").unwrap().as_f64(), Some(12.0));
        // NaN and missing both land as null.
        assert_eq!(rows[1].get("tok/W"), Some(&Json::Null));
        assert_eq!(rows[1].get("groups"), Some(&Json::Null));
        let notes = doc.get("notes").unwrap().as_arr().unwrap();
        assert_eq!(notes[0].as_str(), Some("note with \"quotes\" and γ"));
    }

    #[test]
    fn quote_escapes_controls_and_astral() {
        assert_eq!(quote("a\nb"), "\"a\\nb\"");
        assert_eq!(quote("\r"), "\"\\u000d\"");
        assert_eq!(quote("γ"), "\"\\u03b3\"");
        // Astral chars become surrogate pairs.
        assert_eq!(quote("𝄞"), "\"\\ud834\\udd1e\"");
    }
}
