//! The typed results layer: one `RowSet` per output surface, three
//! emitters.
//!
//! Every result the crate renders — the paper tables t1–t7, the scenario
//! sweep's analyze-vs-simulate consistency records, the FleetOpt
//! optimizer's ranking, the `report` claim checks — is a table: a column
//! schema (names, units, alignment) over typed cell values. Before this
//! module each surface built its own strings, so nothing was machine
//! readable; a [`RowSet`] now carries the values and the presentation
//! separately:
//!
//! * [`RowSet::to_text`] — the aligned markdown table humans read
//!   (byte-compatible with the old `tables::render::Table` output).
//! * [`RowSet::to_csv`] ([`csv`]) — pure data, one header row with units,
//!   full-precision floats, for plotting.
//! * [`RowSet::to_json`] ([`json`]) — the same schema and rows as a JSON
//!   document, parseable by [`crate::runtime::json`].
//!
//! A cell is a [`Value`] (string / integer / float / bool / missing)
//! plus an optional display override ([`Cell::shown`]): the text table
//! keeps the paper's formatting conventions (e.g. `tokw`'s
//! two-decimals-below-ten) while CSV/JSON always emit the raw value.
//! Non-finite floats and [`Value::Missing`] render as an em dash in
//! text, an empty field in CSV, and `null` in JSON.
//!
//! `--format table|csv|json` on the CLI selects the emitter
//! ([`OutputFormat`]); [`emit_all`] concatenates several tables in one
//! document (CSV tables are separated by `# title` comment lines, JSON
//! becomes an array).

pub mod csv;
pub mod json;

/// Column alignment in the text renderer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// One column of a [`RowSet`]: name, optional unit, text alignment.
#[derive(Debug, Clone)]
pub struct Column {
    pub name: String,
    pub unit: Option<String>,
    pub align: Align,
}

impl Column {
    /// A string-valued column (left-aligned).
    pub fn str(name: impl Into<String>) -> Self {
        Column { name: name.into(), unit: None, align: Align::Left }
    }

    /// An integer-valued column (right-aligned).
    pub fn int(name: impl Into<String>) -> Self {
        Column { name: name.into(), unit: None, align: Align::Right }
    }

    /// A float-valued column (right-aligned).
    pub fn float(name: impl Into<String>) -> Self {
        Column { name: name.into(), unit: None, align: Align::Right }
    }

    pub fn with_unit(mut self, unit: impl Into<String>) -> Self {
        self.unit = Some(unit.into());
        self
    }

    pub fn left(mut self) -> Self {
        self.align = Align::Left;
        self
    }

    pub fn right(mut self) -> Self {
        self.align = Align::Right;
        self
    }

    /// Header text: `name (unit)` when a unit is declared.
    pub fn header(&self) -> String {
        match &self.unit {
            Some(u) => format!("{} ({u})", self.name),
            None => self.name.clone(),
        }
    }
}

/// A typed cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    /// No value for this cell (distinct from NaN, which is a computed
    /// float that happened to be undefined — both emit as null/empty).
    Missing,
}

/// A cell: the raw value plus an optional display override for the text
/// table. CSV/JSON always emit the raw value at full precision.
#[derive(Debug, Clone)]
pub struct Cell {
    pub value: Value,
    pub display: Option<String>,
}

impl Cell {
    pub fn str(s: impl Into<String>) -> Self {
        Cell { value: Value::Str(s.into()), display: None }
    }

    pub fn int(i: i64) -> Self {
        Cell { value: Value::Int(i), display: None }
    }

    pub fn float(x: f64) -> Self {
        Cell { value: Value::Float(x), display: None }
    }

    pub fn bool(b: bool) -> Self {
        Cell { value: Value::Bool(b), display: None }
    }

    pub fn missing() -> Self {
        Cell { value: Value::Missing, display: None }
    }

    /// Override the text-table rendering (e.g. the paper's `tokw`
    /// precision convention) without touching the raw value.
    pub fn shown(mut self, s: impl Into<String>) -> Self {
        self.display = Some(s.into());
        self
    }

    /// The string the text table shows for this cell.
    pub fn text(&self) -> String {
        if let Some(d) = &self.display {
            return d.clone();
        }
        match &self.value {
            Value::Str(s) => s.clone(),
            Value::Int(i) => i.to_string(),
            Value::Float(x) if x.is_finite() => format!("{x}"),
            Value::Float(_) => "—".into(),
            Value::Bool(b) => b.to_string(),
            Value::Missing => "—".into(),
        }
    }
}

/// Output format selected by the CLI's `--format` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    #[default]
    Table,
    Csv,
    Json,
}

impl OutputFormat {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "table" | "text" | "md" => Some(OutputFormat::Table),
            "csv" => Some(OutputFormat::Csv),
            "json" => Some(OutputFormat::Json),
            _ => None,
        }
    }
}

/// A titled table of typed rows — the one shape every output surface
/// reduces to.
#[derive(Debug, Clone)]
pub struct RowSet {
    pub title: String,
    columns: Vec<Column>,
    rows: Vec<Vec<Cell>>,
    notes: Vec<String>,
}

impl RowSet {
    pub fn new(title: impl Into<String>, columns: Vec<Column>) -> Self {
        RowSet { title: title.into(), columns, rows: Vec::new(), notes: Vec::new() }
    }

    /// Append one row; arity must match the schema.
    pub fn push(&mut self, row: Vec<Cell>) -> &mut Self {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    pub fn align(&mut self, col: usize, a: Align) -> &mut Self {
        self.columns[col].align = a;
        self
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    pub fn rows(&self) -> &[Vec<Cell>] {
        &self.rows
    }

    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    /// The aligned markdown table (titles as `# …`, notes as trailing
    /// `note:` lines) — the human-facing default.
    pub fn to_text(&self) -> String {
        let ncols = self.columns.len();
        let headers: Vec<String> =
            self.columns.iter().map(|c| c.header()).collect();
        let texts: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|c| c.text()).collect())
            .collect();
        let mut widths: Vec<usize> =
            headers.iter().map(|h| h.chars().count()).collect();
        for r in &texts {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_cell = |s: &str, w: usize, a: Align| match a {
            Align::Left => format!("{s:<w$}"),
            Align::Right => format!("{s:>w$}"),
        };
        let mut out = String::new();
        out.push_str(&format!("\n# {}\n\n", self.title));
        let hdr: Vec<String> = (0..ncols)
            .map(|i| fmt_cell(&headers[i], widths[i], self.columns[i].align))
            .collect();
        out.push_str(&format!("| {} |\n", hdr.join(" | ")));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in &texts {
            let cells: Vec<String> = (0..ncols)
                .map(|i| fmt_cell(&r[i], widths[i], self.columns[i].align))
                .collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// Pure-data CSV: one header row (units in parentheses), no title or
    /// notes, full-precision floats, empty fields for missing/NaN.
    pub fn to_csv(&self) -> String {
        csv::to_csv(self)
    }

    /// The full document (title, schema with units, rows, notes) as JSON.
    pub fn to_json(&self) -> String {
        json::to_json(self)
    }

    pub fn emit(&self, format: OutputFormat) -> String {
        match format {
            OutputFormat::Table => self.to_text(),
            OutputFormat::Csv => self.to_csv(),
            OutputFormat::Json => self.to_json(),
        }
    }
}

/// Emit several tables as one document: concatenated text, `# title`-
/// separated CSV blocks, or a JSON array.
pub fn emit_all(sets: &[RowSet], format: OutputFormat) -> String {
    match format {
        OutputFormat::Table => sets.iter().map(|s| s.to_text()).collect(),
        OutputFormat::Csv => sets
            .iter()
            .map(|s| format!("# {}\n{}", s.title, s.to_csv()))
            .collect::<Vec<_>>()
            .join("\n"),
        OutputFormat::Json => format!(
            "[\n{}\n]",
            sets.iter()
                .map(|s| s.to_json())
                .collect::<Vec<_>>()
                .join(",\n")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> RowSet {
        let mut rs = RowSet::new(
            "Demo",
            vec![
                Column::str("name"),
                Column::float("value").with_unit("W"),
                Column::int("count"),
            ],
        );
        rs.push(vec![
            Cell::str("alpha"),
            Cell::float(1.25).shown("1.2"),
            Cell::int(3),
        ]);
        rs.push(vec![Cell::str("beta"), Cell::float(f64::NAN), Cell::missing()]);
        rs.note("hello");
        rs
    }

    #[test]
    fn text_renders_title_units_and_notes() {
        let s = demo().to_text();
        assert!(s.contains("# Demo"));
        assert!(s.contains("value (W)"));
        assert!(s.contains("| alpha |"));
        assert!(s.contains("1.2")); // display override wins in text
        assert!(s.contains("—")); // NaN and missing render as em dash
        assert!(s.contains("note: hello"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut rs = RowSet::new("x", vec![Column::str("a"), Column::str("b")]);
        rs.push(vec![Cell::str("only-one")]);
    }

    #[test]
    fn emit_dispatches_on_format() {
        let rs = demo();
        assert_eq!(rs.emit(OutputFormat::Table), rs.to_text());
        assert_eq!(rs.emit(OutputFormat::Csv), rs.to_csv());
        assert_eq!(rs.emit(OutputFormat::Json), rs.to_json());
    }

    #[test]
    fn format_parses_known_names_only() {
        assert_eq!(OutputFormat::parse("csv"), Some(OutputFormat::Csv));
        assert_eq!(OutputFormat::parse("JSON"), Some(OutputFormat::Json));
        assert_eq!(OutputFormat::parse("table"), Some(OutputFormat::Table));
        assert_eq!(OutputFormat::parse("yaml"), None);
    }

    #[test]
    fn emit_all_separates_tables() {
        let sets = [demo(), demo()];
        let csv = emit_all(&sets, OutputFormat::Csv);
        assert_eq!(csv.matches("# Demo").count(), 2);
        let json = emit_all(&sets, OutputFormat::Json);
        assert!(json.starts_with("[\n") && json.ends_with("\n]"));
        let parsed = crate::runtime::json::parse(&json).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn default_cell_text_formats_by_type() {
        assert_eq!(Cell::float(2.5).text(), "2.5");
        assert_eq!(Cell::int(-7).text(), "-7");
        assert_eq!(Cell::bool(true).text(), "true");
        assert_eq!(Cell::missing().text(), "—");
        assert_eq!(Cell::float(f64::INFINITY).text(), "—");
    }
}
