//! The decode roofline — paper §2.2:
//!
//! ```text
//! τ(n, L̄) = W + H(L̄) · n          (per-iteration decode latency)
//! W       = active_weight_bytes_per_gpu / bw_weights
//! H(L̄)   = H0 · L̄ / L_calib  =  κ · L̄ / bw_kv
//! ```
//!
//! `W` is the weight-streaming time (every decode iteration reads every
//! activated weight once) and `H(L̄)·n` the KV-scan time (every iteration
//! reads every in-flight sequence's KV cache once). Decode is
//! memory-bandwidth-bound (Maliakel et al.: 77–91 % of inference time), so
//! byte counts over effective bandwidth is the whole model.
//!
//! Because `n_max ∝ 1/W` (Eq. 3) and `H ∝ W̄`, the product `H·n_max` is
//! invariant in the context window — throughput at full concurrency scales
//! exactly as `1/W` while power stays flat. That invariant *is* the 1/W
//! law, and is asserted in the tests below.

pub mod moe;
pub mod quant;
pub mod speculative;

use crate::model::spec::{ModelSpec, Precision};
use crate::model::{kappa_bytes_per_token, KvPlacement};
use crate::power::GpuSpec;

/// Calibration context for `H0` (the paper quotes H at L̄ = 8192).
pub const L_CALIB: f64 = 8192.0;

/// Decode-latency roofline for one (GPU, model, TP, precision) binding.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Weight-streaming time per iteration, ms.
    pub w_ms: f64,
    /// KV-scan time per sequence at `L_CALIB` context, ms.
    pub h0_ms: f64,
    /// MoE dispatch overhead added to every iteration, ms (0 for dense;
    /// the paper treats the MoE W as a lower bound *excluding* dispatch —
    /// this field makes the bound explicit and sweepable).
    pub dispatch_ms: f64,
}

impl Roofline {
    /// Build from catalog entries. `placement` controls κ for the KV-scan
    /// term (and must match the κ used for `n_max`).
    pub fn from_specs(
        gpu: &GpuSpec,
        model: &ModelSpec,
        prec: Precision,
        tp: u32,
        placement: KvPlacement,
    ) -> Self {
        // MoE: stream only activated weights (paper §3.2 override).
        let bytes_per_gpu = model.active_weight_bytes(prec) / tp as f64;
        let w_ms = bytes_per_gpu / gpu.bw_weights() * 1e3;
        let kappa = kappa_bytes_per_token(model, placement, tp);
        let h0_ms = kappa * L_CALIB / gpu.bw_kv() * 1e3;
        Roofline {
            w_ms,
            h0_ms,
            dispatch_ms: 0.0,
        }
    }

    /// Explicit calibrated constructor (ManualProfile path).
    pub const fn manual(w_ms: f64, h0_ms: f64) -> Self {
        Roofline {
            w_ms,
            h0_ms,
            dispatch_ms: 0.0,
        }
    }

    /// Add MoE all-to-all dispatch overhead (paper: "a few to tens of ms").
    pub fn with_dispatch_ms(mut self, d: f64) -> Self {
        self.dispatch_ms = d;
        self
    }

    /// Per-sequence KV-scan time at mean context `l_bar`, ms.
    #[inline]
    pub fn h_ms(&self, l_bar: f64) -> f64 {
        self.h0_ms * l_bar / L_CALIB
    }

    /// τ(n, L̄) — per-iteration decode latency, ms.
    #[inline]
    pub fn tau_ms(&self, n: f64, l_bar: f64) -> f64 {
        self.w_ms + self.dispatch_ms + self.h_ms(l_bar) * n
    }

    /// Decode throughput at concurrency `n` and mean context `l_bar`,
    /// output tokens/second (each iteration emits one token per sequence).
    #[inline]
    pub fn throughput_tok_s(&self, n: f64, l_bar: f64) -> f64 {
        if n <= 0.0 {
            return 0.0;
        }
        n / self.tau_ms(n, l_bar) * 1e3
    }

    /// Time to prefill a prompt of `prompt_tokens` at full bandwidth —
    /// first-order model for the TTFT queueing analysis: one full weight
    /// stream plus writing the prompt KV (compute overlaps the stream on a
    /// memory-bound part).
    pub fn prefill_ms(&self, prompt_tokens: f64) -> f64 {
        // Prefill is compute-bound but short; model as chunked decode over
        // the prompt with perfect batching: weights streamed once per
        // prefill chunk of 1024 tokens, KV grows linearly.
        let chunks = (prompt_tokens / 1024.0).ceil().max(1.0);
        chunks * (self.w_ms + self.dispatch_ms)
            + self.h_ms(prompt_tokens / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{LLAMA31_70B, QWEN3_235B_A22B};
    use crate::power::profiles::{B200, H100};

    #[test]
    fn h100_70b_matches_paper_calibration() {
        let r = Roofline::from_specs(
            &H100, &LLAMA31_70B, Precision::Fp16, 8, KvPlacement::Sharded);
        assert!((r.w_ms - 6.72).abs() < 0.01, "W = {}", r.w_ms);
        // Geometry κ = 40 KB gives H0 = 0.1033 ms; the calibrated fleet
        // profile (κ = 55 KB incl. overhead) uses Roofline::manual.
        assert!((r.h0_ms - 0.1033).abs() < 0.002, "H0 = {}", r.h0_ms);
    }

    #[test]
    fn manual_calibration_closes_table1_throughput() {
        // Table 1 H100 @4K: n_max = 256, tok/W = 17.6 at P = 593 W
        // -> throughput = 10 436 tok/s.
        let r = Roofline::manual(6.72, 0.1387);
        let thpt = r.throughput_tok_s(256.0, 4096.0);
        assert!((thpt - 10_436.0).abs() / 10_436.0 < 0.01, "thpt = {thpt}");
    }

    #[test]
    fn h_times_nmax_invariant_across_context() {
        // The 1/W mechanism: H(L̄)·n_max is context-invariant.
        let r = Roofline::manual(6.72, 0.1387);
        let base = r.h_ms(2048.0) * 512.0;
        for (ctx, n) in [(4096.0, 256.0), (8192.0, 128.0), (65536.0, 16.0)] {
            let v = r.h_ms(ctx) * n;
            assert!((v - base).abs() < 1e-9, "ctx {ctx}: {v} vs {base}");
        }
    }

    #[test]
    fn b200_70b_w_is_2_95ms() {
        let r = Roofline::from_specs(
            &B200, &LLAMA31_70B, Precision::Fp16, 8, KvPlacement::Sharded);
        assert!((r.w_ms - 2.95).abs() < 0.01, "W = {}", r.w_ms);
    }

    #[test]
    fn moe_streams_active_params_only() {
        let dense_equiv_ms = QWEN3_235B_A22B.weight_bytes(Precision::Fp16)
            / 8.0 / H100.bw_weights() * 1e3;
        let r = Roofline::from_specs(
            &H100, &QWEN3_235B_A22B, Precision::Fp16, 8, KvPlacement::Sharded);
        let ratio = r.w_ms / dense_equiv_ms;
        assert!((ratio - 22.0 / 235.0).abs() < 1e-9);
        // Paper: "W ≈ 1.6 ms on H100" using full peak bw; with the
        // calibrated effective bw we land slightly above.
        assert!(r.w_ms > 1.5 && r.w_ms < 2.2, "W = {}", r.w_ms);
    }

    #[test]
    fn dispatch_overhead_erodes_moe_advantage() {
        let moe = Roofline::from_specs(
            &H100, &QWEN3_235B_A22B, Precision::Fp16, 8, KvPlacement::Sharded);
        let with_dispatch = moe.with_dispatch_ms(10.0);
        let t0 = moe.throughput_tok_s(24.0, 8192.0);
        let t1 = with_dispatch.throughput_tok_s(24.0, 8192.0);
        assert!(t1 < t0 * 0.5, "10 ms dispatch must cost >2x here");
    }

    #[test]
    fn quantization_scales_w_linearly() {
        let f16 = Roofline::from_specs(
            &H100, &LLAMA31_70B, Precision::Fp16, 8, KvPlacement::Sharded);
        let f8 = Roofline::from_specs(
            &H100, &LLAMA31_70B, Precision::Fp8, 8, KvPlacement::Sharded);
        assert!((f8.w_ms / f16.w_ms - 0.5).abs() < 1e-9);
        // Paper §5.2: fp8 gives W ≈ 3.36 ms for H100+70B.
        assert!((f8.w_ms - 3.36).abs() < 0.01);
    }

    #[test]
    fn throughput_zero_at_zero_concurrency() {
        let r = Roofline::manual(6.72, 0.1387);
        assert_eq!(r.throughput_tok_s(0.0, 8192.0), 0.0);
    }

    #[test]
    fn prefill_grows_with_prompt() {
        let r = Roofline::manual(6.72, 0.1387);
        assert!(r.prefill_ms(8192.0) > r.prefill_ms(512.0));
    }
}
