//! The MoE architecture lever (paper §3.2): active-parameter weight
//! streaming collapses `W`, but all-to-all expert dispatch adds an
//! iteration overhead the paper's Table 2 excludes. This module makes the
//! bound explicit and quantifies how dispatch erodes the advantage — the
//! paper's own example (10 ms dispatch shrinks Qwen3's 5× edge over
//! Llama-70B to ≈1.5×) is reproduced as a test.

use super::Roofline;
use crate::model::spec::ModelSpec;
use crate::model::KvPlacement;
use crate::power::GpuSpec;

/// MoE advantage over a dense baseline at one operating point.
#[derive(Debug, Clone)]
pub struct MoeAdvantage {
    pub dispatch_ms: f64,
    pub moe_tok_s: f64,
    pub dense_tok_s: f64,
    /// moe / dense throughput ratio at equal concurrency.
    pub ratio: f64,
}

/// Sweep dispatch overhead 0..=`max_dispatch_ms` and report the advantage
/// erosion curve (the paper's "upper bound" caveat, quantified).
pub fn dispatch_erosion(
    gpu: &GpuSpec,
    moe: &ModelSpec,
    dense: &ModelSpec,
    tp: u32,
    n: f64,
    l_bar: f64,
    dispatch_grid_ms: &[f64],
) -> Vec<MoeAdvantage> {
    assert!(moe.is_moe && !dense.is_moe);
    let placement = KvPlacement::Sharded;
    let dense_r =
        Roofline::from_specs(gpu, dense, dense.default_precision, tp, placement);
    let dense_t = dense_r.throughput_tok_s(n, l_bar);
    dispatch_grid_ms
        .iter()
        .map(|&d| {
            let moe_r =
                Roofline::from_specs(gpu, moe, moe.default_precision, tp, placement)
                    .with_dispatch_ms(d);
            let moe_t = moe_r.throughput_tok_s(n, l_bar);
            MoeAdvantage {
                dispatch_ms: d,
                moe_tok_s: moe_t,
                dense_tok_s: dense_t,
                ratio: moe_t / dense_t,
            }
        })
        .collect()
}

/// Break-even dispatch overhead: the d_ms at which the MoE advantage
/// over the dense baseline disappears (ratio = 1), found by bisection.
pub fn breakeven_dispatch_ms(
    gpu: &GpuSpec,
    moe: &ModelSpec,
    dense: &ModelSpec,
    tp: u32,
    n: f64,
    l_bar: f64,
) -> f64 {
    let probe = |d: f64| {
        dispatch_erosion(gpu, moe, dense, tp, n, l_bar, &[d])[0].ratio - 1.0
    };
    let (mut lo, mut hi) = (0.0, 200.0);
    if probe(lo) <= 0.0 {
        return 0.0; // no advantage even without dispatch
    }
    // Grow the bracket geometrically until it straddles the root: a
    // slow-eroding pair (KV-scan-dominated dense baseline at long
    // context) can break even well past the old 200 ms guess, which
    // silently returned INFINITY. Mathematically the root always exists
    // when probe(0) > 0 — throughput decays to zero with dispatch — so
    // the cap only guards degenerate float inputs.
    const BRACKET_CAP_MS: f64 = 1e7;
    while probe(hi) > 0.0 {
        lo = hi;
        hi *= 2.0;
        if hi > BRACKET_CAP_MS {
            return f64::INFINITY;
        }
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if probe(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{
        DEEPSEEK_V3, LLAMA31_405B, LLAMA31_70B, QWEN3_235B_A22B,
    };
    use crate::power::profiles::H100;

    #[test]
    fn dispatch_erodes_the_moe_edge_sharply() {
        // §3.2 claims "5× shrinks to ≈1.5× at 10 ms dispatch"; the paper's
        // 5× comes from its Table 2 parameterization, which does not close
        // under its own roofline (DESIGN.md §4). Under the *consistent*
        // roofline the weight-streaming edge at the weight-bound operating
        // point (low n) is W_dense/W_moe ≈ 3.2×, and 10 ms of dispatch
        // erases more than half of whatever edge exists — the paper's
        // qualitative claim, which we assert.
        let rows = dispatch_erosion(
            &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 2.0, 8192.0,
            &[0.0, 10.0],
        );
        assert!(rows[0].ratio > 2.2, "zero-dispatch ratio = {}", rows[0].ratio);
        assert!(
            rows[1].ratio < rows[0].ratio * 0.55,
            "10 ms must cost half the edge: {} -> {}",
            rows[0].ratio,
            rows[1].ratio
        );
    }

    #[test]
    fn erosion_is_monotone_in_dispatch() {
        let grid: Vec<f64> = (0..=20).map(|i| i as f64).collect();
        let rows = dispatch_erosion(
            &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 24.0, 8192.0, &grid);
        for w in rows.windows(2) {
            assert!(w[1].ratio <= w[0].ratio + 1e-12);
        }
    }

    #[test]
    fn breakeven_exists_and_is_positive() {
        let d = breakeven_dispatch_ms(
            &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 24.0, 8192.0);
        assert!(d.is_finite() && d > 1.0, "breakeven = {d}");
        // At the breakeven the ratio is ~1.
        let r = dispatch_erosion(
            &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 24.0, 8192.0, &[d])[0]
            .ratio;
        assert!((r - 1.0).abs() < 1e-3, "ratio at breakeven = {r}");
        // Breakeven widens at weight-bound operating points (smaller n).
        let d_low_n = breakeven_dispatch_ms(
            &H100, &QWEN3_235B_A22B, &LLAMA31_70B, 8, 2.0, 8192.0);
        assert!(d_low_n > d, "low-n breakeven {d_low_n} > high-n {d}");
    }

    #[test]
    fn breakeven_past_the_old_bracket_is_finite() {
        // DeepSeek-V3 (fp8 actives + MLA-compressed KV) vs Llama-3.1-405B
        // fp16 at 128K context: the dense baseline's τ is dominated by a
        // ~39 ms weight stream plus a huge KV scan, so the MoE edge only
        // dies around ~350 ms of dispatch. The old fixed hi = 200.0
        // bracket silently reported INFINITY here.
        let d = breakeven_dispatch_ms(
            &H100, &DEEPSEEK_V3, &LLAMA31_405B, 8, 128.0, 131_072.0);
        assert!(d.is_finite(), "bracket growth must find the root");
        assert!(d > 200.0, "breakeven {d} should exceed the old bracket");
        let r = dispatch_erosion(
            &H100, &DEEPSEEK_V3, &LLAMA31_405B, 8, 128.0, 131_072.0, &[d])[0]
            .ratio;
        assert!((r - 1.0).abs() < 1e-3, "ratio at breakeven = {r}");
    }
}
