//! Quantization effects (paper §5.2): fp8/int4 cut weight bytes 2–4×,
//! proportionally reducing the weight-streaming time `W`. The benefit is
//! largest for dense models bottlenecked by weight streaming at moderate
//! concurrency, and smallest for MoE models where `W` is already small.

use super::Roofline;
use crate::model::spec::{ModelSpec, Precision};
use crate::model::KvPlacement;
use crate::power::GpuSpec;

/// tok/W gain from quantizing weights `from` → `to` at a fixed operating
/// point `(n, l_bar)` (power is unchanged — same concurrency, same GPU).
pub fn quant_speedup(
    gpu: &GpuSpec,
    model: &ModelSpec,
    tp: u32,
    placement: KvPlacement,
    from: Precision,
    to: Precision,
    n: f64,
    l_bar: f64,
) -> f64 {
    let a = Roofline::from_specs(gpu, model, from, tp, placement);
    let b = Roofline::from_specs(gpu, model, to, tp, placement);
    b.throughput_tok_s(n, l_bar) / a.throughput_tok_s(n, l_bar)
}

/// §5.2 sweep row: one precision's W and throughput at a fixed point.
#[derive(Debug, Clone)]
pub struct QuantRow {
    pub precision: Precision,
    pub w_ms: f64,
    pub throughput_tok_s: f64,
    pub speedup_vs_fp16: f64,
}

/// Sweep all precisions for the §5.2 analysis.
pub fn quant_sweep(
    gpu: &GpuSpec,
    model: &ModelSpec,
    tp: u32,
    placement: KvPlacement,
    n: f64,
    l_bar: f64,
) -> Vec<QuantRow> {
    let base = Roofline::from_specs(gpu, model, Precision::Fp16, tp, placement)
        .throughput_tok_s(n, l_bar);
    [Precision::Fp16, Precision::Fp8, Precision::Int4]
        .into_iter()
        .map(|p| {
            let r = Roofline::from_specs(gpu, model, p, tp, placement);
            let t = r.throughput_tok_s(n, l_bar);
            QuantRow {
                precision: p,
                w_ms: r.w_ms,
                throughput_tok_s: t,
                speedup_vs_fp16: t / base,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::{LLAMA31_70B, QWEN3_235B_A22B};
    use crate::power::profiles::H100;

    #[test]
    fn fp8_speedup_largest_at_low_concurrency() {
        // At low n, τ ≈ W so halving W nearly doubles throughput; at high
        // n the KV term dominates and the gain shrinks (paper §5.2).
        let lo = quant_speedup(&H100, &LLAMA31_70B, 8, KvPlacement::Sharded,
                               Precision::Fp16, Precision::Fp8, 1.0, 8192.0);
        let hi = quant_speedup(&H100, &LLAMA31_70B, 8, KvPlacement::Sharded,
                               Precision::Fp16, Precision::Fp8, 128.0, 8192.0);
        assert!(lo > 1.8, "lo-concurrency speedup = {lo}");
        assert!(hi < lo, "gain must shrink as KV term dominates: {hi} < {lo}");
        assert!(hi > 1.0);
    }

    #[test]
    fn moe_gains_less_from_quant_than_dense() {
        let dense = quant_speedup(&H100, &LLAMA31_70B, 8, KvPlacement::Sharded,
                                  Precision::Fp16, Precision::Fp8, 32.0, 8192.0);
        let moe = quant_speedup(&H100, &QWEN3_235B_A22B, 8, KvPlacement::Sharded,
                                Precision::Fp16, Precision::Fp8, 32.0, 8192.0);
        assert!(moe < dense, "MoE W already small: {moe} < {dense}");
    }

    #[test]
    fn sweep_is_monotone_in_precision() {
        let rows = quant_sweep(&H100, &LLAMA31_70B, 8, KvPlacement::Sharded,
                               16.0, 8192.0);
        assert_eq!(rows.len(), 3);
        assert!(rows[0].w_ms > rows[1].w_ms && rows[1].w_ms > rows[2].w_ms);
        assert!(rows[2].speedup_vs_fp16 > rows[1].speedup_vs_fp16);
        assert!((rows[0].speedup_vs_fp16 - 1.0).abs() < 1e-12);
    }
}
