//! Speculative decoding × the P(b) framework (paper §10.3 "Speculative
//! decoding interaction" — flagged there as an open problem; this module
//! supplies the model).
//!
//! A draft model proposes `k` tokens which the target model verifies in
//! one batched iteration. Per verify iteration a slot advances an expected
//! `E[accepted] = (1 − α^{k+1}) / (1 − α)` tokens (α = per-token
//! acceptance rate), at the cost of (a) the draft model's `k` iterations
//! and (b) a verify iteration whose *effective batch* is `n · (k+1)`
//! query tokens — which pushes the GPU up the logistic power curve. tok/W
//! improves only when the acceptance gain outruns the draft power + the
//! higher verify power.

use super::Roofline;
use crate::power::LogisticPower;

/// Speculative configuration.
#[derive(Debug, Clone, Copy)]
pub struct SpecConfig {
    /// Draft length per verify step.
    pub k: u32,
    /// Per-token acceptance probability α ∈ [0, 1).
    pub alpha: f64,
    /// Draft model weight-streaming time per iteration, ms (e.g. a 1B
    /// draft ≈ W_target × (1/70)).
    pub draft_w_ms: f64,
    /// Draft model idle+active power is folded into the same GPU (self-
    /// speculation / co-located draft): extra watts while drafting.
    pub draft_power_scale: f64,
}

impl SpecConfig {
    /// Expected tokens accepted per verify iteration (including the
    /// bonus token), the standard speculative-decoding formula.
    pub fn expected_tokens(&self) -> f64 {
        if self.alpha >= 1.0 {
            return (self.k + 1) as f64;
        }
        (1.0 - self.alpha.powi(self.k as i32 + 1)) / (1.0 - self.alpha)
    }

    /// Fold the draft+verify iteration into an *effective* roofline for
    /// the scenario layer: with `E = expected_tokens()`, one verify
    /// iteration costs `k·draft_w + τ(n, L̄)` ms and yields `E` tokens
    /// per slot, so the per-accepted-token roofline is
    /// `W' = (W + dispatch + k·draft_w) / E`, `H0' = H0 / E`. Then
    /// `n / τ'(n, L̄)` equals [`spec_point`]'s throughput exactly (the
    /// identity test below pins it), and both fleet engines consume
    /// speculation through the same τ(n, L̄) path as every other
    /// profile. Power is *not* folded here — profiles bill the target
    /// curve P(n), a documented approximation of `spec_point`'s
    /// time-weighted draft/verify split.
    pub fn effective_roofline(&self, target: &Roofline) -> Roofline {
        let e = self.expected_tokens();
        Roofline::manual(
            (target.w_ms
                + target.dispatch_ms
                + self.k as f64 * self.draft_w_ms)
                / e,
            target.h0_ms / e,
        )
    }
}

/// tok/W at a speculative operating point.
#[derive(Debug, Clone, Copy)]
pub struct SpecPoint {
    pub expected_tokens_per_iter: f64,
    pub iter_ms: f64,
    pub throughput_tok_s: f64,
    pub power_w: f64,
    pub tok_per_watt: f64,
}

/// Evaluate speculative decoding for `n` sequences at mean context
/// `l_bar` on a target roofline + power curve.
pub fn spec_point(
    target: &Roofline,
    power: &LogisticPower,
    cfg: &SpecConfig,
    n: f64,
    l_bar: f64,
) -> SpecPoint {
    let e_tok = cfg.expected_tokens();
    // Draft phase: k tiny iterations (draft KV scan negligible next to
    // its weight stream at small models; folded into draft_w_ms).
    let draft_ms = cfg.k as f64 * cfg.draft_w_ms;
    // Verify phase: one target iteration; the KV-scan term is unchanged
    // (same sequences) but each sequence now carries k+1 query tokens, so
    // the effective batch on the power curve is n·(k+1).
    let verify_ms = target.tau_ms(n, l_bar);
    let iter_ms = draft_ms + verify_ms;

    // Time-weighted power: drafting runs near the draft's operating point,
    // verification at the inflated effective batch.
    let p_draft = power.power_w(n) * cfg.draft_power_scale;
    let p_verify = power.power_w(n * (cfg.k + 1) as f64);
    let power_w = (p_draft * draft_ms + p_verify * verify_ms) / iter_ms;

    let throughput = n * e_tok / iter_ms * 1e3;
    SpecPoint {
        expected_tokens_per_iter: e_tok,
        iter_ms,
        throughput_tok_s: throughput,
        power_w,
        tok_per_watt: throughput / power_w,
    }
}

/// Baseline (non-speculative) tok/W at the same point.
pub fn baseline_tok_per_watt(
    target: &Roofline,
    power: &LogisticPower,
    n: f64,
    l_bar: f64,
) -> f64 {
    target.throughput_tok_s(n, l_bar) / power.power_w(n)
}

/// The acceptance rate at which speculation breaks even on tok/W
/// (bisection over α).
pub fn breakeven_alpha(
    target: &Roofline,
    power: &LogisticPower,
    cfg: &SpecConfig,
    n: f64,
    l_bar: f64,
) -> Option<f64> {
    let base = baseline_tok_per_watt(target, power, n, l_bar);
    let gain = |alpha: f64| {
        let c = SpecConfig { alpha, ..*cfg };
        spec_point(target, power, &c, n, l_bar).tok_per_watt - base
    };
    if gain(0.999) < 0.0 {
        return None; // never pays off at this point
    }
    if gain(0.0) > 0.0 {
        return Some(0.0);
    }
    let (mut lo, mut hi) = (0.0, 0.999);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if gain(mid) > 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h100_70b() -> (Roofline, LogisticPower) {
        (Roofline::manual(6.72, 0.1387), LogisticPower::h100())
    }

    fn cfg(alpha: f64) -> SpecConfig {
        SpecConfig {
            k: 4,
            alpha,
            draft_w_ms: 6.72 / 70.0, // ~1B draft
            draft_power_scale: 0.8,
        }
    }

    #[test]
    fn expected_tokens_formula() {
        assert!((cfg(0.0).expected_tokens() - 1.0).abs() < 1e-12);
        // α = 0.8, k = 4: (1 − 0.8⁵) / 0.2 = 3.3616.
        assert!((cfg(0.8).expected_tokens() - 3.3616).abs() < 1e-4);
        let full = SpecConfig { alpha: 1.0, ..cfg(0.0) };
        assert_eq!(full.expected_tokens(), 5.0);
    }

    #[test]
    fn high_acceptance_improves_tok_w_at_low_concurrency() {
        // At low n the verify batch inflation barely moves P(b) while
        // throughput multiplies — speculation wins.
        let (r, p) = h100_70b();
        let base = baseline_tok_per_watt(&r, &p, 4.0, 8192.0);
        let s = spec_point(&r, &p, &cfg(0.8), 4.0, 8192.0);
        assert!(
            s.tok_per_watt > base * 1.5,
            "spec {} vs base {base}",
            s.tok_per_watt
        );
    }

    #[test]
    fn low_acceptance_hurts() {
        let (r, p) = h100_70b();
        let base = baseline_tok_per_watt(&r, &p, 16.0, 65_536.0);
        let s = spec_point(&r, &p, &cfg(0.1), 16.0, 65_536.0);
        assert!(s.tok_per_watt < base, "spec {} vs base {base}", s.tok_per_watt);
    }

    #[test]
    fn breakeven_alpha_is_sane_and_monotone_in_n() {
        let (r, p) = h100_70b();
        let a_low_n = breakeven_alpha(&r, &p, &cfg(0.0), 4.0, 8192.0).unwrap();
        let a_high_n = breakeven_alpha(&r, &p, &cfg(0.0), 128.0, 8192.0).unwrap();
        assert!((0.0..1.0).contains(&a_low_n));
        assert!((0.0..1.0).contains(&a_high_n));
        // At saturated batch, the power inflation from n·(k+1) is free
        // (already at P_nom) but throughput per iteration saturates the
        // memory bus — breakeven must not be easier at high n than the
        // draft overhead allows.
        assert!(a_high_n >= 0.0);
    }

    #[test]
    fn verify_power_rises_with_effective_batch() {
        let (r, p) = h100_70b();
        let s_small = spec_point(&r, &p, &cfg(0.8), 2.0, 8192.0);
        let s_big = spec_point(&r, &p, &cfg(0.8), 64.0, 8192.0);
        assert!(s_big.power_w > s_small.power_w);
    }

    #[test]
    fn effective_roofline_reproduces_spec_point_throughput() {
        // The folding identity: n / τ'(n, L̄) on the effective roofline
        // must equal spec_point's n·E/iter_ms, at every operating point.
        let (r, p) = h100_70b();
        let c = cfg(0.8);
        let eff = c.effective_roofline(&r);
        for (n, l_bar) in
            [(1.0, 2048.0), (4.0, 8192.0), (64.0, 8192.0), (16.0, 65_536.0)]
        {
            let via_roofline = eff.throughput_tok_s(n, l_bar);
            let via_point = spec_point(&r, &p, &c, n, l_bar).throughput_tok_s;
            assert!(
                (via_roofline - via_point).abs() / via_point < 1e-9,
                "n={n} l_bar={l_bar}: {via_roofline} vs {via_point}"
            );
        }
        // α = 0 (every draft rejected, only the bonus token lands):
        // E = 1, so the effective roofline is pure draft overhead on top.
        let none = SpecConfig { alpha: 0.0, ..c };
        let eff0 = none.effective_roofline(&r);
        assert!(eff0.tau_ms(8.0, 8192.0) > r.tau_ms(8.0, 8192.0));
    }
}
