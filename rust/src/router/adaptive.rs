//! Load-aware two-pool context routing: spill short-pool overflow to the
//! long pool under congestion.
//!
//! Plain context routing fixes the split at `B_short` no matter what the
//! pools are doing; under a short-heavy burst the short pool queues while
//! the long pool idles (yet still draws idle watts — §5.1). The long
//! pool's window is a superset of the short pool's, so any short request
//! *can* run there; this router sends short requests to the long pool
//! whenever the short pool's per-group *queue depth* exceeds the long
//! pool's by `spill_factor`. Queue depth — not in-flight batch — is the
//! congestion signal: a short pool running a large batch with free slots
//! is busy-but-healthy and must not shed efficient traffic onto an idle
//! long pool (that would pay the idle→active power jump for nothing).
//! Long-context requests always go to the long pool — the short window
//! physically cannot hold them (Eq. 3).
//!
//! This is the routing counterpart of what WattGPU/FleetOpt model as
//! dynamic dispatch over live pool state, and is only expressible on the
//! event-driven simulator core (the closed per-group loops of the legacy
//! simulator had no shared clock for a snapshot to be consistent under).

use super::{Route, Router};
use crate::sim::FleetState;
use crate::workload::Request;

/// Two-pool context router with congestion spill (pool 0 = short,
/// pool 1 = long).
#[derive(Debug, Clone)]
pub struct AdaptiveRouter {
    /// Inclusive upper prompt length of the short pool.
    pub b_short: u32,
    /// Spill a short request when
    /// `short queued/group > spill_factor × (long queued/group + 1)`.
    /// The `+ 1` keeps an idle long pool from attracting all traffic.
    /// Tunable from the CLI (`--spill`, on both `simulate` and
    /// `simulate sweep`) and from a scenario spec
    /// (`RouterSpec::Adaptive { spill }`).
    pub spill_factor: f64,
}

impl AdaptiveRouter {
    pub fn new(b_short: u32) -> Self {
        AdaptiveRouter { b_short, spill_factor: 2.0 }
    }

    pub fn with_spill_factor(mut self, f: f64) -> Self {
        assert!(f > 0.0, "spill factor must be positive");
        self.spill_factor = f;
        self
    }
}

impl Router for AdaptiveRouter {
    /// Static fallback (no snapshot): plain two-pool context routing.
    #[inline]
    fn route(&self, req: &Request) -> Route {
        Route {
            pool: usize::from(req.prompt_tokens > self.b_short),
            effective_prompt_tokens: req.prompt_tokens,
        }
    }

    fn num_pools(&self) -> usize {
        2
    }

    fn name(&self) -> String {
        format!(
            "adaptive(b_short={}, spill={})",
            self.b_short, self.spill_factor
        )
    }

    fn is_load_aware(&self) -> bool {
        true
    }

    fn route_live(&self, req: &Request, state: &FleetState) -> Route {
        if req.prompt_tokens > self.b_short {
            // Long context never fits the short window.
            return Route { pool: 1, effective_prompt_tokens: req.prompt_tokens };
        }
        debug_assert!(state.num_pools() >= 2, "adaptive router needs 2 pools");
        let short = state.pool(0).queued_per_group();
        let long = state.pool(1).queued_per_group();
        let pool = usize::from(short > self.spill_factor * (long + 1.0));
        Route { pool, effective_prompt_tokens: req.prompt_tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{GroupLoad, PoolLoad};

    fn req(prompt: u32) -> Request {
        Request { id: 0, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: 8 }
    }

    fn state(short_backlog: usize, long_backlog: usize) -> FleetState {
        let pool = |backlog: usize, window: u32, n_max: u32| PoolLoad {
            window_tokens: window,
            n_max,
            groups: vec![GroupLoad {
                queued: backlog,
                active: 0,
                free_blocks: 100,
                used_blocks: 0,
            }],
        };
        FleetState::from_pools(vec![
            pool(short_backlog, 5120, 128),
            pool(long_backlog, 65_536, 16),
        ])
    }

    #[test]
    fn long_prompts_always_go_long() {
        let r = AdaptiveRouter::new(4096);
        assert_eq!(r.route_live(&req(50_000), &state(0, 100)).pool, 1);
        assert_eq!(r.route(&req(50_000)).pool, 1);
    }

    #[test]
    fn short_prompts_stay_short_when_uncongested() {
        let r = AdaptiveRouter::new(4096);
        assert_eq!(r.route_live(&req(100), &state(1, 0)).pool, 0);
    }

    #[test]
    fn congested_short_pool_spills_to_long() {
        let r = AdaptiveRouter::new(4096);
        // short queue 30 > 2.0 * (1 + 1) -> spill.
        assert_eq!(r.route_live(&req(100), &state(30, 1)).pool, 1);
        // Busy long pool raises the spill bar back up.
        assert_eq!(r.route_live(&req(100), &state(30, 20)).pool, 0);
    }

    #[test]
    fn well_batched_short_pool_without_queue_never_spills() {
        // A large in-flight batch with an empty queue is busy, not
        // congested: spilling would wake an idle long pool for nothing.
        let r = AdaptiveRouter::new(4096);
        let mut s = state(0, 0);
        let mut hot = s.pool(0).group(0);
        hot.active = 100; // hot but queue-free
        s.set_group(0, 0, hot);
        assert_eq!(r.route_live(&req(100), &s).pool, 0);
    }

    #[test]
    fn static_route_matches_context_router_semantics() {
        let r = AdaptiveRouter::new(4096);
        assert_eq!(r.route(&req(4096)).pool, 0, "boundary inclusive-short");
        assert_eq!(r.route(&req(4097)).pool, 1);
        assert!(r.is_load_aware());
        assert_eq!(r.num_pools(), 2);
    }
}
