//! Context-length routing: partition traffic by prompt length across K
//! context-tiered pools (two-pool is the paper's §4/§5 configuration;
//! K ≥ 3 is the §10.3 extension).

use super::{Route, Router};
use crate::workload::Request;

/// K-pool context router: `boundaries[i]` is the inclusive upper prompt
/// length of pool `i`; requests beyond the last boundary go to the final
/// pool (the long pool).
#[derive(Debug, Clone)]
pub struct ContextRouter {
    boundaries: Vec<u32>,
}

impl ContextRouter {
    /// The paper's two-pool split at `b_short`.
    pub fn two_pool(b_short: u32) -> Self {
        ContextRouter { boundaries: vec![b_short] }
    }

    /// K-tier router from sorted boundaries.
    pub fn tiered(mut boundaries: Vec<u32>) -> Self {
        assert!(!boundaries.is_empty());
        boundaries.sort_unstable();
        boundaries.dedup();
        ContextRouter { boundaries }
    }
}

impl Router for ContextRouter {
    #[inline]
    fn route(&self, req: &Request) -> Route {
        // Binary search keeps K-tier routing O(log K); for the common
        // two-pool case this compiles to one compare.
        let pool = self
            .boundaries
            .partition_point(|&b| req.prompt_tokens > b);
        Route { pool, effective_prompt_tokens: req.prompt_tokens }
    }

    fn num_pools(&self) -> usize {
        self.boundaries.len() + 1
    }

    fn name(&self) -> String {
        format!("context({:?})", self.boundaries)
    }
}

/// K-pool bucket router with FleetOpt compress-and-route on the final
/// (longest) pool — the serving-time realization of
/// [`Topology::Partition`](crate::fleet::topology::Topology::Partition).
///
/// `boundaries` are the inclusive upper prompt cutoffs of pools
/// `0..K-1`; anything longer lands in the last pool with its prompt KV
/// compressed by γ, floored at the last boundary (the same arithmetic
/// as [`FleetOptRouter`](super::fleetopt::FleetOptRouter), so a K=2
/// partition with γ replays the two-pool FleetOpt path bit-for-bit).
/// γ = 1 is plain tiered context routing; zero boundaries degenerate to
/// the homogeneous single pool.
#[derive(Debug, Clone)]
pub struct KPoolRouter {
    boundaries: Vec<u32>,
    gamma: f64,
}

impl KPoolRouter {
    pub fn new(mut boundaries: Vec<u32>, gamma: f64) -> Self {
        assert!(gamma >= 1.0, "γ must be >= 1");
        boundaries.sort_unstable();
        boundaries.dedup();
        KPoolRouter { boundaries, gamma }
    }
}

impl Router for KPoolRouter {
    #[inline]
    fn route(&self, req: &Request) -> Route {
        let pool = self
            .boundaries
            .partition_point(|&b| req.prompt_tokens > b);
        if pool == self.boundaries.len() && !self.boundaries.is_empty() {
            // Compress-and-route on the long tail; compression never
            // undercuts the last split boundary (matching FleetOptRouter
            // — at γ = 1 this is the identity).
            let floor = *self.boundaries.last().unwrap();
            let eff = ((req.prompt_tokens as f64 / self.gamma).ceil() as u32)
                .max(floor);
            return Route { pool, effective_prompt_tokens: eff };
        }
        Route { pool, effective_prompt_tokens: req.prompt_tokens }
    }

    fn num_pools(&self) -> usize {
        self.boundaries.len() + 1
    }

    fn name(&self) -> String {
        format!("kpool({:?}, γ={})", self.boundaries, self.gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: u32) -> Request {
        Request { id: 0, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: 1 }
    }

    #[test]
    fn two_pool_split() {
        let r = ContextRouter::two_pool(4096);
        assert_eq!(r.route(&req(100)).pool, 0);
        assert_eq!(r.route(&req(4096)).pool, 0, "boundary is inclusive-short");
        assert_eq!(r.route(&req(4097)).pool, 1);
        assert_eq!(r.num_pools(), 2);
    }

    #[test]
    fn tiered_routing() {
        let r = ContextRouter::tiered(vec![16384, 4096]); // unsorted ok
        assert_eq!(r.num_pools(), 3);
        assert_eq!(r.route(&req(1000)).pool, 0);
        assert_eq!(r.route(&req(8000)).pool, 1);
        assert_eq!(r.route(&req(50_000)).pool, 2);
    }

    #[test]
    fn boundary_edges_exact() {
        let r = ContextRouter::tiered(vec![10, 20]);
        assert_eq!(r.route(&req(10)).pool, 0);
        assert_eq!(r.route(&req(11)).pool, 1);
        assert_eq!(r.route(&req(20)).pool, 1);
        assert_eq!(r.route(&req(21)).pool, 2);
    }

    #[test]
    fn kpool_buckets_by_length_and_matches_context_router_at_gamma_one() {
        let k = KPoolRouter::new(vec![16384, 4096], 1.0); // unsorted ok
        let c = ContextRouter::tiered(vec![4096, 16384]);
        assert_eq!(k.num_pools(), 3);
        for p in [1u32, 4096, 4097, 16384, 16385, 100_000] {
            assert_eq!(k.route(&req(p)), c.route(&req(p)), "prompt {p}");
        }
    }

    #[test]
    fn kpool_compresses_only_the_last_pool() {
        let k = KPoolRouter::new(vec![2048, 8192], 2.0);
        // Interior pools: untouched.
        assert_eq!(k.route(&req(5000)).effective_prompt_tokens, 5000);
        assert_eq!(k.route(&req(5000)).pool, 1);
        // Last pool: γ-compressed, floored at the last boundary.
        let long = k.route(&req(40_000));
        assert_eq!(long.pool, 2);
        assert_eq!(long.effective_prompt_tokens, 20_000);
        assert_eq!(k.route(&req(9000)).effective_prompt_tokens, 8192);
    }

    #[test]
    fn kpool_two_pool_matches_fleetopt_router_bitwise() {
        use crate::router::fleetopt::FleetOptRouter;
        for gamma in [1.0, 2.0, 4.0] {
            let k = KPoolRouter::new(vec![4096], gamma);
            let f = FleetOptRouter::new(4096, gamma);
            for p in [1u32, 4095, 4096, 4097, 5000, 16_000, 100_000] {
                assert_eq!(k.route(&req(p)), f.route(&req(p)), "γ={gamma} p={p}");
            }
        }
    }

    #[test]
    fn kpool_without_boundaries_is_homogeneous() {
        let k = KPoolRouter::new(vec![], 1.0);
        assert_eq!(k.num_pools(), 1);
        assert_eq!(k.route(&req(100_000)).pool, 0);
        assert_eq!(k.route(&req(100_000)).effective_prompt_tokens, 100_000);
    }

    #[test]
    #[should_panic(expected = "γ must be >= 1")]
    fn kpool_rejects_gamma_below_one() {
        KPoolRouter::new(vec![4096], 0.5);
    }
}
