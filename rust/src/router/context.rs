//! Context-length routing: partition traffic by prompt length across K
//! context-tiered pools (two-pool is the paper's §4/§5 configuration;
//! K ≥ 3 is the §10.3 extension).

use super::{Route, Router};
use crate::workload::Request;

/// K-pool context router: `boundaries[i]` is the inclusive upper prompt
/// length of pool `i`; requests beyond the last boundary go to the final
/// pool (the long pool).
#[derive(Debug, Clone)]
pub struct ContextRouter {
    boundaries: Vec<u32>,
}

impl ContextRouter {
    /// The paper's two-pool split at `b_short`.
    pub fn two_pool(b_short: u32) -> Self {
        ContextRouter { boundaries: vec![b_short] }
    }

    /// K-tier router from sorted boundaries.
    pub fn tiered(mut boundaries: Vec<u32>) -> Self {
        assert!(!boundaries.is_empty());
        boundaries.sort_unstable();
        boundaries.dedup();
        ContextRouter { boundaries }
    }
}

impl Router for ContextRouter {
    #[inline]
    fn route(&self, req: &Request) -> Route {
        // Binary search keeps K-tier routing O(log K); for the common
        // two-pool case this compiles to one compare.
        let pool = self
            .boundaries
            .partition_point(|&b| req.prompt_tokens > b);
        Route { pool, effective_prompt_tokens: req.prompt_tokens }
    }

    fn num_pools(&self) -> usize {
        self.boundaries.len() + 1
    }

    fn name(&self) -> String {
        format!("context({:?})", self.boundaries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: u32) -> Request {
        Request { id: 0, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: 1 }
    }

    #[test]
    fn two_pool_split() {
        let r = ContextRouter::two_pool(4096);
        assert_eq!(r.route(&req(100)).pool, 0);
        assert_eq!(r.route(&req(4096)).pool, 0, "boundary is inclusive-short");
        assert_eq!(r.route(&req(4097)).pool, 1);
        assert_eq!(r.num_pools(), 2);
    }

    #[test]
    fn tiered_routing() {
        let r = ContextRouter::tiered(vec![16384, 4096]); // unsorted ok
        assert_eq!(r.num_pools(), 3);
        assert_eq!(r.route(&req(1000)).pool, 0);
        assert_eq!(r.route(&req(8000)).pool, 1);
        assert_eq!(r.route(&req(50_000)).pool, 2);
    }

    #[test]
    fn boundary_edges_exact() {
        let r = ContextRouter::tiered(vec![10, 20]);
        assert_eq!(r.route(&req(10)).pool, 0);
        assert_eq!(r.route(&req(11)).pool, 1);
        assert_eq!(r.route(&req(20)).pool, 1);
        assert_eq!(r.route(&req(21)).pool, 2);
    }
}
