//! FleetOpt routing [Chen et al. 2026a]: two-pool context routing with
//! compress-and-route on the long pool — long requests have their prompt
//! KV compressed by γ before admission, so the long pool behaves as if
//! its context window were `W/γ`.

use super::{Route, Router};
use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct FleetOptRouter {
    pub b_short: u32,
    /// Compression factor applied to long-pool prompts (γ ≥ 1).
    pub gamma: f64,
}

impl FleetOptRouter {
    pub fn new(b_short: u32, gamma: f64) -> Self {
        assert!(gamma >= 1.0, "γ must be ≥ 1");
        FleetOptRouter { b_short, gamma }
    }
}

impl Router for FleetOptRouter {
    #[inline]
    fn route(&self, req: &Request) -> Route {
        if req.prompt_tokens <= self.b_short {
            Route { pool: 0, effective_prompt_tokens: req.prompt_tokens }
        } else {
            // Compress-and-route: the long pool ingests γ× fewer KV
            // tokens (quality impact is outside the energy objective;
            // the paper inherits FleetOpt's mechanism).
            let eff = ((req.prompt_tokens as f64 / self.gamma).ceil() as u32)
                .max(self.b_short); // compression never undercuts the split
            Route { pool: 1, effective_prompt_tokens: eff }
        }
    }

    fn num_pools(&self) -> usize {
        2
    }

    fn name(&self) -> String {
        format!("fleetopt(b_short={}, γ={})", self.b_short, self.gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: u32) -> Request {
        Request { id: 0, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: 1 }
    }

    #[test]
    fn short_traffic_untouched() {
        let r = FleetOptRouter::new(4096, 2.0);
        let route = r.route(&req(1000));
        assert_eq!(route.pool, 0);
        assert_eq!(route.effective_prompt_tokens, 1000);
    }

    #[test]
    fn long_traffic_compressed() {
        let r = FleetOptRouter::new(4096, 2.0);
        let route = r.route(&req(40_000));
        assert_eq!(route.pool, 1);
        assert_eq!(route.effective_prompt_tokens, 20_000);
    }

    #[test]
    fn compression_floors_at_split_boundary() {
        let r = FleetOptRouter::new(4096, 4.0);
        let route = r.route(&req(5000));
        assert_eq!(route.pool, 1);
        assert_eq!(route.effective_prompt_tokens, 4096);
    }

    #[test]
    #[should_panic(expected = "γ must be ≥ 1")]
    fn gamma_validated() {
        FleetOptRouter::new(4096, 0.9);
    }
}
