//! L3 request routing — the serving-time realization of the paper's
//! topology lever. A router maps each request to a pool index in O(1);
//! which pool a request lands in determines the context window (and hence
//! the `P(b)`-curve segment) the GPU serving it operates on.
//!
//! Routers come in two flavors:
//!
//! * **Static** ([`route`](Router::route)) — the decision is a pure
//!   function of the request (prompt length, shape). All of the paper's
//!   topologies are static.
//! * **Load-aware** ([`route_live`](Router::route_live)) — the decision
//!   may additionally read the live [`FleetState`] of per-pool queue
//!   depth, in-flight batch and free KV blocks. The event-driven
//!   simulator maintains that state *incrementally* (one in-place group
//!   update per event) and hands every arrival a borrow of it — reading
//!   fleet load costs nothing, regardless of fleet size (and, in a real
//!   deployment, the serving leader would publish the same view).
//!   [`adaptive::AdaptiveRouter`] is the reference implementation:
//!   context routing that spills short-pool overflow to the long pool
//!   under congestion, with a CLI-tunable spill factor (`--spill`).

pub mod adaptive;
pub mod context;
pub mod fleetopt;
pub mod semantic;

use crate::sim::FleetState;
use crate::workload::Request;

/// A routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    /// Destination pool index.
    pub pool: usize,
    /// Prompt length after any compress-and-route transformation.
    pub effective_prompt_tokens: u32,
}

/// The router protocol. Implementations must be `Send + Sync` (the server
/// shares one router across pool threads) and O(1) per decision — routing
/// is on the hot path of every request.
pub trait Router: Send + Sync {
    fn route(&self, req: &Request) -> Route;

    /// Number of pools this router targets.
    fn num_pools(&self) -> usize;

    fn name(&self) -> String;

    /// True when [`route_live`](Router::route_live) actually reads the
    /// fleet state. Load-aware routers cannot be pre-routed, so the
    /// simulator keeps them on the sequential shared-clock engine and
    /// maintains the live state for them; a router returning `false`
    /// here promises `route_live ≡ route` (the default impl), which lets
    /// the engine skip state maintenance entirely.
    fn is_load_aware(&self) -> bool {
        false
    }

    /// Route with the live fleet state. The engine calls this for every
    /// arrival; `state` is current whenever
    /// [`is_load_aware`](Router::is_load_aware) returns true. Default:
    /// ignore the state and fall back to the static decision, so every
    /// existing router is usable in the event-driven simulator unchanged.
    fn route_live(&self, req: &Request, _state: &FleetState) -> Route {
        self.route(req)
    }
}

/// Single-pool pass-through (the homogeneous baseline).
#[derive(Debug, Clone)]
pub struct HomogeneousRouter;

impl Router for HomogeneousRouter {
    fn route(&self, req: &Request) -> Route {
        Route { pool: 0, effective_prompt_tokens: req.prompt_tokens }
    }
    fn num_pools(&self) -> usize {
        1
    }
    fn name(&self) -> String {
        "homogeneous".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_routes_everything_to_pool_zero() {
        let r = HomogeneousRouter;
        for p in [1u32, 1000, 100_000] {
            let req = Request { id: 0, arrival_s: 0.0, prompt_tokens: p, output_tokens: 1 };
            assert_eq!(r.route(&req).pool, 0);
            assert_eq!(r.route(&req).effective_prompt_tokens, p);
        }
        assert_eq!(r.num_pools(), 1);
    }

    #[test]
    fn route_live_defaults_to_static_route() {
        let r = HomogeneousRouter;
        assert!(!r.is_load_aware());
        let req = Request { id: 0, arrival_s: 0.0, prompt_tokens: 7, output_tokens: 1 };
        let state = FleetState::empty();
        assert_eq!(r.route_live(&req, &state), r.route(&req));
    }
}
