//! Semantic routing (paper §5.1): send "simple" requests to a small-model
//! pool and the rest to the large model. Real semantic routers classify
//! prompt content; this offline build uses the paper's own observable
//! proxy — request shape (prompt length plus expected output effort) —
//! with a pluggable difficulty function so a learned classifier can drop
//! in (the GreenServ comparison point in §8).

use super::{Route, Router};
use crate::workload::Request;

/// Difficulty estimate in [0, 1]: ≥ threshold → large-model pool.
pub type DifficultyFn = fn(&Request) -> f64;

/// Default proxy: long prompts or long expected outputs are "hard".
pub fn shape_difficulty(req: &Request) -> f64 {
    let p = (req.prompt_tokens as f64 / 8192.0).min(1.0);
    let o = (req.output_tokens as f64 / 1024.0).min(1.0);
    (0.7 * p + 0.3 * o).min(1.0)
}

#[derive(Clone)]
pub struct SemanticRouter {
    pub difficulty: DifficultyFn,
    pub threshold: f64,
}

impl SemanticRouter {
    pub fn new(threshold: f64) -> Self {
        SemanticRouter { difficulty: shape_difficulty, threshold }
    }

    pub fn with_difficulty(difficulty: DifficultyFn, threshold: f64) -> Self {
        SemanticRouter { difficulty, threshold }
    }
}

impl Router for SemanticRouter {
    #[inline]
    fn route(&self, req: &Request) -> Route {
        let pool = usize::from((self.difficulty)(req) >= self.threshold);
        Route { pool, effective_prompt_tokens: req.prompt_tokens }
    }

    /// Pool 0 = small model, pool 1 = large model.
    fn num_pools(&self) -> usize {
        2
    }

    fn name(&self) -> String {
        format!("semantic(threshold={})", self.threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt: u32, out: u32) -> Request {
        Request { id: 0, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: out }
    }

    #[test]
    fn easy_requests_go_small() {
        let r = SemanticRouter::new(0.3);
        assert_eq!(r.route(&req(500, 100)).pool, 0);
    }

    #[test]
    fn hard_requests_go_large() {
        let r = SemanticRouter::new(0.3);
        assert_eq!(r.route(&req(50_000, 100)).pool, 1);
        assert_eq!(r.route(&req(100, 2000)).pool, 1, "output effort counts");
    }

    #[test]
    fn custom_difficulty_pluggable() {
        fn always_hard(_: &Request) -> f64 {
            1.0
        }
        let r = SemanticRouter::with_difficulty(always_hard, 0.5);
        assert_eq!(r.route(&req(1, 1)).pool, 1);
    }

    #[test]
    fn difficulty_bounded() {
        let d = shape_difficulty(&req(u32::MAX / 2, u32::MAX / 2));
        assert!(d <= 1.0);
    }
}
