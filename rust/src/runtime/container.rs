//! WLW1 tensor-container reader — the interchange format `aot.py` writes
//! for `weights.bin` and `golden.bin`:
//!
//! ```text
//! magic "WLW1", u32 count, then per tensor:
//!   u32 name_len, name utf8, u8 dtype (0=f32, 1=i32), u8 ndim,
//!   u64 dims[ndim], raw little-endian data
//! ```

use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One host tensor.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian bytes (len = product(dims) × 4).
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == DType::F32, "{} is not f32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> crate::Result<Vec<i32>> {
        anyhow::ensure!(self.dtype == DType::I32, "{} is not i32", self.name);
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Dims as i64 for `Literal::reshape`.
    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

/// An ordered container (order matters for the HLO parameter list).
#[derive(Debug, Clone, Default)]
pub struct Container {
    pub tensors: Vec<HostTensor>,
    index: BTreeMap<String, usize>,
}

impl Container {
    pub fn get(&self, name: &str) -> crate::Result<&HostTensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' not in container"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// Parse a WLW1 container from bytes.
pub fn parse(bytes: &[u8]) -> crate::Result<Container> {
    let mut r = Reader { b: bytes, i: 0 };
    let magic = r.take(4)?;
    anyhow::ensure!(magic == b"WLW1", "bad magic {magic:?}");
    let count = r.u32()? as usize;
    let mut c = Container::default();
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())?;
        let dtype = match r.u8()? {
            0 => DType::F32,
            1 => DType::I32,
            d => anyhow::bail!("unknown dtype code {d}"),
        };
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u64()? as usize);
        }
        let n_bytes = dims.iter().product::<usize>() * 4;
        let data = r.take(n_bytes)?.to_vec();
        c.index.insert(name.clone(), c.tensors.len());
        c.tensors.push(HostTensor { name, dtype, dims, data });
    }
    anyhow::ensure!(r.i == bytes.len(), "trailing bytes in container");
    Ok(c)
}

/// Load a container from disk.
pub fn load(path: &Path) -> crate::Result<Container> {
    parse(&std::fs::read(path)?)
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        anyhow::ensure!(self.i + n <= self.b.len(), "container truncated");
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> crate::Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> crate::Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(tensors: &[(&str, DType, &[usize], Vec<u8>)]) -> Vec<u8> {
        let mut b = b"WLW1".to_vec();
        b.extend((tensors.len() as u32).to_le_bytes());
        for (name, dt, dims, data) in tensors {
            b.extend((name.len() as u32).to_le_bytes());
            b.extend(name.as_bytes());
            b.push(match dt {
                DType::F32 => 0,
                DType::I32 => 1,
            });
            b.push(dims.len() as u8);
            for d in *dims {
                b.extend((*d as u64).to_le_bytes());
            }
            b.extend(data);
        }
        b
    }

    #[test]
    fn roundtrip() {
        let f: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let bytes = build(&[("w", DType::F32, &[2, 2], f)]);
        let c = parse(&bytes).unwrap();
        assert_eq!(c.len(), 1);
        let t = c.get("w").unwrap();
        assert_eq!(t.dims, vec![2, 2]);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse(b"NOPE").is_err());
        let f: Vec<u8> = vec![0; 16];
        let mut bytes = build(&[("w", DType::F32, &[2, 2], f)]);
        bytes.truncate(bytes.len() - 3);
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let bytes = build(&[]);
        let c = parse(&bytes).unwrap();
        assert!(c.get("nope").is_err());
        assert!(c.is_empty());
    }
}
