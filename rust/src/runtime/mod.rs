//! Runtime layer: PJRT execution of the AOT artifacts ([`pjrt`]), the
//! WLW1 tensor container ([`container`]), and a minimal JSON parser for
//! the manifest ([`json`]). Python never runs on the request path — the
//! Rust binary is self-contained once `make artifacts` has produced
//! `artifacts/*.hlo.txt` + `weights.bin`.

pub mod container;
pub mod json;
pub mod pjrt;

pub use pjrt::{default_artifacts_dir, ModelCfg, TinyModel};
