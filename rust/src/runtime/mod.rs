//! Runtime layer: execution of the AOT artifacts, the WLW1 tensor
//! container ([`container`]), and a minimal JSON parser for the manifest
//! ([`json`]). Python never runs on the request path — the Rust binary is
//! self-contained once `make artifacts` has produced
//! `artifacts/*.hlo.txt` + `weights.bin`.
//!
//! Two backends share the [`ModelCfg`]/`TinyModel` surface:
//!
//! * **`pjrt` feature on** — [`pjrt`] compiles the HLO text on the CPU
//!   PJRT client and executes prefill/decode for real (requires the
//!   vendored `xla` bindings).
//! * **`pjrt` feature off** (the offline default) — [`stub`] keeps every
//!   call site compiling and reports the missing feature at runtime; the
//!   analytical planner and the event-driven simulator are unaffected.

pub mod container;
pub mod json;
pub mod modelcfg;

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use modelcfg::ModelCfg;

#[cfg(feature = "pjrt")]
pub use pjrt::TinyModel;
#[cfg(not(feature = "pjrt"))]
pub use stub::TinyModel;

/// The backend's KV-cache tensor handle, threaded through the engine.
#[cfg(feature = "pjrt")]
pub type Kv = xla::Literal;
#[cfg(not(feature = "pjrt"))]
pub use stub::Kv;

use std::path::PathBuf;

/// Default artifacts location (repo-root relative, overridable by env).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("WATTLAW_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from("artifacts")
}
