//! Backend-independent model geometry, parsed from `manifest.json`.
//!
//! Both runtime backends (the real PJRT executor and the no-`xla` stub)
//! share this so the serving/engine layers can be compiled and tested
//! without the `pjrt` feature.

use super::json;

/// Static model geometry parsed from `manifest.json` (mirrors the Python
/// `ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelCfg {
    pub vocab: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_q_heads: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    pub d_ff: u32,
    pub max_seq: u32,
    pub batch: u32,
    pub prefill_len: u32,
}

impl ModelCfg {
    pub fn kv_dims(&self) -> [i64; 5] {
        [
            self.n_layers as i64,
            self.batch as i64,
            self.max_seq as i64,
            self.n_kv_heads as i64,
            self.head_dim as i64,
        ]
    }

    /// κ in f32 bytes/token — matches `ModelConfig.kv_bytes_per_token`.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * 4 * self.n_layers as u64 * self.n_kv_heads as u64 * self.head_dim as u64
    }
}

pub(crate) fn parse_cfg(manifest: &json::Json) -> crate::Result<ModelCfg> {
    let c = manifest
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("manifest missing config"))?;
    let f = |k: &str| -> crate::Result<u32> {
        c.get(k)
            .and_then(|v| v.as_u32())
            .ok_or_else(|| anyhow::anyhow!("manifest config missing {k}"))
    };
    Ok(ModelCfg {
        vocab: f("vocab")?,
        d_model: f("d_model")?,
        n_layers: f("n_layers")?,
        n_q_heads: f("n_q_heads")?,
        n_kv_heads: f("n_kv_heads")?,
        head_dim: f("head_dim")?,
        d_ff: f("d_ff")?,
        max_seq: f("max_seq")?,
        batch: f("batch")?,
        prefill_len: f("prefill_len")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_kv_bytes() {
        let cfg = ModelCfg {
            vocab: 512, d_model: 256, n_layers: 4, n_q_heads: 8,
            n_kv_heads: 2, head_dim: 32, d_ff: 688, max_seq: 512,
            batch: 8, prefill_len: 64,
        };
        assert_eq!(cfg.kv_bytes_per_token(), 2 * 4 * 4 * 2 * 32);
        assert_eq!(cfg.kv_dims(), [4, 8, 512, 2, 32]);
    }

    #[test]
    fn manifest_parsing() {
        let doc = r#"{"config": {"vocab": 512, "d_model": 256, "n_layers": 4,
            "n_q_heads": 8, "n_kv_heads": 2, "head_dim": 32, "d_ff": 688,
            "max_seq": 512, "batch": 8, "prefill_len": 64,
            "rope_theta": 10000.0}}"#;
        let j = json::parse(doc).unwrap();
        let cfg = parse_cfg(&j).unwrap();
        assert_eq!(cfg.batch, 8);
        assert_eq!(cfg.max_seq, 512);
    }
}
