//! PJRT runtime: load the AOT HLO-text artifacts, compile them on the CPU
//! PJRT client, and execute prefill/decode on the request path.
//!
//! This is the only place Rust touches XLA. Interchange is HLO **text**
//! (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos; the
//! text parser reassigns ids — see /opt/xla-example/README.md). Python is
//! involved only at `make artifacts` time; the binary is self-contained
//! afterwards.

use std::path::{Path, PathBuf};

use xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

use super::container::{self, Container};
use super::json;
use super::modelcfg::{parse_cfg, ModelCfg};

/// The serving-demo model, compiled and resident on the CPU PJRT client.
///
/// Weights are uploaded to device buffers **once** at load; per-step
/// inputs (tokens, positions, KV) are uploaded as Rust-owned buffers and
/// executed via `execute_b`. (The C wrapper's literal-taking `execute`
/// leaks its internally created input buffers — ~45 MB per decode step on
/// this model — so the runtime owns every buffer explicitly; see
/// EXPERIMENTS.md §Perf.)
pub struct TinyModel {
    client: PjRtClient,
    decode_exe: PjRtLoadedExecutable,
    prefill_exe: PjRtLoadedExecutable,
    /// Device-resident weights in PARAM_ORDER.
    weight_bufs: Vec<PjRtBuffer>,
    /// Host-side weight literals. MUST outlive `weight_bufs`:
    /// `buffer_from_host_literal` copies asynchronously, so dropping the
    /// source literal early is a use-after-free (observed as an XLA size
    /// check abort).
    _weight_lits: Vec<Literal>,
    pub cfg: ModelCfg,
    artifacts_dir: PathBuf,
}

fn compile(client: &PjRtClient, path: &Path) -> crate::Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
    )?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl TinyModel {
    /// Load artifacts (HLO text + weights + manifest) and compile.
    pub fn load(artifacts_dir: &Path) -> crate::Result<Self> {
        let manifest_text =
            std::fs::read_to_string(artifacts_dir.join("manifest.json"))?;
        let manifest = json::parse(&manifest_text)?;
        let cfg = parse_cfg(&manifest)?;

        let client = PjRtClient::cpu()?;
        let decode_exe = compile(&client, &artifacts_dir.join("decode_step.hlo.txt"))?;
        let prefill_exe = compile(&client, &artifacts_dir.join("prefill.hlo.txt"))?;

        // Weights in the exact order the HLO parameter list expects.
        let weights_c = container::load(&artifacts_dir.join("weights.bin"))?;
        let order: Vec<String> = manifest
            .get("param_order")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut weight_bufs = Vec::with_capacity(order.len());
        let mut weight_lits = Vec::with_capacity(order.len());
        for name in &order {
            let t = weights_c.get(name)?;
            let lit = Literal::vec1(&t.as_f32()?).reshape(&t.dims_i64())?;
            weight_bufs.push(client.buffer_from_host_literal(None, &lit)?);
            weight_lits.push(lit); // keep alive: async host->device copy
        }

        Ok(TinyModel {
            client,
            decode_exe,
            prefill_exe,
            weight_bufs,
            _weight_lits: weight_lits,
            cfg,
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    /// Zero-initialized KV caches.
    pub fn fresh_kv(&self) -> crate::Result<(Literal, Literal)> {
        let n: usize = self.cfg.kv_dims().iter().product::<i64>() as usize;
        let zeros = vec![0f32; n];
        let k = Literal::vec1(&zeros).reshape(&self.cfg.kv_dims())?;
        let v = Literal::vec1(&zeros).reshape(&self.cfg.kv_dims())?;
        Ok((k, v))
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        extra: &[&Literal],
    ) -> crate::Result<Vec<Literal>> {
        // Upload per-step inputs as Rust-owned buffers (dropped after the
        // call); weights are already device-resident.
        let extra_bufs: Vec<PjRtBuffer> = extra
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<_, _>>()?;
        let mut inputs: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        inputs.extend(extra_bufs.iter());
        let result = exe.execute_b::<&PjRtBuffer>(&inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Prefill a full batch of prompts.
    ///
    /// `tokens` is row-major `[B, prefill_len]`; `lens[b] >= 1` is each
    /// prompt's true length. Returns (last-position logits `[B, vocab]`,
    /// kv_k, kv_v).
    pub fn prefill(
        &self,
        tokens: &[i32],
        lens: &[i32],
    ) -> crate::Result<(Vec<f32>, Literal, Literal)> {
        let b = self.cfg.batch as usize;
        let t = self.cfg.prefill_len as usize;
        anyhow::ensure!(tokens.len() == b * t, "tokens must be [B, T]");
        anyhow::ensure!(lens.len() == b, "lens must be [B]");
        let tok = Literal::vec1(tokens).reshape(&[b as i64, t as i64])?;
        let len_lit = Literal::vec1(lens);
        let mut out = self.run(&self.prefill_exe, &[&tok, &len_lit])?;
        anyhow::ensure!(out.len() == 3, "prefill returns a 3-tuple");
        let kv_v = out.pop().unwrap();
        let kv_k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, kv_k, kv_v))
    }

    /// One continuous-batching decode iteration.
    ///
    /// `tokens[b]` is the token slot `b` consumes this step, written at
    /// position `pos[b]`; attention sees lengths `pos + 1`. Returns
    /// (logits `[B, vocab]`, kv_k', kv_v').
    pub fn decode_step(
        &self,
        tokens: &[i32],
        kv_k: &Literal,
        kv_v: &Literal,
        pos: &[i32],
    ) -> crate::Result<(Vec<f32>, Literal, Literal)> {
        let b = self.cfg.batch as usize;
        anyhow::ensure!(tokens.len() == b && pos.len() == b);
        let tok = Literal::vec1(tokens);
        let pos_lit = Literal::vec1(pos);
        let mut out =
            self.run(&self.decode_exe, &[&tok, kv_k, kv_v, &pos_lit])?;
        anyhow::ensure!(out.len() == 3, "decode returns a 3-tuple");
        let kv_v_n = out.pop().unwrap();
        let kv_k_n = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, kv_k_n, kv_v_n))
    }

    /// Greedy sampling over `[B, vocab]` logits.
    pub fn argmax(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.cfg.vocab as usize;
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Validate the runtime against the JAX golden trace
    /// (`artifacts/golden.bin`): prefill + two decode steps must reproduce
    /// every logits tensor. Returns the max absolute error seen.
    pub fn validate_golden(&self) -> crate::Result<f64> {
        let g = container::load(&self.artifacts_dir.join("golden.bin"))?;
        let max_err = run_golden(self, &g)?;
        Ok(max_err)
    }
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max)
}

fn run_golden(m: &TinyModel, g: &Container) -> crate::Result<f64> {
    let mut worst = 0.0f64;

    let tokens = g.get("prefill.in.tokens")?.as_i32()?;
    let lens = g.get("prefill.in.lens")?.as_i32()?;
    let (last_logits, kv_k, kv_v) = m.prefill(&tokens, &lens)?;
    worst = worst.max(max_abs_err(
        &last_logits,
        &g.get("prefill.out.last_logits")?.as_f32()?,
    ));

    let t1 = g.get("decode1.in.tokens")?.as_i32()?;
    let p1 = g.get("decode1.in.pos")?.as_i32()?;
    let (logits1, kv_k1, kv_v1) = m.decode_step(&t1, &kv_k, &kv_v, &p1)?;
    worst = worst.max(max_abs_err(
        &logits1,
        &g.get("decode1.out.logits")?.as_f32()?,
    ));

    let t2 = g.get("decode2.in.tokens")?.as_i32()?;
    let p2 = g.get("decode2.in.pos")?.as_i32()?;
    let (logits2, _, _) = m.decode_step(&t2, &kv_k1, &kv_v1, &p2)?;
    worst = worst.max(max_abs_err(
        &logits2,
        &g.get("decode2.out.logits")?.as_f32()?,
    ));

    Ok(worst)
}

/// Default artifacts location — re-exported for backward compatibility;
/// see [`super::default_artifacts_dir`].
pub use super::default_artifacts_dir;
