//! No-`xla` runtime backend: the same `TinyModel` surface as
//! [`super::pjrt`], but every entry point that would execute compiled HLO
//! reports that the binary was built without the `pjrt` feature.
//!
//! This keeps the real-model serving stack ([`crate::serve::engine`],
//! [`crate::serve::server`], `wattlaw serve` / `wattlaw validate`)
//! compiling in the offline image, where the `xla` bindings are not
//! fetchable. The analytical planner, the event-driven fleet simulator
//! and every table/bench are fully functional without it.

use std::path::{Path, PathBuf};

use super::modelcfg::ModelCfg;

/// Opaque stand-in for the backend's KV-cache tensor handle
/// (`xla::Literal` under the `pjrt` feature).
#[derive(Debug, Clone)]
pub struct Kv;

const DISABLED: &str =
    "wattlaw was built without the `pjrt` feature: the real-model runtime \
     is unavailable (vendor the `xla` crate and rebuild with \
     `--features pjrt`); the analytical planner and the event-driven \
     simulator do not need it";

/// Stub model handle. [`TinyModel::load`] always fails, so the execution
/// methods below are unreachable in practice; they exist to keep the
/// engine layer's call sites compiling unchanged.
pub struct TinyModel {
    pub cfg: ModelCfg,
    #[allow(dead_code)]
    artifacts_dir: PathBuf,
}

impl TinyModel {
    pub fn load(_artifacts_dir: &Path) -> crate::Result<Self> {
        anyhow::bail!(DISABLED)
    }

    pub fn fresh_kv(&self) -> crate::Result<(Kv, Kv)> {
        anyhow::bail!(DISABLED)
    }

    pub fn prefill(
        &self,
        _tokens: &[i32],
        _lens: &[i32],
    ) -> crate::Result<(Vec<f32>, Kv, Kv)> {
        anyhow::bail!(DISABLED)
    }

    pub fn decode_step(
        &self,
        _tokens: &[i32],
        _kv_k: &Kv,
        _kv_v: &Kv,
        _pos: &[i32],
    ) -> crate::Result<(Vec<f32>, Kv, Kv)> {
        anyhow::bail!(DISABLED)
    }

    /// Greedy sampling over `[B, vocab]` logits (pure; identical to the
    /// PJRT backend's implementation).
    pub fn argmax(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.cfg.vocab as usize;
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }

    pub fn validate_golden(&self) -> crate::Result<f64> {
        anyhow::bail!(DISABLED)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn load_reports_missing_feature() {
        let err = TinyModel::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
