//! The scenario layer: one spec, two engines.
//!
//! Every headline claim of the paper — the 1/W law, FleetOpt's ~2.5×,
//! FleetOpt×B200's 4.25× — is a comparison between *scenarios*: (fleet
//! topology × workload × routing/dispatch policy) tuples. Before this
//! module the analytical path (`tokeconomy`/`fleet`/`tables`) and the
//! event-driven simulator were configured through disjoint ad-hoc
//! structs; a [`ScenarioSpec`] now names the whole tuple once and both
//! consumers read it:
//!
//! * [`ScenarioSpec::analyze`] — the closed-form planner:
//!   pools sized to λ under the TTFT SLO, Eq. (4) fleet tok/W.
//! * [`ScenarioSpec::simulate`] — the trace played through the
//!   event-driven core ([`crate::sim`]): continuous batching, paged-KV
//!   admission, live-state routing/dispatch, measured per-request TTFT.
//!
//! Because both read the same spec, an analytical number and a simulated
//! number are comparable by construction — the WattGPU/FleetOpt method
//! of earning trust in an analytical model by sweeping configuration
//! grids cheaply and spot-checking dynamically. [`sweep`] runs such
//! grids (dispatch × topology × context window) across worker threads,
//! pairing each cell's analytical and measured tok/W (`wattlaw simulate
//! sweep`); [`optimize`] turns the same machinery into the FleetOpt
//! provisioning loop — a closed-form screen of the
//! B_short × γ × GPU-generation space, then a simulated re-rank of the
//! survivors under the SLO (`wattlaw optimize`).

pub mod optimize;
pub mod sweep;

use std::sync::Arc;

use crate::fleet::analysis::FleetReport;
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use crate::fleet::topology::Topology;
use crate::power::Gpu;
use crate::router::adaptive::AdaptiveRouter;
use crate::router::Router;
use crate::sim::{
    dispatch, simulate_topology_opts, simulate_topology_source,
    EngineOptions, StepMode, TopoSimReport,
};
use crate::workload::arrival::{ArrivalSource, ArrivalSpec};
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;
use crate::workload::Request;

/// The one user-facing message for adaptive routing on a topology with
/// no split boundary, shared by [`ScenarioSpec::validate`] (CLI-level
/// rejection) and the [`ScenarioSpec::router`] backstop panic so the
/// two can never drift apart.
fn adaptive_router_error(topology: &Topology) -> String {
    format!(
        "adaptive routing needs a two-pool topology with a split \
         boundary, but '{}' has none; use --router static, or a \
         two-pool topology (--topo pool, --topo fleetopt, or --pools 2)",
        topology.label()
    )
}

/// Measured-vs-analytical relative delta, percent — the one convention
/// shared by the sweep's consistency records and the optimizer's
/// refined cells (NaN when the analytical value is degenerate).
pub fn rel_delta_pct(measured_tok_w: f64, analytic_tok_w: f64) -> f64 {
    if analytic_tok_w > 0.0 {
        (measured_tok_w / analytic_tok_w - 1.0) * 100.0
    } else {
        f64::NAN
    }
}

/// Which router realizes the topology at serving time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouterSpec {
    /// The topology's canonical static router
    /// ([`Topology::router`](crate::fleet::topology::Topology::router)).
    Static,
    /// The load-aware [`AdaptiveRouter`] at the topology's split
    /// boundary: short-pool overflow spills to the long pool when the
    /// short queue exceeds `spill` × (long queue + 1) per group.
    /// Requires a two-pool topology.
    Adaptive { spill: f64 },
}

/// Service-level objectives a scenario is judged against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    /// p99 time-to-first-token bound, seconds (the paper's sizing SLO).
    pub ttft_p99_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets { ttft_p99_s: 0.5 }
    }
}

/// One (fleet topology × GPU generation × workload × routing/dispatch ×
/// SLO) cell — everything needed to produce a comparable tok/W number
/// from either engine.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub topology: Topology,
    pub gpu: Gpu,
    /// Model architecture served fleet-wide ([`ModelAxis`]): the dense
    /// Llama-70B baseline (default — the pre-axis behavior, bit-for-bit),
    /// MoE weight-streaming, or dense + speculative decode. Resolved
    /// with `gpu` into one [`ManualProfile`] by [`Self::profile`], so
    /// both engines consume the same roofline — the model-axis twin of
    /// the per-pool GPU unification.
    pub model: ModelAxis,
    pub workload: WorkloadTrace,
    /// Traffic: λ, duration, caps, seed (the base parameters every
    /// arrival process modulates; the analytical path reads
    /// `lambda_rps` as the mean rate).
    pub gen: GenConfig,
    /// The arrival process: stationary Poisson (default), a generated
    /// archetype (diurnal, flash-crowd, multi-tenant, heavy-tail), or
    /// CSV trace replay. [`Self::simulate`] streams it lazily into the
    /// engine in O(1) trace memory.
    pub arrivals: ArrivalSpec,
    /// Total simulated TP groups, split across pools by
    /// [`Topology::sim_pools`].
    pub groups: u32,
    /// Dispatch policy name ([`dispatch::parse`]).
    pub dispatch: String,
    pub router: RouterSpec,
    pub slo: SloTargets,
    /// Chunked-prefill size, prompt tokens per slot per step.
    pub ingest_chunk: u32,
    /// L̄ policy for the analytical side ([`Self::analyze`]).
    pub lbar: LBarPolicy,
    /// Target utilization for the analytical pool sizing.
    pub rho: f64,
    /// Fraction of `slo.ttft_p99_s` the `power-slo` dispatch guard may
    /// spend as projected consolidation delay before refusing to pack
    /// (ignored by every other policy).
    pub power_guard_frac: f64,
    /// Engine step scheduling ([`StepMode`]): the macro-stepping
    /// default, or the one-event-per-step replay oracle.
    pub step_mode: StepMode,
}

impl ScenarioSpec {
    /// A spec with the crate's serving defaults: 8 groups, round-robin
    /// dispatch, the topology's canonical router, 0.5 s p99-TTFT SLO,
    /// 1024-token prefill chunks.
    pub fn new(
        topology: Topology,
        gpu: Gpu,
        workload: WorkloadTrace,
        gen: GenConfig,
    ) -> Self {
        ScenarioSpec {
            topology,
            gpu,
            model: ModelAxis::Dense,
            workload,
            gen,
            arrivals: ArrivalSpec::Stationary,
            groups: 8,
            dispatch: "rr".into(),
            router: RouterSpec::Static,
            slo: SloTargets::default(),
            ingest_chunk: 1024,
            lbar: LBarPolicy::Window,
            rho: 0.85,
            power_guard_frac: 0.5,
            step_mode: StepMode::default(),
        }
    }

    pub fn with_groups(mut self, groups: u32) -> Self {
        assert!(groups > 0);
        self.groups = groups;
        self
    }

    pub fn with_dispatch(mut self, name: &str) -> Self {
        assert!(
            dispatch::parse(name).is_some(),
            "unknown dispatch policy '{name}'"
        );
        self.dispatch = name.into();
        self
    }

    pub fn with_router(mut self, router: RouterSpec) -> Self {
        self.router = router;
        self
    }

    /// Serve this scenario with a model architecture other than the
    /// dense default — the third fleet lever after topology and GPU
    /// generation.
    pub fn with_model(mut self, model: ModelAxis) -> Self {
        self.model = model;
        self
    }

    pub fn with_arrivals(mut self, arrivals: ArrivalSpec) -> Self {
        self.arrivals = arrivals;
        self
    }

    pub fn with_slo(mut self, slo: SloTargets) -> Self {
        self.slo = slo;
        self
    }

    pub fn with_lbar(mut self, lbar: LBarPolicy) -> Self {
        self.lbar = lbar;
        self
    }

    pub fn with_rho(mut self, rho: f64) -> Self {
        assert!(rho > 0.0 && rho <= 1.0, "ρ must be in (0, 1]");
        self.rho = rho;
        self
    }

    pub fn with_step_mode(mut self, step_mode: StepMode) -> Self {
        self.step_mode = step_mode;
        self
    }

    pub fn with_power_guard_frac(mut self, frac: f64) -> Self {
        assert!(
            frac > 0.0 && frac.is_finite(),
            "guard fraction must be positive and finite"
        );
        self.power_guard_frac = frac;
        self
    }

    /// Override the per-pool GPU assignment of a partition topology —
    /// the heterogeneous-fleet builder: `spec.gpu` stays the default
    /// every non-overridden pool falls back to.
    ///
    /// # Panics
    /// On a non-partition topology, or an assignment whose length
    /// differs from the pool count.
    pub fn with_pool_gpus(mut self, gpus: &[Gpu]) -> Self {
        match &mut self.topology {
            Topology::Partition { pools, .. } => {
                assert_eq!(
                    pools.len(),
                    gpus.len(),
                    "one GPU per pool: {} pools vs {} GPUs",
                    pools.len(),
                    gpus.len()
                );
                for (p, &g) in pools.iter_mut().zip(gpus) {
                    p.gpu = Some(g);
                }
            }
            other => panic!(
                "per-pool GPU assignment needs a Partition topology \
                 (got {})",
                other.label()
            ),
        }
        self
    }

    /// The per-pool GPU generations this scenario serves, rendered the
    /// way every results surface shows them: the plain SKU name for a
    /// homogeneous fleet, `H100|H100|B200` when mixed
    /// ([`Topology::pool_gpus`] resolved against the spec default).
    pub fn gpus_label(&self) -> String {
        optimize::assignment_label(&self.topology.pool_gpus(self.gpu))
    }

    /// The dispatch policy realizing `self.dispatch`, with scenario
    /// context applied: `power-slo` gets its consolidation-guard bound
    /// from this spec's own SLO (`power_guard_frac × slo.ttft_p99_s`)
    /// rather than [`dispatch::parse`]'s crate-default bound.
    pub fn dispatch_policy(&self) -> Box<dyn dispatch::DispatchPolicy> {
        if dispatch::is_power_slo(&self.dispatch) {
            return Box::new(crate::sim::PowerAware::with_slo_guard(
                self.power_guard_frac * self.slo.ttft_p99_s,
            ));
        }
        dispatch::parse(&self.dispatch).unwrap_or_else(|| {
            panic!("unknown dispatch policy '{}'", self.dispatch)
        })
    }

    /// Human-readable cell identity for reports.
    pub fn label(&self) -> String {
        format!(
            "{} | {} | {} | {} | {} | {} | λ={}",
            self.workload_label(),
            self.topology.label(),
            // Per-pool assignment when mixed; the plain SKU otherwise.
            self.gpus_label(),
            self.model.label(),
            self.router_label(),
            self.dispatch,
            self.gen.lambda_rps,
        )
    }

    fn router_label(&self) -> String {
        match self.router {
            RouterSpec::Static => "static".into(),
            RouterSpec::Adaptive { spill } => format!("adaptive({spill})"),
        }
    }

    /// The GPU profile serving every pool of this scenario: the model
    /// axis resolved on the scenario GPU ([`ModelAxis::profile_for`];
    /// `Dense` is `ManualProfile::for_gpu`, unchanged to the bit).
    pub fn profile(&self) -> ManualProfile {
        self.model.profile_for(self.gpu)
    }

    /// Check the spec for axis combinations no engine can serve —
    /// everything a CLI invocation can get wrong without touching a
    /// panic path. Today that is adaptive routing on a topology with no
    /// split boundary (the `--router adaptive --topo homo` footgun) and
    /// a degenerate MoE dispatch overhead.
    ///
    /// # Errors
    /// A user-facing message naming the offending axis values.
    pub fn validate(&self) -> Result<(), String> {
        if matches!(self.router, RouterSpec::Adaptive { .. })
            && self.topology.b_short().is_none()
        {
            return Err(adaptive_router_error(&self.topology));
        }
        if let Some(d) = self.model.dispatch_ms() {
            if !d.is_finite() || d < 0.0 {
                return Err(format!(
                    "MoE dispatch overhead must be finite and >= 0 ms \
                     (got {d})"
                ));
            }
        }
        Ok(())
    }

    /// The request router realizing this scenario.
    ///
    /// # Panics
    /// `RouterSpec::Adaptive` on a topology without a split boundary —
    /// a programming error at this layer; user-facing paths reject the
    /// combination earlier with the same message via [`Self::validate`].
    pub fn router(&self) -> Box<dyn Router> {
        match self.router {
            RouterSpec::Static => self.topology.router(),
            RouterSpec::Adaptive { spill } => {
                let b = self.topology.b_short().unwrap_or_else(|| {
                    panic!("{}", adaptive_router_error(&self.topology))
                });
                Box::new(AdaptiveRouter::new(b).with_spill_factor(spill))
            }
        }
    }

    /// The lazy arrival source this scenario plays (deterministic in
    /// `gen.seed` for every generated archetype).
    ///
    /// # Errors
    /// [`ArrivalSpec::Replay`] when the trace file is missing or fails
    /// validation (line-numbered CSV errors); generated archetypes are
    /// infallible.
    pub fn source(&self) -> crate::Result<Box<dyn ArrivalSource>> {
        self.arrivals.source(&self.workload, &self.gen)
    }

    /// The workload axis as every results surface shows it: the trace
    /// name alone for stationary arrivals (`Azure`), trace+process when
    /// an archetype modulates it (`Azure+diurnal(a=0.6)`), the process
    /// alone when it replaces the trace outright (multi-tenant mixes,
    /// CSV replay).
    pub fn workload_label(&self) -> String {
        match &self.arrivals {
            ArrivalSpec::Stationary => self.workload.name.to_string(),
            spec @ (ArrivalSpec::MultiTenant | ArrivalSpec::Replay { .. }) => {
                spec.label()
            }
            spec => format!("{}+{}", self.workload.name, spec.label()),
        }
    }

    /// The scenario's trace, materialized as a `Vec` by draining
    /// [`Self::source`] — the replay oracle for the streaming path and
    /// the input to engines that genuinely need the whole trace in
    /// memory (the parallel fast path, hand-crafted-trace comparisons).
    ///
    /// # Panics
    /// When the source fails to build (replay file missing/invalid);
    /// [`Self::simulate`] and the CLI validate replay specs up front.
    pub fn trace(&self) -> Vec<Request> {
        self.source()
            .expect("arrival source failed to build")
            .collect()
    }

    /// The closed-form side: pools sized to `gen.lambda_rps` under the
    /// TTFT SLO, Eq. (4) fleet tok/W. Same spec, no trace. One shared
    /// evaluation path with the optimizer's stage-A screen
    /// ([`optimize::analyze_cell`]).
    pub fn analyze(&self, acct: PowerAccounting) -> FleetReport {
        let profile: Arc<dyn GpuProfile> = Arc::new(self.profile());
        optimize::analyze_cell(
            &self.topology,
            &self.workload,
            self.gen.lambda_rps,
            profile,
            self.lbar,
            self.rho,
            self.slo.ttft_p99_s,
            acct,
            self.model,
        )
    }

    /// The dynamic side: play this scenario's arrival source through
    /// the event-driven engine.
    ///
    /// Arrivals are **streamed** — the engine pulls one request at a
    /// time from [`Self::source`], so trace memory stays O(1) no matter
    /// how long the run is. When `allow_parallel` is set *and* the
    /// (router, dispatch, fleet) tuple is arrival-static, the engine
    /// takes the sharded streaming fast path: arrivals are demuxed into
    /// bounded per-group channels and every group steps on its own
    /// worker thread, still without materializing the trace
    /// (bit-identical results either way — the engine's replay
    /// guarantee).
    ///
    /// # Panics
    /// When a [`ArrivalSpec::Replay`] source fails to build; the CLI
    /// validates replay files before constructing specs.
    pub fn simulate(&self, allow_parallel: bool) -> ScenarioOutcome {
        let profile = self.profile();
        let (pool_groups, pool_cfgs) = self.topology.sim_pools_with_model(
            &profile,
            self.groups,
            self.ingest_chunk,
            self.model,
        );
        let router = self.router();
        let mut policy = self.dispatch_policy();
        let mut source =
            self.source().expect("arrival source failed to build");
        let report = simulate_topology_source(
            source.as_mut(),
            router.as_ref(),
            &pool_groups,
            &pool_cfgs,
            policy.as_mut(),
            EngineOptions {
                allow_parallel,
                step_mode: self.step_mode,
                ..Default::default()
            },
        );
        self.outcome_from_report(report)
    }

    /// Play an explicit trace through this scenario's fleet (for
    /// hand-crafted traces — e.g. the bursty dispatch-comparison figure;
    /// `gen` then only documents the intended traffic).
    pub fn simulate_trace(
        &self,
        trace: &[Request],
        allow_parallel: bool,
    ) -> ScenarioOutcome {
        let profile = self.profile();
        let (pool_groups, pool_cfgs) = self.topology.sim_pools_with_model(
            &profile,
            self.groups,
            self.ingest_chunk,
            self.model,
        );
        let router = self.router();
        let mut policy = self.dispatch_policy();
        let report = simulate_topology_opts(
            trace,
            router.as_ref(),
            &pool_groups,
            &pool_cfgs,
            policy.as_mut(),
            EngineOptions {
                allow_parallel,
                step_mode: self.step_mode,
                ..Default::default()
            },
        );
        self.outcome_from_report(report)
    }

    /// Fold an engine report into this spec's [`ScenarioOutcome`] — the
    /// one place the accounted meters become a reportable cell, shared
    /// by the streamed and materialized paths so the two can never
    /// diverge in what they report.
    fn outcome_from_report(&self, report: TopoSimReport) -> ScenarioOutcome {
        let mut m = report.fleet_metrics();
        let p99_ttft_s = m.ttft_s.p99();
        ScenarioOutcome {
            label: self.label(),
            topology: self.topology.label(),
            workload: self.workload_label(),
            gpus: self.gpus_label(),
            model: self.model.label().to_string(),
            router: self.router_label(),
            dispatch: self.dispatch.clone(),
            // The *accounted* figures: groups the router never touched
            // are charged at idle power over the fleet horizon instead
            // of being silently free (identical to the raw meters when
            // every group saw traffic).
            tok_per_watt: report.tok_per_watt_accounted(),
            output_tokens: report.output_tokens,
            joules: report.accounted_joules(),
            idle_joules: report.idle_joules,
            steps: report.steps,
            completed: m.completed,
            rejected: m.rejected,
            p99_ttft_s,
            slo_ok: p99_ttft_s <= self.slo.ttft_p99_s,
            warnings: report.warnings,
        }
    }
}

/// What one simulated scenario cell reports: energy efficiency and the
/// SLO-facing tail latency, comparable across every cell of a sweep.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub label: String,
    pub topology: String,
    /// The workload axis ([`ScenarioSpec::workload_label`]): trace name
    /// for stationary arrivals, trace+process when an archetype
    /// modulates it (`Azure+diurnal(a=0.6)`).
    pub workload: String,
    /// Per-pool GPU assignment label ([`ScenarioSpec::gpus_label`]):
    /// the plain SKU name for homogeneous fleets, `H100|H100|B200`
    /// when generations are mixed.
    pub gpus: String,
    /// The model-architecture axis ([`ModelAxis::label`]): `dense`,
    /// `qwen3-moe`, or `dense+spec`.
    pub model: String,
    pub router: String,
    pub dispatch: String,
    /// Fleet output tokens per joule (== per watt-second), with
    /// never-touched groups charged at idle power
    /// ([`TopoSimReport::tok_per_watt_accounted`](crate::sim::TopoSimReport::tok_per_watt_accounted)).
    pub tok_per_watt: f64,
    pub output_tokens: u64,
    /// Accounted fleet energy (metered + idle draw of untouched groups).
    pub joules: f64,
    /// The idle-draw share of `joules`: every group is billed at idle
    /// watts from its own meter horizon to the fleet's, so a
    /// router-starved pool (or a group idling after one stray request)
    /// is never free capacity. ~Zero when all groups run to the end.
    pub idle_joules: f64,
    /// Engine iterations executed fleet-wide.
    pub steps: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Fleet-wide p99 time-to-first-token, seconds (NaN when nothing
    /// completed).
    pub p99_ttft_s: f64,
    /// `p99_ttft_s` within the spec's SLO (false on NaN).
    pub slo_ok: bool,
    /// Zero-traffic pool warnings from the simulator (router cutoffs
    /// excluding a pool, groups that never saw an arrival).
    pub warnings: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::topology::LONG_CTX;
    use crate::workload::cdf::azure_conversations;

    fn quick_gen(lambda: f64) -> GenConfig {
        GenConfig {
            lambda_rps: lambda,
            duration_s: 1.0,
            max_prompt_tokens: 20_000,
            max_output_tokens: 128,
            seed: 9,
        }
    }

    fn pool_spec() -> ScenarioSpec {
        ScenarioSpec::new(
            Topology::PoolRouting { b_short: 4096, short_ctx: 4096 },
            Gpu::H100,
            azure_conversations(),
            quick_gen(40.0),
        )
        .with_groups(4)
    }

    /// The token-conservation oracle every engine path must satisfy:
    /// drain the spec's own streaming source and sum the output tokens
    /// it promises. One helper instead of three copy-pasted sums — and
    /// because it consumes the *source*, it also pins `trace()` (a
    /// collected source) and the streamed engine to the same ledger.
    fn expected_output_tokens(spec: &ScenarioSpec) -> u64 {
        spec.source()
            .expect("arrival source failed to build")
            .map(|r| r.output_tokens as u64)
            .sum()
    }

    #[test]
    fn one_spec_feeds_both_engines() {
        let spec = pool_spec();
        let analytic = spec.analyze(PowerAccounting::PerGpu);
        assert_eq!(analytic.pools.len(), 2);
        assert!(analytic.tok_per_watt.0 > 0.0);

        let sim = spec.simulate(true);
        assert!(sim.tok_per_watt > 0.0);
        assert!(sim.completed > 0);
        assert!(sim.p99_ttft_s.is_finite());
        // Token conservation against the spec's own arrival source.
        assert_eq!(sim.output_tokens, expected_output_tokens(&spec));
    }

    #[test]
    fn simulate_is_deterministic_in_the_spec() {
        let spec = pool_spec().with_dispatch("jsq");
        let a = spec.simulate(true);
        let b = spec.simulate(true);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules.to_bits(), b.joules.to_bits());
        assert_eq!(a.p99_ttft_s.to_bits(), b.p99_ttft_s.to_bits());
    }

    #[test]
    fn adaptive_router_spec_builds_at_the_split() {
        let spec = pool_spec().with_router(RouterSpec::Adaptive { spill: 3.0 });
        let r = spec.router();
        assert!(r.is_load_aware());
        assert!(r.name().contains("spill=3"));
        let out = spec.simulate(true);
        assert!(out.completed > 0);
    }

    #[test]
    fn adaptive_on_homogeneous_is_a_clear_error_not_a_panic() {
        // The `--router adaptive --topo homo` footgun: the spec layer
        // reports a user-facing error naming the topology and the fix,
        // instead of the old reachable `expect` panic.
        let spec = ScenarioSpec::new(
            Topology::Homogeneous { ctx: LONG_CTX },
            Gpu::H100,
            azure_conversations(),
            quick_gen(10.0),
        )
        .with_router(RouterSpec::Adaptive { spill: 2.0 });
        let err = spec.validate().expect_err("must be rejected");
        assert!(err.contains("adaptive routing"), "{err}");
        assert!(err.contains("Homo 64K"), "names the topology: {err}");
        assert!(err.contains("--router static"), "suggests the fix: {err}");
        // A 3-pool partition has no *single* split boundary either.
        let three = ScenarioSpec::new(
            Topology::partition(&[2048, 8192, LONG_CTX]),
            Gpu::H100,
            azure_conversations(),
            quick_gen(10.0),
        )
        .with_router(RouterSpec::Adaptive { spill: 2.0 });
        assert!(three.validate().is_err());
        // Valid combinations pass.
        assert!(pool_spec()
            .with_router(RouterSpec::Adaptive { spill: 2.0 })
            .validate()
            .is_ok());
        assert!(pool_spec().validate().is_ok());
        // Degenerate MoE dispatch is caught at the same gate.
        let bad_moe = pool_spec()
            .with_model(ModelAxis::MoeStreaming { dispatch_ms: f64::NAN });
        assert!(bad_moe.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "unknown dispatch policy")]
    fn bogus_dispatch_rejected_at_build() {
        pool_spec().with_dispatch("bogus");
    }

    #[test]
    fn analysis_knobs_thread_through() {
        // The more optimistic TrafficMean L̄ must improve the analytical
        // tok/W relative to the conservative full-window default.
        let base = pool_spec().analyze(PowerAccounting::PerGpu);
        let traffic = pool_spec()
            .with_lbar(LBarPolicy::TrafficMean)
            .analyze(PowerAccounting::PerGpu);
        assert!(
            traffic.tok_per_watt.0 > base.tok_per_watt.0,
            "TrafficMean {} vs Window {}",
            traffic.tok_per_watt.0,
            base.tok_per_watt.0
        );
    }

    #[test]
    fn kpool_partition_spec_feeds_both_engines() {
        let spec = ScenarioSpec::new(
            Topology::partition(&[2048, 8192, LONG_CTX]),
            Gpu::H100,
            azure_conversations(),
            quick_gen(40.0),
        )
        .with_groups(4);
        let analytic = spec.analyze(PowerAccounting::PerGpu);
        assert_eq!(analytic.pools.len(), 3);
        assert!(analytic.tok_per_watt.0 > 0.0);
        let sim = spec.simulate(true);
        assert!(sim.completed > 0);
        assert_eq!(
            sim.output_tokens,
            expected_output_tokens(&spec),
            "K-pool token conservation"
        );
    }

    #[test]
    fn excluded_pools_surface_warnings_and_idle_charge() {
        // Every generated prompt fits the first tier, so the 16K and
        // 64K pools never see a request: the outcome must say so and
        // bill their idle draw instead of reporting them as free.
        let spec = ScenarioSpec::new(
            Topology::partition(&[4096, 16384, LONG_CTX]),
            Gpu::H100,
            azure_conversations(),
            GenConfig {
                lambda_rps: 30.0,
                duration_s: 1.0,
                max_prompt_tokens: 2048,
                max_output_tokens: 64,
                seed: 3,
            },
        )
        .with_groups(3);
        let out = spec.simulate(true);
        assert!(out.completed > 0);
        assert!(
            out.warnings.iter().any(|w| w.contains("zero traffic")),
            "{:?}",
            out.warnings
        );
        assert!(out.idle_joules > 0.0);
        assert!(out.joules > out.idle_joules, "metered energy present too");
    }

    #[test]
    fn pool_gpus_flow_through_both_engines_and_the_labels() {
        use crate::power::Gpu;
        let mixed = ScenarioSpec::new(
            Topology::partition(&[4096, LONG_CTX]),
            Gpu::H100,
            azure_conversations(),
            quick_gen(40.0),
        )
        .with_groups(4)
        .with_pool_gpus(&[Gpu::H100, Gpu::B200]);
        assert_eq!(mixed.gpus_label(), "H100|B200");
        assert!(mixed.label().contains("H100|B200"), "{}", mixed.label());

        // Analytical side: the long pool runs the B200 profile.
        let analytic = mixed.analyze(PowerAccounting::PerGpu);
        assert!(analytic.pools[0].profile_label.contains("H100"));
        assert!(analytic.pools[1].profile_label.contains("B200"));

        // Dynamic side: runs end-to-end, conserves tokens, and reports
        // the assignment on the outcome.
        let sim = mixed.simulate(true);
        assert!(sim.completed > 0);
        assert_eq!(sim.gpus, "H100|B200");
        assert_eq!(sim.output_tokens, expected_output_tokens(&mixed));
    }

    #[test]
    fn homogeneous_pool_gpu_overrides_reduce_bit_identically() {
        use crate::power::Gpu;
        // A partition whose pools all override to the fleet default must
        // be indistinguishable from the same partition with no overrides
        // — through BOTH engines, to the bit. This is the oracle the
        // heterogeneity refactor leans on: every optimizer stage-B cell
        // now goes through the override path.
        let plain = ScenarioSpec::new(
            Topology::partition(&[4096, LONG_CTX]),
            Gpu::H100,
            azure_conversations(),
            quick_gen(40.0),
        )
        .with_groups(4)
        .with_dispatch("jsq");
        let overridden = plain.clone().with_pool_gpus(&[Gpu::H100, Gpu::H100]);
        assert_eq!(overridden.gpus_label(), "H100-SXM5", "homogeneous label");

        let a = plain.analyze(PowerAccounting::PerGpu);
        let b = overridden.analyze(PowerAccounting::PerGpu);
        assert_eq!(a.tok_per_watt.0.to_bits(), b.tok_per_watt.0.to_bits());
        assert_eq!(a.total_groups, b.total_groups);
        for (x, y) in a.pools.iter().zip(&b.pools) {
            assert_eq!(x.power.0.to_bits(), y.power.0.to_bits());
            assert_eq!(x.demand_tok_s.to_bits(), y.demand_tok_s.to_bits());
        }

        let s1 = plain.simulate(true);
        let s2 = overridden.simulate(true);
        assert_eq!(s1.tok_per_watt.to_bits(), s2.tok_per_watt.to_bits());
        assert_eq!(s1.joules.to_bits(), s2.joules.to_bits());
        assert_eq!(s1.output_tokens, s2.output_tokens);
        assert_eq!(s1.p99_ttft_s.to_bits(), s2.p99_ttft_s.to_bits());
    }

    #[test]
    fn dense_model_axis_reduces_to_the_pre_axis_engines_bitwise() {
        // The reduction oracle the model-axis refactor rests on: a spec
        // that never mentions the axis (Dense is the default) must
        // reproduce the pre-axis engine constructions — profile built by
        // `ManualProfile::for_gpu`, pools by the dense `pools`/`sim_pools`
        // wrappers — on all four reported meters, to the bit.
        let spec = pool_spec().with_dispatch("jsq");
        assert_eq!(spec.model, ModelAxis::Dense, "Dense is the default");

        // Analytical engine.
        let now = spec.analyze(PowerAccounting::PerGpu);
        let pre: Arc<dyn GpuProfile> =
            Arc::new(ManualProfile::for_gpu(spec.gpu));
        let was = optimize::analyze_cell(
            &spec.topology,
            &spec.workload,
            spec.gen.lambda_rps,
            pre,
            spec.lbar,
            spec.rho,
            spec.slo.ttft_p99_s,
            PowerAccounting::PerGpu,
            ModelAxis::Dense,
        );
        assert_eq!(now.tok_per_watt.0.to_bits(), was.tok_per_watt.0.to_bits());
        assert_eq!(now.total_groups, was.total_groups);
        assert_eq!(now.total_power.0.to_bits(), was.total_power.0.to_bits());

        // Event engine: the spec path vs the engine fed by the pre-axis
        // dense sim_pools construction, four-oracle comparison.
        let p = ManualProfile::for_gpu(spec.gpu);
        let (groups, cfgs) =
            spec.topology.sim_pools(&p, spec.groups, spec.ingest_chunk);
        let router = spec.router();
        let mut policy = spec.dispatch_policy();
        let report = simulate_topology_opts(
            &spec.trace(),
            router.as_ref(),
            &groups,
            &cfgs,
            policy.as_mut(),
            EngineOptions {
                allow_parallel: false,
                step_mode: spec.step_mode,
                ..Default::default()
            },
        );
        let now_sim = spec.simulate(false);
        assert_eq!(
            now_sim.tok_per_watt.to_bits(),
            report.tok_per_watt_accounted().to_bits()
        );
        assert_eq!(
            now_sim.joules.to_bits(),
            report.accounted_joules().to_bits()
        );
        assert_eq!(now_sim.output_tokens, report.output_tokens);
        assert_eq!(
            now_sim.p99_ttft_s.to_bits(),
            report.fleet_metrics().ttft_s.p99().to_bits()
        );
        assert_eq!(now_sim.model, "dense");
    }

    #[test]
    fn moe_scenario_feeds_both_engines_and_beats_dense() {
        // The tentpole end-to-end: the same spec with the MoE axis runs
        // both engines and shows the weight-streaming advantage the
        // paper's Table 2 claims, with the axis on every label surface.
        let dense = pool_spec();
        let moe = pool_spec()
            .with_model(ModelAxis::MoeStreaming { dispatch_ms: 0.0 });
        let a_dense = dense.analyze(PowerAccounting::PerGpu);
        let a_moe = moe.analyze(PowerAccounting::PerGpu);
        assert!(
            a_moe.tok_per_watt.0 > 2.0 * a_dense.tok_per_watt.0,
            "analytical MoE {} vs dense {}",
            a_moe.tok_per_watt.0,
            a_dense.tok_per_watt.0
        );
        let s = moe.simulate(true);
        assert!(s.completed > 0);
        assert_eq!(s.model, "qwen3-moe");
        assert!(moe.label().contains("qwen3-moe"), "{}", moe.label());
        let s_dense = dense.simulate(true);
        assert!(
            s.tok_per_watt > s_dense.tok_per_watt,
            "measured MoE {} vs dense {}",
            s.tok_per_watt,
            s_dense.tok_per_watt
        );
        // Dispatch overhead erodes the measured number too.
        let eroded = pool_spec()
            .with_model(ModelAxis::MoeStreaming { dispatch_ms: 10.0 })
            .simulate(true);
        assert!(eroded.tok_per_watt < s.tok_per_watt);

        // Speculative decode: same capacity (n_max unchanged), faster
        // effective iterations → at least the dense throughput per watt.
        let spec_ax = pool_spec().with_model(ModelAxis::Speculative {
            k: ModelAxis::SPEC_K,
            alpha: ModelAxis::SPEC_ALPHA,
        });
        let s_spec = spec_ax.simulate(true);
        assert!(s_spec.completed > 0);
        assert_eq!(s_spec.model, "dense+spec");
        assert!(
            s_spec.tok_per_watt > s_dense.tok_per_watt,
            "spec-decode {} vs dense {}",
            s_spec.tok_per_watt,
            s_dense.tok_per_watt
        );
    }

    #[test]
    fn mixed_fleet_beats_all_h100_on_both_engines() {
        use crate::power::Gpu;
        use crate::workload::cdf::agent_heavy;
        // Long-prompt-heavy traffic, so the long pool dominates the
        // fleet's energy: upgrading exactly that pool to B200 is where
        // the generation lever pays most (the Table 9 placement story).
        let base = ScenarioSpec::new(
            Topology::partition(&[4096, LONG_CTX]),
            Gpu::H100,
            agent_heavy(),
            GenConfig {
                lambda_rps: 80.0,
                duration_s: 1.5,
                max_prompt_tokens: 60_000,
                max_output_tokens: 128,
                seed: 6,
            },
        )
        .with_groups(4);
        let mixed = base.clone().with_pool_gpus(&[Gpu::H100, Gpu::B200]);
        // Analytically a strict win: same token demand, lower power.
        assert!(
            mixed.analyze(PowerAccounting::PerGpu).tok_per_watt.0
                > base.analyze(PowerAccounting::PerGpu).tok_per_watt.0
        );
        // And a measured win: B200's 2.3× faster weight stream and
        // 2.62× KV budget on the energy-dominant pool outweigh its
        // higher wattage.
        let (m, b) = (mixed.simulate(true), base.simulate(true));
        assert_eq!(m.output_tokens, b.output_tokens, "same served tokens");
        assert!(
            m.tok_per_watt > b.tok_per_watt,
            "mixed {} vs all-H100 {}",
            m.tok_per_watt,
            b.tok_per_watt
        );
    }

    /// A deterministic consolidation-pathology trace: one long-decode
    /// request keeps group 0 hot for the whole run, then a tight burst
    /// of near-window prompts arrives. Pure `power` packs every burst
    /// arrival onto the hot group (it always has queue-empty batch
    /// headroom), so the packed prompts ride an ever-bigger batch's
    /// step time; JSQ splits them across both groups.
    fn consolidation_burst() -> Vec<Request> {
        let mut reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 512,
            output_tokens: 1200,
        }];
        for i in 0..20u64 {
            reqs.push(Request {
                id: 1 + i,
                arrival_s: 0.5 + 0.1 * i as f64,
                prompt_tokens: 61_000,
                output_tokens: 8,
            });
        }
        reqs
    }

    fn burst_spec(dispatch: &str) -> ScenarioSpec {
        ScenarioSpec::new(
            Topology::Homogeneous { ctx: LONG_CTX },
            Gpu::H100,
            azure_conversations(),
            GenConfig {
                lambda_rps: 4.0,
                duration_s: 3.0,
                max_prompt_tokens: 61_000,
                max_output_tokens: 1200,
                seed: 1,
            },
        )
        .with_groups(2)
        .with_dispatch(dispatch)
        .with_slo(SloTargets { ttft_p99_s: 0.5 })
    }

    #[test]
    fn power_slo_guard_removes_the_consolidation_ttft_regression() {
        let trace = consolidation_burst();
        let run = |d: &str| burst_spec(d).simulate_trace(&trace, false);
        let pure = run("power");
        let jsq = run("jsq");
        let guarded = run("power-slo");

        // Pure consolidation piles the burst onto the hot group — the
        // p99-TTFT regression the ROADMAP flagged.
        assert!(
            pure.p99_ttft_s > jsq.p99_ttft_s,
            "no regression to remove: power p99 {} vs jsq {}",
            pure.p99_ttft_s,
            jsq.p99_ttft_s
        );
        // The guard projects ≥ 0.5 s of packed-ingest delay per burst
        // prompt against its 0.25 s bound (0.5 × the 0.5 s SLO), so it
        // refuses every pack: on this trace the guarded policy IS
        // join-shortest-queue, to the bit.
        assert_eq!(guarded.joules.to_bits(), jsq.joules.to_bits());
        assert_eq!(guarded.p99_ttft_s.to_bits(), jsq.p99_ttft_s.to_bits());
        assert_eq!(guarded.output_tokens, jsq.output_tokens);
        // And therefore the regression is gone.
        assert!(
            guarded.p99_ttft_s < pure.p99_ttft_s,
            "guard failed to remove the regression: guarded {} vs pure {}",
            guarded.p99_ttft_s,
            pure.p99_ttft_s
        );
        // Token conservation across all three policies.
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        for o in [&pure, &jsq, &guarded] {
            assert_eq!(o.output_tokens, want, "{}", o.dispatch);
        }
    }

    #[test]
    fn streamed_simulate_replays_the_materialized_trace_bitwise() {
        // `simulate(false)` streams arrivals through the engine;
        // `simulate_trace(&trace(), false)` materializes the identical
        // trace first. The seq-offset argument in `sim::events` says the
        // meters must agree to the bit — across a load-aware dispatch
        // (jsq streams even under `allow_parallel`).
        let spec = pool_spec().with_dispatch("jsq");
        let streamed = spec.simulate(false);
        let materialized = spec.simulate_trace(&spec.trace(), false);
        assert_eq!(streamed.output_tokens, materialized.output_tokens);
        assert_eq!(streamed.joules.to_bits(), materialized.joules.to_bits());
        assert_eq!(
            streamed.idle_joules.to_bits(),
            materialized.idle_joules.to_bits()
        );
        assert_eq!(streamed.steps, materialized.steps);
        assert_eq!(streamed.completed, materialized.completed);
        assert_eq!(streamed.rejected, materialized.rejected);
        assert_eq!(
            streamed.p99_ttft_s.to_bits(),
            materialized.p99_ttft_s.to_bits()
        );
    }

    #[test]
    fn generated_archetypes_run_end_to_end_through_simulate() {
        for name in ["diurnal", "flash-crowd", "multi-tenant", "heavy-tail"] {
            let arrivals = ArrivalSpec::parse(name)
                .unwrap_or_else(|| panic!("unknown archetype '{name}'"));
            let spec = pool_spec().with_arrivals(arrivals);
            let out = spec.simulate(true);
            assert!(out.completed > 0, "{name}: nothing completed");
            assert_eq!(
                out.output_tokens,
                expected_output_tokens(&spec),
                "{name}: token conservation"
            );
            // The workload axis surfaces the process on the outcome and
            // in the cell label.
            assert!(
                out.workload.contains(name.split('(').next().unwrap()),
                "{name}: workload label was '{}'",
                out.workload
            );
            assert!(
                out.label.contains(&out.workload),
                "{name}: label '{}' missing workload '{}'",
                out.label,
                out.workload
            );
        }
    }

    #[test]
    fn archetype_simulate_is_deterministic_in_the_spec() {
        let spec = pool_spec()
            .with_dispatch("jsq")
            .with_arrivals(ArrivalSpec::parse("flash-crowd").unwrap());
        let a = spec.simulate(true);
        let b = spec.simulate(true);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules.to_bits(), b.joules.to_bits());
        assert_eq!(a.p99_ttft_s.to_bits(), b.p99_ttft_s.to_bits());
    }

    #[test]
    fn workload_label_shows_the_arrival_process() {
        assert_eq!(pool_spec().workload_label(), "Azure");
        let diurnal = pool_spec()
            .with_arrivals(ArrivalSpec::parse("diurnal").unwrap());
        assert_eq!(diurnal.workload_label(), "Azure+diurnal(a=0.6)");
        // Multi-tenant replaces the base trace outright, so the label
        // drops it rather than claiming traffic it doesn't carry.
        let mt = pool_spec().with_arrivals(ArrivalSpec::MultiTenant);
        assert_eq!(mt.workload_label(), "multi-tenant");
        assert!(!mt.label().contains("Azure"), "{}", mt.label());
    }

    #[test]
    fn slo_flag_follows_p99() {
        // An absurdly tight SLO must be violated, a loose one met.
        let tight = pool_spec()
            .with_slo(SloTargets { ttft_p99_s: 1e-9 })
            .simulate(true);
        assert!(!tight.slo_ok);
        let loose = pool_spec()
            .with_slo(SloTargets { ttft_p99_s: 1e9 })
            .simulate(true);
        assert!(loose.slo_ok);
    }
}
