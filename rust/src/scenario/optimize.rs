//! The scenario-native FleetOpt optimizer: a two-stage search over
//! [`ScenarioSpec`] space — `wattlaw optimize`.
//!
//! FleetOpt (Chen et al. 2026) frames provisioning as an
//! analytical-search-then-validate loop, and SweetSpot (Pizzini Cavagna
//! et al. 2026) shows why the analytical screen and the measured check
//! must be cross-tabulated per operating point. This module is that
//! loop over the crate's own two engines:
//!
//! * **Stage A — analytical screen.** The full
//!   partition × γ × GPU-generation grid is evaluated with the
//!   closed-form Eq. (4) planner ([`ScenarioSpec::analyze`]; dispatch
//!   does not enter the closed form, so each analytical cell is
//!   screened once). The partition axis is a vector of K-pool context
//!   cutoffs ([`kpool_partitions`] generates the K ∈ {2, …, 7} grids;
//!   the default is the legacy `[B_short, LONG_CTX]` two-pool axis).
//!   The heterogeneous assignment axis ([`GpuAxis::Mixed`]) is searched
//!   by branch-and-bound over partial per-pool GPU vectors
//!   ([`screen_mixed`]): Eq. 4 separates into a per-(pool, generation)
//!   power table plus a GPU-independent demand, so an admissible
//!   optimistic bound ([`Eq4PowerTable::bound`]) prunes whole assignment
//!   subtrees while staying bit-identical to the brute-force
//!   cross-product (retained behind [`MixedScreen::BruteForce`] as the
//!   oracle). Cheap: hundreds of cells per millisecond, so the grid can
//!   be wide.
//! * **Stage B — simulated refine.** The top-k surviving cells are
//!   expanded across the dispatch axis and replayed through
//!   [`ScenarioSpec::simulate`] on scoped worker threads
//!   ([`sweep::run`]), then re-ranked by *measured* tok/W with the
//!   p99-TTFT SLO verdict as a hard filter: an SLO-violating cell can
//!   appear in the report but can never be the winner.
//!
//! The legacy closed-form sweep (`fleet::optimizer::sweep_fleetopt`)
//! is now a thin wrapper over this module's [`screen_closed_form`], so
//! both paths rank by the same arithmetic — the regression oracle in
//! `tests/optimize_oracle.rs` holds them together.

use std::sync::Arc;

use super::{sweep, ScenarioOutcome, ScenarioSpec, SloTargets};
use crate::fleet::analysis::{fleet_tpw_analysis, FleetReport};
use crate::fleet::optimizer::{OptResult, B_SHORT_GRID, GAMMA_GRID};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::sim::{dispatch, StepMode};
use crate::workload::arrival::ArrivalSpec;
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;

/// Interior-cutoff choices for the generated K-pool grids
/// ([`kpool_partitions`]); the final pool always serves the full
/// [`LONG_CTX`] window.
pub const CUTOFF_LADDER: [u32; 6] = [1024, 2048, 4096, 8192, 16384, 32768];

/// Every K-pool partition vector on the cutoff ladder: all strictly
/// increasing (K−1)-combinations of [`CUTOFF_LADDER`], each closed with
/// the `LONG_CTX` long pool. Deterministic lexicographic order (so the
/// stage-A stable sort is reproducible). K=2 yields one `[b, 64K]`
/// vector per ladder entry — the classic two-pool split axis.
pub fn kpool_partitions(k: u32) -> Vec<Vec<u32>> {
    assert!(
        (2..=CUTOFF_LADDER.len() as u32 + 1).contains(&k),
        "K must be in 2..={} (got {k})",
        CUTOFF_LADDER.len() + 1
    );
    let interior = (k - 1) as usize;
    let mut out = Vec::new();
    let mut combo: Vec<usize> = (0..interior).collect();
    loop {
        let mut cuts: Vec<u32> =
            combo.iter().map(|&i| CUTOFF_LADDER[i]).collect();
        cuts.push(LONG_CTX);
        out.push(cuts);
        // Advance the combination (lexicographic).
        let mut pos = interior;
        while pos > 0 {
            pos -= 1;
            if combo[pos] + 1 <= CUTOFF_LADDER.len() - (interior - pos) {
                combo[pos] += 1;
                for j in pos + 1..interior {
                    combo[j] = combo[j - 1] + 1;
                }
                break;
            }
            if pos == 0 {
                return out;
            }
        }
        if interior == 0 {
            return out;
        }
    }
}

/// Closed-form evaluation of one (topology, profile) cell — the single
/// Eq. (4) path behind [`ScenarioSpec::analyze`], the stage-A screen,
/// and the legacy `fleet::optimizer` wrapper.
#[allow(clippy::too_many_arguments)]
pub fn analyze_cell(
    topology: &Topology,
    workload: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    model: ModelAxis,
) -> FleetReport {
    let pools = topology.pools_with_model(
        workload, lambda_rps, profile, None, lbar, rho, ttft_slo_s, model,
    );
    fleet_tpw_analysis(&pools, acct)
}

/// Counters for one [`ScreenMemo`]: how many Eq. 4 cell evaluations the
/// screen requested and how many were served from cache. Follows the
/// [`MixedScreenStats`] convention — plain counters the report and bench
/// layers surface verbatim.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScreenMemoStats {
    /// Cell evaluations requested through the memo (hits + misses).
    pub evals: u64,
    /// Evaluations answered from the cache instead of re-running the
    /// Eq. 4 closed form.
    pub hits: u64,
}

impl ScreenMemoStats {
    /// Fraction of requested evaluations served from cache, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.evals == 0 {
            0.0
        } else {
            self.hits as f64 / self.evals as f64
        }
    }
}

/// Cache key for one stage-A cell. The workload, traffic, and policy
/// context (trace, λ, L̄ policy, ρ, SLO, accounting) are deliberately
/// absent: a [`ScreenMemo`] is scoped to a single screen invocation
/// where those are invariant, so the key only needs the axes that vary
/// within one grid. `f64` axes key on their bit patterns — two cells
/// collide only when every input is bitwise identical, which is exactly
/// when [`analyze_cell`] is a pure replay.
#[derive(PartialEq, Eq, Hash)]
struct MemoKey {
    /// [`ModelAxis`] encoded as (discriminant, payload bits).
    model: (u8, u64, u64),
    cutoffs: Vec<u32>,
    gpus: Vec<Gpu>,
    gamma_bits: u64,
}

impl MemoKey {
    fn new(model: ModelAxis, cutoffs: &[u32], gpus: &[Gpu], gamma: f64) -> Self {
        let model = match model {
            ModelAxis::Dense => (0, 0, 0),
            ModelAxis::MoeStreaming { dispatch_ms } => {
                (1, dispatch_ms.to_bits(), 0)
            }
            ModelAxis::Speculative { k, alpha } => {
                (2, k as u64, alpha.to_bits())
            }
        };
        MemoKey {
            model,
            cutoffs: cutoffs.to_vec(),
            gpus: gpus.to_vec(),
            gamma_bits: gamma.to_bits(),
        }
    }
}

/// Memo for stage-A Eq. 4 cell evaluations, keyed on
/// (model, cutoffs, per-pool GPUs, γ) — every axis that varies inside
/// one [`screen`] call. The stage-A grid evaluates the same homogeneous
/// cells repeatedly: the per-fleet axis and [`Eq4PowerTable::new`]'s
/// table builds request identical (gpu, partition, γ) tuples, and the
/// budgeted-upgrade greedy re-evaluates candidate assignments across
/// rounds. Because [`analyze_cell`] is a pure function of the key (for
/// a fixed workload/traffic/policy context — see [`MemoKey`]), replaying
/// a cached [`FleetReport`] is *bitwise* the same as re-running the
/// closed form, so the memoized screen ranks identically to the
/// uncached one (`memoized_screen_ranks_identical_to_uncached` pins
/// this against [`screen_uncached`]).
///
/// [`ScreenMemo::disabled`] is the same object with no cache — every
/// call misses — so the cached and uncached paths share one code path
/// and cannot drift.
pub struct ScreenMemo {
    /// `None` = disabled: evaluate every cell (the uncached oracle).
    cache: Option<std::collections::HashMap<MemoKey, FleetReport>>,
    stats: ScreenMemoStats,
}

impl ScreenMemo {
    /// A caching memo — the default for [`screen`].
    pub fn new() -> Self {
        ScreenMemo {
            cache: Some(std::collections::HashMap::new()),
            stats: ScreenMemoStats::default(),
        }
    }

    /// A pass-through memo that never caches: every evaluation runs the
    /// Eq. 4 closed form. This is the bitwise oracle the cached screen
    /// is held identical to.
    pub fn disabled() -> Self {
        ScreenMemo { cache: None, stats: ScreenMemoStats::default() }
    }

    /// Work counters so far.
    pub fn stats(&self) -> ScreenMemoStats {
        self.stats
    }

    /// Evaluate one fully-assigned stage-A cell, from cache when
    /// possible. Every pool carries a GPU override, so the default
    /// profile passed to [`analyze_cell`] is never consulted for a pool
    /// plan — which is why the memo key can ignore it and why the
    /// homogeneous axis can route through here bit-identically (the
    /// homogeneous-reduction oracle in `tests/optimize_oracle.rs` pins
    /// the equivalence).
    #[allow(clippy::too_many_arguments)]
    fn eval(
        &mut self,
        trace: &WorkloadTrace,
        lambda_rps: f64,
        cutoffs: &[u32],
        gpus: &[Gpu],
        gamma: f64,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
        acct: PowerAccounting,
        model: ModelAxis,
    ) -> FleetReport {
        self.stats.evals += 1;
        let key = MemoKey::new(model, cutoffs, gpus, gamma);
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.get(&key) {
                self.stats.hits += 1;
                return hit.clone();
            }
        }
        let report = analyze_cell(
            &Topology::partition_with_gpus(cutoffs, gpus, gamma),
            trace,
            lambda_rps,
            Arc::new(model.profile_for(gpus[0])),
            lbar,
            rho,
            ttft_slo_s,
            acct,
            model,
        );
        if let Some(cache) = &mut self.cache {
            cache.insert(key, report.clone());
        }
        report
    }
}

impl Default for ScreenMemo {
    fn default() -> Self {
        ScreenMemo::new()
    }
}

/// One screened K-pool cell: the partition vector, its long-pool γ, and
/// the closed-form Eq. 4 report.
#[derive(Debug, Clone)]
pub struct PartitionOptResult {
    /// Sorted cutoff vector; the last entry is the long pool's window.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment; empty = every pool on the caller's
    /// fleet-default profile (the homogeneous legacy axis).
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub report: FleetReport,
}

/// Render a per-pool GPU assignment: the plain SKU name when the fleet
/// is homogeneous (matching every legacy single-GPU surface), the
/// compact `H100|H100|B200` vector when generations are mixed.
pub fn assignment_label(gpus: &[Gpu]) -> String {
    match gpus {
        [] => String::new(),
        [first, rest @ ..] if rest.iter().all(|g| g == first) => {
            first.spec().name.to_string()
        }
        _ => gpus
            .iter()
            .map(|g| g.short_name())
            .collect::<Vec<_>>()
            .join("|"),
    }
}

/// Stage A over an explicit (partition vector × γ) grid with an
/// arbitrary profile, best-first (the stable sort keeps grid order on
/// ties). Profile-generic (not `Gpu`-keyed) so the legacy
/// `sweep_fleetopt` API — which accepts any [`GpuProfile`] — can
/// delegate here without loss of generality. A `[b, LONG_CTX]` vector
/// with γ evaluates bit-identically to the two-pool
/// `Topology::FleetOpt { b_short: b, .. }` cell, which is what makes
/// the K=2 reduction oracle exact.
#[allow(clippy::too_many_arguments)]
pub fn screen_partitions(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    partitions: &[Vec<u32>],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    model: ModelAxis,
) -> Vec<PartitionOptResult> {
    let mut out = Vec::with_capacity(partitions.len() * gammas.len());
    for cutoffs in partitions {
        for &gamma in gammas {
            let topo = Topology::partition_with_gamma(cutoffs, gamma);
            let report = analyze_cell(
                &topo,
                trace,
                lambda_rps,
                profile.clone(),
                lbar,
                rho,
                ttft_slo_s,
                acct,
                model,
            );
            out.push(PartitionOptResult {
                cutoffs: cutoffs.clone(),
                gpus: Vec::new(),
                gamma,
                report,
            });
        }
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    out
}

/// Stage A over explicit (partition, per-pool GPU assignment) pairs —
/// the heterogeneous counterpart of [`screen_partitions`]: each cell's
/// pools carry their own generation's profile through the *same*
/// [`analyze_cell`] Eq. 4 path (an all-same assignment evaluates
/// bit-identically to the homogeneous cell, which is what makes the
/// homogeneous-reduction oracle exact). Best-first; the stable sort
/// keeps grid order on ties.
#[allow(clippy::too_many_arguments)]
pub fn screen_assignments(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    cells: &[(Vec<u32>, Vec<Gpu>)],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    model: ModelAxis,
) -> Vec<PartitionOptResult> {
    screen_assignments_with(
        trace,
        lambda_rps,
        cells,
        gammas,
        lbar,
        rho,
        ttft_slo_s,
        acct,
        model,
        &mut ScreenMemo::disabled(),
    )
}

/// [`screen_assignments`] with an explicit [`ScreenMemo`] — the shared
/// evaluation core. The public wrapper passes a disabled memo, so the
/// cached and uncached screens are the same code path.
#[allow(clippy::too_many_arguments)]
fn screen_assignments_with(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    cells: &[(Vec<u32>, Vec<Gpu>)],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    model: ModelAxis,
    memo: &mut ScreenMemo,
) -> Vec<PartitionOptResult> {
    let mut out = Vec::with_capacity(cells.len() * gammas.len());
    for (cutoffs, gpus) in cells {
        for &gamma in gammas {
            let report = memo.eval(
                trace, lambda_rps, cutoffs, gpus, gamma, lbar, rho,
                ttft_slo_s, acct, model,
            );
            out.push(PartitionOptResult {
                cutoffs: cutoffs.clone(),
                gpus: gpus.clone(),
                gamma,
                report,
            });
        }
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    out
}

/// Stage A over the legacy (B_short × γ) two-pool grid — a wrapper that
/// lifts each boundary into the `[b, LONG_CTX]` partition vector and
/// delegates to [`screen_partitions`], so the legacy ranking and the
/// K-pool ranking are the same arithmetic by construction.
#[allow(clippy::too_many_arguments)]
pub fn screen_closed_form(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    b_shorts: &[u32],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<OptResult> {
    let partitions: Vec<Vec<u32>> = b_shorts
        .iter()
        .map(|&b| {
            // The boundary becomes the [b, LONG_CTX] partition vector;
            // reject a degenerate b up front with the legacy axis's own
            // vocabulary instead of a partition-invariant panic deep in
            // the screen.
            assert!(
                (1..LONG_CTX).contains(&b),
                "B_short {b} must be in 1..{LONG_CTX} (the two-pool split \
                 needs a boundary below the long window)"
            );
            vec![b, LONG_CTX]
        })
        .collect();
    screen_partitions(
        trace, lambda_rps, profile, &partitions, gammas, lbar, rho,
        ttft_slo_s, acct, ModelAxis::Dense,
    )
    .into_iter()
    .map(|r| OptResult { b_short: r.cutoffs[0], gamma: r.gamma, report: r.report })
    .collect()
}

/// Constraint for the budgeted-upgrade search ([`GpuAxis::Budget`]):
/// "I can afford `max_groups` groups of `to` — which pools should get
/// them?"
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UpgradeBudget {
    /// Generation the upgraded pools move to (`--upgrade-to`).
    pub to: Gpu,
    /// Ceiling on total upgraded groups, counted by the analytical
    /// plan's per-pool sizing (`--upgrade-budget`).
    pub max_groups: u32,
}

/// How stage A explores the GPU-generation axis.
#[derive(Debug, Clone, Default)]
pub enum GpuAxis {
    /// One fleet-wide GPU per cell, swept over `gpus` — the legacy
    /// axis, and the only one before heterogeneous fleets landed.
    #[default]
    Homogeneous,
    /// The homogeneous cells **plus** the mixed per-pool assignments
    /// over `gpus`, searched by Eq. 4 branch-and-bound
    /// ([`screen_mixed`]) so K = 4–6 partitions and 3+ generation sets
    /// stay tractable: the |gpus|^K cross-product is pruned by an
    /// admissible closed-form bound, keeping the best
    /// [`OptimizeConfig::mixed_keep`] assignments with rankings
    /// bit-identical to the brute-force enumeration
    /// ([`MixedScreen::BruteForce`], the replay oracle).
    Mixed,
    /// The homogeneous cells plus these explicit per-pool vectors, each
    /// applied to every screened partition with a matching pool count
    /// (`--gpu h100,h100,b200` on the CLI).
    Explicit(Vec<Vec<Gpu>>),
    /// The homogeneous cells plus a greedily grown budgeted-upgrade
    /// path per (partition, γ): starting from an all-`gpus[0]` fleet,
    /// repeatedly upgrade the pool with the best marginal Eq. 4 tok/W
    /// per upgraded group while the budget holds, screening every step
    /// of the path (`--upgrade-budget N --upgrade-to b200`).
    Budget(UpgradeBudget),
}

/// Grid axes and per-cell settings for the two-stage search.
#[derive(Debug, Clone)]
pub struct OptimizeConfig {
    /// GPU-generation axis (each served by its calibrated/projected 70B
    /// fleet profile, [`ManualProfile::for_gpu`]).
    pub gpus: Vec<Gpu>,
    /// Model-architecture axis ([`ModelAxis`]): every screened
    /// (topology × GPU × partition) cell is evaluated once per model —
    /// the 4-axis stage-A screen. Default: dense only (the pre-axis
    /// grid, bit-for-bit).
    pub models: Vec<ModelAxis>,
    /// Split-boundary axis (legacy two-pool grid). Ignored when
    /// `partitions` is non-empty.
    pub b_shorts: Vec<u32>,
    /// K-pool partition-vector axis: each entry is a sorted cutoff
    /// vector whose last element is the long pool's window (e.g.
    /// `[4096, 16384, 65536]` for K=3). Empty = derive the classic
    /// `[b, LONG_CTX]` two-pool vectors from `b_shorts`
    /// ([`Self::effective_partitions`]); [`kpool_partitions`] generates
    /// full grids for K up to the ladder width, `--pools K` (2..=6) on
    /// the CLI.
    pub partitions: Vec<Vec<u32>>,
    /// How the GPU-generation axis is explored: homogeneous fleets
    /// only (legacy), the mixed per-pool assignment space, explicit
    /// per-pool assignment vectors, or the greedy budgeted-upgrade
    /// search.
    pub gpu_axis: GpuAxis,
    /// How [`GpuAxis::Mixed`] enumerates assignments: branch-and-bound
    /// (default) or the brute-force cross-product oracle.
    pub mixed_screen: MixedScreen,
    /// Mixed cells the branch-and-bound screen keeps (its beam of exact
    /// survivors). The default 64 covers every K ≤ 3 grid per
    /// (partition, γ) — and far more than stage B's `top_k` ever reads —
    /// so truncation never touches the winner.
    pub mixed_keep: usize,
    /// FleetOpt compression-factor axis (applies to the last pool).
    pub gammas: Vec<f64>,
    /// Dispatch axis — resolved by measurement in stage B only (the
    /// closed form is dispatch-blind).
    pub dispatches: Vec<String>,
    /// Traffic for stage B (`lambda_rps` also feeds stage A's sizing).
    pub gen: GenConfig,
    /// Arrival process for stage B's simulated cells, streamed lazily
    /// per cell. Stage A stays arrival-process-blind: the closed form
    /// sizes to the *mean* rate `gen.lambda_rps`, so a bursty archetype
    /// widens the analyze-vs-simulate delta rather than moving the
    /// screen — exactly the fidelity question stage B exists to answer.
    pub arrivals: ArrivalSpec,
    /// Simulated TP groups per stage-B cell.
    pub groups: u32,
    pub slo: SloTargets,
    pub lbar: LBarPolicy,
    pub rho: f64,
    pub acct: PowerAccounting,
    /// Analytical cells surviving into stage B.
    pub top_k: usize,
    /// Engine step scheduling for stage B's simulated cells (fused
    /// default; per-step is the replay oracle).
    pub step_mode: StepMode,
}

impl Default for OptimizeConfig {
    fn default() -> Self {
        OptimizeConfig {
            gpus: Gpu::ALL.to_vec(),
            models: vec![ModelAxis::Dense],
            b_shorts: B_SHORT_GRID.to_vec(),
            partitions: Vec::new(),
            gpu_axis: GpuAxis::Homogeneous,
            mixed_screen: MixedScreen::BranchAndBound,
            mixed_keep: 64,
            gammas: GAMMA_GRID.to_vec(),
            dispatches: dispatch::ALL.iter().map(|s| s.to_string()).collect(),
            gen: GenConfig {
                lambda_rps: 1000.0,
                duration_s: 1.0,
                max_prompt_tokens: 60_000,
                max_output_tokens: 512,
                seed: 42,
            },
            arrivals: ArrivalSpec::Stationary,
            groups: 8,
            slo: SloTargets::default(),
            lbar: LBarPolicy::Window,
            rho: 0.85,
            acct: PowerAccounting::PerGpu,
            top_k: 4,
            step_mode: StepMode::default(),
        }
    }
}

impl OptimizeConfig {
    /// The partition-vector axis actually screened: the explicit
    /// `partitions` when set, otherwise the legacy `[b, LONG_CTX]`
    /// two-pool vector per `b_shorts` entry.
    pub fn effective_partitions(&self) -> Vec<Vec<u32>> {
        if self.partitions.is_empty() {
            self.b_shorts
                .iter()
                .map(|&b| {
                    assert!(
                        (1..LONG_CTX).contains(&b),
                        "B_short {b} must be in 1..{LONG_CTX} (the two-pool \
                         split needs a boundary below the long window)"
                    );
                    vec![b, LONG_CTX]
                })
                .collect()
        } else {
            self.partitions.clone()
        }
    }
}

/// One stage-A cell: analytical Eq. (4) report at
/// (GPU assignment, partition vector, γ).
#[derive(Debug, Clone)]
pub struct ScreenedCell {
    /// The fleet-default generation (the scenario's `gpu`; for a mixed
    /// cell, the base the assignment was grown from).
    pub gpu: Gpu,
    /// Model architecture the cell serves ([`OptimizeConfig::models`]).
    pub model: ModelAxis,
    /// Sorted cutoff vector of the cell's K-pool partition; for the
    /// legacy two-pool grid this is `[B_short, LONG_CTX]`.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment, one generation per cutoff (all equal to
    /// `gpu` for homogeneous cells).
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub analytic: FleetReport,
}

impl ScreenedCell {
    /// The first cutoff — the legacy B_short boundary at K=2.
    pub fn b_short(&self) -> u32 {
        self.cutoffs[0]
    }

    /// True when the cell serves more than one GPU generation.
    pub fn is_mixed(&self) -> bool {
        self.gpus.windows(2).any(|w| w[0] != w[1])
    }
}

/// One stage-B cell: the screened point expanded with a dispatch policy
/// and replayed through the event-driven simulator.
#[derive(Debug, Clone)]
pub struct RefinedCell {
    /// The fleet-default generation (see [`ScreenedCell::gpu`]).
    pub gpu: Gpu,
    /// Model architecture the cell serves ([`ScreenedCell::model`]).
    pub model: ModelAxis,
    /// Sorted cutoff vector of the cell's K-pool partition.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment, one generation per cutoff.
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub dispatch: String,
    /// Stage-A analytical tok/W (Eq. 4).
    pub analytic_tok_w: f64,
    /// Stage-A analytical group count.
    pub analytic_groups: u64,
    /// Stage-B measured outcome.
    pub outcome: ScenarioOutcome,
}

impl RefinedCell {
    /// Measured-vs-analytical relative delta, percent
    /// ([`super::rel_delta_pct`], shared with the sweep records).
    pub fn rel_delta_pct(&self) -> f64 {
        super::rel_delta_pct(self.outcome.tok_per_watt, self.analytic_tok_w)
    }

    /// The first cutoff — the legacy B_short boundary at K=2.
    pub fn b_short(&self) -> u32 {
        self.cutoffs[0]
    }
}

/// `"4096|65536"`-style display of a cutoff vector — the one rendering
/// every CLI surface (optimize rowset, K-pool sweep) uses.
pub fn cutoffs_label(cutoffs: &[u32]) -> String {
    cutoffs
        .iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join("|")
}

/// Every mixed per-pool assignment over `gpus`, in deterministic
/// lexicographic order: per partition, assignment codes count up in base
/// |gpus| with pool 0 the most-significant digit (homogeneous vectors
/// are skipped — the legacy per-fleet axis already screens them). This
/// is the brute-force enumeration the branch-and-bound screen
/// ([`screen_mixed`]) must reproduce cell-for-cell; |gpus|^K growth is
/// why B&B is the default beyond toy grids.
pub fn mixed_assignments(
    partitions: &[Vec<u32>],
    gpus: &[Gpu],
) -> Vec<(Vec<u32>, Vec<Gpu>)> {
    let n = gpus.len();
    let mut out = Vec::new();
    if n < 2 {
        return out;
    }
    for cuts in partitions {
        let k = cuts.len() as u32;
        for code in 0..n.pow(k) {
            let mut v = Vec::with_capacity(k as usize);
            let mut c = code;
            for _ in 0..k {
                v.push(gpus[c % n]);
                c /= n;
            }
            v.reverse();
            if v.windows(2).all(|w| w[0] == w[1]) {
                continue;
            }
            out.push((cuts.clone(), v));
        }
    }
    out
}

/// How [`GpuAxis::Mixed`] enumerates the per-pool assignment space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MixedScreen {
    /// Branch-and-bound over partial assignment vectors with the
    /// admissible Eq. 4 bound ([`Eq4PowerTable::bound`]) — the default;
    /// opens K = 4–6 partitions and 3+ generation sets.
    #[default]
    BranchAndBound,
    /// The full |gpus|^K cross-product through [`screen_assignments`] —
    /// the replay oracle the B&B rankings are held bit-identical to.
    BruteForce,
}

/// Work counters for one [`screen_mixed`] call — what the bench layer
/// records to show the pruning win (`bnb_screen` in
/// `BENCH_sim_engine.json`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MixedScreenStats {
    /// Mixed cells the full cross-product enumerates
    /// (Σ over partitions of (|gpus|^K − |gpus|) × |γ grid|).
    pub brute_cells: u64,
    /// Partial/full assignment vectors the B&B tree expanded.
    pub nodes_visited: u64,
    /// Subtrees cut by the Eq. 4 bound.
    pub pruned: u64,
    /// Non-homogeneous leaves scored against the kept set.
    pub leaves_scored: u64,
    /// Homogeneous table-building Eq. 4 evaluations
    /// (|gpus| per (partition, γ) point).
    pub table_evals: u64,
    /// Surviving cells re-evaluated through the exact Eq. 4 path.
    pub full_evals: u64,
}

/// The Eq. 4 decomposition for one (partition, γ) point, the engine of
/// the branch-and-bound screen. Pool `i`'s closed-form power depends
/// only on its own (cutoff, γ, generation) — not on the other pools'
/// assignments — and total demand is GPU-independent, so any assignment
/// vector `v` scores `demand / Σ_i power[i][v_i]`, **bit-identical** to
/// [`analyze_cell`] when the sum runs left-to-right in pool order
/// (`fleet_tpw_analysis` accumulates exactly that way; pinned by
/// `prop_mixed_fleet_analyze_is_the_poolwise_eq4_sum`).
pub struct Eq4PowerTable {
    /// `power[i][j]`: pool `i`'s Eq. 4 power (W) under generation
    /// `gpus[j]`, read off the homogeneous-`gpus[j]` fleet report.
    power: Vec<Vec<f64>>,
    /// Per-pool minimum over generations — the bound's optimistic tail.
    min_power: Vec<f64>,
    /// Fleet demand (tok/s); identical across assignments.
    demand: f64,
}

impl Eq4PowerTable {
    /// Build the table from |gpus| homogeneous [`analyze_cell`] runs —
    /// one per generation, each yielding every pool's power at once.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        trace: &WorkloadTrace,
        lambda_rps: f64,
        cutoffs: &[u32],
        gpus: &[Gpu],
        gamma: f64,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
        acct: PowerAccounting,
        model: ModelAxis,
    ) -> Self {
        Self::new_with(
            trace,
            lambda_rps,
            cutoffs,
            gpus,
            gamma,
            lbar,
            rho,
            ttft_slo_s,
            acct,
            model,
            &mut ScreenMemo::disabled(),
        )
    }

    /// [`Eq4PowerTable::new`] with an explicit [`ScreenMemo`]: the
    /// table's homogeneous runs are exactly the cells the per-fleet
    /// axis already screened (same (gpu, partition, γ) tuples through
    /// the same evaluator), so under a shared memo the table build is
    /// pure cache replay.
    #[allow(clippy::too_many_arguments)]
    fn new_with(
        trace: &WorkloadTrace,
        lambda_rps: f64,
        cutoffs: &[u32],
        gpus: &[Gpu],
        gamma: f64,
        lbar: LBarPolicy,
        rho: f64,
        ttft_slo_s: f64,
        acct: PowerAccounting,
        model: ModelAxis,
        memo: &mut ScreenMemo,
    ) -> Self {
        let k = cutoffs.len();
        let mut power = vec![vec![0.0; gpus.len()]; k];
        let mut demand = 0.0;
        for (j, &g) in gpus.iter().enumerate() {
            // Every pool overrides to `g`, so the default profile is
            // never consulted for a pool plan (same as the brute path).
            let report = memo.eval(
                trace,
                lambda_rps,
                cutoffs,
                &vec![g; k],
                gamma,
                lbar,
                rho,
                ttft_slo_s,
                acct,
                model,
            );
            demand = report.total_demand_tok_s;
            for (i, pool) in report.pools.iter().enumerate() {
                power[i][j] = pool.power.0;
            }
        }
        let min_power = power
            .iter()
            .map(|row| row.iter().copied().fold(f64::INFINITY, f64::min))
            .collect();
        Eq4PowerTable { power, min_power, demand }
    }

    /// Number of pools (assignment-vector length).
    pub fn num_pools(&self) -> usize {
        self.min_power.len()
    }

    /// Upper bound on Eq. 4 tok/W over **every** completion of the
    /// partial assignment `digits` (generation indices for pools
    /// `0..digits.len()`). The bound denominator is the left-to-right
    /// sum of the chosen powers followed by the per-pool minima — term
    /// by term, in pool order, exactly like the real evaluation. That
    /// ordering is what makes the bound admissible *in floating point*:
    /// each tail term is ≤ the completion's term and `fl(x + y)` is
    /// monotone in both arguments, so by induction the bound denominator
    /// is ≤ every completion's denominator bitwise (a precomputed suffix
    /// sum would not be — re-associating the tail can round the other
    /// way and over-shoot the true denominator, under-estimating the
    /// bound and wrongly pruning an optimal subtree).
    pub fn bound(&self, digits: &[usize]) -> f64 {
        let mut denom = 0.0;
        for (i, &j) in digits.iter().enumerate() {
            denom += self.power[i][j];
        }
        for m in &self.min_power[digits.len()..] {
            denom += m;
        }
        self.demand / denom
    }

    /// Exact Eq. 4 tok/W of a full assignment — bit-identical to the
    /// [`analyze_cell`] report's `tok_per_watt` for the same vector.
    pub fn value(&self, digits: &[usize]) -> f64 {
        debug_assert_eq!(digits.len(), self.num_pools());
        let mut denom = 0.0;
        for (i, &j) in digits.iter().enumerate() {
            denom += self.power[i][j];
        }
        self.demand / denom
    }
}

/// Decode a base-|gpus| assignment code (pool 0 the most-significant
/// digit) into the per-pool vector — the same encoding
/// [`mixed_assignments`] counts through.
fn decode_assignment(code: u64, k: usize, gpus: &[Gpu]) -> Vec<Gpu> {
    let n = gpus.len() as u64;
    let mut v = vec![gpus[0]; k];
    let mut c = code;
    for i in (0..k).rev() {
        v[i] = gpus[(c % n) as usize];
        c /= n;
    }
    v
}

/// Bounded best-set under the brute-force ranking order: value
/// descending, ties broken by enumeration order (partition, code, γ) —
/// the order the stable sort in [`screen_assignments`] would leave them
/// in. Offering every candidate in any order yields exactly the top
/// `cap` of that total order, which is what keeps the truncated B&B
/// ranking a bitwise prefix-selection of the brute ranking.
struct KeptSet {
    cap: usize,
    /// `(exact value, (partition idx, assignment code, γ idx))`.
    entries: Vec<(f64, (usize, u64, usize))>,
}

impl KeptSet {
    /// Prune threshold: a subtree whose bound is strictly below this can
    /// contain no candidate that enters the set. `None` while the set
    /// still has room (then nothing may be pruned — even a worst-ranked
    /// leaf must be admitted).
    fn threshold(&self) -> Option<f64> {
        if self.entries.len() < self.cap {
            None
        } else {
            self.entries.get(self.worst_idx()).map(|e| e.0)
        }
    }

    /// Index of the entry that ranks last: smallest value; among equal
    /// values, the latest in enumeration order.
    fn worst_idx(&self) -> usize {
        let mut w = 0;
        for i in 1..self.entries.len() {
            let (vi, ti) = &self.entries[i];
            let (vw, tw) = &self.entries[w];
            match vi.total_cmp(vw) {
                std::cmp::Ordering::Less => w = i,
                std::cmp::Ordering::Equal if ti > tw => w = i,
                _ => {}
            }
        }
        w
    }

    fn offer(&mut self, value: f64, tag: (usize, u64, usize)) {
        if self.cap == 0 {
            return;
        }
        if self.entries.len() < self.cap {
            self.entries.push((value, tag));
            return;
        }
        let w = self.worst_idx();
        let (vw, tw) = self.entries[w];
        let enters = match value.total_cmp(&vw) {
            std::cmp::Ordering::Greater => true,
            // An equal-value candidate earlier in enumeration order
            // out-ranks the worst under the stable sort — ties *lose*
            // only against earlier entries.
            std::cmp::Ordering::Equal => tag < tw,
            std::cmp::Ordering::Less => false,
        };
        if enters {
            self.entries[w] = (value, tag);
        }
    }
}

/// Depth-first branch-and-bound over assignment vectors for one
/// (partition, γ) table: pools assigned most-significant-first so leaves
/// appear in [`mixed_assignments`] code order, homogeneous leaves
/// skipped (the per-fleet axis already screens them), subtrees cut when
/// the admissible bound cannot beat the kept set's worst value.
#[allow(clippy::too_many_arguments)]
fn bnb_descend(
    table: &Eq4PowerTable,
    n: usize,
    depth: usize,
    code: u64,
    prefix: f64,
    first_digit: usize,
    homogeneous: bool,
    tag: (usize, usize),
    kept: &mut KeptSet,
    stats: &mut MixedScreenStats,
) {
    let k = table.num_pools();
    for j in 0..n {
        let code2 = code * n as u64 + j as u64;
        // Left-to-right prefix sum — bitwise the same partial denominator
        // the full evaluation computes.
        let prefix2 = prefix + table.power[depth][j];
        let first2 = if depth == 0 { j } else { first_digit };
        let homog2 = depth == 0 || (homogeneous && j == first2);
        stats.nodes_visited += 1;
        if depth + 1 == k {
            if !homog2 {
                stats.leaves_scored += 1;
                kept.offer(table.demand / prefix2, (tag.0, code2, tag.1));
            }
            continue;
        }
        if let Some(worst) = kept.threshold() {
            let mut denom = prefix2;
            for m in &table.min_power[depth + 1..] {
                denom += m;
            }
            // Strict: a bound *equal* to the worst value may still admit
            // an equal-value leaf earlier in enumeration order.
            if table.demand / denom < worst {
                stats.pruned += 1;
                continue;
            }
        }
        bnb_descend(
            table, n, depth + 1, code2, prefix2, first2, homog2, tag, kept,
            stats,
        );
    }
}

/// Stage A over the mixed per-pool assignment space — the heterogeneous
/// screen behind [`GpuAxis::Mixed`]. [`MixedScreen::BruteForce`]
/// enumerates the full cross-product through [`screen_assignments`];
/// [`MixedScreen::BranchAndBound`] (the default) searches partial
/// assignment vectors with the admissible Eq. 4 bound, keeps the best
/// `keep` cells, and re-evaluates the survivors through the exact
/// [`analyze_cell`] path — so its output is bitwise the brute-force
/// ranking restricted to the top `keep` mixed cells (bit-for-bit equal
/// whenever `keep` covers the grid, e.g. every K ≤ 3 instance under the
/// default budget). Returns the best-first results plus work counters.
#[allow(clippy::too_many_arguments)]
pub fn screen_mixed(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    partitions: &[Vec<u32>],
    gpus: &[Gpu],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    mode: MixedScreen,
    keep: usize,
    model: ModelAxis,
) -> (Vec<PartitionOptResult>, MixedScreenStats) {
    screen_mixed_with(
        trace,
        lambda_rps,
        partitions,
        gpus,
        gammas,
        lbar,
        rho,
        ttft_slo_s,
        acct,
        mode,
        keep,
        model,
        &mut ScreenMemo::disabled(),
    )
}

/// [`screen_mixed`] with an explicit [`ScreenMemo`]: the table builds
/// ([`Eq4PowerTable::new_with`]) and the survivor re-evaluations route
/// through the memo, so a screen that already evaluated the homogeneous
/// axis replays those cells from cache instead of re-running Eq. 4.
#[allow(clippy::too_many_arguments)]
fn screen_mixed_with(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    partitions: &[Vec<u32>],
    gpus: &[Gpu],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
    mode: MixedScreen,
    keep: usize,
    model: ModelAxis,
    memo: &mut ScreenMemo,
) -> (Vec<PartitionOptResult>, MixedScreenStats) {
    let n = gpus.len();
    let mut stats = MixedScreenStats::default();
    for cuts in partitions {
        let cells = (n as u64).pow(cuts.len() as u32) - n as u64;
        stats.brute_cells += cells * gammas.len() as u64;
    }
    if n < 2 || partitions.is_empty() || gammas.is_empty() {
        return (Vec::new(), stats);
    }
    if mode == MixedScreen::BruteForce {
        let cells = mixed_assignments(partitions, gpus);
        stats.leaves_scored = stats.brute_cells;
        stats.full_evals = stats.brute_cells;
        let out = screen_assignments_with(
            trace, lambda_rps, &cells, gammas, lbar, rho, ttft_slo_s, acct,
            model, memo,
        );
        return (out, stats);
    }
    let mut kept = KeptSet { cap: keep, entries: Vec::new() };
    for (pi, cuts) in partitions.iter().enumerate() {
        for (gi, &gamma) in gammas.iter().enumerate() {
            let table = Eq4PowerTable::new_with(
                trace, lambda_rps, cuts, gpus, gamma, lbar, rho, ttft_slo_s,
                acct, model, memo,
            );
            stats.table_evals += n as u64;
            bnb_descend(
                &table, n, 0, 0, 0.0, 0, true, (pi, gi), &mut kept,
                &mut stats,
            );
        }
    }
    // Survivors re-enter the exact Eq. 4 path in brute enumeration order
    // (partition, code, γ) so the final stable sort reproduces the
    // brute-force ranking bit for bit.
    let mut tags = kept.entries;
    tags.sort_by(|a, b| a.1.cmp(&b.1));
    let mut out = Vec::with_capacity(tags.len());
    for (_, (pi, code, gi)) in tags {
        let cuts = &partitions[pi];
        let gamma = gammas[gi];
        let v = decode_assignment(code, cuts.len(), gpus);
        let report = memo.eval(
            trace, lambda_rps, cuts, &v, gamma, lbar, rho, ttft_slo_s, acct,
            model,
        );
        stats.full_evals += 1;
        out.push(PartitionOptResult {
            cutoffs: cuts.clone(),
            gpus: v,
            gamma,
            report,
        });
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    (out, stats)
}

/// Each explicit assignment vector paired with every partition whose
/// pool count matches its length.
fn explicit_assignments(
    partitions: &[Vec<u32>],
    vectors: &[Vec<Gpu>],
) -> Vec<(Vec<u32>, Vec<Gpu>)> {
    let mut out = Vec::new();
    for cuts in partitions {
        for v in vectors {
            if v.len() == cuts.len() {
                out.push((cuts.clone(), v.clone()));
            }
        }
    }
    out
}

/// The greedy budgeted-upgrade path for one config: per (partition, γ),
/// start from the all-`base` fleet (already screened by the homogeneous
/// axis) and repeatedly upgrade the pool with the best marginal Eq. 4
/// tok/W per upgraded group, while total upgraded groups — by the
/// analytical plan's sizing — stay within the budget. Every step of the
/// path becomes a screened cell, so the report shows the whole
/// placement curve, not just its endpoint.
fn budget_cells(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    partitions: &[Vec<u32>],
    budget: UpgradeBudget,
    model: ModelAxis,
    memo: &mut ScreenMemo,
) -> Vec<ScreenedCell> {
    let base = cfg.gpus.first().copied().unwrap_or(Gpu::H100);
    // The all-`base` starting fleet of every (partition, γ) path is the
    // homogeneous cell the per-fleet axis already screened — same key,
    // so under a shared memo the path starts from cache replay.
    let mut eval = |cuts: &[u32], gpus: &[Gpu], gamma: f64| {
        memo.eval(
            workload,
            cfg.gen.lambda_rps,
            cuts,
            gpus,
            gamma,
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
            model,
        )
    };
    let mut cells = Vec::new();
    for cuts in partitions {
        for &gamma in &cfg.gammas {
            let k = cuts.len();
            let mut current = vec![base; k];
            let mut cur_tok_w =
                eval(cuts, &current, gamma).tok_per_watt.0;
            loop {
                // (pool, report, marginal tok/W per upgraded group)
                let mut best: Option<(usize, FleetReport, f64)> = None;
                for i in 0..k {
                    if current[i] == budget.to {
                        continue;
                    }
                    let mut cand = current.clone();
                    cand[i] = budget.to;
                    let rep = eval(cuts, &cand, gamma);
                    let upgraded: u64 = rep
                        .pools
                        .iter()
                        .zip(&cand)
                        .filter(|(_, g)| **g == budget.to)
                        .map(|(p, _)| p.sizing.groups)
                        .sum();
                    if upgraded > budget.max_groups as u64 {
                        continue;
                    }
                    let gain = rep.tok_per_watt.0 - cur_tok_w;
                    if gain <= 0.0 {
                        continue;
                    }
                    let marginal =
                        gain / rep.pools[i].sizing.groups.max(1) as f64;
                    let better = match &best {
                        None => true,
                        Some((_, _, m)) => marginal > *m,
                    };
                    if better {
                        best = Some((i, rep, marginal));
                    }
                }
                let Some((i, rep, _)) = best else { break };
                current[i] = budget.to;
                cur_tok_w = rep.tok_per_watt.0;
                cells.push(ScreenedCell {
                    gpu: base,
                    model,
                    cutoffs: cuts.clone(),
                    gpus: current.clone(),
                    gamma,
                    analytic: rep,
                });
            }
        }
    }
    cells
}

/// Stage A: screen the full GPU-assignment × partition × γ grid
/// analytically, best-first (ties keep grid order). The homogeneous
/// per-fleet axis is always screened; [`GpuAxis`] adds mixed, explicit
/// or budgeted-upgrade assignment cells on top. Memoized: repeated
/// Eq. 4 cells — the homogeneous tuples the mixed screen's power tables
/// rebuild, the budgeted-upgrade starting fleets — are evaluated once
/// and replayed from cache, bit-identically ([`ScreenMemo`]).
pub fn screen(workload: &WorkloadTrace, cfg: &OptimizeConfig) -> Vec<ScreenedCell> {
    screen_with_stats(workload, cfg).0
}

/// [`screen`] plus the memo's work counters — what `wattlaw optimize`
/// reports and the `screen_memo` bench section measures.
pub fn screen_with_stats(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
) -> (Vec<ScreenedCell>, ScreenMemoStats) {
    let mut memo = ScreenMemo::new();
    let cells = screen_impl(workload, cfg, &mut memo);
    (cells, memo.stats())
}

/// [`screen`] with the cache disabled: every cell runs the Eq. 4 closed
/// form. This is the bitwise oracle the memoized screen is held
/// identical to (`memoized_screen_ranks_identical_to_uncached`) — same
/// code path, pass-through memo.
pub fn screen_uncached(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
) -> Vec<ScreenedCell> {
    screen_impl(workload, cfg, &mut ScreenMemo::disabled())
}

fn screen_impl(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    memo: &mut ScreenMemo,
) -> Vec<ScreenedCell> {
    let partitions = cfg.effective_partitions();
    let mut cells = Vec::with_capacity(
        cfg.models.len()
            * cfg.gpus.len()
            * partitions.len()
            * cfg.gammas.len(),
    );
    for &model in &cfg.models {
        for &gpu in &cfg.gpus {
            // The homogeneous axis routes through the same per-pool
            // override evaluator as every other cell (all pools pinned
            // to `gpu` — bit-identical to the legacy shared-profile
            // path by the homogeneous-reduction oracle), so the mixed
            // screen's table builds below hit these entries in cache.
            let pairs: Vec<(Vec<u32>, Vec<Gpu>)> = partitions
                .iter()
                .map(|cuts| (cuts.clone(), vec![gpu; cuts.len()]))
                .collect();
            for r in screen_assignments_with(
                workload,
                cfg.gen.lambda_rps,
                &pairs,
                &cfg.gammas,
                cfg.lbar,
                cfg.rho,
                cfg.slo.ttft_p99_s,
                cfg.acct,
                model,
                memo,
            ) {
                cells.push(ScreenedCell {
                    gpu,
                    model,
                    gpus: r.gpus,
                    cutoffs: r.cutoffs,
                    gamma: r.gamma,
                    analytic: r.report,
                });
            }
        }
        let hetero: Vec<PartitionOptResult> = match &cfg.gpu_axis {
            GpuAxis::Homogeneous | GpuAxis::Budget(_) => Vec::new(),
            GpuAxis::Mixed => {
                screen_mixed_with(
                    workload,
                    cfg.gen.lambda_rps,
                    &partitions,
                    &cfg.gpus,
                    &cfg.gammas,
                    cfg.lbar,
                    cfg.rho,
                    cfg.slo.ttft_p99_s,
                    cfg.acct,
                    cfg.mixed_screen,
                    cfg.mixed_keep,
                    model,
                    memo,
                )
                .0
            }
            GpuAxis::Explicit(vectors) => {
                let pairs = explicit_assignments(&partitions, vectors);
                if pairs.is_empty() {
                    Vec::new()
                } else {
                    screen_assignments_with(
                        workload,
                        cfg.gen.lambda_rps,
                        &pairs,
                        &cfg.gammas,
                        cfg.lbar,
                        cfg.rho,
                        cfg.slo.ttft_p99_s,
                        cfg.acct,
                        model,
                        memo,
                    )
                }
            }
        };
        for r in hetero {
            cells.push(ScreenedCell {
                gpu: r.gpus[0],
                model,
                cutoffs: r.cutoffs,
                gpus: r.gpus,
                gamma: r.gamma,
                analytic: r.report,
            });
        }
        if let GpuAxis::Budget(b) = &cfg.gpu_axis {
            cells.extend(budget_cells(
                workload, cfg, &partitions, *b, model, memo,
            ));
        }
    }
    cells.sort_by(|a, b| {
        b.analytic.tok_per_watt.0.total_cmp(&a.analytic.tok_per_watt.0)
    });
    cells
}

/// The [`ScenarioSpec`] realizing one screened cell at serving time.
/// For a two-entry cutoff vector this builds the same routed fleet as
/// the PR 3 `Topology::FleetOpt` spec, bit-for-bit (the K=2 reduction).
/// Every cell — mixed or homogeneous — goes through the per-pool
/// override path, so a pool overridden to the fleet default is
/// bit-identical to no override at all (the homogeneous-reduction
/// oracle in `tests/optimize_oracle.rs` pins this).
fn spec_for(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    cell: &ScreenedCell,
    dispatch: &str,
) -> ScenarioSpec {
    ScenarioSpec::new(
        Topology::partition_with_gpus(&cell.cutoffs, &cell.gpus, cell.gamma),
        cell.gpu,
        workload.clone(),
        cfg.gen.clone(),
    )
    .with_model(cell.model)
    .with_groups(cfg.groups)
    .with_dispatch(dispatch)
    .with_arrivals(cfg.arrivals.clone())
    .with_slo(cfg.slo)
    .with_lbar(cfg.lbar)
    .with_rho(cfg.rho)
    .with_step_mode(cfg.step_mode)
}

/// Stage B: expand the surviving cells across the dispatch axis, replay
/// each through the event engine on `workers` scoped threads, and
/// re-rank by measured tok/W — SLO-passing cells strictly first.
pub fn refine(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    survivors: &[ScreenedCell],
    workers: usize,
) -> Vec<RefinedCell> {
    let mut specs = Vec::with_capacity(survivors.len() * cfg.dispatches.len());
    let mut meta = Vec::with_capacity(specs.capacity());
    for cell in survivors {
        for d in &cfg.dispatches {
            specs.push(spec_for(workload, cfg, cell, d));
            meta.push((cell, d.clone()));
        }
    }
    let outcomes = sweep::run(&specs, workers);
    let mut refined: Vec<RefinedCell> = meta
        .into_iter()
        .zip(outcomes)
        .map(|((cell, dispatch), outcome)| RefinedCell {
            gpu: cell.gpu,
            model: cell.model,
            cutoffs: cell.cutoffs.clone(),
            gpus: cell.gpus.clone(),
            gamma: cell.gamma,
            dispatch,
            analytic_tok_w: cell.analytic.tok_per_watt.0,
            analytic_groups: cell.analytic.total_groups,
            outcome,
        })
        .collect();
    refined.sort_by(|a, b| {
        b.outcome
            .slo_ok
            .cmp(&a.outcome.slo_ok)
            .then(b.outcome.tok_per_watt.total_cmp(&a.outcome.tok_per_watt))
    });
    refined
}

/// The full two-stage search.
pub fn optimize(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    workers: usize,
) -> OptimizeReport {
    let (screened, memo) = screen_with_stats(workload, cfg);
    let k = cfg.top_k.max(1).min(screened.len());
    let refined = refine(workload, cfg, &screened[..k], workers);
    OptimizeReport { screened, refined, memo }
}

/// Everything the search produced: the full stage-A ranking plus the
/// stage-B refinements (measured-rank order, SLO-passing cells first).
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    pub screened: Vec<ScreenedCell>,
    pub refined: Vec<RefinedCell>,
    /// Stage-A memo counters: Eq. 4 evaluations requested vs served
    /// from cache ([`ScreenMemo`]).
    pub memo: ScreenMemoStats,
}

impl OptimizeReport {
    /// The best *measured* cell that meets the SLO — the hard filter:
    /// `None` when every refined cell violates it.
    pub fn winner(&self) -> Option<&RefinedCell> {
        self.refined.first().filter(|c| c.outcome.slo_ok)
    }

    /// The refined cells as one typed table: stage-A analytical and
    /// stage-B simulated tok/W side by side for every cell.
    pub fn rowset(&self) -> RowSet {
        let mut rs = RowSet::new(
            "FleetOpt optimization — stage A analytical screen, \
             stage B simulated refine",
            vec![
                Column::str("GPU"),
                Column::str("model"),
                Column::int("pools"),
                Column::str("cutoffs").with_unit("tok"),
                Column::float("gamma"),
                Column::str("dispatch"),
                Column::float("analyze tok/W").with_unit("tok/J"),
                Column::float("simulate tok/W").with_unit("tok/J"),
                Column::float("delta").with_unit("%"),
                Column::float("p99 TTFT").with_unit("s"),
                Column::str("slo"),
                Column::int("analyze groups"),
                Column::str("winner"),
            ],
        );
        let winner_idx = if self.winner().is_some() { Some(0) } else { None };
        for (i, c) in self.refined.iter().enumerate() {
            let delta = c.rel_delta_pct();
            rs.push(vec![
                Cell::str(assignment_label(&c.gpus)),
                Cell::str(c.model.label()),
                Cell::int(c.cutoffs.len() as i64),
                Cell::str(cutoffs_label(&c.cutoffs)),
                Cell::float(c.gamma),
                Cell::str(&c.dispatch),
                Cell::float(c.analytic_tok_w)
                    .shown(format!("{:.3}", c.analytic_tok_w)),
                Cell::float(c.outcome.tok_per_watt)
                    .shown(format!("{:.3}", c.outcome.tok_per_watt)),
                Cell::float(delta).shown(format!("{delta:+.1}%")),
                Cell::float(c.outcome.p99_ttft_s)
                    .shown(format!("{:.3}", c.outcome.p99_ttft_s)),
                Cell::str(if c.outcome.slo_ok { "pass" } else { "MISS" }),
                Cell::int(c.analytic_groups as i64),
                Cell::str(if winner_idx == Some(i) { "*" } else { "" }),
            ]);
        }
        rs.note(format!(
            "stage A screened {} analytical cells; top {} refined across {} \
             dispatch polic{} through the event-driven simulator",
            self.screened.len(),
            self.refined.len() / self.dispatch_count().max(1),
            self.dispatch_count(),
            if self.dispatch_count() == 1 { "y" } else { "ies" },
        ));
        if self.memo.hits > 0 {
            rs.note(format!(
                "stage A memo: {} of {} Eq. 4 evaluations served from cache \
                 ({:.0}% hit rate)",
                self.memo.hits,
                self.memo.evals,
                100.0 * self.memo.hit_rate(),
            ));
        }
        match self.winner() {
            Some(w) => rs.note(format!(
                "winner (best measured tok/W within SLO): {} cutoffs={} γ={} \
                 dispatch={} at {:.3} tok/W (analytical said {:.3})",
                assignment_label(&w.gpus),
                cutoffs_label(&w.cutoffs),
                w.gamma,
                w.dispatch,
                w.outcome.tok_per_watt,
                w.analytic_tok_w,
            )),
            None => rs.note(
                "no refined cell met the p99 TTFT SLO — no winner \
                 (widen the grid, relax the SLO, or add capacity)",
            ),
        };
        rs
    }

    fn dispatch_count(&self) -> usize {
        let mut names: Vec<&str> =
            self.refined.iter().map(|c| c.dispatch.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn tiny_cfg() -> OptimizeConfig {
        OptimizeConfig {
            gpus: vec![Gpu::H100],
            b_shorts: vec![2048, 4096],
            gammas: vec![1.0, 2.0],
            dispatches: vec!["rr".into()],
            gen: GenConfig {
                lambda_rps: 120.0,
                duration_s: 0.5,
                max_prompt_tokens: 20_000,
                max_output_tokens: 64,
                seed: 7,
            },
            groups: 2,
            // Generous SLO so the mechanics (not the latency magnitudes)
            // are under test.
            slo: SloTargets { ttft_p99_s: 1e3 },
            top_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn screen_covers_the_grid_best_first() {
        let cells = screen(&azure_conversations(), &tiny_cfg());
        assert_eq!(cells.len(), 4);
        for w in cells.windows(2) {
            assert!(
                w[0].analytic.tok_per_watt.0 >= w[1].analytic.tok_per_watt.0
            );
        }
        // γ=2 compression always beats γ=1 at the same boundary here.
        assert_eq!(cells[0].gamma, 2.0);
    }

    #[test]
    fn optimize_pairs_analytical_and_measured_per_cell() {
        let cfg = tiny_cfg();
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert_eq!(report.refined.len(), cfg.top_k * cfg.dispatches.len());
        for c in &report.refined {
            assert!(c.analytic_tok_w > 0.0);
            assert!(c.outcome.completed > 0);
            assert!(c.rel_delta_pct().is_finite());
        }
        let w = report.winner().expect("generous SLO must yield a winner");
        assert!(w.outcome.slo_ok);
        // The winner leads the measured ranking.
        assert!(std::ptr::eq(w, &report.refined[0]));
    }

    #[test]
    fn slo_is_a_hard_filter_for_the_winner() {
        let cfg = OptimizeConfig {
            slo: SloTargets { ttft_p99_s: 1e-9 },
            ..tiny_cfg()
        };
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert!(!report.refined.is_empty());
        assert!(report.refined.iter().all(|c| !c.outcome.slo_ok));
        assert!(report.winner().is_none(), "impossible SLO ⇒ no winner");
        let rs = report.rowset();
        assert!(rs.to_text().contains("no refined cell met"));
    }

    #[test]
    fn kpool_partitions_enumerate_the_ladder() {
        let k2 = kpool_partitions(2);
        assert_eq!(k2.len(), CUTOFF_LADDER.len());
        assert_eq!(k2[0], vec![1024, crate::fleet::topology::LONG_CTX]);
        let k3 = kpool_partitions(3);
        assert_eq!(k3.len(), 15, "C(6,2) interior pairs");
        let k4 = kpool_partitions(4);
        assert_eq!(k4.len(), 20, "C(6,3) interior triples");
        for cuts in k2.iter().chain(&k3).chain(&k4) {
            assert!(cuts.windows(2).all(|w| w[0] < w[1]), "{cuts:?}");
            assert_eq!(
                *cuts.last().unwrap(),
                crate::fleet::topology::LONG_CTX
            );
        }
    }

    #[test]
    fn kpool_grid_screens_and_refines_end_to_end() {
        let cfg = OptimizeConfig {
            partitions: vec![
                vec![4096, crate::fleet::topology::LONG_CTX],
                vec![2048, 8192, crate::fleet::topology::LONG_CTX],
            ],
            gammas: vec![1.0],
            groups: 4,
            ..tiny_cfg()
        };
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert_eq!(report.screened.len(), 2);
        assert_eq!(report.refined.len(), 2);
        assert!(report
            .screened
            .iter()
            .any(|c| c.cutoffs.len() == 3), "K=3 cell screened");
        let w = report.winner().expect("generous SLO yields a winner");
        assert!(w.outcome.completed > 0);
        let rs = report.rowset();
        assert!(rs.to_text().contains("2048|8192|65536"));
    }

    /// Small deterministic generator for the admissibility sampling —
    /// the bound proof is order-theoretic, the test just probes it.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self, modulo: usize) -> usize {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((self.0 >> 11) % modulo as u64) as usize
        }
    }

    #[test]
    fn eq4_table_value_matches_analyze_cell_bitwise() {
        let trace = azure_conversations();
        let cuts = vec![4096, 16384, LONG_CTX];
        let gpus = [Gpu::H100, Gpu::H200, Gpu::B200];
        let table = Eq4PowerTable::new(
            &trace,
            120.0,
            &cuts,
            &gpus,
            2.0,
            LBarPolicy::Window,
            0.85,
            1e3,
            PowerAccounting::PerGpu,
            ModelAxis::Dense,
        );
        let mut rng = Lcg(17);
        for _ in 0..10 {
            let digits: Vec<usize> =
                (0..cuts.len()).map(|_| rng.next(gpus.len())).collect();
            let v: Vec<Gpu> = digits.iter().map(|&j| gpus[j]).collect();
            let report = analyze_cell(
                &Topology::partition_with_gpus(&cuts, &v, 2.0),
                &trace,
                120.0,
                Arc::new(ManualProfile::for_gpu(v[0])),
                LBarPolicy::Window,
                0.85,
                1e3,
                PowerAccounting::PerGpu,
                ModelAxis::Dense,
            );
            assert_eq!(
                table.value(&digits).to_bits(),
                report.tok_per_watt.0.to_bits(),
                "{v:?}: the Eq. 4 table must reproduce analyze_cell \
                 bit for bit"
            );
        }
    }

    #[test]
    fn eq4_bound_is_admissible_on_random_partial_assignments() {
        let trace = azure_conversations();
        let cuts = vec![2048, 8192, LONG_CTX];
        let gpus = [Gpu::H100, Gpu::H200, Gpu::B200];
        let table = Eq4PowerTable::new(
            &trace,
            120.0,
            &cuts,
            &gpus,
            1.0,
            LBarPolicy::Window,
            0.85,
            1e3,
            PowerAccounting::PerGpu,
            ModelAxis::Dense,
        );
        let k = cuts.len();
        let n = gpus.len();
        let mut rng = Lcg(99);
        for _ in 0..40 {
            let depth = rng.next(k + 1);
            let mut digits: Vec<usize> =
                (0..depth).map(|_| rng.next(n)).collect();
            let bound = table.bound(&digits);
            // Enumerate every completion of the partial assignment and
            // check the bound dominates each exact value (bitwise ≥,
            // not within-epsilon — pruning correctness is exact).
            let tail = k - depth;
            for code in 0..(n as u64).pow(tail as u32) {
                let mut c = code;
                digits.truncate(depth);
                let mut suffix = vec![0usize; tail];
                for slot in suffix.iter_mut().rev() {
                    *slot = (c % n as u64) as usize;
                    c /= n as u64;
                }
                digits.extend_from_slice(&suffix);
                let value = table.value(&digits);
                assert!(
                    bound >= value,
                    "bound {bound} < completion value {value} at \
                     depth {depth}, digits {digits:?}"
                );
            }
        }
    }

    #[test]
    fn bnb_matches_brute_force_bitwise_on_a_small_grid() {
        let trace = azure_conversations();
        let partitions = vec![
            vec![4096, LONG_CTX],
            vec![2048, 8192, LONG_CTX],
        ];
        let gpus = [Gpu::H100, Gpu::B200];
        let gammas = [1.0, 2.0];
        let run = |mode| {
            screen_mixed(
                &trace,
                120.0,
                &partitions,
                &gpus,
                &gammas,
                LBarPolicy::Window,
                0.85,
                1e3,
                PowerAccounting::PerGpu,
                mode,
                64,
                ModelAxis::Dense,
            )
        };
        let (brute, bstats) = run(MixedScreen::BruteForce);
        let (bnb, nstats) = run(MixedScreen::BranchAndBound);
        assert_eq!(bstats.brute_cells, 2 * 2 + 6 * 2); // (2²−2)·2 + (2³−2)·2
        assert_eq!(nstats.brute_cells, bstats.brute_cells);
        assert_eq!(brute.len(), bnb.len());
        for (a, b) in brute.iter().zip(&bnb) {
            assert_eq!(a.cutoffs, b.cutoffs);
            assert_eq!(a.gpus, b.gpus);
            assert_eq!(a.gamma.to_bits(), b.gamma.to_bits());
            assert_eq!(
                a.report.tok_per_watt.0.to_bits(),
                b.report.tok_per_watt.0.to_bits()
            );
        }
    }

    #[test]
    fn bnb_keep_truncation_is_a_prefix_of_the_brute_ranking() {
        let trace = azure_conversations();
        let partitions = vec![vec![2048, 8192, LONG_CTX]];
        let gpus = [Gpu::H100, Gpu::H200, Gpu::B200];
        let gammas = [1.0];
        let run = |mode, keep| {
            screen_mixed(
                &trace,
                120.0,
                &partitions,
                &gpus,
                &gammas,
                LBarPolicy::Window,
                0.85,
                1e3,
                PowerAccounting::PerGpu,
                mode,
                keep,
                ModelAxis::Dense,
            )
            .0
        };
        let brute = run(MixedScreen::BruteForce, usize::MAX);
        assert_eq!(brute.len(), 27 - 3);
        let kept = run(MixedScreen::BranchAndBound, 5);
        assert_eq!(kept.len(), 5);
        for (a, b) in brute.iter().zip(&kept) {
            assert_eq!(a.gpus, b.gpus);
            assert_eq!(
                a.report.tok_per_watt.0.to_bits(),
                b.report.tok_per_watt.0.to_bits()
            );
        }
    }

    #[test]
    fn rowset_shows_both_engines_side_by_side() {
        let report = optimize(&azure_conversations(), &tiny_cfg(), 2);
        let rs = report.rowset();
        let csv = rs.to_csv();
        assert!(csv.starts_with(
            "GPU,model,pools,cutoffs (tok),gamma,dispatch,\
             analyze tok/W (tok/J),simulate tok/W (tok/J),delta (%),\
             p99 TTFT (s),slo,analyze groups,winner\n"
        ));
        assert!(csv.contains(",dense,"));
        let doc = crate::runtime::json::parse(&rs.to_json()).unwrap();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), report.refined.len());
        for r in rows {
            assert!(r.get("analyze tok/W").unwrap().as_f64().is_some());
            assert!(r.get("simulate tok/W").unwrap().as_f64().is_some());
        }
        // Winner marked on the first (SLO-passing) row.
        assert_eq!(rows[0].get("winner").unwrap().as_str(), Some("*"));
        assert_eq!(rows[0].get("slo").unwrap().as_str(), Some("pass"));
    }

    #[test]
    fn model_axis_multiplies_the_screen_and_moe_wins_it() {
        let trace = azure_conversations();
        let dense_only = screen(&trace, &tiny_cfg());
        let moe = ModelAxis::MoeStreaming { dispatch_ms: 0.0 };
        let cfg = OptimizeConfig {
            models: vec![ModelAxis::Dense, moe],
            ..tiny_cfg()
        };
        let cells = screen(&trace, &cfg);
        assert_eq!(cells.len(), 2 * dense_only.len(), "4th axis multiplies");
        // Weight streaming collapses W ⇒ every MoE cell out-ranks every
        // dense cell in the joint best-first ordering.
        assert!(cells[..dense_only.len()].iter().all(|c| c.model == moe));
        assert!(cells[dense_only.len()..]
            .iter()
            .all(|c| c.model == ModelAxis::Dense));
        // The dense slice of the joint screen is the dense-only screen,
        // bit for bit — the new axis is orthogonal, not perturbative.
        for (joint, solo) in cells[dense_only.len()..].iter().zip(&dense_only)
        {
            assert_eq!(joint.cutoffs, solo.cutoffs);
            assert_eq!(joint.gamma.to_bits(), solo.gamma.to_bits());
            assert_eq!(
                joint.analytic.tok_per_watt.0.to_bits(),
                solo.analytic.tok_per_watt.0.to_bits()
            );
        }
    }

    /// Cell-for-cell bitwise comparison of two screen rankings — the
    /// memo oracle's assertion body.
    fn assert_screens_identical(a: &[ScreenedCell], b: &[ScreenedCell]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.gpu, y.gpu);
            assert_eq!(x.model, y.model);
            assert_eq!(x.cutoffs, y.cutoffs);
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.gamma.to_bits(), y.gamma.to_bits());
            assert_eq!(
                x.analytic.tok_per_watt.0.to_bits(),
                y.analytic.tok_per_watt.0.to_bits()
            );
            assert_eq!(x.analytic.total_groups, y.analytic.total_groups);
        }
    }

    #[test]
    fn memoized_screen_ranks_identical_to_uncached() {
        let trace = azure_conversations();
        let cfg = OptimizeConfig {
            gpus: vec![Gpu::H100, Gpu::H200],
            models: vec![
                ModelAxis::Dense,
                ModelAxis::MoeStreaming { dispatch_ms: 0.5 },
            ],
            partitions: vec![
                vec![4096, LONG_CTX],
                vec![2048, 8192, LONG_CTX],
            ],
            gammas: vec![1.0, 2.0],
            gpu_axis: GpuAxis::Mixed,
            ..tiny_cfg()
        };
        let (cached, stats) = screen_with_stats(&trace, &cfg);
        let uncached = screen_uncached(&trace, &cfg);
        assert_screens_identical(&cached, &uncached);
        // Every table-build run of the mixed screen replays a cell the
        // homogeneous axis already evaluated: |models| × |gpus| ×
        // |partitions| × |γ| hits, nothing else cached twice.
        assert_eq!(stats.hits, 2 * 2 * 2 * 2, "one hit per table run");
        assert!(stats.evals > stats.hits);
        assert!(stats.hit_rate() > 0.0 && stats.hit_rate() < 1.0);
    }

    #[test]
    fn memoized_budget_screen_replays_its_starting_fleets() {
        let trace = azure_conversations();
        let cfg = OptimizeConfig {
            gpus: vec![Gpu::H100],
            partitions: vec![
                vec![4096, LONG_CTX],
                vec![2048, 8192, LONG_CTX],
            ],
            gammas: vec![1.0],
            gpu_axis: GpuAxis::Budget(UpgradeBudget {
                to: Gpu::B200,
                max_groups: 10_000,
            }),
            ..tiny_cfg()
        };
        let (cached, stats) = screen_with_stats(&trace, &cfg);
        let uncached = screen_uncached(&trace, &cfg);
        assert_screens_identical(&cached, &uncached);
        // Each greedy path's all-base starting fleet is a homogeneous
        // cell the per-fleet axis screened — one hit per (partition, γ).
        assert!(
            stats.hits >= 2,
            "expected one starting-fleet hit per greedy path, got {stats:?}"
        );
    }
}
