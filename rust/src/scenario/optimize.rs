//! The scenario-native FleetOpt optimizer: a two-stage search over
//! [`ScenarioSpec`] space — `wattlaw optimize`.
//!
//! FleetOpt (Chen et al. 2026) frames provisioning as an
//! analytical-search-then-validate loop, and SweetSpot (Pizzini Cavagna
//! et al. 2026) shows why the analytical screen and the measured check
//! must be cross-tabulated per operating point. This module is that
//! loop over the crate's own two engines:
//!
//! * **Stage A — analytical screen.** The full
//!   partition × γ × GPU-generation grid is evaluated with the
//!   closed-form Eq. (4) planner ([`ScenarioSpec::analyze`]; dispatch
//!   does not enter the closed form, so each analytical cell is
//!   screened once). The partition axis is a vector of K-pool context
//!   cutoffs ([`kpool_partitions`] generates the K ∈ {2, 3, 4} grids;
//!   the default is the legacy `[B_short, LONG_CTX]` two-pool axis).
//!   Cheap: hundreds of cells per millisecond, so the grid can be wide.
//! * **Stage B — simulated refine.** The top-k surviving cells are
//!   expanded across the dispatch axis and replayed through
//!   [`ScenarioSpec::simulate`] on scoped worker threads
//!   ([`sweep::run`]), then re-ranked by *measured* tok/W with the
//!   p99-TTFT SLO verdict as a hard filter: an SLO-violating cell can
//!   appear in the report but can never be the winner.
//!
//! The legacy closed-form sweep (`fleet::optimizer::sweep_fleetopt`)
//! is now a thin wrapper over this module's [`screen_closed_form`], so
//! both paths rank by the same arithmetic — the regression oracle in
//! `tests/optimize_oracle.rs` holds them together.

use std::sync::Arc;

use super::{sweep, ScenarioOutcome, ScenarioSpec, SloTargets};
use crate::fleet::analysis::{fleet_tpw_analysis, FleetReport};
use crate::fleet::optimizer::{OptResult, B_SHORT_GRID, GAMMA_GRID};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::sim::dispatch;
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;

/// Interior-cutoff choices for the generated K-pool grids
/// ([`kpool_partitions`]); the final pool always serves the full
/// [`LONG_CTX`] window.
pub const CUTOFF_LADDER: [u32; 6] = [1024, 2048, 4096, 8192, 16384, 32768];

/// Every K-pool partition vector on the cutoff ladder: all strictly
/// increasing (K−1)-combinations of [`CUTOFF_LADDER`], each closed with
/// the `LONG_CTX` long pool. Deterministic lexicographic order (so the
/// stage-A stable sort is reproducible). K=2 yields one `[b, 64K]`
/// vector per ladder entry — the classic two-pool split axis.
pub fn kpool_partitions(k: u32) -> Vec<Vec<u32>> {
    assert!(
        (2..=CUTOFF_LADDER.len() as u32 + 1).contains(&k),
        "K must be in 2..={} (got {k})",
        CUTOFF_LADDER.len() + 1
    );
    let interior = (k - 1) as usize;
    let mut out = Vec::new();
    let mut combo: Vec<usize> = (0..interior).collect();
    loop {
        let mut cuts: Vec<u32> =
            combo.iter().map(|&i| CUTOFF_LADDER[i]).collect();
        cuts.push(LONG_CTX);
        out.push(cuts);
        // Advance the combination (lexicographic).
        let mut pos = interior;
        while pos > 0 {
            pos -= 1;
            if combo[pos] + 1 <= CUTOFF_LADDER.len() - (interior - pos) {
                combo[pos] += 1;
                for j in pos + 1..interior {
                    combo[j] = combo[j - 1] + 1;
                }
                break;
            }
            if pos == 0 {
                return out;
            }
        }
        if interior == 0 {
            return out;
        }
    }
}

/// Closed-form evaluation of one (topology, profile) cell — the single
/// Eq. (4) path behind [`ScenarioSpec::analyze`], the stage-A screen,
/// and the legacy `fleet::optimizer` wrapper.
#[allow(clippy::too_many_arguments)]
pub fn analyze_cell(
    topology: &Topology,
    workload: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> FleetReport {
    let pools =
        topology.pools(workload, lambda_rps, profile, None, lbar, rho, ttft_slo_s);
    fleet_tpw_analysis(&pools, acct)
}

/// One screened K-pool cell: the partition vector, its long-pool γ, and
/// the closed-form Eq. 4 report.
#[derive(Debug, Clone)]
pub struct PartitionOptResult {
    /// Sorted cutoff vector; the last entry is the long pool's window.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment; empty = every pool on the caller's
    /// fleet-default profile (the homogeneous legacy axis).
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub report: FleetReport,
}

/// Render a per-pool GPU assignment: the plain SKU name when the fleet
/// is homogeneous (matching every legacy single-GPU surface), the
/// compact `H100|H100|B200` vector when generations are mixed.
pub fn assignment_label(gpus: &[Gpu]) -> String {
    match gpus {
        [] => String::new(),
        [first, rest @ ..] if rest.iter().all(|g| g == first) => {
            first.spec().name.to_string()
        }
        _ => gpus
            .iter()
            .map(|g| g.short_name())
            .collect::<Vec<_>>()
            .join("|"),
    }
}

/// Stage A over an explicit (partition vector × γ) grid with an
/// arbitrary profile, best-first (the stable sort keeps grid order on
/// ties). Profile-generic (not `Gpu`-keyed) so the legacy
/// `sweep_fleetopt` API — which accepts any [`GpuProfile`] — can
/// delegate here without loss of generality. A `[b, LONG_CTX]` vector
/// with γ evaluates bit-identically to the two-pool
/// `Topology::FleetOpt { b_short: b, .. }` cell, which is what makes
/// the K=2 reduction oracle exact.
#[allow(clippy::too_many_arguments)]
pub fn screen_partitions(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    partitions: &[Vec<u32>],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<PartitionOptResult> {
    let mut out = Vec::with_capacity(partitions.len() * gammas.len());
    for cutoffs in partitions {
        for &gamma in gammas {
            let topo = Topology::partition_with_gamma(cutoffs, gamma);
            let report = analyze_cell(
                &topo,
                trace,
                lambda_rps,
                profile.clone(),
                lbar,
                rho,
                ttft_slo_s,
                acct,
            );
            out.push(PartitionOptResult {
                cutoffs: cutoffs.clone(),
                gpus: Vec::new(),
                gamma,
                report,
            });
        }
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    out
}

/// Stage A over explicit (partition, per-pool GPU assignment) pairs —
/// the heterogeneous counterpart of [`screen_partitions`]: each cell's
/// pools carry their own generation's profile through the *same*
/// [`analyze_cell`] Eq. 4 path (an all-same assignment evaluates
/// bit-identically to the homogeneous cell, which is what makes the
/// homogeneous-reduction oracle exact). Best-first; the stable sort
/// keeps grid order on ties.
#[allow(clippy::too_many_arguments)]
pub fn screen_assignments(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    cells: &[(Vec<u32>, Vec<Gpu>)],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<PartitionOptResult> {
    let mut out = Vec::with_capacity(cells.len() * gammas.len());
    for (cutoffs, gpus) in cells {
        for &gamma in gammas {
            let topo = Topology::partition_with_gpus(cutoffs, gpus, gamma);
            // Every pool overrides, so the default profile below is
            // never consulted for a pool plan.
            let report = analyze_cell(
                &topo,
                trace,
                lambda_rps,
                Arc::new(ManualProfile::for_gpu(gpus[0])),
                lbar,
                rho,
                ttft_slo_s,
                acct,
            );
            out.push(PartitionOptResult {
                cutoffs: cutoffs.clone(),
                gpus: gpus.clone(),
                gamma,
                report,
            });
        }
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    out
}

/// Stage A over the legacy (B_short × γ) two-pool grid — a wrapper that
/// lifts each boundary into the `[b, LONG_CTX]` partition vector and
/// delegates to [`screen_partitions`], so the legacy ranking and the
/// K-pool ranking are the same arithmetic by construction.
#[allow(clippy::too_many_arguments)]
pub fn screen_closed_form(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    b_shorts: &[u32],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<OptResult> {
    let partitions: Vec<Vec<u32>> = b_shorts
        .iter()
        .map(|&b| {
            // The boundary becomes the [b, LONG_CTX] partition vector;
            // reject a degenerate b up front with the legacy axis's own
            // vocabulary instead of a partition-invariant panic deep in
            // the screen.
            assert!(
                (1..LONG_CTX).contains(&b),
                "B_short {b} must be in 1..{LONG_CTX} (the two-pool split \
                 needs a boundary below the long window)"
            );
            vec![b, LONG_CTX]
        })
        .collect();
    screen_partitions(
        trace, lambda_rps, profile, &partitions, gammas, lbar, rho,
        ttft_slo_s, acct,
    )
    .into_iter()
    .map(|r| OptResult { b_short: r.cutoffs[0], gamma: r.gamma, report: r.report })
    .collect()
}

/// Constraint for the budgeted-upgrade search ([`GpuAxis::Budget`]):
/// "I can afford `max_groups` groups of `to` — which pools should get
/// them?"
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UpgradeBudget {
    /// Generation the upgraded pools move to (`--upgrade-to`).
    pub to: Gpu,
    /// Ceiling on total upgraded groups, counted by the analytical
    /// plan's per-pool sizing (`--upgrade-budget`).
    pub max_groups: u32,
}

/// How stage A explores the GPU-generation axis.
#[derive(Debug, Clone, Default)]
pub enum GpuAxis {
    /// One fleet-wide GPU per cell, swept over `gpus` — the legacy
    /// axis, and the only one before heterogeneous fleets landed.
    #[default]
    Homogeneous,
    /// The homogeneous cells **plus** every mixed per-pool assignment
    /// over `gpus`, for partitions of K ≤ 3 pools (the full
    /// cross-product; |gpus|^K cells per partition beyond that is grid
    /// explosion, and the budgeted mode covers large K greedily).
    Mixed,
    /// The homogeneous cells plus these explicit per-pool vectors, each
    /// applied to every screened partition with a matching pool count
    /// (`--gpu h100,h100,b200` on the CLI).
    Explicit(Vec<Vec<Gpu>>),
    /// The homogeneous cells plus a greedily grown budgeted-upgrade
    /// path per (partition, γ): starting from an all-`gpus[0]` fleet,
    /// repeatedly upgrade the pool with the best marginal Eq. 4 tok/W
    /// per upgraded group while the budget holds, screening every step
    /// of the path (`--upgrade-budget N --upgrade-to b200`).
    Budget(UpgradeBudget),
}

/// Grid axes and per-cell settings for the two-stage search.
#[derive(Debug, Clone)]
pub struct OptimizeConfig {
    /// GPU-generation axis (each served by its calibrated/projected 70B
    /// fleet profile, [`ManualProfile::for_gpu`]).
    pub gpus: Vec<Gpu>,
    /// Split-boundary axis (legacy two-pool grid). Ignored when
    /// `partitions` is non-empty.
    pub b_shorts: Vec<u32>,
    /// K-pool partition-vector axis: each entry is a sorted cutoff
    /// vector whose last element is the long pool's window (e.g.
    /// `[4096, 16384, 65536]` for K=3). Empty = derive the classic
    /// `[b, LONG_CTX]` two-pool vectors from `b_shorts`
    /// ([`Self::effective_partitions`]); [`kpool_partitions`] generates
    /// full grids for K ∈ {2, 3, 4}, `--pools K` on the CLI.
    pub partitions: Vec<Vec<u32>>,
    /// How the GPU-generation axis is explored: homogeneous fleets
    /// only (legacy), the full mixed cross-product, explicit per-pool
    /// assignment vectors, or the greedy budgeted-upgrade search.
    pub gpu_axis: GpuAxis,
    /// FleetOpt compression-factor axis (applies to the last pool).
    pub gammas: Vec<f64>,
    /// Dispatch axis — resolved by measurement in stage B only (the
    /// closed form is dispatch-blind).
    pub dispatches: Vec<String>,
    /// Traffic for stage B (`lambda_rps` also feeds stage A's sizing).
    pub gen: GenConfig,
    /// Simulated TP groups per stage-B cell.
    pub groups: u32,
    pub slo: SloTargets,
    pub lbar: LBarPolicy,
    pub rho: f64,
    pub acct: PowerAccounting,
    /// Analytical cells surviving into stage B.
    pub top_k: usize,
}

impl Default for OptimizeConfig {
    fn default() -> Self {
        OptimizeConfig {
            gpus: Gpu::ALL.to_vec(),
            b_shorts: B_SHORT_GRID.to_vec(),
            partitions: Vec::new(),
            gpu_axis: GpuAxis::Homogeneous,
            gammas: GAMMA_GRID.to_vec(),
            dispatches: dispatch::ALL.iter().map(|s| s.to_string()).collect(),
            gen: GenConfig {
                lambda_rps: 1000.0,
                duration_s: 1.0,
                max_prompt_tokens: 60_000,
                max_output_tokens: 512,
                seed: 42,
            },
            groups: 8,
            slo: SloTargets::default(),
            lbar: LBarPolicy::Window,
            rho: 0.85,
            acct: PowerAccounting::PerGpu,
            top_k: 4,
        }
    }
}

impl OptimizeConfig {
    /// The partition-vector axis actually screened: the explicit
    /// `partitions` when set, otherwise the legacy `[b, LONG_CTX]`
    /// two-pool vector per `b_shorts` entry.
    pub fn effective_partitions(&self) -> Vec<Vec<u32>> {
        if self.partitions.is_empty() {
            self.b_shorts
                .iter()
                .map(|&b| {
                    assert!(
                        (1..LONG_CTX).contains(&b),
                        "B_short {b} must be in 1..{LONG_CTX} (the two-pool \
                         split needs a boundary below the long window)"
                    );
                    vec![b, LONG_CTX]
                })
                .collect()
        } else {
            self.partitions.clone()
        }
    }
}

/// One stage-A cell: analytical Eq. (4) report at
/// (GPU assignment, partition vector, γ).
#[derive(Debug, Clone)]
pub struct ScreenedCell {
    /// The fleet-default generation (the scenario's `gpu`; for a mixed
    /// cell, the base the assignment was grown from).
    pub gpu: Gpu,
    /// Sorted cutoff vector of the cell's K-pool partition; for the
    /// legacy two-pool grid this is `[B_short, LONG_CTX]`.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment, one generation per cutoff (all equal to
    /// `gpu` for homogeneous cells).
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub analytic: FleetReport,
}

impl ScreenedCell {
    /// The first cutoff — the legacy B_short boundary at K=2.
    pub fn b_short(&self) -> u32 {
        self.cutoffs[0]
    }

    /// True when the cell serves more than one GPU generation.
    pub fn is_mixed(&self) -> bool {
        self.gpus.windows(2).any(|w| w[0] != w[1])
    }
}

/// One stage-B cell: the screened point expanded with a dispatch policy
/// and replayed through the event-driven simulator.
#[derive(Debug, Clone)]
pub struct RefinedCell {
    /// The fleet-default generation (see [`ScreenedCell::gpu`]).
    pub gpu: Gpu,
    /// Sorted cutoff vector of the cell's K-pool partition.
    pub cutoffs: Vec<u32>,
    /// Per-pool GPU assignment, one generation per cutoff.
    pub gpus: Vec<Gpu>,
    pub gamma: f64,
    pub dispatch: String,
    /// Stage-A analytical tok/W (Eq. 4).
    pub analytic_tok_w: f64,
    /// Stage-A analytical group count.
    pub analytic_groups: u64,
    /// Stage-B measured outcome.
    pub outcome: ScenarioOutcome,
}

impl RefinedCell {
    /// Measured-vs-analytical relative delta, percent
    /// ([`super::rel_delta_pct`], shared with the sweep records).
    pub fn rel_delta_pct(&self) -> f64 {
        super::rel_delta_pct(self.outcome.tok_per_watt, self.analytic_tok_w)
    }

    /// The first cutoff — the legacy B_short boundary at K=2.
    pub fn b_short(&self) -> u32 {
        self.cutoffs[0]
    }
}

/// `"4096|65536"`-style display of a cutoff vector — the one rendering
/// every CLI surface (optimize rowset, K-pool sweep) uses.
pub fn cutoffs_label(cutoffs: &[u32]) -> String {
    cutoffs
        .iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join("|")
}

/// Every mixed per-pool assignment over `gpus` for partitions of K ≤ 3
/// pools, in deterministic lexicographic order (homogeneous vectors are
/// skipped — the legacy per-fleet axis already screens them).
fn mixed_assignments(
    partitions: &[Vec<u32>],
    gpus: &[Gpu],
) -> Vec<(Vec<u32>, Vec<Gpu>)> {
    let n = gpus.len();
    let mut out = Vec::new();
    if n < 2 {
        return out;
    }
    for cuts in partitions {
        let k = cuts.len() as u32;
        if k > 3 {
            continue;
        }
        for code in 0..n.pow(k) {
            let mut v = Vec::with_capacity(k as usize);
            let mut c = code;
            for _ in 0..k {
                v.push(gpus[c % n]);
                c /= n;
            }
            v.reverse();
            if v.windows(2).all(|w| w[0] == w[1]) {
                continue;
            }
            out.push((cuts.clone(), v));
        }
    }
    out
}

/// Each explicit assignment vector paired with every partition whose
/// pool count matches its length.
fn explicit_assignments(
    partitions: &[Vec<u32>],
    vectors: &[Vec<Gpu>],
) -> Vec<(Vec<u32>, Vec<Gpu>)> {
    let mut out = Vec::new();
    for cuts in partitions {
        for v in vectors {
            if v.len() == cuts.len() {
                out.push((cuts.clone(), v.clone()));
            }
        }
    }
    out
}

/// The greedy budgeted-upgrade path for one config: per (partition, γ),
/// start from the all-`base` fleet (already screened by the homogeneous
/// axis) and repeatedly upgrade the pool with the best marginal Eq. 4
/// tok/W per upgraded group, while total upgraded groups — by the
/// analytical plan's sizing — stay within the budget. Every step of the
/// path becomes a screened cell, so the report shows the whole
/// placement curve, not just its endpoint.
fn budget_cells(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    partitions: &[Vec<u32>],
    budget: UpgradeBudget,
) -> Vec<ScreenedCell> {
    let base = cfg.gpus.first().copied().unwrap_or(Gpu::H100);
    let eval = |cuts: &[u32], gpus: &[Gpu], gamma: f64| {
        analyze_cell(
            &Topology::partition_with_gpus(cuts, gpus, gamma),
            workload,
            cfg.gen.lambda_rps,
            Arc::new(ManualProfile::for_gpu(base)),
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
        )
    };
    let mut cells = Vec::new();
    for cuts in partitions {
        for &gamma in &cfg.gammas {
            let k = cuts.len();
            let mut current = vec![base; k];
            let mut cur_tok_w =
                eval(cuts, &current, gamma).tok_per_watt.0;
            loop {
                // (pool, report, marginal tok/W per upgraded group)
                let mut best: Option<(usize, FleetReport, f64)> = None;
                for i in 0..k {
                    if current[i] == budget.to {
                        continue;
                    }
                    let mut cand = current.clone();
                    cand[i] = budget.to;
                    let rep = eval(cuts, &cand, gamma);
                    let upgraded: u64 = rep
                        .pools
                        .iter()
                        .zip(&cand)
                        .filter(|(_, g)| **g == budget.to)
                        .map(|(p, _)| p.sizing.groups)
                        .sum();
                    if upgraded > budget.max_groups as u64 {
                        continue;
                    }
                    let gain = rep.tok_per_watt.0 - cur_tok_w;
                    if gain <= 0.0 {
                        continue;
                    }
                    let marginal =
                        gain / rep.pools[i].sizing.groups.max(1) as f64;
                    let better = match &best {
                        None => true,
                        Some((_, _, m)) => marginal > *m,
                    };
                    if better {
                        best = Some((i, rep, marginal));
                    }
                }
                let Some((i, rep, _)) = best else { break };
                current[i] = budget.to;
                cur_tok_w = rep.tok_per_watt.0;
                cells.push(ScreenedCell {
                    gpu: base,
                    cutoffs: cuts.clone(),
                    gpus: current.clone(),
                    gamma,
                    analytic: rep,
                });
            }
        }
    }
    cells
}

/// Stage A: screen the full GPU-assignment × partition × γ grid
/// analytically, best-first (ties keep grid order). The homogeneous
/// per-fleet axis is always screened; [`GpuAxis`] adds mixed, explicit
/// or budgeted-upgrade assignment cells on top.
pub fn screen(workload: &WorkloadTrace, cfg: &OptimizeConfig) -> Vec<ScreenedCell> {
    let partitions = cfg.effective_partitions();
    let mut cells =
        Vec::with_capacity(cfg.gpus.len() * partitions.len() * cfg.gammas.len());
    for &gpu in &cfg.gpus {
        let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
        for r in screen_partitions(
            workload,
            cfg.gen.lambda_rps,
            profile,
            &partitions,
            &cfg.gammas,
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
        ) {
            cells.push(ScreenedCell {
                gpu,
                gpus: vec![gpu; r.cutoffs.len()],
                cutoffs: r.cutoffs,
                gamma: r.gamma,
                analytic: r.report,
            });
        }
    }
    let hetero = match &cfg.gpu_axis {
        GpuAxis::Homogeneous | GpuAxis::Budget(_) => Vec::new(),
        GpuAxis::Mixed => mixed_assignments(&partitions, &cfg.gpus),
        GpuAxis::Explicit(vectors) => {
            explicit_assignments(&partitions, vectors)
        }
    };
    if !hetero.is_empty() {
        for r in screen_assignments(
            workload,
            cfg.gen.lambda_rps,
            &hetero,
            &cfg.gammas,
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
        ) {
            cells.push(ScreenedCell {
                gpu: r.gpus[0],
                cutoffs: r.cutoffs,
                gpus: r.gpus,
                gamma: r.gamma,
                analytic: r.report,
            });
        }
    }
    if let GpuAxis::Budget(b) = &cfg.gpu_axis {
        cells.extend(budget_cells(workload, cfg, &partitions, *b));
    }
    cells.sort_by(|a, b| {
        b.analytic.tok_per_watt.0.total_cmp(&a.analytic.tok_per_watt.0)
    });
    cells
}

/// The [`ScenarioSpec`] realizing one screened cell at serving time.
/// For a two-entry cutoff vector this builds the same routed fleet as
/// the PR 3 `Topology::FleetOpt` spec, bit-for-bit (the K=2 reduction).
/// Every cell — mixed or homogeneous — goes through the per-pool
/// override path, so a pool overridden to the fleet default is
/// bit-identical to no override at all (the homogeneous-reduction
/// oracle in `tests/optimize_oracle.rs` pins this).
fn spec_for(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    cell: &ScreenedCell,
    dispatch: &str,
) -> ScenarioSpec {
    ScenarioSpec::new(
        Topology::partition_with_gpus(&cell.cutoffs, &cell.gpus, cell.gamma),
        cell.gpu,
        workload.clone(),
        cfg.gen.clone(),
    )
    .with_groups(cfg.groups)
    .with_dispatch(dispatch)
    .with_slo(cfg.slo)
    .with_lbar(cfg.lbar)
    .with_rho(cfg.rho)
}

/// Stage B: expand the surviving cells across the dispatch axis, replay
/// each through the event engine on `workers` scoped threads, and
/// re-rank by measured tok/W — SLO-passing cells strictly first.
pub fn refine(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    survivors: &[ScreenedCell],
    workers: usize,
) -> Vec<RefinedCell> {
    let mut specs = Vec::with_capacity(survivors.len() * cfg.dispatches.len());
    let mut meta = Vec::with_capacity(specs.capacity());
    for cell in survivors {
        for d in &cfg.dispatches {
            specs.push(spec_for(workload, cfg, cell, d));
            meta.push((cell, d.clone()));
        }
    }
    let outcomes = sweep::run(&specs, workers);
    let mut refined: Vec<RefinedCell> = meta
        .into_iter()
        .zip(outcomes)
        .map(|((cell, dispatch), outcome)| RefinedCell {
            gpu: cell.gpu,
            cutoffs: cell.cutoffs.clone(),
            gpus: cell.gpus.clone(),
            gamma: cell.gamma,
            dispatch,
            analytic_tok_w: cell.analytic.tok_per_watt.0,
            analytic_groups: cell.analytic.total_groups,
            outcome,
        })
        .collect();
    refined.sort_by(|a, b| {
        b.outcome
            .slo_ok
            .cmp(&a.outcome.slo_ok)
            .then(b.outcome.tok_per_watt.total_cmp(&a.outcome.tok_per_watt))
    });
    refined
}

/// The full two-stage search.
pub fn optimize(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    workers: usize,
) -> OptimizeReport {
    let screened = screen(workload, cfg);
    let k = cfg.top_k.max(1).min(screened.len());
    let refined = refine(workload, cfg, &screened[..k], workers);
    OptimizeReport { screened, refined }
}

/// Everything the search produced: the full stage-A ranking plus the
/// stage-B refinements (measured-rank order, SLO-passing cells first).
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    pub screened: Vec<ScreenedCell>,
    pub refined: Vec<RefinedCell>,
}

impl OptimizeReport {
    /// The best *measured* cell that meets the SLO — the hard filter:
    /// `None` when every refined cell violates it.
    pub fn winner(&self) -> Option<&RefinedCell> {
        self.refined.first().filter(|c| c.outcome.slo_ok)
    }

    /// The refined cells as one typed table: stage-A analytical and
    /// stage-B simulated tok/W side by side for every cell.
    pub fn rowset(&self) -> RowSet {
        let mut rs = RowSet::new(
            "FleetOpt optimization — stage A analytical screen, \
             stage B simulated refine",
            vec![
                Column::str("GPU"),
                Column::int("pools"),
                Column::str("cutoffs").with_unit("tok"),
                Column::float("gamma"),
                Column::str("dispatch"),
                Column::float("analyze tok/W").with_unit("tok/J"),
                Column::float("simulate tok/W").with_unit("tok/J"),
                Column::float("delta").with_unit("%"),
                Column::float("p99 TTFT").with_unit("s"),
                Column::str("slo"),
                Column::int("analyze groups"),
                Column::str("winner"),
            ],
        );
        let winner_idx = if self.winner().is_some() { Some(0) } else { None };
        for (i, c) in self.refined.iter().enumerate() {
            let delta = c.rel_delta_pct();
            rs.push(vec![
                Cell::str(assignment_label(&c.gpus)),
                Cell::int(c.cutoffs.len() as i64),
                Cell::str(cutoffs_label(&c.cutoffs)),
                Cell::float(c.gamma),
                Cell::str(&c.dispatch),
                Cell::float(c.analytic_tok_w)
                    .shown(format!("{:.3}", c.analytic_tok_w)),
                Cell::float(c.outcome.tok_per_watt)
                    .shown(format!("{:.3}", c.outcome.tok_per_watt)),
                Cell::float(delta).shown(format!("{delta:+.1}%")),
                Cell::float(c.outcome.p99_ttft_s)
                    .shown(format!("{:.3}", c.outcome.p99_ttft_s)),
                Cell::str(if c.outcome.slo_ok { "pass" } else { "MISS" }),
                Cell::int(c.analytic_groups as i64),
                Cell::str(if winner_idx == Some(i) { "*" } else { "" }),
            ]);
        }
        rs.note(format!(
            "stage A screened {} analytical cells; top {} refined across {} \
             dispatch polic{} through the event-driven simulator",
            self.screened.len(),
            self.refined.len() / self.dispatch_count().max(1),
            self.dispatch_count(),
            if self.dispatch_count() == 1 { "y" } else { "ies" },
        ));
        match self.winner() {
            Some(w) => rs.note(format!(
                "winner (best measured tok/W within SLO): {} cutoffs={} γ={} \
                 dispatch={} at {:.3} tok/W (analytical said {:.3})",
                assignment_label(&w.gpus),
                cutoffs_label(&w.cutoffs),
                w.gamma,
                w.dispatch,
                w.outcome.tok_per_watt,
                w.analytic_tok_w,
            )),
            None => rs.note(
                "no refined cell met the p99 TTFT SLO — no winner \
                 (widen the grid, relax the SLO, or add capacity)",
            ),
        };
        rs
    }

    fn dispatch_count(&self) -> usize {
        let mut names: Vec<&str> =
            self.refined.iter().map(|c| c.dispatch.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn tiny_cfg() -> OptimizeConfig {
        OptimizeConfig {
            gpus: vec![Gpu::H100],
            b_shorts: vec![2048, 4096],
            gammas: vec![1.0, 2.0],
            dispatches: vec!["rr".into()],
            gen: GenConfig {
                lambda_rps: 120.0,
                duration_s: 0.5,
                max_prompt_tokens: 20_000,
                max_output_tokens: 64,
                seed: 7,
            },
            groups: 2,
            // Generous SLO so the mechanics (not the latency magnitudes)
            // are under test.
            slo: SloTargets { ttft_p99_s: 1e3 },
            top_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn screen_covers_the_grid_best_first() {
        let cells = screen(&azure_conversations(), &tiny_cfg());
        assert_eq!(cells.len(), 4);
        for w in cells.windows(2) {
            assert!(
                w[0].analytic.tok_per_watt.0 >= w[1].analytic.tok_per_watt.0
            );
        }
        // γ=2 compression always beats γ=1 at the same boundary here.
        assert_eq!(cells[0].gamma, 2.0);
    }

    #[test]
    fn optimize_pairs_analytical_and_measured_per_cell() {
        let cfg = tiny_cfg();
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert_eq!(report.refined.len(), cfg.top_k * cfg.dispatches.len());
        for c in &report.refined {
            assert!(c.analytic_tok_w > 0.0);
            assert!(c.outcome.completed > 0);
            assert!(c.rel_delta_pct().is_finite());
        }
        let w = report.winner().expect("generous SLO must yield a winner");
        assert!(w.outcome.slo_ok);
        // The winner leads the measured ranking.
        assert!(std::ptr::eq(w, &report.refined[0]));
    }

    #[test]
    fn slo_is_a_hard_filter_for_the_winner() {
        let cfg = OptimizeConfig {
            slo: SloTargets { ttft_p99_s: 1e-9 },
            ..tiny_cfg()
        };
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert!(!report.refined.is_empty());
        assert!(report.refined.iter().all(|c| !c.outcome.slo_ok));
        assert!(report.winner().is_none(), "impossible SLO ⇒ no winner");
        let rs = report.rowset();
        assert!(rs.to_text().contains("no refined cell met"));
    }

    #[test]
    fn kpool_partitions_enumerate_the_ladder() {
        let k2 = kpool_partitions(2);
        assert_eq!(k2.len(), CUTOFF_LADDER.len());
        assert_eq!(k2[0], vec![1024, crate::fleet::topology::LONG_CTX]);
        let k3 = kpool_partitions(3);
        assert_eq!(k3.len(), 15, "C(6,2) interior pairs");
        let k4 = kpool_partitions(4);
        assert_eq!(k4.len(), 20, "C(6,3) interior triples");
        for cuts in k2.iter().chain(&k3).chain(&k4) {
            assert!(cuts.windows(2).all(|w| w[0] < w[1]), "{cuts:?}");
            assert_eq!(
                *cuts.last().unwrap(),
                crate::fleet::topology::LONG_CTX
            );
        }
    }

    #[test]
    fn kpool_grid_screens_and_refines_end_to_end() {
        let cfg = OptimizeConfig {
            partitions: vec![
                vec![4096, crate::fleet::topology::LONG_CTX],
                vec![2048, 8192, crate::fleet::topology::LONG_CTX],
            ],
            gammas: vec![1.0],
            groups: 4,
            ..tiny_cfg()
        };
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert_eq!(report.screened.len(), 2);
        assert_eq!(report.refined.len(), 2);
        assert!(report
            .screened
            .iter()
            .any(|c| c.cutoffs.len() == 3), "K=3 cell screened");
        let w = report.winner().expect("generous SLO yields a winner");
        assert!(w.outcome.completed > 0);
        let rs = report.rowset();
        assert!(rs.to_text().contains("2048|8192|65536"));
    }

    #[test]
    fn rowset_shows_both_engines_side_by_side() {
        let report = optimize(&azure_conversations(), &tiny_cfg(), 2);
        let rs = report.rowset();
        let csv = rs.to_csv();
        assert!(csv.starts_with(
            "GPU,pools,cutoffs (tok),gamma,dispatch,analyze tok/W (tok/J),\
             simulate tok/W (tok/J),delta (%),p99 TTFT (s),slo,\
             analyze groups,winner\n"
        ));
        let doc = crate::runtime::json::parse(&rs.to_json()).unwrap();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), report.refined.len());
        for r in rows {
            assert!(r.get("analyze tok/W").unwrap().as_f64().is_some());
            assert!(r.get("simulate tok/W").unwrap().as_f64().is_some());
        }
        // Winner marked on the first (SLO-passing) row.
        assert_eq!(rows[0].get("winner").unwrap().as_str(), Some("*"));
        assert_eq!(rows[0].get("slo").unwrap().as_str(), Some("pass"));
    }
}
