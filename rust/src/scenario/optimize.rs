//! The scenario-native FleetOpt optimizer: a two-stage search over
//! [`ScenarioSpec`] space — `wattlaw optimize`.
//!
//! FleetOpt (Chen et al. 2026) frames provisioning as an
//! analytical-search-then-validate loop, and SweetSpot (Pizzini Cavagna
//! et al. 2026) shows why the analytical screen and the measured check
//! must be cross-tabulated per operating point. This module is that
//! loop over the crate's own two engines:
//!
//! * **Stage A — analytical screen.** The full
//!   B_short × γ × GPU-generation grid is evaluated with the closed-form
//!   Eq. (4) planner ([`ScenarioSpec::analyze`]; dispatch does not enter
//!   the closed form, so each analytical cell is screened once). Cheap:
//!   hundreds of cells per millisecond, so the grid can be wide.
//! * **Stage B — simulated refine.** The top-k surviving cells are
//!   expanded across the dispatch axis and replayed through
//!   [`ScenarioSpec::simulate`] on scoped worker threads
//!   ([`sweep::run`]), then re-ranked by *measured* tok/W with the
//!   p99-TTFT SLO verdict as a hard filter: an SLO-violating cell can
//!   appear in the report but can never be the winner.
//!
//! The legacy closed-form sweep (`fleet::optimizer::sweep_fleetopt`)
//! is now a thin wrapper over this module's [`screen_closed_form`], so
//! both paths rank by the same arithmetic — the regression oracle in
//! `tests/optimize_oracle.rs` holds them together.

use std::sync::Arc;

use super::{sweep, ScenarioOutcome, ScenarioSpec, SloTargets};
use crate::fleet::analysis::{fleet_tpw_analysis, FleetReport};
use crate::fleet::optimizer::{OptResult, B_SHORT_GRID, GAMMA_GRID};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use crate::fleet::topology::Topology;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::sim::dispatch;
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;

/// Closed-form evaluation of one (topology, profile) cell — the single
/// Eq. (4) path behind [`ScenarioSpec::analyze`], the stage-A screen,
/// and the legacy `fleet::optimizer` wrapper.
#[allow(clippy::too_many_arguments)]
pub fn analyze_cell(
    topology: &Topology,
    workload: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> FleetReport {
    let pools =
        topology.pools(workload, lambda_rps, profile, None, lbar, rho, ttft_slo_s);
    fleet_tpw_analysis(&pools, acct)
}

/// Stage A over an explicit (B_short × γ) grid with an arbitrary
/// profile, best-first. Kept profile-generic (not `Gpu`-keyed) so the
/// legacy `sweep_fleetopt` API — which accepts any [`GpuProfile`] —
/// can delegate here without loss of generality.
#[allow(clippy::too_many_arguments)]
pub fn screen_closed_form(
    trace: &WorkloadTrace,
    lambda_rps: f64,
    profile: Arc<dyn GpuProfile>,
    b_shorts: &[u32],
    gammas: &[f64],
    lbar: LBarPolicy,
    rho: f64,
    ttft_slo_s: f64,
    acct: PowerAccounting,
) -> Vec<OptResult> {
    let mut out = Vec::with_capacity(b_shorts.len() * gammas.len());
    for &b_short in b_shorts {
        for &gamma in gammas {
            let topo = Topology::FleetOpt {
                b_short,
                short_ctx: b_short.max(1024),
                gamma,
            };
            let report = analyze_cell(
                &topo,
                trace,
                lambda_rps,
                profile.clone(),
                lbar,
                rho,
                ttft_slo_s,
                acct,
            );
            out.push(OptResult { b_short, gamma, report });
        }
    }
    out.sort_by(|a, b| {
        b.report.tok_per_watt.0.total_cmp(&a.report.tok_per_watt.0)
    });
    out
}

/// Grid axes and per-cell settings for the two-stage search.
#[derive(Debug, Clone)]
pub struct OptimizeConfig {
    /// GPU-generation axis (each served by its calibrated/projected 70B
    /// fleet profile, [`ManualProfile::for_gpu`]).
    pub gpus: Vec<Gpu>,
    /// Split-boundary axis.
    pub b_shorts: Vec<u32>,
    /// FleetOpt compression-factor axis.
    pub gammas: Vec<f64>,
    /// Dispatch axis — resolved by measurement in stage B only (the
    /// closed form is dispatch-blind).
    pub dispatches: Vec<String>,
    /// Traffic for stage B (`lambda_rps` also feeds stage A's sizing).
    pub gen: GenConfig,
    /// Simulated TP groups per stage-B cell.
    pub groups: u32,
    pub slo: SloTargets,
    pub lbar: LBarPolicy,
    pub rho: f64,
    pub acct: PowerAccounting,
    /// Analytical cells surviving into stage B.
    pub top_k: usize,
}

impl Default for OptimizeConfig {
    fn default() -> Self {
        OptimizeConfig {
            gpus: Gpu::ALL.to_vec(),
            b_shorts: B_SHORT_GRID.to_vec(),
            gammas: GAMMA_GRID.to_vec(),
            dispatches: dispatch::ALL.iter().map(|s| s.to_string()).collect(),
            gen: GenConfig {
                lambda_rps: 1000.0,
                duration_s: 1.0,
                max_prompt_tokens: 60_000,
                max_output_tokens: 512,
                seed: 42,
            },
            groups: 8,
            slo: SloTargets::default(),
            lbar: LBarPolicy::Window,
            rho: 0.85,
            acct: PowerAccounting::PerGpu,
            top_k: 4,
        }
    }
}

/// One stage-A cell: analytical Eq. (4) report at (GPU, B_short, γ).
#[derive(Debug, Clone)]
pub struct ScreenedCell {
    pub gpu: Gpu,
    pub b_short: u32,
    pub gamma: f64,
    pub analytic: FleetReport,
}

/// One stage-B cell: the screened point expanded with a dispatch policy
/// and replayed through the event-driven simulator.
#[derive(Debug, Clone)]
pub struct RefinedCell {
    pub gpu: Gpu,
    pub b_short: u32,
    pub gamma: f64,
    pub dispatch: String,
    /// Stage-A analytical tok/W (Eq. 4).
    pub analytic_tok_w: f64,
    /// Stage-A analytical group count.
    pub analytic_groups: u64,
    /// Stage-B measured outcome.
    pub outcome: ScenarioOutcome,
}

impl RefinedCell {
    /// Measured-vs-analytical relative delta, percent
    /// ([`super::rel_delta_pct`], shared with the sweep records).
    pub fn rel_delta_pct(&self) -> f64 {
        super::rel_delta_pct(self.outcome.tok_per_watt, self.analytic_tok_w)
    }
}

/// Stage A: screen the full GPU × B_short × γ grid analytically,
/// best-first (ties keep grid order).
pub fn screen(workload: &WorkloadTrace, cfg: &OptimizeConfig) -> Vec<ScreenedCell> {
    let mut cells =
        Vec::with_capacity(cfg.gpus.len() * cfg.b_shorts.len() * cfg.gammas.len());
    for &gpu in &cfg.gpus {
        let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
        for r in screen_closed_form(
            workload,
            cfg.gen.lambda_rps,
            profile,
            &cfg.b_shorts,
            &cfg.gammas,
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
        ) {
            cells.push(ScreenedCell {
                gpu,
                b_short: r.b_short,
                gamma: r.gamma,
                analytic: r.report,
            });
        }
    }
    cells.sort_by(|a, b| {
        b.analytic.tok_per_watt.0.total_cmp(&a.analytic.tok_per_watt.0)
    });
    cells
}

/// The [`ScenarioSpec`] realizing one screened cell at serving time.
fn spec_for(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    cell: &ScreenedCell,
    dispatch: &str,
) -> ScenarioSpec {
    ScenarioSpec::new(
        Topology::FleetOpt {
            b_short: cell.b_short,
            short_ctx: cell.b_short.max(1024),
            gamma: cell.gamma,
        },
        cell.gpu,
        workload.clone(),
        cfg.gen.clone(),
    )
    .with_groups(cfg.groups)
    .with_dispatch(dispatch)
    .with_slo(cfg.slo)
    .with_lbar(cfg.lbar)
    .with_rho(cfg.rho)
}

/// Stage B: expand the surviving cells across the dispatch axis, replay
/// each through the event engine on `workers` scoped threads, and
/// re-rank by measured tok/W — SLO-passing cells strictly first.
pub fn refine(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    survivors: &[ScreenedCell],
    workers: usize,
) -> Vec<RefinedCell> {
    let mut specs = Vec::with_capacity(survivors.len() * cfg.dispatches.len());
    let mut meta = Vec::with_capacity(specs.capacity());
    for cell in survivors {
        for d in &cfg.dispatches {
            specs.push(spec_for(workload, cfg, cell, d));
            meta.push((cell, d.clone()));
        }
    }
    let outcomes = sweep::run(&specs, workers);
    let mut refined: Vec<RefinedCell> = meta
        .into_iter()
        .zip(outcomes)
        .map(|((cell, dispatch), outcome)| RefinedCell {
            gpu: cell.gpu,
            b_short: cell.b_short,
            gamma: cell.gamma,
            dispatch,
            analytic_tok_w: cell.analytic.tok_per_watt.0,
            analytic_groups: cell.analytic.total_groups,
            outcome,
        })
        .collect();
    refined.sort_by(|a, b| {
        b.outcome
            .slo_ok
            .cmp(&a.outcome.slo_ok)
            .then(b.outcome.tok_per_watt.total_cmp(&a.outcome.tok_per_watt))
    });
    refined
}

/// The full two-stage search.
pub fn optimize(
    workload: &WorkloadTrace,
    cfg: &OptimizeConfig,
    workers: usize,
) -> OptimizeReport {
    let screened = screen(workload, cfg);
    let k = cfg.top_k.max(1).min(screened.len());
    let refined = refine(workload, cfg, &screened[..k], workers);
    OptimizeReport { screened, refined }
}

/// Everything the search produced: the full stage-A ranking plus the
/// stage-B refinements (measured-rank order, SLO-passing cells first).
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    pub screened: Vec<ScreenedCell>,
    pub refined: Vec<RefinedCell>,
}

impl OptimizeReport {
    /// The best *measured* cell that meets the SLO — the hard filter:
    /// `None` when every refined cell violates it.
    pub fn winner(&self) -> Option<&RefinedCell> {
        self.refined.first().filter(|c| c.outcome.slo_ok)
    }

    /// The refined cells as one typed table: stage-A analytical and
    /// stage-B simulated tok/W side by side for every cell.
    pub fn rowset(&self) -> RowSet {
        let mut rs = RowSet::new(
            "FleetOpt optimization — stage A analytical screen, \
             stage B simulated refine",
            vec![
                Column::str("GPU"),
                Column::int("B_short").with_unit("tok"),
                Column::float("gamma"),
                Column::str("dispatch"),
                Column::float("analyze tok/W").with_unit("tok/J"),
                Column::float("simulate tok/W").with_unit("tok/J"),
                Column::float("delta").with_unit("%"),
                Column::float("p99 TTFT").with_unit("s"),
                Column::str("slo"),
                Column::int("analyze groups"),
                Column::str("winner"),
            ],
        );
        let winner_idx = if self.winner().is_some() { Some(0) } else { None };
        for (i, c) in self.refined.iter().enumerate() {
            let delta = c.rel_delta_pct();
            rs.push(vec![
                Cell::str(c.gpu.spec().name),
                Cell::int(c.b_short as i64),
                Cell::float(c.gamma),
                Cell::str(&c.dispatch),
                Cell::float(c.analytic_tok_w)
                    .shown(format!("{:.3}", c.analytic_tok_w)),
                Cell::float(c.outcome.tok_per_watt)
                    .shown(format!("{:.3}", c.outcome.tok_per_watt)),
                Cell::float(delta).shown(format!("{delta:+.1}%")),
                Cell::float(c.outcome.p99_ttft_s)
                    .shown(format!("{:.3}", c.outcome.p99_ttft_s)),
                Cell::str(if c.outcome.slo_ok { "pass" } else { "MISS" }),
                Cell::int(c.analytic_groups as i64),
                Cell::str(if winner_idx == Some(i) { "*" } else { "" }),
            ]);
        }
        rs.note(format!(
            "stage A screened {} analytical cells; top {} refined across {} \
             dispatch polic{} through the event-driven simulator",
            self.screened.len(),
            self.refined.len() / self.dispatch_count().max(1),
            self.dispatch_count(),
            if self.dispatch_count() == 1 { "y" } else { "ies" },
        ));
        match self.winner() {
            Some(w) => rs.note(format!(
                "winner (best measured tok/W within SLO): {} B_short={} γ={} \
                 dispatch={} at {:.3} tok/W (analytical said {:.3})",
                w.gpu.spec().name,
                w.b_short,
                w.gamma,
                w.dispatch,
                w.outcome.tok_per_watt,
                w.analytic_tok_w,
            )),
            None => rs.note(
                "no refined cell met the p99 TTFT SLO — no winner \
                 (widen the grid, relax the SLO, or add capacity)",
            ),
        };
        rs
    }

    fn dispatch_count(&self) -> usize {
        let mut names: Vec<&str> =
            self.refined.iter().map(|c| c.dispatch.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn tiny_cfg() -> OptimizeConfig {
        OptimizeConfig {
            gpus: vec![Gpu::H100],
            b_shorts: vec![2048, 4096],
            gammas: vec![1.0, 2.0],
            dispatches: vec!["rr".into()],
            gen: GenConfig {
                lambda_rps: 120.0,
                duration_s: 0.5,
                max_prompt_tokens: 20_000,
                max_output_tokens: 64,
                seed: 7,
            },
            groups: 2,
            // Generous SLO so the mechanics (not the latency magnitudes)
            // are under test.
            slo: SloTargets { ttft_p99_s: 1e3 },
            top_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn screen_covers_the_grid_best_first() {
        let cells = screen(&azure_conversations(), &tiny_cfg());
        assert_eq!(cells.len(), 4);
        for w in cells.windows(2) {
            assert!(
                w[0].analytic.tok_per_watt.0 >= w[1].analytic.tok_per_watt.0
            );
        }
        // γ=2 compression always beats γ=1 at the same boundary here.
        assert_eq!(cells[0].gamma, 2.0);
    }

    #[test]
    fn optimize_pairs_analytical_and_measured_per_cell() {
        let cfg = tiny_cfg();
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert_eq!(report.refined.len(), cfg.top_k * cfg.dispatches.len());
        for c in &report.refined {
            assert!(c.analytic_tok_w > 0.0);
            assert!(c.outcome.completed > 0);
            assert!(c.rel_delta_pct().is_finite());
        }
        let w = report.winner().expect("generous SLO must yield a winner");
        assert!(w.outcome.slo_ok);
        // The winner leads the measured ranking.
        assert!(std::ptr::eq(w, &report.refined[0]));
    }

    #[test]
    fn slo_is_a_hard_filter_for_the_winner() {
        let cfg = OptimizeConfig {
            slo: SloTargets { ttft_p99_s: 1e-9 },
            ..tiny_cfg()
        };
        let report = optimize(&azure_conversations(), &cfg, 2);
        assert!(!report.refined.is_empty());
        assert!(report.refined.iter().all(|c| !c.outcome.slo_ok));
        assert!(report.winner().is_none(), "impossible SLO ⇒ no winner");
        let rs = report.rowset();
        assert!(rs.to_text().contains("no refined cell met"));
    }

    #[test]
    fn rowset_shows_both_engines_side_by_side() {
        let report = optimize(&azure_conversations(), &tiny_cfg(), 2);
        let rs = report.rowset();
        let csv = rs.to_csv();
        assert!(csv.starts_with(
            "GPU,B_short (tok),gamma,dispatch,analyze tok/W (tok/J),\
             simulate tok/W (tok/J),delta (%),p99 TTFT (s),slo,\
             analyze groups,winner\n"
        ));
        let doc = crate::runtime::json::parse(&rs.to_json()).unwrap();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), report.refined.len());
        for r in rows {
            assert!(r.get("analyze tok/W").unwrap().as_f64().is_some());
            assert!(r.get("simulate tok/W").unwrap().as_f64().is_some());
        }
        // Winner marked on the first (SLO-passing) row.
        assert_eq!(rows[0].get("winner").unwrap().as_str(), Some("*"));
        assert_eq!(rows[0].get("slo").unwrap().as_str(), Some("pass"));
    }
}
