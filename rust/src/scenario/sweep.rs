//! Fleet-scale scenario sweeps: dispatch × topology × context-window
//! grids, one [`ScenarioSpec`] per cell, fanned out across worker
//! threads.
//!
//! This is the workload the incremental-state engine exists for: at
//! λ=1000 a one-second cell is already a thousand arrivals, and a
//! default grid is dozens of cells. Cells are embarrassingly parallel,
//! so the sweep parallelizes *across* cells (`std::thread::scope`,
//! results placed by index) and runs each cell's engine sequentially —
//! no nested oversubscription. Every cell reports the same two
//! headline numbers, tok/W and p99 TTFT, plus an SLO verdict, so any
//! two cells of the grid are directly comparable.
//!
//! CLI: `wattlaw simulate sweep [--lambda 1000] [--duration S]
//! [--groups N] [--gpu ...] [--trace ...] [--dispatch NAME]
//! [--b-short N] [--spill F] [--slo-ttft S] [--workers N]`.

use super::{RouterSpec, ScenarioOutcome, ScenarioSpec, SloTargets};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::sim::dispatch;
use crate::tables::render::Table;
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;

/// Grid axes and shared per-cell settings.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub gpu: Gpu,
    /// Traffic per cell (the paper's fleets use λ = 1000).
    pub gen: GenConfig,
    /// Total simulated groups per cell.
    pub groups: u32,
    /// Dispatch axis (policy names; [`dispatch::ALL`] by default).
    pub dispatches: Vec<String>,
    /// Context-window axis: each split boundary yields a pool-routing
    /// and a FleetOpt (γ=2) topology at that boundary.
    pub b_shorts: Vec<u32>,
    /// Also sweep the load-aware adaptive router (at this spill factor)
    /// over each pool-routing topology.
    pub spill: Option<f64>,
    pub slo: SloTargets,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            gpu: Gpu::H100,
            gen: GenConfig {
                lambda_rps: 1000.0,
                duration_s: 1.0,
                max_prompt_tokens: 60_000,
                max_output_tokens: 512,
                seed: 42,
            },
            groups: 8,
            dispatches: dispatch::ALL.iter().map(|s| s.to_string()).collect(),
            b_shorts: vec![2048, 4096, 8192],
            spill: Some(2.0),
            slo: SloTargets::default(),
        }
    }
}

/// Expand the grid: (homogeneous baseline + per-boundary pool-routing,
/// FleetOpt and optionally adaptive-routed cells) × dispatch policies.
/// Cell order is deterministic — topology-major, dispatch-minor — and
/// [`run`] preserves it.
pub fn grid(workload: &WorkloadTrace, cfg: &SweepConfig) -> Vec<ScenarioSpec> {
    let mut topos: Vec<(Topology, RouterSpec)> =
        vec![(Topology::Homogeneous { ctx: LONG_CTX }, RouterSpec::Static)];
    for &b in &cfg.b_shorts {
        topos.push((
            Topology::PoolRouting { b_short: b, short_ctx: b },
            RouterSpec::Static,
        ));
        topos.push((
            Topology::FleetOpt { b_short: b, short_ctx: b, gamma: 2.0 },
            RouterSpec::Static,
        ));
        if let Some(spill) = cfg.spill {
            topos.push((
                Topology::PoolRouting { b_short: b, short_ctx: b },
                RouterSpec::Adaptive { spill },
            ));
        }
    }

    let mut specs = Vec::with_capacity(topos.len() * cfg.dispatches.len());
    for (topo, router) in &topos {
        for d in &cfg.dispatches {
            specs.push(
                ScenarioSpec::new(
                    topo.clone(),
                    cfg.gpu,
                    workload.clone(),
                    cfg.gen.clone(),
                )
                .with_groups(cfg.groups)
                .with_dispatch(d)
                .with_router(*router)
                .with_slo(cfg.slo),
            );
        }
    }
    specs
}

/// Run every cell, `workers` at a time, preserving input order. With
/// `workers > 1` the cell is the unit of parallelism and each cell's
/// engine runs sequentially (no nested oversubscription); `workers == 1`
/// is honored literally — everything on the calling thread — and a
/// single cell is instead given the in-cell parallel fast path when more
/// than one worker was requested. Grid cells all share one
/// (workload, gen), so the synthetic trace is generated once and played
/// through every cell.
pub fn run(specs: &[ScenarioSpec], workers: usize) -> Vec<ScenarioOutcome> {
    let requested = workers.max(1);
    let workers = requested.min(specs.len().max(1));
    // One trace for the whole grid when every cell would generate the
    // same one (always true for grid()-built sweeps).
    let shared: Option<Vec<crate::workload::Request>> = (specs.len() > 1
        && specs.iter().all(|s| {
            s.workload.name == specs[0].workload.name && s.gen == specs[0].gen
        }))
    .then(|| specs[0].trace());
    let cell = |s: &ScenarioSpec, in_cell_parallel: bool| match &shared {
        Some(t) => s.simulate_trace(t, in_cell_parallel),
        None => s.simulate(in_cell_parallel),
    };

    if specs.len() <= 1 {
        return specs.iter().map(|s| cell(s, requested > 1)).collect();
    }
    if workers == 1 {
        return specs.iter().map(|s| cell(s, false)).collect();
    }
    let mut results: Vec<Option<ScenarioOutcome>> =
        (0..specs.len()).map(|_| None).collect();
    let chunk = specs.len().div_ceil(workers);
    let cell = &cell;
    std::thread::scope(|scope| {
        for (spec_chunk, out_chunk) in
            specs.chunks(chunk).zip(results.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for (s, slot) in spec_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(cell(s, false));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("worker filled every slot"))
        .collect()
}

/// Render the sweep as one comparable table: a row per cell, tok/W and
/// p99 TTFT side by side, best-tok/W-within-SLO called out in the notes.
pub fn render(outcomes: &[ScenarioOutcome], cfg: &SweepConfig) -> String {
    let mut t = Table::new(
        format!(
            "Scenario sweep — dispatch × topology × context window \
             ({}, λ={} req/s × {}s, {} groups/cell)",
            cfg.gpu.spec().name,
            cfg.gen.lambda_rps,
            cfg.gen.duration_s,
            cfg.groups,
        ),
        &["Topology", "Router", "Dispatch", "tok/W", "p99 TTFT (s)", "SLO"],
    );
    for o in outcomes {
        t.row(vec![
            o.topology.clone(),
            o.router.clone(),
            o.dispatch.clone(),
            format!("{:.3}", o.tok_per_watt),
            format!("{:.3}", o.p99_ttft_s),
            if o.slo_ok { "ok".into() } else { "MISS".into() },
        ]);
    }
    let best = outcomes
        .iter()
        .filter(|o| o.slo_ok)
        .max_by(|a, b| a.tok_per_watt.total_cmp(&b.tok_per_watt));
    match best {
        Some(b) => t.note(format!(
            "best within SLO (p99 TTFT <= {}s): {} at {:.3} tok/W",
            cfg.slo.ttft_p99_s, b.label, b.tok_per_watt
        )),
        None => t.note(format!(
            "no cell met the p99 TTFT SLO of {}s at this load",
            cfg.slo.ttft_p99_s
        )),
    };
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            gen: GenConfig {
                lambda_rps: 200.0,
                duration_s: 0.3,
                max_prompt_tokens: 20_000,
                max_output_tokens: 64,
                seed: 5,
            },
            groups: 2,
            dispatches: vec!["rr".into(), "jsq".into()],
            b_shorts: vec![4096],
            spill: Some(2.0),
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_all_axes() {
        let specs = grid(&azure_conversations(), &tiny_cfg());
        // homo + (pool + fleetopt + adaptive-pool) = 4 topologies × 2
        // dispatch policies.
        assert_eq!(specs.len(), 8);
        assert!(specs.iter().any(|s| s.label().contains("Homo")));
        assert!(specs.iter().any(|s| s.label().contains("FleetOpt")));
        assert!(specs.iter().any(|s| s.label().contains("adaptive")));
        assert!(specs.iter().any(|s| s.dispatch == "jsq"));
    }

    #[test]
    fn parallel_sweep_matches_sequential_cell_order_and_bits() {
        let specs = grid(&azure_conversations(), &tiny_cfg());
        let seq = run(&specs, 1);
        let par = run(&specs, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.label, b.label, "cell order must be preserved");
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
        }
    }

    #[test]
    fn render_reports_every_cell_with_ttft() {
        let cfg = tiny_cfg();
        let specs = grid(&azure_conversations(), &cfg);
        let out = run(&specs, 4);
        let s = render(&out, &cfg);
        assert!(s.contains("tok/W") && s.contains("p99 TTFT"));
        assert!(s.contains("Homo") && s.contains("FleetOpt"));
        // One verdict-bearing row per cell.
        assert!(
            s.lines().filter(|l| l.contains("ok") || l.contains("MISS")).count()
                >= out.len()
        );
    }
}
