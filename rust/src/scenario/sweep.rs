//! Fleet-scale scenario sweeps: dispatch × topology × context-window
//! grids, one [`ScenarioSpec`] per cell, fanned out across worker
//! threads.
//!
//! This is the workload the incremental-state engine exists for: at
//! λ=1000 a one-second cell is already a thousand arrivals, and a
//! default grid is dozens of cells. Cells are embarrassingly parallel,
//! so the sweep parallelizes *across* cells (`std::thread::scope`,
//! results placed by index) and runs each cell's engine sequentially —
//! no nested oversubscription. Each cell **streams its own arrival
//! source** ([`ScenarioSpec::simulate`]) — O(1) trace memory per cell
//! regardless of λ × duration, so a million-arrival sweep cell costs no
//! more memory than a thousand-arrival one and cells share no trace
//! buffer. Every cell's record pairs the two engines' numbers —
//! closed-form `analyze` tok/W next to measured `simulate` tok/W with
//! their relative delta — plus p99 TTFT and an SLO verdict: the
//! standing analyze-vs-simulate consistency table, so any two cells of
//! the grid (and the two engines within a cell) are directly
//! comparable.
//!
//! CLI: `wattlaw simulate sweep [--lambda 1000] [--duration S]
//! [--groups N] [--gpu ...] [--trace ...] [--workload ARCHETYPE]
//! [--dispatch NAME] [--b-short N] [--pools K] [--cutoffs a,b,c]
//! [--spill F] [--slo-ttft S] [--workers N] [--format table|csv|json]`.

use super::{RouterSpec, ScenarioOutcome, ScenarioSpec, SloTargets};
use crate::fleet::profile::{ModelAxis, PowerAccounting};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::sim::{dispatch, StepMode};
use crate::workload::arrival::ArrivalSpec;
use crate::workload::cdf::WorkloadTrace;
use crate::workload::synth::GenConfig;

/// Grid axes and shared per-cell settings.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub gpu: Gpu,
    /// Traffic per cell (the paper's fleets use λ = 1000).
    pub gen: GenConfig,
    /// Arrival process shared by every cell: stationary Poisson by
    /// default, a generated archetype (`--workload`), or CSV trace
    /// replay (`--trace file.csv`). Streamed lazily per cell.
    pub arrivals: ArrivalSpec,
    /// Total simulated groups per cell.
    pub groups: u32,
    /// Dispatch axis (policy names; [`dispatch::ALL`] by default).
    pub dispatches: Vec<String>,
    /// Context-window axis: each split boundary yields a pool-routing
    /// and a FleetOpt (γ=2) topology at that boundary.
    pub b_shorts: Vec<u32>,
    /// K-pool partition axis: each cutoff vector adds a
    /// [`Topology::Partition`] cell (γ=1, static bucket router) — K as a
    /// grid dimension next to the two-pool cells. Empty by default;
    /// `--pools K` on the CLI fills it with the default ladder for each
    /// K' in 2..=K.
    pub partitions: Vec<Vec<u32>>,
    /// Per-pool GPU assignment axis: each vector adds one heterogeneous
    /// cell per `partitions` entry with a matching pool count (the
    /// homogeneous `gpu` cell stays in the grid as the baseline).
    /// `--gpu a,b,c` on the CLI. Empty by default.
    pub gpu_assignments: Vec<Vec<Gpu>>,
    /// Model-architecture axis: the whole topology × dispatch grid is
    /// replicated per model (`--model`, comma-separated). Defaults to
    /// dense only — the pre-axis grid, bit-for-bit.
    pub models: Vec<ModelAxis>,
    /// Also sweep the load-aware adaptive router (at this spill factor)
    /// over each pool-routing topology.
    pub spill: Option<f64>,
    pub slo: SloTargets,
    /// Power accounting for the per-cell analytical cross-check.
    pub acct: PowerAccounting,
    /// Engine step scheduling shared by every cell (fused default;
    /// `--step-mode per-step` replays the one-event-per-step oracle).
    pub step_mode: StepMode,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            gpu: Gpu::H100,
            gen: GenConfig {
                lambda_rps: 1000.0,
                duration_s: 1.0,
                max_prompt_tokens: 60_000,
                max_output_tokens: 512,
                seed: 42,
            },
            arrivals: ArrivalSpec::Stationary,
            groups: 8,
            dispatches: dispatch::ALL.iter().map(|s| s.to_string()).collect(),
            b_shorts: vec![2048, 4096, 8192],
            partitions: Vec::new(),
            gpu_assignments: Vec::new(),
            models: vec![ModelAxis::Dense],
            spill: Some(2.0),
            slo: SloTargets::default(),
            acct: PowerAccounting::PerGpu,
            step_mode: StepMode::default(),
        }
    }
}

/// Expand the grid: (homogeneous baseline + per-boundary pool-routing,
/// FleetOpt and optionally adaptive-routed cells) × dispatch policies.
/// Cell order is deterministic — topology-major, dispatch-minor — and
/// [`run`] preserves it.
pub fn grid(workload: &WorkloadTrace, cfg: &SweepConfig) -> Vec<ScenarioSpec> {
    let mut topos: Vec<(Topology, RouterSpec)> =
        vec![(Topology::Homogeneous { ctx: LONG_CTX }, RouterSpec::Static)];
    for &b in &cfg.b_shorts {
        topos.push((
            Topology::PoolRouting { b_short: b, short_ctx: b },
            RouterSpec::Static,
        ));
        topos.push((
            Topology::FleetOpt { b_short: b, short_ctx: b, gamma: 2.0 },
            RouterSpec::Static,
        ));
        if let Some(spill) = cfg.spill {
            topos.push((
                Topology::PoolRouting { b_short: b, short_ctx: b },
                RouterSpec::Adaptive { spill },
            ));
        }
    }
    // K as a grid dimension: one K-pool partition cell per cutoff
    // vector (plain bucket routing, γ=1 — compression cells live on the
    // FleetOpt axis above), plus one heterogeneous cell per matching
    // per-pool GPU assignment — generation-per-pool as a third grid
    // axis next to topology and workload.
    for cuts in &cfg.partitions {
        topos.push((Topology::partition(cuts), RouterSpec::Static));
        for gpus in &cfg.gpu_assignments {
            if gpus.len() == cuts.len() {
                topos.push((
                    Topology::partition_with_gpus(cuts, gpus, 1.0),
                    RouterSpec::Static,
                ));
            }
        }
    }

    let mut specs = Vec::with_capacity(
        cfg.models.len() * topos.len() * cfg.dispatches.len(),
    );
    for &model in &cfg.models {
        for (topo, router) in &topos {
            for d in &cfg.dispatches {
                specs.push(
                    ScenarioSpec::new(
                        topo.clone(),
                        cfg.gpu,
                        workload.clone(),
                        cfg.gen.clone(),
                    )
                    .with_model(model)
                    .with_groups(cfg.groups)
                    .with_dispatch(d)
                    .with_router(*router)
                    .with_arrivals(cfg.arrivals.clone())
                    .with_slo(cfg.slo)
                    .with_step_mode(cfg.step_mode),
                );
            }
        }
    }
    specs
}

/// Run every cell, `workers` at a time, preserving input order. With
/// `workers > 1` the cell is the unit of parallelism — cells are pulled
/// off a shared atomic work queue ([`crate::sim::par::run_indexed`], so
/// one slow cell never strands the rest of a statically chunked batch)
/// and each cell's engine runs sequentially (no nested
/// oversubscription); `workers == 1` is honored literally — everything
/// on the calling thread — and a single cell is instead given the
/// in-cell parallel fast path (sharded streaming) when more than one
/// worker was requested. Results are merged in input order, so the CSV
/// out of a `--workers 8` run is byte-identical to `--workers 1`. Each
/// cell streams arrivals from its own source (the pre-streaming grid
/// materialized one shared trace for every cell — now the whole sweep
/// holds no trace buffer at all, so λ × duration no longer bounds the
/// grid size memory can afford).
pub fn run(specs: &[ScenarioSpec], workers: usize) -> Vec<ScenarioOutcome> {
    let requested = workers.max(1);
    if specs.len() <= 1 {
        return specs.iter().map(|s| s.simulate(requested > 1)).collect();
    }
    crate::sim::par::run_indexed(specs.len(), requested, |i| {
        specs[i].simulate(false)
    })
}

/// One sweep cell with both engines' numbers — the standing
/// analyze-vs-simulate consistency record.
#[derive(Debug, Clone)]
pub struct CellRecord {
    pub outcome: ScenarioOutcome,
    /// Closed-form Eq. (4) tok/W for the same spec.
    pub analytic_tok_w: f64,
    /// Closed-form group count (the simulated cell uses the grid's
    /// fixed `groups` instead — part of why the two numbers differ).
    pub analytic_groups: u64,
}

impl CellRecord {
    /// Measured-vs-analytical relative delta, percent
    /// ([`super::rel_delta_pct`], shared with the optimizer).
    pub fn rel_delta_pct(&self) -> f64 {
        super::rel_delta_pct(self.outcome.tok_per_watt, self.analytic_tok_w)
    }
}

/// Pair every cell's simulated outcome with its closed-form analysis
/// (`spec.analyze()` on the very same spec — the cross-check is
/// comparable by construction).
pub fn records(
    specs: &[ScenarioSpec],
    outcomes: &[ScenarioOutcome],
    acct: PowerAccounting,
) -> Vec<CellRecord> {
    assert_eq!(specs.len(), outcomes.len(), "one outcome per spec");
    specs
        .iter()
        .zip(outcomes)
        .map(|(s, o)| {
            let analytic = s.analyze(acct);
            CellRecord {
                outcome: o.clone(),
                analytic_tok_w: analytic.tok_per_watt.0,
                analytic_groups: analytic.total_groups,
            }
        })
        .collect()
}

/// The sweep as one typed table: a row per cell, analytical and
/// simulated tok/W side by side with their relative delta, p99 TTFT
/// and the SLO verdict; best-measured-within-SLO called out in the
/// notes.
pub fn rowset(records: &[CellRecord], cfg: &SweepConfig) -> RowSet {
    let mut rs = RowSet::new(
        format!(
            "Scenario sweep — dispatch × topology × context window \
             ({}, λ={} req/s × {}s, {} groups/cell)",
            cfg.gpu.spec().name,
            cfg.gen.lambda_rps,
            cfg.gen.duration_s,
            cfg.groups,
        ),
        vec![
            Column::str("Workload"),
            Column::str("Topology"),
            Column::str("GPUs"),
            Column::str("Model"),
            Column::str("Router"),
            Column::str("Dispatch"),
            Column::float("analyze tok/W").with_unit("tok/J"),
            Column::float("simulate tok/W").with_unit("tok/J"),
            Column::float("delta").with_unit("%"),
            Column::float("p99 TTFT").with_unit("s"),
            Column::str("SLO"),
            Column::int("completed"),
            Column::int("rejected"),
        ],
    );
    for r in records {
        let o = &r.outcome;
        let delta = r.rel_delta_pct();
        rs.push(vec![
            Cell::str(o.workload.clone()),
            Cell::str(o.topology.clone()),
            Cell::str(o.gpus.clone()),
            Cell::str(o.model.clone()),
            Cell::str(o.router.clone()),
            Cell::str(o.dispatch.clone()),
            Cell::float(r.analytic_tok_w)
                .shown(format!("{:.3}", r.analytic_tok_w)),
            Cell::float(o.tok_per_watt).shown(format!("{:.3}", o.tok_per_watt)),
            Cell::float(delta).shown(format!("{delta:+.1}%")),
            Cell::float(o.p99_ttft_s).shown(format!("{:.3}", o.p99_ttft_s)),
            Cell::str(if o.slo_ok { "ok" } else { "MISS" }),
            Cell::int(o.completed as i64),
            Cell::int(o.rejected as i64),
        ]);
    }
    let best = records
        .iter()
        .filter(|r| r.outcome.slo_ok)
        .max_by(|a, b| a.outcome.tok_per_watt.total_cmp(&b.outcome.tok_per_watt));
    match best {
        Some(b) => rs.note(format!(
            "best within SLO (p99 TTFT <= {}s): {} at {:.3} tok/W",
            cfg.slo.ttft_p99_s, b.outcome.label, b.outcome.tok_per_watt
        )),
        None => rs.note(format!(
            "no cell met the p99 TTFT SLO of {}s at this load",
            cfg.slo.ttft_p99_s
        )),
    };
    rs.note(
        "delta = simulate/analyze − 1: the analytical planner sizes its own \
         fleet under the SLO while the simulated cell serves the grid's \
         fixed groups, so deltas measure model fidelity, not error bars",
    );
    rs
}

/// Render the sweep as the human-facing text table (analytical
/// cross-check included).
pub fn render(
    specs: &[ScenarioSpec],
    outcomes: &[ScenarioOutcome],
    cfg: &SweepConfig,
) -> String {
    rowset(&records(specs, outcomes, cfg.acct), cfg).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn tiny_cfg() -> SweepConfig {
        SweepConfig {
            gen: GenConfig {
                lambda_rps: 200.0,
                duration_s: 0.3,
                max_prompt_tokens: 20_000,
                max_output_tokens: 64,
                seed: 5,
            },
            groups: 2,
            dispatches: vec!["rr".into(), "jsq".into()],
            b_shorts: vec![4096],
            spill: Some(2.0),
            ..Default::default()
        }
    }

    #[test]
    fn grid_covers_all_axes() {
        let specs = grid(&azure_conversations(), &tiny_cfg());
        // homo + (pool + fleetopt + adaptive-pool) = 4 topologies × 2
        // dispatch policies.
        assert_eq!(specs.len(), 8);
        assert!(specs.iter().any(|s| s.label().contains("Homo")));
        assert!(specs.iter().any(|s| s.label().contains("FleetOpt")));
        assert!(specs.iter().any(|s| s.label().contains("adaptive")));
        assert!(specs.iter().any(|s| s.dispatch == "jsq"));
    }

    #[test]
    fn partition_axis_expands_k_as_a_grid_dimension() {
        let cfg = SweepConfig {
            partitions: vec![
                vec![4096, 16384, crate::fleet::topology::LONG_CTX],
                vec![2048, 8192, 32768, crate::fleet::topology::LONG_CTX],
            ],
            groups: 4,
            ..tiny_cfg()
        };
        let specs = grid(&azure_conversations(), &cfg);
        // The two partition topologies ride along the existing axes
        // (homo + pool + fleetopt + adaptive-pool) × 2 dispatch.
        assert_eq!(specs.len(), 12);
        assert!(specs.iter().any(|s| s.label().contains("3-pool")));
        assert!(specs.iter().any(|s| s.label().contains("4-pool")));
        // And the cells run end-to-end with conserved outcomes.
        let kpool: Vec<ScenarioSpec> = specs
            .into_iter()
            .filter(|s| s.label().contains("3-pool"))
            .collect();
        let out = run(&kpool, 2);
        assert_eq!(out.len(), kpool.len());
        for o in &out {
            assert!(o.completed > 0, "{}", o.label);
        }
    }

    #[test]
    fn gpu_assignment_axis_adds_hetero_cells_next_to_the_baseline() {
        use crate::power::Gpu;
        let cuts = vec![4096, crate::fleet::topology::LONG_CTX];
        let cfg = SweepConfig {
            partitions: vec![cuts],
            gpu_assignments: vec![
                vec![Gpu::H100, Gpu::B200],
                // Length-mismatched vectors are skipped, not misapplied.
                vec![Gpu::H100, Gpu::H100, Gpu::B200],
            ],
            groups: 4,
            ..tiny_cfg()
        };
        let specs = grid(&azure_conversations(), &cfg);
        // (homo + pool + fleetopt + adaptive-pool + K=2 partition +
        //  1 matching assignment cell) × 2 dispatch policies.
        assert_eq!(specs.len(), 12);
        let hetero: Vec<&ScenarioSpec> = specs
            .iter()
            .filter(|s| s.gpus_label() == "H100|B200")
            .collect();
        assert_eq!(hetero.len(), 2, "one per dispatch policy");
        // The cells run, and their records carry the assignment.
        let out = run(&specs, 4);
        let recs = records(&specs, &out, cfg.acct);
        let rs = rowset(&recs, &cfg);
        assert!(rs.to_csv().contains("H100|B200"), "{}", rs.to_csv());
        for r in recs.iter().filter(|r| r.outcome.gpus == "H100|B200") {
            assert!(r.outcome.completed > 0);
            assert!(r.analytic_tok_w > 0.0);
        }
    }

    #[test]
    fn workload_axis_rides_through_grid_run_and_rowset() {
        let cfg = SweepConfig {
            arrivals: ArrivalSpec::parse("flash-crowd").unwrap(),
            dispatches: vec!["jsq".into()],
            ..tiny_cfg()
        };
        let specs = grid(&azure_conversations(), &cfg);
        assert!(specs
            .iter()
            .all(|s| matches!(s.arrivals, ArrivalSpec::FlashCrowd { .. })));
        let out = run(&specs, 2);
        let recs = records(&specs, &out, cfg.acct);
        let csv = rowset(&recs, &cfg).to_csv();
        assert!(
            csv.contains("Azure+flash-crowd(x5)"),
            "workload column missing the archetype: {csv}"
        );
        for o in &out {
            assert!(o.completed > 0, "{}", o.label);
        }
    }

    #[test]
    fn parallel_sweep_matches_sequential_cell_order_and_bits() {
        let specs = grid(&azure_conversations(), &tiny_cfg());
        let seq = run(&specs, 1);
        let par = run(&specs, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.label, b.label, "cell order must be preserved");
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
        }
    }

    #[test]
    fn render_reports_every_cell_with_ttft() {
        let cfg = tiny_cfg();
        let specs = grid(&azure_conversations(), &cfg);
        let out = run(&specs, 4);
        let s = render(&specs, &out, &cfg);
        assert!(s.contains("tok/W") && s.contains("p99 TTFT"));
        assert!(s.contains("Homo") && s.contains("FleetOpt"));
        // One verdict-bearing row per cell.
        assert!(
            s.lines().filter(|l| l.contains("ok") || l.contains("MISS")).count()
                >= out.len()
        );
    }

    #[test]
    fn records_pair_both_engines_per_cell() {
        let cfg = tiny_cfg();
        let specs = grid(&azure_conversations(), &cfg);
        let out = run(&specs, 4);
        let recs = records(&specs, &out, cfg.acct);
        assert_eq!(recs.len(), specs.len());
        for r in &recs {
            assert!(r.analytic_tok_w > 0.0, "{}", r.outcome.label);
            assert!(r.analytic_groups > 0);
            assert!(r.rel_delta_pct().is_finite(), "{}", r.outcome.label);
        }
        // The machine formats carry both engines' columns.
        let rs = rowset(&recs, &cfg);
        let csv = rs.to_csv();
        assert!(csv.starts_with(
            "Workload,Topology,GPUs,Model,Router,Dispatch,\
             analyze tok/W (tok/J),simulate tok/W (tok/J),delta (%),\
             p99 TTFT (s),SLO,completed,rejected\n"
        ));
        assert!(csv.contains(",dense,"), "model column filled: {csv}");
        assert!(csv.contains("\nAzure,"), "workload column filled: {csv}");
        assert_eq!(csv.lines().count(), 1 + recs.len());
        let doc = crate::runtime::json::parse(&rs.to_json()).unwrap();
        assert_eq!(
            doc.get("rows").unwrap().as_arr().unwrap().len(),
            recs.len()
        );
    }

    #[test]
    fn model_axis_replicates_the_grid_and_rides_to_the_rowset() {
        let cfg = SweepConfig {
            models: vec![
                ModelAxis::Dense,
                ModelAxis::MoeStreaming { dispatch_ms: 0.0 },
            ],
            dispatches: vec!["jsq".into()],
            ..tiny_cfg()
        };
        let specs = grid(&azure_conversations(), &cfg);
        // (homo + pool + fleetopt + adaptive-pool) × 1 dispatch,
        // replicated per model.
        assert_eq!(specs.len(), 8);
        assert_eq!(
            specs.iter().filter(|s| s.model == ModelAxis::Dense).count(),
            4
        );
        // Run just the homogeneous pair — model-major order puts dense
        // first — and pin the column end-to-end.
        let homo: Vec<ScenarioSpec> = specs
            .into_iter()
            .filter(|s| s.label().contains("Homo"))
            .collect();
        assert_eq!(homo.len(), 2);
        let out = run(&homo, 2);
        let recs = records(&homo, &out, cfg.acct);
        let csv = rowset(&recs, &cfg).to_csv();
        assert!(
            csv.contains(",dense,") && csv.contains(",qwen3-moe,"),
            "model column missing an axis value: {csv}"
        );
        // Weight streaming must lift measured tok/W on the same cell.
        assert!(
            recs[1].outcome.tok_per_watt > recs[0].outcome.tok_per_watt,
            "moe {} !> dense {}",
            recs[1].outcome.tok_per_watt,
            recs[0].outcome.tok_per_watt
        );
    }
}
