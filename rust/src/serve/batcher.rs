//! Continuous batcher — the slot state machine shared by the real-model
//! engine and the discrete-event simulator.
//!
//! Semantics (vLLM-style continuous batching with chunked prompt
//! ingestion):
//!
//! * a pool exposes `slots` concurrent sequences (the physical n_max),
//! * admission requires a free slot **and** KV blocks for the request's
//!   full window footprint (the paged allocator enforces Eq. 3),
//! * admitted sequences first *ingest* their prompt in chunks, then
//!   *decode* one token per step,
//! * completion frees the slot and its blocks immediately (the next
//!   queued request joins on the following step).

use std::collections::VecDeque;

use super::kvblocks::BlockAllocator;
use super::request::{Completion, ServeRequest};

/// Lifecycle phase of an in-flight sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Consuming prompt tokens (`remaining` still to ingest).
    Ingest,
    /// Emitting output tokens.
    Decode,
}

/// One occupied slot.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub req: ServeRequest,
    pub phase: Phase,
    /// Prompt tokens not yet ingested.
    pub remaining_prompt: u32,
    /// Output tokens emitted so far.
    pub emitted: u32,
    /// Current total KV length (ingested + emitted).
    pub kv_len: u32,
    /// Admission time (for TTFT).
    pub admitted_s: f64,
    /// First-output-token time.
    pub first_token_s: Option<f64>,
}

/// What a slot should do on the next engine step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotWork {
    Idle,
    /// Ingest up to `chunk` prompt tokens.
    Ingest { chunk: u32 },
    /// Decode one output token.
    Decode,
}

/// The continuous batcher.
#[derive(Debug, Clone)]
pub struct Batcher {
    pub slots: Vec<Option<SeqState>>,
    pub queue: VecDeque<ServeRequest>,
    pub blocks: BlockAllocator,
    /// Prompt tokens ingested per slot per step (chunked prefill size).
    pub ingest_chunk: u32,
    /// Reject requests whose total footprint exceeds this window.
    pub window_tokens: u32,
    /// When true, admission reserves KV blocks for the *full window*
    /// per sequence (the paper's Eq. 3 convention: n_max = V_KV/(κ·W));
    /// when false, blocks are reserved for the request's actual
    /// footprint and grown on demand (optimistic vLLM-style admission).
    pub reserve_window: bool,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(
        slots: usize,
        blocks: BlockAllocator,
        ingest_chunk: u32,
        window_tokens: u32,
    ) -> Self {
        assert!(slots > 0 && ingest_chunk > 0);
        Batcher {
            slots: vec![None; slots],
            queue: VecDeque::new(),
            blocks,
            ingest_chunk,
            window_tokens,
            reserve_window: false,
            rejected: 0,
        }
    }

    /// Enable Eq.-3-style full-window reservation at admission.
    pub fn with_window_reservation(mut self) -> Self {
        self.reserve_window = true;
        self
    }

    /// Enqueue a request (rejects footprints beyond the window).
    pub fn submit(&mut self, req: ServeRequest) -> bool {
        if req.total_tokens() > self.window_tokens {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Admit queued requests into free slots while KV blocks last.
    /// Returns the number admitted.
    pub fn admit(&mut self, now_s: f64) -> usize {
        let mut admitted = 0;
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                continue;
            }
            // Head-of-line admission (FIFO, like vLLM's default policy).
            let Some(req) = self.queue.front() else { break };
            if req.arrival_s > now_s {
                break; // not yet arrived (simulator feeds future requests)
            }
            let reserve = if self.reserve_window {
                self.window_tokens
            } else {
                req.total_tokens()
            };
            if !self.blocks.admit(req.id, reserve) {
                break; // memory pressure: stall admission
            }
            let req = self.queue.pop_front().unwrap();
            self.slots[i] = Some(SeqState {
                remaining_prompt: req.prompt_tokens,
                emitted: 0,
                kv_len: 0,
                phase: Phase::Ingest,
                admitted_s: now_s,
                first_token_s: None,
                req,
            });
            admitted += 1;
        }
        admitted
    }

    /// Number of occupied slots.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting in the FIFO queue (not yet admitted) — the queue
    /// depth load-aware routers and dispatch policies observe.
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Mean KV length across active sequences (the live L̄).
    pub fn mean_kv_len(&self) -> f64 {
        let (mut n, mut sum) = (0u32, 0u64);
        for s in self.slots.iter().flatten() {
            n += 1;
            sum += s.kv_len as u64;
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    /// Work plan for the next step.
    pub fn plan(&self) -> Vec<SlotWork> {
        self.slots
            .iter()
            .map(|s| match s {
                None => SlotWork::Idle,
                Some(st) => match st.phase {
                    Phase::Ingest => SlotWork::Ingest {
                        chunk: st.remaining_prompt.min(self.ingest_chunk),
                    },
                    Phase::Decode => SlotWork::Decode,
                },
            })
            .collect()
    }

    /// Apply one step's outcome for slot `i` at time `now_s`. For
    /// `Ingest`, `tokens` is the chunk actually consumed; for `Decode`
    /// it must be 1. Returns a completion if the sequence finished.
    pub fn on_step(
        &mut self,
        i: usize,
        work: SlotWork,
        now_s: f64,
    ) -> Option<Completion> {
        let st = self.slots[i].as_mut()?;
        match work {
            SlotWork::Idle => None,
            SlotWork::Ingest { chunk } => {
                st.remaining_prompt = st.remaining_prompt.saturating_sub(chunk);
                st.kv_len += chunk;
                self.blocks.grow(st.req.id, st.kv_len);
                if st.remaining_prompt == 0 {
                    st.phase = Phase::Decode;
                }
                None
            }
            SlotWork::Decode => {
                st.emitted += 1;
                st.kv_len += 1;
                self.blocks.grow(st.req.id, st.kv_len);
                if st.first_token_s.is_none() {
                    st.first_token_s = Some(now_s);
                }
                if st.emitted >= st.req.output_tokens {
                    let st = self.slots[i].take().unwrap();
                    self.blocks.release(st.req.id);
                    return Some(Completion {
                        id: st.req.id,
                        pool: 0,
                        output_tokens: st.emitted,
                        ttft_s: st.first_token_s.unwrap() - st.req.arrival_s,
                        e2e_s: now_s - st.req.arrival_s,
                    });
                }
                None
            }
        }
    }

    /// Work remains (queued or in flight)?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.active() > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: u32, out: u32) -> ServeRequest {
        ServeRequest { id, prompt_tokens: prompt, output_tokens: out, arrival_s: 0.0 }
    }

    fn batcher(slots: usize, blocks: u32) -> Batcher {
        Batcher::new(slots, BlockAllocator::new(64, blocks), 128, 4096)
    }

    /// Drive the batcher synchronously with a fixed per-step time.
    fn drive(b: &mut Batcher, dt: f64) -> Vec<Completion> {
        let mut t = 0.0;
        let mut done = Vec::new();
        let mut guard = 0;
        while b.has_work() {
            b.admit(t);
            t += dt;
            for (i, w) in b.plan().into_iter().enumerate() {
                if w != SlotWork::Idle {
                    if let Some(c) = b.on_step(i, w, t) {
                        done.push(c);
                    }
                }
            }
            guard += 1;
            assert!(guard < 100_000, "stuck batcher");
        }
        done
    }

    #[test]
    fn single_request_lifecycle() {
        let mut b = batcher(2, 64);
        assert!(b.submit(req(1, 200, 3)));
        let done = drive(&mut b, 0.01);
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.output_tokens, 3);
        // 200 prompt @128 chunk = 2 ingest steps, first token on step 3.
        assert!((c.ttft_s - 0.03).abs() < 1e-9, "ttft = {}", c.ttft_s);
        assert!((c.e2e_s - 0.05).abs() < 1e-9);
        assert_eq!(b.blocks.used(), 0, "blocks released");
    }

    #[test]
    fn continuous_join_and_completion() {
        let mut b = batcher(2, 1000);
        for i in 0..5 {
            b.submit(req(i, 64, 2));
        }
        let done = drive(&mut b, 1.0);
        assert_eq!(done.len(), 5);
        // Slots never exceeded 2.
        assert!(b.blocks.peak_used <= 2 * 2, "peak {}", b.blocks.peak_used);
    }

    #[test]
    fn admission_respects_block_budget() {
        // 4 blocks of 64 = 256 tokens; two 128-token requests exhaust it.
        let mut b = Batcher::new(8, BlockAllocator::new(64, 4), 128, 4096);
        for i in 0..3 {
            b.submit(req(i, 100, 28)); // footprint 128 → 2 blocks
        }
        b.admit(0.0);
        assert_eq!(b.active(), 2, "third must stall on blocks, not slots");
    }

    #[test]
    fn oversized_request_rejected() {
        let mut b = batcher(2, 64);
        assert!(!b.submit(req(1, 5000, 100)));
        assert_eq!(b.rejected, 1);
        assert!(!b.has_work());
    }

    #[test]
    fn ttft_counts_queue_wait() {
        let mut b = batcher(1, 1000); // single slot → second request queues
        b.submit(req(1, 128, 5));
        b.submit(req(2, 128, 5));
        let done = drive(&mut b, 1.0);
        let c1 = done.iter().find(|c| c.id == 1).unwrap();
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert!(c2.ttft_s > c1.ttft_s + 4.0, "queued request waits");
    }

    #[test]
    fn mean_kv_len_tracks_growth() {
        let mut b = batcher(2, 1000);
        b.submit(req(1, 128, 10));
        b.admit(0.0);
        assert_eq!(b.mean_kv_len(), 0.0);
        let plan = b.plan();
        b.on_step(0, plan[0], 1.0);
        assert_eq!(b.mean_kv_len(), 128.0);
    }
}
