//! Live energy metering: integrate the calibrated logistic `P(b)` over the
//! engine's actual in-flight batch trajectory.
//!
//! This is the serving-side realization of the paper's accounting — the
//! same `P(b)` the analytical tables use, driven by the *measured* batch
//! occupancy instead of a steady-state assumption. tok/W falls out as
//! `output_tokens / joules` (numerically identical to (tok/s)/W).

use crate::power::LogisticPower;
use crate::units::{Joules, TokensPerWatt, Watts};

/// Piecewise-constant power integrator for one emulated GPU (group).
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    power: LogisticPower,
    /// GPUs charged per observation (1 = paper's per-GPU convention;
    /// TP for the physically complete bill).
    gpus: f64,
    last_t_s: f64,
    last_b: f64,
    joules: f64,
    output_tokens: u64,
    /// Time-weighted mean batch (for reports).
    batch_time_integral: f64,
    start_t_s: f64,
}

impl EnergyMeter {
    pub fn new(power: LogisticPower, gpus: f64, start_t_s: f64) -> Self {
        EnergyMeter {
            power,
            gpus,
            last_t_s: start_t_s,
            last_b: 0.0,
            joules: 0.0,
            output_tokens: 0,
            batch_time_integral: 0.0,
            start_t_s,
        }
    }

    /// Record that the in-flight batch has been `b` since the last
    /// observation, up to time `t_s`.
    pub fn observe(&mut self, t_s: f64, b: f64) {
        let dt = (t_s - self.last_t_s).max(0.0);
        self.joules += self.power.power_w(self.last_b) * self.gpus * dt;
        self.batch_time_integral += self.last_b * dt;
        self.last_t_s = t_s;
        self.last_b = b;
    }

    pub fn add_output_tokens(&mut self, n: u64) {
        self.output_tokens += n;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.last_t_s - self.start_t_s
    }

    pub fn joules(&self) -> Joules {
        Joules(self.joules)
    }

    pub fn output_tokens(&self) -> u64 {
        self.output_tokens
    }

    /// Time-weighted mean in-flight batch.
    pub fn mean_batch(&self) -> f64 {
        let t = self.elapsed_s();
        if t > 0.0 {
            self.batch_time_integral / t
        } else {
            0.0
        }
    }

    /// Mean power over the metered window.
    pub fn mean_power(&self) -> Watts {
        let t = self.elapsed_s();
        Watts(if t > 0.0 { self.joules / t } else { 0.0 })
    }

    /// The headline figure: output tokens per watt — numerically
    /// `(tok/s) / W = tokens / joules`.
    pub fn tok_per_watt(&self) -> TokensPerWatt {
        TokensPerWatt(if self.joules > 0.0 {
            self.output_tokens as f64 / self.joules
        } else {
            0.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_batch_energy() {
        let mut m = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
        m.observe(0.0, 16.0); // from t=0, batch 16
        m.observe(10.0, 16.0); // 10 s at P(16) ≈ 435 W
        assert!((m.joules().0 - 4350.0).abs() < 20.0, "J = {}", m.joules().0);
        assert!((m.mean_batch() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn tok_per_watt_matches_analytical_at_steady_state() {
        // Hold n=16 at the 64K operating point: τ = 24.47 ms/step, each
        // step emits 16 tokens → 653.8 tok/s at 435 W → 1.50 tok/W.
        let mut m = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
        m.observe(0.0, 16.0);
        let tau_s = 0.02447;
        for step in 1..=1000u64 {
            m.observe(step as f64 * tau_s, 16.0);
            m.add_output_tokens(16);
        }
        let tw = m.tok_per_watt().0;
        assert!((tw - 1.50).abs() < 0.02, "tok/W = {tw}");
    }

    #[test]
    fn idle_time_burns_energy_without_tokens() {
        let mut m = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
        m.observe(0.0, 0.0);
        m.observe(5.0, 0.0); // 5 s idle at 300 W
        assert!((m.joules().0 - 1500.0).abs() < 1e-6);
        assert_eq!(m.tok_per_watt().0, 0.0);
    }

    #[test]
    fn per_group_charging() {
        let mut g = EnergyMeter::new(LogisticPower::h100(), 8.0, 0.0);
        g.observe(0.0, 16.0);
        g.observe(1.0, 16.0);
        let mut s = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
        s.observe(0.0, 16.0);
        s.observe(1.0, 16.0);
        assert!((g.joules().0 / s.joules().0 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn out_of_order_observation_is_clamped() {
        let mut m = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
        m.observe(1.0, 8.0);
        m.observe(0.5, 8.0); // earlier timestamp: no negative energy
        assert!(m.joules().0 >= 0.0);
    }
}
