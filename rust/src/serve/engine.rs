//! The real-model pool engine: continuous batching over the AOT-compiled
//! tiny-Llama decode step, with live energy metering.
//!
//! One engine emulates one TP group. The artifact has `B` physical slots
//! (the batch the HLO was lowered at); the pool's *configured context
//! window* and *KV block budget* determine how many of those slots can be
//! simultaneously occupied — which is exactly the `n_max(W)` mechanism of
//! the 1/W law, now enforced by a real allocator in front of a real model.
//!
//! Prompt ingestion is token-by-token through the decode path (chunked
//! prefill with chunk = 1): slots join and leave the batch independently,
//! which is what continuous batching means. The `prefill` artifact is used
//! by the quickstart for whole-batch priming and by the golden validator.

use super::batcher::{Batcher, SlotWork};
use super::energy::EnergyMeter;
use super::kvblocks::BlockAllocator;
use super::metrics::ServeMetrics;
use super::request::{Completion, ServeRequest};
use super::scheduler::{schedule, SchedulerPolicy};
use crate::power::LogisticPower;
use crate::runtime::{Kv, TinyModel};

/// Maps the tiny demo model's operating point onto a datacenter GPU: the
/// energy clock advances by the *emulated* GPU's roofline iteration time
/// at the live (n_active, L̄) — scaled from the tiny window onto the
/// emulated window — while the CPU executes the real numerics. This is
/// the substitution DESIGN.md §2 documents: same code path, calibrated
/// time/power model.
#[derive(Debug, Clone)]
pub struct Emulation {
    pub roofline: crate::roofline::Roofline,
    /// The emulated serving context window (e.g. 4096 or 65536).
    pub emulated_window: u32,
}

/// Pool-engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Serving context window (≤ artifact max_seq − 1; the last KV slot
    /// is the idle-lane scratch position).
    pub window_tokens: u32,
    /// KV block budget in 64-token blocks (emulates V_KV; fewer blocks =
    /// longer-window pools hold fewer sequences — Eq. 3 live).
    pub kv_blocks: u32,
    /// Power curve used for energy metering (paper-calibrated logistic).
    pub power: LogisticPower,
    /// GPUs charged per observation (1 = paper convention).
    pub gpus_charged: f64,
    pub scheduler: SchedulerPolicy,
    /// When set, the energy clock runs on the emulated GPU's roofline
    /// step time instead of measured CPU wall time.
    pub emulation: Option<Emulation>,
}

impl EngineConfig {
    pub fn for_window(window_tokens: u32, kv_blocks: u32) -> Self {
        EngineConfig {
            window_tokens,
            kv_blocks,
            power: LogisticPower::h100(),
            gpus_charged: 1.0,
            scheduler: SchedulerPolicy::default(),
            emulation: None,
        }
    }

    /// Allow up to `n` slots to run prompt ingestion per step.
    pub fn with_ingest_slots(mut self, n: usize) -> Self {
        self.scheduler.max_ingest_slots = n;
        self
    }

    /// Emulate an H100/70B pool at `emulated_window` (paper-calibrated).
    pub fn emulating_h100(mut self, emulated_window: u32) -> Self {
        self.emulation = Some(Emulation {
            roofline: crate::roofline::Roofline::manual(6.72, 0.1387),
            emulated_window,
        });
        self
    }
}

/// Result of serving a request batch through one pool.
#[derive(Debug)]
pub struct EngineReport {
    pub pool: usize,
    pub window_tokens: u32,
    pub completions: Vec<Completion>,
    pub metrics: ServeMetrics,
    pub steps: u64,
    /// Virtual serving time (accumulated measured step latencies), s.
    pub serve_time_s: f64,
    /// Wall-clock time actually spent, s.
    pub wall_s: f64,
    /// Wall time inside the PJRT executor, s.
    pub exec_wall_s: f64,
    pub joules: f64,
    pub output_tokens: u64,
    pub mean_batch: f64,
    pub tok_per_watt: f64,
    /// Decode throughput over the serving window, tok/s.
    pub decode_tok_s: f64,
}

/// The engine.
pub struct PoolEngine {
    pub pool_id: usize,
    model: TinyModel,
    cfg: EngineConfig,
    batcher: Batcher,
    kv_k: Kv,
    kv_v: Kv,
    /// Next input token per slot.
    slot_tokens: Vec<i32>,
    clock_s: f64,
    /// Accumulated measured executor wall time (perf reporting).
    wall_exec_s: f64,
    meter: EnergyMeter,
    metrics: ServeMetrics,
    steps: u64,
}

/// Deterministic synthetic prompt token (requests in the energy study are
/// length-shaped, not content-shaped).
fn prompt_token(req_id: u64, position: u32, vocab: u32) -> i32 {
    let mut x = req_id
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(position as u64);
    x ^= x >> 29;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    (x % vocab as u64) as i32
}

impl PoolEngine {
    pub fn new(pool_id: usize, model: TinyModel, cfg: EngineConfig) -> crate::Result<Self> {
        let b = model.cfg.batch as usize;
        let max_window = model.cfg.max_seq - 1; // last slot is idle scratch
        anyhow::ensure!(
            cfg.window_tokens <= max_window,
            "window {} exceeds artifact max {} - 1",
            cfg.window_tokens,
            model.cfg.max_seq
        );
        let blocks = BlockAllocator::new(64, cfg.kv_blocks);
        // Ingestion is 1 token/step through the decode path; admission
        // reserves the full window per sequence (Eq. 3's n_max
        // mechanism — this is what makes the long-window pool hold fewer
        // concurrent sequences from the same KV budget).
        let batcher =
            Batcher::new(b, blocks, 1, cfg.window_tokens).with_window_reservation();
        let (kv_k, kv_v) = model.fresh_kv()?;
        Ok(PoolEngine {
            pool_id,
            meter: EnergyMeter::new(cfg.power, cfg.gpus_charged, 0.0),
            model,
            cfg,
            batcher,
            kv_k,
            kv_v,
            slot_tokens: vec![0; b],
            clock_s: 0.0,
            wall_exec_s: 0.0,
            metrics: ServeMetrics::default(),
            steps: 0,
        })
    }

    pub fn submit(&mut self, req: ServeRequest) -> bool {
        let ok = self.batcher.submit(req);
        if !ok {
            self.metrics.rejected += 1;
        }
        ok
    }

    /// Run until all submitted work completes; returns the report.
    pub fn run_to_completion(&mut self) -> crate::Result<EngineReport> {
        let wall_start = std::time::Instant::now();
        let scratch_pos = (self.model.cfg.max_seq - 1) as i32;
        let b = self.model.cfg.batch as usize;
        let vocab = self.model.cfg.vocab;

        let mut completions = Vec::new();
        while self.batcher.has_work() {
            self.batcher.admit(self.clock_s);
            let plan = schedule(&self.batcher, &self.cfg.scheduler);
            let n_active = plan
                .iter()
                .filter(|w| !matches!(w, SlotWork::Idle))
                .count();
            if n_active == 0 {
                // All queued requests stalled on admission — impossible
                // here because completion frees blocks synchronously, but
                // guard against a wedged loop anyway.
                anyhow::bail!("engine wedged: queued work but nothing active");
            }

            // Build the step inputs.
            let mut tokens = vec![0i32; b];
            let mut pos = vec![scratch_pos; b];
            for (i, w) in plan.iter().enumerate() {
                match w {
                    SlotWork::Idle => {}
                    SlotWork::Ingest { .. } => {
                        let st = self.batcher.slots[i].as_ref().unwrap();
                        tokens[i] =
                            prompt_token(st.req.id, st.kv_len, vocab);
                        pos[i] = st.kv_len as i32;
                    }
                    SlotWork::Decode => {
                        let st = self.batcher.slots[i].as_ref().unwrap();
                        tokens[i] = self.slot_tokens[i];
                        pos[i] = st.kv_len as i32;
                    }
                }
            }

            // Execute the compiled decode step and advance the clock —
            // by measured latency, or by the emulated GPU's roofline
            // iteration time at the live operating point.
            let l_live = self.batcher.mean_kv_len();
            let n_decode = plan
                .iter()
                .filter(|w| matches!(w, SlotWork::Decode))
                .count();
            let n_ingest = n_active - n_decode;
            let t0 = std::time::Instant::now();
            let (logits, kv_k, kv_v) =
                self.model.decode_step(&tokens, &self.kv_k, &self.kv_v, &pos)?;
            let measured = t0.elapsed().as_secs_f64();
            let dt = match &self.cfg.emulation {
                None => measured,
                Some(emu) => {
                    // The emulated engine runs *chunked* prefill: a real
                    // iteration ingests ~1024 prompt tokens per slot, so a
                    // 1-token physical ingest is charged 1/1024 of a
                    // weight stream; decode slots pay the full roofline
                    // iteration.
                    let frac = (l_live / self.cfg.window_tokens as f64)
                        .clamp(0.0, 1.0);
                    let l_emu = (emu.emulated_window as f64 * frac).max(1.0);
                    let decode_ms = if n_decode > 0 {
                        emu.roofline.tau_ms(n_decode as f64, l_emu)
                    } else {
                        0.0
                    };
                    let ingest_ms =
                        n_ingest as f64 * emu.roofline.w_ms / 1024.0;
                    (decode_ms + ingest_ms) / 1e3
                }
            };
            self.kv_k = kv_k;
            self.kv_v = kv_v;
            self.clock_s += dt;
            self.wall_exec_s += measured;
            self.steps += 1;
            self.meter.observe(self.clock_s, n_active as f64);

            // Apply outcomes.
            let sampled = self.model.argmax(&logits);
            for (i, w) in plan.iter().enumerate() {
                match w {
                    SlotWork::Idle => {}
                    SlotWork::Ingest { .. } => {
                        // chunk = 1 by construction
                        self.batcher.on_step(
                            i,
                            SlotWork::Ingest { chunk: 1 },
                            self.clock_s,
                        );
                        // When ingestion just finished, the next decode
                        // input is the model's continuation of the prompt.
                        self.slot_tokens[i] = sampled[i];
                    }
                    SlotWork::Decode => {
                        self.meter.add_output_tokens(1);
                        self.slot_tokens[i] = sampled[i];
                        if let Some(mut c) =
                            self.batcher.on_step(i, SlotWork::Decode, self.clock_s)
                        {
                            c.pool = self.pool_id;
                            self.metrics.record(&c);
                            completions.push(c);
                        }
                    }
                }
            }
        }

        let wall_s = wall_start.elapsed().as_secs_f64();
        let output_tokens = self.meter.output_tokens();
        Ok(EngineReport {
            pool: self.pool_id,
            window_tokens: self.cfg.window_tokens,
            metrics: self.metrics.clone(),
            steps: self.steps,
            serve_time_s: self.clock_s,
            wall_s,
            exec_wall_s: self.wall_exec_s,
            joules: self.meter.joules().0,
            output_tokens,
            mean_batch: self.meter.mean_batch(),
            tok_per_watt: self.meter.tok_per_watt().0,
            decode_tok_s: if self.clock_s > 0.0 {
                output_tokens as f64 / self.clock_s
            } else {
                0.0
            },
            completions,
        })
    }

    /// Access the model (for prefill priming / golden validation flows).
    pub fn model(&self) -> &TinyModel {
        &self.model
    }
}
