//! Paged KV-cache block allocator (vLLM-style).
//!
//! This is the mechanism that *physically enforces* Eq. (3): the pool has
//! `V_KV / (κ · block)` blocks; a sequence at length L holds
//! `ceil(L / block)` of them; when the free list runs dry, admission
//! stalls — which is exactly the `n_max(W)` concurrency ceiling the 1/W
//! law derives.

use std::collections::HashMap;

/// Fixed-size block allocator over a token-addressed KV space.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    /// Tokens per block (the Pallas kernel's page size — 64 by default).
    pub block_tokens: u32,
    /// Total blocks in the pool.
    pub num_blocks: u32,
    free: Vec<u32>,
    held: HashMap<u64, Vec<u32>>,
    /// High-water mark of blocks in use (for reports).
    pub peak_used: u32,
}

impl BlockAllocator {
    pub fn new(block_tokens: u32, num_blocks: u32) -> Self {
        assert!(block_tokens > 0 && num_blocks > 0);
        BlockAllocator {
            block_tokens,
            num_blocks,
            free: (0..num_blocks).rev().collect(),
            held: HashMap::new(),
            peak_used: 0,
        }
    }

    /// Allocator sized from a KV byte budget and κ (Eq. 3 in block form).
    pub fn from_budget(kv_bytes: u64, kappa_bytes_per_token: u64, block_tokens: u32) -> Self {
        let tokens = kv_bytes / kappa_bytes_per_token.max(1);
        let blocks = (tokens / block_tokens as u64).max(1) as u32;
        Self::new(block_tokens, blocks)
    }

    fn blocks_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_tokens).max(1)
    }

    pub fn used(&self) -> u32 {
        self.num_blocks - self.free.len() as u32
    }

    /// Blocks currently on the free list — the headroom signal
    /// least-KV-load dispatch observes.
    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn utilization(&self) -> f64 {
        self.used() as f64 / self.num_blocks as f64
    }

    /// Can a sequence of `tokens` total length be admitted right now?
    pub fn can_admit(&self, tokens: u32) -> bool {
        self.blocks_for(tokens) as usize <= self.free.len()
    }

    /// Reserve blocks for a sequence's full expected length. Serving
    /// admits against the *window*, mirroring the analytical n_max.
    pub fn admit(&mut self, seq: u64, tokens: u32) -> bool {
        let need = self.blocks_for(tokens);
        if (need as usize) > self.free.len() || self.held.contains_key(&seq) {
            return false;
        }
        let blocks: Vec<u32> =
            (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.held.insert(seq, blocks);
        self.peak_used = self.peak_used.max(self.used());
        true
    }

    /// Grow a sequence to `new_tokens` total (decode appends). Returns
    /// false on memory pressure (caller must evict or stall).
    pub fn grow(&mut self, seq: u64, new_tokens: u32) -> bool {
        let need = self.blocks_for(new_tokens);
        let cur = match self.held.get_mut(&seq) {
            Some(v) => v,
            None => return false,
        };
        while (cur.len() as u32) < need {
            match self.free.pop() {
                Some(b) => cur.push(b),
                None => return false,
            }
        }
        self.peak_used = self.peak_used.max(self.num_blocks - self.free.len() as u32);
        true
    }

    /// Release all blocks of a finished sequence.
    pub fn release(&mut self, seq: u64) {
        if let Some(blocks) = self.held.remove(&seq) {
            self.free.extend(blocks);
        }
    }

    /// Number of sequences currently holding blocks.
    pub fn active_seqs(&self) -> usize {
        self.held.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release_cycle() {
        let mut a = BlockAllocator::new(64, 10);
        assert!(a.admit(1, 100)); // 2 blocks
        assert_eq!(a.used(), 2);
        assert!(a.grow(1, 200)); // 4 blocks
        assert_eq!(a.used(), 4);
        a.release(1);
        assert_eq!(a.used(), 0);
        assert_eq!(a.active_seqs(), 0);
    }

    #[test]
    fn admission_stalls_at_capacity() {
        let mut a = BlockAllocator::new(64, 4);
        assert!(a.admit(1, 128)); // 2 blocks
        assert!(a.admit(2, 128)); // 2 blocks
        assert!(!a.can_admit(64));
        assert!(!a.admit(3, 64));
        a.release(1);
        assert!(a.admit(3, 64));
    }

    #[test]
    fn grow_fails_gracefully_under_pressure() {
        let mut a = BlockAllocator::new(64, 2);
        assert!(a.admit(1, 64));
        assert!(a.admit(2, 64));
        assert!(!a.grow(1, 128), "no free blocks left");
        assert!(a.grow(1, 64), "no-op grow succeeds");
    }

    #[test]
    fn double_admit_rejected() {
        let mut a = BlockAllocator::new(64, 8);
        assert!(a.admit(1, 64));
        assert!(!a.admit(1, 64));
    }

    #[test]
    fn eq3_in_block_form() {
        // 60 GB KV at κ=55 KB and 64-token blocks → n_max(64K) ≈ 16 seqs.
        let a = BlockAllocator::from_budget(60_000_000_000, 55_000, 64);
        let blocks_per_seq = 65_536u32.div_ceil(64);
        let n_max = a.num_blocks / blocks_per_seq;
        assert!((15..=17).contains(&n_max), "n_max = {n_max}");
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut a = BlockAllocator::new(64, 10);
        a.admit(1, 64 * 6);
        a.release(1);
        a.admit(2, 64);
        assert_eq!(a.peak_used, 6);
    }
}
