//! Serving metrics: percentile digests for TTFT/TPOT/E2E plus counters.

/// A simple exact-percentile digest (sorted-on-demand). Capped by
//  reservoir sampling so fleet-scale simulations stay O(1) memory.
#[derive(Debug, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
    cap: usize,
    seen: u64,
    rng_state: u64,
}

/// Default reservoir cap (samples retained per digest).
pub const DEFAULT_CAP: usize = 200_000;

impl Default for Percentiles {
    fn default() -> Self {
        Self::with_cap(DEFAULT_CAP)
    }
}

impl Percentiles {
    pub fn with_cap(cap: usize) -> Self {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
            cap,
            seen: 0,
            rng_state: 0x9E3779B97F4A7C15,
        }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64* — enough for reservoir indices.
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn add(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Vitter's algorithm R.
            let j = (self.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = v;
            }
        }
        self.sorted = false;
    }

    pub fn count(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((self.samples.len() - 1) as f64 * q).round() as usize;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }

    /// True when every value ever seen is still retained.
    fn untruncated(&self) -> bool {
        self.seen as usize == self.samples.len()
    }

    /// Weighted merge of many capped reservoirs into one digest of at
    /// most `cap` samples, unbiased w.r.t. the union distribution.
    ///
    /// A retained sample of a digest that has seen `n` values but kept
    /// `k` represents `n/k` originals; naively re-adding retained
    /// samples (the pre-fix merge) ignored that weight, so a truncated
    /// pool's tail was under-represented relative to untruncated pools.
    /// Here each input's share of the output reservoir is allocated
    /// proportionally to its *true* count (largest-remainder rounding),
    /// and that many samples are drawn without replacement from its
    /// retained set — every output sample then represents the same
    /// `total_seen/cap` originals, regardless of which pool it came
    /// from. When every input is untruncated and everything fits, the
    /// merge is the exact concatenation (bit-identical to the old
    /// behavior below the cap). Deterministic: the sampling PRNG is
    /// seeded from the input counts only.
    pub fn merged_weighted<'a, I>(parts: I, cap: usize) -> Percentiles
    where
        I: IntoIterator<Item = &'a Percentiles>,
    {
        let parts: Vec<&Percentiles> = parts.into_iter().collect();
        let total_seen: u64 = parts.iter().map(|p| p.seen).sum();
        let mut out = Percentiles::with_cap(cap);
        if total_seen == 0 {
            return out;
        }
        let total_retained: usize =
            parts.iter().map(|p| p.samples.len()).sum();
        if total_retained <= cap && parts.iter().all(|p| p.untruncated()) {
            // Exact: every seen value is present exactly once.
            for p in &parts {
                out.samples.extend_from_slice(&p.samples);
            }
            out.seen = total_seen;
            out.sorted = false;
            return out;
        }

        // Largest-remainder allocation of the output reservoir by true
        // counts, clamped to what each part actually retains.
        let cap = cap.min(total_retained);
        let mut targets: Vec<usize> = Vec::with_capacity(parts.len());
        let mut remainders: Vec<(usize, f64)> = Vec::with_capacity(parts.len());
        let mut assigned = 0usize;
        for (i, p) in parts.iter().enumerate() {
            let ideal = cap as f64 * p.seen as f64 / total_seen as f64;
            let floor = (ideal.floor() as usize).min(p.samples.len());
            targets.push(floor);
            assigned += floor;
            remainders.push((i, ideal - ideal.floor()));
        }
        remainders.sort_by(|a, b| {
            b.1.total_cmp(&a.1).then(a.0.cmp(&b.0))
        });
        let mut progressed = true;
        while assigned < cap && progressed {
            progressed = false;
            for &(i, _) in &remainders {
                if assigned >= cap {
                    break;
                }
                if targets[i] < parts[i].samples.len() {
                    targets[i] += 1;
                    assigned += 1;
                    progressed = true;
                }
            }
        }

        // Deterministic seed from the inputs' shape only.
        let mut seed = 0x9E3779B97F4A7C15u64 ^ total_seen;
        for p in &parts {
            seed = seed
                .rotate_left(13)
                .wrapping_mul(0x2545F4914F6CDD1D)
                .wrapping_add(p.seen ^ p.samples.len() as u64);
        }
        out.rng_state = seed | 1;
        for (p, &t) in parts.iter().zip(&targets) {
            if t == p.samples.len() {
                out.samples.extend_from_slice(&p.samples);
                continue;
            }
            // Partial Fisher–Yates over indices: t draws w/o replacement.
            let mut idx: Vec<usize> = (0..p.samples.len()).collect();
            for k in 0..t {
                let j = k + (out.next_u64() as usize) % (idx.len() - k);
                idx.swap(k, j);
                out.samples.push(p.samples[idx[k]]);
            }
        }
        out.seen = total_seen;
        out.sorted = false;
        out
    }
}

/// The standard serving metric set.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub ttft_s: Percentiles,
    pub tpot_s: Percentiles,
    pub e2e_s: Percentiles,
    pub completed: u64,
    pub rejected: u64,
    pub output_tokens: u64,
}

impl ServeMetrics {
    pub fn record(&mut self, c: &super::request::Completion) {
        self.ttft_s.add(c.ttft_s);
        if c.output_tokens > 1 {
            self.tpot_s.add(c.tpot_s());
        }
        self.e2e_s.add(c.e2e_s);
        self.completed += 1;
        self.output_tokens += c.output_tokens as u64;
    }

    /// Merge many per-pool (or per-group) metric sets into one
    /// fleet-wide set — the per-request TTFT/TPOT/E2E digests combine by
    /// a weighted-reservoir merge, counters by summation. Scenario cells
    /// report their fleet p99 TTFT from this.
    ///
    /// Digests are capped reservoirs (200k samples by default). Below
    /// the cap the merge is the exact concatenation; beyond it, each
    /// pool's retained samples enter the merged reservoir in proportion
    /// to the pool's *true* request count
    /// ([`Percentiles::merged_weighted`]), so truncated pools' tails are
    /// weighted correctly on genuinely million-arrival cells.
    pub fn merged<'a, I>(parts: I) -> ServeMetrics
    where
        I: IntoIterator<Item = &'a ServeMetrics>,
    {
        let parts: Vec<&ServeMetrics> = parts.into_iter().collect();
        let cap = parts
            .iter()
            .map(|m| m.ttft_s.cap)
            .max()
            .unwrap_or(DEFAULT_CAP);
        ServeMetrics {
            ttft_s: Percentiles::merged_weighted(
                parts.iter().map(|m| &m.ttft_s),
                cap,
            ),
            tpot_s: Percentiles::merged_weighted(
                parts.iter().map(|m| &m.tpot_s),
                cap,
            ),
            e2e_s: Percentiles::merged_weighted(
                parts.iter().map(|m| &m.e2e_s),
                cap,
            ),
            completed: parts.iter().map(|m| m.completed).sum(),
            rejected: parts.iter().map(|m| m.rejected).sum(),
            output_tokens: parts.iter().map(|m| m.output_tokens).sum(),
        }
    }

    /// Pairwise merge (`self ∪ other`), weight-aware like [`Self::merged`].
    pub fn merge(&mut self, other: &ServeMetrics) {
        let cap = self.ttft_s.cap.max(other.ttft_s.cap);
        self.ttft_s =
            Percentiles::merged_weighted([&self.ttft_s, &other.ttft_s], cap);
        self.tpot_s =
            Percentiles::merged_weighted([&self.tpot_s, &other.tpot_s], cap);
        self.e2e_s =
            Percentiles::merged_weighted([&self.e2e_s, &other.e2e_s], cap);
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.output_tokens += other.output_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Completion;

    #[test]
    fn exact_quantiles_small() {
        let mut p = Percentiles::default();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() <= 0.5, "p50 = {}", p.p50());
        assert_eq!(p.p99(), 99.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert!((p.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn reservoir_bounds_memory() {
        let mut p = Percentiles::with_cap(1000);
        for i in 0..50_000 {
            p.add(i as f64);
        }
        assert_eq!(p.samples.len(), 1000);
        assert_eq!(p.count(), 50_000);
        // Quantiles remain approximately right.
        let p50 = p.p50();
        assert!((p50 - 25_000.0).abs() < 3_000.0, "p50 = {p50}");
    }

    #[test]
    fn metrics_record_and_merge() {
        let mut a = ServeMetrics::default();
        let mut b = ServeMetrics::default();
        a.record(&Completion { id: 1, pool: 0, output_tokens: 10, ttft_s: 0.1, e2e_s: 1.0 });
        b.record(&Completion { id: 2, pool: 1, output_tokens: 20, ttft_s: 0.2, e2e_s: 2.0 });
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.output_tokens, 30);
        assert_eq!(a.ttft_s.count(), 2);
    }

    #[test]
    fn merged_combines_many_pools() {
        let mut a = ServeMetrics::default();
        let mut b = ServeMetrics::default();
        a.record(&Completion { id: 1, pool: 0, output_tokens: 5, ttft_s: 0.1, e2e_s: 1.0 });
        b.record(&Completion { id: 2, pool: 1, output_tokens: 7, ttft_s: 0.9, e2e_s: 2.0 });
        b.rejected = 3;
        let mut m = ServeMetrics::merged([&a, &b]);
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.output_tokens, 12);
        assert_eq!(m.ttft_s.p99(), 0.9);
    }

    #[test]
    fn empty_digest_is_nan() {
        let mut p = Percentiles::default();
        assert!(p.p50().is_nan());
        assert!(p.mean().is_nan());
    }

    #[test]
    fn weighted_merge_is_exact_below_cap() {
        let mut a = Percentiles::with_cap(100);
        let mut b = Percentiles::with_cap(100);
        for i in 0..40 {
            a.add(i as f64);
        }
        for i in 40..80 {
            b.add(i as f64);
        }
        let mut m = Percentiles::merged_weighted([&a, &b], 100);
        assert_eq!(m.count(), 80);
        assert_eq!(m.samples.len(), 80);
        assert_eq!(m.quantile(1.0), 79.0);
        assert_eq!(m.quantile(0.0), 0.0);
    }

    #[test]
    fn weighted_merge_unbiased_on_unbalanced_truncated_pools() {
        // Pool A: 99k requests at 100.0, truncated to a 1k reservoir.
        // Pool B: 1k requests at 0.0, untruncated.
        // True union: 99% of mass at 100 ⇒ p50 must be 100 and only
        // ~1% of the merged reservoir should be B's zeros. The old
        // re-add merge kept A and B at ~equal sample counts (~50% zeros),
        // dragging fleet percentiles toward the small pool.
        let mut a = Percentiles::with_cap(1000);
        for _ in 0..99_000 {
            a.add(100.0);
        }
        let mut b = Percentiles::with_cap(1000);
        for _ in 0..1000 {
            b.add(0.0);
        }
        let mut m = Percentiles::merged_weighted([&a, &b], 1000);
        assert_eq!(m.count(), 100_000);
        assert_eq!(m.samples.len(), 1000);
        assert_eq!(m.p50(), 100.0);
        let zeros = m.samples.iter().filter(|&&v| v == 0.0).count();
        assert!(
            (1..=30).contains(&zeros),
            "B's share must be ≈ 1% of the reservoir, got {zeros}"
        );
    }

    #[test]
    fn weighted_merge_is_deterministic() {
        let mk = || {
            let mut a = Percentiles::with_cap(100);
            for i in 0..5_000 {
                a.add((i % 97) as f64);
            }
            let mut b = Percentiles::with_cap(100);
            for i in 0..300 {
                b.add(1000.0 + i as f64);
            }
            Percentiles::merged_weighted([&a, &b], 100)
        };
        let x = mk();
        let y = mk();
        assert_eq!(x.samples.len(), y.samples.len());
        for (u, v) in x.samples.iter().zip(&y.samples) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn fleet_merge_weights_truncated_pools_by_true_count() {
        // ServeMetrics-level: the fleet p99 TTFT of one huge truncated
        // pool (slow) + one tiny untruncated pool (fast) must reflect
        // the huge pool.
        let mut big = ServeMetrics {
            ttft_s: Percentiles::with_cap(500),
            ..Default::default()
        };
        for _ in 0..50_000 {
            big.ttft_s.add(2.0);
            big.completed += 1;
        }
        let mut small = ServeMetrics {
            ttft_s: Percentiles::with_cap(500),
            ..Default::default()
        };
        for _ in 0..500 {
            small.ttft_s.add(0.001);
            small.completed += 1;
        }
        let mut m = ServeMetrics::merged([&big, &small]);
        assert_eq!(m.completed, 50_500);
        assert_eq!(m.ttft_s.count(), 50_500);
        assert_eq!(m.ttft_s.p50(), 2.0);
        assert_eq!(m.ttft_s.p99(), 2.0);
        let fast =
            m.ttft_s.samples.iter().filter(|&&v| v == 0.001).count();
        assert!(fast <= 20, "small pool ≈ 1% of the reservoir, got {fast}");
    }
}
