//! Serving metrics: percentile digests for TTFT/TPOT/E2E plus counters.

/// A simple exact-percentile digest (sorted-on-demand). Capped by
//  reservoir sampling so fleet-scale simulations stay O(1) memory.
#[derive(Debug, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
    cap: usize,
    seen: u64,
    rng_state: u64,
}

impl Default for Percentiles {
    fn default() -> Self {
        Self::with_cap(200_000)
    }
}

impl Percentiles {
    pub fn with_cap(cap: usize) -> Self {
        Percentiles {
            samples: Vec::new(),
            sorted: true,
            cap,
            seen: 0,
            rng_state: 0x9E3779B97F4A7C15,
        }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64* — enough for reservoir indices.
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn add(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Vitter's algorithm R.
            let j = (self.next_u64() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = v;
            }
        }
        self.sorted = false;
    }

    pub fn count(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((self.samples.len() - 1) as f64 * q).round() as usize;
        self.samples[idx]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn max(&mut self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }
}

/// The standard serving metric set.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub ttft_s: Percentiles,
    pub tpot_s: Percentiles,
    pub e2e_s: Percentiles,
    pub completed: u64,
    pub rejected: u64,
    pub output_tokens: u64,
}

impl ServeMetrics {
    pub fn record(&mut self, c: &super::request::Completion) {
        self.ttft_s.add(c.ttft_s);
        if c.output_tokens > 1 {
            self.tpot_s.add(c.tpot_s());
        }
        self.e2e_s.add(c.e2e_s);
        self.completed += 1;
        self.output_tokens += c.output_tokens as u64;
    }

    /// Merge many per-pool (or per-group) metric sets into one
    /// fleet-wide set — the per-request TTFT/TPOT/E2E digests combine by
    /// re-adding samples, counters by summation. Scenario cells report
    /// their fleet p99 TTFT from this.
    ///
    /// Caveat: digests are capped reservoirs (200k samples by default).
    /// Below the cap the merge is exact; once a pool's digest has been
    /// truncated, re-adding its retained samples under-weights that pool
    /// relative to untruncated ones (each retained sample represents
    /// `seen / len` requests, which re-adding ignores). A
    /// weighted-reservoir merge is an open ROADMAP item for
    /// million-arrival sweeps.
    pub fn merged<'a, I>(parts: I) -> ServeMetrics
    where
        I: IntoIterator<Item = &'a ServeMetrics>,
    {
        let mut all = ServeMetrics::default();
        for m in parts {
            all.merge(m);
        }
        all
    }

    pub fn merge(&mut self, other: &ServeMetrics) {
        // Percentile merge via re-adding the other's samples.
        for &v in &other.ttft_s.samples {
            self.ttft_s.add(v);
        }
        for &v in &other.tpot_s.samples {
            self.tpot_s.add(v);
        }
        for &v in &other.e2e_s.samples {
            self.e2e_s.add(v);
        }
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.output_tokens += other.output_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Completion;

    #[test]
    fn exact_quantiles_small() {
        let mut p = Percentiles::default();
        for i in 1..=100 {
            p.add(i as f64);
        }
        assert!((p.p50() - 50.5).abs() <= 0.5, "p50 = {}", p.p50());
        assert_eq!(p.p99(), 99.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert!((p.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn reservoir_bounds_memory() {
        let mut p = Percentiles::with_cap(1000);
        for i in 0..50_000 {
            p.add(i as f64);
        }
        assert_eq!(p.samples.len(), 1000);
        assert_eq!(p.count(), 50_000);
        // Quantiles remain approximately right.
        let p50 = p.p50();
        assert!((p50 - 25_000.0).abs() < 3_000.0, "p50 = {p50}");
    }

    #[test]
    fn metrics_record_and_merge() {
        let mut a = ServeMetrics::default();
        let mut b = ServeMetrics::default();
        a.record(&Completion { id: 1, pool: 0, output_tokens: 10, ttft_s: 0.1, e2e_s: 1.0 });
        b.record(&Completion { id: 2, pool: 1, output_tokens: 20, ttft_s: 0.2, e2e_s: 2.0 });
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.output_tokens, 30);
        assert_eq!(a.ttft_s.count(), 2);
    }

    #[test]
    fn merged_combines_many_pools() {
        let mut a = ServeMetrics::default();
        let mut b = ServeMetrics::default();
        a.record(&Completion { id: 1, pool: 0, output_tokens: 5, ttft_s: 0.1, e2e_s: 1.0 });
        b.record(&Completion { id: 2, pool: 1, output_tokens: 7, ttft_s: 0.9, e2e_s: 2.0 });
        b.rejected = 3;
        let mut m = ServeMetrics::merged([&a, &b]);
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.output_tokens, 12);
        assert_eq!(m.ttft_s.p99(), 0.9);
    }

    #[test]
    fn empty_digest_is_nan() {
        let mut p = Percentiles::default();
        assert!(p.p50().is_nan());
        assert!(p.mean().is_nan());
    }
}
