//! The L3 serving stack: request records ([`request`]), the paged KV
//! allocator that physically enforces Eq. 3 ([`kvblocks`]), the
//! continuous batcher ([`batcher`]), the prefill/decode interleave policy
//! ([`scheduler`]), live energy metering on the calibrated `P(b)`
//! ([`energy`]), metrics ([`metrics`]), the real-model engine
//! ([`engine`]) and the serving leader ([`server`]).

pub mod batcher;
pub mod energy;
pub mod engine;
pub mod kvblocks;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, Phase, SlotWork};
pub use energy::EnergyMeter;
pub use engine::{EngineConfig, EngineReport, PoolEngine};
pub use kvblocks::BlockAllocator;
pub use metrics::{Percentiles, ServeMetrics};
pub use request::{Completion, ServeRequest};
pub use server::{render_report, serve_trace, PoolSpec, ServeReport};
