//! Serving-side request/response records and SLO clocks.
//!
//! Time is a plain `f64` in seconds: the discrete-event simulator uses a
//! virtual clock and the real-model engine uses accumulated measured step
//! latencies, so both produce directly comparable metrics.

use crate::workload::Request;

/// A request as admitted into a serving pool.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeRequest {
    pub id: u64,
    /// Prompt tokens to ingest before the first output token.
    pub prompt_tokens: u32,
    /// Output tokens to produce (synthetic traces know this up front;
    /// real traffic would stop on EOS — the serving demo stops on either).
    pub output_tokens: u32,
    /// Arrival time, seconds.
    pub arrival_s: f64,
}

impl From<&Request> for ServeRequest {
    fn from(r: &Request) -> Self {
        ServeRequest {
            id: r.id,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            arrival_s: r.arrival_s,
        }
    }
}

impl ServeRequest {
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Completion record with SLO clocks.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub pool: usize,
    pub output_tokens: u32,
    /// Time to first output token, seconds.
    pub ttft_s: f64,
    /// End-to-end latency, seconds.
    pub e2e_s: f64,
}

impl Completion {
    /// Mean time per output token after the first, seconds.
    pub fn tpot_s(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.e2e_s - self.ttft_s) / (self.output_tokens - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_and_clocks() {
        let r = Request { id: 3, arrival_s: 1.0, prompt_tokens: 10, output_tokens: 5 };
        let s = ServeRequest::from(&r);
        assert_eq!(s.total_tokens(), 15);
        let c = Completion { id: 3, pool: 0, output_tokens: 5, ttft_s: 0.1, e2e_s: 0.5 };
        assert!((c.tpot_s() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn single_token_tpot_is_zero() {
        let c = Completion { id: 0, pool: 0, output_tokens: 1, ttft_s: 0.1, e2e_s: 0.1 };
        assert_eq!(c.tpot_s(), 0.0);
    }
}
