//! Prefill/decode interleave policy.
//!
//! The paper's roofline models pure decode; real engines interleave
//! chunked prefill with decode, which steals iteration time from decoding
//! sequences (§10.1 lists this as a reason the analytical tok/W is an
//! upper bound). The scheduler bounds that interference: at most
//! `max_ingest_slots` slots may run prompt-ingestion work in one step,
//! the rest decode.

use super::batcher::{Batcher, SlotWork};

/// Interleave policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerPolicy {
    /// Max slots doing prompt ingestion per step (chunked-prefill cap).
    pub max_ingest_slots: usize,
    /// Prefer finishing ingests before starting new ones (FIFO fairness
    /// vs TTFT-greedy).
    pub ingest_fifo: bool,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy { max_ingest_slots: 2, ingest_fifo: true }
    }
}

/// Apply the policy to the batcher's raw plan: ingests beyond the cap are
/// demoted to `Idle` for this step (their slot waits; decode slots are
/// never demoted).
pub fn schedule(batcher: &Batcher, policy: &SchedulerPolicy) -> Vec<SlotWork> {
    let mut plan = batcher.plan();
    let mut ingest_seen = 0usize;

    // Optionally order ingest priority by admission time (FIFO).
    let mut order: Vec<usize> = (0..plan.len()).collect();
    if policy.ingest_fifo {
        order.sort_by(|&a, &b| {
            let ta = batcher.slots[a]
                .as_ref()
                .map(|s| s.admitted_s)
                .unwrap_or(f64::INFINITY);
            let tb = batcher.slots[b]
                .as_ref()
                .map(|s| s.admitted_s)
                .unwrap_or(f64::INFINITY);
            ta.partial_cmp(&tb).unwrap()
        });
    }

    for &i in &order {
        if let SlotWork::Ingest { .. } = plan[i] {
            ingest_seen += 1;
            if ingest_seen > policy.max_ingest_slots {
                plan[i] = SlotWork::Idle;
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::kvblocks::BlockAllocator;
    use crate::serve::request::ServeRequest;

    fn loaded_batcher(n: usize) -> Batcher {
        let mut b = Batcher::new(n, BlockAllocator::new(64, 10_000), 128, 8192);
        for i in 0..n as u64 {
            b.submit(ServeRequest {
                id: i,
                prompt_tokens: 512,
                output_tokens: 4,
                arrival_s: i as f64 * 0.1, // staggered admission order
            });
        }
        b.admit(10.0);
        b
    }

    #[test]
    fn ingest_cap_enforced() {
        let b = loaded_batcher(6);
        let plan = schedule(&b, &SchedulerPolicy { max_ingest_slots: 2, ingest_fifo: true });
        let ingests = plan
            .iter()
            .filter(|w| matches!(w, SlotWork::Ingest { .. }))
            .count();
        assert_eq!(ingests, 2);
        let idles = plan.iter().filter(|w| matches!(w, SlotWork::Idle)).count();
        assert_eq!(idles, 4);
    }

    #[test]
    fn decode_slots_never_demoted() {
        let mut b = loaded_batcher(3);
        // Push slot 0 into decode phase.
        for _ in 0..4 {
            let plan = b.plan();
            b.on_step(0, plan[0], 1.0);
        }
        let plan = schedule(&b, &SchedulerPolicy { max_ingest_slots: 0, ingest_fifo: false });
        assert!(matches!(plan[0], SlotWork::Decode));
        assert!(plan[1..].iter().all(|w| matches!(w, SlotWork::Idle)));
    }

    #[test]
    fn unlimited_policy_is_identity() {
        let b = loaded_batcher(4);
        let plan = schedule(&b, &SchedulerPolicy { max_ingest_slots: usize::MAX, ingest_fifo: false });
        assert_eq!(plan, b.plan());
    }
}
