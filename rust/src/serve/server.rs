//! The serving leader: route a request trace across pools and drive each
//! pool's engine over the real compiled model.
//!
//! Pools run sequentially on the CPU PJRT client (one emulated TP group
//! each, with its own virtual clock), so per-pool metrics are directly
//! comparable; the fleet-scale concurrent picture is the discrete-event
//! simulator's job ([`crate::sim`]).

use std::path::Path;

use super::engine::{EngineConfig, EngineReport, PoolEngine};
use super::request::ServeRequest;
use crate::router::Router;
use crate::runtime::TinyModel;
use crate::workload::Request;

/// A pool description for the real-model server.
#[derive(Debug, Clone)]
pub struct PoolSpec {
    pub name: String,
    pub config: EngineConfig,
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    pub router: String,
    pub pools: Vec<EngineReport>,
    pub total_output_tokens: u64,
    pub total_joules: f64,
    /// Fleet tok/W across pools (Σ tokens / Σ joules).
    pub tok_per_watt: f64,
    pub golden_max_err: f64,
}

/// Serve `trace` through `router` across `pools`, loading one model
/// instance per pool from `artifacts_dir`.
pub fn serve_trace(
    artifacts_dir: &Path,
    router: &dyn Router,
    pools: &[PoolSpec],
    trace: &[Request],
) -> crate::Result<ServeReport> {
    anyhow::ensure!(
        router.num_pools() == pools.len(),
        "router targets {} pools, {} configured",
        router.num_pools(),
        pools.len()
    );

    // Route the trace.
    let mut per_pool: Vec<Vec<ServeRequest>> = vec![Vec::new(); pools.len()];
    for req in trace {
        let route = router.route(req);
        let mut sreq = ServeRequest::from(req);
        sreq.prompt_tokens = route.effective_prompt_tokens;
        per_pool[route.pool].push(sreq);
    }

    // One worker thread per pool (leader/worker): each loads its own
    // model instance (PJRT handles are not Send) and drives its engine to
    // completion. Golden validation runs once on the leader.
    let golden_max_err = {
        let model = TinyModel::load(artifacts_dir)?;
        model.validate_golden()?
    };
    let mut handles = Vec::with_capacity(pools.len());
    for (i, spec) in pools.iter().enumerate() {
        let dir = artifacts_dir.to_path_buf();
        let config = spec.config.clone();
        let reqs: Vec<ServeRequest> = per_pool[i].drain(..).collect();
        handles.push(std::thread::spawn(move || -> crate::Result<EngineReport> {
            let model = TinyModel::load(&dir)?;
            let mut engine = PoolEngine::new(i, model, config)?;
            for r in reqs {
                engine.submit(r);
            }
            engine.run_to_completion()
        }));
    }
    let mut reports = Vec::with_capacity(pools.len());
    for h in handles {
        reports.push(
            h.join()
                .map_err(|_| anyhow::anyhow!("pool worker panicked"))??,
        );
    }

    let total_output_tokens: u64 = reports.iter().map(|r| r.output_tokens).sum();
    let total_joules: f64 = reports.iter().map(|r| r.joules).sum();
    Ok(ServeReport {
        router: router.name(),
        pools: reports,
        total_output_tokens,
        total_joules,
        tok_per_watt: if total_joules > 0.0 {
            total_output_tokens as f64 / total_joules
        } else {
            0.0
        },
        golden_max_err,
    })
}

/// Render a serve report for the CLI / examples.
pub fn render_report(r: &ServeReport) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    let _ = writeln!(s, "\n== serving report (router: {}) ==", r.router);
    let _ = writeln!(
        s,
        "{:<8} {:>8} {:>7} {:>9} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "pool", "window", "done", "steps", "decode t/s", "mean b", "J", "tok/W",
        "p99 TTFT"
    );
    for p in &r.pools {
        let mut m = p.metrics.clone();
        let _ = writeln!(
            s,
            "{:<8} {:>8} {:>7} {:>9} {:>10.1} {:>9.2} {:>9.1} {:>9.3} {:>8.3}s",
            p.pool,
            p.window_tokens,
            p.metrics.completed,
            p.steps,
            p.decode_tok_s,
            p.mean_batch,
            p.joules,
            p.tok_per_watt,
            m.ttft_s.p99(),
        );
    }
    let _ = writeln!(
        s,
        "total: {} output tokens, {:.1} J → {:.3} tok/W (golden max err {:.2e})",
        r.total_output_tokens, r.total_joules, r.tok_per_watt, r.golden_max_err
    );
    s
}
