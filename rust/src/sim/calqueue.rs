//! Calendar (bucket) priority queue — the event core's fast scheduler.
//!
//! A classic calendar queue (Brown 1988) hashes each pending item into a
//! "day" bucket by `floor(t / width) mod n_buckets` and serves days in
//! increasing order, giving amortized O(1) push/pop when the bucket
//! width tracks the mean inter-event gap — versus O(log n) per
//! operation for a binary heap. The simulation engine derives the
//! initial width from the trace's mean inter-arrival gap and the queue
//! re-derives it from the live population on every lazy resize.
//!
//! **Total-order contract**: [`CalendarItem::order`] must be a strict
//! total order whose *primary* key is [`CalendarItem::time`] (items with
//! smaller time must order `Less`). Under that contract [`CalendarQueue`]
//! pops items in exactly the same sequence as a binary heap over the
//! same order — the engine's `QueueMode::BinaryHeap` oracle asserts this
//! bit-for-bit on random traces.
//!
//! Why pops are exact and not merely approximate: `cur_tick` is
//! maintained as a lower bound on the year (`floor(t / width)`) of every
//! queued item — a push whose year precedes `cur_tick` rewinds it. All
//! items of one year share one bucket, and any item of a later year has
//! strictly greater time (division by a positive width is monotone), so
//! scanning years upward from `cur_tick` and taking the min-by-`order`
//! of the first non-empty year yields the global minimum.

use std::cmp::Ordering;

/// An item schedulable on a [`CalendarQueue`].
pub trait CalendarItem {
    /// The priority timestamp. Must be finite.
    fn time(&self) -> f64;

    /// Strict total order used to rank items, ascending (the queue pops
    /// the least item first). Must refine `time`: if
    /// `self.time() < other.time()` under `f64::total_cmp` this must
    /// return [`Ordering::Less`].
    fn order(&self, other: &Self) -> Ordering;
}

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;

/// Floor on the bucket width. Burst-heavy arrival sources (flash-crowd
/// spikes) can report a near-zero mean inter-event gap, and a near-zero
/// width makes every queued item's year index astronomically large —
/// each pop then wraps the whole bucket ring before hitting the
/// direct-scan fallback. The floor only bounds the *seed*; pop order is
/// width-independent (the total-order contract), so clamping never
/// changes what replays.
const MIN_WIDTH: f64 = 1e-9;

/// Bucketed event queue with lazy load-driven resize.
#[derive(Debug)]
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<T>>,
    /// Year width in time units; finite and positive by construction.
    width: f64,
    len: usize,
    /// Lower bound on the year index of every queued item.
    cur_tick: f64,
}

impl<T: CalendarItem> CalendarQueue<T> {
    /// Queue with an explicit bucket width (time units per year) and a
    /// capacity hint sizing the initial bucket array. Non-finite or
    /// non-positive widths fall back to 1.0; tiny positive widths are
    /// clamped up to [`MIN_WIDTH`].
    pub fn with_width(width: f64, capacity_hint: usize) -> Self {
        let width = if width.is_finite() && width > 0.0 {
            width.max(MIN_WIDTH)
        } else {
            1.0
        };
        let n = capacity_hint
            .max(1)
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        CalendarQueue {
            buckets: (0..n).map(|_| Vec::new()).collect(),
            width,
            len: 0,
            cur_tick: 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Year index of a timestamp under the current width.
    fn tick_of(&self, t: f64) -> f64 {
        (t / self.width).floor()
    }

    /// Bucket holding a year (years wrap around the bucket array).
    fn bucket_index(&self, tick: f64) -> usize {
        let n = self.buckets.len();
        (tick.rem_euclid(n as f64) as usize).min(n - 1)
    }

    pub fn push(&mut self, item: T) {
        let t = item.time();
        debug_assert!(t.is_finite(), "calendar queue requires finite times");
        let tick = self.tick_of(t);
        // Maintain the invariant: cur_tick never exceeds any queued
        // item's year (a push into the past rewinds the calendar).
        if tick < self.cur_tick {
            self.cur_tick = tick;
        }
        let idx = self.bucket_index(tick);
        self.buckets[idx].push(item);
        self.len += 1;
        if self.len > 2 * self.buckets.len()
            && self.buckets.len() < MAX_BUCKETS
        {
            let grown = (self.buckets.len() * 2).min(MAX_BUCKETS);
            self.rebuild(grown);
        }
    }

    /// Pop the least item under [`CalendarItem::order`].
    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        // Serve years in increasing order from the lower bound. After a
        // full wrap of empty days (possible when the population spread
        // far exceeds buckets × width), fall back to a direct scan.
        let n = self.buckets.len();
        for _ in 0..n {
            let idx = self.bucket_index(self.cur_tick);
            if let Some(i) = self.min_of_year(idx) {
                return Some(self.take(idx, i));
            }
            self.cur_tick += 1.0;
        }
        let (idx, i) = self
            .global_min()
            .expect("non-empty queue has a global minimum");
        self.cur_tick = self.tick_of(self.buckets[idx][i].time());
        Some(self.take(idx, i))
    }

    /// Index of the min-by-`order` item of year `cur_tick` inside its
    /// bucket, or `None` when the year is empty. Items of other years
    /// sharing the bucket (wrap-around collisions) are skipped.
    fn min_of_year(&self, idx: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, it) in self.buckets[idx].iter().enumerate() {
            if self.tick_of(it.time()) <= self.cur_tick {
                best = match best {
                    Some(b)
                        if self.buckets[idx][b].order(it)
                            != Ordering::Greater =>
                    {
                        Some(b)
                    }
                    _ => Some(i),
                };
            }
        }
        best
    }

    /// (bucket, index) of the global min-by-`order` item.
    fn global_min(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for (idx, bucket) in self.buckets.iter().enumerate() {
            for (i, it) in bucket.iter().enumerate() {
                best = match best {
                    Some((bidx, bi))
                        if self.buckets[bidx][bi].order(it)
                            != Ordering::Greater =>
                    {
                        Some((bidx, bi))
                    }
                    _ => Some((idx, i)),
                };
            }
        }
        best
    }

    fn take(&mut self, idx: usize, i: usize) -> T {
        let item = self.buckets[idx].swap_remove(i);
        self.len -= 1;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 4
        {
            let shrunk = (self.buckets.len() / 2).max(MIN_BUCKETS);
            self.rebuild(shrunk);
        }
        item
    }

    /// Drain into `new_n` buckets, re-deriving the width from the live
    /// population's mean gap and restarting the calendar at its
    /// earliest queued year.
    fn rebuild(&mut self, new_n: usize) {
        let items: Vec<T> = self
            .buckets
            .iter_mut()
            .flat_map(|b| b.drain(..))
            .collect();
        if items.len() >= 2 {
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for it in &items {
                let t = it.time();
                lo = lo.min(t);
                hi = hi.max(t);
            }
            let w = (hi - lo) / (items.len() - 1) as f64;
            if w.is_finite() && w > 0.0 {
                self.width = w.max(MIN_WIDTH);
            }
        }
        self.buckets = (0..new_n).map(|_| Vec::new()).collect();
        self.cur_tick = items
            .iter()
            .map(|it| self.tick_of(it.time()))
            .fold(f64::INFINITY, f64::min);
        if !self.cur_tick.is_finite() {
            self.cur_tick = 0.0;
        }
        for it in items {
            let idx = self.bucket_index(self.tick_of(it.time()));
            self.buckets[idx].push(it);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    #[derive(Debug, Clone, Copy, PartialEq)]
    struct Item {
        t: f64,
        seq: u64,
    }

    impl CalendarItem for Item {
        fn time(&self) -> f64 {
            self.t
        }
        fn order(&self, other: &Self) -> Ordering {
            self.t
                .total_cmp(&other.t)
                .then_with(|| self.seq.cmp(&other.seq))
        }
    }

    /// Max-heap wrapper popping the least (t, seq) — the oracle.
    #[derive(Debug, PartialEq)]
    struct Rev(Item);
    impl Eq for Rev {}
    impl PartialOrd for Rev {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Rev {
        fn cmp(&self, other: &Self) -> Ordering {
            other.0.order(&self.0)
        }
    }

    /// Tiny deterministic LCG so tests need no external rand crate.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            self.0 >> 11
        }
        fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
            lo + (self.next() as f64 / (1u64 << 53) as f64) * (hi - lo)
        }
    }

    #[test]
    fn drains_in_sorted_order() {
        let mut q = CalendarQueue::with_width(0.5, 8);
        let mut rng = Lcg(42);
        for seq in 0..500u64 {
            q.push(Item { t: rng.f64_in(0.0, 100.0), seq });
        }
        let mut prev: Option<Item> = None;
        let mut count = 0;
        while let Some(it) = q.pop() {
            if let Some(p) = prev {
                assert!(p.order(&it) == Ordering::Less, "{p:?} !< {it:?}");
            }
            prev = Some(it);
            count += 1;
        }
        assert_eq!(count, 500);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop_matches_binary_heap_bitwise() {
        for seed in [1u64, 7, 1234, 99999] {
            let mut rng = Lcg(seed);
            let mut q = CalendarQueue::with_width(
                rng.f64_in(1e-3, 2.0),
                rng.next() as usize % 64 + 1,
            );
            let mut h: BinaryHeap<Rev> = BinaryHeap::new();
            let mut clock = 0.0f64;
            let mut seq = 0u64;
            for _ in 0..3000 {
                if rng.next() % 3 != 0 || q.is_empty() {
                    // Mostly forward-dated pushes, occasionally at or
                    // just after the last popped time (ties on t).
                    let t = if rng.next() % 10 == 0 {
                        clock
                    } else {
                        clock + rng.f64_in(0.0, 5.0)
                    };
                    q.push(Item { t, seq });
                    h.push(Rev(Item { t, seq }));
                    seq += 1;
                } else {
                    let a = q.pop().unwrap();
                    let b = h.pop().unwrap().0;
                    assert_eq!(
                        (a.t.to_bits(), a.seq),
                        (b.t.to_bits(), b.seq),
                        "seed {seed}"
                    );
                    clock = a.t;
                }
            }
            while let Some(a) = q.pop() {
                let b = h.pop().unwrap().0;
                assert_eq!((a.t.to_bits(), a.seq), (b.t.to_bits(), b.seq));
            }
            assert!(h.pop().is_none());
        }
    }

    #[test]
    fn resize_churn_preserves_contents() {
        // Push far more items than buckets (forcing grows), then drain
        // (forcing shrinks), across a huge time spread that defeats the
        // initial width and exercises the direct-scan fallback.
        let mut q = CalendarQueue::with_width(1.0, 4);
        let mut rng = Lcg(3);
        let mut want: Vec<(u64, u64)> = Vec::new();
        for seq in 0..2000u64 {
            let t = rng.f64_in(0.0, 1e6);
            want.push((t.to_bits(), seq));
            q.push(Item { t, seq });
        }
        want.sort_by(|a, b| {
            f64::from_bits(a.0)
                .total_cmp(&f64::from_bits(b.0))
                .then_with(|| a.1.cmp(&b.1))
        });
        let got: Vec<(u64, u64)> =
            std::iter::from_fn(|| q.pop().map(|i| (i.t.to_bits(), i.seq)))
                .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn degenerate_widths_fall_back_sanely() {
        for w in [f64::NAN, 0.0, -3.0, f64::INFINITY] {
            let mut q = CalendarQueue::with_width(w, 4);
            q.push(Item { t: 2.0, seq: 0 });
            q.push(Item { t: 1.0, seq: 1 });
            assert_eq!(q.pop().unwrap().seq, 1);
            assert_eq!(q.pop().unwrap().seq, 0);
        }
    }

    #[test]
    fn near_zero_width_seed_is_clamped_and_pops_exactly() {
        // A flash-crowd gap_hint can be arbitrarily close to zero. The
        // seed must be floored so year indices stay sane, and pop order
        // must still match the heap oracle bit-for-bit over a
        // second-scale spread.
        for w in [1e-300, f64::MIN_POSITIVE, 1e-15] {
            let mut q = CalendarQueue::with_width(w, 32);
            assert_eq!(
                q.width, MIN_WIDTH,
                "seed width {w:e} not clamped to the floor"
            );
            let mut h: BinaryHeap<Rev> = BinaryHeap::new();
            let mut rng = Lcg(17);
            for seq in 0..400u64 {
                let t = rng.f64_in(0.0, 120.0);
                q.push(Item { t, seq });
                h.push(Rev(Item { t, seq }));
            }
            // Rebuild re-derives the width from the live population;
            // the clamp must hold there too.
            assert!(q.width >= MIN_WIDTH);
            while let Some(a) = q.pop() {
                let b = h.pop().unwrap().0;
                assert_eq!((a.t.to_bits(), a.seq), (b.t.to_bits(), b.seq));
                assert!(q.width >= MIN_WIDTH);
            }
            assert!(h.pop().is_none());
        }
    }

    #[test]
    fn identical_times_pop_in_seq_order() {
        let mut q = CalendarQueue::with_width(0.25, 8);
        for seq in [5u64, 1, 9, 0, 3] {
            q.push(Item { t: 7.5, seq });
        }
        let got: Vec<u64> =
            std::iter::from_fn(|| q.pop().map(|i| i.seq)).collect();
        assert_eq!(got, vec![0, 1, 3, 5, 9]);
    }
}
