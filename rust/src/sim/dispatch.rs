//! Stateful dispatch policies: which *group inside a pool* an arriving
//! request joins.
//!
//! The router (L3) decides the pool — that fixes the context window and
//! hence the `P(b)`-curve segment. Dispatch decides the group, and that
//! fixes how the pool's live batch is packed. The legacy simulator
//! hard-coded round-robin-at-arrival; the event-driven core
//! ([`super::events`]) calls a [`DispatchPolicy`] at every arrival event,
//! handing every policy a borrow of the engine's *incrementally
//! maintained* [`FleetState`](super::events::FleetState) (per-group queue
//! depth, in-flight batch, free KV blocks) — reading load costs zero
//! allocations regardless of fleet size.
//!
//! Dispatch is decide-once: a request joins its group's FIFO queue at
//! arrival and is never jockeyed to another group afterwards (matching
//! how production routers pin a request to an engine replica).

use super::events::{FleetState, GroupLoad};
use super::fleetsim::{GroupSimConfig, KV_BLOCK_TOKENS};
use crate::roofline::Roofline;
use crate::serve::request::ServeRequest;

/// The dispatch protocol. Implementations are stateful (`&mut self`):
/// round-robin keeps per-pool counters, and learned policies could keep
/// arbitrary history. Determinism contract: the decision may depend only
/// on construction parameters, prior `pick_group` calls, and the provided
/// live state — never on wall-clock or ambient randomness — so
/// simulations replay bit-for-bit.
pub trait DispatchPolicy {
    fn name(&self) -> &'static str;

    /// True when the decision depends only on the arrival *sequence*
    /// (never on `state`). Static policies let the engine skip live-state
    /// maintenance entirely and step independent groups in parallel; in
    /// exchange they **must not read `state`**, which the engine then
    /// leaves *empty* — a policy that claims to be static but indexes
    /// into the state panics on its first decision instead of silently
    /// acting on stale load.
    fn is_arrival_static(&self) -> bool {
        false
    }

    /// Pick the destination group in `[0, groups)` for `req`, which the
    /// router already sent to `pool`. `state` is the engine's live fleet
    /// load, current as of this arrival whenever this policy declares
    /// itself non-static (or the router is load-aware).
    fn pick_group(
        &mut self,
        pool: usize,
        groups: u32,
        req: &ServeRequest,
        state: &FleetState,
    ) -> usize;

    /// Called once by the engine before a run with the per-pool
    /// simulation configs, letting delay-projecting policies (the SLO
    /// guard on power-aware consolidation) learn each pool's roofline
    /// and prefill chunking. Most policies ignore it. Default: no-op.
    fn configure_pools(&mut self, _cfgs: &[GroupSimConfig]) {}
}

/// Round-robin at arrival — the legacy simulator's hard-coded policy and
/// the production default for uniform pools. Arrival-static: group =
/// (per-pool arrival index) mod groups.
#[derive(Debug, Clone, Default)]
pub struct RoundRobin {
    counters: Vec<u64>,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }

    fn counter(&mut self, pool: usize) -> &mut u64 {
        if self.counters.len() <= pool {
            self.counters.resize(pool + 1, 0);
        }
        &mut self.counters[pool]
    }
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn is_arrival_static(&self) -> bool {
        true
    }

    fn pick_group(
        &mut self,
        pool: usize,
        groups: u32,
        _req: &ServeRequest,
        _state: &FleetState,
    ) -> usize {
        let c = self.counter(pool);
        let g = (*c % groups as u64) as usize;
        *c += 1;
        g
    }
}

/// Join-shortest-queue: the group with the fewest requests in flight
/// (queued + admitted), lowest index on ties. The classic load-balancing
/// improvement over round-robin under bursty or size-skewed traffic.
#[derive(Debug, Clone, Default)]
pub struct JoinShortestQueue;

impl DispatchPolicy for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join-shortest-queue"
    }

    fn pick_group(
        &mut self,
        pool: usize,
        groups: u32,
        _req: &ServeRequest,
        state: &FleetState,
    ) -> usize {
        let p = state.pool(pool);
        argmin_by_key(groups, |g| p.in_flight(g))
    }
}

/// Least-KV-load: the group with the most free KV blocks (lowest index on
/// ties). Differs from JSQ under length-skewed traffic: ten 1K-token
/// sequences queue higher than two 60K ones, but the latter hold the KV
/// that actually gates admission (Eq. 3).
#[derive(Debug, Clone, Default)]
pub struct LeastKvLoad;

impl DispatchPolicy for LeastKvLoad {
    fn name(&self) -> &'static str {
        "least-kv-load"
    }

    fn pick_group(
        &mut self,
        pool: usize,
        groups: u32,
        _req: &ServeRequest,
        state: &FleetState,
    ) -> usize {
        // min over used blocks == max over free blocks.
        let p = state.pool(pool);
        argmin_by_key(groups, |g| u32::MAX - p.group(g).free_blocks)
    }
}

/// Power-aware consolidation: pack arrivals onto the hottest group that
/// still has batch headroom, and only then balance. Rationale: the
/// logistic `P(b)` is steep at the bottom and flat near saturation, so
/// the marginal energy of one more sequence on an already-hot group is
/// small, while landing work on a cold group pays the idle→active power
/// jump for little throughput (the paper's §5.1 long-pool observation).
///
/// **SLO guard** ([`Self::with_slo_guard`], `power-slo` on the CLI):
/// pure consolidation keeps growing the packed group's batch, and with
/// it the step time `τ(n, L̄)` every co-batched request — including an
/// arrival still ingesting its prompt — must sit through. That is the
/// p99-TTFT regression consolidation trades for energy. The guard
/// projects the delay-to-first-decode an arrival would face on each
/// hot candidate (prompt-ingest steps × τ at the grown batch, L̄ read
/// from the group's held KV blocks) and refuses to pack once the
/// projection exceeds the configured bound — typically a fraction of
/// the serving TTFT SLO — falling back to join-shortest-queue.
/// Unguarded [`PowerAware::new`] is bit-for-bit the legacy policy.
#[derive(Debug, Clone, Default)]
pub struct PowerAware {
    /// Max projected queue delay, seconds, a packed arrival may face;
    /// `None` = unguarded legacy consolidation.
    max_delay_s: Option<f64>,
    /// Per-pool (roofline, ingest chunk), learned from the engine via
    /// [`DispatchPolicy::configure_pools`].
    pools: Vec<(Roofline, u32)>,
}

impl PowerAware {
    /// Unguarded consolidation (the legacy policy).
    pub fn new() -> Self {
        Self::default()
    }

    /// Consolidation with the TTFT guard: never pack a group whose
    /// projected queue delay for this arrival exceeds `max_delay_s`
    /// (callers typically pass `fraction × slo_ttft`; the scenario
    /// layer wires `power-slo` to its own SLO).
    pub fn with_slo_guard(max_delay_s: f64) -> Self {
        assert!(
            max_delay_s.is_finite() && max_delay_s >= 0.0,
            "guard bound must be a finite non-negative delay, got \
             {max_delay_s}"
        );
        PowerAware { max_delay_s: Some(max_delay_s), pools: Vec::new() }
    }

    /// Projected delay until this arrival's first decode if it joins
    /// `gl`: every prompt-ingest chunk rides one engine step of the
    /// grown batch, each `τ(active + 1, L̄)` long, with L̄ estimated
    /// from the KV blocks the group's admitted sequences hold.
    fn projected_delay_s(
        &self,
        pool: usize,
        gl: &GroupLoad,
        req: &ServeRequest,
    ) -> f64 {
        let (roofline, chunk) = self.pools[pool];
        let l_bar = if gl.active > 0 {
            (gl.used_blocks as f64 * KV_BLOCK_TOKENS as f64
                / gl.active as f64)
                .max(1.0)
        } else {
            req.prompt_tokens as f64
        };
        let steps = req.prompt_tokens.div_ceil(chunk.max(1)).max(1) as f64;
        steps * roofline.tau_ms(gl.active as f64 + 1.0, l_bar) / 1e3
    }
}

impl DispatchPolicy for PowerAware {
    fn name(&self) -> &'static str {
        if self.max_delay_s.is_some() {
            "power-aware(slo-guard)"
        } else {
            "power-aware"
        }
    }

    fn configure_pools(&mut self, cfgs: &[GroupSimConfig]) {
        self.pools =
            cfgs.iter().map(|c| (c.roofline, c.ingest_chunk)).collect();
    }

    fn pick_group(
        &mut self,
        pool: usize,
        groups: u32,
        req: &ServeRequest,
        state: &FleetState,
    ) -> usize {
        let p = state.pool(pool);
        // Hottest group whose batch still has headroom and whose queue is
        // empty (joining it batches immediately instead of waiting).
        let mut best: Option<(usize, usize)> = None; // (active, group)
        for g in 0..groups as usize {
            let gl = p.group(g);
            if gl.queued == 0 && (gl.active as u32) < p.n_max() && gl.active > 0
            {
                if let Some(bound) = self.max_delay_s {
                    assert!(
                        !self.pools.is_empty(),
                        "SLO-guarded power dispatch needs configure_pools() \
                         before its first decision (the engine does this; \
                         direct pick_group callers must too)"
                    );
                    // Packing this group would already breach the TTFT
                    // guard — skip it, even though it is the most
                    // energy-efficient landing spot.
                    if self.projected_delay_s(pool, &gl, req) > bound {
                        continue;
                    }
                }
                // First-seen wins ties, i.e. lowest index.
                let better = match best {
                    None => true,
                    Some((a, _)) => gl.active > a,
                };
                if better {
                    best = Some((gl.active, g));
                }
            }
        }
        if let Some((_, g)) = best {
            return g;
        }
        // Everyone is cold, saturated or guard-rejected: fall back to
        // shortest queue so neither saturation nor the TTFT guard turns
        // into unbounded skew.
        argmin_by_key(groups, |g| p.in_flight(g))
    }
}

fn argmin_by_key<K: Ord>(groups: u32, key: impl Fn(usize) -> K) -> usize {
    let mut best = 0usize;
    let mut best_k = key(0);
    for g in 1..groups as usize {
        let k = key(g);
        if k < best_k {
            best = g;
            best_k = k;
        }
    }
    best
}

/// Parse a `--dispatch` CLI name.
///
/// `power-slo` here carries the crate-default guard bound (half the
/// default 0.5 s TTFT SLO); the scenario layer rebuilds it from each
/// spec's *own* SLO
/// ([`ScenarioSpec::dispatch_policy`](crate::scenario::ScenarioSpec::dispatch_policy)).
pub fn parse(name: &str) -> Option<Box<dyn DispatchPolicy>> {
    match name {
        "rr" | "round-robin" => Some(Box::new(RoundRobin::new())),
        "jsq" | "join-shortest-queue" => Some(Box::new(JoinShortestQueue)),
        "least-kv" | "least-kv-load" => Some(Box::new(LeastKvLoad)),
        "power" | "power-aware" => Some(Box::new(PowerAware::new())),
        n if is_power_slo(n) => {
            Some(Box::new(PowerAware::with_slo_guard(0.25)))
        }
        _ => None,
    }
}

/// Whether `name` names the SLO-guarded power policy. The one alias
/// set shared with the scenario layer, which rebuilds the guard from
/// its spec's own SLO instead of [`parse`]'s crate-default bound.
pub fn is_power_slo(name: &str) -> bool {
    matches!(name, "power-slo" | "power-aware-slo")
}

/// All policy names, for sweeps and tables.
pub const ALL: [&str; 5] = ["rr", "jsq", "least-kv", "power", "power-slo"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::events::{FleetState, GroupLoad, PoolLoad};

    fn req() -> ServeRequest {
        ServeRequest { id: 0, prompt_tokens: 64, output_tokens: 8, arrival_s: 0.0 }
    }

    fn state(loads: &[(usize, usize, u32)]) -> FleetState {
        FleetState::from_pools(vec![PoolLoad {
            window_tokens: 8192,
            n_max: 16,
            groups: loads
                .iter()
                .map(|&(queued, active, free_blocks)| GroupLoad {
                    queued,
                    active,
                    free_blocks,
                    used_blocks: 2048 - free_blocks,
                })
                .collect(),
        }])
    }

    /// Static policies must ignore the state entirely; hand them the
    /// emptiest one possible to prove it.
    fn no_state() -> FleetState {
        FleetState::empty()
    }

    #[test]
    fn round_robin_cycles_per_pool() {
        let mut rr = RoundRobin::new();
        let picks: Vec<usize> = (0..6)
            .map(|_| rr.pick_group(0, 3, &req(), &no_state()))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // A second pool has its own counter.
        assert_eq!(rr.pick_group(1, 3, &req(), &no_state()), 0);
        assert_eq!(rr.pick_group(0, 3, &req(), &no_state()), 0);
    }

    #[test]
    fn jsq_picks_fewest_in_flight_lowest_index_ties() {
        let s = state(&[(2, 3, 100), (0, 4, 100), (1, 3, 100)]);
        let mut jsq = JoinShortestQueue;
        assert_eq!(jsq.pick_group(0, 3, &req(), &s), 1);
        let tie = state(&[(1, 1, 100), (0, 2, 100)]);
        assert_eq!(jsq.pick_group(0, 2, &req(), &tie), 0);
    }

    #[test]
    fn least_kv_picks_most_free_blocks() {
        let s = state(&[(0, 2, 10), (0, 2, 200), (0, 2, 50)]);
        let mut lk = LeastKvLoad;
        assert_eq!(lk.pick_group(0, 3, &req(), &s), 1);
    }

    #[test]
    fn power_aware_consolidates_then_balances() {
        // Group 1 is hot with headroom -> consolidate onto it.
        let s = state(&[(0, 1, 100), (0, 9, 100), (0, 0, 100)]);
        let mut pa = PowerAware::new();
        assert_eq!(pa.pick_group(0, 3, &req(), &s), 1);
        // All saturated (n_max = 16) or queued -> shortest queue wins.
        let s2 = state(&[(5, 16, 0), (2, 16, 0), (9, 16, 0)]);
        assert_eq!(pa.pick_group(0, 3, &req(), &s2), 1);
    }

    fn h100_cfg(window: u32) -> GroupSimConfig {
        GroupSimConfig {
            window_tokens: window,
            n_max: 16,
            roofline: Roofline::manual(6.72, 0.1387),
            power: crate::power::LogisticPower::h100(),
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }
    }

    #[test]
    fn slo_guard_refuses_hot_pack_and_falls_back_to_jsq() {
        // Same fleet as the consolidation test: group 1 is the pure
        // policy's pick. The guarded policy projects the delay of
        // riding group 1's grown batch and, under a zero bound, must
        // refuse every pack and land on the JSQ choice instead.
        let s = state(&[(0, 1, 100), (0, 9, 100), (0, 0, 100)]);
        let mut strict = PowerAware::with_slo_guard(0.0);
        strict.configure_pools(&[h100_cfg(8192)]);
        assert_eq!(
            strict.pick_group(0, 3, &req(), &s),
            2,
            "zero bound: every projection is positive, fall back to JSQ"
        );
        // A generous bound admits the consolidation pick unchanged.
        let mut loose = PowerAware::with_slo_guard(1e3);
        loose.configure_pools(&[h100_cfg(8192)]);
        assert_eq!(loose.pick_group(0, 3, &req(), &s), 1);
        // The names distinguish the two on every report surface.
        assert_ne!(strict.name(), PowerAware::new().name());
    }

    #[test]
    #[should_panic(expected = "configure_pools")]
    fn unconfigured_guard_panics_instead_of_guessing() {
        let s = state(&[(0, 9, 100), (0, 0, 100)]);
        PowerAware::with_slo_guard(0.1).pick_group(0, 2, &req(), &s);
    }

    #[test]
    fn parse_covers_all_names() {
        for n in ALL {
            assert!(parse(n).is_some(), "{n}");
        }
        assert!(parse("bogus").is_none());
        assert!(parse("rr").unwrap().is_arrival_static());
        assert!(!parse("jsq").unwrap().is_arrival_static());
    }
}
