//! The event-driven simulation core: one calendar event queue, one
//! virtual clock, all groups of all pools advancing concurrently.
//!
//! Three event kinds drive the engine:
//!
//! * **Arrival** — a request reaches the fleet: the router picks the pool
//!   (reading the engine's live [`FleetState`]), the [`DispatchPolicy`]
//!   picks the group, and the request joins that group's FIFO queue. An
//!   arrival to a quiescent group schedules a *wake*.
//! * **StepComplete** — a group's in-flight engine iteration finishes:
//!   outcomes (chunked prompt ingestion, decoded tokens, completions) are
//!   applied at the step-end timestamp, then the group immediately plans
//!   its next step from live `(n_active, L̄)` via the roofline.
//! * **Wake** — a previously idle group re-enters the stepping loop. The
//!   idle gap is integrated into the energy meter at the meter's standing
//!   batch: idle watts for a group that has never run (the paper's §5.1
//!   "nearly idle yet still draws watts" effect), and — matching the
//!   legacy loop's piecewise-constant-from-the-left convention exactly —
//!   the last step's `P(n_active)` for a gap that follows a drain.
//!
//! Ties are broken deterministically by `(time, kind, push-sequence)`
//! with arrivals first, so a request arriving exactly at a step boundary
//! is admitted on that boundary — matching the legacy closed loop
//! bit-for-bit under round-robin dispatch (asserted by
//! `tests/sim_replay.rs`).
//!
//! **Event queue**: pending events live in a calendar/bucket queue
//! ([`super::calqueue`]) whose bucket width is seeded from the trace's
//! mean inter-arrival gap and re-derived on lazy resizes — amortized
//! O(1) push/pop versus the O(log n) binary heap it replaced, the
//! difference that dominates at λ ≥ 1000. The heap survives behind
//! [`QueueMode::BinaryHeap`] as the bit-for-bit replay oracle (both
//! orders are the same strict total order, so the pop sequences are
//! identical — property-tested across dispatch policies on random
//! traces), exactly as [`StateMode::RebuildPerArrival`] was kept when
//! the incremental live state replaced per-arrival snapshots.
//!
//! **Struct-of-arrays fleet state**: the hot per-group fields — local
//! clock, busy flag, queue depth, batch occupancy, free/used KV blocks —
//! live in contiguous lanes of a [`GroupSimState`], indexed by the
//! flattened (pool, group) lane id. Dispatch scans (`argmin` over a
//! pool's groups) and per-event refreshes walk a few cache lines instead
//! of pointer-chasing per-group structs; the cold machinery (batcher,
//! energy meter, metrics) stays in per-group [`GroupSim`] structs that
//! only the owning event touches. Routers and policies read the lanes
//! through [`FleetState::pool`]'s borrowed [`PoolView`], still at zero
//! allocation cost. The live state is **maintained incrementally**: after
//! every event only the touched group's lanes are refreshed. The
//! pre-refactor rebuild-a-snapshot-per-arrival behavior is preserved as
//! [`StateMode::RebuildPerArrival`] — the verification oracle
//! (`tests/properties.rs` asserts both modes replay bit-for-bit on
//! random traces) and the "before" baseline of `bench_sim_engine` —
//! and [`EngineOptions::validate_state`] additionally cross-checks the
//! live state against a fresh snapshot after *every* event.
//!
//! **Parallel fast path**: when the router is not load-aware and the
//! dispatch policy is arrival-static, group assignment is a pure function
//! of the arrival sequence, so independent groups can be stepped on
//! worker threads (`std::thread::scope`; the offline image has no rayon)
//! and merged in group-index order. Per-group event streams are identical
//! either way, so sequential and parallel runs produce bit-identical
//! results (property-tested). The materialized form
//! ([`run_fleet_auto`]) pre-assigns the whole trace on the calling
//! thread, then fans the per-group request lists out over a shared
//! atomic work queue ([`super::par::run_indexed`] — no static chunking,
//! so one slow group never idles the other workers). The streaming form
//! ([`run_fleet_stream_sharded`]) keeps O(1)-per-group memory instead:
//! the calling thread becomes a **demux** that pulls one request at a
//! time from the [`ArrivalSource`], routes it (same [`assign`] call as
//! the pre-assign loop, effective prompt baked in), and sends it down
//! the owning group's bounded `mpsc` channel; one scoped thread per
//! group runs the ordinary [`run_fleet_stream`] engine over a
//! [`ChannelSource`](crate::workload::arrival::ChannelSource). Bounded
//! channels give backpressure both ways, so total memory is
//! O(groups × buffer) regardless of trace length. Bitwise equivalence
//! is the composition of two proved facts: the demux delivers each
//! group exactly the request subsequence the pre-assign loop would
//! bucket for it (same pure assignment function, same order), and a
//! per-group streamed run replays a per-group materialized run
//! bit-for-bit (the seq-offset argument below). Hence sharded-streamed
//! ≡ materialized-parallel ≡ sequential, float for float — pinned by
//! `prop_parallel_stream_replays_sequential_bitwise` across all five
//! dispatch policies × both queue modes × both step modes.
//!
//! **Streaming arrivals**: [`run_fleet`] takes a materialized, sorted
//! trace and enqueues every arrival up front; [`run_fleet_stream`]
//! instead pulls one request at a time from an
//! [`ArrivalSource`](crate::workload::arrival::ArrivalSource), keeping
//! exactly one pending arrival in the event queue — O(1) trace memory
//! at any λ·duration. The two replay bit-for-bit because `seq` only
//! breaks ties between events with equal `(time, class)`: arrivals
//! only tie with arrivals, and their relative push order is the same
//! 0, 1, 2, … on both paths; steps and wakes share one counter
//! incremented at identical processing points, so starting it at 0
//! instead of `trace.len()` offsets every step/wake `seq` uniformly
//! and flips no comparison. Identical pop order ⇒ identical meters
//! (asserted bitwise across all dispatch policies and both queue modes
//! by `tests/properties.rs` and the in-module tests).
//! Sources must yield non-decreasing times (asserted), which also
//! guarantees the calendar queue never sees a backward push.
//! [`run_fleet_stream_auto`] picks between the sequential engine and
//! the sharded demux exactly the way [`run_fleet_auto`] picks its
//! paths: `opts.allow_parallel` plus [`parallel_eligible`]. Both feed
//! variants run one shared [`drive`] loop parameterized over the
//! arrival [`Feed`], so they cannot drift apart in event handling.
//!
//! **Macro-stepping**: between consecutive arrivals a group's batch
//! composition evolves by a deterministic recurrence — admit finds an
//! empty queue, plan/τ(n, L̄)/apply depend only on the group's own
//! state — so scheduling one `StepComplete` event per decode iteration
//! buys ordering flexibility nothing needs. Under the default
//! [`StepMode::Fused`], [`start_step`] runs that recurrence in a tight
//! in-line loop: each iteration makes the *same* calls in the *same*
//! order as the event-driven path (admit, plan, `tau_ms`, meter
//! `observe`, apply plan), and only falls back to scheduling a real
//! event for the first step whose end time `t_end` does not satisfy
//! `t_end < next_arrival` — i.e. the step's completion is no longer
//! provably the group's next observable moment. The comparison is a
//! plain `<` on purpose: when it is true, `(t_end, STEP)` strictly
//! precedes `(next_arrival, ARRIVAL)` in the pop order, so fusing the
//! step is exactly what the event queue would have done; when it is
//! false (including the `-0.0 < 0.0` and NaN edges where `<` and
//! `total_cmp` disagree), the engine conservatively schedules the
//! event and lets the queue arbitrate — slower, never wrong. No other
//! horizon needs tracking: slot completions, ingest-phase changes and
//! the meter all live inside the per-iteration calls, which the loop
//! re-runs every step. Because every arrival at time t pops before any
//! step or wake at t (class order), `next_arrival` is always strictly
//! ahead of the handler's `now`, and because all steps that precede an
//! arrival are applied before it in both modes, live-state reads at
//! arrivals — and therefore routing, dispatch, and every float — are
//! bit-identical. The event count, not the results, is what changes:
//! events popped scale with arrivals + quiesce boundaries instead of
//! decode steps ([`FleetRun::events_popped`] surfaces the count, the
//! `macro_step` bench section asserts the ≥10× reduction at λ=4000).
//!
//! **Four replay oracles, one pattern**: every performance-motivated
//! rewrite of this engine kept its predecessor alive behind an options
//! switch as a bit-for-bit replay oracle, so correctness is always one
//! equality assertion away from the slow-but-obvious implementation:
//!
//! * [`QueueMode::BinaryHeap`] — the heap scheduler the calendar/bucket
//!   queue replaced;
//! * [`StateMode::RebuildPerArrival`] — the per-arrival fleet snapshot
//!   the incremental live state replaced;
//! * the materialized trace ([`run_fleet`]) — the all-upfront arrival
//!   path the streaming feed replaced;
//! * [`StepMode::PerStep`] — the one-event-per-decode-step schedule
//!   that macro-stepping replaced.
//!
//! All four axes compose, and `tests/properties.rs` pins the fused
//! default against the per-step oracle across every dispatch policy ×
//! both queue modes × streamed/materialized feeds on random traces.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::calqueue::{CalendarItem, CalendarQueue};
use super::dispatch::{DispatchPolicy, RoundRobin};
use super::fleetsim::GroupSimConfig;
use crate::router::{HomogeneousRouter, Router};
use crate::serve::batcher::{Batcher, SlotWork};
use crate::serve::energy::EnergyMeter;
use crate::serve::kvblocks::BlockAllocator;
use crate::serve::metrics::ServeMetrics;
use crate::serve::request::ServeRequest;
use crate::workload::arrival::{ArrivalSource, ChannelSource};
use crate::workload::Request;

/// Live load of one group, as routers and dispatch policies see it.
/// Inside the engine the four fields live in the struct-of-arrays lanes
/// of [`GroupSimState`]; this is the assembled per-group value that
/// [`PoolView::group`] returns and that test/bench constructors build
/// fleet states from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupLoad {
    /// Requests waiting in the group's FIFO queue.
    pub queued: usize,
    /// Sequences admitted into slots (the in-flight batch).
    pub active: usize,
    /// Free KV blocks in the group's paged allocator.
    pub free_blocks: u32,
    /// KV blocks currently held by admitted sequences.
    pub used_blocks: u32,
}

impl GroupLoad {
    /// Queued + admitted — the JSQ load signal.
    pub fn in_flight(&self) -> usize {
        self.queued + self.active
    }
}

/// Load of one pool in assembled (array-of-structs) form — the builder
/// type for [`FleetState::from_pools`] and the shape snapshots are
/// described in. Policies read live load through [`PoolView`] instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolLoad {
    pub window_tokens: u32,
    /// Per-group concurrency limit (Eq. 3's n_max for this window).
    pub n_max: u32,
    pub groups: Vec<GroupLoad>,
}

/// Static per-pool metadata of the struct-of-arrays fleet state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolMeta {
    pub window_tokens: u32,
    /// Per-group concurrency limit (Eq. 3's n_max for this window).
    pub n_max: u32,
}

/// The hot per-group simulation state, struct-of-arrays: one contiguous
/// lane per field, indexed by the flattened (pool, group) lane id
/// (pool-major, group-minor — pool p's groups occupy
/// `base[p]..base[p+1]`). `clock`/`busy` are the engine's own scheduling
/// state; the four load lanes are what routing and dispatch read.
#[derive(Debug, Clone, Default)]
pub struct GroupSimState {
    /// Local group clock: last boundary or fast-forward time.
    pub(crate) clock: Vec<f64>,
    /// A step or wake event is scheduled for this group.
    pub(crate) busy: Vec<bool>,
    pub(crate) queued: Vec<usize>,
    pub(crate) active: Vec<usize>,
    pub(crate) free_blocks: Vec<u32>,
    pub(crate) used_blocks: Vec<u32>,
}

/// The live load of the whole fleet, handed to
/// [`Router::route_live`](crate::router::Router::route_live) and
/// [`DispatchPolicy::pick_group`] at every arrival.
///
/// The engine maintains exactly one of these per run, *incrementally*:
/// after each event only the touched group's lanes are refreshed, so
/// reading it is a borrow, never an allocation. Storage is
/// struct-of-arrays ([`GroupSimState`]) so a dispatch scan over one
/// pool's groups is a walk over contiguous lanes; [`Self::pool`] exposes
/// a pool's slice of each lane as a [`PoolView`]. It is plain data —
/// clone it if a policy needs to hold load across decisions.
///
/// Equality compares the load lanes (and pool metadata) only: the
/// `clock`/`busy` scheduling lanes are engine-internal and a snapshot
/// rebuilt from batcher state cannot know them.
#[derive(Debug, Clone)]
pub struct FleetState {
    pub(crate) meta: Vec<PoolMeta>,
    /// Lane offsets: pool p's groups are lanes `base[p]..base[p+1]`.
    pub(crate) base: Vec<usize>,
    pub(crate) s: GroupSimState,
}

impl PartialEq for FleetState {
    fn eq(&self, other: &Self) -> bool {
        self.meta == other.meta
            && self.base == other.base
            && self.s.queued == other.s.queued
            && self.s.active == other.s.active
            && self.s.free_blocks == other.s.free_blocks
            && self.s.used_blocks == other.s.used_blocks
    }
}
impl Eq for FleetState {}

/// One pool's slice of the fleet's struct-of-arrays load lanes —
/// what [`FleetState::pool`] hands a router or dispatch policy.
#[derive(Debug, Clone, Copy)]
pub struct PoolView<'a> {
    meta: PoolMeta,
    queued: &'a [usize],
    active: &'a [usize],
    free_blocks: &'a [u32],
    used_blocks: &'a [u32],
}

impl PoolView<'_> {
    pub fn window_tokens(&self) -> u32 {
        self.meta.window_tokens
    }

    /// Per-group concurrency limit (Eq. 3's n_max for this window).
    pub fn n_max(&self) -> u32 {
        self.meta.n_max
    }

    pub fn num_groups(&self) -> usize {
        self.queued.len()
    }

    /// Assemble one group's load from the lanes.
    pub fn group(&self, g: usize) -> GroupLoad {
        GroupLoad {
            queued: self.queued[g],
            active: self.active[g],
            free_blocks: self.free_blocks[g],
            used_blocks: self.used_blocks[g],
        }
    }

    /// Queued + admitted of one group — the JSQ load signal.
    pub fn in_flight(&self, g: usize) -> usize {
        self.queued[g] + self.active[g]
    }

    /// Total queued + admitted across the pool's groups.
    pub fn in_flight_total(&self) -> usize {
        self.queued.iter().sum::<usize>() + self.active.iter().sum::<usize>()
    }

    /// Mean queued + admitted per group.
    pub fn backlog_per_group(&self) -> f64 {
        if self.queued.is_empty() {
            0.0
        } else {
            self.in_flight_total() as f64 / self.queued.len() as f64
        }
    }

    /// Mean *waiting* requests per group — the cross-pool congestion
    /// signal load-aware routers compare. Queue depth, not in-flight
    /// batch: a well-batched pool with free slots is busy, not
    /// congested, and comparing raw in-flight counts across pools is
    /// biased because n_max differs per window (Eq. 3).
    pub fn queued_per_group(&self) -> f64 {
        if self.queued.is_empty() {
            0.0
        } else {
            self.queued.iter().sum::<usize>() as f64 / self.queued.len() as f64
        }
    }
}

impl FleetState {
    /// The all-idle state of a freshly configured fleet: empty queues,
    /// empty batches, every paged-KV block on the free list. This is
    /// what the engine's live state starts from. (Paths where nobody may
    /// read the state — arrival-static pre-assignment, static-only
    /// sequential runs — instead hand consumers an [`Self::empty`]
    /// canary, so a policy that falsely declares itself static and reads
    /// anyway panics on the first index instead of silently acting on
    /// stale load.)
    pub fn initial(pool_groups: &[u32], cfgs: &[GroupSimConfig]) -> Self {
        let meta = cfgs
            .iter()
            .map(|c| PoolMeta { window_tokens: c.window_tokens, n_max: c.n_max })
            .collect();
        let mut base = Vec::with_capacity(pool_groups.len() + 1);
        let mut total = 0usize;
        base.push(0);
        for &g in pool_groups {
            total += g as usize;
            base.push(total);
        }
        let mut s = GroupSimState {
            clock: vec![0.0; total],
            busy: vec![false; total],
            queued: vec![0; total],
            active: vec![0; total],
            free_blocks: vec![0; total],
            used_blocks: vec![0; total],
        };
        for (p, cfg) in cfgs.iter().enumerate() {
            for lane in base[p]..base[p + 1] {
                s.free_blocks[lane] = cfg.blocks_total();
            }
        }
        FleetState { meta, base, s }
    }

    /// The zero-pool canary state: any indexed read panics. Handed to
    /// routing/dispatch on paths where no consumer may legitimately
    /// read live load.
    pub fn empty() -> Self {
        FleetState {
            meta: Vec::new(),
            base: vec![0],
            s: GroupSimState::default(),
        }
    }

    /// Build a state from assembled per-pool loads — the constructor for
    /// tests, benches and [`snapshot`]s. Scheduling lanes default to
    /// idle (t = 0, not busy).
    pub fn from_pools(pools: Vec<PoolLoad>) -> Self {
        let meta = pools
            .iter()
            .map(|p| PoolMeta { window_tokens: p.window_tokens, n_max: p.n_max })
            .collect();
        let mut base = vec![0usize];
        let mut s = GroupSimState::default();
        for p in &pools {
            for g in &p.groups {
                s.clock.push(0.0);
                s.busy.push(false);
                s.queued.push(g.queued);
                s.active.push(g.active);
                s.free_blocks.push(g.free_blocks);
                s.used_blocks.push(g.used_blocks);
            }
            base.push(s.queued.len());
        }
        FleetState { meta, base, s }
    }

    pub fn num_pools(&self) -> usize {
        self.meta.len()
    }

    /// Borrow one pool's slice of every load lane.
    pub fn pool(&self, p: usize) -> PoolView<'_> {
        let (lo, hi) = (self.base[p], self.base[p + 1]);
        PoolView {
            meta: self.meta[p],
            queued: &self.s.queued[lo..hi],
            active: &self.s.active[lo..hi],
            free_blocks: &self.s.free_blocks[lo..hi],
            used_blocks: &self.s.used_blocks[lo..hi],
        }
    }

    /// Overwrite one group's load lanes — test/bench plumbing for
    /// constructing specific load shapes.
    pub fn set_group(&mut self, pool: usize, group: usize, load: GroupLoad) {
        let lane = self.lane(pool, group);
        self.s.queued[lane] = load.queued;
        self.s.active[lane] = load.active;
        self.s.free_blocks[lane] = load.free_blocks;
        self.s.used_blocks[lane] = load.used_blocks;
    }

    /// Flattened lane id of (pool, group).
    fn lane(&self, pool: usize, group: usize) -> usize {
        let lane = self.base[pool] + group;
        assert!(
            lane < self.base[pool + 1],
            "group {group} out of range for pool {pool}"
        );
        lane
    }

    /// Refresh one group's load lanes from its live batcher — the
    /// O(1)-in-fleet-size update the engine applies after every event
    /// that touches the group.
    fn refresh_group(&mut self, pool: usize, group: usize, gs: &GroupSim) {
        let lane = self.lane(pool, group);
        self.s.queued[lane] = gs.batcher.queued_len();
        self.s.active[lane] = gs.batcher.active();
        self.s.free_blocks[lane] = gs.batcher.blocks.free_blocks();
        self.s.used_blocks[lane] = gs.batcher.blocks.used();
    }
}

/// How the engine supplies [`FleetState`] to load-aware routing and
/// dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StateMode {
    /// Maintain one live state in place (O(changed group) per event).
    /// The production mode.
    #[default]
    Incremental,
    /// Rebuild a full snapshot at every arrival — the pre-refactor
    /// behavior, O(total groups) allocations per arrival. Kept as the
    /// verification oracle for the incremental path and as the "before"
    /// baseline in `bench_sim_engine`.
    RebuildPerArrival,
}

/// Which scheduler orders the engine's pending events. Both implement
/// the same strict `(time, kind, sequence)` total order, so the pop
/// sequences — and therefore entire simulations — are bit-identical;
/// only the cost differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueMode {
    /// Calendar/bucket queue ([`super::calqueue`]), bucket width seeded
    /// from the trace's mean inter-arrival gap — amortized O(1) per
    /// event. The production mode.
    #[default]
    Calendar,
    /// The pre-refactor `BinaryHeap` scheduler, O(log n) per event.
    /// Kept as the bit-for-bit replay oracle and the "before" baseline
    /// in `bench_sim_engine`.
    BinaryHeap,
}

/// How the engine schedules a group's decode/ingest iterations.
/// Both modes make the identical per-step calls in the identical
/// order, so entire simulations are bit-identical; only the number of
/// events that transit the queue differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StepMode {
    /// Macro-stepping: run every step whose end time provably precedes
    /// the next arrival in one in-line loop, scheduling a single fused
    /// `StepComplete` at the horizon — events scale with arrivals, not
    /// decode steps. The production mode.
    #[default]
    Fused,
    /// One `StepComplete` event per engine iteration — the pre-fusion
    /// schedule. Kept as the bit-for-bit replay oracle and the
    /// "before" baseline of the `macro_step` bench section.
    PerStep,
}

/// Engine knobs beyond the (trace, router, policy) triple.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// Step independent groups on worker threads when routing and
    /// dispatch are arrival-static (bit-identical to sequential).
    pub allow_parallel: bool,
    /// Live-state maintenance strategy.
    pub state_mode: StateMode,
    /// Event-queue implementation ([`QueueMode`]).
    pub queue_mode: QueueMode,
    /// Step scheduling strategy ([`StepMode`]).
    pub step_mode: StepMode,
    /// Cross-check the incrementally maintained state against a freshly
    /// built snapshot after **every** event (O(fleet) per event — tests
    /// only). Panics on the first divergence. Requires
    /// [`StateMode::Incremental`] and a load-aware router or non-static
    /// dispatch policy — any combination where the live state is never
    /// maintained is rejected up front (the check would otherwise pass
    /// vacuously).
    pub validate_state: bool,
}

/// Reject `validate_state` requests that could never check anything.
fn assert_validate_applicable(
    router: &dyn Router,
    dispatch: &dyn DispatchPolicy,
    opts: EngineOptions,
) {
    if opts.validate_state {
        assert!(
            opts.state_mode == StateMode::Incremental
                && (router.is_load_aware() || !dispatch.is_arrival_static()),
            "validate_state requires StateMode::Incremental and a \
             load-aware router or non-static dispatch policy; with this \
             combination the live state is never maintained, so the \
             cross-check would pass without checking anything"
        );
    }
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            allow_parallel: true,
            state_mode: StateMode::Incremental,
            queue_mode: QueueMode::Calendar,
            step_mode: StepMode::Fused,
            validate_state: false,
        }
    }
}

/// Per-group simulation result, aggregated by the pool/topology wrappers
/// in [`super::fleetsim`] in group-index order (so aggregation is
/// independent of event interleaving and thread scheduling).
#[derive(Debug)]
pub(crate) struct GroupOutcome {
    pub(crate) metrics: ServeMetrics,
    pub(crate) joules: f64,
    pub(crate) output_tokens: u64,
    pub(crate) horizon_s: f64,
    pub(crate) mean_batch: f64,
    pub(crate) steps: u64,
}

const CLASS_ARRIVAL: u8 = 0;
const CLASS_STEP: u8 = 1;
const CLASS_WAKE: u8 = 2;

#[derive(Debug, Clone, Copy)]
enum EvKind {
    Arrival { idx: usize },
    StepComplete { pool: usize, group: usize },
    Wake { pool: usize, group: usize },
}

#[derive(Debug)]
struct Ev {
    t: f64,
    class: u8,
    seq: u64,
    kind: EvKind,
}

impl Ev {
    /// The engine's strict total event order, ascending: earliest time
    /// first, arrivals before step-completions before wakes at equal
    /// times, FIFO within a kind. Every event carries a unique `seq`,
    /// so no two distinct events compare `Equal`.
    fn key_cmp(&self, other: &Self) -> Ordering {
        self.t
            .total_cmp(&other.t)
            .then_with(|| self.class.cmp(&other.class))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.key_cmp(other) == Ordering::Equal
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed so the max-heap pops the smallest key first.
        other.key_cmp(self)
    }
}

impl CalendarItem for Ev {
    fn time(&self) -> f64 {
        self.t
    }
    fn order(&self, other: &Self) -> Ordering {
        self.key_cmp(other)
    }
}

/// The engine's scheduler, behind [`QueueMode`]: both variants pop the
/// identical `(time, kind, sequence)` order.
enum EventQueue {
    Calendar(CalendarQueue<Ev>),
    Heap(BinaryHeap<Ev>),
}

impl EventQueue {
    fn new(mode: QueueMode, width: f64, capacity: usize) -> Self {
        match mode {
            QueueMode::Calendar => {
                EventQueue::Calendar(CalendarQueue::with_width(width, capacity))
            }
            QueueMode::BinaryHeap => {
                EventQueue::Heap(BinaryHeap::with_capacity(capacity))
            }
        }
    }

    fn push(&mut self, ev: Ev) {
        match self {
            EventQueue::Calendar(q) => q.push(ev),
            EventQueue::Heap(h) => h.push(ev),
        }
    }

    fn pop(&mut self) -> Option<Ev> {
        match self {
            EventQueue::Calendar(q) => q.pop(),
            EventQueue::Heap(h) => h.pop(),
        }
    }
}

/// Calendar bucket width for a trace: its mean inter-arrival gap
/// (step/wake events densify the schedule from there; lazy resizes
/// re-derive the width from the live population as that happens).
fn trace_bucket_width(trace: &[Request]) -> f64 {
    if trace.len() < 2 {
        return 1.0;
    }
    let span = trace[trace.len() - 1].arrival_s - trace[0].arrival_s;
    let w = span / (trace.len() - 1) as f64;
    if w.is_finite() && w > 0.0 {
        w
    } else {
        1.0
    }
}

/// One virtual GPU group: the same `Batcher` state machine the real
/// engine runs, plus its energy meter. The group's scheduling state
/// (local clock, busy flag) lives in the fleet's [`GroupSimState`]
/// lanes, not here.
struct GroupSim {
    batcher: Batcher,
    meter: EnergyMeter,
    metrics: ServeMetrics,
    /// Work plan of the in-flight step, applied at its StepComplete.
    pending_plan: Option<Vec<SlotWork>>,
    steps: u64,
}

impl GroupSim {
    fn new(cfg: &GroupSimConfig) -> Self {
        GroupSim {
            batcher: Batcher::new(
                cfg.n_max as usize,
                BlockAllocator::new(
                    super::fleetsim::KV_BLOCK_TOKENS,
                    cfg.blocks_total(),
                ),
                cfg.ingest_chunk,
                cfg.window_tokens,
            ),
            meter: EnergyMeter::new(cfg.power, cfg.gpus_charged, 0.0),
            metrics: ServeMetrics::default(),
            pending_plan: None,
            steps: 0,
        }
    }

    /// `horizon_s` is the group's final clock-lane value.
    fn finish(self, horizon_s: f64) -> GroupOutcome {
        GroupOutcome {
            joules: self.meter.joules().0,
            output_tokens: self.meter.output_tokens(),
            horizon_s,
            mean_batch: self.meter.mean_batch(),
            metrics: self.metrics,
            steps: self.steps,
        }
    }
}

/// Build a point-in-time copy of the whole fleet's load — O(total
/// groups). The engine no longer does this per arrival; it remains as
/// the [`StateMode::RebuildPerArrival`] oracle and the
/// `validate_state` cross-check.
fn snapshot(pools: &[Vec<GroupSim>], cfgs: &[GroupSimConfig]) -> FleetState {
    FleetState::from_pools(
        pools
            .iter()
            .zip(cfgs)
            .map(|(groups, cfg)| PoolLoad {
                window_tokens: cfg.window_tokens,
                n_max: cfg.n_max,
                groups: groups
                    .iter()
                    .map(|g| GroupLoad {
                        queued: g.batcher.queued_len(),
                        active: g.batcher.active(),
                        free_blocks: g.batcher.blocks.free_blocks(),
                        used_blocks: g.batcher.blocks.used(),
                    })
                    .collect(),
            })
            .collect(),
    )
}

/// Route + dispatch one arrival: pool from the router, group from the
/// policy, effective prompt baked into the returned request — all
/// borrowing the engine's live `state` (the contract behind
/// [`Router::is_load_aware`] and
/// [`DispatchPolicy::is_arrival_static`](super::dispatch::DispatchPolicy::is_arrival_static):
/// consumers that declare themselves static promise not to read it, so
/// the engine only keeps it fresh when someone will). The single
/// definition keeps the sequential engine and the parallel
/// pre-assignment bit-for-bit in agreement.
fn assign(
    router: &dyn Router,
    dispatch: &mut dyn DispatchPolicy,
    pool_groups: &[u32],
    req: &Request,
    state: &FleetState,
) -> (usize, usize, ServeRequest) {
    let route = router.route_live(req, state);
    let mut sreq = ServeRequest::from(req);
    sreq.prompt_tokens = route.effective_prompt_tokens;
    let group =
        dispatch.pick_group(route.pool, pool_groups[route.pool], &sreq, state);
    (route.pool, group, sreq)
}

/// Apply a finished step's work plan at its boundary timestamp: chunked
/// prompt ingestion advances, decode slots emit one token each and may
/// complete. The single definition is shared by the event-driven path
/// ([`handle_step_complete`]) and the fused in-line loop
/// ([`start_step`]), so the two cannot diverge in what a step does.
fn apply_plan(gs: &mut GroupSim, plan: Vec<SlotWork>, now: f64) {
    for (i, w) in plan.into_iter().enumerate() {
        match w {
            SlotWork::Idle => {}
            SlotWork::Ingest { .. } => {
                gs.batcher.on_step(i, w, now);
            }
            SlotWork::Decode => {
                gs.meter.add_output_tokens(1);
                if let Some(c) = gs.batcher.on_step(i, SlotWork::Decode, now) {
                    gs.metrics.record(&c);
                }
            }
        }
    }
}

/// Plan the group's next step from its live `(n_active, L̄)` operating
/// point, or quiesce if nothing is admitted. `clock`/`busy` are the
/// group's scheduling lanes.
///
/// Under [`StepMode::Fused`] this is a loop, not a single plan: every
/// step whose end time `t_end` satisfies the strict `t_end <
/// next_arrival` is applied in line (the queue would have popped its
/// `StepComplete` before anything else the group can observe — see the
/// module docs for why plain `<` is exactly the safe test), and only
/// the first step that reaches the horizon is scheduled as a real
/// event. `next_arrival` is the timestamp of the next unconsumed
/// arrival ([`Feed::next_arrival_t`]), `f64::INFINITY` once the feed
/// is drained; it is strictly greater than `now` on every call because
/// arrivals pop before same-time steps and wakes. Per-step mode never
/// enters the fused branch, preserving the one-event-per-step oracle.
#[allow(clippy::too_many_arguments)]
fn start_step(
    gs: &mut GroupSim,
    cfg: &GroupSimConfig,
    now: f64,
    q: &mut EventQueue,
    seq: &mut u64,
    pool: usize,
    group: usize,
    clock: &mut f64,
    busy: &mut bool,
    step_mode: StepMode,
    next_arrival: f64,
) {
    let mut now = now;
    loop {
        gs.batcher.admit(now);
        if gs.batcher.active() == 0 {
            // Nothing in flight: quiesce; the next arrival wakes the
            // group (and accounts the idle-power gap).
            *busy = false;
            *clock = now;
            return;
        }
        let plan = gs.batcher.plan();
        let n_active = plan
            .iter()
            .filter(|w| !matches!(w, SlotWork::Idle))
            .count() as f64;
        let l_bar = gs.batcher.mean_kv_len().max(1.0);
        let dt = cfg.roofline.tau_ms(n_active, l_bar) / 1e3;
        let t_end = now + dt;
        gs.meter.observe(t_end, n_active);
        gs.steps += 1;
        if step_mode == StepMode::Fused && t_end < next_arrival {
            // Fuse: the step's completion strictly precedes every event
            // the queue could pop, so apply it here — same calls, same
            // order, same floats as the event-driven path.
            *clock = t_end;
            apply_plan(gs, plan, t_end);
            now = t_end;
            continue;
        }
        gs.pending_plan = Some(plan);
        *seq += 1;
        q.push(Ev {
            t: t_end,
            class: CLASS_STEP,
            seq: *seq,
            kind: EvKind::StepComplete { pool, group },
        });
        return;
    }
}

/// Topology sanity checks shared by every engine entry point (the
/// streaming path has no trace to scan, so the per-request finiteness
/// check lives in [`validate_fleet_inputs`] and inline at the pull
/// site of `run_fleet_stream`).
fn validate_topology_inputs(
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
) {
    assert_eq!(
        router.num_pools(),
        pool_cfgs.len(),
        "router targets {} pools, {} configured",
        router.num_pools(),
        pool_cfgs.len()
    );
    assert_eq!(pool_groups.len(), pool_cfgs.len());
    assert!(pool_groups.iter().all(|&g| g > 0), "empty pool");
}

fn validate_fleet_inputs(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
) {
    validate_topology_inputs(router, pool_groups, pool_cfgs);
    for r in trace {
        assert!(
            r.arrival_s.is_finite(),
            "non-finite arrival time for request {}",
            r.id
        );
    }
}

/// Handle one arrival: route + dispatch it, submit to the chosen
/// group's queue, and wake the group if it was quiescent. Shared
/// verbatim by the materialized and streaming engines, so the two can
/// only diverge in how events are *ordered* — which the seq-offset
/// argument in the module docs rules out.
#[allow(clippy::too_many_arguments)]
fn handle_arrival(
    req: &Request,
    now: f64,
    router: &dyn Router,
    dispatch: &mut dyn DispatchPolicy,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    pools: &mut [Vec<GroupSim>],
    q: &mut EventQueue,
    seq: &mut u64,
    live: &mut FleetState,
    canary: &FleetState,
    need_state: bool,
    track: bool,
    state_mode: StateMode,
) {
    // Legacy oracle mode only: rebuild the full snapshot the
    // pre-refactor engine allocated on every arrival.
    let rebuilt = (need_state && state_mode == StateMode::RebuildPerArrival)
        .then(|| snapshot(pools, pool_cfgs));
    let state_ref: &FleetState = match &rebuilt {
        Some(s) => s,
        None if track => live,
        None => canary,
    };
    let (pool, group, sreq) =
        assign(router, dispatch, pool_groups, req, state_ref);
    assert!(
        pool < pools.len() && group < pools[pool].len(),
        "dispatch out of range: pool {pool} group {group}"
    );
    let lane = live.lane(pool, group);
    let gs = &mut pools[pool][group];
    if !gs.batcher.submit(sreq) {
        gs.metrics.rejected += 1;
    }
    if !live.s.busy[lane] {
        // Fast-forward the quiescent group to now: the gap integrates
        // at the meter's standing batch — idle power for a never-run
        // group, the final step's P(n_active) after a drain (the
        // legacy loop's left-constant convention, kept for replay).
        live.s.busy[lane] = true;
        gs.meter.observe(now, 0.0);
        live.s.clock[lane] = now;
        *seq += 1;
        q.push(Ev {
            t: now,
            class: CLASS_WAKE,
            seq: *seq,
            kind: EvKind::Wake { pool, group },
        });
    }
    if track {
        live.refresh_group(pool, group, &pools[pool][group]);
    }
}

/// Apply a finished step's work plan at its boundary, then immediately
/// plan the group's next step(s). Shared by both feeds.
#[allow(clippy::too_many_arguments)]
fn handle_step_complete(
    pool: usize,
    group: usize,
    now: f64,
    pool_cfgs: &[GroupSimConfig],
    pools: &mut [Vec<GroupSim>],
    q: &mut EventQueue,
    seq: &mut u64,
    live: &mut FleetState,
    track: bool,
    step_mode: StepMode,
    next_arrival: f64,
) {
    let lane = live.lane(pool, group);
    live.s.clock[lane] = now;
    let gs = &mut pools[pool][group];
    let plan = gs
        .pending_plan
        .take()
        .expect("StepComplete without an in-flight plan");
    apply_plan(gs, plan, now);
    start_step(
        gs,
        &pool_cfgs[pool],
        now,
        q,
        seq,
        pool,
        group,
        &mut live.s.clock[lane],
        &mut live.s.busy[lane],
        step_mode,
        next_arrival,
    );
    if track {
        live.refresh_group(pool, group, &pools[pool][group]);
    }
}

/// Re-enter the stepping loop after an idle gap. Shared by both feeds.
#[allow(clippy::too_many_arguments)]
fn handle_wake(
    pool: usize,
    group: usize,
    now: f64,
    pool_cfgs: &[GroupSimConfig],
    pools: &mut [Vec<GroupSim>],
    q: &mut EventQueue,
    seq: &mut u64,
    live: &mut FleetState,
    track: bool,
    step_mode: StepMode,
    next_arrival: f64,
) {
    let lane = live.lane(pool, group);
    let gs = &mut pools[pool][group];
    start_step(
        gs,
        &pool_cfgs[pool],
        now,
        q,
        seq,
        pool,
        group,
        &mut live.s.clock[lane],
        &mut live.s.busy[lane],
        step_mode,
        next_arrival,
    );
    if track {
        live.refresh_group(pool, group, &pools[pool][group]);
    }
}

/// Drain finished groups into per-pool outcomes, in index order.
fn finish_outcomes(
    pools: Vec<Vec<GroupSim>>,
    live: &FleetState,
) -> Vec<Vec<GroupOutcome>> {
    let mut out: Vec<Vec<GroupOutcome>> = Vec::with_capacity(pools.len());
    let mut lane = 0usize;
    for groups in pools {
        let mut pool_out = Vec::with_capacity(groups.len());
        for g in groups {
            pool_out.push(g.finish(live.s.clock[lane]));
            lane += 1;
        }
        out.push(pool_out);
    }
    out
}

/// One engine run's results: per-group outcomes in (pool, group) index
/// order plus the number of events that transited the queue — the cost
/// metric macro-stepping exists to shrink. `events_popped` is invariant
/// across queue modes, state modes and materialized/streamed feeds, but
/// *not* across step modes (that asymmetry is the point) nor across the
/// sequential/parallel paths in fused mode: a group simulated in
/// isolation fuses past other groups' arrivals, so the per-group sum
/// undercounts the shared-queue run. Outcome floats are bit-identical
/// on every path regardless.
#[derive(Debug)]
pub(crate) struct FleetRun {
    pub(crate) pools: Vec<Vec<GroupOutcome>>,
    pub(crate) events_popped: u64,
}

/// Where [`drive`] gets its arrivals — the one axis on which the
/// materialized and streaming engines differ. Everything downstream of
/// the pop loop is shared, so the two paths cannot drift apart.
enum Feed<'a> {
    /// Every arrival pre-pushed into the queue; `cursor` tracks the
    /// next not-yet-popped index (arrivals pop in push order because
    /// the trace is sorted and seq breaks ties FIFO).
    Materialized { trace: &'a [Request], cursor: usize },
    /// Exactly one pending arrival in the queue at a time, pulled
    /// lazily from the source.
    Stream {
        source: &'a mut dyn ArrivalSource,
        pending: Option<Request>,
        arrival_seq: u64,
    },
}

impl Feed<'_> {
    /// Timestamp of the next arrival the queue will pop — the fusion
    /// horizon of [`start_step`] — or `f64::INFINITY` once the feed is
    /// drained. Strictly greater than the current event's time whenever
    /// a step or wake handler runs, because every arrival at that time
    /// has already popped (class order).
    fn next_arrival_t(&self) -> f64 {
        match self {
            Feed::Materialized { trace, cursor } => trace
                .get(*cursor)
                .map_or(f64::INFINITY, |r| r.arrival_s),
            Feed::Stream { pending, .. } => {
                pending.as_ref().map_or(f64::INFINITY, |r| r.arrival_s)
            }
        }
    }
}

/// The shared event loop both entry points delegate to: pop, dispatch
/// on kind, maintain/validate live state, count events, finish. The
/// feed is the only behavioral parameter — see [`Feed`].
#[allow(clippy::too_many_arguments)]
fn drive(
    mut feed: Feed<'_>,
    mut q: EventQueue,
    mut seq: u64,
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
    mut pools: Vec<Vec<GroupSim>>,
) -> FleetRun {
    let need_state = router.is_load_aware() || !dispatch.is_arrival_static();
    // Refresh the live load lanes in place only when someone will read
    // them AND we are not in the legacy rebuild-per-arrival oracle mode.
    let track = need_state && opts.state_mode == StateMode::Incremental;
    // The SoA state itself is always allocated: its clock/busy lanes are
    // the engine's own per-group scheduling state, maintained on every
    // path. The one-off initial build is O(total groups) once per run.
    let mut live = FleetState::initial(pool_groups, pool_cfgs);
    // When nobody may legitimately read the state (static-only run, or
    // the rebuild oracle supplying its own snapshots), hand out an
    // empty canary instead: a policy that lies about being static and
    // indexes into it panics immediately rather than silently deciding
    // from stale load.
    let canary = FleetState::empty();
    let mut events_popped: u64 = 0;

    while let Some(ev) = q.pop() {
        events_popped += 1;
        match ev.kind {
            EvKind::Arrival { idx } => match &mut feed {
                Feed::Materialized { trace, cursor } => {
                    *cursor = idx + 1;
                    handle_arrival(
                        &trace[idx],
                        ev.t,
                        router,
                        dispatch,
                        pool_groups,
                        pool_cfgs,
                        &mut pools,
                        &mut q,
                        &mut seq,
                        &mut live,
                        &canary,
                        need_state,
                        track,
                        opts.state_mode,
                    );
                }
                Feed::Stream { source, pending, arrival_seq } => {
                    let req = pending
                        .take()
                        .expect("arrival event without a pending request");
                    // Pull the successor before handling, so the queue
                    // already orders it against whatever steps/wakes
                    // the current arrival schedules — and so the
                    // fusion horizon those handlers read is the true
                    // next arrival. The pending arrival always
                    // precedes every future arrival (non-decreasing
                    // time, lower seq within the arrival class), so
                    // the pop candidates match the materialized run's
                    // exactly.
                    if let Some(next) = source.next() {
                        assert!(
                            next.arrival_s.is_finite(),
                            "non-finite arrival time for request {}",
                            next.id
                        );
                        assert!(
                            next.arrival_s >= req.arrival_s,
                            "arrival source must be non-decreasing in time: \
                             request {} at t = {} after t = {}",
                            next.id,
                            next.arrival_s,
                            req.arrival_s
                        );
                        *arrival_seq += 1;
                        q.push(Ev {
                            t: next.arrival_s,
                            class: CLASS_ARRIVAL,
                            seq: *arrival_seq,
                            kind: EvKind::Arrival {
                                idx: *arrival_seq as usize,
                            },
                        });
                        *pending = Some(next);
                    }
                    handle_arrival(
                        &req,
                        ev.t,
                        router,
                        dispatch,
                        pool_groups,
                        pool_cfgs,
                        &mut pools,
                        &mut q,
                        &mut seq,
                        &mut live,
                        &canary,
                        need_state,
                        track,
                        opts.state_mode,
                    );
                }
            },
            EvKind::StepComplete { pool, group } => handle_step_complete(
                pool,
                group,
                ev.t,
                pool_cfgs,
                &mut pools,
                &mut q,
                &mut seq,
                &mut live,
                track,
                opts.step_mode,
                feed.next_arrival_t(),
            ),
            EvKind::Wake { pool, group } => handle_wake(
                pool,
                group,
                ev.t,
                pool_cfgs,
                &mut pools,
                &mut q,
                &mut seq,
                &mut live,
                track,
                opts.step_mode,
                feed.next_arrival_t(),
            ),
        }
        if opts.validate_state && track {
            assert!(
                live == snapshot(&pools, pool_cfgs),
                "incremental FleetState diverged from a fresh snapshot \
                 after event at t = {}",
                ev.t
            );
        }
    }

    FleetRun { pools: finish_outcomes(pools, &live), events_popped }
}

pub(crate) fn run_fleet(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> FleetRun {
    validate_fleet_inputs(trace, router, pool_groups, pool_cfgs);
    assert_validate_applicable(router, &*dispatch, opts);
    // Hand delay-projecting policies (the power-slo TTFT guard) the
    // per-pool rooflines before the first decision; a no-op for the
    // classic policies.
    dispatch.configure_pools(pool_cfgs);
    debug_assert!(
        trace.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "run_fleet requires an arrival-sorted trace"
    );

    let pools: Vec<Vec<GroupSim>> = pool_groups
        .iter()
        .zip(pool_cfgs)
        .map(|(&g, cfg)| (0..g).map(|_| GroupSim::new(cfg)).collect())
        .collect();

    let mut q = EventQueue::new(
        opts.queue_mode,
        trace_bucket_width(trace),
        trace.len() + 16,
    );
    for (i, r) in trace.iter().enumerate() {
        q.push(Ev {
            t: r.arrival_s,
            class: CLASS_ARRIVAL,
            seq: i as u64,
            kind: EvKind::Arrival { idx: i },
        });
    }
    let seq = trace.len() as u64;
    drive(
        Feed::Materialized { trace, cursor: 0 },
        q,
        seq,
        router,
        pool_groups,
        pool_cfgs,
        dispatch,
        opts,
        pools,
    )
}

/// Run the fleet over a lazy [`ArrivalSource`], pulling one request at
/// a time: exactly one pending arrival lives in the event queue, so
/// trace memory is O(1) at any λ·duration. The source must yield
/// non-decreasing arrival times (asserted per pull).
///
/// Bit-for-bit equivalent to [`run_fleet`] on the materialized
/// collection of the same source — see the module docs for the
/// seq-offset argument, and `tests/properties.rs` for the property
/// pinning it across all dispatch policies and both queue modes.
/// Always sequential; for the arrival-static parallel form see
/// [`run_fleet_stream_sharded`] (or [`run_fleet_stream_auto`], which
/// picks automatically).
pub(crate) fn run_fleet_stream(
    source: &mut dyn ArrivalSource,
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> FleetRun {
    validate_topology_inputs(router, pool_groups, pool_cfgs);
    assert_validate_applicable(router, &*dispatch, opts);
    dispatch.configure_pools(pool_cfgs);

    let pools: Vec<Vec<GroupSim>> = pool_groups
        .iter()
        .zip(pool_cfgs)
        .map(|(&g, cfg)| (0..g).map(|_| GroupSim::new(cfg)).collect())
        .collect();

    // The queue never holds more than one arrival plus at most one
    // step/wake per group, so its capacity is fleet-sized, not
    // trace-sized; the bucket width comes from the source's rate hint
    // instead of a measured trace span.
    let total_groups: usize =
        pool_groups.iter().map(|&g| g as usize).sum();
    let mut q = EventQueue::new(
        opts.queue_mode,
        source.gap_hint(),
        total_groups * 2 + 16,
    );

    // Arrivals carry their own seq counter (0, 1, 2, … in pull order —
    // the same relative order the materialized path assigns them);
    // steps/wakes share `seq` as in `run_fleet`, offset by not knowing
    // the trace length up front, which no comparison can observe.
    let arrival_seq: u64 = 0;
    let mut pending: Option<Request> = None;
    if let Some(r) = source.next() {
        assert!(
            r.arrival_s.is_finite(),
            "non-finite arrival time for request {}",
            r.id
        );
        q.push(Ev {
            t: r.arrival_s,
            class: CLASS_ARRIVAL,
            seq: arrival_seq,
            kind: EvKind::Arrival { idx: arrival_seq as usize },
        });
        pending = Some(r);
    }
    drive(
        Feed::Stream { source, pending, arrival_seq },
        q,
        0,
        router,
        pool_groups,
        pool_cfgs,
        dispatch,
        opts,
        pools,
    )
}

/// Bounded per-group channel capacity of the sharded streaming demux.
/// Small on purpose: total buffered memory is O(groups × this), and the
/// buffer only needs to be deep enough to keep a group's engine fed
/// while the demux round-robins over the others.
const SHARD_BUFFER: usize = 64;

/// The sharded parallel streaming path: pull one request at a time
/// from the source on the calling thread, route it (arrival-static, so
/// the assignment is a pure function of the arrival sequence), and
/// send it down the owning group's bounded channel; one scoped thread
/// per group runs the ordinary sequential [`run_fleet_stream`] engine
/// over its [`ChannelSource`]. Results merge in flattened (pool,
/// group) index order, and `events_popped` sums the per-group queues —
/// exactly the materialized parallel path's count. Memory is
/// O(groups × [`SHARD_BUFFER`]) regardless of trace length.
///
/// Callers must check [`parallel_eligible`] first (debug-asserted);
/// use [`run_fleet_stream_auto`] to pick the path automatically.
pub(crate) fn run_fleet_stream_sharded(
    source: &mut dyn ArrivalSource,
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> FleetRun {
    validate_topology_inputs(router, pool_groups, pool_cfgs);
    assert_validate_applicable(router, &*dispatch, opts);
    debug_assert!(
        parallel_eligible(router, &*dispatch, pool_groups),
        "sharded streaming requires an arrival-static scenario"
    );
    dispatch.configure_pools(pool_cfgs);

    let gap = source.gap_hint();
    // Static consumers must never read live load; the canary panics on
    // any read, exposing a policy that lied about being arrival-static
    // (same guard as the materialized pre-assign loop).
    let idle = FleetState::empty();

    // One bounded channel per flattened (pool, group) lane; the
    // receivers move into the group threads, the senders stay with the
    // demux. Dropping the senders is the end-of-trace signal.
    let mut senders: Vec<Vec<std::sync::mpsc::SyncSender<Request>>> =
        pool_groups.iter().map(|&g| Vec::with_capacity(g as usize)).collect();
    let mut jobs: Vec<(usize, std::sync::mpsc::Receiver<Request>)> =
        Vec::new();
    for (pool, &g) in pool_groups.iter().enumerate() {
        for _ in 0..g {
            let (tx, rx) = std::sync::mpsc::sync_channel(SHARD_BUFFER);
            senders[pool].push(tx);
            jobs.push((pool, rx));
        }
    }

    let (outcomes, events_popped) = std::thread::scope(|scope| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|(pool, rx)| {
                let cfg = &pool_cfgs[pool];
                scope.spawn(move || {
                    let mut src = ChannelSource::new(rx, gap);
                    let mut rr = RoundRobin::new();
                    let run = run_fleet_stream(
                        &mut src,
                        &HomogeneousRouter,
                        &[1],
                        std::slice::from_ref(cfg),
                        &mut rr,
                        EngineOptions {
                            queue_mode: opts.queue_mode,
                            step_mode: opts.step_mode,
                            ..Default::default()
                        },
                    );
                    let FleetRun { mut pools, events_popped } = run;
                    let outcome = pools
                        .pop()
                        .expect("one pool")
                        .pop()
                        .expect("one group");
                    (pool, outcome, events_popped)
                })
            })
            .collect();

        // The demux: the same validate + assign sequence the sequential
        // stream feed and the materialized pre-assign loop run, so a
        // malformed source fails identically on every path. If a group
        // thread dies, its receiver hangs up and the send fails —
        // propagate instead of silently dropping arrivals (the real
        // panic resurfaces at join below).
        let mut last_t = f64::NEG_INFINITY;
        for r in &mut *source {
            assert!(
                r.arrival_s.is_finite(),
                "non-finite arrival time for request {}",
                r.id
            );
            assert!(
                r.arrival_s >= last_t,
                "arrival source must be non-decreasing in time: \
                 request {} at t = {} after t = {}",
                r.id,
                r.arrival_s,
                last_t
            );
            last_t = r.arrival_s;
            let (pool, group, s) =
                assign(router, dispatch, pool_groups, &r, &idle);
            senders[pool][group]
                .send(Request {
                    id: r.id,
                    arrival_s: r.arrival_s,
                    prompt_tokens: s.prompt_tokens,
                    output_tokens: r.output_tokens,
                })
                .expect("sharded group worker hung up mid-trace");
        }
        drop(senders);

        // Joining in job order *is* the group-index-order merge.
        let mut outcomes: Vec<(usize, GroupOutcome)> = Vec::new();
        let mut events_popped = 0u64;
        for h in handles {
            let (pool, outcome, events) =
                h.join().expect("sharded group worker panicked");
            events_popped += events;
            outcomes.push((pool, outcome));
        }
        (outcomes, events_popped)
    });

    let mut out: Vec<Vec<GroupOutcome>> =
        pool_groups.iter().map(|_| Vec::new()).collect();
    for (pool, outcome) in outcomes {
        out[pool].push(outcome);
    }
    FleetRun { pools: out, events_popped }
}

/// Streaming analogue of [`run_fleet_auto`]: take the sharded parallel
/// demux when `opts.allow_parallel` holds and the scenario is
/// arrival-static, the sequential single-queue engine otherwise. Both
/// paths are bit-identical, so the choice is pure performance.
pub(crate) fn run_fleet_stream_auto(
    source: &mut dyn ArrivalSource,
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> FleetRun {
    if opts.allow_parallel && parallel_eligible(router, &*dispatch, pool_groups)
    {
        run_fleet_stream_sharded(
            source,
            router,
            pool_groups,
            pool_cfgs,
            dispatch,
            opts,
        )
    } else {
        run_fleet_stream(source, router, pool_groups, pool_cfgs, dispatch, opts)
    }
}

/// Simulate one group in isolation — the unit of work of the parallel
/// fast path. Runs the exact same event engine (one pool, one group), so
/// per-group results are bit-identical to the shared-queue run. The
/// returned event count covers this group's private queue only; in
/// fused mode the group fuses past the *fleet's* other arrivals, so
/// the per-group sum is a lower bound on the shared-queue count.
fn run_one_group(
    reqs: &[Request],
    cfg: &GroupSimConfig,
    queue_mode: QueueMode,
    step_mode: StepMode,
) -> (GroupOutcome, u64) {
    let mut rr = RoundRobin::new();
    let run = run_fleet(
        reqs,
        &HomogeneousRouter,
        &[1],
        std::slice::from_ref(cfg),
        &mut rr,
        EngineOptions { queue_mode, step_mode, ..Default::default() },
    );
    let FleetRun { mut pools, events_popped } = run;
    let outcome = pools.pop().expect("one pool").pop().expect("one group");
    (outcome, events_popped)
}

/// Whether `run_fleet_auto` may take the parallel per-group path.
pub(crate) fn parallel_eligible(
    router: &dyn Router,
    dispatch: &dyn DispatchPolicy,
    pool_groups: &[u32],
) -> bool {
    !router.is_load_aware()
        && dispatch.is_arrival_static()
        && pool_groups.iter().map(|&g| g as u64).sum::<u64>() > 1
}

/// Run the fleet, stepping independent groups on worker threads when the
/// routing/dispatch combination is arrival-static (group assignment
/// precomputed on this thread, results merged in group-index order).
/// Falls back to the sequential shared-queue engine otherwise.
pub(crate) fn run_fleet_auto(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> FleetRun {
    assert_validate_applicable(router, &*dispatch, opts);
    if !(opts.allow_parallel
        && parallel_eligible(router, &*dispatch, pool_groups))
    {
        return run_fleet(trace, router, pool_groups, pool_cfgs, dispatch, opts);
    }
    // Same input contract as the sequential engine — a malformed
    // topology must fail identically on both paths.
    validate_fleet_inputs(trace, router, pool_groups, pool_cfgs);
    dispatch.configure_pools(pool_cfgs);

    // Pre-assign: for arrival-static dispatch the (pool, group) of every
    // request is a pure function of the arrival sequence — an empty
    // canary state stands in for live load, which static consumers must
    // not read (reading it panics, loudly exposing a policy that lied
    // about being arrival-static). Bake the router's effective-prompt
    // transform into the stored request so the per-group engine can run
    // it through an identity router.
    let idle = FleetState::empty();
    let mut per_group: Vec<Vec<Vec<Request>>> = pool_groups
        .iter()
        .map(|&g| vec![Vec::new(); g as usize])
        .collect();
    for r in trace {
        let (pool, group, s) = assign(router, dispatch, pool_groups, r, &idle);
        per_group[pool][group].push(Request {
            id: r.id,
            arrival_s: r.arrival_s,
            prompt_tokens: s.prompt_tokens,
            output_tokens: r.output_tokens,
        });
    }

    // Flatten to (pool, arrivals) jobs and fan them out over the shared
    // atomic work queue — no static chunking, so one heavy group never
    // idles the other workers. `run_indexed` returns results in job
    // order, which is exactly the group-index merge order.
    let jobs: Vec<(usize, Vec<Request>)> = per_group
        .into_iter()
        .enumerate()
        .flat_map(|(p, groups)| groups.into_iter().map(move |reqs| (p, reqs)))
        .collect();
    let workers = super::par::resolve_workers(None);
    let results = super::par::run_indexed(jobs.len(), workers, |i| {
        let (pool, reqs) = &jobs[i];
        run_one_group(reqs, &pool_cfgs[*pool], opts.queue_mode, opts.step_mode)
    });

    let mut out: Vec<Vec<GroupOutcome>> =
        pool_groups.iter().map(|_| Vec::new()).collect();
    let mut events_popped = 0u64;
    for ((pool, _), (outcome, events)) in jobs.iter().zip(results) {
        events_popped += events;
        out[*pool].push(outcome);
    }
    FleetRun { pools: out, events_popped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::{GpuProfile, ManualProfile};
    use crate::workload::synth::{generate, GenConfig};

    fn cfg(window: u32) -> GroupSimConfig {
        let p = ManualProfile::h100_70b();
        GroupSimConfig {
            window_tokens: window,
            n_max: p.n_max(window),
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }
    }

    fn small_trace(seed: u64) -> Vec<Request> {
        generate(
            &crate::workload::cdf::azure_conversations(),
            &GenConfig {
                lambda_rps: 40.0,
                duration_s: 2.0,
                max_prompt_tokens: 6000,
                max_output_tokens: 128,
                seed,
            },
        )
    }

    #[test]
    fn event_ordering_is_time_then_class_then_seq() {
        let mk = |t, class, seq| Ev {
            t,
            class,
            seq,
            kind: EvKind::Arrival { idx: 0 },
        };
        let want = vec![
            (0.5, CLASS_WAKE, 1),
            (1.0, CLASS_ARRIVAL, 2),
            (1.0, CLASS_ARRIVAL, 9),
            (1.0, CLASS_STEP, 5),
        ];
        for mode in [QueueMode::Calendar, QueueMode::BinaryHeap] {
            let mut q = EventQueue::new(mode, 0.25, 4);
            q.push(mk(1.0, CLASS_STEP, 5));
            q.push(mk(1.0, CLASS_ARRIVAL, 9));
            q.push(mk(0.5, CLASS_WAKE, 1));
            q.push(mk(1.0, CLASS_ARRIVAL, 2));
            let order: Vec<(f64, u8, u64)> = std::iter::from_fn(|| q.pop())
                .map(|e| (e.t, e.class, e.seq))
                .collect();
            assert_eq!(order, want, "{mode:?}");
        }
    }

    #[test]
    fn all_requests_complete_and_energy_accrues() {
        let trace = small_trace(1);
        let n = trace.len() as u64;
        let mut rr = RoundRobin::new();
        let out = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[2],
            &[cfg(8192)],
            &mut rr,
            EngineOptions::default(),
        )
        .pools;
        let completed: u64 = out[0].iter().map(|g| g.metrics.completed).sum();
        let tokens: u64 = out[0].iter().map(|g| g.output_tokens).sum();
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        assert_eq!(completed, n);
        assert_eq!(tokens, want, "token conservation");
        assert!(out[0].iter().all(|g| g.joules > 0.0));
    }

    #[test]
    fn parallel_path_is_bit_identical_to_sequential() {
        let trace = small_trace(7);
        let seq_out = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        )
        .pools;
        let par_out = run_fleet_auto(
            &trace,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        )
        .pools;
        for (s, p) in seq_out[0].iter().zip(&par_out[0]) {
            assert_eq!(s.joules.to_bits(), p.joules.to_bits());
            assert_eq!(s.output_tokens, p.output_tokens);
            assert_eq!(s.horizon_s.to_bits(), p.horizon_s.to_bits());
            assert_eq!(s.steps, p.steps);
            assert_eq!(s.metrics.completed, p.metrics.completed);
        }
    }

    #[test]
    fn sharded_stream_is_bit_identical_to_sequential_stream() {
        use crate::router::context::ContextRouter;
        use crate::workload::VecSource;

        // Two pools, five groups, arrival-static scenario: the sharded
        // demux must replay both the sequential streamed run and the
        // materialized parallel run bit for bit (and agree with the
        // materialized parallel path on events_popped — per-group
        // queues count identically on both parallel forms).
        let mut trace = generate(
            &crate::workload::cdf::azure_conversations(),
            &GenConfig {
                lambda_rps: 40.0,
                duration_s: 2.0,
                max_prompt_tokens: 20_000,
                max_output_tokens: 128,
                seed: 13,
            },
        );
        trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let router = ContextRouter::two_pool(4096);
        let groups = [3u32, 2];
        let cfgs = [cfg(4096 + 1024), cfg(65_536)];
        let opts = EngineOptions::default();

        let mut src = VecSource::new(trace.clone());
        let seq = run_fleet_stream(
            &mut src,
            &router,
            &groups,
            &cfgs,
            &mut RoundRobin::new(),
            EngineOptions { allow_parallel: false, ..opts },
        );
        let mut src = VecSource::new(trace.clone());
        let sharded = run_fleet_stream_sharded(
            &mut src,
            &router,
            &groups,
            &cfgs,
            &mut RoundRobin::new(),
            opts,
        );
        let mat = run_fleet_auto(
            &trace,
            &router,
            &groups,
            &cfgs,
            &mut RoundRobin::new(),
            opts,
        );
        assert_eq!(sharded.events_popped, mat.events_popped);
        for (p, (s, m)) in sharded.pools.iter().zip(&mat.pools).enumerate() {
            assert_eq!(s.len(), m.len(), "pool {p} group count");
        }
        for (oracle, label) in [(&seq, "sequential"), (&mat, "materialized")] {
            for (sp, op) in sharded.pools.iter().zip(&oracle.pools) {
                for (s, o) in sp.iter().zip(op) {
                    assert_eq!(
                        s.joules.to_bits(),
                        o.joules.to_bits(),
                        "{label} joules"
                    );
                    assert_eq!(s.output_tokens, o.output_tokens, "{label}");
                    assert_eq!(
                        s.horizon_s.to_bits(),
                        o.horizon_s.to_bits(),
                        "{label} horizon"
                    );
                    assert_eq!(s.steps, o.steps, "{label}");
                    assert_eq!(
                        s.metrics.completed,
                        o.metrics.completed,
                        "{label}"
                    );
                }
            }
        }
    }

    #[test]
    fn wake_integrates_idle_power() {
        // One request arriving after 5 idle seconds: the wake must charge
        // the gap at idle watts.
        let trace = vec![Request {
            id: 0,
            arrival_s: 5.0,
            prompt_tokens: 100,
            output_tokens: 10,
        }];
        let out = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[1],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        )
        .pools;
        assert!(out[0][0].joules > 5.0 * 299.0, "idle joules missing");
        assert_eq!(out[0][0].metrics.completed, 1);
    }

    #[test]
    #[should_panic(expected = "non-finite arrival")]
    fn nan_arrival_rejected() {
        let trace = vec![Request {
            id: 0,
            arrival_s: f64::NAN,
            prompt_tokens: 10,
            output_tokens: 1,
        }];
        run_fleet(
            &trace,
            &HomogeneousRouter,
            &[1],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        );
    }

    #[test]
    #[should_panic(expected = "validate_state requires")]
    fn vacuous_validate_state_rejected() {
        // Static router + static policy never read the live state, so a
        // validate_state run would check nothing — reject it loudly.
        let trace = small_trace(2);
        run_fleet(
            &trace,
            &HomogeneousRouter,
            &[1],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions { validate_state: true, ..Default::default() },
        );
    }

    #[test]
    fn initial_state_matches_fresh_snapshot() {
        let cfgs = [cfg(5120), cfg(65_536)];
        let pool_groups = [3u32, 2];
        let pools: Vec<Vec<GroupSim>> = pool_groups
            .iter()
            .zip(&cfgs)
            .map(|(&g, c)| (0..g).map(|_| GroupSim::new(c)).collect())
            .collect();
        assert_eq!(
            FleetState::initial(&pool_groups, &cfgs),
            snapshot(&pools, &cfgs)
        );
    }

    #[test]
    fn pool_view_reads_the_soa_lanes() {
        let state = FleetState::from_pools(vec![
            PoolLoad {
                window_tokens: 5120,
                n_max: 64,
                groups: vec![
                    GroupLoad {
                        queued: 3,
                        active: 2,
                        free_blocks: 10,
                        used_blocks: 6,
                    },
                    GroupLoad {
                        queued: 1,
                        active: 0,
                        free_blocks: 16,
                        used_blocks: 0,
                    },
                ],
            },
            PoolLoad {
                window_tokens: 65_536,
                n_max: 16,
                groups: vec![GroupLoad {
                    queued: 0,
                    active: 4,
                    free_blocks: 8,
                    used_blocks: 8,
                }],
            },
        ]);
        assert_eq!(state.num_pools(), 2);
        let p0 = state.pool(0);
        assert_eq!(p0.window_tokens(), 5120);
        assert_eq!(p0.n_max(), 64);
        assert_eq!(p0.num_groups(), 2);
        assert_eq!(p0.in_flight(0), 5);
        assert_eq!(p0.in_flight_total(), 6);
        assert_eq!(p0.backlog_per_group(), 3.0);
        assert_eq!(p0.queued_per_group(), 2.0);
        assert_eq!(
            p0.group(1),
            GroupLoad { queued: 1, active: 0, free_blocks: 16, used_blocks: 0 }
        );
        assert_eq!(state.pool(1).group(0).active, 4);
    }

    #[test]
    #[should_panic]
    fn empty_canary_panics_on_read() {
        let state = FleetState::empty();
        let _ = state.pool(0);
    }

    #[test]
    fn incremental_state_survives_per_event_validation() {
        // JSQ forces need_state; validate_state cross-checks the live
        // state against a fresh snapshot after every single event.
        let trace = small_trace(11);
        let mut jsq = super::super::dispatch::JoinShortestQueue;
        let out = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut jsq,
            EngineOptions { validate_state: true, ..Default::default() },
        )
        .pools;
        let completed: u64 = out[0].iter().map(|g| g.metrics.completed).sum();
        assert_eq!(completed, trace.len() as u64);
    }

    #[test]
    fn rebuild_per_arrival_oracle_matches_incremental_bitwise() {
        let trace = small_trace(5);
        let run = |mode: StateMode| {
            let mut jsq = super::super::dispatch::JoinShortestQueue;
            run_fleet(
                &trace,
                &HomogeneousRouter,
                &[4],
                &[cfg(8192)],
                &mut jsq,
                EngineOptions { state_mode: mode, ..Default::default() },
            )
            .pools
        };
        let incr = run(StateMode::Incremental);
        let oracle = run(StateMode::RebuildPerArrival);
        for (a, b) in incr[0].iter().zip(&oracle[0]) {
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
        }
    }

    #[test]
    fn binary_heap_oracle_matches_calendar_bitwise() {
        let trace = small_trace(9);
        let run = |queue_mode: QueueMode| {
            let mut jsq = super::super::dispatch::JoinShortestQueue;
            run_fleet(
                &trace,
                &HomogeneousRouter,
                &[4],
                &[cfg(8192)],
                &mut jsq,
                EngineOptions { queue_mode, ..Default::default() },
            )
            .pools
        };
        let cal = run(QueueMode::Calendar);
        let heap = run(QueueMode::BinaryHeap);
        for (a, b) in cal[0].iter().zip(&heap[0]) {
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
            assert_eq!(a.metrics.completed, b.metrics.completed);
        }
    }

    #[test]
    fn streamed_arrivals_replay_the_materialized_trace_bitwise() {
        // Same seed through SynthSource (streaming) and generate()
        // (materialized): the engines must agree to the bit, with a
        // stateful policy so the live FleetState path is exercised.
        let workload = crate::workload::cdf::azure_conversations();
        let gen_cfg = GenConfig {
            lambda_rps: 40.0,
            duration_s: 2.0,
            max_prompt_tokens: 6000,
            max_output_tokens: 128,
            seed: 21,
        };
        let trace = generate(&workload, &gen_cfg);
        let materialized = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut super::super::dispatch::JoinShortestQueue,
            EngineOptions::default(),
        )
        .pools;
        let mut source =
            crate::workload::arrival::SynthSource::new(&workload, &gen_cfg);
        let streamed = run_fleet_stream(
            &mut source,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut super::super::dispatch::JoinShortestQueue,
            EngineOptions::default(),
        )
        .pools;
        for (a, b) in materialized[0].iter().zip(&streamed[0]) {
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
            assert_eq!(a.metrics.completed, b.metrics.completed);
            assert_eq!(a.metrics.rejected, b.metrics.rejected);
        }
    }

    #[test]
    fn streamed_empty_source_finishes_idle() {
        let mut source =
            crate::workload::arrival::VecSource::new(Vec::new());
        let out = run_fleet_stream(
            &mut source,
            &HomogeneousRouter,
            &[2],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        )
        .pools;
        assert_eq!(out[0].len(), 2);
        for g in &out[0] {
            assert_eq!(g.metrics.completed, 0);
            assert_eq!(g.output_tokens, 0);
            assert_eq!(g.horizon_s, 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "non-decreasing in time")]
    fn streamed_backwards_source_panics() {
        // A source whose clock runs backwards must be rejected at the
        // pull site, not corrupt the calendar queue.
        struct Backwards(std::vec::IntoIter<Request>);
        impl Iterator for Backwards {
            type Item = Request;
            fn next(&mut self) -> Option<Request> {
                self.0.next()
            }
        }
        impl ArrivalSource for Backwards {}
        let reqs = vec![
            Request { id: 0, arrival_s: 1.0, prompt_tokens: 10, output_tokens: 1 },
            Request { id: 1, arrival_s: 0.5, prompt_tokens: 10, output_tokens: 1 },
        ];
        let mut source = Backwards(reqs.into_iter());
        run_fleet_stream(
            &mut source,
            &HomogeneousRouter,
            &[1],
            &[cfg(8192)],
            &mut RoundRobin::new(),
            EngineOptions::default(),
        );
    }

    #[test]
    fn fused_replays_per_step_oracle_bitwise() {
        // The macro-stepping default against the one-event-per-step
        // oracle, across both queue modes, with a stateful policy so
        // live-state reads at arrivals are exercised.
        let trace = small_trace(13);
        let run = |step_mode: StepMode, queue_mode: QueueMode| {
            let mut jsq = super::super::dispatch::JoinShortestQueue;
            run_fleet(
                &trace,
                &HomogeneousRouter,
                &[4],
                &[cfg(8192)],
                &mut jsq,
                EngineOptions { step_mode, queue_mode, ..Default::default() },
            )
        };
        for qm in [QueueMode::Calendar, QueueMode::BinaryHeap] {
            let fused = run(StepMode::Fused, qm);
            let oracle = run(StepMode::PerStep, qm);
            assert!(
                fused.events_popped < oracle.events_popped,
                "fusion popped {} events, oracle {} — no reduction ({qm:?})",
                fused.events_popped,
                oracle.events_popped
            );
            for (a, b) in fused.pools[0].iter().zip(&oracle.pools[0]) {
                assert_eq!(a.joules.to_bits(), b.joules.to_bits());
                assert_eq!(a.output_tokens, b.output_tokens);
                assert_eq!(a.steps, b.steps);
                assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
                assert_eq!(a.mean_batch.to_bits(), b.mean_batch.to_bits());
                assert_eq!(a.metrics.completed, b.metrics.completed);
                assert_eq!(a.metrics.rejected, b.metrics.rejected);
            }
        }
    }

    #[test]
    fn fused_streamed_matches_fused_materialized_event_count() {
        // events_popped is feed-invariant: the streamed run sees the
        // same fusion horizons as the materialized one because the
        // pending arrival is pulled before its predecessor is handled.
        let workload = crate::workload::cdf::azure_conversations();
        let gen_cfg = GenConfig {
            lambda_rps: 40.0,
            duration_s: 2.0,
            max_prompt_tokens: 6000,
            max_output_tokens: 128,
            seed: 23,
        };
        let trace = generate(&workload, &gen_cfg);
        let materialized = run_fleet(
            &trace,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut super::super::dispatch::JoinShortestQueue,
            EngineOptions::default(),
        );
        let mut source =
            crate::workload::arrival::SynthSource::new(&workload, &gen_cfg);
        let streamed = run_fleet_stream(
            &mut source,
            &HomogeneousRouter,
            &[3],
            &[cfg(8192)],
            &mut super::super::dispatch::JoinShortestQueue,
            EngineOptions::default(),
        );
        assert_eq!(materialized.events_popped, streamed.events_popped);
        for (a, b) in materialized.pools[0].iter().zip(&streamed.pools[0]) {
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
            assert_eq!(a.steps, b.steps);
        }
    }

    #[test]
    fn fused_event_count_scales_with_arrivals_not_steps() {
        // One request with a long output: per-step pops one event per
        // decode iteration; fused pops a handful (arrival, wake, and
        // the terminal fused StepComplete chain).
        let trace = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 64,
            output_tokens: 512,
        }];
        let run = |step_mode: StepMode| {
            run_fleet(
                &trace,
                &HomogeneousRouter,
                &[1],
                &[cfg(8192)],
                &mut RoundRobin::new(),
                EngineOptions { step_mode, ..Default::default() },
            )
        };
        let fused = run(StepMode::Fused);
        let oracle = run(StepMode::PerStep);
        assert!(
            oracle.events_popped > 500,
            "oracle should pop one event per decode step, got {}",
            oracle.events_popped
        );
        assert!(
            fused.events_popped <= 4,
            "fused should pop O(arrivals) events, got {}",
            fused.events_popped
        );
        assert_eq!(
            fused.pools[0][0].joules.to_bits(),
            oracle.pools[0][0].joules.to_bits()
        );
        assert_eq!(fused.pools[0][0].steps, oracle.pools[0][0].steps);
    }
}
