//! Virtual-time simulation of serving pools.
//!
//! Each TP group runs the same [`Batcher`] state machine as the real
//! engine, but its per-step latency comes from the roofline
//! `τ(n_active, L̄_live)` (with L̄ measured live from the slots' actual
//! KV lengths) and its energy from the logistic `P(n_active)` — i.e. a
//! faithful dynamic model of the paper's analytics, including the effects
//! the closed form ignores: ramp-up, queue waits, chunked prefill
//! interference and fragmentation.
//!
//! Requests are assigned to a pool's groups round-robin at arrival (the
//! dispatch policy production routers use for uniform pools), so groups
//! evolve independently and the simulation is embarrassingly sequential
//! and deterministic.

use crate::power::LogisticPower;
use crate::roofline::Roofline;
use crate::router::Router;
use crate::serve::batcher::{Batcher, SlotWork};
use crate::serve::energy::EnergyMeter;
use crate::serve::kvblocks::BlockAllocator;
use crate::serve::metrics::ServeMetrics;
use crate::serve::request::ServeRequest;
use crate::workload::Request;

/// Configuration of one pool's groups.
#[derive(Debug, Clone)]
pub struct GroupSimConfig {
    /// Serving context window of the pool, tokens.
    pub window_tokens: u32,
    /// Concurrency limit per group (Eq. 3's n_max for this window).
    pub n_max: u32,
    /// Roofline for step latency.
    pub roofline: Roofline,
    /// Power curve for energy.
    pub power: LogisticPower,
    /// GPUs charged per group-observation (1 = paper convention).
    pub gpus_charged: f64,
    /// Prompt tokens ingested per slot per step (chunked prefill).
    pub ingest_chunk: u32,
}

/// Result of simulating one pool.
#[derive(Debug, Clone)]
pub struct PoolSimReport {
    pub name: String,
    pub groups: u32,
    pub window_tokens: u32,
    pub metrics: ServeMetrics,
    pub output_tokens: u64,
    pub joules: f64,
    pub tok_per_watt: f64,
    /// Time-weighted mean in-flight batch per group.
    pub mean_batch: f64,
    /// Pool-wide decode throughput over the busy horizon, tok/s.
    pub decode_tok_s: f64,
    /// Horizon: last completion time, s.
    pub horizon_s: f64,
}

/// Simulate one pool of `groups` identical groups over its request slice.
pub fn simulate_pool(
    name: &str,
    mut requests: Vec<ServeRequest>,
    groups: u32,
    cfg: &GroupSimConfig,
) -> PoolSimReport {
    assert!(groups > 0);
    requests.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());

    // Round-robin dispatch at arrival.
    let mut per_group: Vec<Vec<ServeRequest>> =
        vec![Vec::new(); groups as usize];
    for (i, r) in requests.into_iter().enumerate() {
        per_group[i % groups as usize].push(r);
    }

    let mut metrics = ServeMetrics::default();
    let mut joules = 0.0;
    let mut output_tokens = 0u64;
    let mut horizon_s: f64 = 0.0;
    let mut batch_integral = 0.0;
    let mut time_integral = 0.0;

    for arrivals in per_group {
        let g = simulate_group(arrivals, cfg);
        metrics.merge(&g.metrics);
        joules += g.joules;
        output_tokens += g.output_tokens;
        horizon_s = horizon_s.max(g.horizon_s);
        batch_integral += g.mean_batch * g.horizon_s;
        time_integral += g.horizon_s;
    }

    PoolSimReport {
        name: name.into(),
        groups,
        window_tokens: cfg.window_tokens,
        metrics,
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        mean_batch: if time_integral > 0.0 {
            batch_integral / time_integral
        } else {
            0.0
        },
        decode_tok_s: if horizon_s > 0.0 {
            output_tokens as f64 / horizon_s
        } else {
            0.0
        },
        horizon_s,
    }
}

struct GroupResult {
    metrics: ServeMetrics,
    joules: f64,
    output_tokens: u64,
    horizon_s: f64,
    mean_batch: f64,
}

fn simulate_group(arrivals: Vec<ServeRequest>, cfg: &GroupSimConfig) -> GroupResult {
    // Block budget = n_max × window (Eq. 3 inverted): admission saturates
    // at exactly n_max full-window sequences.
    let blocks_total =
        (cfg.n_max as u64 * cfg.window_tokens as u64 / 64).max(1) as u32;
    let mut b = Batcher::new(
        cfg.n_max as usize,
        BlockAllocator::new(64, blocks_total),
        cfg.ingest_chunk,
        cfg.window_tokens,
    );
    let mut meter = EnergyMeter::new(cfg.power, cfg.gpus_charged, 0.0);
    let mut metrics = ServeMetrics::default();

    let mut pending = arrivals.into_iter().peekable();
    let mut t = 0.0f64;

    loop {
        // Feed arrivals up to the current time.
        while pending
            .peek()
            .map(|r| r.arrival_s <= t)
            .unwrap_or(false)
        {
            let r = pending.next().unwrap();
            if !b.submit(r) {
                metrics.rejected += 1;
            }
        }
        b.admit(t);

        if b.active() == 0 {
            // Nothing in flight: fast-forward to the next arrival (idle
            // power still accrues — the long-pool "nearly idle yet still
            // draws watts" effect of §5.1).
            match pending.peek() {
                Some(r) => {
                    let t_next = r.arrival_s;
                    meter.observe(t_next, 0.0);
                    t = t_next;
                    continue;
                }
                None => break,
            }
        }

        // One engine step at the live operating point.
        let plan = b.plan();
        let n_active = plan
            .iter()
            .filter(|w| !matches!(w, SlotWork::Idle))
            .count() as f64;
        let l_bar = b.mean_kv_len().max(1.0);
        let dt = cfg.roofline.tau_ms(n_active, l_bar) / 1e3;
        t += dt;
        meter.observe(t, n_active);

        for (i, w) in plan.into_iter().enumerate() {
            match w {
                SlotWork::Idle => {}
                SlotWork::Ingest { .. } => {
                    b.on_step(i, w, t);
                }
                SlotWork::Decode => {
                    meter.add_output_tokens(1);
                    if let Some(c) = b.on_step(i, SlotWork::Decode, t) {
                        metrics.record(&c);
                    }
                }
            }
        }
    }

    GroupResult {
        metrics,
        joules: meter.joules().0,
        output_tokens: meter.output_tokens(),
        horizon_s: t,
        mean_batch: meter.mean_batch(),
    }
}

/// Simulate a routed topology: requests go through `router` to pools,
/// each with its own group count and config.
#[derive(Debug, Clone)]
pub struct TopoSimReport {
    pub pools: Vec<PoolSimReport>,
    pub output_tokens: u64,
    pub joules: f64,
    pub tok_per_watt: f64,
}

pub fn simulate_topology(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
) -> TopoSimReport {
    assert_eq!(router.num_pools(), pool_cfgs.len());
    assert_eq!(pool_groups.len(), pool_cfgs.len());

    let mut per_pool: Vec<Vec<ServeRequest>> =
        vec![Vec::new(); pool_cfgs.len()];
    for req in trace {
        let route = router.route(req);
        let mut s = ServeRequest::from(req);
        s.prompt_tokens = route.effective_prompt_tokens;
        per_pool[route.pool].push(s);
    }

    let pools: Vec<PoolSimReport> = per_pool
        .into_iter()
        .enumerate()
        .map(|(i, reqs)| {
            simulate_pool(&format!("pool-{i}"), reqs, pool_groups[i], &pool_cfgs[i])
        })
        .collect();

    let output_tokens = pools.iter().map(|p| p.output_tokens).sum();
    let joules: f64 = pools.iter().map(|p| p.joules).sum();
    TopoSimReport {
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        pools,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::{GpuProfile, ManualProfile};
    use crate::router::context::ContextRouter;
    use crate::workload::synth::{generate, GenConfig};

    fn h100_cfg(window: u32) -> GroupSimConfig {
        let p = ManualProfile::h100_70b();
        GroupSimConfig {
            window_tokens: window,
            n_max: p.n_max(window),
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }
    }

    fn azure_trace(lambda: f64, secs: f64, max_prompt: u32) -> Vec<Request> {
        generate(
            &crate::workload::cdf::azure_conversations(),
            &GenConfig {
                lambda_rps: lambda,
                duration_s: secs,
                max_prompt_tokens: max_prompt,
                max_output_tokens: 512,
                seed: 42,
            },
        )
    }

    #[test]
    fn saturated_group_lands_near_analytical_tok_w() {
        // Saturate one 64K group: the analytical operating point says
        // n=16 at 435 W → 1.50 tok/W with L̄=64K. Live L̄ is smaller
        // (requests are mostly short), so the simulated tok/W must land
        // between the window bound and the short-context bound.
        let cfg = h100_cfg(65_536);
        let reqs: Vec<ServeRequest> = azure_trace(50.0, 4.0, 60_000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r = simulate_pool("sat", reqs, 1, &cfg);
        assert!(r.metrics.completed > 50, "completed {}", r.metrics.completed);
        assert!(
            r.tok_per_watt > 1.0,
            "simulated tok/W {} must beat the L̄=window bound",
            r.tok_per_watt
        );
        assert!(r.mean_batch > 8.0, "group should saturate: {}", r.mean_batch);
    }

    #[test]
    fn window_halving_doubles_tok_w_in_simulation() {
        // The 1/W law, dynamically: same traffic (all short), two window
        // configurations; n_max halves, tok/W roughly halves.
        let short_reqs: Vec<ServeRequest> = azure_trace(120.0, 3.0, 2000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r8 = simulate_pool("w8k", short_reqs.clone(), 1, &h100_cfg(8192));
        let r32 = simulate_pool("w32k", short_reqs, 1, &h100_cfg(32_768));
        assert!(r8.metrics.completed > 100);
        let ratio = r8.tok_per_watt / r32.tok_per_watt;
        assert!(
            (2.0..=5.5).contains(&ratio),
            "8K vs 32K window tok/W ratio = {ratio:.2} (law: ≈4 at fixed \
             traffic, attenuated by live-L̄ effects)"
        );
    }

    #[test]
    fn routed_topology_beats_homogeneous_in_simulation() {
        // The paper's headline, validated dynamically end-to-end.
        let trace = azure_trace(40.0, 5.0, 60_000);
        let homo = simulate_topology(
            &trace,
            &crate::router::HomogeneousRouter,
            &[4],
            &[h100_cfg(65_536)],
        );
        // Short-pool window = split boundary + output headroom so that a
        // prompt routed short always fits prompt+output.
        let routed = simulate_topology(
            &trace,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
        );
        assert!(
            routed.tok_per_watt > homo.tok_per_watt,
            "routed {} vs homo {}",
            routed.tok_per_watt,
            homo.tok_per_watt
        );
        // Token conservation between topologies.
        assert_eq!(routed.output_tokens, homo.output_tokens);
    }

    #[test]
    fn idle_pool_burns_idle_power() {
        let cfg = h100_cfg(8192);
        let reqs = vec![ServeRequest {
            id: 0,
            prompt_tokens: 100,
            output_tokens: 10,
            arrival_s: 5.0, // five idle seconds first
        }];
        let r = simulate_pool("idle", reqs, 1, &cfg);
        assert!(r.joules > 5.0 * 299.0, "idle joules missing: {}", r.joules);
        assert_eq!(r.metrics.completed, 1);
    }

    #[test]
    fn deterministic() {
        let trace = azure_trace(30.0, 2.0, 30_000);
        let a = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        let b = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules, b.joules);
    }
}
