//! Pool/topology simulation reports and the public simulation entry
//! points, built on the event-driven core in [`super::events`].
//!
//! Each TP group runs the same [`Batcher`](crate::serve::batcher::Batcher)
//! state machine as the real engine, but its per-step latency comes from
//! the roofline `τ(n_active, L̄_live)` (with L̄ measured live from the
//! slots' actual KV lengths) and its energy from the logistic
//! `P(n_active)` — i.e. a faithful dynamic model of the paper's
//! analytics, including the effects the closed form ignores: ramp-up,
//! queue waits, chunked prefill interference and fragmentation.
//!
//! [`simulate_pool`] and [`simulate_topology`] are thin compatibility
//! wrappers over the event engine with round-robin dispatch — they
//! reproduce the pre-refactor sequential per-group loop bit-for-bit
//! (`tests/sim_replay.rs` keeps that loop as an inline oracle).
//! [`simulate_topology_with`] exposes the full engine: any
//! [`DispatchPolicy`], load-aware routers, and the parallel per-group
//! fast path. [`simulate_topology_source`] is the streaming entry
//! point: arrivals pulled lazily from an
//! [`ArrivalSource`](crate::workload::arrival::ArrivalSource) in O(1)
//! trace memory, bit-for-bit equivalent to the materialized run of the
//! same source. Reports carry [`TopoSimReport::events_popped`] so the
//! macro-stepping win (events scaling with arrivals instead of decode
//! steps under the default
//! [`StepMode::Fused`](super::events::StepMode)) is observable.

use super::dispatch::{DispatchPolicy, RoundRobin};
use super::events::{
    run_fleet_auto, run_fleet_stream_auto, EngineOptions, FleetRun,
    GroupOutcome,
};
use crate::power::LogisticPower;
use crate::roofline::Roofline;
use crate::router::Router;
use crate::serve::metrics::ServeMetrics;
use crate::serve::request::ServeRequest;
use crate::workload::Request;

/// Paged-KV block granularity, tokens per block — the one constant
/// shared by the engine's allocators ([`super::events`]), the Eq. 3
/// block budget ([`GroupSimConfig::blocks_total`]), and the `power-slo`
/// guard's L̄-from-held-blocks estimate
/// ([`super::dispatch::PowerAware`]).
pub const KV_BLOCK_TOKENS: u32 = 64;

/// Configuration of one pool's groups.
#[derive(Debug, Clone)]
pub struct GroupSimConfig {
    /// Serving context window of the pool, tokens.
    pub window_tokens: u32,
    /// Concurrency limit per group (Eq. 3's n_max for this window).
    pub n_max: u32,
    /// Roofline for step latency.
    pub roofline: Roofline,
    /// Power curve for energy.
    pub power: LogisticPower,
    /// GPUs charged per group-observation (1 = paper convention).
    pub gpus_charged: f64,
    /// Prompt tokens ingested per slot per step (chunked prefill).
    pub ingest_chunk: u32,
}

impl GroupSimConfig {
    /// Paged-KV block budget backing one group: n_max × window tokens in
    /// 64-token blocks (Eq. 3 inverted) — admission saturates at exactly
    /// n_max full-window sequences. Shared by the live engine and
    /// [`FleetState::initial`](super::events::FleetState::initial) so the
    /// all-idle state matches a fresh snapshot exactly.
    pub fn blocks_total(&self) -> u32 {
        (self.n_max as u64 * self.window_tokens as u64
            / KV_BLOCK_TOKENS as u64)
            .max(1) as u32
    }
}

/// Result of simulating one pool.
#[derive(Debug, Clone)]
pub struct PoolSimReport {
    pub name: String,
    pub groups: u32,
    pub window_tokens: u32,
    pub metrics: ServeMetrics,
    pub output_tokens: u64,
    pub joules: f64,
    pub tok_per_watt: f64,
    /// Time-weighted mean in-flight batch per group.
    pub mean_batch: f64,
    /// Pool-wide decode throughput over the busy horizon, tok/s.
    pub decode_tok_s: f64,
    /// Horizon: last completion time, s.
    pub horizon_s: f64,
    /// Σ of the per-group horizons, s (a never-touched group contributes
    /// zero). The accounted idle top-up bills each group from its own
    /// horizon to the fleet's: `groups × fleet_horizon − this`.
    pub horizon_sum_s: f64,
    /// Engine iterations executed across the pool's groups.
    pub steps: u64,
    /// Groups of this pool that never received a single arrival. Their
    /// meters never ran, so they contribute **zero** joules to `joules`
    /// — real provisioned hardware would draw idle watts the whole run
    /// (§5.1), which the topology report's accounted figures charge
    /// ([`TopoSimReport::idle_joules`]).
    pub untouched_groups: u32,
}

/// Simulate a routed topology: requests go through `router` to pools,
/// each with its own group count and config.
#[derive(Debug, Clone)]
pub struct TopoSimReport {
    pub pools: Vec<PoolSimReport>,
    pub output_tokens: u64,
    /// Raw metered energy: exactly what the per-group event meters
    /// integrated (untouched groups contribute nothing — the legacy
    /// replay contract). See [`Self::accounted_joules`].
    pub joules: f64,
    /// `output_tokens / joules` over the raw metered energy.
    pub tok_per_watt: f64,
    /// Engine iterations executed fleet-wide.
    pub steps: u64,
    /// Events popped from the engine's queue — the wall-clock cost
    /// metric macro-stepping shrinks. Under the fused default this
    /// scales with arrivals + quiesce boundaries; under
    /// [`StepMode::PerStep`](super::events::StepMode) it is ≈ `steps`
    /// plus arrivals and wakes. Invariant across queue modes, state
    /// modes and streamed/materialized feeds, but not across step
    /// modes or the sequential/parallel engine paths (an isolated
    /// group fuses past other groups' arrivals).
    pub events_popped: u64,
    /// Idle-power energy billed for each group's gap between its own
    /// meter horizon and the fleet horizon: a pool excluded by the
    /// router's cutoffs (or a group that served one stray request and
    /// then sat) is provisioned hardware drawing idle watts, not free
    /// capacity. Zero when every group runs to the fleet horizon.
    pub idle_joules: f64,
    /// Zero-traffic warnings: one line per pool with groups that never
    /// received an arrival (e.g. router cutoffs that exclude the pool).
    pub warnings: Vec<String>,
}

impl TopoSimReport {
    /// Fleet-wide serving metrics: every pool's per-request
    /// TTFT/TPOT/E2E digests and counters merged into one — what a
    /// scenario cell reports its p99 TTFT from.
    pub fn fleet_metrics(&self) -> ServeMetrics {
        ServeMetrics::merged(self.pools.iter().map(|p| &p.metrics))
    }

    /// Metered energy plus the idle draw of every group's gap to the
    /// fleet horizon — the honest fleet bill.
    pub fn accounted_joules(&self) -> f64 {
        self.joules + self.idle_joules
    }

    /// Fleet tok/W with every provisioned group billed to the common
    /// fleet horizon — idle watts for the span its meter never covered
    /// (≈ `tok_per_watt` when every group stays busy to the end; far
    /// below it when the router's cutoffs starve a pool). The scenario
    /// layer reports this figure.
    pub fn tok_per_watt_accounted(&self) -> f64 {
        let joules = self.accounted_joules();
        if joules > 0.0 {
            self.output_tokens as f64 / joules
        } else {
            0.0
        }
    }
}

/// Aggregate a pool's group outcomes in group-index order (the order is
/// part of the deterministic-replay contract: float sums match the legacy
/// sequential loop bit-for-bit).
fn aggregate_pool(
    name: &str,
    groups: u32,
    cfg: &GroupSimConfig,
    outcomes: Vec<GroupOutcome>,
) -> PoolSimReport {
    let mut joules = 0.0;
    let mut output_tokens = 0u64;
    let mut horizon_s: f64 = 0.0;
    let mut batch_integral = 0.0;
    let mut time_integral = 0.0;
    let mut steps = 0u64;
    let mut untouched_groups = 0u32;

    for g in &outcomes {
        joules += g.joules;
        output_tokens += g.output_tokens;
        horizon_s = horizon_s.max(g.horizon_s);
        batch_integral += g.mean_batch * g.horizon_s;
        time_integral += g.horizon_s;
        steps += g.steps;
        // A group that never received an arrival was never woken: its
        // meter integrated nothing and its local clock never advanced.
        if g.steps == 0 && g.joules == 0.0 && g.horizon_s == 0.0 {
            untouched_groups += 1;
        }
    }
    // One all-parts weighted merge (not a pairwise fold): linear in the
    // total samples, and a single proportional subsampling pass when any
    // group's digest is truncated.
    let metrics = ServeMetrics::merged(outcomes.iter().map(|g| &g.metrics));

    PoolSimReport {
        name: name.into(),
        groups,
        window_tokens: cfg.window_tokens,
        metrics,
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        mean_batch: if time_integral > 0.0 {
            batch_integral / time_integral
        } else {
            0.0
        },
        decode_tok_s: if horizon_s > 0.0 {
            output_tokens as f64 / horizon_s
        } else {
            0.0
        },
        horizon_s,
        horizon_sum_s: time_integral,
        steps,
        untouched_groups,
    }
}

fn aggregate_topology(
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    outcomes: Vec<Vec<GroupOutcome>>,
    events_popped: u64,
) -> TopoSimReport {
    let pools: Vec<PoolSimReport> = outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            aggregate_pool(&format!("pool-{i}"), pool_groups[i], &pool_cfgs[i], o)
        })
        .collect();

    let output_tokens = pools.iter().map(|p| p.output_tokens).sum();
    let joules: f64 = pools.iter().map(|p| p.joules).sum();
    let steps = pools.iter().map(|p| p.steps).sum();

    // A group's meter stops at its own last event, so the raw totals
    // silently treat everything after — a router-excluded pool's whole
    // run, or a mostly-idle group's long tail — as free hardware. Bill
    // every group's gap to the common fleet horizon at idle watts into
    // the accounted figures, and warn explicitly for zero-traffic
    // groups (the router-cutoff smell this accounting exists to catch).
    let fleet_horizon_s =
        pools.iter().map(|p| p.horizon_s).fold(0.0f64, f64::max);
    let mut idle_joules = 0.0;
    let mut warnings = Vec::new();
    for (i, p) in pools.iter().enumerate() {
        let idle_w =
            pool_cfgs[i].power.power_w(0.0) * pool_cfgs[i].gpus_charged;
        let idle_gap_s =
            (p.groups as f64 * fleet_horizon_s - p.horizon_sum_s).max(0.0);
        idle_joules += idle_w * idle_gap_s;
        if p.untouched_groups == 0 {
            continue;
        }
        if p.untouched_groups == p.groups {
            warnings.push(format!(
                "pool-{i} ({} tok window): zero traffic — the router's \
                 cutoffs exclude it; {} idle group{} charged at {:.0} W \
                 over the {:.2}s fleet horizon in the accounted figures",
                p.window_tokens,
                p.untouched_groups,
                if p.untouched_groups == 1 { "" } else { "s" },
                idle_w,
                fleet_horizon_s,
            ));
        } else {
            warnings.push(format!(
                "pool-{i}: {} of {} groups never received an arrival; \
                 idle power charged in the accounted figures",
                p.untouched_groups, p.groups,
            ));
        }
    }

    TopoSimReport {
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        steps,
        events_popped,
        pools,
        idle_joules,
        warnings,
    }
}

/// Stable arrival-time sort (total order; NaN arrivals are rejected by
/// the engine with a clear message instead of a `partial_cmp` panic).
fn sorted_by_arrival(trace: &[Request]) -> Vec<Request> {
    let mut t = trace.to_vec();
    t.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    t
}

/// Simulate one pool of `groups` identical groups over its request slice
/// (round-robin dispatch at arrival — the legacy behavior, bit-for-bit).
pub fn simulate_pool(
    name: &str,
    requests: Vec<ServeRequest>,
    groups: u32,
    cfg: &GroupSimConfig,
) -> PoolSimReport {
    assert!(groups > 0);
    let trace: Vec<Request> = requests
        .iter()
        .map(|s| Request {
            id: s.id,
            arrival_s: s.arrival_s,
            prompt_tokens: s.prompt_tokens,
            output_tokens: s.output_tokens,
        })
        .collect();
    let trace = sorted_by_arrival(&trace);
    let mut rr = RoundRobin::new();
    let mut run = run_fleet_auto(
        &trace,
        &crate::router::HomogeneousRouter,
        &[groups],
        std::slice::from_ref(cfg),
        &mut rr,
        EngineOptions::default(),
    );
    aggregate_pool(name, groups, cfg, run.pools.pop().expect("one pool"))
}

/// Simulate a routed topology with round-robin dispatch — the legacy
/// entry point, bit-for-bit compatible with the pre-refactor loop.
pub fn simulate_topology(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
) -> TopoSimReport {
    let mut rr = RoundRobin::new();
    simulate_topology_with(trace, router, pool_groups, pool_cfgs, &mut rr, true)
}

/// Full-control entry point: any dispatch policy, load-aware routers,
/// optional parallel per-group stepping (taken automatically when the
/// policy is arrival-static and the router is not load-aware).
pub fn simulate_topology_with(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    allow_parallel: bool,
) -> TopoSimReport {
    simulate_topology_opts(
        trace,
        router,
        pool_groups,
        pool_cfgs,
        dispatch,
        EngineOptions { allow_parallel, ..Default::default() },
    )
}

/// Everything-exposed entry point: on top of
/// [`simulate_topology_with`], selects the live-state maintenance mode
/// ([`StateMode`](super::events::StateMode) — incremental vs the legacy
/// rebuild-per-arrival oracle), the event-queue implementation
/// ([`QueueMode`](super::events::QueueMode) — calendar queue vs the
/// legacy binary-heap oracle) and the per-event state cross-check used
/// by the property suites.
pub fn simulate_topology_opts(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> TopoSimReport {
    let trace = sorted_by_arrival(trace);
    let FleetRun { pools, events_popped } =
        run_fleet_auto(&trace, router, pool_groups, pool_cfgs, dispatch, opts);
    aggregate_topology(pool_groups, pool_cfgs, pools, events_popped)
}

/// Streaming entry point: arrivals pulled one at a time from an
/// [`ArrivalSource`](crate::workload::arrival::ArrivalSource), so
/// trace memory is O(1) at any λ·duration. The source contract is
/// non-decreasing arrival times (asserted per pull — there is no trace
/// to sort). When `opts.allow_parallel` holds and the scenario is
/// arrival-static (non-load-aware router, static dispatch), the run
/// takes the sharded demux fast path — one worker thread per group fed
/// over bounded channels, O(groups × buffer) memory — and otherwise
/// the sequential single-queue engine. Both are bit-for-bit equivalent
/// to [`simulate_topology_opts`] on the collected source
/// (`tests/properties.rs` pins this across dispatch policies, queue
/// modes and step modes).
pub fn simulate_topology_source(
    source: &mut dyn crate::workload::arrival::ArrivalSource,
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> TopoSimReport {
    let FleetRun { pools, events_popped } = run_fleet_stream_auto(
        source, router, pool_groups, pool_cfgs, dispatch, opts,
    );
    aggregate_topology(pool_groups, pool_cfgs, pools, events_popped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::{GpuProfile, ManualProfile};
    use crate::router::context::ContextRouter;
    use crate::sim::dispatch::{self, JoinShortestQueue};
    use crate::workload::synth::{generate, GenConfig};

    fn h100_cfg(window: u32) -> GroupSimConfig {
        let p = ManualProfile::h100_70b();
        GroupSimConfig {
            window_tokens: window,
            n_max: p.n_max(window),
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }
    }

    fn azure_trace(lambda: f64, secs: f64, max_prompt: u32) -> Vec<Request> {
        generate(
            &crate::workload::cdf::azure_conversations(),
            &GenConfig {
                lambda_rps: lambda,
                duration_s: secs,
                max_prompt_tokens: max_prompt,
                max_output_tokens: 512,
                seed: 42,
            },
        )
    }

    #[test]
    fn saturated_group_lands_near_analytical_tok_w() {
        // Saturate one 64K group: the analytical operating point says
        // n=16 at 435 W → 1.50 tok/W with L̄=64K. Live L̄ is smaller
        // (requests are mostly short), so the simulated tok/W must land
        // between the window bound and the short-context bound.
        let cfg = h100_cfg(65_536);
        let reqs: Vec<ServeRequest> = azure_trace(50.0, 4.0, 60_000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r = simulate_pool("sat", reqs, 1, &cfg);
        assert!(r.metrics.completed > 50, "completed {}", r.metrics.completed);
        assert!(
            r.tok_per_watt > 1.0,
            "simulated tok/W {} must beat the L̄=window bound",
            r.tok_per_watt
        );
        assert!(r.mean_batch > 8.0, "group should saturate: {}", r.mean_batch);
        assert!(r.steps > 0);
    }

    #[test]
    fn window_halving_doubles_tok_w_in_simulation() {
        // The 1/W law, dynamically: same traffic (all short), two window
        // configurations; n_max halves, tok/W roughly halves.
        let short_reqs: Vec<ServeRequest> = azure_trace(120.0, 3.0, 2000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r8 = simulate_pool("w8k", short_reqs.clone(), 1, &h100_cfg(8192));
        let r32 = simulate_pool("w32k", short_reqs, 1, &h100_cfg(32_768));
        assert!(r8.metrics.completed > 100);
        let ratio = r8.tok_per_watt / r32.tok_per_watt;
        assert!(
            (2.0..=5.5).contains(&ratio),
            "8K vs 32K window tok/W ratio = {ratio:.2} (law: ≈4 at fixed \
             traffic, attenuated by live-L̄ effects)"
        );
    }

    #[test]
    fn routed_topology_beats_homogeneous_in_simulation() {
        // The paper's headline, validated dynamically end-to-end.
        let trace = azure_trace(40.0, 5.0, 60_000);
        let homo = simulate_topology(
            &trace,
            &crate::router::HomogeneousRouter,
            &[4],
            &[h100_cfg(65_536)],
        );
        // Short-pool window = split boundary + output headroom so that a
        // prompt routed short always fits prompt+output.
        let routed = simulate_topology(
            &trace,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
        );
        assert!(
            routed.tok_per_watt > homo.tok_per_watt,
            "routed {} vs homo {}",
            routed.tok_per_watt,
            homo.tok_per_watt
        );
        // Token conservation between topologies.
        assert_eq!(routed.output_tokens, homo.output_tokens);
    }

    #[test]
    fn idle_pool_burns_idle_power() {
        let cfg = h100_cfg(8192);
        let reqs = vec![ServeRequest {
            id: 0,
            prompt_tokens: 100,
            output_tokens: 10,
            arrival_s: 5.0, // five idle seconds first
        }];
        let r = simulate_pool("idle", reqs, 1, &cfg);
        assert!(r.joules > 5.0 * 299.0, "idle joules missing: {}", r.joules);
        assert_eq!(r.metrics.completed, 1);
    }

    #[test]
    fn deterministic() {
        let trace = azure_trace(30.0, 2.0, 30_000);
        let a = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        let b = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules, b.joules);
    }

    #[test]
    fn deterministic_under_stateful_dispatch() {
        let trace = azure_trace(30.0, 2.0, 30_000);
        let run = || {
            let mut jsq = JoinShortestQueue;
            simulate_topology_with(
                &trace,
                &crate::router::HomogeneousRouter,
                &[2],
                &[h100_cfg(65_536)],
                &mut jsq,
                true,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules.to_bits(), b.joules.to_bits());
    }

    #[test]
    fn zero_traffic_pools_warn_and_charge_idle_power_in_accounted_figures() {
        use crate::router::context::KPoolRouter;

        // Every prompt fits the first tier; the router's cutoffs leave
        // the 16K and 64K pools without a single arrival.
        let trace: Vec<Request> = (0..40)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 * 0.05,
                prompt_tokens: 256,
                output_tokens: 32,
            })
            .collect();
        let router = KPoolRouter::new(vec![2048, 16384], 1.0);
        let cfgs =
            [h100_cfg(2048 + 1024), h100_cfg(16384 + 1024), h100_cfg(65_536)];
        let mut rr = RoundRobin::new();
        let r = simulate_topology_with(
            &trace, &router, &[1, 2, 1], &cfgs, &mut rr, true,
        );

        assert_eq!(r.pools[0].untouched_groups, 0);
        assert_eq!(r.pools[1].untouched_groups, 2);
        assert_eq!(r.pools[2].untouched_groups, 1);
        assert_eq!(r.pools[1].joules, 0.0, "raw meters never ran");
        assert_eq!(r.warnings.len(), 2, "{:?}", r.warnings);
        assert!(r.warnings[0].contains("zero traffic"), "{:?}", r.warnings);

        // The accounted bill charges exactly idle watts × fleet horizon
        // per untouched group (the served pool's lone group defines the
        // fleet horizon, so its own gap is zero).
        let fleet_h = r.pools.iter().map(|p| p.horizon_s).fold(0.0, f64::max);
        assert!(fleet_h > 0.0);
        let idle_w = cfgs[0].power.power_w(0.0); // same curve per pool here
        let expected = 3.0 * idle_w * fleet_h;
        assert!(
            (r.idle_joules - expected).abs() < 1e-9,
            "idle_joules {} vs expected {expected}",
            r.idle_joules
        );
        assert_eq!(r.accounted_joules(), r.joules + r.idle_joules);
        assert!(
            r.tok_per_watt_accounted() < r.tok_per_watt,
            "idle draw must lower the honest tok/W: {} vs {}",
            r.tok_per_watt_accounted(),
            r.tok_per_watt
        );

        // A fleet where every group sees traffic to the end reports no
        // warnings, and its idle bill is only the tiny drain gap between
        // the groups' final completions — not a zero-traffic charge.
        let full = simulate_topology(
            &trace,
            &crate::router::HomogeneousRouter,
            &[2],
            &[h100_cfg(8192)],
        );
        assert!(full.warnings.is_empty());
        let full_h =
            full.pools.iter().map(|p| p.horizon_s).fold(0.0, f64::max);
        let full_gap = 2.0 * full_h - full.pools[0].horizon_sum_s;
        assert!(
            (full.idle_joules - idle_w * full_gap).abs() < 1e-9,
            "healthy fleet bills exactly the drain gap: {} vs {}",
            full.idle_joules,
            idle_w * full_gap
        );
        assert!(
            full.idle_joules < 0.05 * full.joules,
            "drain-gap bill must be noise next to the metered energy: \
             {} vs {}",
            full.idle_joules,
            full.joules
        );
    }

    #[test]
    fn mostly_idle_group_is_billed_to_the_fleet_horizon() {
        // One stray early request on the long pool must not exempt its
        // group from the idle bill for the rest of the run: the short
        // pool serves steadily for ~4 s while the long pool's only
        // request completes almost immediately.
        let mut trace: Vec<Request> = (0..80)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 * 0.05,
                prompt_tokens: 256,
                output_tokens: 32,
            })
            .collect();
        trace.push(Request {
            id: 80,
            arrival_s: 0.0,
            prompt_tokens: 10_000,
            output_tokens: 8,
        });
        let router = crate::router::context::ContextRouter::two_pool(4096);
        let cfgs = [h100_cfg(4096 + 1024), h100_cfg(65_536)];
        let mut rr = RoundRobin::new();
        let r = simulate_topology_with(
            &trace, &router, &[1, 1], &cfgs, &mut rr, true,
        );
        // The long pool served its request, so no zero-traffic warning —
        // but its meter stopped early and the accounted bill covers the
        // gap to the fleet horizon at idle watts.
        assert_eq!(r.pools[1].untouched_groups, 0);
        assert!(r.pools[1].metrics.completed == 1);
        let fleet_h = r.pools.iter().map(|p| p.horizon_s).fold(0.0, f64::max);
        let gap = fleet_h - r.pools[1].horizon_s;
        assert!(gap > 1.0, "long pool must drain well before the fleet: {gap}");
        let idle_w = cfgs[1].power.power_w(0.0);
        assert!(
            r.idle_joules >= idle_w * gap - 1e-9,
            "stray-request group escaped its idle bill: {} < {}",
            r.idle_joules,
            idle_w * gap
        );
        assert!(r.tok_per_watt_accounted() < r.tok_per_watt);
    }

    #[test]
    fn engine_configures_the_slo_guard_automatically() {
        // `power-slo` through the public entry point: the engine hands
        // the policy the per-pool rooflines before the first arrival
        // (an unconfigured guard would panic on its first decision),
        // and the guarded run still conserves tokens.
        let trace = azure_trace(40.0, 2.0, 4000);
        let mut policy = dispatch::parse("power-slo").unwrap();
        let r = simulate_topology_with(
            &trace,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
            policy.as_mut(),
            true,
        );
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        assert_eq!(r.output_tokens, want);
    }

    #[test]
    fn streamed_report_matches_materialized_report_bitwise() {
        let workload = crate::workload::cdf::azure_conversations();
        let gen_cfg = GenConfig {
            lambda_rps: 40.0,
            duration_s: 2.0,
            max_prompt_tokens: 4000,
            max_output_tokens: 512,
            seed: 42,
        };
        let trace = generate(&workload, &gen_cfg);
        let mut jsq = JoinShortestQueue;
        let materialized = simulate_topology_opts(
            &trace,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
            &mut jsq,
            EngineOptions { allow_parallel: false, ..Default::default() },
        );
        let mut source =
            crate::workload::arrival::SynthSource::new(&workload, &gen_cfg);
        let mut jsq = JoinShortestQueue;
        let streamed = simulate_topology_source(
            &mut source,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
            &mut jsq,
            EngineOptions::default(),
        );
        assert_eq!(materialized.output_tokens, streamed.output_tokens);
        assert_eq!(materialized.joules.to_bits(), streamed.joules.to_bits());
        assert_eq!(
            materialized.idle_joules.to_bits(),
            streamed.idle_joules.to_bits()
        );
        assert_eq!(materialized.steps, streamed.steps);
        assert_eq!(materialized.events_popped, streamed.events_popped);
        for (a, b) in materialized.pools.iter().zip(&streamed.pools) {
            assert_eq!(a.joules.to_bits(), b.joules.to_bits());
            assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
            assert_eq!(a.metrics.completed, b.metrics.completed);
        }
    }

    #[test]
    fn every_dispatch_policy_conserves_tokens() {
        let trace = azure_trace(40.0, 2.0, 4000);
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        for name in dispatch::ALL {
            let mut policy = dispatch::parse(name).unwrap();
            let r = simulate_topology_with(
                &trace,
                &ContextRouter::two_pool(4096),
                &[2, 2],
                &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
                policy.as_mut(),
                true,
            );
            assert_eq!(r.output_tokens, want, "policy {name}");
            let done: u64 = r.pools.iter().map(|p| p.metrics.completed).sum();
            assert_eq!(done, trace.len() as u64, "policy {name}");
        }
    }
}
