//! Pool/topology simulation reports and the public simulation entry
//! points, built on the event-driven core in [`super::events`].
//!
//! Each TP group runs the same [`Batcher`](crate::serve::batcher::Batcher)
//! state machine as the real engine, but its per-step latency comes from
//! the roofline `τ(n_active, L̄_live)` (with L̄ measured live from the
//! slots' actual KV lengths) and its energy from the logistic
//! `P(n_active)` — i.e. a faithful dynamic model of the paper's
//! analytics, including the effects the closed form ignores: ramp-up,
//! queue waits, chunked prefill interference and fragmentation.
//!
//! [`simulate_pool`] and [`simulate_topology`] are thin compatibility
//! wrappers over the event engine with round-robin dispatch — they
//! reproduce the pre-refactor sequential per-group loop bit-for-bit
//! (`tests/sim_replay.rs` keeps that loop as an inline oracle).
//! [`simulate_topology_with`] exposes the full engine: any
//! [`DispatchPolicy`], load-aware routers, and the parallel per-group
//! fast path.

use super::dispatch::{DispatchPolicy, RoundRobin};
use super::events::{run_fleet_auto, EngineOptions, GroupOutcome};
use crate::power::LogisticPower;
use crate::roofline::Roofline;
use crate::router::Router;
use crate::serve::metrics::ServeMetrics;
use crate::serve::request::ServeRequest;
use crate::workload::Request;

/// Configuration of one pool's groups.
#[derive(Debug, Clone)]
pub struct GroupSimConfig {
    /// Serving context window of the pool, tokens.
    pub window_tokens: u32,
    /// Concurrency limit per group (Eq. 3's n_max for this window).
    pub n_max: u32,
    /// Roofline for step latency.
    pub roofline: Roofline,
    /// Power curve for energy.
    pub power: LogisticPower,
    /// GPUs charged per group-observation (1 = paper convention).
    pub gpus_charged: f64,
    /// Prompt tokens ingested per slot per step (chunked prefill).
    pub ingest_chunk: u32,
}

impl GroupSimConfig {
    /// Paged-KV block budget backing one group: n_max × window tokens in
    /// 64-token blocks (Eq. 3 inverted) — admission saturates at exactly
    /// n_max full-window sequences. Shared by the live engine and
    /// [`FleetState::initial`](super::events::FleetState::initial) so the
    /// all-idle state matches a fresh snapshot exactly.
    pub fn blocks_total(&self) -> u32 {
        (self.n_max as u64 * self.window_tokens as u64 / 64).max(1) as u32
    }
}

/// Result of simulating one pool.
#[derive(Debug, Clone)]
pub struct PoolSimReport {
    pub name: String,
    pub groups: u32,
    pub window_tokens: u32,
    pub metrics: ServeMetrics,
    pub output_tokens: u64,
    pub joules: f64,
    pub tok_per_watt: f64,
    /// Time-weighted mean in-flight batch per group.
    pub mean_batch: f64,
    /// Pool-wide decode throughput over the busy horizon, tok/s.
    pub decode_tok_s: f64,
    /// Horizon: last completion time, s.
    pub horizon_s: f64,
    /// Engine iterations executed across the pool's groups.
    pub steps: u64,
}

/// Simulate a routed topology: requests go through `router` to pools,
/// each with its own group count and config.
#[derive(Debug, Clone)]
pub struct TopoSimReport {
    pub pools: Vec<PoolSimReport>,
    pub output_tokens: u64,
    pub joules: f64,
    pub tok_per_watt: f64,
    /// Engine iterations executed fleet-wide.
    pub steps: u64,
}

impl TopoSimReport {
    /// Fleet-wide serving metrics: every pool's per-request
    /// TTFT/TPOT/E2E digests and counters merged into one — what a
    /// scenario cell reports its p99 TTFT from.
    pub fn fleet_metrics(&self) -> ServeMetrics {
        ServeMetrics::merged(self.pools.iter().map(|p| &p.metrics))
    }
}

/// Aggregate a pool's group outcomes in group-index order (the order is
/// part of the deterministic-replay contract: float sums match the legacy
/// sequential loop bit-for-bit).
fn aggregate_pool(
    name: &str,
    groups: u32,
    cfg: &GroupSimConfig,
    outcomes: Vec<GroupOutcome>,
) -> PoolSimReport {
    let mut joules = 0.0;
    let mut output_tokens = 0u64;
    let mut horizon_s: f64 = 0.0;
    let mut batch_integral = 0.0;
    let mut time_integral = 0.0;
    let mut steps = 0u64;

    for g in &outcomes {
        joules += g.joules;
        output_tokens += g.output_tokens;
        horizon_s = horizon_s.max(g.horizon_s);
        batch_integral += g.mean_batch * g.horizon_s;
        time_integral += g.horizon_s;
        steps += g.steps;
    }
    // One all-parts weighted merge (not a pairwise fold): linear in the
    // total samples, and a single proportional subsampling pass when any
    // group's digest is truncated.
    let metrics = ServeMetrics::merged(outcomes.iter().map(|g| &g.metrics));

    PoolSimReport {
        name: name.into(),
        groups,
        window_tokens: cfg.window_tokens,
        metrics,
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        mean_batch: if time_integral > 0.0 {
            batch_integral / time_integral
        } else {
            0.0
        },
        decode_tok_s: if horizon_s > 0.0 {
            output_tokens as f64 / horizon_s
        } else {
            0.0
        },
        horizon_s,
        steps,
    }
}

fn aggregate_topology(
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    outcomes: Vec<Vec<GroupOutcome>>,
) -> TopoSimReport {
    let pools: Vec<PoolSimReport> = outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            aggregate_pool(&format!("pool-{i}"), pool_groups[i], &pool_cfgs[i], o)
        })
        .collect();

    let output_tokens = pools.iter().map(|p| p.output_tokens).sum();
    let joules: f64 = pools.iter().map(|p| p.joules).sum();
    let steps = pools.iter().map(|p| p.steps).sum();
    TopoSimReport {
        output_tokens,
        tok_per_watt: if joules > 0.0 {
            output_tokens as f64 / joules
        } else {
            0.0
        },
        joules,
        steps,
        pools,
    }
}

/// Stable arrival-time sort (total order; NaN arrivals are rejected by
/// the engine with a clear message instead of a `partial_cmp` panic).
fn sorted_by_arrival(trace: &[Request]) -> Vec<Request> {
    let mut t = trace.to_vec();
    t.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    t
}

/// Simulate one pool of `groups` identical groups over its request slice
/// (round-robin dispatch at arrival — the legacy behavior, bit-for-bit).
pub fn simulate_pool(
    name: &str,
    requests: Vec<ServeRequest>,
    groups: u32,
    cfg: &GroupSimConfig,
) -> PoolSimReport {
    assert!(groups > 0);
    let trace: Vec<Request> = requests
        .iter()
        .map(|s| Request {
            id: s.id,
            arrival_s: s.arrival_s,
            prompt_tokens: s.prompt_tokens,
            output_tokens: s.output_tokens,
        })
        .collect();
    let trace = sorted_by_arrival(&trace);
    let mut rr = RoundRobin::new();
    let mut outcomes = run_fleet_auto(
        &trace,
        &crate::router::HomogeneousRouter,
        &[groups],
        std::slice::from_ref(cfg),
        &mut rr,
        EngineOptions::default(),
    );
    aggregate_pool(name, groups, cfg, outcomes.pop().expect("one pool"))
}

/// Simulate a routed topology with round-robin dispatch — the legacy
/// entry point, bit-for-bit compatible with the pre-refactor loop.
pub fn simulate_topology(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
) -> TopoSimReport {
    let mut rr = RoundRobin::new();
    simulate_topology_with(trace, router, pool_groups, pool_cfgs, &mut rr, true)
}

/// Full-control entry point: any dispatch policy, load-aware routers,
/// optional parallel per-group stepping (taken automatically when the
/// policy is arrival-static and the router is not load-aware).
pub fn simulate_topology_with(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    allow_parallel: bool,
) -> TopoSimReport {
    simulate_topology_opts(
        trace,
        router,
        pool_groups,
        pool_cfgs,
        dispatch,
        EngineOptions { allow_parallel, ..Default::default() },
    )
}

/// Everything-exposed entry point: on top of
/// [`simulate_topology_with`], selects the live-state maintenance mode
/// ([`StateMode`](super::events::StateMode) — incremental vs the legacy
/// rebuild-per-arrival oracle) and the per-event state cross-check used
/// by the property suites.
pub fn simulate_topology_opts(
    trace: &[Request],
    router: &dyn Router,
    pool_groups: &[u32],
    pool_cfgs: &[GroupSimConfig],
    dispatch: &mut dyn DispatchPolicy,
    opts: EngineOptions,
) -> TopoSimReport {
    let trace = sorted_by_arrival(trace);
    let outcomes =
        run_fleet_auto(&trace, router, pool_groups, pool_cfgs, dispatch, opts);
    aggregate_topology(pool_groups, pool_cfgs, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::{GpuProfile, ManualProfile};
    use crate::router::context::ContextRouter;
    use crate::sim::dispatch::{self, JoinShortestQueue};
    use crate::workload::synth::{generate, GenConfig};

    fn h100_cfg(window: u32) -> GroupSimConfig {
        let p = ManualProfile::h100_70b();
        GroupSimConfig {
            window_tokens: window,
            n_max: p.n_max(window),
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }
    }

    fn azure_trace(lambda: f64, secs: f64, max_prompt: u32) -> Vec<Request> {
        generate(
            &crate::workload::cdf::azure_conversations(),
            &GenConfig {
                lambda_rps: lambda,
                duration_s: secs,
                max_prompt_tokens: max_prompt,
                max_output_tokens: 512,
                seed: 42,
            },
        )
    }

    #[test]
    fn saturated_group_lands_near_analytical_tok_w() {
        // Saturate one 64K group: the analytical operating point says
        // n=16 at 435 W → 1.50 tok/W with L̄=64K. Live L̄ is smaller
        // (requests are mostly short), so the simulated tok/W must land
        // between the window bound and the short-context bound.
        let cfg = h100_cfg(65_536);
        let reqs: Vec<ServeRequest> = azure_trace(50.0, 4.0, 60_000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r = simulate_pool("sat", reqs, 1, &cfg);
        assert!(r.metrics.completed > 50, "completed {}", r.metrics.completed);
        assert!(
            r.tok_per_watt > 1.0,
            "simulated tok/W {} must beat the L̄=window bound",
            r.tok_per_watt
        );
        assert!(r.mean_batch > 8.0, "group should saturate: {}", r.mean_batch);
        assert!(r.steps > 0);
    }

    #[test]
    fn window_halving_doubles_tok_w_in_simulation() {
        // The 1/W law, dynamically: same traffic (all short), two window
        // configurations; n_max halves, tok/W roughly halves.
        let short_reqs: Vec<ServeRequest> = azure_trace(120.0, 3.0, 2000)
            .iter()
            .map(ServeRequest::from)
            .collect();
        let r8 = simulate_pool("w8k", short_reqs.clone(), 1, &h100_cfg(8192));
        let r32 = simulate_pool("w32k", short_reqs, 1, &h100_cfg(32_768));
        assert!(r8.metrics.completed > 100);
        let ratio = r8.tok_per_watt / r32.tok_per_watt;
        assert!(
            (2.0..=5.5).contains(&ratio),
            "8K vs 32K window tok/W ratio = {ratio:.2} (law: ≈4 at fixed \
             traffic, attenuated by live-L̄ effects)"
        );
    }

    #[test]
    fn routed_topology_beats_homogeneous_in_simulation() {
        // The paper's headline, validated dynamically end-to-end.
        let trace = azure_trace(40.0, 5.0, 60_000);
        let homo = simulate_topology(
            &trace,
            &crate::router::HomogeneousRouter,
            &[4],
            &[h100_cfg(65_536)],
        );
        // Short-pool window = split boundary + output headroom so that a
        // prompt routed short always fits prompt+output.
        let routed = simulate_topology(
            &trace,
            &ContextRouter::two_pool(4096),
            &[2, 2],
            &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
        );
        assert!(
            routed.tok_per_watt > homo.tok_per_watt,
            "routed {} vs homo {}",
            routed.tok_per_watt,
            homo.tok_per_watt
        );
        // Token conservation between topologies.
        assert_eq!(routed.output_tokens, homo.output_tokens);
    }

    #[test]
    fn idle_pool_burns_idle_power() {
        let cfg = h100_cfg(8192);
        let reqs = vec![ServeRequest {
            id: 0,
            prompt_tokens: 100,
            output_tokens: 10,
            arrival_s: 5.0, // five idle seconds first
        }];
        let r = simulate_pool("idle", reqs, 1, &cfg);
        assert!(r.joules > 5.0 * 299.0, "idle joules missing: {}", r.joules);
        assert_eq!(r.metrics.completed, 1);
    }

    #[test]
    fn deterministic() {
        let trace = azure_trace(30.0, 2.0, 30_000);
        let a = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        let b = simulate_topology(&trace, &crate::router::HomogeneousRouter,
                                  &[2], &[h100_cfg(65_536)]);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules, b.joules);
    }

    #[test]
    fn deterministic_under_stateful_dispatch() {
        let trace = azure_trace(30.0, 2.0, 30_000);
        let run = || {
            let mut jsq = JoinShortestQueue;
            simulate_topology_with(
                &trace,
                &crate::router::HomogeneousRouter,
                &[2],
                &[h100_cfg(65_536)],
                &mut jsq,
                true,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.output_tokens, b.output_tokens);
        assert_eq!(a.joules.to_bits(), b.joules.to_bits());
    }

    #[test]
    fn every_dispatch_policy_conserves_tokens() {
        let trace = azure_trace(40.0, 2.0, 4000);
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        for name in dispatch::ALL {
            let mut policy = dispatch::parse(name).unwrap();
            let r = simulate_topology_with(
                &trace,
                &ContextRouter::two_pool(4096),
                &[2, 2],
                &[h100_cfg(4096 + 1024), h100_cfg(65_536)],
                policy.as_mut(),
                true,
            );
            assert_eq!(r.output_tokens, want, "policy {name}");
            let done: u64 = r.pools.iter().map(|p| p.metrics.completed).sum();
            assert_eq!(done, trace.len() as u64, "policy {name}");
        }
    }
}
