//! Discrete-event fleet simulation — the dynamic counterpart of the
//! analytical planner.
//!
//! Where [`crate::fleet`] solves the steady state in closed form, this
//! module *plays the trace through* virtual GPU groups (continuous
//! batching, paged KV admission, roofline step times, logistic power
//! integration) and must land near the analytical tok/W — the crate's
//! internal consistency check.
//!
//! # Architecture
//!
//! The core ([`events`]) is a single event queue over one virtual clock:
//! arrival, step-complete and wake events drive **all groups of all
//! pools concurrently in virtual time**. The queue is a calendar/bucket
//! queue ([`calqueue`]) — amortized O(1) per event, bucket width seeded
//! from the trace's mean inter-arrival gap — with the pre-refactor
//! binary heap retained behind [`QueueMode::BinaryHeap`] as the
//! bit-for-bit replay oracle. That shared clock is what makes *stateful*
//! policies expressible: the engine owns one live [`FleetState`]
//! (per-pool queue depth, in-flight batch, free KV blocks), stored
//! **struct-of-arrays** — each hot per-group field is one contiguous
//! lane indexed by the flattened (pool, group) id, so dispatch scans and
//! per-event refreshes are cache-linear — and **maintained
//! incrementally**: only the event's touched group is refreshed, so at
//! every arrival the router and the [`DispatchPolicy`] borrow current
//! fleet load (via [`FleetState::pool`]'s [`PoolView`]) at zero
//! allocation cost, no matter how many groups the fleet has. The
//! pre-refactor rebuild-a-snapshot-per-arrival behavior survives as
//! [`StateMode::RebuildPerArrival`], the bit-for-bit verification
//! oracle.
//!
//! * [`calqueue`] — the calendar/bucket priority queue and its
//!   [`CalendarItem`](calqueue::CalendarItem) total-order contract.
//! * [`dispatch`] — round-robin, join-shortest-queue, least-KV-load and
//!   power-aware group selection behind the [`DispatchPolicy`] trait.
//! * [`events`] — the engine ([`EngineOptions`], [`StateMode`],
//!   [`QueueMode`], [`StepMode`]), plus the parallel fast path: when
//!   routing and dispatch are arrival-static, independent groups are
//!   stepped on worker threads and merged in group-index order,
//!   bit-identically to the sequential run — on the materialized path
//!   via a pre-assigned trace split, and on the streaming path via a
//!   sharded demux that routes one arrival at a time into bounded
//!   per-group channels, keeping memory at O(groups). Under the default
//!   [`StepMode::Fused`] the engine macro-steps: every decode/ingest
//!   iteration that provably completes before the next arrival runs in
//!   one in-line loop, so events popped scale with arrivals instead of
//!   decode steps ([`StepMode::PerStep`] keeps the one-event-per-step
//!   schedule as the bit-for-bit replay oracle).
//! * [`fleetsim`] — reports and entry points. [`simulate_pool`] /
//!   [`simulate_topology`] reproduce the pre-refactor round-robin
//!   simulator bit-for-bit (deterministic-replay guarantee);
//!   [`simulate_topology_with`] exposes policy and parallelism control;
//!   [`simulate_topology_opts`] additionally exposes the state mode, the
//!   queue mode and the per-event live-state cross-check;
//!   [`simulate_topology_source`] streams arrivals lazily from an
//!   [`ArrivalSource`](crate::workload::arrival::ArrivalSource) in O(1)
//!   trace memory, replaying the materialized run bit-for-bit (and
//!   taking the sharded parallel path itself when
//!   `opts.allow_parallel` holds and the scenario is arrival-static).
//! * [`par`] — the shared worker-pool plumbing: [`par::resolve_workers`]
//!   (explicit > `WATTLAW_WORKERS` env > available parallelism) and
//!   [`par::run_indexed`], the atomic-index work queue every parallel
//!   site (per-group fan-out, sweep grids, optimizer stage B) pulls
//!   from — results always merge in input order, so worker count never
//!   changes a byte of output.
//!
//! For running *grids* of (topology × workload × routing/dispatch)
//! configurations through this engine — the paper-style scenario
//! comparisons — see [`crate::scenario`]: a
//! [`ScenarioSpec`](crate::scenario::ScenarioSpec) describes one cell,
//! and [`scenario::sweep`](crate::scenario::sweep) fans cells out across
//! worker threads (`wattlaw simulate sweep` on the CLI).
//!
//! Determinism: every event is ordered by `(time, kind, sequence)` under
//! `f64::total_cmp` — the same strict total order in both queue modes —
//! policies are forbidden ambient randomness, and all aggregation runs
//! in index order — so a (trace, router, policy, seed) tuple replays to
//! the bit.

pub mod calqueue;
pub mod dispatch;
pub mod events;
pub mod fleetsim;
pub mod par;

pub use dispatch::{
    DispatchPolicy, JoinShortestQueue, LeastKvLoad, PowerAware, RoundRobin,
};
pub use events::{
    EngineOptions, FleetState, GroupLoad, GroupSimState, PoolLoad, PoolMeta,
    PoolView, QueueMode, StateMode, StepMode,
};
pub use fleetsim::{
    simulate_pool, simulate_topology, simulate_topology_opts,
    simulate_topology_source, simulate_topology_with, GroupSimConfig,
    PoolSimReport, TopoSimReport,
};
