//! Discrete-event fleet simulation — the dynamic counterpart of the
//! analytical planner. Where [`crate::fleet`] solves the steady state in
//! closed form, [`fleetsim`] *plays the trace through* virtual GPU groups
//! (continuous batching, paged KV admission, roofline step times,
//! logistic power integration) and must land near the analytical tok/W —
//! the crate's internal consistency check.

pub mod fleetsim;

pub use fleetsim::{simulate_pool, simulate_topology, GroupSimConfig, PoolSimReport, TopoSimReport};
