//! Discrete-event fleet simulation — the dynamic counterpart of the
//! analytical planner.
//!
//! Where [`crate::fleet`] solves the steady state in closed form, this
//! module *plays the trace through* virtual GPU groups (continuous
//! batching, paged KV admission, roofline step times, logistic power
//! integration) and must land near the analytical tok/W — the crate's
//! internal consistency check.
//!
//! # Architecture
//!
//! The core ([`events`]) is a single binary-heap event queue over one
//! virtual clock: arrival, step-complete and wake events drive **all
//! groups of all pools concurrently in virtual time**. That shared clock
//! is what makes *stateful* policies expressible: at every arrival the
//! router can read a live [`FleetState`] snapshot (per-pool queue depth,
//! in-flight batch, free KV blocks) and a [`DispatchPolicy`] picks the
//! destination group from the same snapshot.
//!
//! * [`dispatch`] — round-robin, join-shortest-queue, least-KV-load and
//!   power-aware group selection behind the [`DispatchPolicy`] trait.
//! * [`events`] — the engine, plus the parallel fast path: when routing
//!   and dispatch are arrival-static, independent groups are stepped on
//!   worker threads and merged in group-index order, bit-identically to
//!   the sequential run.
//! * [`fleetsim`] — reports and entry points. [`simulate_pool`] /
//!   [`simulate_topology`] reproduce the pre-refactor round-robin
//!   simulator bit-for-bit (deterministic-replay guarantee);
//!   [`simulate_topology_with`] exposes policy and parallelism control.
//!
//! Determinism: every event is ordered by `(time, kind, sequence)` under
//! `f64::total_cmp`, policies are forbidden ambient randomness, and all
//! aggregation runs in index order — so a (trace, router, policy, seed)
//! tuple replays to the bit.

pub mod dispatch;
pub mod events;
pub mod fleetsim;

pub use dispatch::{
    DispatchPolicy, JoinShortestQueue, LeastKvLoad, PowerAware, RoundRobin,
};
pub use events::{FleetState, GroupLoad, PoolLoad};
pub use fleetsim::{
    simulate_pool, simulate_topology, simulate_topology_with, GroupSimConfig,
    PoolSimReport, TopoSimReport,
};
