//! Shared worker-pool plumbing: one place that decides *how many*
//! threads to use and one place that decides *which thread does what*.
//!
//! Before this module every parallel site rolled its own
//! `available_parallelism().unwrap_or(1)` plus a static
//! `chunks()/div_ceil` split. Static chunking loses up to
//! (workers−1)/workers of the machine on skewed inputs: one slow cell
//! (a λ=4000 streaming run next to closed-form-cheap neighbors) pins
//! its whole chunk's thread while the others drain and idle.
//! [`run_indexed`] replaces the split with a shared atomic work index —
//! every worker pulls the next undone item the moment it finishes its
//! last one, so the makespan is bounded by the slowest *single item*
//! rather than the slowest *chunk* — while still returning results in
//! input order, so callers stay deterministic byte-for-byte regardless
//! of the worker count.
//!
//! [`resolve_workers`] centralizes the worker-count policy:
//! explicit request (`--workers N`) > `WATTLAW_WORKERS` env override >
//! [`std::thread::available_parallelism`].

use std::sync::atomic::{AtomicUsize, Ordering};

/// Environment variable consulted when no explicit worker count is
/// given. Values that fail to parse as a positive integer are ignored.
pub const WORKERS_ENV: &str = "WATTLAW_WORKERS";

/// Resolve the number of worker threads to use: an explicit request
/// wins, else the `WATTLAW_WORKERS` env override, else the machine's
/// available parallelism. Always at least 1.
pub fn resolve_workers(explicit: Option<usize>) -> usize {
    if let Some(n) = explicit {
        return n.max(1);
    }
    if let Ok(v) = std::env::var(WORKERS_ENV) {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(0), f(1), …, f(n-1)` on up to `workers` scoped threads
/// and return the results **in index order**. Work is distributed by a
/// shared atomic index (work stealing in the degenerate
/// everyone-steals-from-one-queue sense): no static split, no idle
/// thread while undone items remain. With `workers <= 1` (or `n <= 1`)
/// everything runs on the calling thread — no threads are spawned, so
/// single-worker callers keep their exact sequential behavior.
///
/// `f` must be pure up to its index (no cross-item ordering
/// assumptions); results are identical for every worker count.
pub fn run_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut filled: Vec<Vec<(usize, T)>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            filled.push(h.join().expect("sim::par worker panicked"));
        }
    });
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in filled.into_iter().flatten() {
        out[i] = Some(v);
    }
    out.into_iter()
        .map(|s| s.expect("atomic index covered every item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_worker_request_wins_and_is_clamped() {
        assert_eq!(resolve_workers(Some(3)), 3);
        assert_eq!(resolve_workers(Some(0)), 1);
    }

    #[test]
    fn results_are_in_index_order_for_every_worker_count() {
        let expect: Vec<usize> = (0..37).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 64] {
            assert_eq!(run_indexed(37, workers, |i| i * i), expect);
        }
    }

    #[test]
    fn empty_and_single_item_inputs() {
        assert_eq!(run_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(1, 4, |i| i + 1), vec![1]);
    }
}
