//! Figure (dispatch) — simulated dispatch-policy comparison on the
//! event-driven core: the same bursty two-pool trace played through
//! round-robin, join-shortest-queue, least-KV-load and power-aware group
//! dispatch.
//!
//! This is the first table that *requires* the shared-clock engine: every
//! policy except round-robin reads the live [`FleetState`]
//! (per-group queue depth, in-flight batch, free KV blocks) at each
//! arrival, which the legacy isolated per-group loops could not provide.
//!
//! [`FleetState`]: crate::sim::FleetState

use super::render::Table;
use crate::fleet::profile::ManualProfile;
use crate::fleet::topology::Topology;
use crate::sim::{dispatch, simulate_topology_with, TopoSimReport};
use crate::workload::synth::{generate, GenConfig};
use crate::workload::Request;

/// A deterministic bursty two-pool trace: steady Azure-shaped background
/// traffic plus periodic short-prompt bursts that pile onto the short
/// pool — the regime where load-aware dispatch separates from
/// round-robin.
pub fn bursty_trace() -> Vec<Request> {
    let mut reqs = generate(
        &crate::workload::cdf::azure_conversations(),
        &GenConfig {
            lambda_rps: 30.0,
            duration_s: 3.0,
            max_prompt_tokens: 30_000,
            max_output_tokens: 256,
            seed: 42,
        },
    );
    let base_id = reqs.len() as u64;
    for burst in 0..3u64 {
        for i in 0..24u64 {
            reqs.push(Request {
                id: base_id + burst * 24 + i,
                arrival_s: burst as f64 + 0.001 * i as f64,
                prompt_tokens: 512,
                // Size-skewed bursts: round-robin's parity assignment
                // piles the heavy half onto the same groups.
                output_tokens: if i % 2 == 0 { 16 } else { 384 },
            });
        }
    }
    reqs
}

/// Simulate one policy over the bursty trace.
pub fn simulate_policy(name: &str) -> TopoSimReport {
    let trace = bursty_trace();
    let profile = ManualProfile::h100_70b();
    let topo = Topology::PoolRouting { b_short: 4096, short_ctx: 4096 };
    let (groups, cfgs) = topo.sim_pools(&profile, 4, 1024);
    let router = topo.router();
    let mut policy = dispatch::parse(name).expect("known policy");
    simulate_topology_with(
        &trace,
        router.as_ref(),
        &groups,
        &cfgs,
        policy.as_mut(),
        true,
    )
}

pub fn generate() -> String {
    let mut t = Table::new(
        "Figure (dispatch) — group dispatch policies, simulated \
         (H100, two-pool 4K split, bursty Azure trace)",
        &["Dispatch", "tok/W", "tokens", "kJ", "steps", "p99 TTFT (s)"],
    );
    for name in dispatch::ALL {
        let r = simulate_policy(name);
        let mut merged = crate::serve::metrics::ServeMetrics::default();
        for p in &r.pools {
            merged.merge(&p.metrics);
        }
        t.row(vec![
            name.to_string(),
            format!("{:.3}", r.tok_per_watt),
            format!("{}", r.output_tokens),
            format!("{:.1}", r.joules / 1e3),
            format!("{}", r.steps),
            format!("{:.3}", merged.ttft_s.p99()),
        ]);
    }
    t.note(
        "same trace, same pools; only the arrival-time group decision \
         changes — stateful policies read live queue/batch/KV state from \
         the event engine",
    );
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_policy_and_conserves_tokens() {
        let s = generate();
        for name in dispatch::ALL {
            assert!(s.contains(name), "missing {name}");
        }
        let want: u64 = bursty_trace()
            .iter()
            .map(|r| r.output_tokens as u64)
            .sum();
        for name in dispatch::ALL {
            assert_eq!(simulate_policy(name).output_tokens, want, "{name}");
        }
    }
}
