//! Figure (dispatch) — simulated dispatch-policy comparison on the
//! event-driven core: the same bursty two-pool trace played through
//! round-robin, join-shortest-queue, least-KV-load and power-aware group
//! dispatch.
//!
//! This is the first table that *requires* the shared-clock engine: every
//! policy except round-robin reads the live [`FleetState`]
//! (per-group queue depth, in-flight batch, free KV blocks) at each
//! arrival, which the legacy isolated per-group loops could not provide.
//!
//! [`FleetState`]: crate::sim::FleetState

use crate::fleet::topology::Topology;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::scenario::{ScenarioOutcome, ScenarioSpec};
use crate::sim::dispatch;
use crate::workload::synth::GenConfig;
use crate::workload::Request;

/// A deterministic bursty two-pool trace: steady Azure-shaped background
/// traffic plus periodic short-prompt bursts that pile onto the short
/// pool — the regime where load-aware dispatch separates from
/// round-robin.
/// Background-traffic generator — one definition shared by the trace
/// builder and the scenario spec's label, so they cannot drift apart.
fn background_gen() -> GenConfig {
    GenConfig {
        lambda_rps: 30.0,
        duration_s: 3.0,
        max_prompt_tokens: 30_000,
        max_output_tokens: 256,
        seed: 42,
    }
}

pub fn bursty_trace() -> Vec<Request> {
    let mut reqs = crate::workload::synth::generate(
        &crate::workload::cdf::azure_conversations(),
        &background_gen(),
    );
    let base_id = reqs.len() as u64;
    for burst in 0..3u64 {
        for i in 0..24u64 {
            reqs.push(Request {
                id: base_id + burst * 24 + i,
                arrival_s: burst as f64 + 0.001 * i as f64,
                prompt_tokens: 512,
                // Size-skewed bursts: round-robin's parity assignment
                // piles the heavy half onto the same groups.
                output_tokens: if i % 2 == 0 { 16 } else { 384 },
            });
        }
    }
    reqs
}

/// Simulate one policy over the bursty trace — a [`ScenarioSpec`] cell
/// with only the dispatch axis varying (the scenario layer's unified
/// configuration; the hand-crafted trace overrides the spec's generator).
pub fn simulate_policy(name: &str) -> ScenarioOutcome {
    let spec = ScenarioSpec::new(
        Topology::PoolRouting { b_short: 4096, short_ctx: 4096 },
        Gpu::H100,
        crate::workload::cdf::azure_conversations(),
        background_gen(),
    )
    .with_groups(4)
    .with_dispatch(name);
    spec.simulate_trace(&bursty_trace(), true)
}

/// The typed rowset behind the figure.
pub fn rowset() -> RowSet {
    let mut t = RowSet::new(
        "Figure (dispatch) — group dispatch policies, simulated \
         (H100, two-pool 4K split, bursty Azure trace)",
        vec![
            Column::str("Dispatch"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::int("tokens"),
            Column::float("energy").with_unit("kJ"),
            Column::int("steps"),
            Column::float("p99 TTFT").with_unit("s"),
        ],
    );
    for name in dispatch::ALL {
        let r = simulate_policy(name);
        t.push(vec![
            Cell::str(name),
            Cell::float(r.tok_per_watt).shown(format!("{:.3}", r.tok_per_watt)),
            Cell::int(r.output_tokens as i64),
            Cell::float(r.joules / 1e3).shown(format!("{:.1}", r.joules / 1e3)),
            Cell::int(r.steps as i64),
            Cell::float(r.p99_ttft_s).shown(format!("{:.3}", r.p99_ttft_s)),
        ]);
    }
    t.note(
        "same trace, same pools; only the arrival-time group decision \
         changes — stateful policies read live queue/batch/KV state from \
         the event engine",
    );
    t
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_policy_and_conserves_tokens() {
        let s = generate();
        for name in dispatch::ALL {
            assert!(s.contains(name), "missing {name}");
        }
        let want: u64 = bursty_trace()
            .iter()
            .map(|r| r.output_tokens as u64)
            .sum();
        for name in dispatch::ALL {
            assert_eq!(simulate_policy(name).output_tokens, want, "{name}");
        }
    }
}
