//! §4.2 — the factorization analysis: topology and generation gains are
//! independent levers whose product predicts the combined gain.
//!
//! ```text
//! Δ_topo(G) = tok/W_FleetOpt(G) / tok/W_Homo(G)
//! Δ_gen(T)  = tok/W_B200(T)     / tok/W_H100(T)
//! combined  ≈ Δ_topo × Δ_gen
//! ```

use std::sync::Arc;

use super::render::{f2, tokw};
use crate::fleet::analysis::fleet_tpw_analysis;
use crate::results::{Cell, Column, RowSet};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::workload::cdf::{azure_conversations, WorkloadTrace};

#[derive(Debug, Clone)]
pub struct Independence {
    pub trace: &'static str,
    /// tok/W indexed by [topology 0..3][gpu 0..2] = [Homo,Pool,Opt]×[H100,B200].
    pub grid: [[f64; 2]; 3],
    pub d_topo_h100: f64,
    pub d_topo_b200: f64,
    pub d_gen_homo: f64,
    pub d_gen_opt: f64,
    pub combined: f64,
    pub product: f64,
}

pub fn analyze(trace: &WorkloadTrace, lbar: LBarPolicy) -> Independence {
    let b = trace.paper_b_short;
    let topos = [
        Topology::Homogeneous { ctx: LONG_CTX },
        Topology::PoolRouting { b_short: b, short_ctx: b.max(2048) },
        Topology::FleetOpt { b_short: b, short_ctx: b.max(2048), gamma: 2.0 },
    ];
    let mut grid = [[0.0; 2]; 3];
    for (gi, gpu) in [Gpu::H100, Gpu::B200].into_iter().enumerate() {
        let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
        for (ti, topo) in topos.iter().enumerate() {
            let pools =
                topo.pools(trace, 1000.0, profile.clone(), None, lbar, 0.85, 0.5);
            grid[ti][gi] =
                fleet_tpw_analysis(&pools, PowerAccounting::PerGpu).tok_per_watt.0;
        }
    }
    let d_topo_h100 = grid[2][0] / grid[0][0];
    let d_topo_b200 = grid[2][1] / grid[0][1];
    let d_gen_homo = grid[0][1] / grid[0][0];
    let d_gen_opt = grid[2][1] / grid[2][0];
    Independence {
        trace: trace.name,
        grid,
        d_topo_h100,
        d_topo_b200,
        d_gen_homo,
        d_gen_opt,
        combined: grid[2][1] / grid[0][0],
        product: d_topo_h100 * d_gen_homo,
    }
}

/// The typed rowsets behind the analysis: the 3×2 grid and the
/// multiplicativity check.
pub fn rowsets(lbar: LBarPolicy) -> Vec<RowSet> {
    let a = analyze(&azure_conversations(), lbar);
    let mut t = RowSet::new(
        format!("§4.2 — topology × generation independence (Azure, L̄={lbar:?})"),
        vec![
            Column::str("topology"),
            Column::float("H100").with_unit("tok/J"),
            Column::float("B200").with_unit("tok/J"),
            Column::float("Δ_gen"),
        ],
    );
    let names = ["Homo 64K", "Pool routing", "FleetOpt"];
    for (i, n) in names.iter().enumerate() {
        t.push(vec![
            Cell::str(*n),
            Cell::float(a.grid[i][0]).shown(tokw(a.grid[i][0])),
            Cell::float(a.grid[i][1]).shown(tokw(a.grid[i][1])),
            Cell::float(a.grid[i][1] / a.grid[i][0])
                .shown(f2(a.grid[i][1] / a.grid[i][0])),
        ]);
    }
    t.push(vec![
        Cell::str("Δ_topo (Opt/Homo)"),
        Cell::float(a.d_topo_h100).shown(f2(a.d_topo_h100)),
        Cell::float(a.d_topo_b200).shown(f2(a.d_topo_b200)),
        Cell::missing().shown(""),
    ]);
    let mut s = RowSet::new(
        "Multiplicativity check",
        vec![Column::str("quantity"), Column::float("value")],
    );
    s.push(vec![
        Cell::str("Δ_topo(H100) × Δ_gen(Homo)"),
        Cell::float(a.product).shown(f2(a.product)),
    ]);
    s.push(vec![
        Cell::str("combined (B200 FleetOpt / H100 Homo)"),
        Cell::float(a.combined).shown(f2(a.combined)),
    ]);
    let rel = ((a.combined - a.product) / a.product * 100.0).abs();
    s.push(vec![
        Cell::str("relative error (%)"),
        Cell::float(rel).shown(format!("{rel:.1}%")),
    ]);
    s.note("paper: Δ_topo ≈ 2.5, Δ_gen ≈ 1.7, product ≈ combined ≈ 4.25; our \
            honest sizing yields larger Δ_topo (the paper's Homo fleet exceeds \
            its own 64K per-GPU bound — EXPERIMENTS.md §T3) but the \
            independence/multiplicativity structure is exactly reproduced");
    vec![t, s]
}

pub fn generate(lbar: LBarPolicy) -> String {
    rowsets(lbar).iter().map(|r| r.to_text()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn independence_and_multiplicativity_hold() {
        let a = analyze(&azure_conversations(), LBarPolicy::Window);
        // Δ_topo barely changes across generations.
        assert!(
            (a.d_topo_h100 - a.d_topo_b200).abs() / a.d_topo_h100 < 0.2,
            "Δ_topo: {} vs {}",
            a.d_topo_h100,
            a.d_topo_b200
        );
        // Δ_gen barely changes across topologies.
        assert!(
            (a.d_gen_homo - a.d_gen_opt).abs() / a.d_gen_homo < 0.2,
            "Δ_gen: {} vs {}",
            a.d_gen_homo,
            a.d_gen_opt
        );
        // Product predicts combined.
        assert!(
            (a.combined - a.product).abs() / a.product < 0.15,
            "combined {} vs product {}",
            a.combined,
            a.product
        );
    }

    #[test]
    fn neither_lever_alone_reaches_half_the_combined_gain() {
        // The paper's closing argument, asserted on our numbers.
        let a = analyze(&azure_conversations(), LBarPolicy::Window);
        assert!(a.d_topo_h100 < a.combined);
        assert!(a.d_gen_homo < a.combined / 2.0);
    }

    #[test]
    fn weakens_but_survives_traffic_mean_ablation() {
        // Under TrafficMean L̄ the pool split changes each pool's scan
        // cost, so the levers interact mildly; multiplicativity loosens to
        // ~±35 % but both levers still compound well beyond either alone.
        let a = analyze(&azure_conversations(), LBarPolicy::TrafficMean);
        assert!(
            (a.combined - a.product).abs() / a.product < 0.4,
            "combined {} vs product {}",
            a.combined,
            a.product
        );
        assert!(a.combined > a.d_topo_h100.max(a.d_gen_homo));
    }
}
