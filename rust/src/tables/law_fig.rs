//! Figure-equivalent: the 1/W law curve — tok/W vs context window on a
//! log–log grid for every GPU generation, with the fitted slope and the
//! 2K→128K spread (§3.1's "nearly 40×").

use super::render::{ctx_k, f2, tokw};
use crate::fleet::profile::ManualProfile;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::tokeconomy::law::{fit_law, LawFit, LAW_CONTEXTS};

pub fn fits() -> Vec<(Gpu, LawFit)> {
    Gpu::ALL
        .iter()
        .map(|&g| (g, fit_law(&ManualProfile::for_gpu(g), &LAW_CONTEXTS)))
        .collect()
}

/// The typed rowsets behind the figure: the curve and the fit stats.
pub fn rowsets() -> Vec<RowSet> {
    let all = fits();
    let mut t = RowSet::new(
        "Figure (1/W law) — tok/W vs context window, all GPU generations",
        vec![
            Column::str("Context"),
            Column::float("H100").with_unit("tok/J"),
            Column::float("H200").with_unit("tok/J"),
            Column::float("B200").with_unit("tok/J"),
            Column::float("GB200").with_unit("tok/J"),
        ],
    );
    for (i, &ctx) in LAW_CONTEXTS.iter().enumerate() {
        let mut row = vec![Cell::str(ctx_k(ctx))];
        for fit in all.iter().map(|(_, f)| f) {
            let v = fit.points[i].tok_per_watt.0;
            row.push(Cell::float(v).shown(tokw(v)));
        }
        t.push(row);
    }
    let mut s = RowSet::new(
        "1/W law statistics (log–log slope; per-doubling halving; spread)",
        vec![
            Column::str("GPU"),
            Column::float("slope"),
            Column::float("min ratio"),
            Column::float("max ratio"),
            Column::float("2K→128K spread").with_unit("x"),
        ],
    );
    for (g, f) in &all {
        let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
        for r in &f.halving_ratios {
            lo = lo.min(*r);
            hi = hi.max(*r);
        }
        s.push(vec![
            Cell::str(g.spec().name),
            Cell::float(f.slope).shown(f2(f.slope)),
            Cell::float(lo).shown(f2(lo)),
            Cell::float(hi).shown(f2(hi)),
            Cell::float(f.spread).shown(format!("{:.1}x", f.spread)),
        ]);
    }
    s.note("the law predicts slope −1 / ratio 2.0; the tail softens to ≈1.7 \
            because P(b) also falls at tiny n_max — visible in the paper's \
            own Table 1 (1.50/0.88 = 1.70)");
    vec![t, s]
}

pub fn generate() -> String {
    let all = fits();
    let tables: String = rowsets().iter().map(|r| r.to_text()).collect();

    // ASCII log-log sparkline for the H100 curve.
    let mut plot = String::from("\nlog2(tok/W) vs log2(context), H100:\n");
    for p in &all[0].1.points {
        let stars = ((p.tok_per_watt.0.log2() + 1.0) * 4.0).max(1.0) as usize;
        plot.push_str(&format!(
            "{:>6} | {} {:.2}\n",
            ctx_k(p.context),
            "#".repeat(stars),
            p.tok_per_watt.0
        ));
    }
    format!("{tables}{plot}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generations_obey_the_law() {
        for (g, f) in fits() {
            assert!(
                f.slope < -0.8 && f.slope > -1.05,
                "{:?}: slope {}",
                g,
                f.slope
            );
            assert!(f.spread > 30.0, "{:?}: spread {}", g, f.spread);
        }
    }

    #[test]
    fn curves_are_vertically_ordered_at_short_context() {
        // At 2K–8K: B200 > H200 > H100 (GB200 sits below B200 per-GPU).
        let all = fits();
        for i in 0..3 {
            let h100 = all[0].1.points[i].tok_per_watt.0;
            let h200 = all[1].1.points[i].tok_per_watt.0;
            let b200 = all[2].1.points[i].tok_per_watt.0;
            assert!(h100 < h200 && h200 < b200, "index {i}");
        }
    }

    #[test]
    fn renders_plot() {
        let s = generate();
        assert!(s.contains("###"));
        assert!(s.contains("128K"));
    }
}
