//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §6 maps each to its module and bench target).

pub mod dispatch_fig;
pub mod independence;
pub mod law_fig;
pub mod power_fig;
pub mod render;
pub mod t1;
pub mod t2;
pub mod t3;
pub mod t4;
pub mod t5;
pub mod t6;
pub mod t7;
pub mod t8;
pub mod t10;
pub mod t9;

use crate::fleet::pool::LBarPolicy;
use crate::results::RowSet;

/// Every artifact's CLI flag, in `tables --all` emission order.
pub const ALL_FLAGS: [&str; 14] = [
    "t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10", "law",
    "power-fig", "dispatch-fig", "independence",
];

/// The typed rowsets behind one artifact, keyed by its CLI flag — the
/// machine-readable path `tables --format csv|json` emits through (the
/// figures' ASCII plots are table-format-only garnish and are not part
/// of the rowsets).
pub fn rowsets_for(flag: &str, lbar: LBarPolicy) -> Option<Vec<RowSet>> {
    Some(match flag {
        "t1" => vec![t1::rowset()],
        "t2" => vec![t2::rowset()],
        "t3" => vec![t3::rowset(lbar)],
        "t4" => vec![t4::rowset()],
        "t5" => vec![t5::rowset()],
        "t6" => vec![t6::rowset()],
        "t7" => t7::rowsets(),
        "t8" => vec![t8::rowset()],
        "t9" => vec![t9::rowset()],
        "t10" => vec![t10::rowset()],
        "law" => law_fig::rowsets(),
        "power-fig" => vec![power_fig::rowset()],
        "dispatch-fig" => vec![dispatch_fig::rowset()],
        "independence" => independence::rowsets(lbar),
        _ => return None,
    })
}

/// Generate every table + figure as one report (the `tables --all` output).
pub fn generate_all(lbar: LBarPolicy) -> String {
    let mut s = String::new();
    s.push_str(&t1::generate());
    s.push_str(&t2::generate());
    s.push_str(&t3::generate(lbar));
    s.push_str(&t4::generate());
    s.push_str(&t5::generate());
    s.push_str(&t6::generate());
    s.push_str(&t7::generate());
    s.push_str(&t8::generate());
    s.push_str(&t9::generate());
    s.push_str(&t10::generate());
    s.push_str(&law_fig::generate());
    s.push_str(&power_fig::generate());
    s.push_str(&dispatch_fig::generate());
    s.push_str(&independence::generate(lbar));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_all_contains_every_artifact() {
        let s = generate_all(LBarPolicy::Window);
        for needle in [
            "Table 1", "Table 2", "Table 3", "Table 4", "Table 5",
            "Table 6", "Table 7", "Table 8", "Table 9", "Table 10",
            "1/W law",
            "Figure (power)", "Figure (dispatch)", "independence",
        ] {
            assert!(s.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn every_flag_resolves_to_rowsets() {
        // The fast artifacts: every flag except the simulation-backed
        // dispatch figure and the K-pool/heterogeneity tables (covered
        // by their own module tests).
        for flag in ALL_FLAGS {
            if flag == "dispatch-fig" || flag == "t8" || flag == "t9" {
                continue;
            }
            let sets = rowsets_for(flag, LBarPolicy::Window)
                .unwrap_or_else(|| panic!("no rowsets for {flag}"));
            assert!(!sets.is_empty(), "{flag}");
            for rs in &sets {
                // Machine formats must at least be structurally valid.
                assert!(rs.to_csv().lines().count() >= 1, "{flag}");
                crate::runtime::json::parse(&rs.to_json())
                    .unwrap_or_else(|e| panic!("{flag}: bad JSON: {e}"));
            }
        }
        assert!(rowsets_for("bogus", LBarPolicy::Window).is_none());
    }
}
