//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §6 maps each to its module and bench target).

pub mod dispatch_fig;
pub mod independence;
pub mod law_fig;
pub mod power_fig;
pub mod render;
pub mod t1;
pub mod t2;
pub mod t3;
pub mod t4;
pub mod t5;
pub mod t6;
pub mod t7;

use crate::fleet::pool::LBarPolicy;

/// Generate every table + figure as one report (the `tables --all` output).
pub fn generate_all(lbar: LBarPolicy) -> String {
    let mut s = String::new();
    s.push_str(&t1::generate());
    s.push_str(&t2::generate());
    s.push_str(&t3::generate(lbar));
    s.push_str(&t4::generate());
    s.push_str(&t5::generate());
    s.push_str(&t6::generate());
    s.push_str(&t7::generate());
    s.push_str(&law_fig::generate());
    s.push_str(&power_fig::generate());
    s.push_str(&dispatch_fig::generate());
    s.push_str(&independence::generate(lbar));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_all_contains_every_artifact() {
        let s = generate_all(LBarPolicy::Window);
        for needle in [
            "Table 1", "Table 2", "Table 3", "Table 4", "Table 5",
            "Table 6", "Table 7", "1/W law", "Figure (power)",
            "Figure (dispatch)", "independence",
        ] {
            assert!(s.contains(needle), "missing {needle}");
        }
    }
}
