//! Figure-equivalent: the logistic P(b) curves (paper Eq. 1 / the G2G
//! Figure-2 shape) for every GPU generation, b ∈ {1..1024}.

use super::render::f0;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};

pub const BATCHES: [f64; 11] =
    [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];

/// The typed rowset behind the figure.
pub fn rowset() -> RowSet {
    let mut t = RowSet::new(
        "Figure (power) — logistic P(b), watts vs in-flight batch",
        vec![
            Column::int("b"),
            Column::float("H100").with_unit("W"),
            Column::float("H200").with_unit("W"),
            Column::float("B200").with_unit("W"),
            Column::float("GB200").with_unit("W"),
        ],
    );
    for &b in &BATCHES {
        let mut row = vec![Cell::int(b as i64)];
        for gpu in Gpu::ALL {
            let w = gpu.spec().power.power_w(b);
            row.push(Cell::float(w).shown(f0(w)));
        }
        t.push(row);
    }
    t.note("H100 anchors: 300 W @b≈1, ≈600 W @b=128 (ML.ENERGY v3.0, <3% fit)");
    t
}

pub fn generate() -> String {
    let t = rowset();

    // ASCII curve for H100.
    let p = &Gpu::H100.spec().power;
    let mut plot = String::from("\nP(b), H100 (# = 10 W above idle):\n");
    for &b in &BATCHES {
        let w = p.power_w(b);
        let bars = ((w - p.p_idle_w) / 10.0).round() as usize;
        plot.push_str(&format!("b={b:>5} | {} {w:.0} W\n", "#".repeat(bars)));
    }
    format!("{}{}", t.to_text(), plot)
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_saturating_curves() {
        let s = super::generate();
        assert!(s.contains("b=    1"));
        assert!(s.contains("1024"));
    }
}
