//! Table rendering for the paper-regeneration commands — since the
//! results-layer refactor, a thin compatibility wrapper over the typed
//! [`RowSet`](crate::results::RowSet): `Table` keeps the old
//! string-row builder API for surfaces that are inherently textual,
//! while the shared `RowSet` does the actual alignment/markdown work
//! (and gains CSV/JSON for free via [`Table::into_rowset`]). New code
//! and the typed tables (t1–t7) build `RowSet`s directly.

pub use crate::results::Align;
use crate::results::{Cell, Column, RowSet};

/// A simple aligned text table (string cells; first column left-aligned,
/// the rest right). Backed by a [`RowSet`] with `Str`-typed columns.
#[derive(Debug, Clone)]
pub struct Table {
    rs: RowSet,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        let columns = headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                if i == 0 {
                    Column::str(*h)
                } else {
                    Column::str(*h).right()
                }
            })
            .collect();
        Table { rs: RowSet::new(title, columns) }
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.rs.align(col, a);
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rs.push(cells.into_iter().map(Cell::str).collect());
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.rs.note(n);
        self
    }

    pub fn render(&self) -> String {
        self.rs.to_text()
    }

    /// The backing typed rowset (string-valued), for CSV/JSON emission
    /// of tables that are built through this legacy API.
    pub fn into_rowset(self) -> RowSet {
        self.rs
    }
}

/// Format helpers shared by the table generators.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f0(x: f64) -> String {
    format!("{x:.0}")
}
/// tok/W with the paper's precision convention (2 dp < 10, else 1 dp).
pub fn tokw(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}
/// Context in K.
pub fn ctx_k(ctx: u32) -> String {
    format!("{}K", ctx / 1024)
}
/// Ratio vs a baseline as the paper's "+NN%" column.
pub fn vs_pct(x: f64, base: f64) -> String {
    if (x - base).abs() < 1e-9 {
        "—".into()
    } else {
        format!("{:+.0}%", (x / base - 1.0) * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.0".into()]);
        t.row(vec!["b".into(), "12345.6".into()]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("# Demo"));
        assert!(s.contains("| alpha |"));
        assert!(s.contains("note: hello"));
        // alignment: value column right-aligned to the widest cell
        assert!(s.contains("|     1.0 |"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn percent_formatting() {
        assert_eq!(vs_pct(15.0, 10.0), "+50%");
        assert_eq!(vs_pct(10.0, 10.0), "—");
        assert_eq!(vs_pct(5.0, 10.0), "-50%");
    }

    #[test]
    fn wrapper_exposes_its_rowset() {
        let mut t = Table::new("W", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        let rs = t.clone().into_rowset();
        assert_eq!(rs.columns().len(), 2);
        assert_eq!(rs.to_csv(), "a,b\nx,1\n");
        assert_eq!(rs.to_text(), t.render());
    }
}
