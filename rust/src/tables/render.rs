//! ASCII/markdown table rendering for the paper-regeneration commands.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_cell = |s: &str, w: usize, a: Align| match a {
            Align::Left => format!("{s:<w$}"),
            Align::Right => format!("{s:>w$}"),
        };
        let mut out = String::new();
        out.push_str(&format!("\n# {}\n\n", self.title));
        let hdr: Vec<String> = (0..ncols)
            .map(|i| fmt_cell(&self.headers[i], widths[i], self.aligns[i]))
            .collect();
        out.push_str(&format!("| {} |\n", hdr.join(" | ")));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in &self.rows {
            let cells: Vec<String> = (0..ncols)
                .map(|i| fmt_cell(&r[i], widths[i], self.aligns[i]))
                .collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }
}

/// Format helpers shared by the table generators.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f0(x: f64) -> String {
    format!("{x:.0}")
}
/// tok/W with the paper's precision convention (2 dp < 10, else 1 dp).
pub fn tokw(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}
/// Context in K.
pub fn ctx_k(ctx: u32) -> String {
    format!("{}K", ctx / 1024)
}
/// Ratio vs a baseline as the paper's "+NN%" column.
pub fn vs_pct(x: f64, base: f64) -> String {
    if (x - base).abs() < 1e-9 {
        "—".into()
    } else {
        format!("{:+.0}%", (x / base - 1.0) * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.0".into()]);
        t.row(vec!["b".into(), "12345.6".into()]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("# Demo"));
        assert!(s.contains("| alpha |"));
        assert!(s.contains("note: hello"));
        // alignment: value column right-aligned to the widest cell
        assert!(s.contains("|     1.0 |"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn percent_formatting() {
        assert_eq!(vs_pct(15.0, 10.0), "+50%");
        assert_eq!(vs_pct(10.0, 10.0), "—");
        assert_eq!(vs_pct(5.0, 10.0), "-50%");
    }
}
