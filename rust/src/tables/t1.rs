//! Table 1 — n_max and tok/W vs context window for Llama-3.1-70B (TP=8,
//! fp16) on H100-SXM5 (calibrated, HIGH) and B200-SXM (projected, FAIR).

use super::render::{ctx_k, f0, tokw};
use crate::fleet::profile::{ManualProfile, PowerAccounting};
use crate::results::{Cell, Column, RowSet};
use crate::tokeconomy::{context_sweep, OperatingPoint};

pub const CONTEXTS: [u32; 7] = [2048, 4096, 8192, 16384, 32768, 65536, 131072];

/// Paper's published values for the comparison columns:
/// (context, h100 n_max, h100 P, h100 tok/W, b200 n_max, b200 P, b200 tok/W).
pub const PAPER: [(u32, u32, f64, f64, u32, f64, f64); 7] = [
    (2048, 512, 598.0, 35.0, 1343, 859.0, 61.4),
    (4096, 256, 593.0, 17.6, 671, 857.0, 30.8),
    (8192, 128, 583.0, 8.97, 335, 852.0, 15.5),
    (16384, 64, 557.0, 4.69, 167, 838.0, 7.87),
    (32768, 32, 507.0, 2.58, 83, 805.0, 4.09),
    (65536, 16, 435.0, 1.50, 41, 735.0, 2.24),
    (131072, 8, 369.0, 0.88, 20, 630.0, 1.30),
];

/// Our regenerated rows.
#[derive(Debug, Clone)]
pub struct T1Row {
    pub context: u32,
    pub h100: OperatingPoint,
    pub b200: OperatingPoint,
}

pub fn rows() -> Vec<T1Row> {
    let h = ManualProfile::h100_70b();
    let b = ManualProfile::b200_70b();
    let hs = context_sweep(&h, &CONTEXTS, PowerAccounting::PerGpu);
    let bs = context_sweep(&b, &CONTEXTS, PowerAccounting::PerGpu);
    CONTEXTS
        .iter()
        .zip(hs.into_iter().zip(bs))
        .map(|(&context, (h100, b200))| T1Row { context, h100, b200 })
        .collect()
}

/// The typed rowset behind the table: raw values for CSV/JSON, the
/// paper's formatting conventions kept as display overrides.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 1 — n_max and tok/W vs context window, Llama-3.1-70B TP8 fp16 \
         (ours vs paper)",
        vec![
            Column::str("Context"),
            Column::int("h100 n_max"),
            Column::float("h100 P_sat").with_unit("W"),
            Column::float("h100 tok/W").with_unit("tok/J"),
            Column::float("h100 paper tok/W").with_unit("tok/J"),
            Column::int("b200 n_max"),
            Column::float("b200 P_sat").with_unit("W"),
            Column::float("b200 tok/W").with_unit("tok/J"),
            Column::float("b200 paper tok/W").with_unit("tok/J"),
        ],
    );
    for (r, p) in rows().iter().zip(PAPER.iter()) {
        rs.push(vec![
            Cell::str(ctx_k(r.context)),
            Cell::int(r.h100.n_max as i64),
            Cell::float(r.h100.power.0)
                .shown(format!("{} W", f0(r.h100.power.0))),
            Cell::float(r.h100.tok_per_watt.0).shown(tokw(r.h100.tok_per_watt.0)),
            Cell::float(p.3).shown(tokw(p.3)),
            Cell::int(r.b200.n_max as i64),
            Cell::float(r.b200.power.0)
                .shown(format!("{} W", f0(r.b200.power.0))),
            Cell::float(r.b200.tok_per_watt.0).shown(tokw(r.b200.tok_per_watt.0)),
            Cell::float(p.6).shown(tokw(p.6)),
        ]);
    }
    rs.note("cols 2-5: H100-SXM5 (HIGH quality, calibrated); cols 6-9: B200-SXM (FAIR, ±20%)");
    rs.note("'paper' columns are the published values for side-by-side comparison");
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_row_within_3pct_of_paper() {
        for (r, p) in rows().iter().zip(PAPER.iter()) {
            let h_err = (r.h100.tok_per_watt.0 - p.3).abs() / p.3;
            let b_err = (r.b200.tok_per_watt.0 - p.6).abs() / p.6;
            assert!(h_err < 0.015, "H100 ctx {}: err {h_err}", r.context);
            assert!(b_err < 0.03, "B200 ctx {}: err {b_err}", r.context);
            assert_eq!(r.h100.n_max, p.1, "H100 n_max at {}", r.context);
        }
    }

    #[test]
    fn renders_all_contexts() {
        let s = generate();
        for ctx in ["2K", "4K", "8K", "16K", "32K", "64K", "128K"] {
            assert!(s.contains(ctx), "missing {ctx} row");
        }
    }

    #[test]
    fn rowset_carries_raw_values_for_machine_formats() {
        let rs = rowset();
        assert_eq!(rs.rows().len(), CONTEXTS.len());
        let csv = rs.to_csv();
        // Units live in the header; cells are full-precision floats.
        assert!(csv.starts_with("Context,h100 n_max,h100 P_sat (W),"));
        let parsed =
            crate::runtime::json::parse(&rs.to_json()).expect("valid JSON");
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(
            rows[0].get("h100 n_max").unwrap().as_f64(),
            Some(super::rows()[0].h100.n_max as f64)
        );
    }
}
